"""Convergence tests for the scenario-opening strategies.

  * PartialParticipation with full participation IS GradientTracking —
    exactly (the sampling machinery is elided at trace time);
  * CompressedGT with a 100% compression ratio IS GradientTracking —
    exactly (compression and error feedback are elided at trace time);
  * with real sampling / real sparsification both still converge on the
    strongly-convex-strongly-concave quadratic (to a small noise floor —
    the exact-limit property is FedGDA-GT's, Theorem 1), and error
    feedback demonstrably tightens the compressed floor.

Everything here is deterministic: fixed seeds, fixed trace-time shapes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_round, run_strategy_rounds, tree_sq_dist
from repro.fed import CompressedGT, GradientTracking, PartialParticipation
from repro.problems import make_quadratic_problem, quadratic_minimax_point

M, DIM, K, ETA, T = 8, 6, 4, 2e-4, 1500


@pytest.fixture(scope="module")
def quad():
    prob = make_quadratic_problem(
        jax.random.PRNGKey(0), dim=DIM, num_samples=40, num_agents=M
    )
    x_star, y_star = quadratic_minimax_point(prob)
    return prob, x_star, y_star


def _final_gap(prob, x_star, y_star, strategy, rounds=T):
    def gap(x, y):
        return {"gap": tree_sq_dist(x, x_star) + tree_sq_dist(y, y_star)}

    x0 = jnp.zeros(DIM)
    rnd = jax.jit(make_round(prob.loss, strategy, K, ETA, explicit_state=True))
    state0 = strategy.init_state(x0, x0, M)
    (_, _, _), metrics = run_strategy_rounds(
        rnd, x0, x0, prob.agent_data, rounds, state0, gap
    )
    g = np.asarray(metrics["gap"])
    return float(g[0]), float(g[-1])


def _rounds_equal(prob, strat_a, strat_b, rounds=5):
    ra = jax.jit(make_round(prob.loss, strat_a, K, ETA))
    rb = jax.jit(make_round(prob.loss, strat_b, K, ETA))
    xa = xb = jnp.ones(DIM)
    ya = yb = -jnp.ones(DIM)
    for t in range(rounds):
        xa, ya = ra(xa, ya, prob.agent_data)
        xb, yb = rb(xb, yb, prob.agent_data)
        assert bool(jnp.all(xa == xb)), f"x diverges at round {t}"
        assert bool(jnp.all(ya == yb)), f"y diverges at round {t}"


# ------------------------------------------------- identity configurations
class TestIdentityConfigurations:
    def test_full_participation_equals_gradient_tracking_exactly(self, quad):
        prob, _, _ = quad
        _rounds_equal(
            prob, PartialParticipation(participation=1.0), GradientTracking()
        )

    def test_dense_compression_equals_gradient_tracking_exactly(self, quad):
        prob, _, _ = quad
        for mode in ("topk", "randk"):
            _rounds_equal(
                prob,
                CompressedGT(compression_ratio=1.0, mode=mode),
                GradientTracking(),
            )

    def test_identity_configurations_are_stateless(self):
        assert not PartialParticipation(participation=1.0).stateful
        assert not CompressedGT(compression_ratio=1.0).stateful
        assert PartialParticipation(participation=0.5).stateful
        assert CompressedGT(compression_ratio=0.5).stateful


# --------------------------------------------------------- convergence
class TestConvergence:
    def test_gradient_tracking_converges_to_exact_point(self, quad):
        prob, xs, ys = quad
        g0, gT = _final_gap(prob, xs, ys, GradientTracking())
        assert gT < 1e-9 * g0  # linear rate, constant stepsize (Theorem 1)

    def test_partial_participation_converges(self, quad):
        prob, xs, ys = quad
        g0, gT = _final_gap(
            prob, xs, ys, PartialParticipation(participation=0.5, seed=0)
        )
        # unbiased sampling: converges to a small noise floor
        assert g0 > 1e2 and gT < 1e-1

    @pytest.mark.parametrize("mode", ["topk", "randk"])
    def test_compressed_gt_converges(self, quad, mode):
        prob, xs, ys = quad
        g0, gT = _final_gap(
            prob,
            xs,
            ys,
            CompressedGT(compression_ratio=0.5, mode=mode, seed=0),
        )
        assert g0 > 1e2 and gT < 1e-1

    def test_error_feedback_tightens_the_floor(self, quad):
        prob, xs, ys = quad
        _, g_ef = _final_gap(
            prob, xs, ys, CompressedGT(compression_ratio=0.5, mode="topk")
        )
        _, g_noef = _final_gap(
            prob,
            xs,
            ys,
            CompressedGT(
                compression_ratio=0.5, mode="topk", error_feedback=False
            ),
        )
        assert g_ef < g_noef / 10.0


# ----------------------------------------------------- mechanism checks
class TestMechanisms:
    def test_sample_weights_are_an_unbiased_reweighting(self):
        s = PartialParticipation(participation=0.5, seed=3)
        state = s.init_state(jnp.zeros(2), jnp.zeros(2), 8)
        w, state = s.sample_weights(state, 8)
        w = np.asarray(w)
        assert w.shape == (8,)
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-12)
        assert (w > 0).sum() == 4  # S = round(0.5 * 8)
        # successive rounds draw different subsets (the RNG key advances)
        w2, _ = s.sample_weights(state, 8)
        assert not np.array_equal(np.asarray(w2), w)

    def test_topk_keeps_largest_and_feedback_stores_rest(self):
        s = CompressedGT(compression_ratio=0.5, mode="topk")
        m, n = 2, 4
        cx = jnp.asarray([[4.0, -3.0, 0.5, 0.25], [1.0, 2.0, -8.0, 0.125]])
        cy = jnp.zeros((m, 1))
        state = s.init_state(jnp.zeros(n), jnp.zeros(1), m)
        cx2, cy2, state = s.transform_correction(cx, cy, state)
        np.testing.assert_allclose(
            np.asarray(cx2),
            [[4.0, -3.0, 0.0, 0.0], [0.0, 2.0, -8.0, 0.0]],
        )
        # feedback buffer holds exactly what compression dropped
        np.testing.assert_allclose(
            np.asarray(state["ex"]), np.asarray(cx - cx2)
        )

    def test_topk_keeps_exactly_k_under_ties(self):
        """Tied magnitudes (including all-zero rows) must not inflate the
        kept fraction beyond what bytes_per_round prices."""
        s = CompressedGT(compression_ratio=0.5, mode="topk")
        cx = jnp.asarray([[1.0, 1.0, 1.0, 1.0], [0.0, 0.0, 0.0, 0.0]])
        cy = jnp.zeros((2, 1))
        state = s.init_state(jnp.zeros(4), jnp.zeros(1), 2)
        cx2, _, _ = s.transform_correction(cx, cy, state)
        kept = np.asarray(jnp.sum(cx2 != 0, axis=1))
        assert kept[0] == 2  # k = ceil(0.5 * 4), not all 4 tied entries
        assert kept[1] == 0  # zero row stays zero (not dense!)
