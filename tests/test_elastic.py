"""Elastic correctness: membership-aware rounds vs the seed engine.

The acceptance-grade facts pinned here:
  * a full-participation Population reproduces the existing
    `FederatedRunner` BITWISE for all six strategy families (the
    static-full schedule degenerates to the unmodified legacy path);
  * under flaky Markov churn, FedGDA-GT with tracker rebasing reaches
    eps = 1e-6 on the quadratic game while the naive no-rebase server
    (1/m weights over the full registry) never does;
  * the membership-aware round's tracker table keeps the GT invariant —
    corrections sum to the tracked global gradient gap — on every
    round, full or partial;
  * straggler budgets gate local steps exactly (an agent with budget b
    takes b steps, an absent agent takes none);
  * error-feedback residuals of non-continuing agents are re-anchored
    to zero by `rebase_state`, and departed agents contribute zero wire
    bytes (`sim.schedule_bytes`);
  * the async runner consumes the same schedule and matches the sync
    elastic iterates to fp tolerance (multihost-marked).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_round, tree_sq_dist
from repro.core.engine import agent_mean
from repro.fed import (
    CompressedGT,
    FederatedRunner,
    FullSync,
    GradientTracking,
    LocalOnly,
    PartialParticipation,
    QuantizedGT,
)
from repro.problems import make_quadratic_problem, quadratic_minimax_point
from repro.sim import (
    AlwaysOn,
    ElasticAggregator,
    MarkovChurn,
    NoStragglers,
    Population,
    UniformStragglers,
    init_tracker,
    make_elastic_round,
    make_population,
    renormalized_weights,
    schedule_bytes,
)

pytestmark = pytest.mark.sim

ETA = 1e-4


def _problem(m=8, dim=16, samples=40):
    return make_quadratic_problem(
        jax.random.PRNGKey(0), dim=dim, num_samples=samples, num_agents=m
    )


STRATEGIES = [
    ("full_sync", FullSync(), 1),
    ("local_only", LocalOnly(), 5),
    ("gradient_tracking", GradientTracking(), 5),
    ("partial_participation", PartialParticipation(participation=0.5, seed=0), 5),
    ("compressed_gt", CompressedGT(compression_ratio=0.25, seed=0), 5),
    ("quantized_gt", QuantizedGT(bits=8, seed=0), 5),
]


# ------------------------------------------- full participation == bitwise
class TestFullParticipationParity:
    @pytest.mark.parametrize("name,strategy,K", STRATEGIES,
                             ids=[s[0] for s in STRATEGIES])
    def test_stable_population_bitwise_equals_plain_runner(
        self, name, strategy, K
    ):
        prob = _problem()
        x0 = jnp.zeros(16)
        T = 7
        plain = FederatedRunner.from_strategy(
            prob.loss, strategy, prob.agent_data, K, ETA
        )
        xa, ya = plain.run(x0, x0, T)
        sched = make_population("stable", prob.num_agents).schedule(0, T, K)
        assert sched.is_static_full
        elastic = FederatedRunner.from_strategy(
            prob.loss, strategy, prob.agent_data, K, ETA
        )
        xb, yb = elastic.run(x0, x0, T, schedule=sched)
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
        np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))

    def test_full_round_elastic_math_matches_engine_round(self):
        """`make_elastic_round` on an all-active round IS the engine's
        GT round up to fp noise (the tracker table holds this round's
        fresh gradients, so gbar and the corrections agree)."""
        prob = _problem()
        m, K = prob.num_agents, 4
        strat = GradientTracking()
        rnd = jax.jit(make_round(prob.loss, strat, K, ETA))
        ernd = jax.jit(make_elastic_round(prob.loss, strat, K, ETA))
        x = jnp.ones(16)
        y = -jnp.ones(16)
        tracker = init_tracker(prob.loss, strat, x, y, prob.agent_data)
        active = jnp.ones((m,), bool)
        weights = renormalized_weights(active)
        budgets = jnp.full((m,), K, jnp.int32)
        x1, y1 = rnd(x, y, prob.agent_data)
        xe, ye, _, _ = ernd(
            x, y, prob.agent_data, {}, tracker, weights, budgets, active,
            jnp.ones((m,), bool),
        )
        np.testing.assert_allclose(np.asarray(x1), np.asarray(xe), rtol=1e-12)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(ye), rtol=1e-12)


# ------------------------------------------------------ rebase vs naive
class TestTrackerRebase:
    def _flaky_run(self, rebase, T=500):
        prob = _problem(m=8, dim=16, samples=100)
        xs, ys = quadratic_minimax_point(prob)

        def gap(x, y):
            return {"gap": tree_sq_dist(x, xs) + tree_sq_dist(y, ys)}

        sched = Population(
            8, MarkovChurn(p_leave=0.25, p_join=0.6), NoStragglers()
        ).schedule(0, T, 10)
        assert not sched.is_static_full and sched.churn_events() > 0
        r = FederatedRunner.from_strategy(
            prob.loss, GradientTracking(), prob.agent_data, 10, ETA,
            metric_fn=gap,
        )
        r.run(jnp.zeros(16), jnp.zeros(16), T, schedule=sched, rebase=rebase)
        return np.asarray(r.metric_series("gap"))

    def test_rebase_recovers_exact_convergence_under_churn(self):
        """The acceptance claim: eps = 1e-6 is reached under persistent
        join/leave churn WITH membership-aware rebasing..."""
        gaps = self._flaky_run(rebase=True)
        assert gaps.min() <= 1e-6, f"min gap {gaps.min():.3e}"
        # and it is genuine exact convergence, not a lucky dip
        assert gaps[-1] <= 1e-6

    def test_no_rebase_ablation_stalls(self):
        """...while the naive server (stale 1/m weights) never gets
        close: the aggregate loses the departed agents' mass every
        partial round."""
        gaps = self._flaky_run(rebase=False)
        assert gaps.min() > 1e-3, f"min gap {gaps.min():.3e}"

    def test_tracker_keeps_gt_invariant_each_round(self):
        """The GT invariant the rebase restores: the corrections the
        round steps with sum (uniformly) to zero around the tracked
        global gradient — gbar == mean(table) by construction, on full
        AND partial rounds."""
        prob = _problem(m=6)
        strat = GradientTracking()
        x = jnp.ones(16)
        y = -jnp.ones(16)
        tracker = init_tracker(prob.loss, strat, x, y, prob.agent_data)
        # partial round: agents {0, 2, 3} present
        active = jnp.asarray([True, False, True, True, False, False])
        ernd = jax.jit(make_elastic_round(prob.loss, strat, 3, ETA))
        _, _, _, tracker = ernd(
            x, y, prob.agent_data, {}, tracker,
            renormalized_weights(active),
            jnp.where(active, 3, 0).astype(jnp.int32), active,
            jnp.ones((6,), bool),
        )
        gbar = agent_mean(tracker["gx"], None)
        corr_sum = jnp.mean(gbar[None] - tracker["gx"], axis=0)
        np.testing.assert_allclose(
            np.asarray(corr_sum), np.zeros(16), atol=1e-12
        )


# ---------------------------------------------------------- step budgets
class TestStepBudgets:
    def test_budget_gates_local_steps_exactly(self):
        """LocalOnly with per-agent budgets: agent i's pre-aggregate
        iterate equals exactly budget_i manual GDA steps from the
        broadcast point; absent agents never move."""
        prob = _problem(m=4)
        K = 4
        x = jnp.ones(16)
        y = -jnp.ones(16)
        active = jnp.asarray([True, True, True, False])
        budgets = jnp.asarray([4, 1, 2, 0], jnp.int32)
        weights = renormalized_weights(active)

        ernd = jax.jit(make_elastic_round(prob.loss, LocalOnly(), K, ETA))
        x1, y1, _, _ = ernd(
            x, y, prob.agent_data, {}, {}, weights, budgets, active, None
        )

        from repro.core.types import grad_xy

        g = grad_xy(prob.loss)
        xs_exp, ys_exp = [], []
        for i in range(4):
            data_i = jax.tree.map(lambda u: u[i], prob.agent_data)
            xi, yi = x, y
            for _ in range(int(budgets[i])):
                gi = g(xi, yi, data_i)
                xi = xi - ETA * gi.gx
                yi = yi + ETA * gi.gy
            xs_exp.append(xi)
            ys_exp.append(yi)
        w = np.asarray(weights)
        x_exp = sum(w[i] * np.asarray(xs_exp[i]) for i in range(4))
        y_exp = sum(w[i] * np.asarray(ys_exp[i]) for i in range(4))
        np.testing.assert_allclose(np.asarray(x1), x_exp, rtol=1e-10)
        np.testing.assert_allclose(np.asarray(y1), y_exp, rtol=1e-10)

    def test_straggler_run_still_converges_exactly(self):
        """Budget caps change the path, not the fixed point: FedGDA-GT
        under heavy stragglers still drives the gap to eps (at the
        minimax point every local step is zero, budgeted or not)."""
        prob = _problem(m=8, dim=16, samples=100)
        xs, ys = quadratic_minimax_point(prob)

        def gap(x, y):
            return {"gap": tree_sq_dist(x, xs) + tree_sq_dist(y, ys)}

        sched = Population(
            8,
            availability=AlwaysOn(),
            stragglers=UniformStragglers(p_straggle=0.7, min_frac=0.25),
        ).schedule(0, 600, 10)
        r = FederatedRunner.from_strategy(
            prob.loss, GradientTracking(), prob.agent_data, 10, ETA,
            metric_fn=gap,
        )
        r.run(jnp.zeros(16), jnp.zeros(16), 600, schedule=sched)
        assert np.asarray(r.metric_series("gap"))[-1] <= 1e-6


# ----------------------------------------------- EF rebasing + wire bytes
class TestStateAndBytes:
    def test_rebase_state_zeroes_non_continuing_ef_rows(self):
        strat = CompressedGT(compression_ratio=0.25)
        m = 6
        x = jnp.ones(16)
        state = strat.init_state(x, x, m)
        # fill the buffers with sentinels
        state["ex"] = jnp.ones((m, 16))
        state["ey"] = 2.0 * jnp.ones((m, 16))
        active = jnp.asarray([True, True, False, True, False, True])
        prev = jnp.asarray([True, False, True, True, False, False])
        out = strat.rebase_state(state, active, prev)
        keep = np.asarray(active & prev)  # only continuing agents
        np.testing.assert_array_equal(
            np.asarray(out["ex"])[keep], np.ones((keep.sum(), 16))
        )
        np.testing.assert_array_equal(
            np.asarray(out["ex"])[~keep], np.zeros(((~keep).sum(), 16))
        )
        np.testing.assert_array_equal(
            np.asarray(out["ey"])[~keep], np.zeros(((~keep).sum(), 16))
        )
        # the aggregator only applies it when rebasing
        agg = ElasticAggregator(strat, rebase=False)
        untouched = agg.rebase_state(dict(state), active, prev)
        np.testing.assert_array_equal(
            np.asarray(untouched["ex"]), np.asarray(state["ex"])
        )

    def test_elastic_resume_matches_uninterrupted_run(self):
        """Checkpoint/resume contract: continuing with the saved
        tracker + prev_active (and the schedule tail) reproduces the
        uninterrupted elastic run EXACTLY; resuming without the elastic
        state does not (the tracker re-anchors and EF rebase forgets
        who was absent)."""
        prob = _problem(m=6)
        strat = CompressedGT(compression_ratio=0.5, seed=0)
        sched = Population(
            6, MarkovChurn(p_leave=0.3, p_join=0.5), NoStragglers()
        ).schedule(1, 12, 4)
        assert not sched.is_static_full
        x0 = jnp.zeros(16)

        full = FederatedRunner.from_strategy(
            prob.loss, strat, prob.agent_data, 4, ETA
        )
        xf, yf = full.run(x0, x0, 12, schedule=sched)

        part = FederatedRunner.from_strategy(
            prob.loss, strat, prob.agent_data, 4, ETA
        )
        xm, ym = part.run(x0, x0, 6, schedule=sched)
        xr, yr = part.run(
            xm, ym, 6, schedule=sched.tail(6),
            elastic_state=part.elastic_state,
        )
        np.testing.assert_array_equal(np.asarray(xf), np.asarray(xr))
        np.testing.assert_array_equal(np.asarray(yf), np.asarray(yr))

        naive = FederatedRunner.from_strategy(
            prob.loss, strat, prob.agent_data, 4, ETA
        )
        xm2, ym2 = naive.run(x0, x0, 6, schedule=sched)
        xn, yn = naive.run(xm2, ym2, 6, schedule=sched.tail(6))
        assert (np.asarray(xf) != np.asarray(xn)).any()

    def test_runner_rejects_wrong_population_size(self):
        """A schedule built for a different m must fail loudly — a
        larger-m schedule would renormalize weights over phantom agents
        and silently lose their mass when sliced."""
        prob = _problem(m=4)
        sched = make_population("flaky", 6).schedule(0, 5, 3)
        r = FederatedRunner.from_strategy(
            prob.loss, GradientTracking(), prob.agent_data, 3, ETA
        )
        with pytest.raises(ValueError, match="m=6"):
            r.run(jnp.zeros(16), jnp.zeros(16), 5, schedule=sched)

    def test_partial_participation_bytes_not_double_discounted(self):
        """Under a schedule the strategy's own sampling is bypassed, so
        PartialParticipation's per-agent price must be the FULL
        gradient-tracking payload, active-count-scaled once."""
        x0 = jnp.zeros(16)
        sched = make_population("stable", 4).schedule(0, 2, 3)
        pp = schedule_bytes(
            PartialParticipation(participation=0.5), x0, x0, 3, sched
        )
        gt = schedule_bytes(GradientTracking(), x0, x0, 3, sched)
        assert pp == gt

    def test_schedule_rejects_empty_rounds(self):
        from repro.sim import RoundSchedule

        active = np.array([[1, 1], [0, 0], [1, 0]], bool)
        budgets = np.where(active, 3, 0).astype(np.int32)
        with pytest.raises(ValueError, match="no active agents"):
            RoundSchedule(active, budgets, 3)

    def test_gradient_tracking_rebase_state_is_noop(self):
        strat = GradientTracking()
        state = {"anything": jnp.ones(3)}
        out = strat.rebase_state(state, jnp.asarray([True, False]))
        assert out is state

    def test_departed_agents_contribute_zero_bytes(self):
        prob = _problem(m=4)
        x0 = jnp.zeros(16)
        strat = GradientTracking()
        K = 5
        full = make_population("stable", 4).schedule(0, 3, K)
        per_round_full = schedule_bytes(strat, x0, x0, K, full)
        active = np.array([[1, 1, 1, 1], [1, 0, 1, 0], [0, 0, 1, 0]], bool)
        from repro.sim import RoundSchedule

        part = RoundSchedule(active, np.where(active, K, 0), K)
        per_round_part = schedule_bytes(strat, x0, x0, K, part)
        per_agent = per_round_full[0] // 4
        assert per_round_part == [4 * per_agent, 2 * per_agent, 1 * per_agent]

    @pytest.mark.skipif(
        __import__("importlib").util.find_spec("hypothesis") is None,
        reason="needs hypothesis",
    )
    def test_bytes_scale_with_active_count_property(self):
        from hypothesis import given, settings
        from hypothesis import strategies as st

        prob_x = jnp.zeros(16)
        strat = QuantizedGT(bits=8)

        @given(rows=st.lists(st.integers(0, 2**6 - 1), min_size=1,
                             max_size=8))
        @settings(max_examples=25, deadline=None)
        def inner(rows):
            from repro.sim import RoundSchedule

            active = np.array(
                [[(r >> i) & 1 for i in range(6)] for r in rows], bool
            )
            active[:, 0] |= ~active.any(axis=1)  # keep rounds nonempty
            sched = RoundSchedule(active, np.where(active, 3, 0), 3)
            per = schedule_bytes(strat, prob_x, prob_x, 3, sched)
            per_agent = schedule_bytes(
                strat, prob_x, prob_x, 3,
                RoundSchedule(
                    np.ones((1, 6), bool), np.full((1, 6), 3), 3
                ),
            )[0] // 6
            assert per == [per_agent * int(a.sum()) for a in active]

        inner()


# ------------------------------------------------------------ async parity
@pytest.mark.multihost
class TestAsyncElasticParity:
    @pytest.mark.parametrize(
        "strategy,K",
        [
            (GradientTracking(), 5),
            (LocalOnly(), 5),
            (FullSync(), 1),
            (CompressedGT(compression_ratio=0.5, seed=0), 4),
            (QuantizedGT(bits=8, seed=0), 4),
        ],
        ids=["gt", "local", "fullsync", "compressed", "quantized"],
    )
    def test_async_matches_sync_elastic(self, fed_devices, strategy, K):
        from repro.fed import AsyncFederatedRunner

        prob = _problem(m=8)
        x0 = jnp.zeros(16)
        T = 10
        pop = Population(
            8,
            MarkovChurn(p_leave=0.25, p_join=0.6),
            UniformStragglers(p_straggle=0.5, min_frac=0.4),
        )
        sched = pop.schedule(3, T, K)
        assert not sched.is_static_full
        sr = FederatedRunner.from_strategy(
            prob.loss, strategy, prob.agent_data, K, ETA
        )
        xs_, ys_ = sr.run(x0, x0, T, schedule=sched)
        ar = AsyncFederatedRunner(
            prob.loss, strategy, prob.agent_data, K, ETA,
            devices=fed_devices,
        )
        xa, ya = ar.run(x0, x0, T, schedule=sched)
        assert ar._n_shards > 1
        np.testing.assert_allclose(
            np.asarray(xs_), np.asarray(xa), rtol=0, atol=1e-12
        )
        np.testing.assert_allclose(
            np.asarray(ys_), np.asarray(ya), rtol=0, atol=1e-12
        )

    def test_async_split_run_resumes_exactly(self, fed_devices):
        """The async continuation contract: a run split in two and
        resumed with `elastic_state` + the schedule tail matches the
        uninterrupted async run exactly (EF state persists on the
        shards; tracker + prev_active ride through elastic_state)."""
        from repro.fed import AsyncFederatedRunner

        prob = _problem(m=8)
        strat = CompressedGT(compression_ratio=0.5, seed=0)
        sched = Population(
            8, MarkovChurn(p_leave=0.3, p_join=0.5), NoStragglers()
        ).schedule(1, 12, 4)
        x0 = jnp.zeros(16)
        full = AsyncFederatedRunner(
            prob.loss, strat, prob.agent_data, 4, ETA, devices=fed_devices
        )
        xf, yf = full.run(x0, x0, 12, schedule=sched)
        part = AsyncFederatedRunner(
            prob.loss, strat, prob.agent_data, 4, ETA, devices=fed_devices
        )
        xm, ym = part.run(x0, x0, 6, schedule=sched)
        xr, yr = part.run(
            xm, ym, 6, schedule=sched.tail(6),
            elastic_state=part.elastic_state,
        )
        np.testing.assert_array_equal(np.asarray(xf), np.asarray(xr))
        np.testing.assert_array_equal(np.asarray(yf), np.asarray(yr))

    def test_async_consumes_identical_membership(self, fed_devices):
        """Satellite: both runtimes record the same per-round active
        counts when handed schedules built independently from the same
        config + seed (the dedicated-fold reproducibility contract,
        observed end to end)."""
        from repro.fed import AsyncFederatedRunner

        prob = _problem(m=8)
        x0 = jnp.zeros(16)
        T = 8
        s1 = make_population("flaky", 8).schedule(11, T, 4)
        s2 = make_population("flaky", 8).schedule(11, T, 4)
        np.testing.assert_array_equal(s1.trace()["active"], s2.trace()["active"])
        sr = FederatedRunner.from_strategy(
            prob.loss, GradientTracking(), prob.agent_data, 4, ETA
        )
        sr.run(x0, x0, T, schedule=s1)
        ar = AsyncFederatedRunner(
            prob.loss, GradientTracking(), prob.agent_data, 4, ETA,
            devices=fed_devices,
        )
        ar.run(x0, x0, T, schedule=s2)
        np.testing.assert_array_equal(
            sr.metric_series("n_active"), ar.metric_series("n_active")
        )
