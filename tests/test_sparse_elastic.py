"""O(active) sparse elastic + the two-level pod aggregation tree.

The acceptance-grade facts pinned here (PR: million-agent elastic runs):
  * `ChunkedRoundSchedule` generates the SAME rounds as the dense
    builder bit-for-bit — including across chunk boundaries for the
    stateful `MarkovChurn` carry, under random access, and for resumed
    tails;
  * the streaming statistics (`participation_rate`, `churn_events`,
    `summary_trace`) agree across dense / chunked / sparse
    representations of the same rounds, without densifying;
  * `SparseRoundSchedule` events scatter (`to_dense` / `densify`) into
    exactly the dense schedule the parity runs consume, and `tail`
    reports churn at the resume seam against what actually ran;
  * `SparseElasticEngine` at small m routes through the dense elastic
    machinery BITWISE (dense fallback) for all six strategy families,
    and the genuinely-sparse path matches the dense reference to fp
    tolerance for the deterministic-draw families (RNG-shaped draws —
    stochastic rounding — are excluded by construction: they consume
    [n·rows] instead of [m·rows] uniforms);
  * resume via `schedule.tail(t)` + `resume=True` is bitwise equal to
    the uninterrupted run on both the sparse and fallback paths;
  * the pod tree (`pod_weighted_sums` -> `pods_total`) equals the flat
    weighted mean to fp tolerance (property-tested), quiet pods are
    exact zero rows, and `fed.pods.encode_pod_partials` round-trips
    bitwise through the packed transport;
  * `schedule_bytes` with pods prices per-agent + per-live-pod traffic
    streamingly, priced == measured, identically for sparse and
    densified schedules;
  * `realign_state_rows` re-gathers EF residual rows across id layouts
    (continuing agents keep rows, everyone else restarts at zero);
  * `benchmarks.common.peak_memory` reports a host allocation peak that
    actually covers the allocation it measured (the primitive behind
    the 1e6-agent O(active) memory gate).
"""
import importlib.util

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import pod_weighted_sums, pods_total
from repro.fed import (
    CompressedGT,
    FederatedRunner,
    FullSync,
    GradientTracking,
    LocalOnly,
    PartialParticipation,
    QuantizedGT,
)
from repro.fed.pods import (
    decode_pod_partials,
    encode_pod_partials,
    pod_aligned_shard_count,
    pod_payload_bytes,
)
from repro.problems import make_quadratic_problem
from repro.sim import (
    ArrayDataSource,
    BernoulliAvailability,
    MarkovChurn,
    PodMap,
    Population,
    SparseElasticEngine,
    UniformActiveSubset,
    UniformStragglers,
    schedule_bytes,
)

pytestmark = [pytest.mark.sim, pytest.mark.pods]

ETA = 1e-4
M, T, K = 8, 6, 5
ACTIVE = 4

_HAS_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


def _problem(m=M, dim=16, samples=40):
    return make_quadratic_problem(
        jax.random.PRNGKey(0), dim=dim, num_samples=samples, num_agents=m
    )


def _sparse_pop(m=M, size=ACTIVE, pods=0):
    return Population(
        m,
        UniformActiveSubset(size=size),
        UniformStragglers(p_straggle=0.5, min_frac=0.4),
        pods=pods,
    )


STRATEGIES = [
    ("full_sync", FullSync(), 1),
    ("local_only", LocalOnly(), 5),
    ("gradient_tracking", GradientTracking(), 5),
    ("partial_participation", PartialParticipation(participation=0.5, seed=0), 5),
    ("compressed_gt", CompressedGT(compression_ratio=0.25, seed=0), 5),
    ("quantized_gt", QuantizedGT(bits=8, seed=0), 5),
]
# deterministic-draw families for the genuinely-sparse fp parity:
# QuantizedGT's stochastic rounding draws one uniform per CARRIED row,
# so [n_active·rows] vs [m·rows] streams diverge by construction
SPARSE_PARITY = [s for s in STRATEGIES if s[0] != "quantized_gt"]


def _events_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.active), np.asarray(b.active))
    np.testing.assert_array_equal(np.asarray(a.budgets), np.asarray(b.budgets))
    np.testing.assert_array_equal(np.asarray(a.joined), np.asarray(b.joined))
    np.testing.assert_array_equal(
        np.asarray(a.departed), np.asarray(b.departed)
    )
    assert a.full == b.full and a.index == b.index


# ------------------------------------------------- chunked == dense bitwise
class TestChunkedSchedule:
    CHURN = [
        MarkovChurn(p_leave=0.3, p_join=0.5),  # stateful: carry threads
        BernoulliAvailability(p=0.6),          # stateless per-round fold
    ]

    @pytest.mark.parametrize("avail", CHURN, ids=lambda p: type(p).__name__)
    def test_chunked_rounds_match_dense_bitwise(self, avail):
        """Every chunked event equals the dense builder's, with chunk
        boundaries that do NOT divide T (carry crosses them)."""
        pop = Population(12, avail, UniformStragglers(0.7, 0.3))
        dense = pop.schedule(0, 40, K)
        ch = pop.chunked_schedule(0, 40, K, chunk_rounds=7)
        assert len(ch) == len(dense) and ch.m == dense.m
        for t in range(40):
            _events_equal(ch[t], dense[t])

    def test_chunked_random_access_replays_from_checkpoints(self):
        pop = Population(10, MarkovChurn(0.2, 0.6), UniformStragglers())
        dense = pop.schedule(3, 30, K)
        ch = pop.chunked_schedule(3, 30, K, chunk_rounds=4)
        # jump straight to a late block, then back behind the carry
        _events_equal(ch[27], dense[27])
        _events_equal(ch[2], dense[2])
        _events_equal(ch[15], dense[15])

    def test_chunked_tail_continues_the_trajectory(self):
        pop = Population(12, MarkovChurn(0.3, 0.5), UniformStragglers())
        dense = pop.schedule(0, 40, K)
        tail = pop.chunked_schedule(0, 40, K, chunk_rounds=7).tail(13)
        dtail = dense.tail(13)
        assert len(tail) == len(dtail)
        for t in range(len(tail)):
            _events_equal(tail[t], dtail[t])

    def test_chunked_materialize_equals_dense_trace(self):
        pop = Population(12, MarkovChurn(0.3, 0.5), UniformStragglers())
        a = pop.schedule(0, 40, K).trace()
        b = pop.chunked_schedule(0, 40, K, chunk_rounds=9).materialize().trace()
        np.testing.assert_array_equal(a["active"], b["active"])
        np.testing.assert_array_equal(a["budgets"], b["budgets"])


# ----------------------------------------------- streaming statistics parity
class TestStreamingStats:
    def test_stats_agree_dense_vs_chunked(self):
        pop = Population(12, MarkovChurn(0.3, 0.5), UniformStragglers())
        dense = pop.schedule(0, 40, K)
        ch = pop.chunked_schedule(0, 40, K, chunk_rounds=7)
        assert ch.participation_rate() == pytest.approx(
            dense.participation_rate(), abs=1e-15
        )
        assert ch.churn_events() == dense.churn_events()
        a, b = dense.summary_trace(), ch.summary_trace()
        for k in ("num_active", "budget_total", "active_digest"):
            np.testing.assert_array_equal(a[k], b[k])

    def test_sparse_summary_matches_densified(self):
        """The CRC digest is over SORTED ACTIVE IDS — representation-
        independent, so a sparse schedule and its densification summarize
        identically without either touching the other's layout."""
        sp = _sparse_pop().sparse_schedule(0, T, K)
        de = sp.densify()
        a, b = sp.summary_trace(), de.summary_trace()
        for k in ("num_active", "budget_total", "active_digest"):
            np.testing.assert_array_equal(a[k], b[k])
        assert sp.participation_rate() == pytest.approx(ACTIVE / M, abs=1e-15)
        assert sp.churn_events() == de.churn_events()


# ----------------------------------------------- sparse schedule contract
class TestSparseScheduleContract:
    def test_events_scatter_to_the_densified_schedule(self):
        sp = _sparse_pop().sparse_schedule(0, T, K)
        de = sp.densify()
        for t in range(T):
            _events_equal(sp[t].to_dense(K), de[t])

    def test_event_contract(self):
        sp = _sparse_pop().sparse_schedule(0, T, K)
        for ev in sp:
            ids = ev.active_ids
            assert ids.dtype == np.int64
            assert (np.diff(ids) > 0).all()  # sorted unique
            assert ev.num_active == ACTIVE
            assert (ev.budgets >= 1).all() and (ev.budgets <= K).all()

    def test_tail_reports_churn_at_the_seam(self):
        sp = _sparse_pop().sparse_schedule(0, T, K)
        tail = sp.tail(3)
        np.testing.assert_array_equal(tail[0].active_ids, sp[3].active_ids)
        np.testing.assert_array_equal(tail[0].prev_ids, sp[2].active_ids)
        np.testing.assert_array_equal(
            tail[0].joined_ids,
            np.setdiff1d(sp[3].active_ids, sp[2].active_ids),
        )
        np.testing.assert_array_equal(
            tail[0].departed_ids,
            np.setdiff1d(sp[2].active_ids, sp[3].active_ids),
        )
        # a fresh sparse schedule has no predecessor: empty churn report
        assert sp[0].prev_ids is None and len(sp[0].joined_ids) == 0

    def test_dense_process_is_rejected(self):
        pop = Population(M, MarkovChurn(), UniformStragglers())
        with pytest.raises(TypeError, match="SparseAvailability"):
            pop.sparse_schedule(0, T, K)


# ------------------------------------------------- engine parity + resume
class TestSparseEngineParity:
    def _reference(self, strategy, Ks, sched, prob, x0):
        runner = FederatedRunner.from_strategy(
            prob.loss, strategy, prob.agent_data, Ks, ETA
        )
        return runner.run(x0, x0, len(sched), schedule=sched.densify())

    @pytest.mark.parametrize("name,strategy,Ks", STRATEGIES,
                             ids=[s[0] for s in STRATEGIES])
    def test_dense_fallback_bitwise_equals_dense_elastic(
        self, name, strategy, Ks
    ):
        """m = 8 <= DENSE_FALLBACK_MAX_M: the sparse entry point routes
        through the EXISTING dense elastic machinery, bitwise."""
        prob = _problem()
        x0 = jnp.zeros(16)
        sched = _sparse_pop().sparse_schedule(0, T, Ks)
        xr, yr = self._reference(strategy, Ks, sched, prob, x0)
        eng = SparseElasticEngine(
            prob.loss, strategy, ArrayDataSource(prob.agent_data), Ks, ETA
        )
        xe, ye = eng.run(x0, x0, sched)
        np.testing.assert_array_equal(np.asarray(xr), np.asarray(xe))
        np.testing.assert_array_equal(np.asarray(yr), np.asarray(ye))
        assert all(r["path"] == "dense-fallback" for r in eng.history)

    @pytest.mark.parametrize("name,strategy,Ks", SPARSE_PARITY,
                             ids=[s[0] for s in SPARSE_PARITY])
    def test_forced_sparse_matches_dense_to_fp_tolerance(
        self, name, strategy, Ks
    ):
        """dense_fallback_max_m=0 forces the O(active) path; only the
        reduction order differs from the dense reference."""
        prob = _problem()
        x0 = jnp.zeros(16)
        sched = _sparse_pop().sparse_schedule(0, T, Ks)
        xr, yr = self._reference(strategy, Ks, sched, prob, x0)
        eng = SparseElasticEngine(
            prob.loss, strategy, ArrayDataSource(prob.agent_data), Ks, ETA,
            dense_fallback_max_m=0,
        )
        xe, ye = eng.run(x0, x0, sched)
        np.testing.assert_allclose(
            np.asarray(xr), np.asarray(xe), rtol=1e-8, atol=1e-10
        )
        np.testing.assert_allclose(
            np.asarray(yr), np.asarray(ye), rtol=1e-8, atol=1e-10
        )
        assert all(r["path"] == "sparse" for r in eng.history)

    @pytest.mark.parametrize("fallback", [0, 4096],
                             ids=["sparse", "dense-fallback"])
    def test_resume_via_tail_is_bitwise(self, fallback):
        prob = _problem()
        x0 = jnp.zeros(16)
        sched = _sparse_pop().sparse_schedule(0, T, K)
        mk = lambda: SparseElasticEngine(
            prob.loss, GradientTracking(),
            ArrayDataSource(prob.agent_data), K, ETA,
            dense_fallback_max_m=fallback,
        )
        full = mk()
        xf, yf = full.run(x0, x0, sched)
        split = mk()
        xm, ym = split.run(x0, x0, sched, num_rounds=3)
        xs, ys = split.run(xm, ym, sched.tail(3), resume=True)
        np.testing.assert_array_equal(np.asarray(xf), np.asarray(xs))
        np.testing.assert_array_equal(np.asarray(yf), np.asarray(ys))
        assert len(split.history) == len(full.history) == T

    def test_sparse_resume_without_a_run_raises(self):
        prob = _problem()
        eng = SparseElasticEngine(
            prob.loss, GradientTracking(),
            ArrayDataSource(prob.agent_data), K, ETA,
            dense_fallback_max_m=0,
        )
        sched = _sparse_pop().sparse_schedule(0, T, K)
        with pytest.raises(ValueError, match="resume"):
            eng.run(jnp.zeros(16), jnp.zeros(16), sched, resume=True)

    def test_schedule_population_mismatch_raises(self):
        prob = _problem()
        eng = SparseElasticEngine(
            prob.loss, GradientTracking(),
            ArrayDataSource(prob.agent_data), K, ETA,
        )
        sched = _sparse_pop(m=12, size=4).sparse_schedule(0, T, K)
        with pytest.raises(ValueError, match="m=12"):
            eng.run(jnp.zeros(16), jnp.zeros(16), sched)


# ------------------------------------------------------- pod aggregation
class TestPodAggregation:
    def test_pod_engine_matches_flat_and_records_wire(self):
        """The two-level aggregate changes only the reduction order; the
        history carries the pod tier's observability (live pods + packed
        partial payload bytes)."""
        prob = _problem()
        x0 = jnp.zeros(16)
        pop = _sparse_pop(pods=4)
        sched = pop.sparse_schedule(0, T, K)
        mk = lambda pods, wire: SparseElasticEngine(
            prob.loss, GradientTracking(),
            ArrayDataSource(prob.agent_data), K, ETA,
            pod_map=pods, wire_pods=wire, dense_fallback_max_m=0,
        )
        xf, yf = mk(None, False).run(x0, x0, sched)
        eng = mk(pop.pod_map(), True)
        xp, yp = eng.run(x0, x0, sched)
        np.testing.assert_allclose(
            np.asarray(xf), np.asarray(xp), rtol=1e-8, atol=1e-10
        )
        np.testing.assert_allclose(
            np.asarray(yf), np.asarray(yp), rtol=1e-8, atol=1e-10
        )
        for rec in eng.history:
            assert 1 <= rec["live_pods"] <= 4
            assert rec["pod_wire_bytes"] > 0

    def test_pod_tree_equals_flat_weighted_mean(self):
        """Seeded property sweep: pods_total . pod_weighted_sums is the
        flat weighted sum for any (n, P, assignment)."""
        for seed, n, P in [(0, 5, 2), (1, 16, 4), (2, 7, 7), (3, 24, 3)]:
            k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
            u = {
                "a": jax.random.normal(k1, (n, 3)),
                "b": jax.random.normal(k2, (n,)),
            }
            w = jax.nn.softmax(jax.random.normal(k3, (n,)))
            pod_ids = np.asarray(
                jax.random.randint(k3, (n,), 0, P, jnp.int32)
            )
            total = pods_total(
                pod_weighted_sums(u, w, jnp.asarray(pod_ids), P)
            )
            flat = jax.tree.map(
                lambda v: jnp.tensordot(w.astype(v.dtype), v, axes=1), u
            )
            for a, b in zip(jax.tree.leaves(total), jax.tree.leaves(flat)):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=1e-12, atol=1e-14
                )

    def test_quiet_pods_are_exact_zero_rows(self):
        u = jnp.arange(12.0).reshape(4, 3)
        w = jnp.full((4,), 0.25)
        pod_ids = jnp.zeros((4,), jnp.int32)  # everyone in pod 0 of 3
        part = pod_weighted_sums(u, w, pod_ids, 3)
        np.testing.assert_array_equal(np.asarray(part[1:]), 0.0)

    @pytest.mark.skipif(not _HAS_HYPOTHESIS, reason="needs hypothesis")
    def test_pod_tree_property_hypothesis(self):
        from hypothesis import given, settings
        from hypothesis import strategies as st

        @given(
            seed=st.integers(0, 2**16),
            n=st.integers(1, 32),
            num_pods=st.integers(1, 8),
        )
        @settings(max_examples=40, deadline=None)
        def inner(seed, n, num_pods):
            k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
            u = jax.random.normal(k1, (n, 4))
            w = jax.nn.softmax(jax.random.normal(k2, (n,)))
            pod_ids = jax.random.randint(k2, (n,), 0, num_pods, jnp.int32)
            total = pods_total(pod_weighted_sums(u, w, pod_ids, num_pods))
            flat = jnp.tensordot(w, u, axes=1)
            np.testing.assert_allclose(
                np.asarray(total), np.asarray(flat), rtol=1e-10, atol=1e-12
            )

        inner()

    def test_encode_decode_roundtrip_is_bitwise(self):
        k = jax.random.PRNGKey(9)
        partials = {
            "x": jax.random.normal(k, (3, 16)),
            "y": jax.random.normal(k, (3, 5)).astype(jnp.float32),
        }
        packed = encode_pod_partials(partials)
        out = decode_pod_partials(packed)
        for a, b in zip(jax.tree.leaves(partials), jax.tree.leaves(out)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert packed.total_bytes() > 0

    def test_pod_payload_priced_equals_measured(self):
        x = jnp.zeros((16,))
        y = jnp.zeros((16,))
        assert pod_payload_bytes(x, y, measured=True) == pod_payload_bytes(
            x, y, measured=False
        )

    def test_pod_aligned_shard_count(self):
        for num_pods in range(1, 25):
            for max_shards in range(1, 10):
                d = pod_aligned_shard_count(num_pods, max_shards)
                assert 1 <= d <= max_shards
                assert num_pods % d == 0
                # largest such divisor
                assert not any(
                    num_pods % e == 0 for e in range(d + 1, max_shards + 1)
                )
        with pytest.raises(ValueError):
            pod_aligned_shard_count(0, 4)

    def test_pod_map_partition(self):
        pm = PodMap(10, 3)  # pod_size = ceil(10/3) = 4: pods 4/4/2
        got = np.concatenate([pm.agents_of(p) for p in range(3)])
        np.testing.assert_array_equal(got, np.arange(10))
        np.testing.assert_array_equal(
            np.asarray(pm.pod_of(np.array([0, 3, 4, 9]))), [0, 0, 1, 2]
        )
        np.testing.assert_array_equal(pm.live_pods(np.array([9, 1, 0])), [0, 2])


# --------------------------------------------------- wire accounting (pods)
class TestScheduleBytesWithPods:
    def test_streaming_price_matches_hand_account(self):
        from repro.fed.transport import measured_bytes_per_round

        prob = _problem()
        x = jnp.zeros(16)
        pop = _sparse_pop(pods=4)
        sp = pop.sparse_schedule(0, T, K)
        pm = pop.pod_map()
        strat = GradientTracking()
        got = schedule_bytes(strat, x, x, K, sp, pods=pm)
        per_agent = measured_bytes_per_round(strat, x, x, K)
        per_pod = pod_payload_bytes(x, x)
        want = [
            per_agent * ev.num_active
            + per_pod * len(pm.live_pods(ev.active_ids))
            for ev in sp
        ]
        assert got == want
        del prob

    def test_sparse_and_densified_price_identically(self):
        x = jnp.zeros(16)
        pop = _sparse_pop(pods=4)
        sp = pop.sparse_schedule(0, T, K)
        pm = pop.pod_map()
        strat = GradientTracking()
        a = schedule_bytes(strat, x, x, K, sp, pods=pm)
        b = schedule_bytes(strat, x, x, K, sp.densify(), pods=pm)
        assert a == b

    def test_priced_equals_measured(self):
        x = jnp.zeros(16)
        pop = _sparse_pop(pods=4)
        sp = pop.sparse_schedule(0, T, K)
        pm = pop.pod_map()
        strat = GradientTracking()
        assert schedule_bytes(
            strat, x, x, K, sp, pods=pm, measured=True
        ) == schedule_bytes(strat, x, x, K, sp, pods=pm, measured=False)


# ------------------------------------------------------- EF row realignment
class TestRealignStateRows:
    def test_continuing_rows_carry_others_restart_at_zero(self):
        strat = CompressedGT(compression_ratio=0.25, seed=0)
        x0 = jnp.zeros(16)
        state = strat.init_state(x0, x0, 3)
        assert set(strat.sharded_state_keys) <= set(state)
        # distinguishable rows: row j of the prev layout filled with its
        # own GLOBAL id
        prev_ids = np.array([2, 5, 9])
        for k in strat.sharded_state_keys:
            state[k] = jax.tree.map(
                lambda u: jnp.asarray(prev_ids, u.dtype).reshape(
                    (-1,) + (1,) * (u.ndim - 1)
                )
                * jnp.ones_like(u),
                state[k],
            )
        ids = np.array([5, 7, 9])
        out = strat.realign_state_rows(state, prev_ids, ids)
        for k in strat.sharded_state_keys:
            rows = np.asarray(jax.tree.leaves(out[k])[0])
            np.testing.assert_array_equal(rows[0], 5.0)  # continued
            np.testing.assert_array_equal(rows[1], 0.0)  # new agent
            np.testing.assert_array_equal(rows[2], 9.0)  # continued

    def test_none_prev_zeroes_everything(self):
        strat = CompressedGT(compression_ratio=0.25, seed=0)
        x0 = jnp.zeros(4)
        state = strat.init_state(x0, x0, 2)
        for k in strat.sharded_state_keys:
            state[k] = jax.tree.map(lambda u: u + 1.0, state[k])
        out = strat.realign_state_rows(state, None, np.array([0, 1]))
        for k in strat.sharded_state_keys:
            for leaf in jax.tree.leaves(out[k]):
                np.testing.assert_array_equal(np.asarray(leaf), 0.0)


# --------------------------------------------------------- pod device groups
def _data_mesh(devices):
    return jax.sharding.Mesh(
        np.array(devices).reshape(8, 1), ("data", "model")
    )


class TestPodDeviceGroups:
    def test_groups_partition_the_fed_devices(self, fed_devices):
        from repro.launch.mesh import pod_device_groups

        mesh = _data_mesh(fed_devices)
        groups = pod_device_groups(mesh, "A", 4)
        assert len(groups) == 4 and all(len(g) == 2 for g in groups)
        flat = [d for g in groups for d in g]
        assert [d.id for d in flat] == sorted(d.id for d in flat)
        assert len(set(flat)) == 8

    def test_non_dividing_pod_count_is_rejected(self, fed_devices):
        from repro.launch.mesh import pod_device_groups

        mesh = _data_mesh(fed_devices)
        with pytest.raises(ValueError, match="divide"):
            pod_device_groups(mesh, "A", 3)


# --------------------------------------------------------- peak-memory gate
class TestPeakMemoryHelper:
    def test_reports_cover_the_allocation(self):
        from benchmarks.common import peak_memory

        n = 400_000  # 3.2 MB of float64

        def work():
            buf = np.ones(n, np.float64)
            return float(buf.sum())

        rec = peak_memory(work)
        assert rec["result"] == float(n)
        assert rec["host_peak_bytes"] >= n * 8
        assert rec["live_buffer_bytes"] >= 0
