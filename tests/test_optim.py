"""Tests for the optimizer extensions (beyond-paper, OFF by default)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tree_sq_dist
from repro.optim import (
    constant_schedule,
    diminishing_schedule,
    make_momentum_fedgda_gt_round,
)
from repro.problems import make_quadratic_problem, quadratic_minimax_point


class TestSchedules:
    def test_constant(self):
        s = constant_schedule(3e-4)
        assert float(s(0)) == float(s(10_000)) == 3e-4

    def test_diminishing_is_o_1_over_t(self):
        s = diminishing_schedule(1e-2, decay=1.0)
        assert float(s(0)) == 1e-2
        np.testing.assert_allclose(float(s(99)), 1e-2 / 100.0)
        # monotone decreasing
        vals = [float(s(t)) for t in range(0, 50, 5)]
        assert all(a > b for a, b in zip(vals, vals[1:]))


class TestServerMomentum:
    def test_momentum_converges_and_accelerates(self, rng):
        prob = make_quadratic_problem(rng, dim=12, num_samples=60, num_agents=6)
        xs, ys = quadratic_minimax_point(prob)
        eta, K, T = 5e-5, 10, 400
        from repro.core import make_fedgda_gt_round

        base = jax.jit(make_fedgda_gt_round(prob.loss, K, eta))
        mom = make_momentum_fedgda_gt_round(prob.loss, K, eta, beta=0.8)
        jmom = jax.jit(mom)
        x0 = jnp.zeros(12)
        xb, yb = x0, x0
        state = (x0, x0, mom.init_velocity(x0, x0))
        for _ in range(T):
            xb, yb = base(xb, yb, prob.agent_data)
            state = jmom(state, prob.agent_data)
        xm, ym, _ = state
        gap_base = float(tree_sq_dist(xb, xs) + tree_sq_dist(yb, ys))
        gap_mom = float(tree_sq_dist(xm, xs) + tree_sq_dist(ym, ys))
        assert np.isfinite(gap_mom)
        # same budget: momentum must be at least as tight (and typically
        # orders of magnitude tighter on this well-conditioned problem)
        assert gap_mom <= gap_base * 1.05, (gap_mom, gap_base)

    def test_velocity_zero_init_matches_first_round_direction(self, rng):
        prob = make_quadratic_problem(rng, dim=6, num_samples=30, num_agents=3)
        eta, K = 1e-4, 5
        from repro.core import make_fedgda_gt_round

        base = make_fedgda_gt_round(prob.loss, K, eta)
        mom = make_momentum_fedgda_gt_round(prob.loss, K, eta, beta=0.9)
        x0 = jnp.ones(6)
        xb, yb = base(x0, x0, prob.agent_data)
        x1, y1, _ = mom((x0, x0, mom.init_velocity(x0, x0)), prob.agent_data)
        # round 1: velocity = increment, so x1 = x + 1*(x_b - x) ... = x_b
        np.testing.assert_allclose(np.asarray(x1), np.asarray(xb), rtol=1e-10)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(yb), rtol=1e-10)
