"""Property-based tests (hypothesis) on the system's invariants
(deliverable c).

Invariants covered:
  * projections: membership, idempotence, non-expansiveness
  * tree utilities: broadcast/mean inverses, metric axioms
  * FedGDA-GT structure: the tracking correction averages to zero; with a
    single agent the round IS K centralized GDA steps; with homogeneous
    agents all agents stay in lockstep
  * Local SGDA: K=1 equals centralized GDA
  * fixed-point algebra: the Appendix-C closed form is a fixed point of the
    round map for any K, eta in the stable range
  * communication accounting: positivity and the paper's orderings
  * correction compression (CompressedGT / QuantizedGT): pytree
    structure/shape/dtype preservation, sent + residual == raw
    correction, and exact identity in the bits -> inf / ratio -> 1 limits
  * wire transport (fed.transport): decode(encode(c)) == the dense
    compressed correction EXACTLY for every mode x bits x dtype, and the
    packed payload length == the priced bytes, on arbitrary shapes
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need the optional `hypothesis` extra; "
    "the rest of tier-1 runs without it",
)
from hypothesis import given, settings, strategies as st

from repro.core import (
    appendix_c_fixed_point,
    box_proj,
    communication_bytes_per_round,
    l2_ball_proj,
    make_fedgda_gt_round,
    make_gda_step,
    make_local_sgda_round,
    simplex_proj,
    tree_broadcast_agents,
    tree_mean_over_agents,
    tree_sq_dist,
)
from repro.fed import CompressedGT, QuantizedGT
from repro.problems import make_appendix_c_problem, make_quadratic_problem

SETTINGS = dict(max_examples=25, deadline=None)

vec = st.integers(min_value=1, max_value=24).flatmap(
    lambda d: st.lists(
        st.floats(
            -1e3, 1e3, allow_nan=False, allow_subnormal=False, width=32
        ),
        min_size=d,
        max_size=d,
    )
)


# ------------------------------------------------------------- projections
class TestProjections:
    @given(v=vec, radius=st.floats(0.1, 10.0))
    @settings(**SETTINGS)
    def test_l2_ball_membership_and_idempotence(self, v, radius):
        p = l2_ball_proj(radius)
        x = jnp.asarray(v, jnp.float32)
        y = p(x)
        assert float(jnp.linalg.norm(y)) <= radius * (1 + 1e-5)
        np.testing.assert_allclose(np.asarray(p(y)), np.asarray(y), rtol=1e-6)

    @given(v=vec, w=vec, radius=st.floats(0.1, 10.0))
    @settings(**SETTINGS)
    def test_l2_ball_nonexpansive(self, v, w, radius):
        d = min(len(v), len(w))
        x = jnp.asarray(v[:d], jnp.float32)
        y = jnp.asarray(w[:d], jnp.float32)
        p = l2_ball_proj(radius)
        dp = float(jnp.linalg.norm(p(x) - p(y)))
        d0 = float(jnp.linalg.norm(x - y))
        assert dp <= d0 * (1 + 1e-5) + 1e-6

    @given(v=vec, lo=st.floats(-5, 0), hi=st.floats(0.1, 5))
    @settings(**SETTINGS)
    def test_box_membership_idempotence(self, v, lo, hi):
        p = box_proj(lo, hi)
        y = p(jnp.asarray(v, jnp.float32))
        assert float(jnp.min(y)) >= lo - 1e-6
        assert float(jnp.max(y)) <= hi + 1e-6
        np.testing.assert_allclose(np.asarray(p(y)), np.asarray(y))

    @given(v=vec)
    @settings(**SETTINGS)
    def test_simplex_membership(self, v):
        p = simplex_proj()
        y = p(jnp.asarray(v, jnp.float64))
        assert float(jnp.min(y)) >= -1e-9
        np.testing.assert_allclose(float(jnp.sum(y)), 1.0, rtol=1e-6)
        # idempotence
        np.testing.assert_allclose(
            np.asarray(p(y)), np.asarray(y), rtol=1e-6, atol=1e-9
        )


# ----------------------------------------------------------- tree utilities
class TestTreeOps:
    @given(v=vec, m=st.integers(1, 6))
    @settings(**SETTINGS)
    def test_mean_inverts_broadcast(self, v, m):
        x = {"a": jnp.asarray(v, jnp.float32), "b": jnp.asarray([[1.0, 2.0]])}
        xs = tree_broadcast_agents(x, m)
        back = tree_mean_over_agents(xs)
        for u, w in zip(jax.tree.leaves(back), jax.tree.leaves(x)):
            np.testing.assert_allclose(np.asarray(u), np.asarray(w), rtol=1e-6)

    @given(v=vec, w=vec)
    @settings(**SETTINGS)
    def test_sq_dist_metric_axioms(self, v, w):
        d = min(len(v), len(w))
        x = jnp.asarray(v[:d], jnp.float64)
        y = jnp.asarray(w[:d], jnp.float64)
        assert float(tree_sq_dist(x, y)) >= 0.0
        np.testing.assert_allclose(float(tree_sq_dist(x, x)), 0.0, atol=1e-12)
        np.testing.assert_allclose(
            float(tree_sq_dist(x, y)), float(tree_sq_dist(y, x)), rtol=1e-10
        )


# --------------------------------------------------- FedGDA-GT invariants
def _quadratic(seed, dim=6, m=4):
    return make_quadratic_problem(
        jax.random.PRNGKey(seed), dim=dim, num_samples=20, num_agents=m
    )


class TestFedGdaGtStructure:
    @given(seed=st.integers(0, 10_000))
    @settings(**SETTINGS)
    def test_correction_terms_average_to_zero(self, seed):
        """sum_i (gbar - g_i) = 0 — the defining property of gradient
        tracking: the average local step direction equals the global one."""
        prob = _quadratic(seed)
        from repro.core.types import grad_xy

        g = jax.vmap(grad_xy(prob.loss), in_axes=(None, None, 0))(
            jnp.ones(6), jnp.ones(6), prob.agent_data
        )
        for leaf in jax.tree.leaves(g):
            corr = jnp.mean(leaf, axis=0)[None] - leaf  # c_i per agent
            np.testing.assert_allclose(
                np.asarray(jnp.mean(corr, axis=0)),
                np.zeros(leaf.shape[1:]),
                atol=1e-8,
            )

    @given(seed=st.integers(0, 10_000), K=st.integers(1, 6))
    @settings(max_examples=10, deadline=None)
    def test_single_agent_reduces_to_k_gda_steps(self, seed, K):
        prob = make_quadratic_problem(
            jax.random.PRNGKey(seed), dim=5, num_samples=20, num_agents=1
        )
        eta = 1e-3
        rnd = make_fedgda_gt_round(prob.loss, K, eta)
        step = make_gda_step(prob.loss, eta, eta)
        x0 = jnp.zeros(5)
        xg, yg = rnd(x0, x0, prob.agent_data)
        xc, yc = x0, x0
        for _ in range(K):
            xc, yc = step(xc, yc, prob.agent_data)
        np.testing.assert_allclose(np.asarray(xg), np.asarray(xc), rtol=1e-8)
        np.testing.assert_allclose(np.asarray(yg), np.asarray(yc), rtol=1e-8)

    @given(seed=st.integers(0, 10_000), m=st.integers(2, 5))
    @settings(max_examples=10, deadline=None)
    def test_homogeneous_agents_lockstep(self, seed, m):
        """Identical local objectives: the K local trajectories coincide, so
        one FedGDA-GT round == K centralized GDA steps (Appendix D.4)."""
        base = make_quadratic_problem(
            jax.random.PRNGKey(seed), dim=5, num_samples=20, num_agents=1
        )
        hom = jax.tree.map(
            lambda u: jnp.broadcast_to(u, (m,) + u.shape[1:]), base.agent_data
        )
        eta, K = 1e-3, 4
        rnd = make_fedgda_gt_round(base.loss, K, eta)
        step = make_gda_step(base.loss, eta, eta)
        x0 = jnp.zeros(5)
        xg, yg = rnd(x0, x0, hom)
        xc, yc = x0, x0
        for _ in range(K):
            xc, yc = step(xc, yc, base.agent_data)
        np.testing.assert_allclose(np.asarray(xg), np.asarray(xc), rtol=1e-7)

    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_local_sgda_k1_equals_gda(self, seed):
        prob = _quadratic(seed)
        eta = 1e-3
        rnd = make_local_sgda_round(prob.loss, 1, eta, eta)
        step = make_gda_step(prob.loss, eta, eta)
        x0 = jnp.zeros(6)
        xr, yr = rnd(x0, x0, prob.agent_data)
        xs, ys = step(x0, x0, prob.agent_data)
        np.testing.assert_allclose(np.asarray(xr), np.asarray(xs), rtol=1e-9)
        np.testing.assert_allclose(np.asarray(yr), np.asarray(ys), rtol=1e-9)


# ----------------------------------------------------- Appendix C algebra
class TestAppendixCFixedPoint:
    @given(K=st.integers(1, 60), eta=st.floats(1e-4, 5e-3))
    @settings(**SETTINGS)
    def test_closed_form_is_fixed_point_of_round_map(self, K, eta):
        prob = make_appendix_c_problem()
        fx, fy = appendix_c_fixed_point(K, eta, eta)
        rnd = make_local_sgda_round(prob.loss, K, eta, eta)
        x1, y1 = rnd(jnp.float64(fx), jnp.float64(fy), prob.agent_data)
        np.testing.assert_allclose(float(x1), fx, rtol=1e-9)
        np.testing.assert_allclose(float(y1), fy, rtol=1e-9)

    @given(eta=st.floats(1e-4, 0.2))
    @settings(**SETTINGS)
    def test_k1_fixed_point_is_minimax(self, eta):
        fx, fy = appendix_c_fixed_point(1, eta, eta)
        np.testing.assert_allclose(fx, 3.3, rtol=1e-9)
        np.testing.assert_allclose(fy, 3.3, rtol=1e-9)


# ------------------------------------------- compression invariants
def _correction_trees(seed, m, d1, d2):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    cx = {
        "a": jax.random.normal(k1, (m, d1)),
        "b": jax.random.normal(k2, (m, 2, d2)),
    }
    cy = {"d": jax.random.normal(k3, (m, d2))}
    return cx, cy


def _x0(tree):
    return jax.tree.map(lambda u: u[0], tree)


class TestCompressionInvariants:
    @given(
        seed=st.integers(0, 10_000),
        ratio=st.floats(0.05, 1.0),
        bits=st.sampled_from([2, 4, 8, 32]),
        mode=st.sampled_from(["topk", "randk"]),
    )
    @settings(max_examples=15, deadline=None)
    def test_transform_preserves_structure_shape_dtype(
        self, seed, ratio, bits, mode
    ):
        m = 3
        cx, cy = _correction_trees(seed, m, 7, 4)
        s = QuantizedGT(bits=bits, ratio=ratio, mode=mode, seed=seed)
        state = s.init_state(_x0(cx), _x0(cy), m)
        cx2, cy2, _ = s.transform_correction(cx, cy, state)
        assert jax.tree.structure(cx2) == jax.tree.structure(cx)
        assert jax.tree.structure(cy2) == jax.tree.structure(cy)
        for a, b in zip(
            jax.tree.leaves((cx2, cy2)), jax.tree.leaves((cx, cy))
        ):
            assert a.shape == b.shape and a.dtype == b.dtype

    @given(
        seed=st.integers(0, 10_000),
        ratio=st.floats(0.05, 0.9),
        bits=st.sampled_from([4, 8, 32]),
        mode=st.sampled_from(["topk", "randk"]),
    )
    @settings(max_examples=15, deadline=None)
    def test_sent_plus_residual_is_raw_correction(self, seed, ratio, bits, mode):
        """With error feedback, what compression drops is exactly what
        lands in the feedback buffer: chat + e' == c + e (here e = 0)."""
        m = 3
        cx, cy = _correction_trees(seed, m, 9, 5)
        s = QuantizedGT(
            bits=bits, ratio=ratio, mode=mode, seed=seed, error_feedback=True
        )
        state = s.init_state(_x0(cx), _x0(cy), m)
        cx2, cy2, state2 = s.transform_correction(cx, cy, state)
        for sent, resid, raw in (
            *zip(
                jax.tree.leaves(cx2),
                jax.tree.leaves(state2["ex"]),
                jax.tree.leaves(cx),
            ),
            *zip(
                jax.tree.leaves(cy2),
                jax.tree.leaves(state2["ey"]),
                jax.tree.leaves(cy),
            ),
        ):
            np.testing.assert_allclose(
                np.asarray(sent + resid), np.asarray(raw), rtol=0, atol=1e-10
            )

    @given(seed=st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_identity_limits_are_exact(self, seed):
        """bits -> inf (>= 32) and ratio -> 1: the transform IS the
        identity — arrays pass through unchanged and no state is kept."""
        m = 4
        cx, cy = _correction_trees(seed, m, 6, 3)
        for s in (
            QuantizedGT(bits=32, ratio=1.0, seed=seed),
            CompressedGT(compression_ratio=1.0, seed=seed),
        ):
            assert not s.stateful and s.exact_correction
            state = s.init_state(_x0(cx), _x0(cy), m)
            assert state == {}
            cx2, cy2, state2 = s.transform_correction(cx, cy, state)
            for a, b in zip(
                jax.tree.leaves((cx2, cy2)), jax.tree.leaves((cx, cy))
            ):
                assert a is b  # elided at trace time, not just allclose
            assert state2 == {}


# ------------------------------------------- wire-transport round-trip
class TestWireTransportRoundTrip:
    @given(
        seed=st.integers(0, 10_000),
        rows=st.integers(1, 6),
        cols=st.integers(1, 300),
        ratio=st.floats(0.05, 1.0),
        bits=st.sampled_from([2, 3, 4, 8, 16, 32]),
        mode=st.sampled_from(["topk", "randk"]),
        dtype=st.sampled_from(["float32", "float64", "bfloat16"]),
    )
    @settings(max_examples=40, deadline=None)
    def test_decode_encode_is_masked_correction_exactly(
        self, seed, rows, cols, ratio, bits, mode, dtype
    ):
        """decode(encode(c)) == the dense compressed correction, exactly,
        for every mode x bits x dtype on arbitrary [rows, cols] leaves —
        and the packed buffers weigh exactly what the pricing says."""
        import dataclasses

        from repro.fed.transport import LeafSpec, decode_leaf, encode_leaf
        from repro.kernels.compress_correction import compress_leaf

        dt = jnp.dtype(dtype)
        spec = dataclasses.replace(
            LeafSpec.build((cols,), dt, ratio, bits, mode), rows=rows
        )
        k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
        c = jax.random.normal(k1, (rows, cols)).astype(dt)
        e = (0.1 * jax.random.normal(k2, (rows, cols))).astype(dt)
        u_sel = jax.random.uniform(k3, (rows, cols))
        u_rnd = jax.random.uniform(k4, (rows, cols))
        payload, resid = encode_leaf(c, e, u_sel, u_rnd, spec)
        decoded = decode_leaf(payload, spec)
        chat, resid_dense = compress_leaf(
            c, e, u_sel, u_rnd, k=spec.k, bits=bits, mode=mode
        )
        np.testing.assert_array_equal(
            np.asarray(decoded, np.float64), np.asarray(chat, np.float64)
        )
        np.testing.assert_array_equal(
            np.asarray(resid, np.float64), np.asarray(resid_dense, np.float64)
        )
        assert payload.nbytes == spec.wire_bytes()
        if payload.indices is not None:
            assert payload.indices.dtype == spec.index_dtype


# ---------------------------------------------------- comm accounting
class TestCommAccounting:
    @given(p=st.integers(1, 4096), q=st.integers(1, 256), K=st.integers(1, 64))
    @settings(**SETTINGS)
    def test_orderings(self, p, q, K):
        x = jnp.zeros((p,), jnp.float32)
        y = jnp.zeros((q,), jnp.float32)
        ls = communication_bytes_per_round(x, y, "local_sgda", K)
        gt = communication_bytes_per_round(x, y, "fedgda_gt", K)
        gda = communication_bytes_per_round(x, y, "gda", K)
        assert 0 < ls < gt  # GT pays extra for the tracked gradient
        assert gt == 2 * ls  # exactly 2x (paper's cost model)
        if K > 2:
            assert gda > gt  # sync GDA communicates every inner step


# ---------------------------------------------- stochastic noise models
class TestNoiseModels:
    """fed.noise: unbiasedness with the configured spread, and the
    independence of the noise stream from the strategies' own RNG."""

    @given(
        seed=st.integers(0, 2**16),
        sigma=st.floats(0.05, 0.5, allow_nan=False),
    )
    @settings(max_examples=10, deadline=None)
    def test_gaussian_noise_unbiased_with_configured_sigma(self, seed, sigma):
        from repro.core import grad_xy
        from repro.fed.noise import GaussianNoise

        d = 4
        loss = lambda x, y, data: 0.5 * x @ x - 0.5 * y @ y
        gfn = grad_xy(loss)
        x = jnp.arange(1.0, d + 1.0)
        y = -x
        g0 = gfn(x, y, {})
        noise = GaussianNoise(sigma=sigma)
        n_mc = 2048
        keys = jax.random.split(jax.random.PRNGKey(seed), n_mc)
        gs = jax.vmap(lambda k: noise.grad(gfn, k, x, y, {}))(keys)
        tol = 8.0 * sigma / np.sqrt(n_mc)
        for u, u0 in ((gs.gx, g0.gx), (gs.gy, g0.gy)):
            mean = np.asarray(jnp.mean(u, axis=0))
            np.testing.assert_allclose(mean, np.asarray(u0), atol=tol)
            std = float(jnp.std(u, axis=0).mean())
            assert abs(std - sigma) < 0.2 * sigma

    @given(seed=st.integers(0, 2**16))
    @settings(max_examples=10, deadline=None)
    def test_minibatch_noise_unbiased_for_mean_losses(self, seed):
        from repro.core import grad_xy
        from repro.fed.noise import MinibatchNoise

        n, d = 32, 3
        a = jax.random.normal(jax.random.PRNGKey(42), (n, d))
        data = {"a": a}
        # grad_x of mean_i <a_i, x> is mean(a) regardless of x
        loss = lambda x, y, data: jnp.mean(data["a"] @ x) - 0.5 * y @ y
        gfn = grad_xy(loss)
        x, y = jnp.ones(d), jnp.ones(d)
        noise = MinibatchNoise(fraction=0.25)
        n_mc = 2048
        keys = jax.random.split(jax.random.PRNGKey(seed), n_mc)
        gs = jax.vmap(lambda k: noise.grad(gfn, k, x, y, data))(keys)
        mean = np.asarray(jnp.mean(gs.gx, axis=0))
        # std of an 8-sample mean of unit normals ~ 0.35; 2048 MC reps
        tol = 8.0 * float(jnp.std(a)) / np.sqrt(8) / np.sqrt(n_mc)
        np.testing.assert_allclose(mean, np.asarray(jnp.mean(a, axis=0)),
                                   atol=tol)
        # y is untouched by subsampling (no sample axis in its grad)
        np.testing.assert_array_equal(
            np.asarray(gs.gy[0]), np.asarray(gfn(x, y, data).gy)
        )

    @given(
        seed=st.integers(0, 2**16),
        participation=st.floats(0.2, 0.9, allow_nan=False),
    )
    @settings(**SETTINGS)
    def test_sampling_draws_independent_of_noise_toggle(
        self, seed, participation
    ):
        """The fold-tree contract as a property: toggling the noise
        model on a sampling strategy never changes its participation
        draws, for ANY seed."""
        from repro.fed import PartialParticipation
        from repro.fed.noise import GaussianNoise

        m = 8
        x = jnp.ones(4)
        det = PartialParticipation(participation=participation, seed=seed)
        sto = PartialParticipation(
            participation=participation, seed=seed,
            noise=GaussianNoise(sigma=0.1),
        )
        s_det = det.init_state(x, x, m)
        s_sto = sto.init_state(x, x, m)
        for _ in range(3):
            w_det, s_det = det.sample_weights(s_det, m)
            w_sto, s_sto = sto.sample_weights(s_sto, m)
            np.testing.assert_array_equal(
                np.asarray(w_det), np.asarray(w_sto)
            )


# ------------------------------------------------ Dirichlet heterogeneity
class TestDirichletPartitions:
    @given(
        seed=st.integers(0, 2**16),
        m=st.integers(2, 12),
        c=st.integers(2, 8),
        alpha=st.floats(0.05, 50.0, allow_nan=False),
    )
    @settings(**SETTINGS)
    def test_weights_are_a_distribution(self, seed, m, c, alpha):
        from repro.data import dirichlet_partition_weights

        w = dirichlet_partition_weights(jax.random.PRNGKey(seed), m, c, alpha)
        assert w.shape == (m, c)
        assert (np.asarray(w) >= 0).all()
        np.testing.assert_allclose(
            np.asarray(jnp.sum(w, axis=1)), np.ones(m), rtol=1e-9
        )

    @given(seed=st.integers(0, 2**16))
    @settings(**SETTINGS)
    def test_heterogeneity_monotone_in_alpha(self, seed):
        """Widely separated concentrations must order the heterogeneity
        index: near-one-hot agents (alpha -> 0) are farther from the
        population mixture than near-uniform ones (alpha -> inf)."""
        from repro.data import dirichlet_partition_weights, heterogeneity_index

        key = jax.random.PRNGKey(seed)
        m, c = 12, 4
        het_lo = heterogeneity_index(
            dirichlet_partition_weights(key, m, c, 0.05)
        )
        het_hi = heterogeneity_index(
            dirichlet_partition_weights(key, m, c, 50.0)
        )
        assert float(het_lo) > float(het_hi)

    def test_index_extremes(self):
        from repro.data import heterogeneity_index

        uniform = jnp.full((6, 4), 0.25)
        assert float(heterogeneity_index(uniform)) == 0.0
        onehot = jnp.eye(4)
        # distinct one-hot agents: TV distance to the uniform mixture
        # is (C-1)/C
        np.testing.assert_allclose(
            float(heterogeneity_index(onehot)), 0.75, rtol=1e-12
        )
