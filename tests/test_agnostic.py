"""Agnostic federated learning (paper Appendix A.2) solved with FedGDA-GT."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_fedgda_gt_round, make_local_sgda_round
from repro.problems.agnostic import (
    make_agnostic_problem,
    per_agent_risks,
    uniform_lambda,
)


def _solve(rnd, x0, y0, data, T):
    x, y = x0, y0
    for _ in range(T):
        x, y = rnd(x, y, data)
    return x, y


class TestAgnosticFL:
    def test_lambda_stays_on_simplex_and_converges(self, rng):
        prob = make_agnostic_problem(rng, dim=8, num_samples=80, num_agents=5)
        rnd = jax.jit(
            make_fedgda_gt_round(prob.loss, 5, 2e-3, proj_y=prob.proj_y)
        )
        x0 = jnp.zeros(8)
        y0 = uniform_lambda(5)
        x, y = _solve(rnd, x0, y0, prob.agent_data, 800)
        assert np.all(np.isfinite(np.asarray(x)))
        np.testing.assert_allclose(float(jnp.sum(y)), 1.0, rtol=1e-8)
        assert float(jnp.min(y)) >= -1e-12

    def test_risks_equalize_at_saddle(self, rng):
        """At the agnostic saddle the adversary equalizes the supported
        agents' risks (lambda* is non-unique exactly when they tie), so the
        seed-robust property is that the per-agent risk SPREAD shrinks
        versus the uniform-average model."""
        prob = make_agnostic_problem(
            rng, dim=8, num_samples=80, num_agents=5, shift=4.0
        )
        x0 = jnp.zeros(8)
        rnd = jax.jit(
            make_fedgda_gt_round(prob.loss, 5, 2e-3, proj_y=prob.proj_y)
        )
        xa, _ = _solve(rnd, x0, uniform_lambda(5), prob.agent_data, 1500)
        frozen = jax.jit(
            make_fedgda_gt_round(
                prob.loss, 5, 2e-3, proj_y=lambda y: uniform_lambda(5)
            )
        )
        xu, _ = _solve(frozen, x0, uniform_lambda(5), prob.agent_data, 1500)
        ra = np.asarray(per_agent_risks(prob, xa))
        ru = np.asarray(per_agent_risks(prob, xu))
        assert (ra.max() - ra.min()) <= (ru.max() - ru.min()) + 1e-9

    def test_agnostic_beats_uniform_on_worst_agent(self, rng):
        """The minimax-fair model's WORST agent risk must not exceed the
        uniform-average (standard FL) model's worst agent risk."""
        prob = make_agnostic_problem(
            rng, dim=8, num_samples=80, num_agents=5, shift=4.0
        )
        x0 = jnp.zeros(8)
        # agnostic model
        rnd = jax.jit(
            make_fedgda_gt_round(prob.loss, 5, 2e-3, proj_y=prob.proj_y)
        )
        xa, _ = _solve(rnd, x0, uniform_lambda(5), prob.agent_data, 1500)
        # uniform model: freeze y = uniform (max step 0) == plain FedAvg-GT
        frozen = jax.jit(
            make_fedgda_gt_round(
                prob.loss, 5, 2e-3, proj_y=lambda y: uniform_lambda(5)
            )
        )
        xu, _ = _solve(frozen, x0, uniform_lambda(5), prob.agent_data, 1500)
        worst_a = float(jnp.max(per_agent_risks(prob, xa)))
        worst_u = float(jnp.max(per_agent_risks(prob, xu)))
        assert worst_a <= worst_u * 1.01, (worst_a, worst_u)
