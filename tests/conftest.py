import jax
import pytest

# float64 needed for the paper's convergence experiments (linear rates are
# verified down to ~1e-20 optimality gaps); model smoke tests pass explicit
# float32 dtypes throughout and are unaffected.
jax.config.update("jax_enable_x64", True)


def pytest_configure(config):
    # also declared in pytest.ini so `-m "not slow"` / `-m kernel`
    # filtering is warning-free even when conftest isn't the one
    # registering them
    config.addinivalue_line(
        "markers",
        "slow: multi-minute system / arch-smoke tests; deselect with "
        '-m "not slow"',
    )
    config.addinivalue_line(
        "markers",
        "kernel: Pallas interpret-mode kernel suites; select with "
        '-m kernel, deselect with -m "not kernel"',
    )


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
