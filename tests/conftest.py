import os

# CPU emulation for the async-runtime / multi-host suites: 8 host devices
# so agent shards have somewhere to land without real TPUs.  Must be set
# BEFORE jax initializes its backend (conftest imports first under
# pytest); appended, so an explicit XLA_FLAGS from the environment wins.
_DEVICE_FLAG = "--xla_force_host_platform_device_count=8"
if "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _DEVICE_FLAG
    ).strip()

import jax
import pytest

# float64 needed for the paper's convergence experiments (linear rates are
# verified down to ~1e-20 optimality gaps); model smoke tests pass explicit
# float32 dtypes throughout and are unaffected.
jax.config.update("jax_enable_x64", True)


def pytest_configure(config):
    # also declared in pytest.ini so `-m "not slow"` / `-m kernel`
    # filtering is warning-free even when conftest isn't the one
    # registering them
    config.addinivalue_line(
        "markers",
        "slow: multi-minute system / arch-smoke tests; deselect with "
        '-m "not slow"',
    )
    config.addinivalue_line(
        "markers",
        "kernel: Pallas interpret-mode kernel suites; select with "
        '-m kernel, deselect with -m "not kernel"',
    )
    config.addinivalue_line(
        "markers",
        "multihost: async-runtime / multi-host suites needing the "
        "8-device CPU emulation; select with -m multihost",
    )
    config.addinivalue_line(
        "markers",
        "sim: client-population / elastic-schedule suites (repro.sim); "
        "select with -m sim",
    )
    config.addinivalue_line(
        "markers",
        "stochastic: stochastic-gradient family suites (fed.noise, "
        "SAGDA / Local SGDA+, noise-fold contract); select with "
        "-m stochastic",
    )
    config.addinivalue_line(
        "markers",
        "pods: O(active) sparse-state + two-level pod-aggregation "
        "suites (sim.sparse, fed.pods); select with -m pods",
    )
    config.addinivalue_line(
        "markers",
        "obs: telemetry / observability suites (repro.obs: bitwise "
        "pins, invariant probes, run ledger); select with -m obs",
    )


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def fed_devices():
    """The emulated 8-device pool the async / multi-host suites shard
    agents over.  Skips (instead of failing) when jax was initialized
    before conftest could force the host device count — e.g. under a
    caller-provided XLA_FLAGS."""
    devices = jax.devices()
    if len(devices) < 8:
        pytest.skip(
            f"needs 8 emulated host devices, have {len(devices)} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=8)"
        )
    return devices[:8]
