import jax
import pytest

# float64 needed for the paper's convergence experiments (linear rates are
# verified down to ~1e-20 optimality gaps); model smoke tests pass explicit
# float32 dtypes throughout and are unaffected.
jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
