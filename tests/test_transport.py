"""Sparse wire transport for compressed corrections (deliverable: ISSUE 3).

Four layers of guarantees:

  * round-trip — decode(encode(c)) reproduces the dense compressed
    correction chat EXACTLY (indices/words bitwise; values land via an
    exact scatter-add) for every encoding x bits x dtype, on aligned,
    unaligned, multi-row and degenerate (scalar/tiny) leaves, and the
    residual the encoder emits is the dense path's residual bitwise —
    so error feedback cannot tell the wire from the dense tree;
  * accounting — `LeafSpec.wire_bytes` (which IS the strategies'
    payload pricing) equals the measured packed buffer lengths, both
    per leaf (`probe_leaf_bytes` / `LeafPayload.nbytes`) and per round
    (`measured_bytes_per_round` vs `bytes_per_round`, exact without
    headers, within `wire_header_overhead` with them);
  * engine — wire_transport on/off produces bitwise-identical GT
    iterates round after round, and the bits>=32 + ratio>=1 identity
    configuration degenerates to the dense GradientTracking path;
  * comm table — rows report measured next to priced bytes and key
    colliding strategies by knob signature (order-independent).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_round, run_strategy_rounds
from repro.fed import (
    CompressedGT,
    GradientTracking,
    HEADER_BYTES,
    LeafSpec,
    PackedTree,
    QuantizedGT,
    decode_leaf,
    encode_leaf,
    measured_bytes_per_round,
    wire_header_overhead,
)
from repro.fed.transport import probe_leaf_bytes, wire_rows_cols
from repro.kernels.compress_correction import compress_leaf
from repro.problems import make_quadratic_problem

F32, F64, BF16 = jnp.float32, jnp.float64, jnp.bfloat16

# per-agent leaf shapes: aligned vector, unaligned vector, matrix
# (multi-row groups), odd 3-D, scalar, tiny
SHAPES = [(256,), (37,), (4, 32), (2, 3, 64), (), (3,)]
CONFIGS = [  # (ratio, bits, mode)
    (0.25, 32, "topk"),
    (0.25, 8, "topk"),
    (0.5, 4, "randk"),
    (1.0, 8, "topk"),
    (1.0, 2, "topk"),
    (0.1, 16, "randk"),
]


def _leaf(shape, dtype, m=3, seed=0):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    c = jax.random.normal(k1, (m,) + shape).astype(dtype)
    e = (0.1 * jax.random.normal(k2, (m,) + shape)).astype(dtype)
    spec = LeafSpec.build(shape, dtype, 1.0, 32).stacked(m)
    u_sel = jax.random.uniform(k3, (spec.rows, spec.cols))
    u_rnd = jax.random.uniform(k4, (spec.rows, spec.cols))
    return c, e, u_sel, u_rnd


# ------------------------------------------------------------- round-trip
class TestRoundTrip:
    @pytest.mark.parametrize("dtype", [F32, F64, BF16])
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("ratio,bits,mode", CONFIGS)
    def test_decode_encode_is_dense_compress(self, dtype, shape, ratio,
                                             bits, mode):
        """decode(encode(c)) == the masked/quantized chat of the dense
        compress path, and the residuals agree bitwise — on the SAME
        uniform draws the two paths are the same math."""
        m = 3
        c, e, u_sel, u_rnd = _leaf(shape, dtype, m)
        spec = LeafSpec.build(shape, dtype, ratio, bits, mode).stacked(m)
        flat = c.reshape(spec.rows, spec.cols)
        e_flat = e.reshape(flat.shape)
        payload, resid = encode_leaf(flat, e_flat, u_sel, u_rnd, spec)
        decoded = decode_leaf(payload, spec)
        chat, resid_dense = compress_leaf(
            flat, e_flat, u_sel, u_rnd, k=spec.k, bits=bits, mode=mode
        )
        np.testing.assert_array_equal(
            np.asarray(decoded, np.float64), np.asarray(chat, np.float64)
        )
        np.testing.assert_array_equal(
            np.asarray(resid, np.float64), np.asarray(resid_dense, np.float64)
        )
        assert decoded.dtype == dtype

    @pytest.mark.parametrize(
        "encoding", ["dense", "sparse", "quant", "quant_dense"]
    )
    def test_each_encoding_round_trips(self, encoding):
        """Force every encoding (not just the cheapest) through the
        codec: words/indices are bitwise-stable, values exact."""
        c, e, u_sel, u_rnd = _leaf((64,), F32, m=4, seed=1)
        spec = LeafSpec.build((64,), F32, 0.25, 6)  # 6 -> stored at 8 bits
        spec = dataclasses.replace(spec.stacked(4), encoding=encoding)
        flat = c.reshape(spec.rows, spec.cols)
        payload, _ = encode_leaf(flat, None, u_sel, u_rnd, spec)
        a = decode_leaf(payload, spec)
        b = decode_leaf(payload, spec)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        chat, _ = compress_leaf(
            flat, None, u_sel, u_rnd, k=spec.k, bits=spec.bits, mode=spec.mode
        )
        np.testing.assert_array_equal(np.asarray(a), np.asarray(chat))

    def test_residual_closes_the_books_through_the_wire(self):
        """chat(decoded) + resid == c + e: packing defers, never loses."""
        c, e, u_sel, u_rnd = _leaf((128,), F64, m=2, seed=2)
        spec = LeafSpec.build((128,), F64, 0.3, 4).stacked(2)
        flat, e_flat = c.reshape(spec.rows, spec.cols), e.reshape(2, 128)
        payload, resid = encode_leaf(flat, e_flat, u_sel, u_rnd, spec)
        decoded = decode_leaf(payload, spec)
        np.testing.assert_allclose(
            np.asarray(decoded + resid), np.asarray(flat + e_flat),
            rtol=0, atol=1e-12,
        )

    def test_grid_edge_levels_survive_the_word_packer(self):
        """REGRESSION (review): fp rounding can land kept*(s/safe) an ulp
        outside [-s, s] (scale 6.4059205 in f32 gives 127*(s/x)*x =
        127.00001), so stochastic rounding could emit level -s-1 == -1 ==
        uint32 0xFFFFFFFF, whose carry corrupts every neighbour in its
        packed word.  quantize_levels clamps to the grid, so the wire
        round-trip stays exact at both grid edges."""
        x = 6.4059205
        c = jnp.array([[-x, x / 2, 0.0, x] + [0.0] * 60], F32)
        spec = dataclasses.replace(
            LeafSpec.build((64,), F32, 1.0, 8), encoding="quant"
        )
        # u_rnd high: floor survives the Bernoulli, the worst case
        u_rnd = jnp.full(c.shape, 0.999, F32)
        payload, resid = encode_leaf(c, None, None, u_rnd, spec)
        decoded = decode_leaf(payload, spec)
        chat, _ = compress_leaf(c, None, None, u_rnd, k=64, bits=8)
        np.testing.assert_array_equal(np.asarray(decoded), np.asarray(chat))
        # levels live strictly inside the 8-bit budget: no 0xFFFFFFFF
        lvls = np.asarray(decoded[0, :4]) * (127.0 / np.max(np.abs(c)))
        assert np.all(np.abs(np.round(lvls)) <= 127)

    def test_zero_rows_survive(self):
        """All-zero rows (zero quantization scale) decode to zeros."""
        spec = dataclasses.replace(
            LeafSpec.build((128,), F32, 0.25, 8), rows=3
        )
        c = jnp.zeros((3, 128), F32)
        u = jax.random.uniform(jax.random.PRNGKey(3), (3, 128))
        payload, resid = encode_leaf(c, None, u, u, spec)
        assert not bool(jnp.any(decode_leaf(payload, spec)))
        assert not bool(jnp.any(resid))


# ------------------------------------------------------------ wire layout
class TestLeafSpec:
    def test_rows_are_quantization_groups(self):
        assert wire_rows_cols(()) == (1, 1)
        assert wire_rows_cols((7,)) == (1, 7)
        assert wire_rows_cols((4, 32)) == (4, 32)
        assert wire_rows_cols((2, 3, 64)) == (6, 64)

    def test_index_width_derives_from_row_length(self):
        # UNSIGNED halfword: int16 would overflow at 2**15 columns; the
        # max stored index is cols - 1, so uint16 covers cols == 2**16
        assert LeafSpec.build((100,), F32, 0.1, 32).index_dtype == jnp.uint16
        assert (
            LeafSpec.build((2**16,), F32, 0.1, 32).index_dtype == jnp.uint16
        )
        assert (
            LeafSpec.build((2**16 + 1,), F32, 0.1, 32).index_dtype
            == jnp.int32
        )
        # a matrix with many short rows still indexes within a row
        assert (
            LeafSpec.build((2**17, 8), F32, 0.5, 32).index_dtype == jnp.uint16
        )

    def test_halfword_indices_above_int16_range_round_trip(self):
        """REGRESSION (review): rows with 2**15 < cols < 2**16 keep
        2-byte indices; a signed int16 would wrap negative above 32767
        and the scatter-add would silently misplace the tail of the
        row.  Kept entries beyond column 32768 must survive the wire."""
        cols = 40_000
        spec = LeafSpec.build((cols,), F32, 0.001, 32)
        assert spec.index_dtype == jnp.uint16 and spec.encoding == "sparse"
        c = jnp.zeros((1, cols), F32).at[0, cols - 2].set(7.0)
        payload, _ = encode_leaf(c, None, None, None, spec)
        assert int(jnp.max(payload.indices.astype(jnp.int32))) == cols - 2
        decoded = decode_leaf(payload, spec)
        np.testing.assert_array_equal(np.asarray(decoded), np.asarray(c))

    def test_encoding_chooses_cheapest(self):
        # near-dense ratio: value+index costs more than sending densely
        assert LeafSpec.build((100,), F64, 0.9, 32).encoding == "dense"
        # genuinely sparse, unquantized
        assert LeafSpec.build((100,), F64, 0.1, 32).encoding == "sparse"
        # quantization wins on a long row
        assert LeafSpec.build((1000,), F64, 0.1, 8).encoding == "quant"
        # tiny row: the per-row scale overhead loses to plain sparse
        assert LeafSpec.build((10,), F64, 0.1, 8).encoding == "sparse"
        # mid/high kept fraction: packing ALL levels with implicit
        # indices beats paying an index per kept level
        spec = LeafSpec.build((1000,), F64, 0.9, 8)
        assert spec.encoding == "quant_dense"
        # 250 words + one f64 scale vs 900 levels + 900 uint16 indices
        assert spec.wire_bytes() == 4 * 250 + 8

    def test_identity_config_is_verbatim_dense(self):
        spec = LeafSpec.build((64,), F32, 1.0, 32)
        assert spec.encoding == "dense" and spec.k == 64
        c = jax.random.normal(jax.random.PRNGKey(4), (1, 64), F32)
        payload, _ = encode_leaf(c, None, None, None, spec)
        assert payload.indices is None and payload.scales is None
        np.testing.assert_array_equal(np.asarray(payload.data), np.asarray(c))
        np.testing.assert_array_equal(
            np.asarray(decode_leaf(payload, spec)), np.asarray(c)
        )


# ------------------------------------------------------ bytes accounting
class TestMeasuredBytes:
    @pytest.mark.parametrize("dtype", [F32, F64, BF16])
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("ratio,bits,mode", CONFIGS)
    def test_price_equals_packed_length(self, dtype, shape, ratio, bits,
                                        mode):
        """The analytic price, the abstract probe and the concrete packed
        buffers are the same number — agreement by construction."""
        spec = LeafSpec.build(shape, dtype, ratio, bits, mode)
        assert probe_leaf_bytes(spec) == spec.wire_bytes()
        c, e, u_sel, u_rnd = _leaf(shape, dtype, m=1, seed=5)
        flat = c.reshape(spec.rows, spec.cols)
        payload, _ = encode_leaf(
            flat, e.reshape(flat.shape), u_sel, u_rnd, spec
        )
        assert payload.nbytes == spec.wire_bytes()

    def test_measured_matches_priced_per_round(self):
        x = jnp.zeros((1000,))
        y = jnp.zeros((10,))
        for s in (
            CompressedGT(compression_ratio=0.1, wire_transport=True),
            CompressedGT(
                compression_ratio=0.25, mode="randk", wire_transport=True
            ),
            QuantizedGT(bits=8, wire_transport=True),
            QuantizedGT(bits=4, ratio=0.1, wire_transport=True),
            QuantizedGT(
                bits=2, ratio=0.5, mode="randk", wire_transport=True
            ),
        ):
            priced = s.bytes_per_round(x, y, 16)
            bare = measured_bytes_per_round(
                s, x, y, 16, include_headers=False
            )
            assert bare == priced, s
            full = measured_bytes_per_round(s, x, y, 16)
            assert full - bare == wire_header_overhead(x, y)
            assert wire_header_overhead(x, y) == 2 * 2 * HEADER_BYTES

    def test_dense_strategies_measure_their_price(self):
        x, y = jnp.zeros((100,)), jnp.zeros((5,))
        for s in (GradientTracking(), QuantizedGT(bits=32, ratio=1.0)):
            assert measured_bytes_per_round(s, x, y, 8) == s.bytes_per_round(
                x, y, 8
            )

    def test_correction_dtype_is_what_gets_priced_and_measured(self):
        """REGRESSION (review): the engine casts corrections to
        `correction_dtype` before the transform, so both the analytic
        price and the measured probe must use that dtype for the
        correction exchange — and they must equal what the strategy's
        PackedTree actually weighs."""
        x, y = jnp.zeros((256,)), jnp.zeros((64,))
        s = QuantizedGT(
            bits=8, ratio=0.5, wire_transport=True,
            correction_dtype=jnp.bfloat16, seed=0,
        )
        priced = s.bytes_per_round(x, y, 16)
        bare = measured_bytes_per_round(s, x, y, 16, include_headers=False)
        assert bare == priced
        # and against the real packed buffers the transform emits
        m = 2
        cx = jnp.zeros((m,) + x.shape, jnp.bfloat16)
        cy = jnp.zeros((m,) + y.shape, jnp.bfloat16)
        px, py, _ = s.transform_correction(cx, cy, s.init_state(x, y, m))
        dense_models = 2 * (x.size * 8 + y.size * 8)
        assert priced == dense_models + 2 * (
            (px.wire_bytes() + py.wire_bytes()) // m
        )

    def test_wire_off_measures_dense_traffic(self):
        """REGRESSION (review): a compressor with wire_transport OFF
        still moves dense masked corrections — its measurement is the
        dense gradient-tracking cost, NOT its compressed price; the gap
        is what enabling the wire buys."""
        x, y = jnp.zeros((1000,)), jnp.zeros((10,))
        dense_round = 4 * (x.size * 8 + y.size * 8)
        for off, on in (
            (CompressedGT(compression_ratio=0.1),
             CompressedGT(compression_ratio=0.1, wire_transport=True)),
            (QuantizedGT(bits=8),
             QuantizedGT(bits=8, wire_transport=True)),
        ):
            assert off.bytes_per_round(x, y, 16) == on.bytes_per_round(
                x, y, 16
            )
            assert measured_bytes_per_round(off, x, y, 16) == dense_round
            assert measured_bytes_per_round(on, x, y, 16) < dense_round

    def test_packed_tree_reports_its_bytes(self):
        s = QuantizedGT(bits=8, ratio=0.5, wire_transport=True)
        m = 4
        cx = {"a": jax.random.normal(jax.random.PRNGKey(6), (m, 256))}
        cy = {"d": jax.random.normal(jax.random.PRNGKey(7), (m, 64))}
        state = s.init_state(
            jax.tree.map(lambda u: u[0], cx),
            jax.tree.map(lambda u: u[0], cy), m,
        )
        px, py, _ = s.transform_correction(cx, cy, state)
        assert isinstance(px, PackedTree) and isinstance(py, PackedTree)
        # the stacked payload is m agents' worth of the per-agent price
        per_agent = LeafSpec.build((256,), cx["a"].dtype, 0.5, 8).wire_bytes()
        assert px.wire_bytes() == m * per_agent
        assert px.total_bytes() == px.wire_bytes() + HEADER_BYTES


# ------------------------------------------------------------ engine path
class TestEngineWireParity:
    @pytest.fixture(scope="class")
    def quad(self):
        return make_quadratic_problem(
            jax.random.PRNGKey(0), dim=6, num_samples=20, num_agents=4
        )

    @pytest.mark.parametrize(
        "mk",
        [
            lambda w: CompressedGT(compression_ratio=0.25, wire_transport=w),
            lambda w: QuantizedGT(bits=8, wire_transport=w),
            lambda w: QuantizedGT(
                bits=4, ratio=0.5, mode="randk", wire_transport=w
            ),
            lambda w: CompressedGT(
                compression_ratio=0.25, error_feedback=False, wire_transport=w
            ),
        ],
        ids=["compressed", "quantized", "quantized_randk", "no_feedback"],
    )
    def test_wire_and_dense_paths_are_bitwise_identical(self, quad, mk):
        """The packed payload carries exactly the dense chat, so turning
        the wire on cannot move a single bit of the iterates."""
        x0 = jnp.zeros(6)
        outs = {}
        for w in (False, True):
            s = mk(w)
            rnd = jax.jit(
                make_round(quad.loss, s, 4, 1e-3, explicit_state=True)
            )
            (xT, yT, _), _ = run_strategy_rounds(
                rnd, x0, x0, quad.agent_data, 5, s.init_state(x0, x0, 4)
            )
            outs[w] = (np.asarray(xT), np.asarray(yT))
        np.testing.assert_array_equal(outs[False][0], outs[True][0])
        np.testing.assert_array_equal(outs[False][1], outs[True][1])

    def test_identity_config_degenerates_to_dense_gt(self, quad):
        """bits>=32 + ratio>=1 with the wire on IS GradientTracking —
        bitwise, keeping the existing parity suites meaningful."""
        s = QuantizedGT(bits=32, ratio=1.0, wire_transport=True)
        assert not s.stateful and s.exact_correction
        ra = jax.jit(make_round(quad.loss, s, 4, 1e-3))
        rb = jax.jit(make_round(quad.loss, GradientTracking(), 4, 1e-3))
        xa = xb = jnp.ones(6)
        ya = yb = -jnp.ones(6)
        for t in range(4):
            xa, ya = ra(xa, ya, quad.agent_data)
            xb, yb = rb(xb, yb, quad.agent_data)
            assert bool(jnp.all(xa == xb)) and bool(jnp.all(ya == yb)), t

    def test_transform_returns_packed_trees_with_decode_hook(self):
        """The engine detects wire payloads by the duck-typed `decode`
        hook; the decoded tree matches the dense transform exactly."""
        s_wire = QuantizedGT(bits=8, ratio=0.25, wire_transport=True)
        s_dense = QuantizedGT(bits=8, ratio=0.25)
        m = 3
        mk = lambda key, sh: jax.random.normal(key, (m,) + sh)
        ks = jax.random.split(jax.random.PRNGKey(8), 3)
        cx = {"a": mk(ks[0], (128,)), "b": mk(ks[1], (4, 32))}
        cy = {"d": mk(ks[2], (37,))}
        x0 = jax.tree.map(lambda u: u[0], cx)
        y0 = jax.tree.map(lambda u: u[0], cy)
        pw = s_wire.transform_correction(
            cx, cy, s_wire.init_state(x0, y0, m)
        )
        pd = s_dense.transform_correction(
            cx, cy, s_dense.init_state(x0, y0, m)
        )
        assert hasattr(pw[0], "decode")
        for a, b in zip(
            jax.tree.leaves((pw[0].decode(), pw[1].decode())),
            jax.tree.leaves((pd[0], pd[1])),
        ):
            assert a.shape == b.shape and a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # error-feedback buffers agree too (resid is path-independent)
        for a, b in zip(jax.tree.leaves(pw[2]), jax.tree.leaves(pd[2])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_runner_wire_report(self, quad):
        from repro.fed import FederatedRunner

        runner = FederatedRunner.from_strategy(
            quad.loss,
            QuantizedGT(bits=8, wire_transport=True),
            quad.agent_data,
            num_local_steps=4,
            eta_x=1e-3,
        )
        x0 = jnp.zeros(6)
        rep = runner.wire_report(x0, x0, 4)
        assert rep["measured_bytes_per_round"] - rep["bytes_per_round"] == (
            wire_header_overhead(x0, x0)
        )
