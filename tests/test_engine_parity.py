"""Differential tests: the unified round engine vs the legacy algorithms.

The engine (`repro.core.engine.make_round` + `repro.fed.strategies`) must
reproduce the pre-engine implementations, which are kept verbatim as
`*_reference` oracles:

  * GradientTracking vs FedGDA-GT — BITWISE identical iterates over
    multiple rounds (the public `make_fedgda_gt_round` wrapper AND the
    frozen reference), including the reduced-dtype correction and the
    m == 1 reduction-to-GDA case;
  * LocalOnly vs Local SGDA — allclose;
  * FullSync vs K composed centralized GDA steps — allclose.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    make_fedgda_gt_round,
    make_fedgda_gt_round_reference,
    make_gda_step,
    make_gda_step_reference,
    make_local_sgda_round,
    make_local_sgda_round_reference,
    make_round,
)
from repro.fed import FullSync, GradientTracking, LocalOnly
from repro.problems import make_quadratic_problem

ETA = 1e-4
ROUNDS = 6  # acceptance: bitwise over >= 5 rounds


def _problem(rng, m=6, dim=10):
    return make_quadratic_problem(rng, dim=dim, num_samples=40, num_agents=m)


def _iterate(rnd, x, y, data, rounds=ROUNDS):
    out = []
    for _ in range(rounds):
        x, y = rnd(x, y, data)
        out.append((np.asarray(x), np.asarray(y)))
    return out


def _assert_bitwise(trace_a, trace_b):
    for t, ((xa, ya), (xb, yb)) in enumerate(zip(trace_a, trace_b)):
        assert (xa == xb).all(), f"x diverges at round {t}"
        assert (ya == yb).all(), f"y diverges at round {t}"


# ------------------------------------------------- gradient tracking (bitwise)
class TestGradientTrackingParity:
    @pytest.mark.parametrize("K", [1, 2, 5])
    def test_engine_bitwise_equals_legacy_constructor(self, rng, K):
        prob = _problem(rng)
        engine = jax.jit(make_round(prob.loss, GradientTracking(), K, ETA))
        legacy = jax.jit(make_fedgda_gt_round(prob.loss, K, ETA))
        x, y = jnp.ones(10), -jnp.ones(10)
        _assert_bitwise(
            _iterate(engine, x, y, prob.agent_data),
            _iterate(legacy, x, y, prob.agent_data),
        )

    @pytest.mark.parametrize("K", [1, 2, 5])
    def test_engine_bitwise_equals_frozen_reference(self, rng, K):
        """The real differential test: the reference is the pre-engine
        implementation kept verbatim, not a wrapper."""
        prob = _problem(rng)
        engine = jax.jit(make_round(prob.loss, GradientTracking(), K, ETA))
        ref = jax.jit(make_fedgda_gt_round_reference(prob.loss, K, ETA))
        x, y = jnp.ones(10), -jnp.ones(10)
        _assert_bitwise(
            _iterate(engine, x, y, prob.agent_data),
            _iterate(ref, x, y, prob.agent_data),
        )

    def test_engine_bitwise_with_reduced_correction_dtype(self, rng):
        prob = _problem(rng)
        strat = GradientTracking(correction_dtype=jnp.bfloat16)
        engine = jax.jit(make_round(prob.loss, strat, 4, ETA))
        ref = jax.jit(
            make_fedgda_gt_round_reference(
                prob.loss, 4, ETA, correction_dtype=jnp.bfloat16
            )
        )
        x, y = jnp.ones(10), -jnp.ones(10)
        _assert_bitwise(
            _iterate(engine, x, y, prob.agent_data),
            _iterate(ref, x, y, prob.agent_data),
        )

    @pytest.mark.parametrize("K", [1, 3])
    def test_m1_reduces_to_k_gda_steps(self, rng, K):
        """Single agent: the correction is identically zero and one round
        IS K centralized GDA steps (Appendix D.4)."""
        prob = make_quadratic_problem(
            rng, dim=8, num_samples=30, num_agents=1
        )
        engine = jax.jit(make_round(prob.loss, GradientTracking(), K, ETA))
        ref = jax.jit(make_fedgda_gt_round_reference(prob.loss, K, ETA))
        step = jax.jit(make_gda_step_reference(prob.loss, ETA, ETA))
        x, y = jnp.ones(8), -jnp.ones(8)
        _assert_bitwise(
            _iterate(engine, x, y, prob.agent_data),
            _iterate(ref, x, y, prob.agent_data),
        )
        xe, ye = engine(x, y, prob.agent_data)
        xc, yc = x, y
        for _ in range(K):
            xc, yc = step(xc, yc, prob.agent_data)
        np.testing.assert_allclose(np.asarray(xe), np.asarray(xc), rtol=1e-12)
        np.testing.assert_allclose(np.asarray(ye), np.asarray(yc), rtol=1e-12)


# ----------------------------------------------------------- local only
class TestLocalOnlyParity:
    @pytest.mark.parametrize("K", [1, 2, 5])
    def test_engine_allclose_to_legacy(self, rng, K):
        prob = _problem(rng)
        engine = jax.jit(make_round(prob.loss, LocalOnly(), K, ETA, 2 * ETA))
        legacy = jax.jit(make_local_sgda_round(prob.loss, K, ETA, 2 * ETA))
        ref = jax.jit(
            make_local_sgda_round_reference(prob.loss, K, ETA, 2 * ETA)
        )
        x, y = jnp.ones(10), -jnp.ones(10)
        te = _iterate(engine, x, y, prob.agent_data)
        tl = _iterate(legacy, x, y, prob.agent_data)
        tr = _iterate(ref, x, y, prob.agent_data)
        for (xe, ye), (xl, yl), (xr, yr) in zip(te, tl, tr):
            np.testing.assert_allclose(xe, xl, rtol=1e-12)
            np.testing.assert_allclose(xe, xr, rtol=1e-12)
            np.testing.assert_allclose(ye, yl, rtol=1e-12)
            np.testing.assert_allclose(ye, yr, rtol=1e-12)


# ------------------------------------------------------------- full sync
class TestFullSyncParity:
    @pytest.mark.parametrize("K", [1, 4])
    def test_one_round_equals_k_composed_gda_steps(self, rng, K):
        prob = _problem(rng)
        engine = jax.jit(make_round(prob.loss, FullSync(), K, ETA, 2 * ETA))
        step_pub = jax.jit(make_gda_step(prob.loss, ETA, 2 * ETA))
        step_ref = jax.jit(make_gda_step_reference(prob.loss, ETA, 2 * ETA))
        x, y = jnp.ones(10), -jnp.ones(10)
        for _ in range(ROUNDS):
            x1, y1 = engine(x, y, prob.agent_data)
            xp, yp = x, y
            xr, yr = x, y
            for _ in range(K):
                xp, yp = step_pub(xp, yp, prob.agent_data)
                xr, yr = step_ref(xr, yr, prob.agent_data)
            np.testing.assert_allclose(np.asarray(x1), np.asarray(xp), rtol=1e-12)
            np.testing.assert_allclose(np.asarray(x1), np.asarray(xr), rtol=1e-12)
            np.testing.assert_allclose(np.asarray(y1), np.asarray(yp), rtol=1e-12)
            np.testing.assert_allclose(np.asarray(y1), np.asarray(yr), rtol=1e-12)
            x, y = x1, y1
