"""Fused pack/unpack payload kernel conformance (deliverable: ISSUE 3).

Three layers of agreement, all on CPU via interpret=True:

  * pack kernel vs oracle — `pack_payload_2d` (Pallas) against
    `ref.pack_payload_ref` on lane-aligned shapes, fp32 / bf16 / fp64,
    topk / randk, every encoding, with and without feedback: packed
    uint32 words and indices agree BITWISE (they are integer pipelines),
    scales and residuals to <= 1e-6 (the kernel compiles as one XLA unit
    whose fusion may round the float math differently);
  * unpack kernel vs oracle — `unpack_payload_2d` against
    `ref.decode_payload_ref` on the same payloads;
  * word packing algebra — pack_words/unpack_words round-trip bitwise
    for every storage width, including non-power-of-two bit requests
    that pad up to the next sub-word width;
  * dispatcher — `encode_leaf(use_kernel=True)` takes the fused path
    exactly on lane-aligned leaves with results interchangeable with
    the oracle path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fed.transport import LeafSpec, decode_leaf, encode_leaf
from repro.kernels import pack_payload_2d, ref, unpack_payload_2d

pytestmark = pytest.mark.kernel  # Pallas interpret-mode suite

F32, F64, BF16 = jnp.float32, jnp.float64, jnp.bfloat16
ALIGNED = [(1, 128), (4, 128), (6, 256), (3, 384)]


def _spec(R, C, ratio, bits, mode="topk"):
    return dataclasses.replace(
        LeafSpec.build((C,), F32, ratio, bits, mode), rows=R
    )


def _inputs(shape, dtype, seed=0):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    c = jax.random.normal(k1, shape, dtype)
    e = (0.1 * jax.random.normal(k2, shape)).astype(dtype)
    u_sel = jax.random.uniform(k3, shape)
    u_rnd = jax.random.uniform(k4, shape)
    return c, e, u_sel, u_rnd


def _run_both(c, e, u_sel, u_rnd, spec):
    kw = dict(k=spec.k, bits=spec.bits, mode=spec.mode,
              encoding=spec.encoding)
    got = pack_payload_2d(
        c, e, u_sel, u_rnd,
        index_dtype=spec.index_dtype, scale_dtype=spec.scale_dtype,
        interpret=True, **kw,
    )
    want = ref.pack_payload_ref(
        c, e, u_sel, u_rnd, index_dtype=spec.index_dtype, **kw
    )
    return got, want


def _assert_match(got, want, spec, atol=1e-6):
    data_g, idx_g, scale_g, res_g = got
    data_w, idx_w, scale_w, res_w = want
    if spec.encoding == "quant":  # uint32 words: bitwise
        np.testing.assert_array_equal(np.asarray(data_g), np.asarray(data_w))
    else:
        np.testing.assert_allclose(
            np.asarray(data_g, np.float64), np.asarray(data_w, np.float64),
            rtol=0, atol=atol,
        )
    np.testing.assert_array_equal(np.asarray(idx_g), np.asarray(idx_w))
    assert idx_g.dtype == spec.index_dtype
    np.testing.assert_allclose(
        np.asarray(scale_g, np.float64), np.asarray(scale_w, np.float64),
        rtol=0, atol=atol,
    )
    np.testing.assert_allclose(
        np.asarray(res_g, np.float64), np.asarray(res_w, np.float64),
        rtol=0, atol=atol,
    )


# --------------------------------------------------- pack kernel vs oracle
class TestPackKernelMatchesReference:
    @pytest.mark.parametrize("shape", ALIGNED)
    @pytest.mark.parametrize("dtype", [F32, BF16])
    @pytest.mark.parametrize("mode", ["topk", "randk"])
    @pytest.mark.parametrize("bits", [32, 8, 4])
    def test_matches_ref(self, shape, dtype, mode, bits):
        c, e, u_sel, u_rnd = _inputs(shape, dtype)
        spec = dataclasses.replace(
            LeafSpec.build((shape[1],), dtype, 1 / 3, bits, mode),
            rows=shape[0],
        )
        got, want = _run_both(c, e, u_sel, u_rnd, spec)
        _assert_match(got, want, spec)

    @pytest.mark.parametrize("shape", [(4, 128), (6, 256)])
    def test_matches_ref_float64(self, shape):
        c, e, u_sel, u_rnd = _inputs(shape, F64)
        spec = dataclasses.replace(
            LeafSpec.build((shape[1],), F64, 0.25, 8), rows=shape[0]
        )
        got, want = _run_both(c, e, u_sel, u_rnd, spec)
        _assert_match(got, want, spec, atol=1e-12)

    @pytest.mark.parametrize(
        "encoding", ["dense", "sparse", "quant", "quant_dense"]
    )
    def test_every_encoding(self, encoding):
        c, e, u_sel, u_rnd = _inputs((4, 256), F32, seed=1)
        spec = dataclasses.replace(
            _spec(4, 256, 0.25, 8), encoding=encoding
        )
        got, want = _run_both(c, e, u_sel, u_rnd, spec)
        _assert_match(got, want, spec)

    def test_no_feedback_path(self):
        c, _, u_sel, u_rnd = _inputs((4, 256), F32, seed=2)
        spec = _spec(4, 256, 0.25, 8)
        got, want = (
            pack_payload_2d(
                c, None, u_sel, u_rnd, k=spec.k, bits=8,
                index_dtype=spec.index_dtype, interpret=True,
            ),
            ref.pack_payload_ref(
                c, None, u_sel, u_rnd, k=spec.k, bits=8,
                index_dtype=spec.index_dtype,
            ),
        )
        _assert_match(got, want, spec)

    def test_block_rows_invariance(self):
        c, e, u_sel, u_rnd = _inputs((8, 256), F32, seed=3)
        spec = _spec(8, 256, 0.25, 8)
        kw = dict(k=spec.k, bits=8, index_dtype=spec.index_dtype)
        a = pack_payload_2d(
            c, e, u_sel, u_rnd, block_rows=8, interpret=True, **kw
        )
        b = pack_payload_2d(
            c, e, u_sel, u_rnd, block_rows=2, interpret=True, **kw
        )
        for g, w in zip(a, b):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))

    def test_indices_sorted_and_exact_k(self):
        """Indices come out ascending with exactly k per row, even under
        ties (all-equal and all-zero rows)."""
        c = jnp.concatenate(
            [jnp.ones((1, 128)), jnp.zeros((1, 128)),
             -jnp.ones((1, 128))]
        ).astype(F32)
        spec = _spec(3, 128, 0.25, 32)
        _, idx, _, _ = pack_payload_2d(
            c, None, None, None, k=spec.k, bits=32, encoding="sparse",
            index_dtype=spec.index_dtype, interpret=True,
        )
        idx = np.asarray(idx)
        assert idx.shape == (3, 32)
        for row in idx:
            assert np.all(np.diff(row) > 0)  # strictly ascending, unique
            assert row.min() >= 0 and row.max() < 128


# ------------------------------------------------- unpack kernel vs oracle
class TestUnpackKernelMatchesReference:
    @pytest.mark.parametrize("dtype", [F32, BF16, F64])
    @pytest.mark.parametrize("bits", [8, 4])
    def test_matches_ref(self, dtype, bits):
        c, e, u_sel, u_rnd = _inputs((4, 256), dtype, seed=4)
        spec = dataclasses.replace(
            LeafSpec.build((256,), dtype, 0.25, bits), rows=4
        )
        data, idx, scale, _ = ref.pack_payload_ref(
            c, e, u_sel, u_rnd, k=spec.k, bits=bits,
            encoding=spec.encoding, index_dtype=spec.index_dtype,
        )
        kw = dict(cols=256, dtype=dtype, k=spec.k, bits=bits,
                  encoding=spec.encoding)
        got = unpack_payload_2d(data, idx, scale, interpret=True, **kw)
        want = ref.decode_payload_ref(data, idx, scale, **kw)
        np.testing.assert_allclose(
            np.asarray(got, np.float64), np.asarray(want, np.float64),
            rtol=0, atol=1e-6,
        )

    def test_fused_round_trip_equals_dense_compress(self):
        """encode(kernel) -> decode(kernel) reproduces the dense fused
        compress kernel's chat to <= 1 ulp on the same draws."""
        from repro.kernels import compress_correction_2d

        c, e, u_sel, u_rnd = _inputs((4, 256), F32, seed=5)
        spec = _spec(4, 256, 0.25, 8)
        payload, resid = encode_leaf(
            c, e, u_sel, u_rnd, spec, use_kernel=True, interpret=True
        )
        decoded = decode_leaf(payload, spec, use_kernel=True, interpret=True)
        chat, resid_dense = compress_correction_2d(
            c, e, u_sel, u_rnd, k=spec.k, bits=8, interpret=True
        )
        np.testing.assert_allclose(
            np.asarray(decoded, np.float64), np.asarray(chat, np.float64),
            rtol=0, atol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(resid, np.float64),
            np.asarray(resid_dense, np.float64),
            rtol=0, atol=1e-6,
        )


# ------------------------------------------------------ word pack algebra
class TestWordPacking:
    @pytest.mark.parametrize("bits", [2, 3, 4, 6, 8, 12, 16])
    @pytest.mark.parametrize("k", [1, 7, 8, 31, 32, 100])
    def test_pack_unpack_round_trip_bitwise(self, bits, k):
        sb = ref.storage_bits(bits)
        assert sb in (2, 4, 8, 16, 32) and sb >= bits
        levels = jax.random.randint(
            jax.random.PRNGKey(bits * 101 + k), (5, k), 0, 2**bits - 1
        ).astype(jnp.uint32)
        words = ref.pack_words(levels, bits)
        assert words.dtype == jnp.uint32
        assert words.shape == (5, ref.word_layout(k, bits)[2])
        back = ref.unpack_words(words, k, bits)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(levels))

    def test_word_budget_is_tight(self):
        # 8-bit levels: 4 per word; 9 levels -> 3 words, not 9 bytes
        assert ref.word_layout(9, 8) == (8, 4, 3)
        # 3-bit levels store at 4 bits: 8 per word
        assert ref.word_layout(16, 3) == (4, 8, 2)
        assert ref.storage_bits(17) == 32


# ------------------------------------------------------------- dispatcher
class TestDispatcher:
    @pytest.mark.parametrize("C,fused", [(128, True), (256, True),
                                         (100, False), (37, False)])
    def test_kernel_dispatch_by_alignment(self, C, fused, monkeypatch):
        import repro.fed.transport as tr

        calls = {"kernel": 0}
        orig = tr.pack_payload_2d

        def spy(*a, **k):
            calls["kernel"] += 1
            return orig(*a, **k)

        monkeypatch.setattr(tr, "pack_payload_2d", spy)
        c, e, u_sel, u_rnd = _inputs((2, C), F32, seed=6)
        spec = dataclasses.replace(
            LeafSpec.build((C,), F32, 0.5, 8), rows=2
        )
        fusedp, _ = tr.encode_leaf(
            c, e, u_sel, u_rnd, spec, use_kernel=True, interpret=True
        )
        assert calls["kernel"] == (1 if fused else 0)
        plain, _ = tr.encode_leaf(c, e, u_sel, u_rnd, spec, use_kernel=False)
        for a, b in zip(fusedp, plain):
            if a is None:
                assert b is None
                continue
            if a.dtype == jnp.uint32 or "int" in str(a.dtype):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            else:
                np.testing.assert_allclose(
                    np.asarray(a, np.float64), np.asarray(b, np.float64),
                    rtol=0, atol=1e-6,
                )
