"""Population processes + RoundSchedule: determinism, seed-fold
isolation, membership contracts, and the PartialParticipation dedup.

The load-bearing facts pinned here:
  * schedules are a pure function of (population config, seed) — two
    builds, or builds on different "runtimes", yield identical traces
    (so sync and async consume bit-identical membership);
  * the availability stream is a DEDICATED fold of the run seed: other
    consumers of PRNGKey(seed) cannot perturb it;
  * the membership contract: budgets are 0 iff inactive, in [1, K] when
    active, and at least `min_active` agents survive every round;
  * `PartialParticipation.sample_weights` delegating its draw to
    `sim.population.fixed_size_mask` stays BITWISE identical to the
    historical inline implementation;
  * re-normalized weights over ANY nonempty active set sum to 1.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fed import PartialParticipation
from repro.sim import (
    AlwaysOn,
    BernoulliAvailability,
    DeterministicLag,
    DiurnalAvailability,
    ElasticAggregator,
    FixedSizeSampling,
    MarkovChurn,
    NoStragglers,
    Population,
    RoundSchedule,
    UniformStragglers,
    availability_key,
    fixed_size_mask,
    make_population,
    renormalized_weights,
)

pytestmark = pytest.mark.sim

M, T, K = 12, 40, 7

PROCESSES = [
    AlwaysOn(),
    BernoulliAvailability(p=0.6),
    MarkovChurn(p_leave=0.3, p_join=0.5),
    DiurnalAvailability(period=10, low=0.2, high=0.9),
    FixedSizeSampling(participation=0.4),
]
STRAGGLERS = [
    NoStragglers(),
    UniformStragglers(p_straggle=0.7, min_frac=0.3),
    DeterministicLag(slow_every=3, budget_frac=0.3),
]


def _schedules(availability, stragglers, seed=0):
    pop = Population(M, availability, stragglers)
    return pop.schedule(seed, T, K)


# ------------------------------------------------------------- determinism
class TestDeterminism:
    @pytest.mark.parametrize("avail", PROCESSES, ids=lambda p: type(p).__name__)
    @pytest.mark.parametrize(
        "strag", STRAGGLERS, ids=lambda s: type(s).__name__
    )
    def test_rebuild_trace_identical(self, avail, strag):
        """Two independent builds of the same config => identical
        traces; this is the cross-runtime reproducibility contract the
        sync and async runners rely on (each may build its own
        schedule object)."""
        a = _schedules(avail, strag).trace()
        b = _schedules(avail, strag).trace()
        np.testing.assert_array_equal(a["active"], b["active"])
        np.testing.assert_array_equal(a["budgets"], b["budgets"])

    def test_seed_changes_trace(self):
        a = _schedules(BernoulliAvailability(0.5), NoStragglers(), seed=0)
        b = _schedules(BernoulliAvailability(0.5), NoStragglers(), seed=1)
        assert (a.active != b.active).any()

    def test_availability_stream_is_a_dedicated_fold(self):
        """The availability key is NOT the raw run key: a consumer
        drawing from PRNGKey(seed) directly can never collide with (or
        shift) the availability stream."""
        seed = 7
        raw = jax.random.PRNGKey(seed)
        k = availability_key(seed)
        assert not np.array_equal(np.asarray(raw), np.asarray(k))
        # and it is stable: the same seed always folds to the same key
        assert np.array_equal(np.asarray(k), np.asarray(availability_key(seed)))

    def test_scenario_presets_resolve_and_build(self):
        for name in ("stable", "flaky", "diurnal", "straggler_heavy"):
            sched = make_population(name, M).schedule(0, T, K)
            assert len(sched) == T and sched.m == M
        with pytest.raises(ValueError, match="unknown population scenario"):
            make_population("nope", M)


# ------------------------------------------------------- membership contract
class TestMembershipContract:
    @pytest.mark.parametrize("avail", PROCESSES, ids=lambda p: type(p).__name__)
    @pytest.mark.parametrize(
        "strag", STRAGGLERS, ids=lambda s: type(s).__name__
    )
    def test_budget_bounds(self, avail, strag):
        s = _schedules(avail, strag)
        assert (s.budgets[~s.active] == 0).all()
        assert (s.budgets[s.active] >= 1).all()
        assert (s.budgets[s.active] <= K).all()

    def test_min_active_floor(self):
        pop = Population(
            M, BernoulliAvailability(p=0.01), NoStragglers(), min_active=2
        )
        s = pop.schedule(0, 200, K)
        assert (s.active.sum(axis=1) >= 2).all()

    def test_always_on_is_static_full(self):
        s = _schedules(AlwaysOn(), NoStragglers())
        assert s.is_static_full
        assert s.churn_events() == 0
        ev = s[0]
        assert ev.full and not ev.churned

    def test_stragglers_break_static_full(self):
        s = _schedules(AlwaysOn(), DeterministicLag(slow_every=2))
        assert not s.is_static_full
        assert s[0].full is False

    def test_events_report_joins_and_departures(self):
        active = np.array([[1, 1, 0], [1, 0, 1]], bool)
        budgets = np.where(active, K, 0).astype(np.int32)
        s = RoundSchedule(active, budgets, K)
        ev = s[1]
        np.testing.assert_array_equal(ev.joined, [False, False, True])
        np.testing.assert_array_equal(ev.departed, [False, True, False])
        assert ev.churned and ev.num_active == 2
        # round 0 churns vs the implicit all-present start
        assert s[0].departed[2] and not s[0].joined.any()

    def test_schedule_validates_contract(self):
        active = np.ones((2, 3), bool)
        bad = np.full((2, 3), K, np.int32)
        bad[0, 1] = 0  # active agent with zero budget
        with pytest.raises(ValueError, match="budget of >= 1"):
            RoundSchedule(active, bad, K)
        active2 = ~active
        with pytest.raises(ValueError, match="zero step budget"):
            RoundSchedule(active2, np.full((2, 3), 1, np.int32), K)

    def test_tail_preserves_churn_provenance_at_the_seam(self):
        """Round 0 of `tail(t)` reports joins/departures against the
        TRUE round t-1 active set, not an implicit all-present start."""
        active = np.array(
            [[1, 1, 1], [1, 0, 1], [0, 1, 1], [1, 1, 1]], bool
        )
        budgets = np.where(active, K, 0).astype(np.int32)
        s = RoundSchedule(active, budgets, K)
        t = s.tail(2)
        np.testing.assert_array_equal(t[0].active, s[2].active)
        np.testing.assert_array_equal(t[0].joined, s[2].joined)
        np.testing.assert_array_equal(t[0].departed, s[2].departed)
        # a fresh schedule still baselines round 0 against all-present
        assert s[0].joined.sum() == 0

    def test_fixed_size_sampling_exact_count(self):
        s = _schedules(FixedSizeSampling(participation=0.4), NoStragglers())
        S = FixedSizeSampling(participation=0.4).subset_size(M)
        assert (s.active.sum(axis=1) == S).all()


# -------------------------------------------------------------- weights
class TestWeights:
    def test_renormalized_weights_sum_to_one(self):
        for n_active in range(1, M + 1):
            mask = jnp.zeros((M,), bool).at[:n_active].set(True)
            w = renormalized_weights(mask)
            assert float(jnp.sum(w)) == pytest.approx(1.0, abs=1e-12)
            assert (np.asarray(w)[~np.asarray(mask)] == 0).all()

    def test_aggregator_rebase_off_is_naive(self):
        from repro.fed import GradientTracking

        agg = ElasticAggregator(GradientTracking(), rebase=False)
        mask = jnp.zeros((M,), bool).at[:3].set(True)
        w = agg.weights(mask)
        # naive server: 1/m per active agent — mass leaks
        assert float(jnp.sum(w)) == pytest.approx(3 / M, abs=1e-12)


# ------------------------------------------- PartialParticipation dedup
class TestPartialParticipationDedup:
    def _legacy_sample(self, key, m, S):
        """The historical inline draw, kept verbatim as the oracle."""
        sel = jax.random.permutation(key, m)[:S]
        return jnp.zeros((m,)).at[sel].set(1.0 / S)

    @pytest.mark.parametrize("participation", [0.25, 0.5, 0.75])
    def test_sample_weights_bitwise_vs_legacy(self, participation):
        strat = PartialParticipation(participation=participation, seed=3)
        m = M
        state = strat.init_state(None, None, m)
        S = max(1, int(round(participation * m)))
        key = state["key"]
        for _ in range(5):
            key, sub = jax.random.split(key)
            expected = self._legacy_sample(sub, m, S)
            w, state = strat.sample_weights(state, m)
            np.testing.assert_array_equal(np.asarray(w), np.asarray(expected))

    def test_mask_matches_fixed_size_process_draw(self):
        """One owner: the strategy's weights are exactly the shared
        fixed-size mask, re-normalized."""
        key = jax.random.PRNGKey(5)
        mask = fixed_size_mask(key, M, 4)
        w = renormalized_weights(mask)
        assert int(np.asarray(mask).sum()) == 4
        np.testing.assert_array_equal(
            np.asarray(w) > 0, np.asarray(mask)
        )


# ----------------------------------------------------- hypothesis properties
# guarded per-class (NOT importorskip at module level, which would skip
# the whole non-hypothesis suite above with it)
_HAS_HYPOTHESIS = (
    __import__("importlib").util.find_spec("hypothesis") is not None
)


@pytest.mark.skipif(not _HAS_HYPOTHESIS, reason="needs hypothesis")
class TestProperties:
    def test_any_nonempty_active_set_weights_sum_to_one(self):
        from hypothesis import given, settings
        from hypothesis import strategies as st

        @given(m=st.integers(2, 24), bits=st.integers(1, 2**24 - 1))
        @settings(max_examples=60, deadline=None)
        def inner(m, bits):
            mask = np.array(
                [(bits >> (i % 24)) & 1 for i in range(m)], bool
            )
            if not mask.any():
                mask[0] = True
            w = renormalized_weights(jnp.asarray(mask))
            assert float(jnp.sum(w)) == pytest.approx(1.0, abs=1e-9)
            assert (np.asarray(w)[~mask] == 0.0).all()

        inner()

    def test_markov_schedules_respect_contract(self):
        from hypothesis import given, settings
        from hypothesis import strategies as st

        @given(
            seed=st.integers(0, 2**16),
            p_leave=st.floats(0.05, 0.95),
            p_join=st.floats(0.05, 0.95),
        )
        @settings(max_examples=20, deadline=None)
        def inner(seed, p_leave, p_join):
            pop = Population(
                8,
                MarkovChurn(p_leave=p_leave, p_join=p_join),
                UniformStragglers(p_straggle=0.5, min_frac=0.2),
            )
            s = pop.schedule(seed, 25, 6)
            assert (s.active.sum(axis=1) >= 1).all()
            assert (s.budgets[~s.active] == 0).all()
            assert (s.budgets[s.active] >= 1).all()
            assert (s.budgets[s.active] <= 6).all()

        inner()
