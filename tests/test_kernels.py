"""Per-kernel validation: shape/dtype sweeps, Pallas interpret=True vs the
pure-jnp ref.py oracles (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    batched_ssm_scan,
    flash_attention,
    grouped_flash_attention,
    gt_update_2d,
    make_gt_update_fn,
    ref,
    ssm_scan,
)

pytestmark = pytest.mark.kernel  # Pallas interpret-mode suite

F32, BF16 = jnp.float32, jnp.bfloat16


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == BF16 else dict(rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ gt_update
class TestGtUpdate:
    @pytest.mark.parametrize("shape", [(8, 128), (256, 128), (128, 512), (512, 384)])
    @pytest.mark.parametrize("dtype", [F32, BF16])
    @pytest.mark.parametrize("sign", [-1.0, 1.0])
    def test_matches_ref(self, shape, dtype, sign):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        z = jax.random.normal(k1, shape, dtype)
        g = jax.random.normal(k2, shape, dtype)
        c = jax.random.normal(k3, shape, dtype)
        eta = 3e-3
        got = gt_update_2d(
            z, g, c, eta=eta, sign=sign,
            block_rows=min(128, shape[0]), interpret=True,
        )
        want = ref.gt_update_ref(z, g, c, eta, sign)
        assert got.dtype == dtype
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
        )

    def test_fp8_correction_dtype(self):
        """The beyond-paper fp8 correction storage must flow through the
        kernel (cast up inside, result dtype = param dtype)."""
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
        z = jax.random.normal(k1, (128, 128), F32)
        g = jax.random.normal(k2, (128, 128), F32)
        c = jax.random.normal(k3, (128, 128), F32).astype(jnp.float8_e4m3fn)
        got = gt_update_2d(z, g, c, eta=1e-2, sign=-1.0, interpret=True)
        want = ref.gt_update_ref(z, g, c, 1e-2, -1.0)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
        )

    def test_pytree_wrapper_handles_ragged_sizes(self):
        """make_gt_update_fn pads non-multiple-of-128 leaves; values must be
        identical to the oracle on every leaf."""
        key = jax.random.PRNGKey(2)
        ks = jax.random.split(key, 9)
        tree_shape = [(17,), (3, 5), (130, 7)]
        z = {f"l{i}": jax.random.normal(ks[i], s) for i, s in enumerate(tree_shape)}
        g = {f"l{i}": jax.random.normal(ks[3 + i], s) for i, s in enumerate(tree_shape)}
        c = {f"l{i}": jax.random.normal(ks[6 + i], s) for i, s in enumerate(tree_shape)}
        upd = make_gt_update_fn(interpret=True, use_kernel=True)
        got = upd(z, g, c, 1e-2, 1.0)
        for kname in z:
            want = ref.gt_update_ref(z[kname], g[kname], c[kname], 1e-2, 1.0)
            np.testing.assert_allclose(
                np.asarray(got[kname]), np.asarray(want), rtol=1e-6, atol=1e-6
            )
            assert got[kname].shape == z[kname].shape


# ------------------------------------------------------------ flash_attention
class TestFlashAttention:
    @pytest.mark.parametrize("Sq,Skv", [(128, 128), (256, 256), (128, 384)])
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("dtype", [F32, BF16])
    def test_matches_ref(self, Sq, Skv, causal, dtype):
        if causal and Sq != Skv:
            pytest.skip("causal with Sq<Skv is the cache case, covered below")
        B, H, hd = 1, 2, 64
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(kq, (B, H, Sq, hd), dtype)
        k = jax.random.normal(kk, (B, H, Skv, hd), dtype)
        v = jax.random.normal(kv, (B, H, Skv, hd), dtype)
        got = flash_attention(q, k, v, causal=causal, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
        )

    @pytest.mark.parametrize("window", [128, 256])
    def test_sliding_window(self, window):
        B, H, S, hd = 1, 2, 512, 64
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(kq, (B, H, S, hd), F32)
        k = jax.random.normal(kk, (B, H, S, hd), F32)
        v = jax.random.normal(kv, (B, H, S, hd), F32)
        got = flash_attention(q, k, v, causal=True, window=window, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
        )

    def test_logit_softcap(self):
        B, H, S, hd = 1, 1, 256, 64
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(2), 3)
        q = 4.0 * jax.random.normal(kq, (B, H, S, hd), F32)
        k = 4.0 * jax.random.normal(kk, (B, H, S, hd), F32)
        v = jax.random.normal(kv, (B, H, S, hd), F32)
        got = flash_attention(q, k, v, causal=True, softcap=50.0, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True, softcap=50.0)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
        )
        # and the capped result differs from the uncapped one
        uncapped = ref.flash_attention_ref(q, k, v, causal=True)
        assert float(jnp.max(jnp.abs(want - uncapped))) > 1e-3

    @pytest.mark.parametrize("block_q,block_kv", [(64, 128), (128, 64), (64, 64)])
    def test_block_shape_invariance(self, block_q, block_kv):
        """The result must not depend on the BlockSpec tiling."""
        B, H, S, hd = 1, 1, 256, 64
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(kq, (B, H, S, hd), F32)
        k = jax.random.normal(kk, (B, H, S, hd), F32)
        v = jax.random.normal(kv, (B, H, S, hd), F32)
        got = flash_attention(
            q, k, v, causal=True, block_q=block_q, block_kv=block_kv,
            interpret=True,
        )
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
        )

    def test_gqa_adapter(self):
        """grouped_flash_attention repeats KV groups and restores layout."""
        B, S, H, KV, hd = 2, 128, 8, 2, 64
        kq, kk, kv = jax.random.split(jax.random.PRNGKey(4), 3)
        q = jax.random.normal(kq, (B, S, H, hd), F32)
        k = jax.random.normal(kk, (B, S, KV, hd), F32)
        v = jax.random.normal(kv, (B, S, KV, hd), F32)
        got = grouped_flash_attention(q, k, v, causal=True, interpret=True)
        assert got.shape == (B, S, H, hd)
        G = H // KV
        kt = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1)
        vt = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1)
        want = ref.flash_attention_ref(
            q.transpose(0, 2, 1, 3), kt, vt, causal=True
        ).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5
        )


# ---------------------------------------------------------------- ssm_scan
class TestSsmScan:
    @pytest.mark.parametrize("S,D,N", [(64, 128, 16), (128, 128, 8), (256, 256, 16)])
    @pytest.mark.parametrize("chunk", [32, 64])
    def test_matches_ref(self, S, D, N, chunk):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
        # decay in (0, 1) for stability, like exp(-softplus) in mamba
        da = jax.nn.sigmoid(jax.random.normal(k1, (S, D, N))) * 0.95
        dbx = jax.random.normal(k2, (S, D, N)) * 0.1
        c = jax.random.normal(k3, (S, N))
        got = ssm_scan(da, dbx, c, chunk=chunk, interpret=True)
        want, _ = ref.ssm_scan_ref(da, dbx, c, jnp.zeros((D, N)))
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )

    def test_chunk_invariance(self):
        """Carried state across chunk boundaries: result must not depend on
        the chunk size."""
        S, D, N = 128, 128, 16
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
        da = jax.nn.sigmoid(jax.random.normal(k1, (S, D, N))) * 0.9
        dbx = jax.random.normal(k2, (S, D, N)) * 0.1
        c = jax.random.normal(k3, (S, N))
        y32 = ssm_scan(da, dbx, c, chunk=32, interpret=True)
        y128 = ssm_scan(da, dbx, c, chunk=128, interpret=True)
        np.testing.assert_allclose(
            np.asarray(y32), np.asarray(y128), rtol=1e-5, atol=1e-5
        )

    def test_batched_wrapper(self):
        B, S, D, N = 2, 64, 128, 8
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
        da = jax.nn.sigmoid(jax.random.normal(k1, (B, S, D, N))) * 0.9
        dbx = jax.random.normal(k2, (B, S, D, N)) * 0.1
        c = jax.random.normal(k3, (B, S, N))
        got = batched_ssm_scan(da, dbx, c, chunk=32, interpret=True)
        for b in range(B):
            want, _ = ref.ssm_scan_ref(da[b], dbx[b], c[b], jnp.zeros((D, N)))
            np.testing.assert_allclose(
                np.asarray(got[b]), np.asarray(want), rtol=1e-4, atol=1e-4
            )
