"""The paper's tradeoff, both branches: constant-stepsize Local SGDA stalls
at the Proposition-1 bias floor; a diminishing schedule [25, 26] converges
past it (slowly); FedGDA-GT gets exactness AND speed at constant eta."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    make_fedgda_gt_round,
    make_local_sgda_round,
    make_scheduled_local_sgda_round,
    tree_sq_dist,
)
from repro.optim import diminishing_schedule
from repro.problems import make_quadratic_problem, quadratic_minimax_point


def test_diminishing_schedule_breaks_the_bias_floor(rng):
    prob = make_quadratic_problem(rng, dim=12, num_samples=60, num_agents=6)
    xs, ys = quadratic_minimax_point(prob)
    K, eta0, T = 10, 2e-4, 4000

    const = jax.jit(make_local_sgda_round(prob.loss, K, eta0, eta0))
    sched_round = jax.jit(make_scheduled_local_sgda_round(prob.loss, K))
    sched = diminishing_schedule(eta0, decay=0.01)
    gt = jax.jit(make_fedgda_gt_round(prob.loss, K, eta0))

    x0 = jnp.zeros(12)
    xc, yc = x0, x0
    xd, yd = x0, x0
    xg, yg = x0, x0
    for t in range(T):
        xc, yc = const(xc, yc, prob.agent_data)
        xd, yd = sched_round(xd, yd, prob.agent_data, sched(t))
        xg, yg = gt(xg, yg, prob.agent_data)
    gap = lambda x, y: float(tree_sq_dist(x, xs) + tree_sq_dist(y, ys))
    g_const, g_dim, g_gt = gap(xc, yc), gap(xd, yd), gap(xg, yg)
    # constant stepsize: stuck at the bias floor
    assert g_const > 1e-8, g_const
    # diminishing: below the constant-stepsize floor (exactness, slowly)
    assert g_dim < g_const * 0.5, (g_dim, g_const)
    # FedGDA-GT: exact AND fast at the same constant stepsize
    assert g_gt < g_dim * 1e-3, (g_gt, g_dim)
