"""Unit tests for the sharding rules (baseline + megatron variants) across
all 10 architectures, without touching device state."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.launch.shardings import param_pspec


class _Mesh:
    shape = {"data": 16, "model": 16}
    axis_names = ("data", "model")


class _PodMesh:
    shape = {"pod": 2, "data": 16, "model": 16}
    axis_names = ("pod", "data", "model")


MESH = _Mesh()


def _axes_used(spec):
    out = []
    for e in spec:
        if e is None:
            continue
        out.extend(e if isinstance(e, tuple) else (e,))
    return out


class TestMegatronRules:
    def test_no_contraction_dim_sharding_for_attention(self):
        """wq/wk/wv must never be sharded on d_model (the contraction dim)
        under the megatron variant — that was the §Perf baseline pathology."""
        for cfg in ARCHS.values():
            for name in ("wq", "wk", "wv"):
                shape = (8, cfg.d_model, cfg.num_heads, cfg.head_dim)
                spec = param_pspec(
                    f"blocks/0_attn/attn/{name}", shape, cfg, MESH, "megatron"
                )
                assert spec[1] is None, (cfg.name, name, spec)

    def test_heads_sharded_when_divisible(self):
        for cfg in ARCHS.values():
            shape = (8, cfg.d_model, cfg.num_heads, cfg.head_dim)
            spec = param_pspec(
                "blocks/0_attn/attn/wq", shape, cfg, MESH, "megatron"
            )
            if cfg.num_heads % 16 == 0:
                assert spec[2] == "model", (cfg.name, spec)
            else:  # replicated fallback (llama4 H=40, gemma2 H=8, ...)
                assert _axes_used(spec) == [], (cfg.name, spec)

    def test_mlp_column_row_pairing(self):
        for cfg in ARCHS.values():
            if not cfg.d_ff:
                continue
            up = param_pspec(
                "blocks/0_attn/mlp/up", (8, cfg.d_model, cfg.d_ff), cfg, MESH,
                "megatron",
            )
            down = param_pspec(
                "blocks/0_attn/mlp/down", (8, cfg.d_ff, cfg.d_model), cfg, MESH,
                "megatron",
            )
            if cfg.d_ff % 16 == 0:
                assert up[2] == "model" and down[1] == "model", (cfg.name,)

    def test_moe_expert_dim_over_data_in_mode_b(self):
        for cfg in ARCHS.values():
            if not cfg.num_experts:
                continue
            spec = param_pspec(
                "blocks/0_moe/moe/up",
                (8, cfg.num_experts, cfg.d_model, cfg.d_ff),
                cfg, MESH, "megatron",
            )
            if cfg.fed_mode == "B" and cfg.num_experts % 16 == 0:
                assert spec[1] == "data", (cfg.name, spec)
            assert spec[3] == "model"  # ff column

    def test_mamba_column_row(self):
        cfg = ARCHS["falcon-mamba-7b"]
        in_p = param_pspec(
            "blocks/0_mamba1/mamba/in_proj",
            (64, cfg.d_model, 2 * cfg.d_inner), cfg, MESH, "megatron",
        )
        out_p = param_pspec(
            "blocks/0_mamba1/mamba/out_proj",
            (64, cfg.d_inner, cfg.d_model), cfg, MESH, "megatron",
        )
        assert in_p[2] == "model" and out_p[1] == "model"

    def test_scalars_and_vectors_replicated(self):
        cfg = ARCHS["granite-8b"]
        for variant in ("baseline", "megatron"):
            spec = param_pspec(
                "blocks/0_attn/ln1/scale", (8, cfg.d_model), cfg, MESH, variant
            )
            assert _axes_used(spec) == [], spec


class TestBaselineRules:
    def test_largest_divisible_dim(self):
        cfg = ARCHS["granite-8b"]
        spec = param_pspec(
            "blocks/0_attn/mlp/up", (8, 4096, 14336), cfg, MESH, "baseline"
        )
        assert spec[2] == "model"  # 14336 > 4096

    def test_same_rules_on_multipod_mesh(self):
        cfg = ARCHS["granite-8b"]
        for variant in ("baseline", "megatron"):
            spec = param_pspec(
                "blocks/0_attn/attn/wq", (8, 4096, 32, 128), cfg,
                _PodMesh(), variant,
            )
            assert len(spec) == 4
