"""The observability contract: telemetry, probes and the run ledger.

The acceptance-grade facts pinned here (see tests/README.md for the
event schema):

  * telemetry is bitwise-free when disabled: a runner given
    `telemetry=None` — and the SAME runner after flipping the sink on —
    produces bitwise-identical iterates for all six strategy families
    across the sync runner (plain and elastic), the async runner, and
    the sparse engine (dense-fallback and genuinely-sparse paths); the
    sink never enters a jitted program, so enabling it cannot perturb a
    single bit;
  * `Telemetry(phase_spans=True)` dispatches the four engine phases as
    separate jitted programs and matches the fused round at rtol 1e-12
    (the phases contract — fp-level, not bitwise: XLA partitions the
    programs differently);
  * the invariant probes are pure functions that read ~fp-reduction
    noise when the math is right: `gt_residual` over the tracker-table
    corrections, `tracker_drift` of the SparseTracker running sums, EF
    residual norms, priced-vs-measured bytes — and they AGREE across
    the sync-elastic, async-elastic and forced-sparse runtimes on a
    shared seed;
  * "wire_bytes" counters are byte truth: on a scheduled run each
    round's value equals `sim.schedule_bytes` exactly (per-active-agent
    payload x n_active); unscheduled, per_agent equals
    `transport.measured_bytes_per_round` as-is;
  * `wire_report` is active-set-aware: after (or with) a schedule it
    adds the `scheduled_*` keys priced by `sim.schedule_bytes`, and a
    static-full schedule adds nothing (the run was the legacy path);
  * the run ledger round-trips: every emitted event lands in
    events.jsonl verbatim, and the manifest records the seed-fold
    stream constants and the schedule digest (`summary_trace`);
  * `metric_series` on an EMPTY history raises the ValueError naming
    the available keys instead of returning a silent empty array;
  * `peak_memory` moved to `repro.obs` with `benchmarks.common`
    re-exporting the same function object, and a sink records the
    measurement as a "peak_memory" counter.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import grad_xy
from repro.fed import (
    AsyncFederatedRunner,
    CompressedGT,
    FederatedRunner,
    FullSync,
    GradientTracking,
    LocalOnly,
    PartialParticipation,
    QuantizedGT,
)
from repro.fed.noise import NOISE_STREAM
from repro.fed.transport import measured_bytes_per_round
from repro.obs import RunLedger, Telemetry, maybe_span, peak_memory, probes
from repro.obs import run_manifest
from repro.problems import make_quadratic_problem, quadratic_minimax_point
from repro.sim import (
    ArrayDataSource,
    Population,
    SparseElasticEngine,
    UniformActiveSubset,
    UniformStragglers,
    make_population,
    per_agent_bytes,
    schedule_bytes,
)
from repro.sim.schedule import AVAILABILITY_STREAM

pytestmark = pytest.mark.obs

ETA = 1e-4
DIM, M, T = 16, 8, 5
SEED = 0

STRATEGIES = [
    ("full_sync", FullSync(), 1),
    ("local_only", LocalOnly(), 5),
    ("gradient_tracking", GradientTracking(), 5),
    ("partial_participation", PartialParticipation(participation=0.5, seed=0), 5),
    ("compressed_gt", CompressedGT(compression_ratio=0.25, seed=0), 5),
    ("quantized_gt", QuantizedGT(bits=8, seed=0), 5),
]
IDS = [s[0] for s in STRATEGIES]

x0 = jnp.ones(DIM)
y0 = -jnp.ones(DIM)

#: a sink with every emission path on: probes sampled each round, a gap
#: oracle, phase bookkeeping — everything except phase_spans (its own
#: fp-level test below) and a ledger (its own round-trip test below)
ALL_PROBES = (
    "gt_residual", "tracker_drift", "ef_residual", "priced_vs_measured",
    "duality_gap",
)


@pytest.fixture(scope="module")
def prob():
    return make_quadratic_problem(
        jax.random.PRNGKey(0), dim=DIM, num_samples=40, num_agents=M
    )


def _full_telemetry(prob):
    xs, ys = quadratic_minimax_point(prob)
    from repro.core import tree_sq_dist

    return Telemetry(
        probes=ALL_PROBES,
        gap_fn=lambda x, y: tree_sq_dist(x, xs) + tree_sq_dist(y, ys),
    )


def _fresh_state(strategy, x, y, m):
    return (
        strategy.init_state(x, y, m)
        if getattr(strategy, "stateful", False)
        else None
    )


def _flaky_schedule(K, rounds=T):
    return make_population("flaky", M).schedule(SEED, rounds, K)


def _sparse_schedule(K, rounds=T):
    pop = Population(
        M,
        UniformActiveSubset(size=4),
        UniformStragglers(p_straggle=0.5, min_frac=0.4),
    )
    return pop.sparse_schedule(SEED, rounds, K)


# ------------------------------------------------- disabled == bitwise pin
class TestBitwisePins:
    """telemetry=None vs an enabled sink (probes, gap oracle and all) on
    the SAME compiled runner: iterates must be bitwise identical — the
    sink is host-side only, so the jitted programs cannot differ."""

    @pytest.mark.parametrize("name,strategy,K", STRATEGIES, ids=IDS)
    def test_sync_plain(self, prob, name, strategy, K):
        runner = FederatedRunner.from_strategy(
            prob.loss, strategy, prob.agent_data, K, ETA
        )
        xa, ya = runner.run(x0, y0, T, state=_fresh_state(strategy, x0, y0, M))
        runner.telemetry = _full_telemetry(prob)
        xb, yb = runner.run(x0, y0, T, state=_fresh_state(strategy, x0, y0, M))
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
        np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))
        assert len(runner.telemetry.series("span", "round")) == T

    @pytest.mark.parametrize("name,strategy,K", STRATEGIES, ids=IDS)
    def test_sync_elastic(self, prob, name, strategy, K):
        sched = _flaky_schedule(K)
        runner = FederatedRunner.from_strategy(
            prob.loss, strategy, prob.agent_data, K, ETA
        )
        xa, ya = runner.run(
            x0, y0, T, schedule=sched,
            state=_fresh_state(strategy, x0, y0, M),
        )
        runner.telemetry = _full_telemetry(prob)
        xb, yb = runner.run(
            x0, y0, T, schedule=sched,
            state=_fresh_state(strategy, x0, y0, M),
        )
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
        np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))

    @pytest.mark.multihost
    @pytest.mark.parametrize("name,strategy,K", STRATEGIES, ids=IDS)
    def test_async(self, prob, name, strategy, K, fed_devices):
        # two runners (async shard state initializes once per runner);
        # same devices, same programs — only the sink differs
        off = AsyncFederatedRunner(
            prob.loss, strategy, prob.agent_data, K, ETA,
            devices=fed_devices,
        )
        xa, ya = off.run(x0, y0, T)
        on = AsyncFederatedRunner(
            prob.loss, strategy, prob.agent_data, K, ETA,
            devices=fed_devices, telemetry=_full_telemetry(prob),
        )
        xb, yb = on.run(x0, y0, T)
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
        np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))
        assert len(on.telemetry.series("span", "round")) == T

    @pytest.mark.parametrize("name,strategy,K", STRATEGIES, ids=IDS)
    @pytest.mark.parametrize("fallback", [True, False],
                             ids=["dense-fallback", "sparse"])
    def test_sparse_engine(self, prob, name, strategy, K, fallback):
        sched = _sparse_schedule(K)
        kw = {} if fallback else {"dense_fallback_max_m": 0}

        def build(tm):
            return SparseElasticEngine(
                prob.loss, strategy, ArrayDataSource(prob.agent_data),
                K, ETA, telemetry=tm, **kw,
            )

        xa, ya = build(None).run(x0, y0, sched)
        tm = _full_telemetry(prob)
        xb, yb = build(tm).run(x0, y0, sched)
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
        np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))
        fb = tm.series("event", "dense_fallback")
        assert len(fb) == 1 and fb[0]["value"] is (True if fallback else False)


# ----------------------------------------------------- phase-span dispatch
class TestPhaseSpans:
    def test_matches_fused_round_fp(self, prob):
        """phase_spans=True re-dispatches the four phases as separate
        jitted programs: rtol 1e-12 vs the fused round (the phases
        contract, tests/test_phases.py), with one span per phase."""
        K = 4
        runner = FederatedRunner.from_strategy(
            prob.loss, GradientTracking(), prob.agent_data, K, ETA
        )
        xa, ya = runner.run(x0, y0, T)
        tm = Telemetry(phase_spans=True)
        runner.telemetry = tm
        xb, yb = runner.run(x0, y0, T)
        np.testing.assert_allclose(np.asarray(xa), np.asarray(xb), rtol=1e-12)
        np.testing.assert_allclose(np.asarray(ya), np.asarray(yb), rtol=1e-12)
        for phase in ("broadcast", "exchange_corrections", "local_steps",
                      "aggregate"):
            assert len(tm.series("span", phase)) == T

    def test_needs_strategy_built_runner(self, prob):
        from repro.core import make_round

        rnd = make_round(prob.loss, GradientTracking(), 2, ETA)
        runner = FederatedRunner(rnd, prob.agent_data)
        runner.telemetry = Telemetry(phase_spans=True)
        with pytest.raises(ValueError, match="from_strategy"):
            runner._phase_round(runner.telemetry)


# ------------------------------------------------------------- probe units
class TestProbeFunctions:
    def test_anchor_corrections_satisfy_gt_invariant(self, prob):
        cx, cy = probes.anchor_corrections(
            grad_xy(prob.loss), x0, y0, prob.agent_data
        )
        assert probes.gt_residual(cx, cy) < 1e-10

    def test_table_corrections_and_drift(self, prob):
        g = jax.vmap(grad_xy(prob.loss), in_axes=(None, None, 0))(
            x0, y0, prob.agent_data
        )
        cx, cy = probes.corrections_from_table(g.gx, g.gy)
        assert probes.gt_residual(cx, cy) < 1e-10
        colsum = jax.tree.map(lambda u: jnp.sum(u, axis=0), (g.gx, g.gy))
        assert probes.tracker_drift(g.gx, g.gy, *colsum) == 0.0
        # a perturbed running sum reads as drift
        off = jax.tree.map(lambda u: u + 1.0, colsum[0])
        assert probes.tracker_drift(g.gx, g.gy, off, colsum[1]) > 1.0

    def test_ef_residual_norms(self):
        assert probes.ef_residual_norms(None) == {}
        assert probes.ef_residual_norms({"rng": 0}) == {}
        norms = probes.ef_residual_norms(
            {"ex": jnp.full((3,), 2.0), "ey": jnp.zeros((3,))}
        )
        np.testing.assert_allclose(norms["ex"], np.sqrt(12.0))
        assert norms["ey"] == 0.0

    def test_priced_vs_measured(self, prob):
        pv = probes.priced_vs_measured(GradientTracking(), x0, y0, 4)
        assert pv["priced"] == pv["measured"] > 0

    def test_duality_gap_uses_oracle(self):
        assert probes.duality_gap(lambda x, y: 7.5, x0, y0) == 7.5


# -------------------------------------------- probe parity across runtimes
class TestProbeParity:
    """The same pure probes over the state each runtime holds, on a
    shared seed: the GT invariant must read ~fp noise everywhere, and
    the priced-vs-measured account must be the SAME dict — a mismatch
    localizes the faulty layer, not the faulty runner."""

    K = 5

    def _run_sync(self, prob):
        tm = _full_telemetry(prob)
        runner = FederatedRunner.from_strategy(
            prob.loss, GradientTracking(), prob.agent_data, self.K, ETA,
            telemetry=tm,
        )
        runner.run(x0, y0, T, schedule=_flaky_schedule(self.K))
        return tm

    def test_sync_elastic_probes(self, prob):
        tm = self._run_sync(prob)
        res = tm.probe_series("gt_residual")
        assert len(res) == T and max(res) < 1e-8
        assert tm.probe_series("duality_gap")

    @pytest.mark.multihost
    def test_async_elastic_agrees_with_sync(self, prob, fed_devices):
        sync_tm = self._run_sync(prob)
        tm = _full_telemetry(prob)
        runner = AsyncFederatedRunner(
            prob.loss, GradientTracking(), prob.agent_data, self.K, ETA,
            devices=fed_devices, telemetry=tm,
        )
        runner.run(x0, y0, T, schedule=_flaky_schedule(self.K))
        res = tm.probe_series("gt_residual")
        assert len(res) == T and max(res) < 1e-8
        assert (
            tm.probe_series("priced_vs_measured")
            == sync_tm.probe_series("priced_vs_measured")
        )

    def test_forced_sparse_agrees(self, prob):
        sync_tm = self._run_sync(prob)
        tm = _full_telemetry(prob)
        eng = SparseElasticEngine(
            prob.loss, GradientTracking(), ArrayDataSource(prob.agent_data),
            self.K, ETA, dense_fallback_max_m=0, telemetry=tm,
        )
        eng.run(x0, y0, _sparse_schedule(self.K))
        res = tm.probe_series("gt_residual")
        assert len(res) == T and max(res) < 1e-8
        drift = tm.probe_series("tracker_drift")
        assert len(drift) == T and max(drift) < 1e-8
        assert (
            tm.probe_series("priced_vs_measured")
            == sync_tm.probe_series("priced_vs_measured")
        )

    def test_ef_residual_probe_sees_compressor_state(self, prob):
        tm = _full_telemetry(prob)
        runner = FederatedRunner.from_strategy(
            prob.loss, CompressedGT(compression_ratio=0.25, seed=0),
            prob.agent_data, self.K, ETA, telemetry=tm,
        )
        runner.run(x0, y0, T)
        norms = tm.probe_series("ef_residual")
        assert len(norms) == T
        # top-k residuals are non-zero after the first compression
        assert norms[-1]["ex"] > 0.0


# ------------------------------------------------------------- wire truth
class TestWireCounters:
    def test_scheduled_counter_equals_schedule_bytes(self, prob):
        K = 5
        strategy = GradientTracking()
        sched = _flaky_schedule(K)
        tm = Telemetry()
        runner = FederatedRunner.from_strategy(
            prob.loss, strategy, prob.agent_data, K, ETA, telemetry=tm,
        )
        runner.run(x0, y0, T, schedule=sched)
        counters = tm.series("counter", "wire_bytes")
        totals = schedule_bytes(strategy, x0, y0, K, sched)
        assert [e["value"] for e in counters] == [int(v) for v in totals[:T]]
        pa = per_agent_bytes(strategy, x0, y0, K)
        assert all(e["per_agent"] == pa for e in counters)
        assert [e["value"] // pa for e in counters] == [
            e["n_active"] for e in counters
        ]

    def test_unscheduled_counter_is_measured_times_m(self, prob):
        K = 5
        strategy = CompressedGT(compression_ratio=0.25, seed=0)
        tm = Telemetry(probes=("priced_vs_measured",))
        runner = FederatedRunner.from_strategy(
            prob.loss, strategy, prob.agent_data, K, ETA, telemetry=tm,
        )
        runner.run(x0, y0, T)
        meas = int(measured_bytes_per_round(strategy, x0, y0, K))
        for e in tm.series("counter", "wire_bytes"):
            assert e["per_agent"] == meas and e["value"] == meas * M
        (pv,) = tm.probe_series("priced_vs_measured")
        assert pv["measured"] == meas

    def test_wire_report_is_schedule_aware(self, prob):
        K = 5
        strategy = GradientTracking()
        sched = _flaky_schedule(K)
        runner = FederatedRunner.from_strategy(
            prob.loss, strategy, prob.agent_data, K, ETA
        )
        runner.run(x0, y0, T, schedule=sched)
        # remembered from run(..., schedule=...) — no need to re-pass
        rep = runner.wire_report(x0, y0, K)
        totals = schedule_bytes(strategy, x0, y0, K, sched)
        assert rep["scheduled_per_agent_bytes"] == per_agent_bytes(
            strategy, x0, y0, K
        )
        assert rep["scheduled_total_bytes"] == int(np.sum(totals))
        assert rep["scheduled_mean_bytes_per_round"] == pytest.approx(
            float(np.mean(totals))
        )
        # passing the schedule explicitly is the same account
        assert runner.wire_report(x0, y0, K, schedule=sched) == rep

    def test_wire_report_static_full_has_no_scheduled_keys(self, prob):
        K = 5
        runner = FederatedRunner.from_strategy(
            prob.loss, GradientTracking(), prob.agent_data, K, ETA
        )
        sched = make_population("stable", M).schedule(SEED, T, K)
        runner.run(x0, y0, T, schedule=sched)
        rep = runner.wire_report(x0, y0, K)
        assert set(rep) == {"bytes_per_round", "measured_bytes_per_round"}

    @pytest.mark.multihost
    def test_async_wire_report_mirrors_sync(self, prob, fed_devices):
        K = 5
        strategy = GradientTracking()
        sched = _flaky_schedule(K)
        runner = AsyncFederatedRunner(
            prob.loss, strategy, prob.agent_data, K, ETA,
            devices=fed_devices,
        )
        runner.run(x0, y0, T, schedule=sched)
        rep = runner.wire_report(x0, y0, K)
        totals = schedule_bytes(strategy, x0, y0, K, sched)
        assert rep["scheduled_total_bytes"] == int(np.sum(totals))


# ------------------------------------------------------- multihost absorbs
@pytest.mark.multihost
class TestMultiHostTelemetry:
    def test_wire_log_absorbed_and_bitwise(self, prob, fed_devices):
        from repro.launch.multihost import MultiHostRunner

        strategy = CompressedGT(compression_ratio=0.25, wire_transport=True)
        off = MultiHostRunner(
            prob.loss, strategy, prob.agent_data, 4, ETA,
            devices=fed_devices,
        )
        xa, ya = off.run(x0, y0, 2)
        tm = Telemetry()
        on = MultiHostRunner(
            prob.loss, strategy, prob.agent_data, 4, ETA,
            devices=fed_devices, telemetry=tm,
        )
        xb, yb = on.run(x0, y0, 2)
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
        np.testing.assert_array_equal(np.asarray(ya), np.asarray(yb))
        # wire_log stays; the sink absorbs it as counters
        gathered = [
            e["value"]
            for e in tm.series("counter", "gathered_payload_bytes")
        ]
        assert gathered == [
            w["gathered_payload_bytes"] for w in on.wire_log
        ]
        rounds = tm.series("span", "round")
        assert [e["runtime"] for e in rounds] == ["multihost"] * 2
        for phase in ("broadcast", "exchange_corrections", "local_steps",
                      "aggregate"):
            assert len(tm.series("span", phase)) == 2


# ---------------------------------------------------------- sparse events
class TestSparseEvents:
    def test_realign_and_active_set_events(self, prob):
        K = 5
        tm = Telemetry()
        eng = SparseElasticEngine(
            prob.loss, GradientTracking(), ArrayDataSource(prob.agent_data),
            K, ETA, dense_fallback_max_m=0, telemetry=tm,
        )
        eng.run(x0, y0, _sparse_schedule(K))
        rounds = tm.series("span", "round")
        assert [e["runtime"] for e in rounds] == ["sparse"] * T
        # the fixed-size sampler keeps 4 agents active every round
        assert all(e["n_active"] == 4 for e in rounds)
        realigns = tm.series("event", "realign")
        assert len(realigns) == T - 1  # every round after the first
        assert all(0 <= e["n_continuing"] <= 4 for e in realigns)


# ---------------------------------------------------------- ledger + seeds
class TestRunLedger:
    def test_events_round_trip_jsonl(self, prob, tmp_path):
        import json

        d = str(tmp_path / "ledger")
        ledger = RunLedger(d)
        tm = Telemetry(ledger=ledger, probes=("priced_vs_measured",))
        runner = FederatedRunner.from_strategy(
            prob.loss, GradientTracking(), prob.agent_data, 4, ETA,
            telemetry=tm,
        )
        runner.run(x0, y0, T)
        ledger.close()
        back = RunLedger.events(d)
        # everything emitted landed, verbatim up to JSON normalization
        assert back == json.loads(
            json.dumps(tm.events, default=lambda o: o.tolist()
                       if hasattr(o, "tolist") else str(o))
        )
        assert sum(1 for e in back if e["name"] == "round") == T

    def test_manifest_records_seed_folds_and_digest(self, prob, tmp_path):
        sched = _flaky_schedule(5)
        d = str(tmp_path / "ledger")
        ledger = RunLedger(d)
        strategy = QuantizedGT(bits=8, seed=0)
        ledger.write_manifest(run_manifest(
            config={"rounds": T}, strategy=strategy,
            noise_seed=3, availability_seed=SEED, schedule=sched,
        ))
        man = RunLedger.manifest(d)
        assert man["config"] == {"rounds": T}
        assert man["strategy"]["class"] == "QuantizedGT"
        assert man["seeds"]["noise_stream"] == NOISE_STREAM
        assert man["seeds"]["availability_stream"] == AVAILABILITY_STREAM
        assert man["seeds"]["noise_seed"] == 3
        assert man["seeds"]["availability_seed"] == SEED
        import json

        from repro.obs.ledger import _jsonable

        digest = dict(sched.summary_trace())
        assert man["schedule"] == json.loads(
            json.dumps(digest, default=_jsonable)
        )

    def test_maybe_span_disabled_is_nullcontext(self):
        with maybe_span(None, "anything"):
            pass
        tm = Telemetry()
        with maybe_span(tm, "phase", dispatches=3):
            pass
        (ev,) = tm.series("span", "phase")
        assert ev["dispatches"] == 3 and ev["seconds"] >= 0.0


# -------------------------------------------------- metric_series contract
class TestMetricSeries:
    def test_empty_history_raises_with_available_keys(self, prob):
        runner = FederatedRunner.from_strategy(
            prob.loss, GradientTracking(), prob.agent_data, 2, ETA
        )
        with pytest.raises(ValueError, match=r"available metric keys: \[\]"):
            runner.metric_series("gap")

    def test_unknown_key_still_names_available(self, prob):
        runner = FederatedRunner.from_strategy(
            prob.loss, GradientTracking(), prob.agent_data, 2, ETA,
            metric_fn=lambda x, y: {"gap": jnp.sum(x * x)},
        )
        runner.run(x0, y0, 2)
        with pytest.raises(ValueError, match=r"\['gap'\]"):
            runner.metric_series("loss")
        assert runner.metric_series("gap").shape == (2,)


# ------------------------------------------------------------- peak memory
class TestPeakMemory:
    def test_benchmarks_shim_is_the_same_function(self):
        from benchmarks.common import peak_memory as shim

        assert shim is peak_memory

    def test_emits_counter_into_sink(self):
        tm = Telemetry()
        rec = peak_memory(
            lambda: np.zeros(100_000), telemetry=tm, label="alloc"
        )
        assert rec["host_peak_bytes"] > 0
        (ev,) = tm.series("counter", "peak_memory")
        assert ev["value"] == rec["host_peak_bytes"]
        assert ev["label"] == "alloc"
        assert ev["live_buffer_bytes"] == rec["live_buffer_bytes"]
