"""Validation of the paper's own claims (the faithful-reproduction gate).

Each test is tagged with the claim it validates:
  * Theorem 1   — FedGDA-GT converges LINEARLY to the EXACT minimax point
                  with a constant stepsize.
  * Proposition 1 / Appendix C — Local SGDA with constant stepsizes and
                  K >= 2 has biased fixed points, matching the closed form.
  * Proposition 2 — homogeneous agents: rate improves >= K-fold.
  * Section 5.1 — FedGDA-GT outperforms Local SGDA on the quadratic game.
  * Section 5.2 — robust regression: FedGDA-GT's robust loss <= Local SGDA's
                  under heterogeneity.
  * Section 4 (stochastic regime) — at one constant stepsize, Local SGDA's
                  drift floor is structural while FedGDA-GT/SAGDA's only
                  floor is the sigma^2-scaling variance floor.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    appendix_c_fixed_point,
    make_fedgda_gt_round,
    make_gda_step,
    make_local_sgda_round,
    prop1_residual,
    run_rounds,
    tree_sq_dist,
)
from repro.problems import (
    make_appendix_c_problem,
    make_quadratic_problem,
    make_robust_regression_problem,
    quadratic_minimax_point,
    robust_loss,
)


def _gap_metric(xs, ys):
    def metric(x, y):
        return {"gap": tree_sq_dist(x, xs) + tree_sq_dist(y, ys)}

    return metric


# ---------------------------------------------------------------- Theorem 1
class TestTheorem1:
    def test_linear_convergence_exact_limit(self, rng):
        prob = make_quadratic_problem(rng, dim=20, num_samples=100, num_agents=8)
        xs, ys = quadratic_minimax_point(prob)
        rnd = jax.jit(make_fedgda_gt_round(prob.loss, 10, 2e-4))
        x0 = jnp.zeros(20)
        (_, _), m = run_rounds(
            rnd, x0, x0, prob.agent_data, 4000, _gap_metric(xs, ys)
        )
        gap = np.asarray(m["gap"])
        assert gap[-1] < 1e-18, gap[-1]  # exact (machine-precision) limit
        # linearity: log-gap decreases at a steady per-round rate over the
        # pre-floor segment
        seg = gap[(gap > 1e-14) & (gap < 1e2)]
        rates = np.diff(np.log(seg))
        assert np.all(rates < 0)
        assert np.std(rates) < 0.25 * abs(np.mean(rates))

    def test_constant_stepsize_no_accuracy_floor_vs_local_sgda(self, rng):
        prob = make_quadratic_problem(rng, dim=20, num_samples=100, num_agents=8)
        xs, ys = quadratic_minimax_point(prob)
        K, eta = 10, 2e-4
        x0 = jnp.zeros(20)
        r_gt = jax.jit(make_fedgda_gt_round(prob.loss, K, eta))
        r_ls = jax.jit(make_local_sgda_round(prob.loss, K, eta, eta))
        (_, _), m_gt = run_rounds(r_gt, x0, x0, prob.agent_data, 3000, _gap_metric(xs, ys))
        (_, _), m_ls = run_rounds(r_ls, x0, x0, prob.agent_data, 3000, _gap_metric(xs, ys))
        assert m_gt["gap"][-1] < 1e-15
        assert m_ls["gap"][-1] > 1e-6  # Local SGDA stalls at a bias floor
        assert m_gt["gap"][-1] < m_ls["gap"][-1] * 1e-6


# --------------------------------------------------- Proposition 1 / App. C
class TestProposition1:
    @pytest.mark.parametrize("K", [1, 10, 20, 50])
    def test_appendix_c_closed_form(self, K):
        prob = make_appendix_c_problem()
        eta = 0.1 if K == 1 else 0.001  # the paper's own stepsizes
        rnd = jax.jit(make_local_sgda_round(prob.loss, K, eta, eta))
        x0 = jnp.array(0.0)
        (x, y), _ = run_rounds(rnd, x0, x0, prob.agent_data, 30000)
        fx, fy = appendix_c_fixed_point(K, eta, eta)
        np.testing.assert_allclose(float(x), fx, rtol=1e-10)
        np.testing.assert_allclose(float(y), fy, rtol=1e-10)
        if K == 1:  # K=1 reduces to centralized GDA: exact minimax point
            np.testing.assert_allclose(float(x), 3.3, rtol=1e-9)
        else:  # K>=2: biased away from the minimax point
            assert abs(float(x) - 3.3) > 1e-4

    def test_prop1_residual_zero_at_fixed_point(self):
        prob = make_appendix_c_problem()
        K, eta = 10, 0.001
        rnd = jax.jit(make_local_sgda_round(prob.loss, K, eta, eta))
        x0 = jnp.array(0.0)
        (x, y), _ = run_rounds(rnd, x0, x0, prob.agent_data, 30000)
        r_fp = prop1_residual(prob.loss, x, y, prob.agent_data, K, eta, eta)
        assert float(r_fp) < 1e-10
        # ... and non-zero at the true minimax point (which is NOT a fixed pt)
        xm = jnp.array(3.3)
        r_mm = prop1_residual(prob.loss, xm, xm, prob.agent_data, K, eta, eta)
        assert float(r_mm) > 1e-3

    def test_larger_K_larger_bias(self):
        prob = make_appendix_c_problem()
        eta = 0.001
        biases = []
        for K in (2, 10, 50):
            fx, _ = appendix_c_fixed_point(K, eta, eta)
            biases.append(abs(fx - 3.3))
        assert biases[0] < biases[1] < biases[2]


# ------------------------------------------------------------ Proposition 2
class TestProposition2:
    def test_homogeneous_speedup_at_least_K(self, rng):
        dim, m = 10, 6
        base = make_quadratic_problem(rng, dim=dim, num_samples=50, num_agents=1)
        # replicate one agent's data m times -> identical objectives
        hom = jax.tree.map(
            lambda u: jnp.broadcast_to(u, (m,) + u.shape[1:]), base.agent_data
        )
        xs, ys = quadratic_minimax_point(base)
        eta, K = 5e-5, 8
        x0 = jnp.zeros(dim)
        met = _gap_metric(xs, ys)
        r1 = jax.jit(make_fedgda_gt_round(base.loss, 1, eta))
        rK = jax.jit(make_fedgda_gt_round(base.loss, K, eta))
        (_, _), m1 = run_rounds(r1, x0, x0, hom, 1500, met)
        (_, _), mK = run_rounds(rK, x0, x0, hom, 1500, met)

        def per_round_rate(g):  # slope of log-gap on the pre-floor segment
            g = np.asarray(g)
            idx = np.where((g > 1e-12) & (g < 1e2))[0]
            lo, hi = idx[0], idx[-1]
            return (np.log(g[hi]) - np.log(g[lo])) / (hi - lo)

        rate1, rateK = per_round_rate(m1["gap"]), per_round_rate(mK["gap"])
        # homogeneous: K local steps give >= K x faster per-round decay
        assert rateK <= rate1 * (K * 0.9)

    def test_homogeneous_equals_centralized_gda(self, rng):
        """Appendix D.4: with identical agents FedGDA-GT == centralized GDA."""
        dim, m = 8, 4
        base = make_quadratic_problem(rng, dim=dim, num_samples=40, num_agents=1)
        hom = jax.tree.map(
            lambda u: jnp.broadcast_to(u, (m,) + u.shape[1:]), base.agent_data
        )
        eta, K = 1e-4, 5
        x0 = jnp.zeros(dim)
        r_fed = jax.jit(make_fedgda_gt_round(base.loss, K, eta))
        step = make_gda_step(base.loss, eta, eta)

        def r_cent(x, y, data):  # K centralized GDA steps
            for _ in range(K):
                x, y = step(x, y, data)
            return x, y

        xf, yf = x0, x0
        xc, yc = x0, x0
        for _ in range(20):
            xf, yf = r_fed(xf, yf, hom)
            xc, yc = r_cent(xc, yc, base.agent_data)
        np.testing.assert_allclose(np.asarray(xf), np.asarray(xc), rtol=1e-8)


# -------------------------------------------------------------- Section 5.1
class TestQuadraticExperiment:
    def test_paper_setup_fedgda_gt_beats_local_sgda_and_gda(self, rng):
        # paper scale (d=50, n=500, m=20, eta=1e-4) at reduced round count
        prob = make_quadratic_problem(rng, dim=50, num_samples=500, num_agents=20)
        xs, ys = quadratic_minimax_point(prob)
        eta = 1e-4
        x0 = jnp.zeros(50)
        met = _gap_metric(xs, ys)
        T = 1500
        (_, _), m_gt = run_rounds(
            jax.jit(make_fedgda_gt_round(prob.loss, 20, eta)), x0, x0,
            prob.agent_data, T, met)
        (_, _), m_ls = run_rounds(
            jax.jit(make_local_sgda_round(prob.loss, 20, eta, eta)), x0, x0,
            prob.agent_data, T, met)
        (_, _), m_gda = run_rounds(
            jax.jit(make_local_sgda_round(prob.loss, 1, eta, eta)), x0, x0,
            prob.agent_data, T, met)
        # FedGDA-GT reaches far tighter accuracy in the same rounds
        assert m_gt["gap"][-1] < 1e-8 * m_ls["gap"][-1]
        assert m_gt["gap"][-1] < 1e-8 * m_gda["gap"][-1]


# ------------------------------------------- Section 4 stochastic separation
@pytest.mark.stochastic
class TestStochasticSeparation:
    """The stochastic-regime separation behind the Section-4 discussion:
    at ONE shared constant stepsize, Local SGDA stalls at a structural
    drift floor that no noise reduction removes, while FedGDA-GT (run as
    SAGDA through the stochastic engine path) drives its noiseless
    component linearly to machine precision — under gradient noise its
    only floor is the VARIANCE floor, which scales away with sigma^2."""

    K, ETA, T, DIM = 10, 5e-4, 1500, 10

    def _gaps(self, prob, strategy, metric):
        from repro.core.engine import make_round, run_strategy_rounds

        rnd = jax.jit(
            make_round(prob.loss, strategy, self.K, self.ETA,
                       explicit_state=True)
        )
        x0 = jnp.zeros(self.DIM)
        state = strategy.init_state(x0, x0, prob.num_agents)
        (_, _, _), m = run_strategy_rounds(
            rnd, x0, x0, prob.agent_data, self.T, state, metric
        )
        return np.asarray(m["gap"])

    def test_drift_floor_vs_linear_noiseless_component(self, rng):
        from repro.fed import LocalSGDAPlus, SAGDA
        from repro.fed.noise import GaussianNoise

        prob = make_quadratic_problem(
            rng, dim=self.DIM, num_samples=40, num_agents=6
        )
        xs, ys = quadratic_minimax_point(prob)
        met = _gap_metric(xs, ys)
        g_gt = self._gaps(prob, SAGDA(), met)
        g_ls = self._gaps(prob, LocalSGDAPlus(), met)
        g_hi = self._gaps(
            prob, SAGDA(noise=GaussianNoise(sigma=0.1)), met
        )
        g_lo = self._gaps(
            prob, SAGDA(noise=GaussianNoise(sigma=0.01)), met
        )
        # noiseless component: linear to machine precision
        assert g_gt[-1] < 1e-20, g_gt[-1]
        seg = g_gt[(g_gt > 1e-14) & (g_gt < 1e2)]
        rates = np.diff(np.log(seg))
        assert np.all(rates < 0)
        assert np.std(rates) < 0.25 * abs(np.mean(rates))
        # Local SGDA's floor is structural — present WITHOUT any noise,
        # orders of magnitude above every SAGDA regime at the same eta
        floor_ls = float(g_ls[-100:].mean())
        floor_hi = float(g_hi[-100:].mean())
        floor_lo = float(g_lo[-100:].mean())
        assert floor_ls > 1e-2, floor_ls
        assert floor_hi < 1e-4 * floor_ls
        # SAGDA's floor is the variance floor: sigma 10x down => the
        # squared-distance floor ~100x down (and never below noiseless)
        assert 30.0 < floor_hi / floor_lo < 300.0
        assert floor_lo > float(g_gt[-1])


# -------------------------------------------------------------- Section 5.2
class TestRobustRegressionExperiment:
    def test_high_heterogeneity_gt_at_least_as_good(self, rng):
        """Fig 2(c): under strong heterogeneity (alpha=20) FedGDA-GT's robust
        loss is no worse than Local SGDA's."""
        prob = make_robust_regression_problem(
            rng, dim=20, num_samples=100, num_agents=10, alpha=20.0
        )
        # data scale grows with alpha (L ~ 2 lam_max(mean aa^T) + 1), so the
        # stable constant stepsize must be derived from the data
        a = prob.agent_data["a"]
        H = 2 * jnp.einsum("mnd,mne->de", a, a) / (a.shape[0] * a.shape[1])
        L = float(jnp.linalg.eigvalsh(H + jnp.eye(20))[-1])
        eta, K, T = 0.1 / L, 10, 2000
        x0 = jnp.zeros(20)
        r_gt = jax.jit(make_fedgda_gt_round(prob.loss, K, eta, proj_y=prob.proj_y))
        r_ls = jax.jit(
            make_local_sgda_round(prob.loss, K, eta, eta, proj_y=prob.proj_y)
        )
        xg, yg = x0, jnp.zeros(20)
        xl, yl = x0, jnp.zeros(20)
        for _ in range(T):
            xg, yg = r_gt(xg, yg, prob.agent_data)
            xl, yl = r_ls(xl, yl, prob.agent_data)
        rl_gt = float(robust_loss(prob, xg))
        rl_ls = float(robust_loss(prob, xl))
        assert rl_gt <= rl_ls * 1.001

    def test_gt_matches_centralized_gda_local_sgda_biased(self, rng):
        """Fig 2(a) restated as the claim that is actually seed-robust.

        Eq. (14) is convex (not concave) in y, so the scalar ``robust_loss``
        (projected ascent from y0=0) has multiple boundary local maxima and
        its *value* at two different near-solutions is not a stable
        reproduction criterion.  The paper's underlying claim — FedGDA-GT
        converges to the same solution as centralized (projected) GDA while
        Local SGDA's fixed point is biased away from it (Prop. 1) — is
        checked directly on the iterates instead.
        """
        prob = make_robust_regression_problem(
            rng, dim=20, num_samples=100, num_agents=10, alpha=1.0
        )
        eta, K, T = 5e-3, 10, 600
        x0 = jnp.zeros(20)
        r_gt = jax.jit(make_fedgda_gt_round(prob.loss, K, eta, proj_y=prob.proj_y))
        r_ls = jax.jit(
            make_local_sgda_round(prob.loss, K, eta, eta, proj_y=prob.proj_y)
        )
        r_c = jax.jit(
            make_local_sgda_round(prob.loss, 1, eta, eta, proj_y=prob.proj_y)
        )
        xg, yg = x0, jnp.zeros(20)
        xl, yl = x0, jnp.zeros(20)
        xc, yc = x0, jnp.zeros(20)
        for _ in range(T):
            xg, yg = r_gt(xg, yg, prob.agent_data)
            xl, yl = r_ls(xl, yl, prob.agent_data)
        for _ in range(T * K):  # same gradient-evaluation budget
            xc, yc = r_c(xc, yc, prob.agent_data)
        d_gt = float(jnp.linalg.norm(xg - xc))
        d_ls = float(jnp.linalg.norm(xl - xc))
        # GT lands (essentially) on the centralized solution; SGDA does not.
        assert d_gt < 0.2, d_gt
        assert d_ls > 1.0, d_ls
        assert d_gt < 0.15 * d_ls
        # and its robust loss matches centralized GDA's to <1%
        rl_gt = float(robust_loss(prob, xg))
        rl_c = float(robust_loss(prob, xc))
        assert abs(rl_gt - rl_c) / rl_c < 0.01
