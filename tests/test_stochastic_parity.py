"""Stochastic-family differential harness (PR 6 acceptance contracts).

  * Zero-noise degeneration is BITWISE: `SAGDA()` runs the identical
    trace as `GradientTracking()` (FedGDA-GT), and
    `LocalSGDAPlus(momentum=0)` the identical trace as `LocalOnly()` —
    the stochastic layer must be trace-time elided, not zeroed at run
    time.
  * The noise-fold contract: noise draws come from a DEDICATED stream
    (`fed.noise.noise_key` — `fold_in(PRNGKey(seed), NOISE_STREAM)`),
    which can never alias the client-sampling / compression RNG
    (`PRNGKey(seed)` directly) or the population availability stream.
    Toggling noise on a strategy must leave its OTHER random draws
    (participation sampling, stochastic quantization) bitwise unchanged.
  * Sync/async runtime parity: both runtimes consume the same
    server-side noise stream, so stochastic iterates agree to fp
    tolerance on the 8-device emulation (multihost-marked).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_round
from repro.fed import (
    CompressedGT,
    GradientTracking,
    LocalOnly,
    LocalSGDAPlus,
    PartialParticipation,
    QuantizedGT,
    SAGDA,
)
from repro.fed.noise import (
    NOISE_STREAM,
    GaussianNoise,
    MinibatchNoise,
    noise_key,
    resolve_noise,
)
from repro.problems import make_quadratic_problem
from repro.sim import AVAILABILITY_STREAM

pytestmark = pytest.mark.stochastic

ETA = 1e-4
ROUNDS = 6  # acceptance: bitwise over >= 5 rounds


def _problem(rng, m=6, dim=10):
    return make_quadratic_problem(rng, dim=dim, num_samples=40, num_agents=m)


def _iterate(rnd, x, y, data, rounds=ROUNDS):
    out = []
    for _ in range(rounds):
        x, y = rnd(x, y, data)
        out.append((np.asarray(x), np.asarray(y)))
    return out


def _iterate_stateful(rnd, x, y, data, state, rounds=ROUNDS):
    out = []
    for _ in range(rounds):
        x, y, state = rnd(x, y, data, state)
        out.append((np.asarray(x), np.asarray(y)))
    return out, state


def _assert_bitwise(trace_a, trace_b):
    for t, ((xa, ya), (xb, yb)) in enumerate(zip(trace_a, trace_b)):
        assert (xa == xb).all(), f"x diverges at round {t}"
        assert (ya == yb).all(), f"y diverges at round {t}"


# ------------------------------------------- zero-noise degeneration (bitwise)
class TestZeroNoiseDegeneration:
    @pytest.mark.parametrize("K", [1, 2, 5])
    def test_sagda_bitwise_equals_gradient_tracking(self, rng, K):
        """SAGDA without noise IS FedGDA-GT — same engine trace, fused
        anchor shortcut included."""
        prob = _problem(rng)
        sagda = jax.jit(make_round(prob.loss, SAGDA(), K, ETA))
        gt = jax.jit(make_round(prob.loss, GradientTracking(), K, ETA))
        x, y = jnp.ones(10), -jnp.ones(10)
        _assert_bitwise(
            _iterate(sagda, x, y, prob.agent_data),
            _iterate(gt, x, y, prob.agent_data),
        )

    @pytest.mark.parametrize("K", [1, 2, 5])
    def test_local_sgda_plus_zero_momentum_bitwise_equals_local_only(
        self, rng, K
    ):
        """momentum=0 must not introduce velocity primitives into the
        trace (a 0-scaled velocity would already break bitwise via
        -0.0 and fma re-association)."""
        prob = _problem(rng)
        lsp = jax.jit(
            make_round(prob.loss, LocalSGDAPlus(), K, ETA, 2 * ETA)
        )
        lo = jax.jit(make_round(prob.loss, LocalOnly(), K, ETA, 2 * ETA))
        x, y = jnp.ones(10), -jnp.ones(10)
        _assert_bitwise(
            _iterate(lsp, x, y, prob.agent_data),
            _iterate(lo, x, y, prob.agent_data),
        )

    def test_zero_noise_strategies_are_stateless(self):
        assert not SAGDA().stateful
        assert not LocalSGDAPlus().stateful
        assert not LocalSGDAPlus(momentum=0.9).stateful
        assert SAGDA(noise=GaussianNoise(sigma=0.1)).stateful
        assert LocalSGDAPlus(noise=MinibatchNoise(fraction=0.5)).stateful


# ------------------------------------------------- noise-fold contract
class TestNoiseFoldContract:
    def test_streams_do_not_alias(self):
        """The three seeded subsystems each fold a distinct stream
        constant, so equal integer seeds can never produce colliding
        key sequences across subsystems."""
        assert NOISE_STREAM != AVAILABILITY_STREAM
        # the strategy-RNG convention is PRNGKey(seed) directly
        k_noise = noise_key(0)
        k_strategy = jax.random.PRNGKey(0)
        assert not np.array_equal(
            jax.random.key_data(k_noise), jax.random.key_data(k_strategy)
        )

    def test_state_layouts_pin_the_fold_tree(self):
        """Regression pin: which strategy carries which RNG state.  A
        refactor that starts reusing one key for both draws changes
        these layouts and must fail here."""
        x = jnp.ones(4)
        noise = GaussianNoise(sigma=0.1)
        assert set(SAGDA(noise=noise).init_state(x, x, 3)) == {"noise_key"}
        pp = PartialParticipation(participation=0.5, seed=0, noise=noise)
        st = pp.init_state(x, x, 3)
        assert set(st) == {"key", "noise_key"}
        # equal seeds, distinct folds => distinct keys
        assert not np.array_equal(
            jax.random.key_data(st["key"]),
            jax.random.key_data(st["noise_key"]),
        )
        # top-k compression has no RNG of its own: EF buffers + the
        # noise stream only
        cg = CompressedGT(compression_ratio=0.5, noise=noise, seed=0)
        assert set(cg.init_state(x, x, 3)) == {"ex", "ey", "noise_key"}
        # stochastic rounding adds its own key next to the noise stream
        qg = QuantizedGT(bits=4, noise=noise, seed=0)
        assert set(qg.init_state(x, x, 3)) == {"ex", "ey", "key", "noise_key"}
        st = qg.init_state(x, x, 3)
        assert not np.array_equal(
            jax.random.key_data(st["key"]),
            jax.random.key_data(st["noise_key"]),
        )

    def test_participation_draws_unchanged_by_noise_toggle(self):
        """Client-sampling weights must be bitwise identical with and
        without noise — the noise stream is additive state, not a
        reindexing of the sampling stream."""
        x = jnp.ones(4)
        m = 8
        det = PartialParticipation(participation=0.5, seed=3)
        sto = PartialParticipation(
            participation=0.5, seed=3, noise=GaussianNoise(sigma=0.1)
        )
        s_det = det.init_state(x, x, m)
        s_sto = sto.init_state(x, x, m)
        for _ in range(4):
            w_det, s_det = det.sample_weights(s_det, m)
            w_sto, s_sto = sto.sample_weights(s_sto, m)
            assert (np.asarray(w_det) == np.asarray(w_sto)).all()

    def test_quantization_draws_unchanged_by_noise_toggle(self):
        """Stochastic-rounding corrections must be bitwise identical
        with and without noise (same seed)."""
        m, d = 4, 12
        cx = jax.random.normal(jax.random.PRNGKey(5), (m, d))
        cy = jax.random.normal(jax.random.PRNGKey(6), (m, d))
        det = QuantizedGT(bits=4, seed=1)
        sto = QuantizedGT(bits=4, seed=1, noise=GaussianNoise(sigma=0.1))
        s_det = det.init_state(cx[0], cy[0], m)
        s_sto = sto.init_state(cx[0], cy[0], m)
        qx_d, qy_d, s_det = det.transform_correction(cx, cy, s_det)
        qx_s, qy_s, s_sto = sto.transform_correction(cx, cy, s_sto)
        for a, b in ((qx_d, qx_s), (qy_d, qy_s)):
            if hasattr(a, "decode"):
                a, b = a.decode(), b.decode()
            assert (np.asarray(a) == np.asarray(b)).all()
        assert np.array_equal(
            jax.random.key_data(s_det["key"]),
            jax.random.key_data(s_sto["key"]),
        )

    def test_noise_key_advances_every_round(self, rng):
        prob = _problem(rng)
        strat = SAGDA(noise=GaussianNoise(sigma=0.1), noise_seed=0)
        rnd = jax.jit(
            make_round(prob.loss, strat, 2, ETA, explicit_state=True)
        )
        x, y = jnp.ones(10), -jnp.ones(10)
        state = strat.init_state(x, y, prob.num_agents)
        k0 = np.asarray(jax.random.key_data(state["noise_key"]))
        _, state = _iterate_stateful(
            rnd, x, y, prob.agent_data, state, rounds=1
        )
        k1 = np.asarray(jax.random.key_data(state["noise_key"]))
        assert not np.array_equal(k0, k1)

    def test_resolve_noise_gating(self):
        assert resolve_noise(None) is None
        assert resolve_noise("none") is None
        assert isinstance(resolve_noise("gaussian"), GaussianNoise)
        assert isinstance(resolve_noise("minibatch"), MinibatchNoise)
        n = GaussianNoise(sigma=0.3)
        assert resolve_noise(n) is n
        with pytest.raises(ValueError):
            resolve_noise("laplace")


# ---------------------------------------------- stochastic rounds (seeded)
class TestStochasticDeterminism:
    def _trace(self, prob, strat, rounds=3):
        rnd = jax.jit(
            make_round(prob.loss, strat, 2, ETA, explicit_state=True)
        )
        x, y = jnp.ones(10), -jnp.ones(10)
        state = strat.init_state(x, y, prob.num_agents)
        trace, _ = _iterate_stateful(
            rnd, x, y, prob.agent_data, state, rounds=rounds
        )
        return trace

    def test_same_seed_is_bitwise_reproducible(self, rng):
        prob = _problem(rng)
        strat = SAGDA(noise=GaussianNoise(sigma=0.1), noise_seed=7)
        _assert_bitwise(self._trace(prob, strat), self._trace(prob, strat))

    def test_noise_seed_changes_the_draws(self, rng):
        prob = _problem(rng)
        a = self._trace(prob, SAGDA(noise=GaussianNoise(0.1), noise_seed=0))
        b = self._trace(prob, SAGDA(noise=GaussianNoise(0.1), noise_seed=1))
        assert not np.array_equal(a[0][0], b[0][0])

    def test_noisy_round_differs_from_deterministic_and_stays_finite(
        self, rng
    ):
        prob = _problem(rng)
        det = self._trace(prob, SAGDA())
        sto = self._trace(prob, SAGDA(noise=GaussianNoise(sigma=0.1)))
        assert not np.array_equal(det[-1][0], sto[-1][0])
        assert np.isfinite(sto[-1][0]).all() and np.isfinite(sto[-1][1]).all()

    def test_momentum_changes_the_trace_without_noise(self, rng):
        prob = _problem(rng)
        x, y = jnp.ones(10), -jnp.ones(10)
        lsp = jax.jit(
            make_round(
                prob.loss, LocalSGDAPlus(momentum=0.9), 4, ETA, 2 * ETA
            )
        )
        lo = jax.jit(make_round(prob.loss, LocalOnly(), 4, ETA, 2 * ETA))
        t_lsp = _iterate(lsp, x, y, prob.agent_data, rounds=2)
        t_lo = _iterate(lo, x, y, prob.agent_data, rounds=2)
        assert not np.array_equal(t_lsp[-1][0], t_lo[-1][0])
        assert np.isfinite(t_lsp[-1][0]).all()


# ------------------------------------- sync vs async noise-stream parity
@pytest.mark.multihost
class TestSyncAsyncNoiseParity:
    M, DIM, K = 8, 16, 4

    @pytest.fixture(scope="class")
    def prob(self):
        return make_quadratic_problem(
            jax.random.PRNGKey(0), dim=self.DIM, num_samples=60,
            num_agents=self.M,
        )

    @pytest.mark.parametrize(
        "strategy",
        [
            SAGDA(noise=GaussianNoise(sigma=0.1), noise_seed=3),
            LocalSGDAPlus(
                momentum=0.9, noise=GaussianNoise(sigma=0.1), noise_seed=3
            ),
        ],
        ids=["sagda", "local_sgda_plus"],
    )
    def test_async_matches_sync_noise_stream(
        self, prob, strategy, fed_devices
    ):
        """Both runtimes draw from the one server-side noise stream
        (per-agent keys folded by GLOBAL agent index), so the stochastic
        iterates agree like the deterministic ones do."""
        from repro.fed import AsyncFederatedRunner, FederatedRunner

        x0, y0 = jnp.ones(self.DIM), -jnp.ones(self.DIM)
        sync = FederatedRunner.from_strategy(
            prob.loss, strategy, prob.agent_data, self.K, 1e-3
        )
        xs, ys = sync.run(x0, y0, ROUNDS)
        runner = AsyncFederatedRunner(
            prob.loss, strategy, prob.agent_data, self.K, 1e-3,
            devices=fed_devices,
        )
        xa, ya = runner.run(x0, y0, ROUNDS)
        np.testing.assert_allclose(
            np.asarray(xs), np.asarray(xa), rtol=1e-9, atol=1e-12
        )
        np.testing.assert_allclose(
            np.asarray(ys), np.asarray(ya), rtol=1e-9, atol=1e-12
        )
