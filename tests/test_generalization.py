"""Section-4 generalization machinery: Rademacher estimation, Theorem-2
bound assembly, Lemma-3 VC bound — plus an empirical validation that the
bound actually holds on a synthetic distributed minimax learning task."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    empirical_rademacher,
    lemma3_vc_bound,
    theorem2_bound,
)
from repro.core.generalization import l2_cover_size


def _threshold_loss_matrix(key, m, n, num_candidates, y_shift=0.0):
    """Finite hypothesis class: 1-D threshold classifiers on agent-shifted
    Gaussians (losses in {0,1} — the Lemma-3 finite-values setting)."""
    kd, kc = jax.random.split(key)
    # heterogeneous agents: agent i's samples ~ N(0.3*i, 1)
    shifts = 0.3 * jnp.arange(m, dtype=jnp.float64)
    xi = jax.random.normal(kd, (m, n), jnp.float64) + shifts[:, None]
    labels = (xi + y_shift > 0.0).astype(jnp.float64)
    thresholds = jnp.linspace(-2.0, 2.0, num_candidates)

    def matrix(idx):
        th = thresholds[idx]  # [C]
        pred = (xi[None] > th[:, None, None]).astype(jnp.float64)
        return jnp.abs(pred - labels[None])  # 0/1 loss, [C, m, n]

    return matrix, xi, labels, thresholds


class TestRademacher:
    def test_nonnegative_and_bounded(self):
        m, n, C = 4, 50, 32
        mat, *_ = _threshold_loss_matrix(jax.random.PRNGKey(0), m, n, C)
        r = float(
            empirical_rademacher(mat, C, m, n, jax.random.PRNGKey(1), num_mc=128)
        )
        assert 0.0 <= r <= 1.0

    def test_decreases_with_sample_size(self):
        """R ~ O(1/sqrt(N)): quadrupling n should roughly halve the estimate."""
        m, C = 4, 64
        rs = {}
        for n in (25, 400):
            mat, *_ = _threshold_loss_matrix(jax.random.PRNGKey(2), m, n, C)
            rs[n] = float(
                empirical_rademacher(
                    mat, C, m, n, jax.random.PRNGKey(3), num_mc=256
                )
            )
        assert rs[400] < rs[25]
        ratio = rs[25] / max(rs[400], 1e-9)
        assert 2.0 < ratio < 8.0  # sqrt(16)=4 within generous slop

    def test_richer_class_bigger_complexity(self):
        m, n = 4, 50
        mat_small, *_ = _threshold_loss_matrix(jax.random.PRNGKey(4), m, n, 2)
        mat_big, *_ = _threshold_loss_matrix(jax.random.PRNGKey(4), m, n, 128)
        r_small = float(
            empirical_rademacher(mat_small, 2, m, n, jax.random.PRNGKey(5), 256)
        )
        r_big = float(
            empirical_rademacher(mat_big, 128, m, n, jax.random.PRNGKey(5), 256)
        )
        assert r_big >= r_small - 1e-6


class TestBoundAssembly:
    def test_theorem2_terms(self):
        b = theorem2_bound(
            empirical_risk=0.5,
            rademacher=0.1,
            M_i=[1.0] * 8,
            n=100,
            cover_size=1000,
            delta=0.05,
            L_y=1.0,
            eps=0.01,
        )
        # decompose: f + 2R + conc + 2 L eps
        conc = math.sqrt(8 / (2 * 64 * 100) * math.log(1000 / 0.05))
        np.testing.assert_allclose(b, 0.5 + 0.2 + conc + 0.02, rtol=1e-12)

    def test_bound_decreases_in_n_and_increases_in_cover(self):
        kw = dict(
            empirical_risk=0.0, rademacher=0.0, M_i=[1.0] * 4,
            delta=0.1, L_y=1.0, eps=0.0,
        )
        assert theorem2_bound(n=400, cover_size=100, **kw) < theorem2_bound(
            n=100, cover_size=100, **kw
        )
        assert theorem2_bound(n=100, cover_size=10_000, **kw) > theorem2_bound(
            n=100, cover_size=100, **kw
        )

    def test_lemma3_dominates_mc_estimate(self):
        """Eq. (12) is an upper bound on R(X, Y); the MC estimate of
        R(X, y) must sit below it for the 1-D threshold class (VC dim 1)."""
        m, n, C = 4, 100, 64
        mat, *_ = _threshold_loss_matrix(jax.random.PRNGKey(6), m, n, C)
        r = float(
            empirical_rademacher(mat, C, m, n, jax.random.PRNGKey(7), 256)
        )
        ub = lemma3_vc_bound([1.0] * m, n, vc_dim=1)
        assert r <= ub, (r, ub)

    def test_recovers_agnostic_fl_special_case(self):
        """Choosing M_i = m*y_i*M recovers the Mohri et al. weighted bound's
        concentration term sqrt(M^2 sum y_i^2 / (2n) log(.))."""
        m, n, M = 5, 80, 2.0
        yw = np.array([0.4, 0.3, 0.1, 0.1, 0.1])
        M_i = [m * w * M for w in yw]
        b = theorem2_bound(
            empirical_risk=0.0, rademacher=0.0, M_i=M_i, n=n,
            cover_size=100, delta=0.05, L_y=0.0, eps=0.0,
        )
        want = math.sqrt(
            M * M * float(np.sum(yw**2)) / (2 * n) * math.log(100 / 0.05)
        )
        np.testing.assert_allclose(b, want, rtol=1e-12)

    def test_cover_size_formula(self):
        assert l2_cover_size(1.0, 0.5, 2) == math.ceil(5.0**2)
        assert l2_cover_size(1.0, 0.1, 3) >= l2_cover_size(1.0, 0.5, 3)


class TestBoundHoldsEmpirically:
    def test_population_risk_below_bound(self):
        """Draw a fresh 'population' sample and check R(x,y) <= bound of
        Eq. (10) for every candidate x (single y slice, delta=0.1)."""
        m, n, C = 4, 200, 32
        mat, xi, labels, ths = _threshold_loss_matrix(
            jax.random.PRNGKey(8), m, n, C
        )
        L_emp = np.asarray(mat(jnp.arange(C)))  # [C, m, n]
        emp = L_emp.mean(axis=(1, 2))
        rad = float(
            empirical_rademacher(mat, C, m, n, jax.random.PRNGKey(9), 512)
        )
        # "population": a much larger fresh draw from the same process
        mat_pop, *_ = _threshold_loss_matrix(
            jax.random.PRNGKey(123), m, 20_000, C
        )
        pop = np.asarray(mat_pop(jnp.arange(C))).mean(axis=(1, 2))
        for c in range(C):
            bound = theorem2_bound(
                empirical_risk=float(emp[c]),
                rademacher=rad,
                M_i=[1.0] * m,
                n=n,
                cover_size=1,  # y fixed: |Y_eps| = 1
                delta=0.1,
                L_y=0.0,
                eps=0.0,
            )
            assert pop[c] <= bound + 1e-9, (c, pop[c], bound)
