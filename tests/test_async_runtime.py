"""Async runtime + multi-host packed-payload gather (8-device CPU emulation).

Acceptance contracts of the phase-dispatched runtimes:

  * `AsyncFederatedRunner` matches `FederatedRunner` iterates to fp
    tolerance for every scenario strategy on the 8-device emulated mesh —
    including the stateful ones, because every random draw happens once,
    server-side, through the same strategy code path; per-agent
    error-feedback state SHARDS across the agent devices instead of
    replicating, and still ends up equal to the sync runner's;
  * `MultiHostRunner` gathers the REAL packed buffers: the per-round
    gathered payload bytes equal both the LeafSpec expectation and the
    m-agent payload share of `transport.measured_bytes_per_round`;
  * `build_gather_decode_step`'s lowered all-gather collective bytes
    equal that same payload (the census the dry-run `--runtime async`
    artifacts carry, gated by comm_collectives --check-async).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fed import AsyncFederatedRunner, FederatedRunner
from repro.fed.strategies import (
    CompressedGT,
    FullSync,
    GradientTracking,
    LocalOnly,
    PartialParticipation,
    QuantizedGT,
)
from repro.fed.transport import dense_payload_bytes, measured_bytes_per_round
from repro.launch.multihost import (
    MultiHostRunner,
    build_gather_decode_step,
    expected_gather_bytes,
    init_distributed,
)
from repro.problems import make_quadratic_problem

pytestmark = pytest.mark.multihost

ETA, K, ROUNDS = 1e-3, 4, 6
DIM, M = 16, 8

SCENARIOS = {
    "full_sync": FullSync(),
    "local_only": LocalOnly(),
    "gradient_tracking": GradientTracking(),
    "partial_gt": PartialParticipation(participation=0.5, seed=0),
    "compressed_gt": CompressedGT(compression_ratio=0.25, wire_transport=True),
    "quantized_gt": QuantizedGT(bits=8, wire_transport=True),
}


@pytest.fixture(scope="module")
def prob():
    return make_quadratic_problem(
        jax.random.PRNGKey(0), dim=DIM, num_samples=60, num_agents=M
    )


x0 = jnp.ones(DIM)
y0 = -jnp.ones(DIM)


class TestAsyncRunnerParity:
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_matches_sync_runner_iterates(self, prob, name, fed_devices):
        strategy = SCENARIOS[name]
        sync = FederatedRunner.from_strategy(
            prob.loss, strategy, prob.agent_data, K, ETA
        )
        xs, ys = sync.run(x0, y0, ROUNDS)
        runner = AsyncFederatedRunner(
            prob.loss, strategy, prob.agent_data, K, ETA,
            devices=fed_devices,
        )
        xa, ya = runner.run(x0, y0, ROUNDS)
        assert runner._n_shards == M  # one agent per emulated device
        np.testing.assert_allclose(
            np.asarray(xa), np.asarray(xs), rtol=1e-9, atol=1e-12
        )
        np.testing.assert_allclose(
            np.asarray(ya), np.asarray(ys), rtol=1e-9, atol=1e-12
        )

    def test_error_feedback_state_shards_and_matches_sync(
        self, prob, fed_devices
    ):
        strategy = QuantizedGT(bits=8, wire_transport=True)
        sync = FederatedRunner.from_strategy(
            prob.loss, strategy, prob.agent_data, K, ETA
        )
        sync.run(x0, y0, ROUNDS)
        runner = AsyncFederatedRunner(
            prob.loss, strategy, prob.agent_data, K, ETA,
            devices=fed_devices,
        )
        runner.run(x0, y0, ROUNDS)
        # EF buffers live as per-agent slices on the shard devices...
        assert runner._sharded_keys == ("ex", "ey")
        for i, shard in enumerate(runner._shard_state):
            assert set(shard) == {"ex", "ey"}
            leaf = jax.tree.leaves(shard["ex"])[0]
            assert leaf.shape[0] == M // runner._n_shards
            assert leaf.devices() == {runner._shard_devices[i]}
        # ...the RNG key stays server-side...
        assert set(runner._server_state) == {"key"}
        # ...and gathered back together they equal the sync state
        gathered = runner._gather_state()
        for k in ("ex", "ey", "key"):
            for a, b in zip(
                jax.tree.leaves(gathered[k]),
                jax.tree.leaves(sync._state[k]),
            ):
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=1e-9, atol=1e-12
                )

    def test_history_and_metric_series(self, prob, fed_devices):
        runner = AsyncFederatedRunner(
            prob.loss, GradientTracking(), prob.agent_data, K, ETA,
            devices=fed_devices,
            metric_fn=lambda x, y: {"gap": jnp.sum(x**2)},
        )
        runner.run(x0, y0, 3)
        assert runner.metric_series("gap").shape == (3,)
        with pytest.raises(ValueError, match="available metric keys"):
            runner.metric_series("loss")

    def test_caller_arrays_survive_donation(self, prob, fed_devices):
        """The donated broadcast buffers must never alias caller arrays:
        x0/y0 stay usable after (and between) runs."""
        runner = AsyncFederatedRunner(
            prob.loss, GradientTracking(), prob.agent_data, K, ETA,
            devices=fed_devices,
        )
        runner.run(x0, y0, 2)
        runner.run(x0, y0, 2)  # same inputs again: would throw if deleted
        assert bool(jnp.all(jnp.isfinite(x0)))


class TestMultiHostGather:
    @pytest.mark.parametrize(
        "strategy",
        [
            CompressedGT(compression_ratio=0.25, wire_transport=True),
            QuantizedGT(bits=8, wire_transport=True),
            QuantizedGT(bits=4, ratio=0.25, wire_transport=True),
        ],
        ids=["topk25", "q8", "q4_top25"],
    )
    def test_gathered_bytes_equal_measured_payload(
        self, prob, strategy, fed_devices
    ):
        runner = MultiHostRunner(
            prob.loss, strategy, prob.agent_data, K, ETA,
            devices=fed_devices,
        )
        x1, y1 = runner.run(x0, y0, 2)
        assert bool(jnp.all(jnp.isfinite(x1)))
        assert len(runner.wire_log) == 2
        gathered = runner.wire_log[-1]["gathered_payload_bytes"]
        # (a) the LeafSpec expectation
        assert gathered == expected_gather_bytes(strategy, x0, y0, M)
        # (b) the m-agent payload share of measured_bytes_per_round
        meas = measured_bytes_per_round(
            strategy, x0, y0, K, include_headers=False
        )
        payload_share = (meas - 2 * dense_payload_bytes((x0, y0))) // 2
        assert gathered == M * payload_share

    def test_exact_gt_multihost_matches_sync(self, prob, fed_devices):
        """No randomness, exact correction: the multi-host schedule must
        agree with the fused round to fp tolerance."""
        sync = FederatedRunner.from_strategy(
            prob.loss, GradientTracking(), prob.agent_data, K, ETA
        )
        xs, ys = sync.run(x0, y0, ROUNDS)
        runner = MultiHostRunner(
            prob.loss, GradientTracking(), prob.agent_data, K, ETA,
            devices=fed_devices,
        )
        xm, ym = runner.run(x0, y0, ROUNDS)
        np.testing.assert_allclose(
            np.asarray(xm), np.asarray(xs), rtol=1e-9, atol=1e-12
        )
        np.testing.assert_allclose(
            np.asarray(ym), np.asarray(ys), rtol=1e-9, atol=1e-12
        )

    def test_rejects_payload_free_strategies(self, prob):
        with pytest.raises(ValueError, match="gathers correction payloads"):
            MultiHostRunner(prob.loss, LocalOnly(), prob.agent_data, K, ETA)
        with pytest.raises(ValueError, match="full-participation"):
            MultiHostRunner(
                prob.loss,
                PartialParticipation(participation=0.5),
                prob.agent_data,
                K,
                ETA,
            )

    def test_init_distributed_noop_single_process(self, monkeypatch):
        monkeypatch.delenv("JAX_COORDINATOR_ADDRESS", raising=False)
        assert init_distributed() is False


class TestGatherDecodeCensus:
    def test_all_gather_bytes_equal_payload(self, fed_devices):
        from repro.launch.hlo_census import HloCensus

        mesh = jax.make_mesh((8,), ("data",), devices=fed_devices)
        strategy = QuantizedGT(bits=8, wire_transport=True)
        jitted, args, expected = build_gather_decode_step(
            strategy, x0, y0, mesh, ("data",)
        )
        compiled = jitted.lower(*args).compile()
        census = HloCensus(compiled.as_text()).summary()[
            "collectives_executed"
        ]
        assert census.get("all-gather", {}).get("bytes", 0) == expected
        assert expected == expected_gather_bytes(strategy, x0, y0, 8)

    def test_check_async_gate(self, tmp_path, fed_devices):
        """benchmarks/comm_collectives.check_async passes a faithful
        record and fails a drifted one."""
        import json

        from benchmarks.comm_collectives import check_async

        rec = {
            "gather_census": {"all-gather": {"count": 4, "bytes": 384}},
            "expected_gather_bytes": 384,
            "wire": {
                "measured_bytes_per_round": 352,
                "payload_share_per_agent": 48,
                "num_agents": 8,
            },
        }
        with open(tmp_path / "a__async.json", "w") as f:
            json.dump(rec, f)
        assert check_async(str(tmp_path)) == 0
        rec["gather_census"]["all-gather"]["bytes"] = 9999
        with open(tmp_path / "b__async.json", "w") as f:
            json.dump(rec, f)
        assert check_async(str(tmp_path)) == 1
