"""Per-architecture smoke tests (deliverable f).

Every assigned architecture is instantiated as the REDUCED variant of the
same family (2 layers / d_model<=256 / <=4 experts — see
ModelConfig.reduced) and exercised through one forward pass, one federated
FedGDA-GT training round, and (where supported) a prefill+decode step, all
on CPU.  Assertions: output shapes, finiteness (no NaN/inf), and cache
consistency.  The FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.core import make_fedgda_gt_round
from repro.models import (
    embed_inputs,
    forward,
    init_caches,
    init_params,
    logits_from_hidden,
    num_params,
    random_batch,
)
from repro.problems.adversarial import (
    delta_projection,
    init_delta,
    make_adversarial_loss,
)

ARCH_NAMES = sorted(ARCHS)
DT = jnp.float32
B, S = 2, 64

pytestmark = pytest.mark.slow  # multi-minute: deselect with -m "not slow"


def _finite(tree):
    return all(bool(jnp.all(jnp.isfinite(u))) for u in jax.tree.leaves(tree))


def _stacked_batches(cfg, m, batch, seq, key):
    ks = jax.random.split(key, m)
    bs = [random_batch(ks[i], cfg, batch, seq, DT) for i in range(m)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *bs)


@pytest.fixture(scope="module", params=ARCH_NAMES)
def arch(request):
    return request.param


@pytest.fixture(scope="module")
def reduced(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg, DT)
    return cfg, params


class TestForward:
    def test_forward_shapes_and_finite(self, reduced):
        cfg, params = reduced
        batch = random_batch(jax.random.PRNGKey(1), cfg, B, S, DT)
        h = embed_inputs(params, cfg, batch)
        assert h.shape == (B, S, cfg.d_model), h.shape
        out, caches, aux = forward(params, cfg, h)
        assert out.shape == (B, S, cfg.d_model)
        assert caches is None
        assert _finite(out) and _finite(aux)
        logits = logits_from_hidden(params, cfg, out)
        assert logits.shape == (B, S, cfg.vocab_size)
        assert _finite(logits)

    def test_param_count_positive_and_layers_cycled(self, reduced):
        cfg, params = reduced
        assert num_params(params) > 0
        assert len(cfg.layer_types) == cfg.num_layers


class TestTrainRound:
    def test_fedgda_gt_round_no_nan(self, reduced):
        cfg, params = reduced
        m, K = 2, 2
        data = _stacked_batches(cfg, m, B, S, jax.random.PRNGKey(2))
        loss = make_adversarial_loss(cfg, remat=False)
        rnd = jax.jit(
            make_fedgda_gt_round(loss, K, 1e-3, proj_y=delta_projection(1.0))
        )
        x1, y1 = rnd(params, init_delta(cfg, DT), data)
        # shapes preserved leaf-by-leaf
        assert jax.tree.structure(x1) == jax.tree.structure(params)
        for a, b in zip(jax.tree.leaves(x1), jax.tree.leaves(params)):
            assert a.shape == b.shape and a.dtype == b.dtype
        assert _finite(x1) and _finite(y1)
        assert float(jnp.linalg.norm(y1["delta"])) <= 1.0 + 1e-5

    def test_round_changes_params(self, reduced):
        cfg, params = reduced
        data = _stacked_batches(cfg, 2, B, S, jax.random.PRNGKey(3))
        loss = make_adversarial_loss(cfg, remat=False)
        rnd = jax.jit(make_fedgda_gt_round(loss, 1, 1e-2))
        x1, _ = rnd(params, init_delta(cfg, DT), data)
        moved = sum(
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(x1), jax.tree.leaves(params))
        )
        assert moved > 0.0


class TestServe:
    def test_prefill_then_decode(self, reduced):
        cfg, params = reduced
        if not cfg.supports_decode:
            pytest.skip("encoder-only architecture has no decode step")
        cap = S + 8
        caches = init_caches(cfg, B, cap, DT)
        batch = random_batch(jax.random.PRNGKey(4), cfg, B, S, DT)
        h = embed_inputs(params, cfg, batch)
        h, caches, _ = forward(params, cfg, h, caches=caches)
        assert _finite(h)
        # decode one token at absolute position S
        tok = jnp.zeros((B, 1), jnp.int32)
        hd = embed_inputs(params, cfg, {"tokens": tok})
        hd, caches2, _ = forward(
            params, cfg, hd, caches=caches, position=jnp.int32(S)
        )
        logits = logits_from_hidden(params, cfg, hd)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert _finite(logits)

    def test_decode_matches_full_forward(self, reduced):
        """Teacher-forced decode must reproduce the full-sequence forward
        logits (KV-cache correctness) on attention-only architectures."""
        cfg, params = reduced
        if not cfg.supports_decode:
            pytest.skip("encoder-only")
        if cfg.frontend != "text":
            pytest.skip("frontend stubs prepend embeddings; text-only check")
        if cfg.num_experts:
            # capacity dropping differs between batched prefill (C<S) and
            # one-token decode (C=1, never drops); disable drops so the
            # equivalence is exact and the KV-cache path is what's tested
            import dataclasses

            cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
        s = 8
        batch = random_batch(jax.random.PRNGKey(5), cfg, 1, s, DT)
        h = embed_inputs(params, cfg, batch)
        full, _, _ = forward(params, cfg, h)
        full_logits = logits_from_hidden(params, cfg, full)

        caches = init_caches(cfg, 1, s, DT)
        outs = []
        for t in range(s):
            ht = embed_inputs(params, cfg, {"tokens": batch["tokens"][:, t : t + 1]})
            ht, caches, _ = forward(
                params, cfg, ht, caches=caches, position=jnp.int32(t)
            )
            outs.append(logits_from_hidden(params, cfg, ht))
        dec_logits = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(dec_logits), np.asarray(full_logits), rtol=2e-3, atol=2e-3
        )
