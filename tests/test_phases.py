"""Phase-split engine: the four phases ARE the round.

`make_round` is now the fused composition of `make_phases`'s
broadcast / exchange_corrections / local_steps / aggregate; these tests
pin that the decomposition is behavior-preserving:

  * composing the phases by hand reproduces `make_round` BITWISE for
    every strategy family (the fused round is literally the same trace);
  * `RoundState` is a registered pytree, so each phase can be jitted and
    dispatched SEPARATELY (the async runtime's schedule) and still
    reproduce the fused round's iterates;
  * `run_strategy_rounds` (lax.scan) and `FederatedRunner.run` (python
    loop over the jitted round) agree exactly — same iterates AND same
    final strategy state for a stateful strategy — so the sync/async
    refactor has one shared oracle;
  * `FederatedRunner.metric_series` names the available metrics instead
    of raising a bare KeyError.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    RoundState,
    make_phases,
    make_round,
    run_strategy_rounds,
)
from repro.fed import (
    CompressedGT,
    FederatedRunner,
    FullSync,
    GradientTracking,
    LocalOnly,
    PartialParticipation,
    QuantizedGT,
)
from repro.problems import make_quadratic_problem

ETA, K, ROUNDS = 1e-4, 4, 5

STRATEGIES = {
    "full_sync": FullSync(),
    "local_only": LocalOnly(),
    "gradient_tracking": GradientTracking(),
    "partial_gt": PartialParticipation(participation=0.5, seed=0),
    "compressed_gt": CompressedGT(compression_ratio=0.25, seed=0),
    "quantized_gt": QuantizedGT(bits=8, seed=0, wire_transport=True),
}


@pytest.fixture(scope="module")
def prob():
    return make_quadratic_problem(
        jax.random.PRNGKey(0), dim=10, num_samples=40, num_agents=6
    )


def _state0(strategy, x, m):
    return strategy.init_state(x, x, m)


class TestFusedComposition:
    @pytest.mark.parametrize("name", sorted(STRATEGIES))
    def test_hand_composed_phases_bitwise_equal_make_round(self, prob, name):
        strategy = STRATEGIES[name]
        ph = make_phases(prob.loss, strategy, K, ETA)

        def composed(x, y, data, state):
            rs = ph.broadcast(x, y, data, state)
            rs = ph.exchange_corrections(rs, data)
            rs = ph.local_steps(rs, data)
            return ph.aggregate(rs)

        rnd = jax.jit(make_round(prob.loss, strategy, K, ETA, explicit_state=True))
        comp = jax.jit(composed)
        x = jnp.ones(10)
        y = -jnp.ones(10)
        s_a = s_b = _state0(strategy, x, 6)
        for t in range(ROUNDS):
            xa, ya, s_a = rnd(x, y, prob.agent_data, s_a)
            xb, yb, s_b = comp(x, y, prob.agent_data, s_b)
            assert (np.asarray(xa) == np.asarray(xb)).all(), (name, t)
            assert (np.asarray(ya) == np.asarray(yb)).all(), (name, t)
            x, y = xa, ya
            s_a, s_b = s_a, s_b

    @pytest.mark.parametrize("name", sorted(STRATEGIES))
    def test_separately_jitted_phases_match(self, prob, name):
        """RoundState crosses jit boundaries: each phase compiled as its
        own program (the async runtime's dispatch granularity) must
        reproduce the fused round."""
        strategy = STRATEGIES[name]
        ph = make_phases(prob.loss, strategy, K, ETA)
        b = jax.jit(ph.broadcast)
        e = jax.jit(ph.exchange_corrections)
        l = jax.jit(ph.local_steps)
        a = jax.jit(ph.aggregate)
        rnd = jax.jit(make_round(prob.loss, strategy, K, ETA, explicit_state=True))
        x = jnp.ones(10)
        y = -jnp.ones(10)
        state = _state0(strategy, x, 6)
        xf, yf, _ = rnd(x, y, prob.agent_data, state)
        rs = b(x, y, prob.agent_data, state)
        rs = e(rs, prob.agent_data)
        rs = l(rs, prob.agent_data)
        xp, yp, _ = a(rs)
        np.testing.assert_allclose(np.asarray(xp), np.asarray(xf), rtol=1e-12)
        np.testing.assert_allclose(np.asarray(yp), np.asarray(yf), rtol=1e-12)


class TestRoundState:
    def test_roundstate_is_pytree_with_static_fused(self):
        rs = RoundState(
            x=jnp.ones(3), y=jnp.zeros(3), state={}, fused=True
        )
        leaves, treedef = jax.tree.flatten(rs)
        rs2 = jax.tree.unflatten(treedef, leaves)
        assert rs2.fused is True
        rs3 = dataclasses.replace(rs2, fused=False)
        assert jax.tree.structure(rs3) != treedef  # fused is metadata

    def test_phase_population_order(self, prob):
        """broadcast fills xs/ys, exchange fills corrections, local_steps
        advances, aggregate consumes — the documented contract."""
        strategy = GradientTracking()
        ph = make_phases(prob.loss, strategy, K, ETA)
        x = jnp.ones(10)
        rs = ph.broadcast(x, -x, prob.agent_data, {})
        assert rs.xs is not None and rs.cx is None and not rs.fused
        rs = ph.exchange_corrections(rs, prob.agent_data)
        assert rs.cx is not None and rs.gbar_x is not None and rs.fused
        stepped = ph.local_steps(rs, prob.agent_data)
        assert not bool(
            jnp.all(
                jax.tree.leaves(stepped.xs)[0] == jax.tree.leaves(rs.xs)[0]
            )
        )


class TestRunnerParity:
    def test_run_strategy_rounds_matches_runner_run_stateful(self, prob):
        """Same strategy, same seed: the scan driver and the host-loop
        runner produce identical iterates and identical final strategy
        state (shared oracle for the sync/async refactor)."""
        strategy = QuantizedGT(bits=8, seed=3, wire_transport=True)
        x0 = jnp.ones(10)
        y0 = -jnp.ones(10)
        m = 6
        T = 6
        rnd = jax.jit(
            make_round(prob.loss, strategy, K, ETA, explicit_state=True)
        )
        (xs, ys, state_scan), _ = run_strategy_rounds(
            rnd, x0, y0, prob.agent_data, T, _state0(strategy, x0, m)
        )
        runner = FederatedRunner.from_strategy(
            prob.loss, strategy, prob.agent_data, K, ETA
        )
        xr, yr = runner.run(x0, y0, T)
        assert (np.asarray(xs) == np.asarray(xr)).all()
        assert (np.asarray(ys) == np.asarray(yr)).all()
        assert sorted(state_scan) == sorted(runner._state)
        for k in state_scan:
            for a, b in zip(
                jax.tree.leaves(state_scan[k]),
                jax.tree.leaves(runner._state[k]),
            ):
                assert (np.asarray(a) == np.asarray(b)).all(), k

    def test_metric_series_unknown_key_names_available(self, prob):
        runner = FederatedRunner.from_strategy(
            prob.loss,
            GradientTracking(),
            prob.agent_data,
            K,
            ETA,
            metric_fn=lambda x, y: {
                "gap": jnp.sum(x**2),
                "y_norm": jnp.sum(y**2),
            },
        )
        runner.run(jnp.ones(10), -jnp.ones(10), 2)
        assert runner.metric_series("gap").shape == (2,)
        with pytest.raises(ValueError, match="gap.*y_norm"):
            runner.metric_series("loss")
        with pytest.raises(ValueError, match="available metric keys"):
            runner.metric_series("nope")
