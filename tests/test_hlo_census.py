"""HLO census correctness: trip-count scaling and collective accounting."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_census import HloCensus


def test_nested_scan_flops_exact():
    def body(c, _):
        return c @ c, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=8)

        def inner(c, _):
            z, _ = jax.lax.scan(body, c, None, length=3)
            return z, None

        y2, _ = jax.lax.scan(inner, y, None, length=5)
        return y2

    compiled = jax.jit(f).lower(jnp.ones((64, 64))).compile()
    s = HloCensus(compiled.as_text()).summary()
    assert s["executed_dot_flops"] == 2 * 64**3 * (8 + 15)


def test_unscanned_matmul_counted_once():
    f = lambda a, b: a @ b
    compiled = (
        jax.jit(f)
        .lower(jnp.ones((32, 128)), jnp.ones((128, 16)))
        .compile()
    )
    s = HloCensus(compiled.as_text()).summary()
    assert s["executed_dot_flops"] == 2 * 32 * 128 * 16


def test_collectives_scaled_by_scan_trips():
    """psum inside a scan body must be counted trip_count times."""
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (run under forced host device count)")


def test_duplicate_dot_detection():
    def f(x):
        return x @ x + (x * 2) @ (x * 3)

    compiled = jax.jit(f).lower(jnp.ones((32, 32))).compile()
    s = HloCensus(compiled.as_text()).summary()
    assert sum(s["duplicate_dot_shapes"].values()) >= 2
