"""QuantizedGT: statistical and algebraic guarantees (ISSUE 2).

  * the stochastic quantizer is UNBIASED: averaged over rounding draws,
    Q(c) recovers c to within the Monte-Carlo error of the grid step;
  * error feedback closes the books every round (chat + e' = c + e),
    keeps the residual bounded over time (contraction, not accumulation),
    and demonstrably tightens the convergence floor;
  * `QuantizedGT(bits=32, ratio=1.0)` IS GradientTracking — exactly
    (quantization, sparsification and state are elided at trace time);
  * with real quantization the round still converges on the
    strongly-convex-strongly-concave quadratic, to a tighter floor than
    biased sparsification at matched payload (the quantizer is unbiased).

Everything here is deterministic: fixed seeds, fixed trace-time shapes —
following the `test_strategy_convergence.py` pattern.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_round, run_strategy_rounds, tree_sq_dist
from repro.fed import GradientTracking, QuantizedGT
from repro.kernels import ref
from repro.problems import make_quadratic_problem, quadratic_minimax_point

M, DIM, K, ETA, T = 8, 6, 4, 2e-4, 1500


@pytest.fixture(scope="module")
def quad():
    prob = make_quadratic_problem(
        jax.random.PRNGKey(0), dim=DIM, num_samples=40, num_agents=M
    )
    x_star, y_star = quadratic_minimax_point(prob)
    return prob, x_star, y_star


def _final_gap(prob, x_star, y_star, strategy, rounds=T):
    def gap(x, y):
        return {"gap": tree_sq_dist(x, x_star) + tree_sq_dist(y, y_star)}

    x0 = jnp.zeros(DIM)
    rnd = jax.jit(make_round(prob.loss, strategy, K, ETA, explicit_state=True))
    state0 = strategy.init_state(x0, x0, M)
    (_, _, _), metrics = run_strategy_rounds(
        rnd, x0, x0, prob.agent_data, rounds, state0, gap
    )
    g = np.asarray(metrics["gap"])
    return float(g[0]), float(g[-1])


# ------------------------------------------------------------ unbiasedness
class TestStochasticRoundingUnbiased:
    @pytest.mark.parametrize("bits", [2, 4, 8])
    def test_mean_over_draws_recovers_input(self, bits):
        """E[Q(c)] = c: the grid is symmetric and the rounding Bernoulli
        hits each neighbour with probability equal to its distance."""
        c = jax.random.normal(jax.random.PRNGKey(1), (2, 256), jnp.float32)
        N = 1024
        keys = jax.random.split(jax.random.PRNGKey(2), N)

        def one(key):
            u = jax.random.uniform(key, c.shape)
            chat, _ = ref.compress_correction_ref(
                c, None, None, u, k=c.shape[1], bits=bits
            )
            return chat

        mean = jnp.mean(jax.jit(jax.vmap(one))(keys), axis=0)
        s = 2 ** (bits - 1) - 1
        step = float(jnp.max(jnp.abs(c))) / s  # grid spacing per row bound
        # per-element MC error <= step/2/sqrt(N); 6 sigma keeps this
        # deterministic-seed test far from the boundary
        tol = 6.0 * step / 2.0 / np.sqrt(N)
        np.testing.assert_allclose(
            np.asarray(mean), np.asarray(c), rtol=0, atol=tol
        )

    def test_quantizer_is_actually_lossy_per_draw(self):
        """Guards against an accidentally-identity quantizer making the
        unbiasedness test vacuous."""
        c = jax.random.normal(jax.random.PRNGKey(3), (2, 256), jnp.float32)
        u = jax.random.uniform(jax.random.PRNGKey(4), c.shape)
        chat, resid = ref.compress_correction_ref(
            c, None, None, u, k=c.shape[1], bits=4
        )
        assert float(jnp.max(jnp.abs(resid))) > 1e-3
        # and the kept grid really has 2^(bits-1)-1 magnitude levels
        s = 2 ** (4 - 1) - 1
        scale = jnp.max(jnp.abs(c), axis=-1, keepdims=True)
        q = np.asarray(chat * s / scale)
        np.testing.assert_allclose(q, np.round(q), atol=1e-5)


# ----------------------------------------------------------- error feedback
class TestErrorFeedback:
    def test_residual_closes_the_books_each_round(self):
        c = jax.random.normal(jax.random.PRNGKey(5), (3, 128), jnp.float64)
        e = 0.1 * jax.random.normal(jax.random.PRNGKey(6), c.shape)
        u = jax.random.uniform(jax.random.PRNGKey(7), c.shape)
        chat, resid = ref.compress_correction_ref(
            c, e, None, u, k=32, bits=4
        )
        np.testing.assert_allclose(
            np.asarray(chat + resid), np.asarray(c + e), rtol=0, atol=1e-12
        )

    def test_feedback_contracts_instead_of_accumulating(self):
        """Iterating Q with feedback on a FIXED correction keeps ||e_t||
        bounded and makes the time-average of what was sent converge to
        the true correction (the mechanism behind the tighter floor)."""
        c = jax.random.normal(jax.random.PRNGKey(8), (2, 256), jnp.float64)
        e = jnp.zeros_like(c)
        sent = jnp.zeros_like(c)
        norms = []
        Tl = 64
        for t in range(Tl):
            u = jax.random.uniform(jax.random.fold_in(jax.random.PRNGKey(9), t), c.shape)
            chat, e = ref.compress_correction_ref(c, e, None, u, k=64, bits=4)
            sent = sent + chat
            norms.append(float(jnp.linalg.norm(e)))
        c_norm = float(jnp.linalg.norm(c))
        assert max(norms) < 2.0 * c_norm  # bounded, never blows up
        avg_err = float(jnp.linalg.norm(sent / Tl - c)) / c_norm
        first_err = float(
            jnp.linalg.norm(
                ref.compress_correction_ref(
                    c, None, None,
                    jax.random.uniform(jax.random.PRNGKey(10), c.shape),
                    k=64, bits=4,
                )[0]
                - c
            )
        ) / c_norm
        assert avg_err < first_err / 4.0  # time-average beats any single send

    def test_error_feedback_tightens_the_floor(self, quad):
        prob, xs, ys = quad
        _, g_ef = _final_gap(
            prob, xs, ys, QuantizedGT(bits=4, ratio=0.25, seed=0)
        )
        _, g_noef = _final_gap(
            prob, xs, ys,
            QuantizedGT(bits=4, ratio=0.25, seed=0, error_feedback=False),
        )
        assert g_ef < g_noef / 10.0


# ------------------------------------------------- identity configuration
class TestIdentityConfiguration:
    def test_bits32_ratio1_equals_gradient_tracking_exactly(self, quad):
        """Acceptance: QuantizedGT(bits=32, ratio=1.0) reproduces
        GradientTracking iterates (we assert bitwise, stronger than the
        1e-10 bound)."""
        prob, _, _ = quad
        ra = jax.jit(
            make_round(prob.loss, QuantizedGT(bits=32, ratio=1.0), K, ETA)
        )
        rb = jax.jit(make_round(prob.loss, GradientTracking(), K, ETA))
        xa = xb = jnp.ones(DIM)
        ya = yb = -jnp.ones(DIM)
        for t in range(5):
            xa, ya = ra(xa, ya, prob.agent_data)
            xb, yb = rb(xb, yb, prob.agent_data)
            assert bool(jnp.all(xa == xb)), f"x diverges at round {t}"
            assert bool(jnp.all(ya == yb)), f"y diverges at round {t}"

    def test_identity_configuration_is_stateless_and_exact(self):
        ident = QuantizedGT(bits=32, ratio=1.0)
        assert not ident.stateful and ident.exact_correction
        assert QuantizedGT(bits=8).stateful
        assert not QuantizedGT(bits=8).exact_correction
        assert QuantizedGT(bits=32, ratio=0.5).stateful  # sparsify only
        # quantization always needs the rounding RNG, even without feedback
        assert QuantizedGT(bits=8, error_feedback=False).stateful

    def test_knob_validation(self):
        with pytest.raises(ValueError, match="bits >= 2"):
            QuantizedGT(bits=1)
        with pytest.raises(ValueError, match="unknown compression mode"):
            QuantizedGT(mode="middlek")


# --------------------------------------------------------------- convergence
class TestConvergence:
    def test_8bit_dense_converges_to_tight_floor(self, quad):
        prob, xs, ys = quad
        g0, gT = _final_gap(prob, xs, ys, QuantizedGT(bits=8, seed=0))
        assert g0 > 1e2 and gT < 1e-4  # unbiased + EF: near-exact limit

    @pytest.mark.parametrize("mode", ["topk", "randk"])
    def test_quantized_plus_sparsified_converges(self, quad, mode):
        prob, xs, ys = quad
        g0, gT = _final_gap(
            prob, xs, ys, QuantizedGT(bits=4, ratio=0.5, mode=mode, seed=0)
        )
        assert g0 > 1e2 and gT < 1e-1
