"""Fused compress-correction kernel conformance (deliverable: ISSUE 2).

Three layers of agreement, all on CPU via interpret=True:

  * kernel vs oracle — `compress_correction_2d` (Pallas) against
    `ref.compress_correction_ref` (pure jnp) on aligned shapes, fp32 /
    bf16 / fp64 corrections, topk / randk, with and without feedback
    and quantization: <= 1e-6 (the two paths are the same math on the
    same uniform draws, so they agree to the last bit in practice);
  * dispatcher — `compress_leaf` takes the fused path exactly on
    lane-aligned 2D leaves and the oracle otherwise, with identical
    results either way;
  * strategy — `CompressedGT` / `QuantizedGT` with `use_kernel=True`
    match the pure-jnp fallback on odd pytrees mixing aligned and
    unaligned leaves (to ~1 ulp: the kernel compiles as one XLA unit,
    whose fusion may round differently than the eager per-op path).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fed import CompressedGT, QuantizedGT
from repro.kernels import (
    compress_correction_2d,
    compress_leaf,
    fusable_leaf,
    ref,
)

pytestmark = pytest.mark.kernel  # Pallas interpret-mode suite

F32, F64, BF16 = jnp.float32, jnp.float64, jnp.bfloat16
ALIGNED = [(1, 128), (4, 128), (6, 256), (3, 384)]
UNALIGNED = [(4, 100), (5, 37), (2, 130)]


def _inputs(shape, dtype, seed=0):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(seed), 4)
    c = jax.random.normal(k1, shape, dtype)
    e = (0.1 * jax.random.normal(k2, shape)).astype(dtype)
    u_sel = jax.random.uniform(k3, shape)
    u_rnd = jax.random.uniform(k4, shape)
    return c, e, u_sel, u_rnd


def _assert_pair_close(got, want, atol=1e-6):
    for g, w, tag in (*zip(got, want, ("chat", "resid")),):
        np.testing.assert_allclose(
            np.asarray(g, np.float64),
            np.asarray(w, np.float64),
            rtol=0,
            atol=atol,
            err_msg=tag,
        )


# ------------------------------------------------------- kernel vs oracle
class TestKernelMatchesReference:
    @pytest.mark.parametrize("shape", ALIGNED)
    @pytest.mark.parametrize("dtype", [F32, BF16])
    @pytest.mark.parametrize("mode", ["topk", "randk"])
    @pytest.mark.parametrize("bits", [32, 8, 4])
    def test_matches_ref(self, shape, dtype, mode, bits):
        c, e, u_sel, u_rnd = _inputs(shape, dtype)
        k = max(1, shape[1] // 3)
        got = compress_correction_2d(
            c, e, u_sel, u_rnd, k=k, bits=bits, mode=mode, interpret=True
        )
        want = ref.compress_correction_ref(
            c, e, u_sel, u_rnd, k=k, bits=bits, mode=mode
        )
        assert got[0].dtype == dtype and got[1].dtype == dtype
        _assert_pair_close(got, want)

    @pytest.mark.parametrize("shape", [(4, 128), (6, 256)])
    def test_matches_ref_float64(self, shape):
        """x64 corrections (the conftest default for convergence tests)."""
        c, e, u_sel, u_rnd = _inputs(shape, F64)
        got = compress_correction_2d(
            c, e, u_sel, u_rnd, k=shape[1] // 4, bits=8, interpret=True
        )
        want = ref.compress_correction_ref(
            c, e, u_sel, u_rnd, k=shape[1] // 4, bits=8
        )
        _assert_pair_close(got, want, atol=1e-12)

    def test_float8_correction_dtype(self):
        """The beyond-paper fp8 correction storage must flow through the
        compressor (regression: promote_types has no float8 path, so the
        compute dtype is chosen explicitly)."""
        c, e, u_sel, u_rnd = _inputs((4, 128), F32, seed=9)
        c8 = c.astype(jnp.float8_e4m3fn)
        e8 = e.astype(jnp.float8_e4m3fn)
        got = compress_correction_2d(
            c8, e8, u_sel, u_rnd, k=32, bits=8, interpret=True
        )
        want = ref.compress_correction_ref(c8, e8, u_sel, u_rnd, k=32, bits=8)
        assert got[0].dtype == jnp.float8_e4m3fn
        _assert_pair_close(got, want, atol=0)
        # and end-to-end through a strategy with correction_dtype=fp8
        from repro.fed import resolve_strategy

        s = resolve_strategy(
            "compressed_gt",
            compression_ratio=0.5,
            correction_dtype=jnp.float8_e4m3fn,
        )
        m = 3
        cx = jnp.ones((m, 8), jnp.float8_e4m3fn)
        cy = jnp.ones((m, 2), jnp.float8_e4m3fn)
        state = s.init_state(jnp.zeros(8), jnp.zeros(2), m)
        cx2, cy2, _ = s.transform_correction(cx, cy, state)
        assert cx2.dtype == jnp.float8_e4m3fn

    @pytest.mark.parametrize("bits", [32, 8])
    def test_no_feedback_path(self, bits):
        """e=None: chat matches; the (ignored) residual equals ceff-chat."""
        c, _, u_sel, u_rnd = _inputs((4, 256), F32, seed=1)
        k = 64
        got = compress_correction_2d(
            c, None, u_sel, u_rnd, k=k, bits=bits, interpret=True
        )
        want = ref.compress_correction_ref(c, None, u_sel, u_rnd, k=k, bits=bits)
        _assert_pair_close(got, want)

    def test_exactly_k_kept_under_ties(self):
        """Tied magnitudes (incl. all-zero rows) keep exactly k entries —
        the property that keeps bytes_per_round honest."""
        c = jnp.concatenate(
            [jnp.ones((1, 128)), jnp.zeros((1, 128)), -jnp.ones((1, 128))]
        )
        got, _ = compress_correction_2d(c, None, None, None, k=32, interpret=True)
        want, _ = ref.compress_correction_ref(c, None, None, None, k=32, bits=32)
        kept = np.asarray(jnp.sum(got != 0, axis=-1))
        np.testing.assert_array_equal(kept, [32, 0, 32])
        _assert_pair_close((got,), (want,))

    def test_feedback_residual_closes_the_books(self):
        """chat + resid == c + e: nothing is lost, only deferred."""
        c, e, u_sel, u_rnd = _inputs((6, 256), F32, seed=2)
        chat, resid = compress_correction_2d(
            c, e, u_sel, u_rnd, k=50, bits=4, mode="topk", interpret=True
        )
        np.testing.assert_allclose(
            np.asarray(chat + resid), np.asarray(c + e), rtol=0, atol=1e-6
        )

    def test_block_rows_invariance(self):
        """The row tiling must not change the result."""
        c, e, u_sel, u_rnd = _inputs((8, 256), F32, seed=3)
        a = compress_correction_2d(
            c, e, u_sel, u_rnd, k=60, bits=8, block_rows=8, interpret=True
        )
        b = compress_correction_2d(
            c, e, u_sel, u_rnd, k=60, bits=8, block_rows=2, interpret=True
        )
        _assert_pair_close(a, b, atol=0)


# ----------------------------------------------------------- dispatcher
class TestDispatcher:
    @pytest.mark.parametrize("shape", ALIGNED)
    def test_aligned_leaves_are_fusable(self, shape):
        assert fusable_leaf(jnp.zeros(shape))

    @pytest.mark.parametrize("shape", UNALIGNED)
    def test_unaligned_leaves_fall_back(self, shape):
        assert not fusable_leaf(jnp.zeros(shape))

    @pytest.mark.parametrize("shape", ALIGNED + UNALIGNED)
    @pytest.mark.parametrize("bits", [32, 8])
    def test_dispatch_never_changes_the_result(self, shape, bits):
        c, e, u_sel, u_rnd = _inputs(shape, F32, seed=4)
        k = max(1, shape[1] // 3)
        kw = dict(k=k, bits=bits, mode="topk")
        fused = compress_leaf(c, e, u_sel, u_rnd, use_kernel=True, **kw)
        plain = compress_leaf(c, e, u_sel, u_rnd, use_kernel=False, **kw)
        _assert_pair_close(fused, plain)


# ------------------------------------------------- strategy conformance
def _tree(m, dtype):
    """Odd pytree: aligned 2D, unaligned 2D, >2D, and tiny leaves."""
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    mk = lambda key, s: jax.random.normal(key, (m,) + s).astype(dtype)
    return {
        "aligned": mk(ks[0], (256,)),
        "ragged": mk(ks[1], (37,)),
        "matrix": mk(ks[2], (4, 32)),  # flattens to (m, 128): aligned
        "tiny": mk(ks[3], (3,)),
    }


class TestStrategyConformance:
    @pytest.mark.parametrize("dtype", [F32, BF16])
    @pytest.mark.parametrize(
        "mk",
        [
            lambda uk: CompressedGT(compression_ratio=0.25, use_kernel=uk),
            lambda uk: QuantizedGT(bits=8, use_kernel=uk),
            lambda uk: QuantizedGT(
                bits=4, ratio=0.5, mode="randk", use_kernel=uk
            ),
        ],
        ids=["compressed_topk", "quantized_dense", "quantized_randk"],
    )
    def test_use_kernel_matches_fallback_on_odd_trees(self, dtype, mk, rng):
        m = 4
        cx = _tree(m, dtype)
        cy = {"delta": jax.random.normal(rng, (m, 128)).astype(dtype)}
        out = {}
        for uk in (True, False):
            s = mk(uk)
            state = s.init_state(
                jax.tree.map(lambda u: u[0], cx),
                jax.tree.map(lambda u: u[0], cy),
                m,
            )
            out[uk] = s.transform_correction(cx, cy, state)
        atol = 4e-2 if dtype == BF16 else 1e-6  # ~1-2 ulp at |c| <= ~4
        for a, b in zip(jax.tree.leaves(out[True]), jax.tree.leaves(out[False])):
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_allclose(
                np.asarray(a, np.float64), np.asarray(b, np.float64),
                rtol=0, atol=atol,
            )

    def test_transform_preserves_structure_and_dtype(self):
        m = 3
        cx = _tree(m, F32)
        cy = {"d": jnp.ones((m, 5), F32)}
        s = QuantizedGT(bits=8, ratio=0.5, use_kernel=True)
        state = s.init_state(
            jax.tree.map(lambda u: u[0], cx),
            jax.tree.map(lambda u: u[0], cy),
            m,
        )
        cx2, cy2, state2 = s.transform_correction(cx, cy, state)
        assert jax.tree.structure(cx2) == jax.tree.structure(cx)
        assert jax.tree.structure(cy2) == jax.tree.structure(cy)
        for a, b in zip(jax.tree.leaves(cx2), jax.tree.leaves(cx)):
            assert a.shape == b.shape and a.dtype == b.dtype
        # the RNG key advanced and feedback buffers took the residual
        assert not np.array_equal(
            np.asarray(state2["key"]), np.asarray(state["key"])
        )
        assert any(
            float(jnp.max(jnp.abs(u))) > 0 for u in jax.tree.leaves(state2["ex"])
        )
