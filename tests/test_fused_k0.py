"""Pins the §Perf it6 optimization: the fused k=0 step must produce
iterates identical (to 1 ulp) to the literal Algorithm 2 schedule, which
recomputes the k=0 gradient at the anchor point.  The only difference is
rounding: the literal form computes g + (gbar - g) where the fused form
uses gbar directly — the fused form avoids the cancellation and is the
numerically cleaner of the two."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_fedgda_gt_round

pytestmark = pytest.mark.kernel  # fused-update suite, same selection knob
# as the Pallas interpret suites (test_kernels / test_compress_kernel)
from repro.core.types import (
    grad_xy,
    tree_broadcast_agents,
    tree_mean_over_agents,
)
from repro.problems import make_quadratic_problem


def _literal_algorithm2_round(loss, K, eta):
    """Verbatim Algorithm 2: K inner steps, each evaluating the local
    gradient — including the redundant k=0 evaluation at the anchor."""
    gfn = grad_xy(loss)
    vgrad = jax.vmap(gfn, in_axes=(0, 0, 0))

    def rnd(x, y, agent_data):
        m = jax.tree.leaves(agent_data)[0].shape[0]
        xs = tree_broadcast_agents(x, m)
        ys = tree_broadcast_agents(y, m)
        g0 = vgrad(xs, ys, agent_data)
        gbar_x = jax.tree.map(lambda u: jnp.mean(u, axis=0), g0.gx)
        gbar_y = jax.tree.map(lambda u: jnp.mean(u, axis=0), g0.gy)
        cx = jax.tree.map(lambda gb, gi: gb[None] - gi, gbar_x, g0.gx)
        cy = jax.tree.map(lambda gb, gi: gb[None] - gi, gbar_y, g0.gy)
        for _ in range(K):
            g = vgrad(xs, ys, agent_data)
            xs = jax.tree.map(
                lambda u, gv, cv: u - eta * (gv + cv), xs, g.gx, cx
            )
            ys = jax.tree.map(
                lambda u, gv, cv: u + eta * (gv + cv), ys, g.gy, cy
            )
        return tree_mean_over_agents(xs), tree_mean_over_agents(ys)

    return rnd


@pytest.mark.parametrize("K", [1, 2, 5])
def test_fused_round_bitwise_equals_literal_algorithm2(rng, K):
    prob = make_quadratic_problem(rng, dim=10, num_samples=40, num_agents=6)
    eta = 1e-4
    fused = jax.jit(make_fedgda_gt_round(prob.loss, K, eta))
    literal = jax.jit(_literal_algorithm2_round(prob.loss, K, eta))
    x, y = jnp.ones(10), -jnp.ones(10)
    for _ in range(5):  # several rounds so divergence would compound
        xf, yf = fused(x, y, prob.agent_data)
        xl, yl = literal(x, y, prob.agent_data)
        np.testing.assert_allclose(
            np.asarray(xf), np.asarray(xl), rtol=1e-12, atol=0
        )
        np.testing.assert_allclose(
            np.asarray(yf), np.asarray(yl), rtol=1e-12, atol=0
        )
        x, y = xf, yf
