"""End-to-end system behaviour tests: federated training on a real
(reduced) model, SPMD step builders on a host mesh, checkpointing, the
federated runner, and the data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_checkpoint, restore_checkpoint, save_checkpoint
from repro.configs import INPUT_SHAPES, ShapeConfig, get_config
from repro.core import make_fedgda_gt_round, make_local_sgda_round
from repro.data import federated_token_batches, partition_among_agents
from repro.fed import FederatedRunner, comm_table
from repro.launch.mesh import fed_axes, make_host_mesh, num_agents
from repro.launch.steps import (
    build_decode_step,
    build_prefill_step,
    build_train_step,
)
from repro.models import init_params, num_params, random_batch
from repro.problems.adversarial import (
    delta_projection,
    init_delta,
    make_adversarial_loss,
)

DT = jnp.float32

pytestmark = pytest.mark.slow  # multi-minute: deselect with -m "not slow"


@pytest.fixture(scope="module")
def small():
    cfg = get_config("gemma2-2b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg, DT)
    data = federated_token_batches(
        jax.random.PRNGKey(1), num_agents=4, per_agent_batch=2,
        seq_len=32, vocab_size=cfg.vocab_size, heterogeneity=11,
    )
    return cfg, params, data


# ------------------------------------------------------------ training e2e
class TestEndToEndTraining:
    def test_fedgda_gt_reduces_loss(self, small):
        cfg, params, data = small
        loss = make_adversarial_loss(cfg, remat=False)
        rnd = jax.jit(
            make_fedgda_gt_round(loss, 4, 5e-3, proj_y=delta_projection(1.0))
        )

        def gl(x, y):
            per = jax.vmap(loss, in_axes=(None, None, 0))(x, y, data)
            return jnp.mean(per)

        gl = jax.jit(gl)
        x, y = params, init_delta(cfg, DT)
        l0 = float(gl(x, y))
        for _ in range(15):
            x, y = rnd(x, y, data)
        l1 = float(gl(x, y))
        assert np.isfinite(l0) and np.isfinite(l1)
        assert l1 < l0 - 0.05, (l0, l1)

    def test_gt_tracks_global_not_local_descent(self, small):
        """Heterogeneous agents: after rounds of equal budget, the GT
        aggregate's GLOBAL loss should not be worse than Local SGDA's
        (whose aggregate drifts toward local optima)."""
        cfg, params, data = small
        loss = make_adversarial_loss(cfg, remat=False)
        K, eta = 8, 5e-3
        r_gt = jax.jit(
            make_fedgda_gt_round(loss, K, eta, proj_y=delta_projection(1.0))
        )
        r_ls = jax.jit(
            make_local_sgda_round(loss, K, eta, eta, proj_y=delta_projection(1.0))
        )

        def gl(x, y):
            per = jax.vmap(loss, in_axes=(None, None, 0))(x, y, data)
            return jnp.mean(per)

        gl = jax.jit(gl)
        y0 = init_delta(cfg, DT)
        xg, yg = params, y0
        xl, yl = params, y0
        for _ in range(10):
            xg, yg = r_gt(xg, yg, data)
            xl, yl = r_ls(xl, yl, data)
        assert float(gl(xg, yg)) <= float(gl(xl, yl)) + 0.02


# -------------------------------------------------------- SPMD step builders
class TestStepBuildersOnHostMesh:
    """The same builders the dry-run lowers on the production mesh must
    EXECUTE on a 1x1 host mesh (CPU) for a reduced config."""

    def test_train_step_executes(self):
        cfg = get_config("granite-8b").reduced()
        mesh = make_host_mesh(1, 1)
        shape = ShapeConfig("tiny_train", seq_len=32, global_batch=2, kind="train")
        with jax.set_mesh(mesh):
            jitted, specs_fn = build_train_step(
                cfg, mesh, num_local_steps=2, dtype=DT
            )
            sp = specs_fn(shape)
            m = num_agents(mesh, cfg.fed_mode)
            assert m == 1  # 1x1 mesh: single agent
            x = init_params(jax.random.PRNGKey(0), cfg, DT)
            y = init_delta(cfg, DT)
            batch = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), sp["batch"]
            )
            x1, y1 = jitted(shape)(x, y, batch)
            assert all(
                bool(jnp.all(jnp.isfinite(u))) for u in jax.tree.leaves(x1)
            )

    def test_quantized_train_step_threads_state(self):
        """quantized_gt rides the same stateful path as partial_gt /
        compressed_gt: rounding RNG + error-feedback buffers as a 4th
        replicated step input."""
        import dataclasses as _dc

        cfg = _dc.replace(
            get_config("granite-8b").reduced(), quantization_bits=8
        )
        try:
            mesh = make_host_mesh(1, 1)
        except AttributeError as e:  # pragma: no cover
            pytest.skip(f"host mesh unavailable on this jax: {e}")
        shape = ShapeConfig("tiny_train", seq_len=32, global_batch=2, kind="train")
        with jax.set_mesh(mesh):
            jitted, specs_fn = build_train_step(
                cfg, mesh, algorithm="quantized_gt", num_local_steps=2, dtype=DT
            )
            sp = specs_fn(shape)
            assert "state" in sp  # stateful: rounding RNG (+ EF buffers)
            x = init_params(jax.random.PRNGKey(0), cfg, DT)
            y = init_delta(cfg, DT)
            batch = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), sp["batch"]
            )
            state = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), sp["state"]
            )
            x1, y1, state1 = jitted(shape)(x, y, batch, state)
            assert all(
                bool(jnp.all(jnp.isfinite(u))) for u in jax.tree.leaves(x1)
            )
            assert jax.tree.structure(state1) == jax.tree.structure(state)

    def test_elastic_train_step_executes(self):
        """The membership-aware elastic round as an SPMD step: schedule
        inputs (tracker table, weights, budgets, active) ride along and
        the round executes for a stateful strategy."""
        import dataclasses as _dc

        from repro.launch.steps import build_elastic_train_step

        cfg = _dc.replace(
            get_config("granite-8b").reduced(), quantization_bits=8
        )
        if not hasattr(jax, "set_mesh"):  # pragma: no cover
            pytest.skip("jax.set_mesh unavailable on this jax")
        try:
            mesh = make_host_mesh(1, 1)
        except AttributeError as e:  # pragma: no cover
            pytest.skip(f"host mesh unavailable on this jax: {e}")
        shape = ShapeConfig("tiny_train", seq_len=32, global_batch=2,
                            kind="train")
        with jax.set_mesh(mesh):
            jitted, specs_fn = build_elastic_train_step(
                cfg, mesh, algorithm="quantized_gt", num_local_steps=2,
                dtype=DT,
            )
            sp = specs_fn(shape)
            m = num_agents(mesh, cfg.fed_mode)
            x = init_params(jax.random.PRNGKey(0), cfg, DT)
            y = init_delta(cfg, DT)
            z = lambda t: jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), t
            )
            x1, y1, state1, tracker1 = jitted(shape)(
                x, y, z(sp["batch"]), z(sp["state"]), z(sp["tracker"]),
                jnp.full((m,), 1.0 / m, jnp.float32),
                jnp.full((m,), 2, jnp.int32),
                jnp.ones((m,), bool),
                jnp.ones((m,), bool),
            )
            assert all(
                bool(jnp.all(jnp.isfinite(u))) for u in jax.tree.leaves(x1)
            )
            assert set(tracker1) == {"gx", "gy"}

    def test_prefill_and_decode_execute(self):
        cfg = get_config("starcoder2-7b").reduced()
        mesh = make_host_mesh(1, 1)
        with jax.set_mesh(mesh):
            shape = ShapeConfig("tiny_prefill", seq_len=32, global_batch=2,
                                kind="prefill")
            jit_p, specs_p = build_prefill_step(cfg, mesh, dtype=DT)
            params = init_params(jax.random.PRNGKey(0), cfg, DT)
            batch = random_batch(jax.random.PRNGKey(1), cfg, 2, 32, DT)
            sp = specs_p(shape)
            caches = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), sp["caches"]
            )
            logits, caches = jit_p(shape)(params, batch, caches)
            assert logits.shape[0] == 2 and bool(jnp.all(jnp.isfinite(logits)))

            dshape = ShapeConfig("tiny_decode", seq_len=32, global_batch=2,
                                 kind="decode")
            jit_d, _ = build_decode_step(cfg, mesh, dtype=DT)
            tok = jnp.zeros((2, 1), jnp.int32)
            logits2, caches = jit_d(dshape)(
                params, caches, tok, jnp.int32(32 - 1)
            )
            assert logits2.shape == (2, 1, cfg.vocab_size)
            assert bool(jnp.all(jnp.isfinite(logits2)))

    def test_fed_axes_modes(self):
        mesh = make_host_mesh(1, 1)
        assert fed_axes(mesh, "A") == ("data",)
        assert fed_axes(mesh, "B") == ()
        assert num_agents(mesh, "B") == 1


# ------------------------------------------------------------- checkpointing
class TestCheckpointing:
    def test_roundtrip_exact(self, tmp_path, small):
        cfg, params, _ = small
        tree = {"x": params, "y": init_delta(cfg, DT), "meta": jnp.int32(7)}
        path = save_checkpoint(str(tmp_path), 3, tree)
        back = restore_checkpoint(path)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_selection(self, tmp_path):
        for step in (1, 12, 5):
            save_checkpoint(str(tmp_path), step, {"v": jnp.ones(3)})
        step, path = latest_checkpoint(str(tmp_path))
        assert step == 12 and os.path.exists(path)

    def test_latest_none_for_missing_dir(self, tmp_path):
        assert latest_checkpoint(str(tmp_path / "nope")) is None


# ------------------------------------------------------------ fed runtime
class TestFederatedRunner:
    def test_runner_history_and_checkpoints(self, tmp_path):
        from repro.problems import make_quadratic_problem

        prob = make_quadratic_problem(
            jax.random.PRNGKey(0), dim=8, num_samples=30, num_agents=4
        )
        rnd = make_fedgda_gt_round(prob.loss, 5, 1e-3)
        runner = FederatedRunner(
            rnd,
            prob.agent_data,
            metric_fn=lambda x, y: {"gap": jnp.sum(x**2) + jnp.sum(y**2)},
            checkpoint_dir=str(tmp_path),
            checkpoint_every=10,
        )
        x0 = jnp.ones(8)
        runner.run(x0, x0, num_rounds=20)
        assert len(runner.history) == 20
        series = runner.metric_series("gap")
        assert series.shape == (20,)
        assert latest_checkpoint(str(tmp_path))[0] == 20

    def test_comm_table(self):
        x, y = jnp.zeros(1000), jnp.zeros(10)
        t = comm_table(x, y, 10, {"fedgda_gt": 50, "local_sgda": 5000})
        assert t["fedgda_gt"]["total_bytes"] < t["local_sgda"]["total_bytes"]


# ------------------------------------------------------------- data pipeline
class TestDataPipeline:
    def test_federated_batches_shape_and_heterogeneity(self):
        d = federated_token_batches(
            jax.random.PRNGKey(0), num_agents=4, per_agent_batch=3,
            seq_len=16, vocab_size=97, heterogeneity=5,
        )
        assert d["tokens"].shape == (4, 3, 16)
        assert d["labels"].shape == (4, 3, 16)
        # heterogeneity shifts marginals: agent histograms must differ
        h0 = np.bincount(np.asarray(d["tokens"][0]).ravel(), minlength=97)
        h3 = np.bincount(np.asarray(d["tokens"][3]).ravel(), minlength=97)
        assert np.argmax(h0) != np.argmax(h3)

    def test_partition_among_agents(self):
        data = {"a": jnp.arange(12).reshape(12, 1)}
        part = partition_among_agents(data, 4)
        assert part["a"].shape == (4, 3, 1)
        np.testing.assert_array_equal(
            np.asarray(part["a"].reshape(12, 1)), np.asarray(data["a"])
        )
