"""Strategy-derived communication accounting (star-topology cost model).

`communication_bytes_per_round` is now a thin veneer over
`CommStrategy.bytes_per_round`; these tests pin the legacy string API to
its historical values AND the new per-strategy payload models (client
sampling scales the expected payload; the compression ratio is reflected
in the sparsified-correction bytes, with index overhead, never exceeding
the dense cost)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import communication_bytes_per_round
from repro.fed import (
    CompressedGT,
    FullSync,
    GradientTracking,
    LocalOnly,
    PartialParticipation,
    QuantizedGT,
    comm_table,
    resolve_strategy,
)

P, Q, K = 1000, 10, 16


@pytest.fixture(scope="module")
def xy():
    # float64 under the conftest x64 flag: itemsize 8
    return jnp.zeros((P,)), jnp.zeros((Q,))


def _z(x, y):
    return x.size * x.dtype.itemsize + y.size * y.dtype.itemsize


# ----------------------------------------------------- legacy string API
class TestLegacyStringApi:
    def test_historical_values_preserved(self, xy):
        x, y = xy
        z = _z(x, y)
        assert communication_bytes_per_round(x, y, "local_sgda", K) == 2 * z
        assert communication_bytes_per_round(x, y, "fedgda_gt", K) == 4 * z
        assert communication_bytes_per_round(x, y, "gda", K) == 2 * z * K

    def test_orderings(self, xy):
        x, y = xy
        ls = communication_bytes_per_round(x, y, "local_sgda", K)
        gt = communication_bytes_per_round(x, y, "fedgda_gt", K)
        gda = communication_bytes_per_round(x, y, "gda", K)
        assert 0 < ls < gt == 2 * ls < gda

    def test_unknown_algorithm_raises(self, xy):
        x, y = xy
        with pytest.raises(ValueError, match="unknown algorithm"):
            communication_bytes_per_round(x, y, "adam", K)

    def test_strategy_instances_accepted(self, xy):
        x, y = xy
        assert communication_bytes_per_round(
            x, y, GradientTracking(), K
        ) == communication_bytes_per_round(x, y, "fedgda_gt", K)


# ------------------------------------------------- per-strategy payloads
class TestStrategyPayloads:
    def test_strategies_match_their_legacy_names(self, xy):
        x, y = xy
        z = _z(x, y)
        assert FullSync().bytes_per_round(x, y, K) == 2 * z * K
        assert LocalOnly().bytes_per_round(x, y, K) == 2 * z
        assert GradientTracking().bytes_per_round(x, y, K) == 4 * z

    def test_partial_participation_scales_expected_payload(self, xy):
        x, y = xy
        z = _z(x, y)
        full = PartialParticipation(participation=1.0)
        half = PartialParticipation(participation=0.5)
        assert full.bytes_per_round(x, y, K) == 4 * z
        assert half.bytes_per_round(x, y, K) == 2 * z
        assert PartialParticipation(participation=0.25).bytes_per_round(
            x, y, K
        ) == z

    def test_compression_ratio_reflected_in_bytes(self, xy):
        x, y = xy
        z = _z(x, y)
        dense = CompressedGT(compression_ratio=1.0).bytes_per_round(x, y, K)
        assert dense == 4 * z  # identity configuration == GradientTracking
        ratios = [0.01, 0.1, 0.25, 0.5]
        costs = [
            CompressedGT(compression_ratio=r).bytes_per_round(x, y, K)
            for r in ratios
        ]
        assert all(c < dense for c in costs)  # compression saves bytes
        assert costs == sorted(costs)  # monotone in the ratio
        assert all(c > 2 * z for c in costs)  # models stay dense
        # exact model: dense models + (value + 4-byte index) per kept entry
        k_x = int(np.ceil(0.1 * P))
        k_y = int(np.ceil(0.1 * Q))
        expected = 2 * z + 2 * (k_x * (8 + 4) + k_y * (8 + 4))
        assert CompressedGT(compression_ratio=0.1).bytes_per_round(
            x, y, K
        ) == expected

    def test_sparse_payload_never_exceeds_dense(self, xy):
        x, y = xy
        # with 12 bytes/entry vs 8 dense, ratio ~0.9 would "cost" more
        # sparsified than dense — the model clamps at the dense payload
        assert CompressedGT(compression_ratio=0.9).bytes_per_round(
            x, y, K
        ) <= 4 * _z(x, y)


# ----------------------------------------------- quantized payloads
class TestQuantizedPayloads:
    def test_identity_configuration_prices_like_gradient_tracking(self, xy):
        x, y = xy
        assert QuantizedGT(bits=32, ratio=1.0).bytes_per_round(
            x, y, K
        ) == 4 * _z(x, y)
        # bits >= 32 quantizes nothing: ratio alone reduces to CompressedGT
        assert QuantizedGT(bits=32, ratio=0.5).bytes_per_round(
            x, y, K
        ) == CompressedGT(compression_ratio=0.5).bytes_per_round(x, y, K)

    def test_bit_width_scaling(self, xy):
        x, y = xy
        costs = [
            QuantizedGT(bits=b).bytes_per_round(x, y, K) for b in (2, 4, 8, 16)
        ]
        assert costs == sorted(costs) and costs[0] < costs[-1]
        # exact model, dense ratio: dense models + ceil(n*bits/8) values
        # + one 4-byte fp32 scale per leaf
        z = _z(x, y)
        for b, cost in zip((2, 4, 8, 16), costs):
            expected = 2 * z + 2 * (
                (int(np.ceil(P * b / 8)) + 4) + (int(np.ceil(Q * b / 8)) + 4)
            )
            assert cost == expected

    def test_scale_metadata_overhead_is_priced(self, xy):
        x, y = xy
        # 64-bit values at 8 bits: exactly 1/8 the value bytes + 4 bytes
        # of scale per leaf — the metadata shows up in the exact model
        got = QuantizedGT(bits=8).bytes_per_round(x, y, K)
        no_scale = 2 * _z(x, y) + 2 * (P + Q)
        assert got == no_scale + 2 * 2 * 4

    def test_sparsified_quantized_composition(self, xy):
        x, y = xy
        # ratio=0.1, bits=8: k values at 1 byte + 4-byte index each
        # + 4-byte scale per leaf
        k_x = int(np.ceil(0.1 * P))
        k_y = int(np.ceil(0.1 * Q))
        expected = 2 * _z(x, y) + 2 * (
            (k_x * (1 + 4) + 4) + (k_y * (1 + 4) + 4)
        )
        assert QuantizedGT(bits=8, ratio=0.1).bytes_per_round(
            x, y, K
        ) == expected

    def test_monotonicity_quantized_leq_sparsified_leq_dense(self, xy):
        x, y = xy
        dense = GradientTracking().bytes_per_round(x, y, K)
        for r in (0.05, 0.1, 0.5, 1.0):
            sparse = CompressedGT(compression_ratio=r).bytes_per_round(x, y, K)
            quant = QuantizedGT(bits=8, ratio=r).bytes_per_round(x, y, K)
            assert quant <= sparse <= dense

    def test_quantized_payload_never_exceeds_sparse_or_dense(self, xy):
        x, y = xy
        # adversarial corner: tiny leaves where per-leaf scale overhead
        # could dominate — the model clamps at the cheaper encodings
        x2, y2 = jnp.zeros((2,)), jnp.zeros((1,))
        q = QuantizedGT(bits=16, ratio=0.9).bytes_per_round(x2, y2, K)
        s = CompressedGT(compression_ratio=0.9).bytes_per_round(x2, y2, K)
        assert q <= s <= 4 * _z(x2, y2)


# ----------------------------------------------------------- comm table
class TestCommTable:
    def test_string_and_strategy_keys(self, xy):
        x, y = xy
        z = _z(x, y)
        table = comm_table(
            x,
            y,
            K,
            {
                "fedgda_gt": 50.0,
                "local_sgda": float("inf"),
                CompressedGT(compression_ratio=0.1): 80.0,
            },
        )
        assert table["fedgda_gt"]["total_bytes"] == 50.0 * 4 * z
        assert table["local_sgda"]["total_bytes"] == float("inf")
        cgt = table["compressed_gt"]
        assert cgt["bytes_per_round"] < 4 * z
        assert cgt["total_bytes"] == cgt["bytes_per_round"] * 80.0

    def test_resolve_strategy_roundtrip(self):
        assert isinstance(resolve_strategy("sync_gda"), FullSync)
        assert isinstance(resolve_strategy("gda"), FullSync)
        assert isinstance(resolve_strategy("local_sgda"), LocalOnly)
        assert isinstance(resolve_strategy("fedgda_gt"), GradientTracking)
        pp = resolve_strategy("partial_gt", participation=0.3)
        assert isinstance(pp, PartialParticipation) and pp.participation == 0.3
        cg = resolve_strategy("compressed_gt", compression_ratio=0.2)
        assert isinstance(cg, CompressedGT) and cg.compression_ratio == 0.2
        qg = resolve_strategy(
            "quantized_gt", quantization_bits=4, compression_ratio=0.5
        )
        assert isinstance(qg, QuantizedGT) and qg.bits == 4 and qg.ratio == 0.5
        assert resolve_strategy("quantized_gt").bits == 8  # active by default
        s = GradientTracking()
        assert resolve_strategy(s) is s
        with pytest.raises(ValueError):
            resolve_strategy("nope")
