"""Strategy-derived communication accounting (star-topology cost model).

`communication_bytes_per_round` is now a thin veneer over
`CommStrategy.bytes_per_round`; these tests pin the legacy string API to
its historical values AND the per-strategy payload models (client
sampling scales the expected payload; the compression ratio is reflected
in the sparsified-correction bytes, with index overhead, never exceeding
the dense cost).

Since the wire-transport PR the payload models are derived from
`transport.LeafSpec` — the object that also shapes the packed encoder's
buffers — so the pinned arithmetic here is the EXACT wire format:
  * index width follows the row length (uint16 below 2**16 columns, int32
    above), not a hard-coded 4 bytes;
  * quantized values are bit-packed at the power-of-two storage width and
    padded to whole uint32 words per row;
  * ONE quantization scale is priced per quantization GROUP (a last-axis
    row, stored at the compute dtype: fp32, or fp64 for f64 leaves) — not
    one per leaf (the per-leaf scale bug this PR fixes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import communication_bytes_per_round
from repro.fed import (
    CompressedGT,
    FullSync,
    GradientTracking,
    LocalOnly,
    PartialParticipation,
    QuantizedGT,
    comm_table,
    resolve_strategy,
)

P, Q, K = 1000, 10, 16
IDX = 2  # uint16 indices: both P and Q rows are shorter than 2**16
SCALE = 8  # per-row scale at the compute dtype of f64 leaves


def _words(k, bits):
    """uint32 words per row of k `bits`-bit levels (bits in {2,4,8,16})."""
    return int(np.ceil(k * bits / 32))


@pytest.fixture(scope="module")
def xy():
    # float64 under the conftest x64 flag: itemsize 8
    return jnp.zeros((P,)), jnp.zeros((Q,))


def _z(x, y):
    return x.size * x.dtype.itemsize + y.size * y.dtype.itemsize


# ----------------------------------------------------- legacy string API
class TestLegacyStringApi:
    def test_historical_values_preserved(self, xy):
        x, y = xy
        z = _z(x, y)
        assert communication_bytes_per_round(x, y, "local_sgda", K) == 2 * z
        assert communication_bytes_per_round(x, y, "fedgda_gt", K) == 4 * z
        assert communication_bytes_per_round(x, y, "gda", K) == 2 * z * K

    def test_orderings(self, xy):
        x, y = xy
        ls = communication_bytes_per_round(x, y, "local_sgda", K)
        gt = communication_bytes_per_round(x, y, "fedgda_gt", K)
        gda = communication_bytes_per_round(x, y, "gda", K)
        assert 0 < ls < gt == 2 * ls < gda

    def test_unknown_algorithm_raises(self, xy):
        x, y = xy
        with pytest.raises(ValueError, match="unknown algorithm"):
            communication_bytes_per_round(x, y, "adam", K)

    def test_strategy_instances_accepted(self, xy):
        x, y = xy
        assert communication_bytes_per_round(
            x, y, GradientTracking(), K
        ) == communication_bytes_per_round(x, y, "fedgda_gt", K)


# ------------------------------------------------- per-strategy payloads
class TestStrategyPayloads:
    def test_strategies_match_their_legacy_names(self, xy):
        x, y = xy
        z = _z(x, y)
        assert FullSync().bytes_per_round(x, y, K) == 2 * z * K
        assert LocalOnly().bytes_per_round(x, y, K) == 2 * z
        assert GradientTracking().bytes_per_round(x, y, K) == 4 * z

    def test_partial_participation_scales_expected_payload(self, xy):
        x, y = xy
        z = _z(x, y)
        full = PartialParticipation(participation=1.0)
        half = PartialParticipation(participation=0.5)
        assert full.bytes_per_round(x, y, K) == 4 * z
        assert half.bytes_per_round(x, y, K) == 2 * z
        assert PartialParticipation(participation=0.25).bytes_per_round(
            x, y, K
        ) == z

    def test_compression_ratio_reflected_in_bytes(self, xy):
        x, y = xy
        z = _z(x, y)
        dense = CompressedGT(compression_ratio=1.0).bytes_per_round(x, y, K)
        assert dense == 4 * z  # identity configuration == GradientTracking
        ratios = [0.01, 0.1, 0.25, 0.5]
        costs = [
            CompressedGT(compression_ratio=r).bytes_per_round(x, y, K)
            for r in ratios
        ]
        assert all(c < dense for c in costs)  # compression saves bytes
        assert costs == sorted(costs)  # monotone in the ratio
        assert all(c > 2 * z for c in costs)  # models stay dense
        # exact model: dense models + (value + uint16 index) per kept entry
        k_x = int(np.ceil(0.1 * P))
        k_y = int(np.ceil(0.1 * Q))
        expected = 2 * z + 2 * (k_x * (8 + IDX) + k_y * (8 + IDX))
        assert CompressedGT(compression_ratio=0.1).bytes_per_round(
            x, y, K
        ) == expected

    def test_sparse_payload_never_exceeds_dense(self, xy):
        x, y = xy
        # with 10 bytes/entry vs 8 dense, ratio ~0.9 would "cost" more
        # sparsified than dense — the model clamps at the dense payload
        assert CompressedGT(compression_ratio=0.9).bytes_per_round(
            x, y, K
        ) <= 4 * _z(x, y)

    def test_index_width_follows_row_length(self):
        """uint16 indices while the max index cols - 1 fits (unsigned:
        int16 would overflow at 2**15), int32 beyond — the same width
        the packed encoder emits (satellite: no hard-coded 4-byte
        indices)."""
        small = jnp.zeros((2**16,))
        big = jnp.zeros((2**16 + 1,))
        y0 = jnp.zeros(())  # scalar leaf: always sent densely (8 bytes)
        for x0, idx_b in ((small, 2), (big, 4)):
            k = int(np.ceil(0.1 * x0.size))
            got = CompressedGT(compression_ratio=0.1).bytes_per_round(
                x0, y0, K
            )
            # dense models up+down, then the sparsified correction
            # exchange: (value + index) per kept entry, scalar y dense
            assert got == 2 * (x0.size * 8 + 8) + 2 * (k * (8 + idx_b) + 8)


# ----------------------------------------------- quantized payloads
class TestQuantizedPayloads:
    def test_identity_configuration_prices_like_gradient_tracking(self, xy):
        x, y = xy
        assert QuantizedGT(bits=32, ratio=1.0).bytes_per_round(
            x, y, K
        ) == 4 * _z(x, y)
        # bits >= 32 quantizes nothing: ratio alone reduces to CompressedGT
        assert QuantizedGT(bits=32, ratio=0.5).bytes_per_round(
            x, y, K
        ) == CompressedGT(compression_ratio=0.5).bytes_per_round(x, y, K)

    def test_bit_width_scaling(self, xy):
        x, y = xy
        costs = [
            QuantizedGT(bits=b).bytes_per_round(x, y, K) for b in (2, 4, 8, 16)
        ]
        assert costs == sorted(costs) and costs[0] < costs[-1]
        # exact model, dense ratio: dense models + bit-packed levels
        # padded to whole uint32 words per row + one scale per row (at
        # the compute dtype: 8 bytes for these f64 leaves)
        z = _z(x, y)
        for b, cost in zip((2, 4, 8, 16), costs):
            expected = 2 * z + 2 * (
                (4 * _words(P, b) + SCALE) + (4 * _words(Q, b) + SCALE)
            )
            assert cost == expected

    def test_scale_metadata_overhead_is_priced(self, xy):
        x, y = xy
        # 64-bit values at 8 bits: word-padded 1-byte levels + one
        # per-ROW scale (the per-leaf scale bug: these 1-D leaves are one
        # quantization group each, and the price says so explicitly)
        got = QuantizedGT(bits=8).bytes_per_round(x, y, K)
        no_scale = 2 * _z(x, y) + 2 * (4 * _words(P, 8) + 4 * _words(Q, 8))
        assert got == no_scale + 2 * 2 * SCALE

    def test_scale_priced_per_quantization_group(self):
        """REGRESSION (this PR): a multi-row leaf carries one scale per
        last-axis row — the groups `QuantizedGT` actually scales — and
        the priced bytes equal the packed payload length exactly."""
        from repro.fed import LeafSpec, encode_leaf

        rows, cols, bits = 4, 32, 8
        x = jnp.zeros((rows, cols))  # f64 under the conftest x64 flag
        spec = LeafSpec.build(x.shape, x.dtype, 1.0, bits)
        assert (spec.rows, spec.cols) == (rows, cols)
        # one scale per ROW, not one per leaf:
        per_row = 4 * _words(cols, bits) + SCALE
        assert spec.wire_bytes() == rows * per_row
        # and the strategy pricing uses the same layout
        y = jnp.zeros(())
        got = QuantizedGT(bits=bits).bytes_per_round(x, y, K)
        assert got == 2 * (x.size * 8 + 8) + 2 * (rows * per_row + 8)
        # pinned against the ACTUAL packed buffers, not just arithmetic
        c = jax.random.normal(jax.random.PRNGKey(0), (rows, cols))
        u = jax.random.uniform(jax.random.PRNGKey(1), (rows, cols))
        payload, _ = encode_leaf(c, None, None, u, spec)
        assert payload.nbytes == spec.wire_bytes()
        assert payload.scales.shape == (rows, 1)

    def test_sparsified_quantized_composition(self, xy):
        x, y = xy
        # ratio=0.1, bits=8: word-padded 1-byte levels + uint16 index per
        # kept entry + one scale per row.  The tiny y leaf (k=1) is
        # CHEAPER at full storage width (8+2 bytes) than bit-packed
        # (4-byte word + 8-byte scale + 2-byte index): the model — and
        # the packed encoder, same LeafSpec — degenerate to the sparse
        # ENCODING for it (the values themselves stay quantized: bits
        # applies to the whole tree so the estimator is uniform).
        k_x = int(np.ceil(0.1 * P))
        k_y = int(np.ceil(0.1 * Q))
        x_quant = 4 * _words(k_x, 8) + k_x * IDX + SCALE
        y_sparse = k_y * (8 + IDX)
        assert y_sparse < 4 * _words(k_y, 8) + k_y * IDX + SCALE
        expected = 2 * _z(x, y) + 2 * (x_quant + y_sparse)
        assert QuantizedGT(bits=8, ratio=0.1).bytes_per_round(
            x, y, K
        ) == expected

    def test_monotonicity_quantized_leq_sparsified_leq_dense(self, xy):
        x, y = xy
        dense = GradientTracking().bytes_per_round(x, y, K)
        for r in (0.05, 0.1, 0.5, 1.0):
            sparse = CompressedGT(compression_ratio=r).bytes_per_round(x, y, K)
            quant = QuantizedGT(bits=8, ratio=r).bytes_per_round(x, y, K)
            assert quant <= sparse <= dense

    def test_quantized_payload_never_exceeds_sparse_or_dense(self, xy):
        x, y = xy
        # adversarial corner: tiny leaves where per-leaf scale overhead
        # could dominate — the model clamps at the cheaper encodings
        x2, y2 = jnp.zeros((2,)), jnp.zeros((1,))
        q = QuantizedGT(bits=16, ratio=0.9).bytes_per_round(x2, y2, K)
        s = CompressedGT(compression_ratio=0.9).bytes_per_round(x2, y2, K)
        assert q <= s <= 4 * _z(x2, y2)


# ----------------------------------------------------------- comm table
class TestCommTable:
    def test_string_and_strategy_keys(self, xy):
        x, y = xy
        z = _z(x, y)
        table = comm_table(
            x,
            y,
            K,
            {
                "fedgda_gt": 50.0,
                "local_sgda": float("inf"),
                CompressedGT(compression_ratio=0.1): 80.0,
            },
        )
        assert table["fedgda_gt"]["total_bytes"] == 50.0 * 4 * z
        assert table["local_sgda"]["total_bytes"] == float("inf")
        cgt = table["compressed_gt"]
        assert cgt["bytes_per_round"] < 4 * z
        assert cgt["total_bytes"] == cgt["bytes_per_round"] * 80.0

    def test_measured_bytes_reported_per_row(self, xy):
        """Every row carries the empirical packed-buffer measurement next
        to the analytic price; dense strategies measure exactly their
        price, compressed ones within the fixed per-leaf headers."""
        from repro.fed import wire_header_overhead

        x, y = xy
        table = comm_table(
            x, y, K,
            {
                "fedgda_gt": 10.0,
                QuantizedGT(bits=8, wire_transport=True): 10.0,
            },
        )
        gt = table["fedgda_gt"]
        assert gt["measured_bytes_per_round"] == gt["bytes_per_round"]
        qt = table["quantized_gt"]
        overhead = qt["measured_bytes_per_round"] - qt["bytes_per_round"]
        assert 0 <= overhead <= wire_header_overhead(x, y)

    def test_collision_keys_are_order_independent(self, xy):
        """REGRESSION (this PR): two instances of one strategy class used
        to get positional `name#k` suffixes, so reordering the input dict
        silently relabeled rows.  Rows now key on the full knob
        signature — identical keys whichever order the entries arrive."""
        x, y = xy
        a = CompressedGT(compression_ratio=0.1)
        b = CompressedGT(compression_ratio=0.25)
        t_ab = comm_table(x, y, K, {a: 10.0, b: 20.0, "fedgda_gt": 5.0})
        t_ba = comm_table(x, y, K, {"fedgda_gt": 5.0, b: 20.0, a: 10.0})
        assert set(t_ab) == set(t_ba)
        key_a = next(k for k in t_ab if "0.1" in k)
        assert "compression_ratio=0.1" in key_a  # knobs, not arrival order
        for k in t_ab:
            assert t_ab[k]["bytes_per_round"] == t_ba[k]["bytes_per_round"]
            assert t_ab[k]["rounds_to_eps"] == t_ba[k]["rounds_to_eps"]
        # the unique base name stays unsuffixed
        assert "fedgda_gt" in t_ab

    def test_legacy_string_keys_survive_collisions(self, xy):
        """Documented contract: a legacy STRING key is always a row key
        verbatim, even when a strategy instance of the same class is in
        the dict; only the instance row gets the knob suffix."""
        x, y = xy
        t = comm_table(
            x, y, K, {"quantized_gt": 10.0, QuantizedGT(bits=4): 20.0}
        )
        assert "quantized_gt" in t
        assert t["quantized_gt"]["rounds_to_eps"] == 10.0
        inst = next(k for k in t if k.startswith("quantized_gt["))
        assert "bits=4" in inst and t[inst]["rounds_to_eps"] == 20.0
        # string + indistinguishable instance: deterministic '+' suffix
        t2 = comm_table(
            x, y, K, {"quantized_gt": 10.0, QuantizedGT(bits=8): 20.0}
        )
        assert set(t2) == {"quantized_gt", "quantized_gt+"}

    def test_resolve_strategy_roundtrip(self):
        assert isinstance(resolve_strategy("sync_gda"), FullSync)
        assert isinstance(resolve_strategy("gda"), FullSync)
        assert isinstance(resolve_strategy("local_sgda"), LocalOnly)
        assert isinstance(resolve_strategy("fedgda_gt"), GradientTracking)
        pp = resolve_strategy("partial_gt", participation=0.3)
        assert isinstance(pp, PartialParticipation) and pp.participation == 0.3
        cg = resolve_strategy("compressed_gt", compression_ratio=0.2)
        assert isinstance(cg, CompressedGT) and cg.compression_ratio == 0.2
        qg = resolve_strategy(
            "quantized_gt", quantization_bits=4, compression_ratio=0.5
        )
        assert isinstance(qg, QuantizedGT) and qg.bits == 4 and qg.ratio == 0.5
        assert resolve_strategy("quantized_gt").bits == 8  # active by default
        s = GradientTracking()
        assert resolve_strategy(s) is s
        with pytest.raises(ValueError):
            resolve_strategy("nope")
