"""Paper Figure 3 / Appendix C — Local SGDA's constant-stepsize fixed-point
bias as a function of the number of local steps K.

For each K: the closed-form fixed point (Proposition 1 algebra), the
empirically converged iterate, the Prop-1 residual at both the fixed point
(must be ~0) and the true minimax point (must be > 0 for K >= 2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    APPENDIX_C_MINIMAX_POINT,
    appendix_c_fixed_point,
    make_local_sgda_round,
    prop1_residual,
    run_rounds,
)
from repro.problems import make_appendix_c_problem

from .common import emit


def run(rows=None):
    jax.config.update("jax_enable_x64", True)
    prob = make_appendix_c_problem()
    xm = APPENDIX_C_MINIMAX_POINT[0]
    rows = [] if rows is None else rows
    for K in (1, 10, 20, 50):
        eta = 0.1 if K == 1 else 0.001  # the paper's own stepsizes
        rnd = jax.jit(make_local_sgda_round(prob.loss, K, eta, eta))
        x0 = jnp.array(0.0)
        (x, y), _ = run_rounds(rnd, x0, x0, prob.agent_data, 30_000)
        fx, _ = appendix_c_fixed_point(K, eta, eta)
        r_fp = float(
            prop1_residual(prob.loss, x, y, prob.agent_data, K, eta, eta)
        )
        r_mm = float(
            prop1_residual(
                prob.loss, jnp.float64(xm), jnp.float64(xm),
                prob.agent_data, K, eta, eta,
            )
        )
        rows.append(
            {
                "K": K,
                "eta": eta,
                "x_empirical": f"{float(x):.8f}",
                "x_closed_form": f"{fx:.8f}",
                "bias_|x-3.3|": f"{abs(float(x) - xm):.3e}",
                "prop1_residual_at_fp": f"{r_fp:.2e}",
                "prop1_residual_at_minimax": f"{r_mm:.2e}",
            }
        )
    emit(
        rows,
        [
            "K",
            "eta",
            "x_empirical",
            "x_closed_form",
            "bias_|x-3.3|",
            "prop1_residual_at_fp",
            "prop1_residual_at_minimax",
        ],
        "fig3/appendix-C: Local SGDA fixed-point bias vs K",
    )
    return rows


if __name__ == "__main__":
    run()
