"""Benchmark driver: one table per paper figure/claim + the roofline.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig1 roofline   # subset

Tables:
  fig1        — quadratic game convergence (paper Fig 1)
  fig2        — robust regression under heterogeneity (paper Fig 2)
  fig3        — Local SGDA fixed-point bias vs K (paper Fig 3 / App C)
  generalization — Theorem-2 bound vs measured gap (paper Sec 4) + the
                stochastic family's strategy x noise x heterogeneity
                rounds-to-eps / gen-gap table
  generalization_check — the stochastic table's CI gate (exits non-zero
                on violation; same as generalization.py --check)
  comm        — bytes-to-accuracy, star-topology model (paper headline)
  overlap     — wall-clock round latency, sync vs async runtime
  elastic     — rounds/bytes to eps under population churn scenarios
  elastic_pods — the 1e6-agent mega preset through the O(active) sparse
                engine + pod tree, with peak-memory columns (the gate
                is elastic.py --check-pods)
  collectives — per-round collective traffic by algorithm (HLO census)
  kernels     — Pallas kernels vs ref oracles
  roofline    — three-term roofline per (arch x shape) (deliverable g)
  obs         — telemetry sink overhead, disabled vs enabled vs ledger
                (the gate is obs.py --check: enabled <= 3% over disabled)
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    want = set(a for a in sys.argv[1:] if not a.startswith("-"))
    from . import (
        comm_collectives,
        comm_efficiency,
        elastic,
        fig1_quadratic,
        fig2_robust_regression,
        fig3_fixed_point,
        generalization,
        kernels,
        obs,
        roofline,
    )

    suites = {
        "fig1": fig1_quadratic.run,
        "fig2": fig2_robust_regression.run,
        "fig3": fig3_fixed_point.run,
        "generalization": generalization.run_all,
        "generalization_check": generalization.check_gate,
        "comm": comm_efficiency.run,
        "overlap": comm_efficiency.overlap,
        "elastic": elastic.run,
        "elastic_pods": elastic.run_pods,
        "collectives": comm_collectives.run,
        "kernels": kernels.run,
        "roofline": roofline.run,
        "obs": obs.run,
    }
    summary = []
    for name, fn in suites.items():
        if want and name not in want:
            continue
        t0 = time.perf_counter()
        fn()
        summary.append((name, time.perf_counter() - t0))
    print("\n# ==== summary ====")
    print("benchmark,seconds")
    for name, dt in summary:
        print(f"{name},{dt:.1f}")


if __name__ == "__main__":
    main()
