"""Kernel validation + host microbenchmark table.

For each Pallas kernel: max |err| vs the ref.py oracle at a model-relevant
shape (interpret=True on CPU — functional validation), plus the host wall
time of the jnp reference path (the numbers that matter on TPU come from the
roofline, not from CPU timings)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (
    compress_correction_2d,
    flash_attention,
    gt_update_2d,
    pack_payload_2d,
    ref,
    ssm_scan,
)

from .common import emit, timed


def run(rows=None):
    rows = [] if rows is None else rows
    key = jax.random.PRNGKey(0)

    # gt_update: one tile of a parameter shard
    z, g, c = (jax.random.normal(k, (512, 512), jnp.float32)
               for k in jax.random.split(key, 3))
    got = gt_update_2d(z, g, c, eta=1e-3, sign=-1.0, interpret=True)
    want = ref.gt_update_ref(z, g, c, 1e-3, -1.0)
    rfn = jax.jit(lambda a, b, d: ref.gt_update_ref(a, b, d, 1e-3, -1.0))
    rfn(z, g, c).block_until_ready()
    rows.append({
        "kernel": "gt_update(512x512 f32)",
        "max_abs_err_vs_ref": f"{float(jnp.max(jnp.abs(got - want))):.2e}",
        "ref_us_per_call": f"{timed(lambda: rfn(z, g, c).block_until_ready()):.0f}",
    })

    # compress_correction: a 20-agent correction leaf, top-10% + 8-bit QSGD
    kc, ke, ku = jax.random.split(jax.random.fold_in(key, 1), 3)
    R, C, kk = 20, 4096, 410
    c, e = jax.random.normal(kc, (R, C)), 0.1 * jax.random.normal(ke, (R, C))
    ur = jax.random.uniform(ku, (R, C))
    got = compress_correction_2d(c, e, None, ur, k=kk, bits=8, interpret=True)
    want = ref.compress_correction_ref(c, e, None, ur, k=kk, bits=8)
    rfn = jax.jit(
        lambda a, b, u: ref.compress_correction_ref(a, b, None, u, k=kk, bits=8)
    )
    rfn(c, e, ur)[0].block_until_ready()
    rows.append({
        "kernel": "compress_correction(20x4096 f32, top-10% 8-bit+EF)",
        "max_abs_err_vs_ref": f"{float(max(jnp.max(jnp.abs(g - w)) for g, w in zip(got, want))):.2e}",
        "ref_us_per_call": f"{timed(lambda: rfn(c, e, ur)[0].block_until_ready()):.0f}",
    })

    # pack_payload: same leaf, packed to the actual wire format
    got = pack_payload_2d(
        c, e, None, ur, k=kk, bits=8, encoding="quant", interpret=True
    )
    want = ref.pack_payload_ref(c, e, None, ur, k=kk, bits=8, encoding="quant")
    rfn = jax.jit(
        lambda a, b, u: ref.pack_payload_ref(
            a, b, None, u, k=kk, bits=8, encoding="quant"
        )
    )
    rfn(c, e, ur)[0].block_until_ready()
    rows.append({
        "kernel": "pack_payload(20x4096 f32, top-10% 8-bit, uint32 words)",
        "max_abs_err_vs_ref": f"{max(float(np.max(np.abs(np.asarray(g, np.float64) - np.asarray(w, np.float64)))) for g, w in zip(got, want)):.2e}",
        "ref_us_per_call": f"{timed(lambda: rfn(c, e, ur)[0].block_until_ready()):.0f}",
    })

    # flash attention: gemma2-like tile
    q, k_, v = (jax.random.normal(kk, (1, 4, 512, 128), jnp.float32)
                for kk in jax.random.split(key, 3))
    got = flash_attention(q, k_, v, causal=True, window=256, interpret=True)
    want = ref.flash_attention_ref(q, k_, v, causal=True, window=256)
    rfn = jax.jit(lambda a, b, d: ref.flash_attention_ref(a, b, d, causal=True, window=256))
    rfn(q, k_, v).block_until_ready()
    rows.append({
        "kernel": "flash_attention(B1 H4 S512 hd128, win=256)",
        "max_abs_err_vs_ref": f"{float(jnp.max(jnp.abs(got - want))):.2e}",
        "ref_us_per_call": f"{timed(lambda: rfn(q, k_, v).block_until_ready()):.0f}",
    })

    # ssm scan: falcon-mamba-like tile
    k1, k2, k3 = jax.random.split(key, 3)
    S, D, N = 256, 256, 16
    da = jax.nn.sigmoid(jax.random.normal(k1, (S, D, N))) * 0.95
    dbx = jax.random.normal(k2, (S, D, N)) * 0.1
    cc = jax.random.normal(k3, (S, N))
    got = ssm_scan(da, dbx, cc, chunk=64, interpret=True)
    want, _ = ref.ssm_scan_ref(da, dbx, cc, jnp.zeros((D, N)))
    rfn = jax.jit(lambda a, b, d: ref.ssm_scan_ref(a, b, d, jnp.zeros((D, N)))[0])
    rfn(da, dbx, cc).block_until_ready()
    rows.append({
        "kernel": "ssm_scan(S256 D256 N16, chunk=64)",
        "max_abs_err_vs_ref": f"{float(jnp.max(jnp.abs(got - want))):.2e}",
        "ref_us_per_call": f"{timed(lambda: rfn(da, dbx, cc).block_until_ready()):.0f}",
    })

    emit(rows, ["kernel", "max_abs_err_vs_ref", "ref_us_per_call"],
         "Pallas kernels vs ref oracles (interpret=True on CPU)")
    return rows


if __name__ == "__main__":
    run()
