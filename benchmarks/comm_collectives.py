"""Infrastructure-level verification of the paper's communication claim.

Reads the dry-run artifacts for the SAME (arch, shape, mesh) lowered under
the three algorithms (fedgda_gt / local_sgda / sync_gda) and compares the
EXECUTED collective bytes per round from the trip-count-scaled HLO census.

Expected (DESIGN.md §2): per round, Local SGDA moves ~1 model of traffic,
FedGDA-GT ~2x that (tracked gradient + aggregate), sync GDA ~K x.  Rounds
to eps come from benchmarks/fig1; total = product."""
from __future__ import annotations

import glob
import json
import os

from .common import emit


def _coll_bytes(rec):
    tot = 0
    for kind, s in rec.get("census", {}).get("collectives_executed", {}).items():
        f = 2.0 if kind == "all-reduce" else 1.0
        tot += f * s["bytes"]
    return tot


def run(rows=None, dryrun_dir: str = "experiments/dryrun"):
    rows = [] if rows is None else rows
    combos = {}
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if rec["kind"] != "train":
            continue
        algo = rec.get("algorithm") or "fedgda_gt"
        key = (rec["arch"], rec["shape"], rec["mesh"])
        combos.setdefault(key, {})[algo] = rec
    for (arch, shape, mesh), algos in sorted(combos.items()):
        if len(algos) < 2:
            continue
        base = _coll_bytes(algos.get("local_sgda", {})) or None
        for algo, rec in sorted(algos.items()):
            b = _coll_bytes(rec)
            rows.append(
                {
                    "arch": arch,
                    "shape": shape,
                    "mesh": mesh,
                    "algorithm": algo,
                    "collective_GiB_per_round": f"{b / 2**30:.3f}",
                    "vs_local_sgda": f"{b / base:.2f}x" if base else "",
                }
            )
    if rows:
        emit(
            rows,
            ["arch", "shape", "mesh", "algorithm",
             "collective_GiB_per_round", "vs_local_sgda"],
            "per-round collective traffic by algorithm (HLO census)",
        )
    else:
        print("\n# ==== comm_collectives: no multi-algorithm dry-runs found ====")
    return rows


if __name__ == "__main__":
    run()
