"""Infrastructure-level verification of the paper's communication claim.

Reads the dry-run artifacts for the SAME (arch, shape, mesh) lowered under
the three algorithms (fedgda_gt / local_sgda / sync_gda) and compares the
EXECUTED collective bytes per round from the trip-count-scaled HLO census.

Expected (DESIGN.md §2): per round, Local SGDA moves ~1 model of traffic,
FedGDA-GT ~2x that (tracked gradient + aggregate), sync GDA ~K x.  Rounds
to eps come from benchmarks/fig1; total = product.

Async-runtime artifacts (dry-run `--runtime async`, tag `__async`) also
carry the census of the packed-payload all-gather — the collective the
multi-host launch path actually drives (launch/multihost.py).  Its
all-gather bytes must equal both the LeafSpec-derived expectation and the
m-agent payload share of `transport.measured_bytes_per_round`:
`--check-async` exits non-zero when they drift apart by more than 10%,
which is the wire-level closure of the byte-accounting story (priced ==
packed == gathered on the interconnect)."""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

from .common import emit

ASYNC_TOL = 0.10  # all-gather bytes may drift from the payload by <= 10%


def _coll_bytes(rec):
    tot = 0
    for kind, s in rec.get("census", {}).get("collectives_executed", {}).items():
        f = 2.0 if kind == "all-reduce" else 1.0
        tot += f * s["bytes"]
    return tot


def run(rows=None, dryrun_dir: str = "experiments/dryrun"):
    rows = [] if rows is None else rows
    combos = {}
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if rec["kind"] != "train":
            continue
        algo = rec.get("algorithm") or "fedgda_gt"
        if rec.get("runtime", "sync") != "sync":
            algo += f"[{rec['runtime']}]"
        key = (rec["arch"], rec["shape"], rec["mesh"])
        combos.setdefault(key, {})[algo] = rec
    for (arch, shape, mesh), algos in sorted(combos.items()):
        if len(algos) < 2:
            continue
        base = _coll_bytes(algos.get("local_sgda", {})) or None
        for algo, rec in sorted(algos.items()):
            b = _coll_bytes(rec)
            gather = rec.get("gather_census", {}).get("all-gather", {})
            rows.append(
                {
                    "arch": arch,
                    "shape": shape,
                    "mesh": mesh,
                    "algorithm": algo,
                    "collective_GiB_per_round": f"{b / 2**30:.3f}",
                    "vs_local_sgda": f"{b / base:.2f}x" if base else "",
                    "payload_gather_KiB": (
                        f"{gather['bytes'] / 2**10:.1f}" if gather else ""
                    ),
                }
            )
    if rows:
        emit(
            rows,
            ["arch", "shape", "mesh", "algorithm",
             "collective_GiB_per_round", "vs_local_sgda",
             "payload_gather_KiB"],
            "per-round collective traffic by algorithm (HLO census)",
        )
    else:
        print("\n# ==== comm_collectives: no multi-algorithm dry-runs found ====")
    return rows


def check_async(dryrun_dir: str = "experiments/dryrun",
                tol: float = ASYNC_TOL) -> int:
    """Audit every async-runtime artifact: the gather program's
    all-gather collective bytes vs (a) the LeafSpec expectation stored at
    lower time and (b) the m-agent payload share of
    `measured_bytes_per_round`.  Returns the number of drifting records
    (0 = the interconnect moves exactly the priced payload)."""
    checked = bad = 0
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if "gather_census" not in rec:
            continue
        checked += 1
        gathered = rec["gather_census"].get("all-gather", {}).get("bytes", 0)
        expected = rec.get("expected_gather_bytes", 0)
        wire = rec.get("wire", {})
        target = wire.get("num_agents", 0) * wire.get(
            "payload_share_per_agent", 0
        )
        drifts = [
            gathered / ref - 1.0 for ref in (expected, target) if ref
        ]
        ok = bool(drifts) and all(abs(d) <= tol for d in drifts)
        bad += not ok
        print(
            f"[{'ok' if ok else 'DRIFT'}] {os.path.basename(path)}: "
            f"gathered={gathered} expected={expected} "
            f"m*payload_share={target}"
        )
    if not checked:
        print("check-async: no __async dry-run artifacts found")
        return 1
    return bad


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument(
        "--check-async",
        action="store_true",
        help="gate async-mode all-gather bytes against the measured "
        f"payload (> {ASYNC_TOL:.0%} drift exits non-zero)",
    )
    args = ap.parse_args()
    if args.check_async:
        sys.exit(1 if check_async(args.dryrun_dir) else 0)
    run(dryrun_dir=args.dryrun_dir)
