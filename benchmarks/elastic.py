"""Churn robustness — the elastic-population benchmark axis.

For the Section-5.1 quadratic game, rounds to optimality gap <= eps and
wire bytes under each client-population scenario (`repro.sim.scenarios`:
stable / flaky / diurnal / straggler_heavy) for Local SGDA, FedGDA-GT
(with membership-aware tracker rebasing), the naive no-rebase ablation,
and the compressed / quantized tracking variants.  Per-round bytes are
active-set-aware (`sim.schedule_bytes`): departed agents move nothing.

The headline rows: under `flaky` Markov churn, FedGDA-GT with tracker
rebasing still reaches eps (the tracker table keeps the corrections
summing to the tracked global gradient gap, so churn noise is
multiplicative in the gradient and the exact limit survives), while the
no-rebase ablation — 1/m weights over the full registry, i.e. the naive
server — loses (m - |active|)/m of the aggregate's mass every partial
round and stalls orders of magnitude above eps.  Local SGDA stalls at
its bias floor with or without churn.

`--check` is the CI gate (training-free-scale sizes, a few seconds):
non-zero exit if the stable-scenario elastic path needs > 5% more
rounds to eps than the seed runner.  Stable schedules are degenerate by
construction (static-full => the runner takes its bitwise legacy path),
so any drift here means the degeneracy fast-path broke.
"""
from __future__ import annotations

import argparse
import math
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tree_sq_dist
from repro.fed import (
    CompressedGT,
    FederatedRunner,
    GradientTracking,
    LocalOnly,
    QuantizedGT,
)
from repro.problems import make_quadratic_problem, quadratic_minimax_point
from repro.sim import make_population, schedule_bytes

from .common import emit

ETA, K, T = 1e-4, 10, 1200
EPS = 1e-6
DIM, M = 30, 10
SEED = 0
CHECK_TOL = 0.05  # stable elastic may need at most 5% more rounds


def _strategies():
    # (display name, strategy, rebase)
    return [
        ("local_sgda", LocalOnly(), True),
        ("fedgda_gt", GradientTracking(), True),
        ("fedgda_gt_norebase", GradientTracking(), False),
        ("compressed_gt_25", CompressedGT(compression_ratio=0.25), True),
        ("quantized_gt_8bit", QuantizedGT(bits=8), True),
    ]


def _problem():
    jax.config.update("jax_enable_x64", True)
    prob = make_quadratic_problem(
        jax.random.PRNGKey(0), dim=DIM, num_samples=200, num_agents=M
    )
    xs, ys = quadratic_minimax_point(prob)

    def metric(x, y):
        return {"gap": tree_sq_dist(x, xs) + tree_sq_dist(y, ys)}

    return prob, metric


def _rounds_to_eps(gaps: np.ndarray) -> float:
    hit = np.nonzero(gaps <= EPS)[0]
    return float(hit[0]) if hit.size else math.inf


def _run_one(prob, metric, strategy, schedule, rebase, rounds=T):
    runner = FederatedRunner.from_strategy(
        prob.loss, strategy, prob.agent_data, K, ETA, metric_fn=metric
    )
    runner.run(jnp.zeros(DIM), jnp.zeros(DIM), rounds, schedule=schedule,
               rebase=rebase)
    return np.asarray(runner.metric_series("gap"))


def run(rows=None):
    prob, metric = _problem()
    x0 = jnp.zeros(DIM)
    rows = [] if rows is None else rows
    for scenario in ("stable", "flaky", "diurnal", "straggler_heavy"):
        schedule = make_population(scenario, M).schedule(SEED, T, K)
        for name, strategy, rebase in _strategies():
            if scenario == "stable" and not rebase:
                # the ablation only differs on non-full rounds; under
                # the static-full stable schedule it is bitwise the
                # fedgda_gt row — skip the duplicate 1200-round run
                continue
            gaps = _run_one(prob, metric, strategy, schedule, rebase)
            r_eps = _rounds_to_eps(gaps)
            per_round = schedule_bytes(strategy, x0, x0, K, schedule)
            total = (
                "inf"
                if math.isinf(r_eps)
                else int(sum(per_round[: int(r_eps) + 1]))
            )
            rows.append(
                {
                    "scenario": scenario,
                    "algorithm": name,
                    "participation": f"{schedule.participation_rate():.2f}",
                    f"rounds_to_{EPS:g}": r_eps,
                    "bytes_per_round": int(np.mean(per_round)),
                    "total_bytes_to_eps": total,
                    "final_gap": f"{gaps[-1]:.2e}",
                }
            )
    emit(
        rows,
        ["scenario", "algorithm", "participation", f"rounds_to_{EPS:g}",
         "bytes_per_round", "total_bytes_to_eps", "final_gap"],
        f"rounds + active-set wire bytes to gap<={EPS:g} under population "
        f"scenarios (quadratic game, m={M}, K={K})",
    )
    # the claims the table must keep making (also asserted in
    # tests/test_elastic.py on a smaller instance)
    by_key = {(r["scenario"], r["algorithm"]): r for r in rows}
    flaky_gt = by_key[("flaky", "fedgda_gt")][f"rounds_to_{EPS:g}"]
    flaky_naive = by_key[("flaky", "fedgda_gt_norebase")][f"rounds_to_{EPS:g}"]
    print(
        f"# flaky churn: fedgda_gt(rebase) reaches eps at round {flaky_gt}; "
        f"the naive no-rebase server "
        f"{'NEVER reaches it' if math.isinf(flaky_naive) else flaky_naive}"
    )
    return rows


def check(tol: float = CHECK_TOL) -> int:
    """CI gate: the stable-scenario elastic path must match the seed
    runner's rounds-to-eps within `tol` (it is bitwise-identical by
    construction, so the honest expectation is EXACTLY equal; the
    tolerance only keeps the gate robust to benign metric jitter).
    Returns the number of violations (0 = gate holds)."""
    prob, metric = _problem()
    rounds = 400  # training-free scale: seconds, not minutes
    bad = 0
    schedule = make_population("stable", M).schedule(SEED, rounds, K)
    for name, strategy, rebase in _strategies():
        if not rebase:
            continue  # the ablation only differs on non-full rounds
        seed_gaps = _run_one(prob, metric, strategy, None, True, rounds)
        elastic_gaps = _run_one(
            prob, metric, strategy, schedule, True, rounds
        )
        r_seed = _rounds_to_eps(seed_gaps)
        r_elastic = _rounds_to_eps(elastic_gaps)
        if math.isinf(r_seed):
            ok = math.isinf(r_elastic)  # neither converges (local_sgda)
            drift = "n/a"
        else:
            ok = r_elastic <= r_seed * (1.0 + tol)
            drift = f"{r_elastic / r_seed - 1.0:+.2%}"
        bad += not ok
        print(
            f"[{'ok' if ok else 'SLOW'}] stable/{name}: "
            f"seed_rounds={r_seed} elastic_rounds={r_elastic} ({drift})"
        )
    return bad


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--check",
        action="store_true",
        help="gate the stable-scenario elastic path against the seed "
        f"runner (> {CHECK_TOL:.0%} more rounds to eps exits non-zero); "
        "skips the full scenario sweep",
    )
    args = ap.parse_args()
    if args.check:
        sys.exit(1 if check() else 0)
    run()
