"""Churn robustness — the elastic-population benchmark axis.

For the Section-5.1 quadratic game, rounds to optimality gap <= eps and
wire bytes under each client-population scenario (`repro.sim.scenarios`:
stable / flaky / diurnal / straggler_heavy) for Local SGDA, FedGDA-GT
(with membership-aware tracker rebasing), the naive no-rebase ablation,
and the compressed / quantized tracking variants.  Per-round bytes are
active-set-aware (`sim.schedule_bytes`): departed agents move nothing.

The headline rows: under `flaky` Markov churn, FedGDA-GT with tracker
rebasing still reaches eps (the tracker table keeps the corrections
summing to the tracked global gradient gap, so churn noise is
multiplicative in the gradient and the exact limit survives), while the
no-rebase ablation — 1/m weights over the full registry, i.e. the naive
server — loses (m - |active|)/m of the aggregate's mass every partial
round and stalls orders of magnitude above eps.  Local SGDA stalls at
its bias floor with or without churn.

`--check` is the CI gate (training-free-scale sizes, a few seconds):
non-zero exit if the stable-scenario elastic path needs > 5% more
rounds to eps than the seed runner.  Stable schedules are degenerate by
construction (static-full => the runner takes its bitwise legacy path),
so any drift here means the degeneracy fast-path broke.

`--population mega` exercises the O(active) sparse path at registry
scale: the `mega` preset (1e6 agents, 256 active per round, 1024 pods)
driven by `sim.sparse.SparseElasticEngine` over a `SyntheticDataSource`
(per-agent data synthesized from the global id — the registry's data
never exists as one array).  `--check-pods` is its memory gate
(`elastic_pods` in benchmarks/run.py): the 1e6-agent run's peak host +
device memory must stay within a constant factor of a 100x-smaller
registry with the SAME active set — i.e. the peak scales with
O(active + pods), not with m.
"""
from __future__ import annotations

import argparse
import math
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tree_sq_dist
from repro.fed import (
    CompressedGT,
    FederatedRunner,
    GradientTracking,
    LocalOnly,
    QuantizedGT,
)
from repro.problems import make_quadratic_problem, quadratic_minimax_point
from repro.sim import make_population, schedule_bytes

from .common import emit

ETA, K, T = 1e-4, 10, 1200
EPS = 1e-6
DIM, M = 30, 10
SEED = 0
CHECK_TOL = 0.05  # stable elastic may need at most 5% more rounds


def _strategies():
    # (display name, strategy, rebase)
    return [
        ("local_sgda", LocalOnly(), True),
        ("fedgda_gt", GradientTracking(), True),
        ("fedgda_gt_norebase", GradientTracking(), False),
        ("compressed_gt_25", CompressedGT(compression_ratio=0.25), True),
        ("quantized_gt_8bit", QuantizedGT(bits=8), True),
    ]


def _problem():
    jax.config.update("jax_enable_x64", True)
    prob = make_quadratic_problem(
        jax.random.PRNGKey(0), dim=DIM, num_samples=200, num_agents=M
    )
    xs, ys = quadratic_minimax_point(prob)

    def metric(x, y):
        return {"gap": tree_sq_dist(x, xs) + tree_sq_dist(y, ys)}

    return prob, metric


def _rounds_to_eps(gaps: np.ndarray) -> float:
    hit = np.nonzero(gaps <= EPS)[0]
    return float(hit[0]) if hit.size else math.inf


def _run_one(prob, metric, strategy, schedule, rebase, rounds=T):
    runner = FederatedRunner.from_strategy(
        prob.loss, strategy, prob.agent_data, K, ETA, metric_fn=metric
    )
    runner.run(jnp.zeros(DIM), jnp.zeros(DIM), rounds, schedule=schedule,
               rebase=rebase)
    return np.asarray(runner.metric_series("gap"))


def run(rows=None):
    prob, metric = _problem()
    x0 = jnp.zeros(DIM)
    rows = [] if rows is None else rows
    for scenario in ("stable", "flaky", "diurnal", "straggler_heavy"):
        schedule = make_population(scenario, M).schedule(SEED, T, K)
        for name, strategy, rebase in _strategies():
            if scenario == "stable" and not rebase:
                # the ablation only differs on non-full rounds; under
                # the static-full stable schedule it is bitwise the
                # fedgda_gt row — skip the duplicate 1200-round run
                continue
            gaps = _run_one(prob, metric, strategy, schedule, rebase)
            r_eps = _rounds_to_eps(gaps)
            per_round = schedule_bytes(strategy, x0, x0, K, schedule)
            total = (
                "inf"
                if math.isinf(r_eps)
                else int(sum(per_round[: int(r_eps) + 1]))
            )
            rows.append(
                {
                    "scenario": scenario,
                    "algorithm": name,
                    "participation": f"{schedule.participation_rate():.2f}",
                    f"rounds_to_{EPS:g}": r_eps,
                    "bytes_per_round": int(np.mean(per_round)),
                    "total_bytes_to_eps": total,
                    "final_gap": f"{gaps[-1]:.2e}",
                }
            )
    emit(
        rows,
        ["scenario", "algorithm", "participation", f"rounds_to_{EPS:g}",
         "bytes_per_round", "total_bytes_to_eps", "final_gap"],
        f"rounds + active-set wire bytes to gap<={EPS:g} under population "
        f"scenarios (quadratic game, m={M}, K={K})",
    )
    # the claims the table must keep making (also asserted in
    # tests/test_elastic.py on a smaller instance)
    by_key = {(r["scenario"], r["algorithm"]): r for r in rows}
    flaky_gt = by_key[("flaky", "fedgda_gt")][f"rounds_to_{EPS:g}"]
    flaky_naive = by_key[("flaky", "fedgda_gt_norebase")][f"rounds_to_{EPS:g}"]
    print(
        f"# flaky churn: fedgda_gt(rebase) reaches eps at round {flaky_gt}; "
        f"the naive no-rebase server "
        f"{'NEVER reaches it' if math.isinf(flaky_naive) else flaky_naive}"
    )
    return rows


def check(tol: float = CHECK_TOL) -> int:
    """CI gate: the stable-scenario elastic path must match the seed
    runner's rounds-to-eps within `tol` (it is bitwise-identical by
    construction, so the honest expectation is EXACTLY equal; the
    tolerance only keeps the gate robust to benign metric jitter).
    Returns the number of violations (0 = gate holds)."""
    prob, metric = _problem()
    rounds = 400  # training-free scale: seconds, not minutes
    bad = 0
    schedule = make_population("stable", M).schedule(SEED, rounds, K)
    for name, strategy, rebase in _strategies():
        if not rebase:
            continue  # the ablation only differs on non-full rounds
        seed_gaps = _run_one(prob, metric, strategy, None, True, rounds)
        elastic_gaps = _run_one(
            prob, metric, strategy, schedule, True, rounds
        )
        r_seed = _rounds_to_eps(seed_gaps)
        r_elastic = _rounds_to_eps(elastic_gaps)
        if math.isinf(r_seed):
            ok = math.isinf(r_elastic)  # neither converges (local_sgda)
            drift = "n/a"
        else:
            ok = r_elastic <= r_seed * (1.0 + tol)
            drift = f"{r_elastic / r_seed - 1.0:+.2%}"
        bad += not ok
        print(
            f"[{'ok' if ok else 'SLOW'}] stable/{name}: "
            f"seed_rounds={r_seed} elastic_rounds={r_elastic} ({drift})"
        )
    return bad


# ------------------------------------------------- mega: O(active) at 1e6
MEGA_DIM, MEGA_SAMPLES, MEGA_T = 8, 8, 4
MEGA_MEM_FACTOR = 1.5  # peak(1e6) must stay within this factor of the
MEGA_MEM_SLACK = 24 * 2**20  # 100x-smaller registry's peak, + slack


def _mega_loss(x, y, data):
    # the Section-5.1 quadratic loss over per-agent sufficient stats
    # (same as problems.quadratic; restated so the synthesized rows and
    # the loss agree on the data layout)
    G, Ab = data["G"], data["Ab"]
    return 0.5 * x @ G @ x - 0.5 * y @ G @ y + Ab @ (2.0 * x - y)


def _mega_source(m, dim=MEGA_DIM, samples=MEGA_SAMPLES, seed=7):
    """Per-agent sufficient statistics synthesized from the GLOBAL agent
    id (a pure fold of the data key) — any subset of the m-agent
    registry can be generated on demand in O(n) memory, which is the
    only way 1e6 agents' data exists on a host."""
    from repro.sim import SyntheticDataSource

    data_key = jax.random.PRNGKey(seed)

    def one(i):
        k = jax.random.fold_in(data_key, i)
        k_a, k_t, k_e = jax.random.split(k, 3)
        A = jax.random.normal(k_a, (samples, dim))
        theta = jax.random.normal(k_t, (dim,))
        b = A @ theta + 0.5 * jax.random.normal(k_e, (samples,))
        return {"G": A.T @ A / samples, "Ab": A.T @ b / samples}

    return SyntheticDataSource(m, jax.jit(jax.vmap(one)))


def _mega_engine_run(m, active, pods, T=MEGA_T):
    from repro.sim import Population, UniformActiveSubset, UniformStragglers
    from repro.sim.sparse import SparseElasticEngine

    jax.config.update("jax_enable_x64", True)
    pop = Population(
        m,
        UniformActiveSubset(size=active),
        UniformStragglers(p_straggle=0.3, min_frac=0.5),
        pods=pods,
    )
    eng = SparseElasticEngine(
        _mega_loss,
        GradientTracking(),
        _mega_source(m),
        K,
        ETA,
        pod_map=pop.pod_map(),
        wire_pods=True,
        dense_fallback_max_m=0,  # force the sparse path at every m
    )
    x0 = jnp.zeros(MEGA_DIM)
    eng.run(x0, x0, pop.sparse_schedule(SEED, T, K))
    return eng


def run_pods(rows=None):
    """The `elastic_pods` suite: the mega preset (1e6 agents, 256
    active, 1024 pods) through the sparse engine, with peak-memory and
    pod-wire columns, next to a 100x-smaller registry with the same
    active set — the side-by-side that makes O(active + pods) visible."""
    from repro.obs import peak_memory
    from repro.sim.scenarios import MEGA_ACTIVE, MEGA_AGENTS, MEGA_PODS

    rows = [] if rows is None else rows
    for label, m in (("mega_1e6", MEGA_AGENTS), ("ref_1e4", MEGA_AGENTS // 100)):
        pods = MEGA_PODS if m >= MEGA_PODS else max(1, m // 64)
        mem = peak_memory(_mega_engine_run, m, MEGA_ACTIVE, pods)
        eng = mem["result"]
        rows.append(
            {
                "population": label,
                "m": m,
                "active": MEGA_ACTIVE,
                "pods": pods,
                "rounds": len(eng.history),
                "host_peak_mib": f"{mem['host_peak_bytes'] / 2**20:.1f}",
                "live_buf_mib": f"{mem['live_buffer_bytes'] / 2**20:.1f}",
                "live_pods": eng.history[-1]["live_pods"],
                "pod_wire_bytes": eng.history[-1]["pod_wire_bytes"],
                "tracker_touched": eng._tracker.num_touched,
            }
        )
    emit(
        rows,
        ["population", "m", "active", "pods", "rounds", "host_peak_mib",
         "live_buf_mib", "live_pods", "pod_wire_bytes", "tracker_touched"],
        f"O(active) sparse engine at registry scale (K={K}, "
        f"T={MEGA_T} rounds, two-level pod aggregation)",
    )
    return rows


def check_pods(factor: float = MEGA_MEM_FACTOR,
               slack: int = MEGA_MEM_SLACK) -> int:
    """CI gate for the million-agent memory claim: the 1e6-agent mega
    run's peak (host traced + live device buffers) must stay within
    `factor` x the peak of a 100x-smaller registry with the SAME active
    set, + `slack`.  Any reintroduced m-dense structure (tracker table,
    broadcast stack, [T, m] schedule mask — ~100 MiB at m=1e6 for the
    table alone) trips it; O(active + pods) state cannot.  Returns the
    number of violations (0 = gate holds)."""
    from repro.obs import peak_memory
    from repro.sim.scenarios import MEGA_ACTIVE, MEGA_AGENTS, MEGA_PODS

    def total(m, pods):
        mem = peak_memory(_mega_engine_run, m, MEGA_ACTIVE, pods)
        mem["result"] = None  # drop the engine before the next run
        return mem["host_peak_bytes"] + mem["live_buffer_bytes"]

    ref = total(MEGA_AGENTS // 100, MEGA_PODS)
    mega = total(MEGA_AGENTS, MEGA_PODS)
    budget = int(ref * factor) + slack
    ok = mega <= budget
    print(
        f"[{'ok' if ok else 'FAIL'}] elastic_pods: mega(m={MEGA_AGENTS:.0e}) "
        f"peak={mega / 2**20:.1f}MiB vs ref(m={MEGA_AGENTS // 100:.0e}) "
        f"peak={ref / 2**20:.1f}MiB budget={budget / 2**20:.1f}MiB"
    )
    return 0 if ok else 1


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--check",
        action="store_true",
        help="gate the stable-scenario elastic path against the seed "
        f"runner (> {CHECK_TOL:.0%} more rounds to eps exits non-zero); "
        "skips the full scenario sweep",
    )
    ap.add_argument(
        "--check-pods",
        action="store_true",
        help="gate the mega preset's peak memory: the 1e6-agent sparse "
        "run must not scale with m (see check_pods)",
    )
    ap.add_argument(
        "--population",
        default=None,
        choices=["mega"],
        help="run the named population instead of the scenario sweep "
        "(mega: 1e6 agents / 256 active / 1024 pods via the sparse "
        "engine)",
    )
    args = ap.parse_args()
    if args.check_pods:
        sys.exit(1 if check_pods() else 0)
    if args.check:
        sys.exit(1 if check() else 0)
    if args.population == "mega":
        run_pods()
        sys.exit(0)
    run()
