"""Paper Figure 2 — robust linear regression (Eq. 14) under heterogeneity
alpha in {1, 5, 20}.

Reports the final robust loss max_{||y||<=1} sum_i f_i(x, y) for Local SGDA
and FedGDA-GT with the same constant stepsize, plus the distance of each
solution from the centralized projected-GDA reference (the paper's notion of
the correct solution; see tests/test_paper_claims.py for why the distance is
the seed-robust criterion)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_fedgda_gt_round, make_local_sgda_round
from repro.problems import make_robust_regression_problem, robust_loss

from .common import emit

DIM, N, M, K = 20, 100, 10, 10
T = 800


def _stable_eta(prob) -> float:
    a = prob.agent_data["a"]
    H = 2 * jnp.einsum("mnd,mne->de", a, a) / (a.shape[0] * a.shape[1])
    L = float(jnp.linalg.eigvalsh(H + jnp.eye(DIM))[-1])
    return 0.1 / L


def run(rows=None):
    jax.config.update("jax_enable_x64", True)
    rows = [] if rows is None else rows
    for alpha in (1.0, 5.0, 20.0):
        prob = make_robust_regression_problem(
            jax.random.PRNGKey(0), dim=DIM, num_samples=N, num_agents=M,
            alpha=alpha,
        )
        eta = _stable_eta(prob)
        r_gt = jax.jit(
            make_fedgda_gt_round(prob.loss, K, eta, proj_y=prob.proj_y)
        )
        r_ls = jax.jit(
            make_local_sgda_round(prob.loss, K, eta, eta, proj_y=prob.proj_y)
        )
        r_c = jax.jit(
            make_local_sgda_round(prob.loss, 1, eta, eta, proj_y=prob.proj_y)
        )
        z = jnp.zeros(DIM)
        xg, yg, xl, yl, xc, yc = z, z, z, z, z, z
        for _ in range(T):
            xg, yg = r_gt(xg, yg, prob.agent_data)
            xl, yl = r_ls(xl, yl, prob.agent_data)
        for _ in range(T * K):
            xc, yc = r_c(xc, yc, prob.agent_data)
        rows.append(
            {
                "alpha": alpha,
                "eta": f"{eta:.2e}",
                "robust_loss_fedgda_gt": f"{float(robust_loss(prob, xg)):.4f}",
                "robust_loss_local_sgda": f"{float(robust_loss(prob, xl)):.4f}",
                "robust_loss_centralized": f"{float(robust_loss(prob, xc)):.4f}",
                "dist_gt_to_centralized": f"{float(jnp.linalg.norm(xg - xc)):.3e}",
                "dist_ls_to_centralized": f"{float(jnp.linalg.norm(xl - xc)):.3e}",
            }
        )
    emit(
        rows,
        [
            "alpha",
            "eta",
            "robust_loss_fedgda_gt",
            "robust_loss_local_sgda",
            "robust_loss_centralized",
            "dist_gt_to_centralized",
            "dist_ls_to_centralized",
        ],
        "fig2: robust linear regression under heterogeneity",
    )
    return rows


if __name__ == "__main__":
    run()
