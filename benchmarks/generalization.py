"""Section 4 — generalization bound vs the measured generalization gap.

Finite threshold-classifier class over heterogeneous per-agent Gaussians:
as the per-agent sample size n grows, both the Theorem-2 bound and the
measured sup_x |R - f| must decay ~ 1/sqrt(n), with the bound above the
measurement.  Also reports the Lemma-3 VC upper bound on the Rademacher
complexity next to the Monte-Carlo estimate."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import empirical_rademacher, lemma3_vc_bound, theorem2_bound

from .common import emit

M_AGENTS, C = 6, 64
DELTA = 0.05


def _loss_matrix(key, m, n, num_candidates):
    kd, _ = jax.random.split(key)
    shifts = 0.3 * jnp.arange(m, dtype=jnp.float64)
    xi = jax.random.normal(kd, (m, n), jnp.float64) + shifts[:, None]
    labels = (xi > 0.0).astype(jnp.float64)
    ths = jnp.linspace(-2.0, 2.0, num_candidates)

    def matrix(idx):
        pred = (xi[None] > ths[idx][:, None, None]).astype(jnp.float64)
        return jnp.abs(pred - labels[None])

    return matrix


def run(rows=None):
    rows = [] if rows is None else rows
    pop_mat = _loss_matrix(jax.random.PRNGKey(999), M_AGENTS, 50_000, C)
    pop = np.asarray(pop_mat(jnp.arange(C))).mean(axis=(1, 2))
    for n in (50, 200, 800):
        mat = _loss_matrix(jax.random.PRNGKey(0), M_AGENTS, n, C)
        emp = np.asarray(mat(jnp.arange(C))).mean(axis=(1, 2))
        rad = float(
            empirical_rademacher(
                mat, C, M_AGENTS, n, jax.random.PRNGKey(1), num_mc=256
            )
        )
        vc_ub = lemma3_vc_bound([1.0] * M_AGENTS, n, vc_dim=1)
        gap = float(np.max(pop - emp))
        bound_margin = theorem2_bound(
            empirical_risk=0.0, rademacher=rad, M_i=[1.0] * M_AGENTS,
            n=n, cover_size=1, delta=DELTA, L_y=0.0, eps=0.0,
        )
        rows.append(
            {
                "n_per_agent": n,
                "measured_sup_gap": f"{gap:.4f}",
                "thm2_margin(2R+conc)": f"{bound_margin:.4f}",
                "rademacher_mc": f"{rad:.4f}",
                "lemma3_vc_upper": f"{vc_ub:.4f}",
                "bound_holds": bool(gap <= bound_margin),
            }
        )
    emit(
        rows,
        [
            "n_per_agent",
            "measured_sup_gap",
            "thm2_margin(2R+conc)",
            "rademacher_mc",
            "lemma3_vc_upper",
            "bound_holds",
        ],
        "generalization: Theorem-2 bound vs measured gap (threshold class)",
    )
    return rows


if __name__ == "__main__":
    run()
