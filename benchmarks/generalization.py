"""Section 4 — generalization bound vs the measured generalization gap.

Finite threshold-classifier class over heterogeneous per-agent Gaussians:
as the per-agent sample size n grows, both the Theorem-2 bound and the
measured sup_x |R - f| must decay ~ 1/sqrt(n), with the bound above the
measurement.  Also reports the Lemma-3 VC upper bound on the Rademacher
complexity next to the Monte-Carlo estimate.

The second table tracks the MEASURED generalization gap of trained
iterates for the stochastic strategy family — strategy x noise x
Dirichlet heterogeneity on the held-out-split quadratic game
(`problems.quadratic.make_dirichlet_quadratic_problem`): rounds-to-eps
against the closed-form minimax point next to the final train/test risk
gap (`core.generalization.generalization_gap`).  `--check` gates the
claims the table keeps making (SAGDA's noiseless degeneration converges
linearly at both heterogeneity levels; plain Local SGDA stalls at its
drift floor under strong heterogeneity; every gap stays bounded)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    empirical_rademacher,
    generalization_gap,
    lemma3_vc_bound,
    theorem2_bound,
)

from .common import emit

M_AGENTS, C = 6, 64
DELTA = 0.05


def _loss_matrix(key, m, n, num_candidates):
    kd, _ = jax.random.split(key)
    shifts = 0.3 * jnp.arange(m, dtype=jnp.float64)
    xi = jax.random.normal(kd, (m, n), jnp.float64) + shifts[:, None]
    labels = (xi > 0.0).astype(jnp.float64)
    ths = jnp.linspace(-2.0, 2.0, num_candidates)

    def matrix(idx):
        pred = (xi[None] > ths[idx][:, None, None]).astype(jnp.float64)
        return jnp.abs(pred - labels[None])

    return matrix


def run(rows=None):
    rows = [] if rows is None else rows
    pop_mat = _loss_matrix(jax.random.PRNGKey(999), M_AGENTS, 50_000, C)
    pop = np.asarray(pop_mat(jnp.arange(C))).mean(axis=(1, 2))
    for n in (50, 200, 800):
        mat = _loss_matrix(jax.random.PRNGKey(0), M_AGENTS, n, C)
        emp = np.asarray(mat(jnp.arange(C))).mean(axis=(1, 2))
        rad = float(
            empirical_rademacher(
                mat, C, M_AGENTS, n, jax.random.PRNGKey(1), num_mc=256
            )
        )
        vc_ub = lemma3_vc_bound([1.0] * M_AGENTS, n, vc_dim=1)
        gap = float(np.max(pop - emp))
        bound_margin = theorem2_bound(
            empirical_risk=0.0, rademacher=rad, M_i=[1.0] * M_AGENTS,
            n=n, cover_size=1, delta=DELTA, L_y=0.0, eps=0.0,
        )
        rows.append(
            {
                "n_per_agent": n,
                "measured_sup_gap": f"{gap:.4f}",
                "thm2_margin(2R+conc)": f"{bound_margin:.4f}",
                "rademacher_mc": f"{rad:.4f}",
                "lemma3_vc_upper": f"{vc_ub:.4f}",
                "bound_holds": bool(gap <= bound_margin),
            }
        )
    emit(
        rows,
        [
            "n_per_agent",
            "measured_sup_gap",
            "thm2_margin(2R+conc)",
            "rademacher_mc",
            "lemma3_vc_upper",
            "bound_holds",
        ],
        "generalization: Theorem-2 bound vs measured gap (threshold class)",
    )
    return rows


# --------------------------------------------------------------------------
# stochastic family: strategy x noise x heterogeneity on the held-out split
# --------------------------------------------------------------------------
S_DIM, S_N, S_M, S_ALPHAS = 12, 60, 6, (0.1, 100.0)
S_ETA, S_K, S_ROUNDS, S_EPS = 0.02, 4, 600, 1e-2
S_SIGMA = 0.05
#: --check bounds, ~2x the measured values so benign jitter passes but a
#: regression in the stochastic engine path (noise folds, momentum
#: steps, SAGDA corrections) trips the gate
CHECK_MAX_SAGDA_ROUNDS = {0.1: 300, 100.0: 300}
CHECK_MAX_ABS_GAP = 3.5


def _stoch_strategies(noise_name):
    from repro.fed import SAGDA, LocalSGDAPlus
    from repro.fed.noise import GaussianNoise

    nz = (
        {"noise": GaussianNoise(sigma=S_SIGMA)}
        if noise_name == "gaussian"
        else {}
    )
    return [
        ("local_sgda", LocalSGDAPlus(momentum=0.0, **nz)),
        ("local_sgda_plus", LocalSGDAPlus(momentum=0.9, **nz)),
        ("sagda", SAGDA(**nz)),
    ]


def _stoch_one(prob, strategy, x_star, y_star):
    from repro.core.engine import make_round, run_strategy_rounds

    rnd = make_round(
        prob.loss, strategy, S_K, S_ETA, explicit_state=True
    )
    x0 = jnp.zeros(S_DIM, jnp.float64)
    state0 = strategy.init_state(x0, x0, prob.num_agents)

    def metric(x, y):
        return {
            "dist": jnp.sqrt(
                jnp.sum((x - x_star) ** 2) + jnp.sum((y - y_star) ** 2)
            )
        }

    (x, y, _), metrics = run_strategy_rounds(
        rnd, x0, x0, prob.agent_data, S_ROUNDS, state0, metric
    )
    dist = np.asarray(metrics["dist"])
    hit = np.nonzero(dist <= S_EPS)[0]
    return (
        float(hit[0]) if hit.size else math.inf,
        float(dist[-1]),
        x,
        y,
    )


def stochastic_rows(rows=None):
    from repro.data import heterogeneity_index
    from repro.problems import (
        make_dirichlet_quadratic_problem,
        quadratic_minimax_point,
    )

    jax.config.update("jax_enable_x64", True)
    rows = [] if rows is None else rows
    for alpha in S_ALPHAS:
        prob, test_data, w = make_dirichlet_quadratic_problem(
            jax.random.PRNGKey(7), dim=S_DIM, num_samples=S_N,
            num_agents=S_M, alpha=alpha, test_samples=S_N,
        )
        het = float(heterogeneity_index(w))
        x_star, y_star = quadratic_minimax_point(prob)
        gap_fn = jax.jit(generalization_gap(prob.loss, prob.agent_data, test_data))
        for noise_name in ("none", "gaussian"):
            for name, strategy in _stoch_strategies(noise_name):
                r_eps, final, x, y = _stoch_one(prob, strategy, x_star, y_star)
                rows.append(
                    {
                        "strategy": name,
                        "noise": noise_name,
                        "alpha": f"{alpha:g}",
                        "het_index": f"{het:.3f}",
                        f"rounds_to_{S_EPS:g}": (
                            "inf" if math.isinf(r_eps) else int(r_eps)
                        ),
                        "final_dist": f"{final:.2e}",
                        "gen_gap": f"{float(gap_fn(x, y)):+.4f}",
                        "_r_eps": r_eps,
                        "_gap": float(gap_fn(x, y)),
                        "_alpha": alpha,
                    }
                )
    emit(
        rows,
        [
            "strategy",
            "noise",
            "alpha",
            "het_index",
            f"rounds_to_{S_EPS:g}",
            "final_dist",
            "gen_gap",
        ],
        "generalization: stochastic family — strategy x noise x "
        "Dirichlet(alpha), rounds-to-eps + measured gen gap",
    )
    return rows


def check() -> int:
    """CI gate over the stochastic table's standing claims.  Returns
    the number of violations (0 = gate holds):

      1. noiseless SAGDA (bitwise FedGDA-GT) reaches eps within the
         pinned round budget at BOTH heterogeneity levels — the linear
         noiseless component of the stochastic engine path;
      2. noiseless plain Local SGDA under strong heterogeneity
         (alpha=0.1) never reaches eps — the drift floor the paper's
         separation rests on (if this starts converging, eps/eta/K
         drifted and the table stopped demonstrating the claim);
      3. every measured generalization gap stays within the pinned cap
         (a blown-up gap means the trained iterates diverged)."""
    rows = stochastic_rows()
    by = {(r["strategy"], r["noise"], r["_alpha"]): r for r in rows}
    bad = 0
    for alpha in S_ALPHAS:
        r = by[("sagda", "none", alpha)]["_r_eps"]
        ok = r <= CHECK_MAX_SAGDA_ROUNDS[alpha]
        bad += not ok
        print(
            f"[{'ok' if ok else 'FAIL'}] sagda/none alpha={alpha:g}: "
            f"rounds={r} (max {CHECK_MAX_SAGDA_ROUNDS[alpha]})"
        )
    r = by[("local_sgda", "none", 0.1)]["_r_eps"]
    ok = math.isinf(r)
    bad += not ok
    print(
        f"[{'ok' if ok else 'FAIL'}] local_sgda/none alpha=0.1 stalls: "
        f"rounds={r} (expected inf)"
    )
    for r in rows:
        ok = abs(r["_gap"]) <= CHECK_MAX_ABS_GAP
        bad += not ok
        if not ok:
            print(
                f"[FAIL] gap blow-up: {r['strategy']}/{r['noise']}"
                f"/alpha={r['alpha']}: {r['_gap']:+.4f}"
            )
    print(f"# gen-gap cap |gap| <= {CHECK_MAX_ABS_GAP}: "
          f"{'ok' if all(abs(r['_gap']) <= CHECK_MAX_ABS_GAP for r in rows) else 'FAIL'}")
    return bad


def run_all():
    """Both tables: the Theorem-2 bound table and the stochastic-family
    gap table (each emits separately — different columns)."""
    return run() + stochastic_rows()


def check_gate():
    """`benchmarks.run` entry: raise instead of returning a count so the
    driver's suite loop stops with a non-zero exit on violation."""
    bad = check()
    if bad:
        raise SystemExit(f"generalization --check: {bad} violation(s)")


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--check",
        action="store_true",
        help="gate the stochastic table's claims (SAGDA linear rounds, "
        "Local SGDA drift floor, bounded gen gaps); exits non-zero on "
        "violation",
    )
    args = ap.parse_args()
    if args.check:
        sys.exit(1 if check() else 0)
    run()
    stochastic_rows()
