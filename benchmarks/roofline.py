"""Roofline analysis (deliverable g) — three terms per (arch x shape x mesh),
derived from the dry-run artifacts in experiments/dryrun/.

  compute   = executed_dot_flops / peak_flops          [census, exact trip-scaled]
  memory    = analytic streaming bytes / HBM bandwidth [documented model below]
  collective= traffic-weighted executed collective bytes / ICI link bw

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

The census numbers come from the partitioned (per-device) HLO with while
bodies scaled by their known_trip_count (repro.launch.hlo_census), so the
compute and collective terms are per-chip executed quantities.  The memory
term is analytic: XLA's "bytes accessed" has the same scan-body-once issue
and double-counts fusion-internal traffic, so we model HBM streaming
explicitly:

  train   : (K+1) grad evals x 3 passes over the local param shard
            (fwd read, bwd read, grad write) + 2 update passes
            + activation traffic 12 bytes/elem x T_chip x d x L_eff
  prefill : 2 passes over param shard + activations + KV-cache write
  decode  : 1 pass over ACTIVE param shard + full KV-cache read per token

Collective traffic factors (ring algorithms, result-shape census):
  all-reduce 2x, all-gather/reduce-scatter/all-to-all/permute 1x.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, INPUT_SHAPES
from repro.models import init_params

from .common import emit

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
BYTES = 2  # bf16

TRAFFIC_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_PARAM_CACHE: Dict[str, Dict[str, float]] = {}


def param_counts(arch: str) -> Dict[str, float]:
    """Exact total and ACTIVE (top-k experts only) parameter counts."""
    if arch in _PARAM_CACHE:
        return _PARAM_CACHE[arch]
    cfg = ARCHS[arch]
    tree = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    )
    total = 0
    active = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        keys = [getattr(p, "key", "") for p in path]
        if cfg.num_experts and "moe" in keys and keys[-1] in ("gate", "up", "down"):
            active += n * cfg.top_k / cfg.num_experts
        else:
            active += n
    _PARAM_CACHE[arch] = {"total": float(total), "active": float(active)}
    return _PARAM_CACHE[arch]


def _mesh_dims(mesh: str) -> Dict[str, int]:
    if mesh == "16x16":
        return {"chips": 256, "data": 16, "model": 16, "pod": 1}
    return {"chips": 512, "data": 16, "model": 16, "pod": 2}


def _shards(cfg, md) -> Dict[str, int]:
    """How many ways params are sharded / how many agents (DESIGN.md §4)."""
    if cfg.fed_mode == "A":
        m = md["data"] * md["pod"]
        param_shards = md["model"]
    else:  # B: agents over pod; experts+model sharded over (data, model)
        m = md["pod"]
        param_shards = md["data"] * md["model"]
    return {"agents": m, "param_shards": param_shards}


def analytic_memory_bytes(rec: Dict, cfg, counts) -> float:
    """Streaming HBM bytes per chip per step (model in module docstring)."""
    md = _mesh_dims(rec["mesh"])
    sh = _shards(cfg, md)
    shape = INPUT_SHAPES[rec["shape"]]
    p_shard = counts["total"] * BYTES / sh["param_shards"]
    p_shard_active = counts["active"] * BYTES / sh["param_shards"]
    L = cfg.num_layers
    d = cfg.d_model
    if rec["kind"] == "train":
        K = rec.get("num_local_steps") or 4
        t_chip = shape.global_batch * shape.seq_len / md["chips"]
        act = 12.0 * t_chip * d * L
        return (K + 1) * (3.0 * p_shard_active) + 2.0 * p_shard + act
    if rec["kind"] == "prefill":
        t_chip = shape.global_batch * shape.seq_len / md["chips"]
        kv = 2.0 * t_chip * cfg.num_kv_heads * cfg.head_dim * L * BYTES
        act = 8.0 * t_chip * d * L
        return 2.0 * p_shard_active + act + kv
    # decode: one token; full KV (or SSM state) read dominates
    b_chip = max(1.0, shape.global_batch / (md["data"] * md["pod"]))
    kv_bytes = 0.0
    for kind in cfg.layer_types:
        if kind in ("attn", "moe"):
            kv_bytes += 2 * shape.seq_len * cfg.num_kv_heads * cfg.head_dim * BYTES
        elif kind == "local":
            kv_bytes += (
                2 * min(shape.seq_len, cfg.sliding_window)
                * cfg.num_kv_heads * cfg.head_dim * BYTES
            )
        else:  # ssm: O(1) recurrent state
            kv_bytes += (cfg.d_inner * max(cfg.ssm_state, 1) * 4)
    if cfg.shared_attn_every:
        n_shared = cfg.num_layers // cfg.shared_attn_every
        kv_bytes += n_shared * 2 * shape.seq_len * cfg.num_kv_heads * cfg.head_dim * BYTES
    kv_bytes /= md["model"]  # KV heads / state sharded over model axis
    return p_shard_active + b_chip * kv_bytes


def model_flops(rec: Dict, counts) -> float:
    """'Useful' FLOPs per chip: 6 N_active D (train) / 2 N_active D (serve)."""
    md = _mesh_dims(rec["mesh"])
    shape = INPUT_SHAPES[rec["shape"]]
    n_act = counts["active"]
    if rec["kind"] == "train":
        K = rec.get("num_local_steps") or 4
        d_tokens = shape.global_batch * shape.seq_len * K
        return 6.0 * n_act * d_tokens / md["chips"]
    if rec["kind"] == "prefill":
        return 2.0 * n_act * shape.global_batch * shape.seq_len / md["chips"]
    return 2.0 * n_act * shape.global_batch / md["chips"]


def collective_seconds(census: Dict) -> float:
    total = 0.0
    for kind, s in census.get("collectives_executed", {}).items():
        total += TRAFFIC_FACTOR.get(kind, 1.0) * s["bytes"]
    return total / ICI_BW


def suggestion(dom: str, rec: Dict, cfg) -> str:
    if dom == "collective":
        if rec["kind"] == "train":
            return (
                "shard params over fewer model ways / keep agent copies "
                "resident to remove in-loop all-gathers"
            )
        return "reduce tensor-parallel degree or overlap collectives with compute"
    if dom == "memory":
        if rec["kind"] == "decode":
            return "quantize KV cache / shrink active params per token (batch more)"
        return "increase per-chip batch or cut activation traffic (better fusion)"
    return "compute-bound: raise MFU via larger MXU-aligned tiles / less remat"


def analyze(rec: Dict) -> Optional[Dict]:
    cfg = ARCHS[rec["arch"]]
    counts = param_counts(rec["arch"])
    census = rec.get("census") or {}
    flops_exec = census.get("executed_dot_flops")
    if flops_exec is None:
        return None
    t_comp = flops_exec / PEAK_FLOPS
    t_mem = analytic_memory_bytes(rec, cfg, counts) / HBM_BW
    t_coll = collective_seconds(census)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec, counts)
    bound = max(terms.values())
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "compute_s": f"{t_comp:.4e}",
        "memory_s": f"{t_mem:.4e}",
        "collective_s": f"{t_coll:.4e}",
        "dominant": dom,
        "model_flops": f"{mf:.3e}",
        "useful_ratio": f"{mf / max(flops_exec, 1.0):.3f}",
        "roofline_frac": f"{(mf / PEAK_FLOPS) / max(bound, 1e-12):.3f}",
        "fix": suggestion(dom, rec, cfg),
    }


HEADER = [
    "arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
    "dominant", "model_flops", "useful_ratio", "roofline_frac", "fix",
]


def run(rows=None, dryrun_dir: str = "experiments/dryrun", meshes=("16x16",)):
    rows = [] if rows is None else rows
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if rec["mesh"] not in meshes:
            continue
        if rec.get("algorithm") not in (None, "fedgda_gt"):
            continue
        row = analyze(rec)
        if row:
            rows.append(row)
    emit(rows, HEADER, f"roofline terms per (arch x shape), mesh={','.join(meshes)}")

    # the §Perf optimized variants, when present (experiments/perf2)
    opt_rows = []
    for path in sorted(glob.glob("experiments/perf2/*.json")):
        rec = json.load(open(path))
        if rec["mesh"] not in meshes:
            continue
        row = analyze(rec)
        if row:
            tags = os.path.basename(path).split("__")[3:]
            row["arch"] = row["arch"] + " [" + "+".join(t.removesuffix(".json") for t in tags) + "]"
            opt_rows.append(row)
    if opt_rows:
        emit(opt_rows, HEADER, "roofline terms, §Perf OPTIMIZED variants")
        rows.extend(opt_rows)
    return rows


if __name__ == "__main__":
    import sys

    meshes = ("16x16", "2x16x16") if "--all-meshes" in sys.argv else ("16x16",)
    run(meshes=meshes)
