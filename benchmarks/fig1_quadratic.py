"""Paper Figure 1 — uncoupled quadratic game (Eq. 13).

d=50, n_i=500, m=20 agents, eta=1e-4 (the paper's own setup);
Local SGDA vs FedGDA-GT at K in {20, 50}, centralized GDA (K=1) baseline.
Reports the optimality gap ||x-x*||^2 + ||y-y*||^2 after T rounds and the
number of rounds to reach gap <= 1e-6 (inf if the bias floor is above it).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    make_fedgda_gt_round,
    make_local_sgda_round,
    run_rounds,
    tree_sq_dist,
)
from repro.problems import make_quadratic_problem, quadratic_minimax_point

from .common import emit

ETA = 1e-4
T = 3000


def rounds_to(gaps: np.ndarray, eps: float) -> float:
    hit = np.nonzero(gaps <= eps)[0]
    return float(hit[0]) if hit.size else math.inf


def run(rows=None):
    jax.config.update("jax_enable_x64", True)
    prob = make_quadratic_problem(
        jax.random.PRNGKey(0), dim=50, num_samples=500, num_agents=20
    )
    xs, ys = quadratic_minimax_point(prob)

    def metric(x, y):
        return {"gap": tree_sq_dist(x, xs) + tree_sq_dist(y, ys)}

    x0 = jnp.zeros(50)
    algos = [("gda(K=1)", make_local_sgda_round(prob.loss, 1, ETA, ETA))]
    for K in (20, 50):
        algos.append(
            (f"local_sgda(K={K})", make_local_sgda_round(prob.loss, K, ETA, ETA))
        )
        algos.append((f"fedgda_gt(K={K})", make_fedgda_gt_round(prob.loss, K, ETA)))

    rows = [] if rows is None else rows
    for name, rnd in algos:
        (_, _), m = run_rounds(jax.jit(rnd), x0, x0, prob.agent_data, T, metric)
        gaps = np.asarray(m["gap"])
        rows.append(
            {
                "algorithm": name,
                "final_gap": f"{gaps[-1]:.3e}",
                "rounds_to_1e-6": rounds_to(gaps, 1e-6),
                "rounds_to_1e-10": rounds_to(gaps, 1e-10),
            }
        )
    emit(
        rows,
        ["algorithm", "final_gap", "rounds_to_1e-6", "rounds_to_1e-10"],
        "fig1: uncoupled quadratic game (d=50, m=20, eta=1e-4)",
    )
    return rows


if __name__ == "__main__":
    run()
