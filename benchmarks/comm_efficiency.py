"""Communication efficiency — the paper's headline claim, quantified.

For the Section-5.1 quadratic game: rounds and total exchanged bytes
(star-topology cost model, Section 3) to reach optimality gap <= eps for
centralized GDA (communicates every step), Local SGDA, FedGDA-GT, and the
scenario strategies (client sampling, sparsified corrections with error
feedback, stochastically quantized corrections at 8 bit and at 4 bit
composed with top-10% sparsification).  Per-round payloads are
strategy-derived (`CommStrategy.bytes_per_round`): FedGDA-GT pays 2x
Local SGDA per round but reaches eps in O(log 1/eps) rounds; Local SGDA
never reaches tight eps at all (bias floor); the compressed / partial /
quantized variants land in between — cheaper rounds, noise-floored
accuracy (the quantizer is unbiased, so its floor is the tightest)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_round, run_strategy_rounds, tree_sq_dist
from repro.fed import (
    CompressedGT,
    FullSync,
    GradientTracking,
    LocalOnly,
    PartialParticipation,
    QuantizedGT,
    comm_table,
)
from repro.problems import make_quadratic_problem, quadratic_minimax_point

from .common import emit

ETA, K, T = 1e-4, 20, 3000
EPS = 1e-8


def run(rows=None):
    jax.config.update("jax_enable_x64", True)
    prob = make_quadratic_problem(
        jax.random.PRNGKey(0), dim=50, num_samples=500, num_agents=20
    )
    xs, ys = quadratic_minimax_point(prob)

    def metric(x, y):
        return {"gap": tree_sq_dist(x, xs) + tree_sq_dist(y, ys)}

    x0 = jnp.zeros(50)
    m = jax.tree.leaves(prob.agent_data)[0].shape[0]
    runs = {
        "gda": (FullSync(), 1),
        "local_sgda": (LocalOnly(), K),
        "fedgda_gt": (GradientTracking(), K),
        "partial_gt_50": (PartialParticipation(participation=0.5, seed=0), K),
        "compressed_gt_10": (CompressedGT(compression_ratio=0.1), K),
        "quantized_gt_8bit": (QuantizedGT(bits=8), K),
        "quantized_gt_4bit_top10": (QuantizedGT(bits=4, ratio=0.1), K),
    }
    rounds_to_eps = {}
    strategies = {}
    for name, (strategy, k) in runs.items():
        # give GDA the same gradient-step budget: T*K single-step rounds
        T_eff = T * K if name == "gda" else T
        # explicit_state works for stateless strategies too (state is {})
        rnd = jax.jit(make_round(prob.loss, strategy, k, ETA, explicit_state=True))
        (_, _, _), mtr = run_strategy_rounds(
            rnd, x0, x0, prob.agent_data, T_eff, strategy.init_state(x0, x0, m), metric
        )
        gaps = np.asarray(mtr["gap"])
        hit = np.nonzero(gaps <= EPS)[0]
        rounds_to_eps[strategy] = float(hit[0]) if hit.size else math.inf
        strategies[strategy] = name

    table = comm_table(x0, x0, K, rounds_to_eps)
    rows = [] if rows is None else rows
    # comm_table preserves insertion order and suffixes duplicate names
    # (two quantized_gt configs), so pair rows by order, not by name
    for (strategy, name), entry in zip(strategies.items(), table.values()):
        rows.append(
            {
                "algorithm": name,
                "bytes_per_round": int(entry["bytes_per_round"]),
                f"rounds_to_{EPS:g}": entry["rounds_to_eps"],
                "total_bytes": entry["total_bytes"],
            }
        )
    emit(
        rows,
        ["algorithm", "bytes_per_round", f"rounds_to_{EPS:g}", "total_bytes"],
        f"communication to reach gap<={EPS:g} (quadratic game, K={K})",
    )
    return rows


if __name__ == "__main__":
    run()
