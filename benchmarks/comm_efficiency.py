"""Communication efficiency — the paper's headline claim, quantified.

For the Section-5.1 quadratic game: rounds and total exchanged bytes
(star-topology cost model, Section 3) to reach optimality gap <= eps for
centralized GDA (communicates every step), Local SGDA, FedGDA-GT, and the
scenario strategies (client sampling, sparsified corrections with error
feedback, stochastically quantized corrections at 8 bit and at 4 bit
composed with top-10% sparsification).  Per-round payloads are
strategy-derived (`CommStrategy.bytes_per_round`), and every row now also
reports the MEASURED per-round bytes — the actual packed wire buffers of
`repro.fed.transport` (the compressed strategies run with
wire_transport=True, so the traffic the table describes is the traffic
the round moves).  FedGDA-GT pays 2x Local SGDA per round but reaches eps
in O(log 1/eps) rounds; Local SGDA never reaches tight eps at all (bias
floor); the compressed / partial / quantized variants land in between —
cheaper rounds, noise-floored accuracy (the quantizer is unbiased, so its
floor is the tightest).

`--check` skips the convergence runs and only audits the accounting:
non-zero exit when measured packed payload bytes (headers excluded —
they are fixed and accounted separately) exceed priced bytes by > 5%,
so price/wire drift fails CI instead of shipping.

`--overlap` times one round of every strategy under BOTH runtimes — the
fused single-program `FederatedRunner` and the phase-dispatched
`AsyncFederatedRunner` (per-agent-shard programs on separate devices,
exchange overlapped with trailing local steps) — and reports the
wall-clock per round side by side.  Run it under
XLA_FLAGS=--xla_force_host_platform_device_count=8 (set automatically
when no device-count flag is present) so the shards have devices to
land on."""
from __future__ import annotations

import argparse
import math
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_round, run_strategy_rounds, tree_sq_dist
from repro.fed import (
    AsyncFederatedRunner,
    CompressedGT,
    FederatedRunner,
    FullSync,
    GradientTracking,
    LocalOnly,
    PartialParticipation,
    QuantizedGT,
    comm_table,
)
from repro.problems import make_quadratic_problem, quadratic_minimax_point

from .common import emit

ETA, K, T = 1e-4, 20, 3000
EPS = 1e-8
DIM = 50
CHECK_TOL = 0.05  # measured may exceed priced by at most 5% (headers)


def _runs():
    return {
        "gda": (FullSync(), 1),
        "local_sgda": (LocalOnly(), K),
        "fedgda_gt": (GradientTracking(), K),
        "partial_gt_50": (PartialParticipation(participation=0.5, seed=0), K),
        "compressed_gt_10": (
            CompressedGT(compression_ratio=0.1, wire_transport=True),
            K,
        ),
        "quantized_gt_8bit": (QuantizedGT(bits=8, wire_transport=True), K),
        "quantized_gt_4bit_top10": (
            QuantizedGT(bits=4, ratio=0.1, wire_transport=True),
            K,
        ),
    }


def check(tol: float = CHECK_TOL) -> int:
    """Audit priced vs measured bytes without running any training.
    Returns the number of drifting strategies (0 = accounting holds).
    The probe excludes the fixed per-leaf wire headers, so the whole
    `tol` is real drift margin — a shrinking model cannot eat the gate
    with header share, and real pricing drift cannot hide under it."""
    from repro.fed import measured_bytes_per_round

    jax.config.update("jax_enable_x64", True)  # the model run() audits
    x0 = jnp.zeros(DIM)
    bad = 0
    for name, (strategy, _) in _runs().items():
        priced = strategy.bytes_per_round(x0, x0, K)
        payload = measured_bytes_per_round(
            strategy, x0, x0, K, include_headers=False
        )
        drift = payload / priced - 1.0
        # two-sided: underpricing (measured > priced) AND overpricing
        # (priced > measured) both count as accounting drift
        ok = abs(drift) <= tol
        bad += not ok
        print(
            f"[{'ok' if ok else 'DRIFT'}] {name}: priced={priced} "
            f"measured_payload={payload} ({drift:+.2%})"
        )
    return bad


def overlap(rows=None, rounds: int = 20, dim: int = 200):
    """Wall-clock per round, sync vs async runtime, per strategy.

    The async column buys its overlap from per-shard dispatch: while one
    shard still runs trailing local steps, the others' partial
    aggregates and the next round's broadcast transfers are already in
    flight.  FullSync is the anti-case — K communicated steps leave
    nothing to overlap, so its async round pays pure dispatch overhead.

    Read the column for what it is: on EMULATED host devices every shard
    shares the same silicon, so the async number is dominated by the
    per-shard dispatch + transfer overhead the schedule adds (the fused
    sync round is one XLA call).  On real multi-chip hardware that
    overhead is what the overlap hides behind agents' local compute; the
    per-round delta reported here is the budget the overlap has to beat,
    measured per strategy."""
    # best effort: emulate 8 host devices if the backend has not
    # initialized yet (a no-op once any suite has touched jax)
    if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""
    ):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8"
        ).strip()
    jax.config.update("jax_enable_x64", True)
    if len(jax.devices()) < 2:
        # the env nudge above lost: another suite initialized the
        # backend first (e.g. `-m benchmarks.run` runs `comm` before
        # `overlap`).  Say so rather than publish a 1-shard "async" row.
        print(
            "# WARNING: only 1 device visible — async degenerates to one "
            "shard; run `python -m benchmarks.comm_efficiency --overlap` "
            "standalone (or set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8) for a "
            "meaningful comparison"
        )
    prob = make_quadratic_problem(
        jax.random.PRNGKey(0), dim=dim, num_samples=500, num_agents=8
    )
    x0 = jnp.zeros(dim)
    rows = [] if rows is None else rows
    for name, (strategy, k) in _runs().items():

        def _time(runner_run):
            runner_run(2)  # warm the compile caches
            t0 = time.perf_counter()
            runner_run(rounds)
            return (time.perf_counter() - t0) / rounds * 1e3

        sr = FederatedRunner.from_strategy(
            prob.loss, strategy, prob.agent_data, k, ETA
        )
        sync_ms = _time(lambda T: sr.run(x0, x0, T))
        ar = AsyncFederatedRunner(prob.loss, strategy, prob.agent_data, k, ETA)
        async_ms = _time(lambda T: ar.run(x0, x0, T))
        rows.append(
            {
                "algorithm": name,
                "sync_round_ms": f"{sync_ms:.2f}",
                "async_round_ms": f"{async_ms:.2f}",
                "async_vs_sync": f"{sync_ms / async_ms:.2f}x",
                "shards": ar._n_shards,
            }
        )
    emit(
        rows,
        ["algorithm", "sync_round_ms", "async_round_ms", "async_vs_sync",
         "shards"],
        f"wall-clock round latency, sync vs async runtime "
        f"({len(jax.devices())} emulated devices share one host — the "
        f"async column is the dispatch budget the overlap must beat; "
        f"K={K})",
    )
    return rows


def run(rows=None):
    jax.config.update("jax_enable_x64", True)
    prob = make_quadratic_problem(
        jax.random.PRNGKey(0), dim=DIM, num_samples=500, num_agents=20
    )
    xs, ys = quadratic_minimax_point(prob)

    def metric(x, y):
        return {"gap": tree_sq_dist(x, xs) + tree_sq_dist(y, ys)}

    x0 = jnp.zeros(DIM)
    m = jax.tree.leaves(prob.agent_data)[0].shape[0]
    runs = _runs()
    rounds_to_eps = {}
    strategies = {}
    for name, (strategy, k) in runs.items():
        # give GDA the same gradient-step budget: T*K single-step rounds
        T_eff = T * K if name == "gda" else T
        # explicit_state works for stateless strategies too (state is {})
        rnd = jax.jit(make_round(prob.loss, strategy, k, ETA, explicit_state=True))
        (_, _, _), mtr = run_strategy_rounds(
            rnd, x0, x0, prob.agent_data, T_eff, strategy.init_state(x0, x0, m), metric
        )
        gaps = np.asarray(mtr["gap"])
        hit = np.nonzero(gaps <= EPS)[0]
        rounds_to_eps[strategy] = float(hit[0]) if hit.size else math.inf
        strategies[strategy] = name

    table = comm_table(x0, x0, K, rounds_to_eps)
    rows = [] if rows is None else rows
    # comm_table preserves insertion order and keys colliding base names
    # by their full knob signature (two quantized_gt configs), so pair
    # rows by order, not by name
    for (strategy, name), entry in zip(strategies.items(), table.values()):
        rows.append(
            {
                "algorithm": name,
                "bytes_per_round": int(entry["bytes_per_round"]),
                "measured_bytes_per_round": int(
                    entry["measured_bytes_per_round"]
                ),
                f"rounds_to_{EPS:g}": entry["rounds_to_eps"],
                "total_bytes": entry["total_bytes"],
            }
        )
    emit(
        rows,
        [
            "algorithm",
            "bytes_per_round",
            "measured_bytes_per_round",
            f"rounds_to_{EPS:g}",
            "total_bytes",
        ],
        f"communication to reach gap<={EPS:g} (quadratic game, K={K})",
    )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--check",
        action="store_true",
        help="audit measured packed bytes against the analytic price "
        f"(> {CHECK_TOL:.0%} drift exits non-zero); skips training",
    )
    ap.add_argument(
        "--overlap",
        action="store_true",
        help="time sync vs async round latency per strategy "
        "(8 emulated host devices unless XLA_FLAGS already set)",
    )
    args = ap.parse_args()
    if args.check:
        sys.exit(1 if check() else 0)
    if args.overlap:
        overlap()
        sys.exit(0)
    run()
