"""Communication efficiency — the paper's headline claim, quantified.

For the Section-5.1 quadratic game: rounds and total exchanged bytes
(star-topology cost model, Section 3) to reach optimality gap <= eps for
centralized GDA (communicates every step), Local SGDA, FedGDA-GT, and the
scenario strategies (client sampling, sparsified corrections with error
feedback, stochastically quantized corrections at 8 bit and at 4 bit
composed with top-10% sparsification).  Per-round payloads are
strategy-derived (`CommStrategy.bytes_per_round`), and every row now also
reports the MEASURED per-round bytes — the actual packed wire buffers of
`repro.fed.transport` (the compressed strategies run with
wire_transport=True, so the traffic the table describes is the traffic
the round moves).  FedGDA-GT pays 2x Local SGDA per round but reaches eps
in O(log 1/eps) rounds; Local SGDA never reaches tight eps at all (bias
floor); the compressed / partial / quantized variants land in between —
cheaper rounds, noise-floored accuracy (the quantizer is unbiased, so its
floor is the tightest).

`--check` skips the convergence runs and only audits the accounting:
non-zero exit when measured packed payload bytes (headers excluded —
they are fixed and accounted separately) exceed priced bytes by > 5%,
so price/wire drift fails CI instead of shipping."""
from __future__ import annotations

import argparse
import math
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import make_round, run_strategy_rounds, tree_sq_dist
from repro.fed import (
    CompressedGT,
    FullSync,
    GradientTracking,
    LocalOnly,
    PartialParticipation,
    QuantizedGT,
    comm_table,
)
from repro.problems import make_quadratic_problem, quadratic_minimax_point

from .common import emit

ETA, K, T = 1e-4, 20, 3000
EPS = 1e-8
DIM = 50
CHECK_TOL = 0.05  # measured may exceed priced by at most 5% (headers)


def _runs():
    return {
        "gda": (FullSync(), 1),
        "local_sgda": (LocalOnly(), K),
        "fedgda_gt": (GradientTracking(), K),
        "partial_gt_50": (PartialParticipation(participation=0.5, seed=0), K),
        "compressed_gt_10": (
            CompressedGT(compression_ratio=0.1, wire_transport=True),
            K,
        ),
        "quantized_gt_8bit": (QuantizedGT(bits=8, wire_transport=True), K),
        "quantized_gt_4bit_top10": (
            QuantizedGT(bits=4, ratio=0.1, wire_transport=True),
            K,
        ),
    }


def check(tol: float = CHECK_TOL) -> int:
    """Audit priced vs measured bytes without running any training.
    Returns the number of drifting strategies (0 = accounting holds).
    The probe excludes the fixed per-leaf wire headers, so the whole
    `tol` is real drift margin — a shrinking model cannot eat the gate
    with header share, and real pricing drift cannot hide under it."""
    from repro.fed import measured_bytes_per_round

    jax.config.update("jax_enable_x64", True)  # the model run() audits
    x0 = jnp.zeros(DIM)
    bad = 0
    for name, (strategy, _) in _runs().items():
        priced = strategy.bytes_per_round(x0, x0, K)
        payload = measured_bytes_per_round(
            strategy, x0, x0, K, include_headers=False
        )
        drift = payload / priced - 1.0
        # two-sided: underpricing (measured > priced) AND overpricing
        # (priced > measured) both count as accounting drift
        ok = abs(drift) <= tol
        bad += not ok
        print(
            f"[{'ok' if ok else 'DRIFT'}] {name}: priced={priced} "
            f"measured_payload={payload} ({drift:+.2%})"
        )
    return bad


def run(rows=None):
    jax.config.update("jax_enable_x64", True)
    prob = make_quadratic_problem(
        jax.random.PRNGKey(0), dim=DIM, num_samples=500, num_agents=20
    )
    xs, ys = quadratic_minimax_point(prob)

    def metric(x, y):
        return {"gap": tree_sq_dist(x, xs) + tree_sq_dist(y, ys)}

    x0 = jnp.zeros(DIM)
    m = jax.tree.leaves(prob.agent_data)[0].shape[0]
    runs = _runs()
    rounds_to_eps = {}
    strategies = {}
    for name, (strategy, k) in runs.items():
        # give GDA the same gradient-step budget: T*K single-step rounds
        T_eff = T * K if name == "gda" else T
        # explicit_state works for stateless strategies too (state is {})
        rnd = jax.jit(make_round(prob.loss, strategy, k, ETA, explicit_state=True))
        (_, _, _), mtr = run_strategy_rounds(
            rnd, x0, x0, prob.agent_data, T_eff, strategy.init_state(x0, x0, m), metric
        )
        gaps = np.asarray(mtr["gap"])
        hit = np.nonzero(gaps <= EPS)[0]
        rounds_to_eps[strategy] = float(hit[0]) if hit.size else math.inf
        strategies[strategy] = name

    table = comm_table(x0, x0, K, rounds_to_eps)
    rows = [] if rows is None else rows
    # comm_table preserves insertion order and keys colliding base names
    # by their full knob signature (two quantized_gt configs), so pair
    # rows by order, not by name
    for (strategy, name), entry in zip(strategies.items(), table.values()):
        rows.append(
            {
                "algorithm": name,
                "bytes_per_round": int(entry["bytes_per_round"]),
                "measured_bytes_per_round": int(
                    entry["measured_bytes_per_round"]
                ),
                f"rounds_to_{EPS:g}": entry["rounds_to_eps"],
                "total_bytes": entry["total_bytes"],
            }
        )
    emit(
        rows,
        [
            "algorithm",
            "bytes_per_round",
            "measured_bytes_per_round",
            f"rounds_to_{EPS:g}",
            "total_bytes",
        ],
        f"communication to reach gap<={EPS:g} (quadratic game, K={K})",
    )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--check",
        action="store_true",
        help="audit measured packed bytes against the analytic price "
        f"(> {CHECK_TOL:.0%} drift exits non-zero); skips training",
    )
    args = ap.parse_args()
    if args.check:
        sys.exit(1 if check() else 0)
    run()
