"""Communication efficiency — the paper's headline claim, quantified.

For the Section-5.1 quadratic game: rounds and total exchanged bytes
(star-topology cost model, Section 3) to reach optimality gap <= eps for
centralized GDA (communicates every step), Local SGDA and FedGDA-GT.
FedGDA-GT pays 2x Local SGDA per round but reaches eps in O(log 1/eps)
rounds; Local SGDA never reaches tight eps at all (bias floor)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    communication_bytes_per_round,
    make_fedgda_gt_round,
    make_local_sgda_round,
    run_rounds,
    tree_sq_dist,
)
from repro.fed import comm_table
from repro.problems import make_quadratic_problem, quadratic_minimax_point

from .common import emit

ETA, K, T = 1e-4, 20, 3000
EPS = 1e-8


def run(rows=None):
    jax.config.update("jax_enable_x64", True)
    prob = make_quadratic_problem(
        jax.random.PRNGKey(0), dim=50, num_samples=500, num_agents=20
    )
    xs, ys = quadratic_minimax_point(prob)

    def metric(x, y):
        return {"gap": tree_sq_dist(x, xs) + tree_sq_dist(y, ys)}

    x0 = jnp.zeros(50)
    runs = {
        "gda": make_local_sgda_round(prob.loss, 1, ETA, ETA),
        "local_sgda": make_local_sgda_round(prob.loss, K, ETA, ETA),
        "fedgda_gt": make_fedgda_gt_round(prob.loss, K, ETA),
    }
    rounds_to_eps = {}
    for name, rnd in runs.items():
        # give GDA the same gradient-step budget: T*K single-step rounds
        T_eff = T * K if name == "gda" else T
        (_, _), m = run_rounds(
            jax.jit(rnd), x0, x0, prob.agent_data, T_eff, metric
        )
        gaps = np.asarray(m["gap"])
        hit = np.nonzero(gaps <= EPS)[0]
        rounds_to_eps[name] = float(hit[0]) if hit.size else math.inf

    table = comm_table(x0, x0, K, rounds_to_eps)
    rows = [] if rows is None else rows
    for algo, entry in table.items():
        rows.append(
            {
                "algorithm": algo,
                "bytes_per_round": int(entry["bytes_per_round"]),
                f"rounds_to_{EPS:g}": entry["rounds_to_eps"],
                "total_bytes": entry["total_bytes"],
            }
        )
    emit(
        rows,
        ["algorithm", "bytes_per_round", f"rounds_to_{EPS:g}", "total_bytes"],
        f"communication to reach gap<={EPS:g} (quadratic game, K={K})",
    )
    return rows


if __name__ == "__main__":
    run()
