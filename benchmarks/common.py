"""Shared helpers for the benchmark drivers."""
from __future__ import annotations

import csv
import io
import os
import sys
import time
from typing import Dict, Iterable, List


def emit(rows: List[Dict], header: Iterable[str], title: str) -> None:
    """Print one benchmark table as CSV with a title banner."""
    print(f"\n# ==== {title} ====")
    w = csv.DictWriter(sys.stdout, fieldnames=list(header))
    w.writeheader()
    for r in rows:
        w.writerow({k: r.get(k, "") for k in header})
    sys.stdout.flush()


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time of fn(*args) in microseconds (host-level; the
    numbers contextualize CPU runs, not TPU projections)."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def out_dir() -> str:
    d = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")
    os.makedirs(d, exist_ok=True)
    return d


# peak_memory moved to repro.obs.memory (one owner; measurements can now
# land in a run ledger via its telemetry kwarg) — re-exported here so
# existing callers keep working unchanged.
from repro.obs.memory import peak_memory  # noqa: E402,F401
