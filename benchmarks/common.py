"""Shared helpers for the benchmark drivers."""
from __future__ import annotations

import csv
import io
import os
import sys
import time
from typing import Dict, Iterable, List


def emit(rows: List[Dict], header: Iterable[str], title: str) -> None:
    """Print one benchmark table as CSV with a title banner."""
    print(f"\n# ==== {title} ====")
    w = csv.DictWriter(sys.stdout, fieldnames=list(header))
    w.writeheader()
    for r in rows:
        w.writerow({k: r.get(k, "") for k in header})
    sys.stdout.flush()


def timed(fn, *args, warmup: int = 1, iters: int = 3):
    """Median wall time of fn(*args) in microseconds (host-level; the
    numbers contextualize CPU runs, not TPU projections)."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def out_dir() -> str:
    d = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")
    os.makedirs(d, exist_ok=True)
    return d


def peak_memory(fn, *args, **kwargs) -> Dict:
    """Run fn(*args, **kwargs) and report its peak memory footprint:

      host_peak_bytes    tracemalloc's peak traced python/numpy
                         allocation during the call (deltas against the
                         running baseline — tracing starts/stops here);
      live_buffer_bytes  a census of live jax device buffers at the end
                         of the call (`jax.live_arrays`), the device-
                         side residency the traced-malloc peak misses;
      result             fn's return value.

    This is the measurement behind the O(active) memory gate: the mega
    population run's peak must scale with the ACTIVE set (+ pods), not
    with the m = 1e6 registry (`benchmarks/elastic.py --check`)."""
    import tracemalloc

    import jax

    tracemalloc.start()
    try:
        result = fn(*args, **kwargs)
        _, host_peak = tracemalloc.get_traced_memory()
    finally:
        tracemalloc.stop()
    live = sum(
        a.size * a.dtype.itemsize
        for a in jax.live_arrays()
        if hasattr(a, "size") and hasattr(a, "dtype")
    )
    return {
        "host_peak_bytes": int(host_peak),
        "live_buffer_bytes": int(live),
        "result": result,
    }
