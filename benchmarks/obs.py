"""Telemetry overhead — the observability suite's cost gate.

The unified telemetry sink (`repro.obs.Telemetry`) is host-side only: a
runner given `telemetry=None` executes its exact pre-telemetry trace
(the bitwise pin, tests/test_obs.py), and an attached sink adds a few
dict appends and one `perf_counter` pair per round.  This suite measures
that cost on the Section-5.1 quadratic game through the sync
`FederatedRunner` — the same runner/round every other benchmark uses —
in three modes:

  disabled   telemetry=None (the baseline every pin compares against);
  enabled    an in-memory `Telemetry()` sink, no probes — spans +
             wire-byte counters only;
  ledger     the same sink streaming every event to a JSONL run ledger
             (`repro.obs.RunLedger`), then read BACK from disk: the
             table's byte column comes from the ledger file, not from
             the in-memory runner — the consumption path is part of
             what's measured.

Timing design: the sink costs deterministic microseconds per round,
while shared-machine scheduler noise arrives in one-sided multi-second
BURSTS that can straddle several consecutive full-length runs and
masquerade as sink cost.  So modes are timed as many short interleaved
chunks (disabled/enabled/ledger rotating every ~0.15 s, faster than the
burst timescale) and each mode is scored by the mean of its `LOW_K`
fastest chunks — a low-noise estimator that a single straggling chunk
cannot move.

`--check` is the CI gate: non-zero exit if enabled-without-probes costs
more than `CHECK_TOL` (3%) wall-clock over disabled.  Probes are
deliberately outside the gate — a sampled `gt_residual` does real
device work and is priced by `--telemetry-probe-every`, not hidden in
the sink.
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed import FederatedRunner, GradientTracking
from repro.obs import RunLedger, Telemetry
from repro.problems import make_quadratic_problem

ETA, K = 1e-4, 10
DIM, M = 256, 8
CHUNKS = 24  # interleaved timing chunks per mode
T_CHUNK = 25  # rounds per chunk: ~0.15 s, well below the noise-burst scale
LOW_K = 6  # score = mean of each mode's LOW_K fastest chunks
CHECK_TOL = 0.03  # enabled-without-probes may cost at most 3% wall-clock


def _runner():
    jax.config.update("jax_enable_x64", True)
    prob = make_quadratic_problem(
        jax.random.PRNGKey(0), dim=DIM, num_samples=200, num_agents=M
    )
    return FederatedRunner.from_strategy(
        prob.loss, GradientTracking(), prob.agent_data, K, ETA
    )


def _time_chunk(runner, telemetry) -> float:
    x0 = jnp.zeros(DIM)
    runner.telemetry = telemetry
    t0 = time.perf_counter()
    out = runner.run(x0, x0, T_CHUNK)
    jax.block_until_ready(out)  # completion, not async-dispatch, time
    return time.perf_counter() - t0


def _measure(runner, sinks):
    """Chunk-interleaved low-quartile timing.  `sinks` is one telemetry
    (or None) per mode; every mode runs CHUNKS chunks, rotating mode
    each chunk so noise bursts hit all modes alike, and is scored by the
    mean of its LOW_K fastest chunks."""
    _time_chunk(runner, None)  # compile + cache warmup, shared by all modes
    _time_chunk(runner, None)
    times = [[] for _ in sinks]
    for _ in range(CHUNKS):
        for mode, tm in enumerate(sinks):
            times[mode].append(_time_chunk(runner, tm))
    return [float(np.mean(sorted(ts)[:LOW_K])) for ts in times]


def run(rows=None):
    rows = [] if rows is None else rows
    runner = _runner()

    # ledger mode: stream to JSONL, then CONSUME the file — byte truth
    # for the table comes from reading the run ledger back, the same
    # path post-hoc analysis uses
    with tempfile.TemporaryDirectory() as d:
        ledger = RunLedger(d)
        tm_on = Telemetry()
        off_s, on_s, led_s = _measure(
            runner, [None, tm_on, Telemetry(ledger=ledger)]
        )
        ledger.close()
        events = RunLedger.events(d)
    runner.telemetry = None
    led_bytes = sum(
        e["value"] for e in events
        if e["kind"] == "counter" and e["name"] == "wire_bytes"
    )

    def row(mode, secs, n_events, bytes_=""):
        return {
            "mode": mode,
            "rounds": CHUNKS * T_CHUNK,
            "chunk_s": f"{secs:.3f}",
            "per_round_us": f"{secs / T_CHUNK * 1e6:.1f}",
            "overhead_pct": f"{(secs / off_s - 1) * 100:.2f}",
            "events": n_events,
            "ledger_wire_bytes": bytes_,
        }

    rows.append(row("disabled", off_s, 0))
    rows.append(row("enabled", on_s, len(tm_on.events)))
    rows.append(row("ledger", led_s, len(events), led_bytes))
    from .common import emit

    emit(
        rows,
        ["mode", "rounds", "chunk_s", "per_round_us", "overhead_pct",
         "events", "ledger_wire_bytes"],
        f"telemetry overhead, sync quadratic round (dim={DIM}, m={M}, "
        f"K={K}; gate: enabled <= {CHECK_TOL:.0%} over disabled)",
    )
    return rows


def check(tol: float = CHECK_TOL) -> int:
    runner = _runner()
    off_s, on_s = _measure(runner, [None, Telemetry()])
    ratio = on_s / off_s
    ok = ratio <= 1.0 + tol
    print(
        f"[{'ok' if ok else 'FAIL'}] obs: enabled/disabled wall-clock "
        f"ratio {ratio:.4f} (disabled {off_s:.3f}s, enabled {on_s:.3f}s "
        f"per {T_CHUNK}-round chunk, budget {1.0 + tol:.2f})"
    )
    return 0 if ok else 1


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--check",
        action="store_true",
        help="CI gate: exit non-zero if the enabled-without-probes sink "
             f"costs > {CHECK_TOL:.0%} wall-clock over disabled",
    )
    args = ap.parse_args()
    if args.check:
        sys.exit(check())
    run()
