"""Regenerate the machine-derived tables of EXPERIMENTS.md from the dry-run
artifacts.  Usage:  PYTHONPATH=src:. python experiments/make_report.py"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline import analyze  # noqa: E402

SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}


def dryrun_table(dirname="experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        rec = json.load(open(path))
        if rec.get("algorithm") not in (None, "fedgda_gt"):
            continue
        ma = rec["memory_analysis"]
        coll = rec.get("census", {}).get("collectives_executed", {})
        coll_gib = sum(v["bytes"] for v in coll.values()) / 2**30
        rows.append(
            (
                rec["arch"], SHAPE_ORDER.get(rec["shape"], 9), rec["shape"],
                rec["mesh"],
                f"{rec['lower_s']:.1f}", f"{rec['compile_s']:.1f}",
                f"{ma.get('argument_size_in_bytes', 0)/2**30:.2f}",
                f"{ma.get('temp_size_in_bytes', 0)/2**30:.2f}",
                f"{rec.get('census', {}).get('executed_dot_flops', 0):.2e}",
                f"{coll_gib:.1f}",
            )
        )
    rows.sort()
    out = [
        "| arch | shape | mesh | lower s | compile s | args GiB/dev | temp GiB/dev | exec dot FLOPs/dev | coll GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append("| " + " | ".join([r[0], r[2], *r[3:]]) + " |")
    return "\n".join(out)


def roofline_table(dirname="experiments/dryrun"):
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant | MODEL_FLOPS/dev | useful ratio | roofline frac | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        rec = json.load(open(path))
        if rec["mesh"] != "16x16":
            continue
        if rec.get("algorithm") not in (None, "fedgda_gt"):
            continue
        r = analyze(rec)
        if not r:
            continue
        out.append(
            "| {arch} | {shape} | {compute_s} | {memory_s} | {collective_s} "
            "| {dominant} | {model_flops} | {useful_ratio} | {roofline_frac} "
            "| {fix} |".format(**r)
        )
    return "\n".join(out)


def perf_rows(paths):
    out = []
    for label, path in paths:
        if not os.path.exists(path):
            continue
        rec = json.load(open(path))
        r = analyze(rec)
        coll = rec.get("census", {}).get("collectives_executed", {})
        coll_gib = sum(v["bytes"] for v in coll.values()) / 2**30
        temp = rec["memory_analysis"].get("temp_size_in_bytes", 0) / 2**30
        out.append(
            f"| {label} | {r['compute_s']} | {r['collective_s']} "
            f"| {coll_gib:.0f} | {temp:.0f} | {r['useful_ratio']} |"
        )
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("## generated: dry-run table\n")
        print(dryrun_table())
    if which in ("all", "roofline"):
        print("\n## generated: roofline table (single-pod 16x16)\n")
        print(roofline_table())
