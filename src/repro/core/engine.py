"""Phase-split federated minimax round engine.

One communication round of the generic federated descent-ascent template
is four **phases**, each a pure function over an explicit `RoundState`:

  broadcast             server ships (x^t, y^t) to the agents; a strategy
                        may sample participants (client-sampling weights)
  exchange_corrections  (if the strategy corrects drift) agents exchange
                        gradients once at the anchor point and form the
                        tracking correction c_i = gbar - g_i, possibly
                        transformed (reduced dtype, sparsification,
                        quantization, error feedback, packed wire payloads)
  local_steps           K local GDA steps, each adding c_i to the local
                        gradient (fused-k0 anchor step when the correction
                        is exact — see below)
  aggregate             server aggregates (weighted by participation) and
                        projects

`make_phases(loss, strategy, ...)` builds the four phase functions for a
strategy; `make_round` is their fused single-program composition and
reproduces the pre-split monolithic round BITWISE (the phase split only
reorganizes the trace — same primitives, same order; see
tests/test_phases.py and tests/test_engine_parity.py).  Runtimes that
dispatch phases separately — `repro.fed.async_runtime` drives per-agent-
shard `broadcast`/`local_steps` programs on their own devices and splits
`exchange_corrections` between the shards (anchor gradients) and the
server (transform) — consume the same phase functions, so there is one
oracle for the round math whatever the execution schedule.

The legacy constructors — `make_gda_step`, `make_local_sgda_round`,
`make_fedgda_gt_round` — remain thin wrappers over this engine with the
`FullSync` / `LocalOnly` / `GradientTracking` strategies.  Strategies are
duck-typed (`repro.fed.strategies.CommStrategy` is the reference
protocol), which keeps this module free of `repro.fed` imports.

Fused k=0 (§Perf, exact): when the correction is exact, the first local
gradient is evaluated at the same point as the tracking gradient, so
g_i + c_i == gbar and the step reduces to z <- z -/+ eta * gbar, saving
one full gradient evaluation per round.  Strategies whose corrections are
inexact (sparsified/quantized) report `exact_correction = False` and take
the literal K-step schedule instead.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .types import (
    LossFn,
    ProjFn,
    Pytree,
    grad_xy,
    identity_proj,
    tree_broadcast_agents,
)

#: sentinel distinguishing "no override" from an explicit None weight
#: override in `broadcast` (None means uniform averaging)
_UNSET = object()


def default_update(z: Pytree, g: Pytree, c: Pytree, eta, sign: float) -> Pytree:
    """z <- z + sign*eta*(g + c); sign=-1 descent (x), +1 ascent (y)."""
    return jax.tree.map(
        lambda u, gv, cv: u + sign * eta * (gv + cv.astype(gv.dtype)), z, g, c
    )


def agent_mean(tree: Pytree, weights) -> Pytree:
    """Uniform mean over the agent axis (weights None — the bitwise-pinned
    legacy path) or a weighted sum with participation weights."""
    if weights is None:
        return jax.tree.map(lambda u: jnp.mean(u, axis=0), tree)
    return jax.tree.map(
        lambda u: jnp.tensordot(weights.astype(u.dtype), u, axes=1), tree
    )


def agent_weighted_sum(tree: Pytree, weights) -> Pytree:
    """Partial aggregate of one agent SHARD: the weighted sum (weights
    None: plain sum — divide by the global m after combining shards).
    Shard runtimes combine these server-side; `agent_mean` is the
    single-program equivalent."""
    if weights is None:
        return jax.tree.map(lambda u: jnp.sum(u, axis=0), tree)
    return jax.tree.map(
        lambda u: jnp.tensordot(weights.astype(u.dtype), u, axes=1), tree
    )


def anchor_step(zs: Pytree, gbar: Pytree, eta, sign: float) -> Pytree:
    """The fused k=0 local step: every agent moves by the global gradient."""
    return jax.tree.map(
        lambda u, gb: u + sign * eta * gb[None].astype(u.dtype), zs, gbar
    )


def agent_where(mask, a: Pytree, b: Pytree) -> Pytree:
    """Per-agent select: leaves of `a` where the [m] mask holds, else
    `b`'s — the membership/budget gate of the elastic schedules (the
    mask broadcasts over every trailing leaf dimension)."""
    return jax.tree.map(
        lambda u, v: jnp.where(
            mask.reshape(mask.shape + (1,) * (u.ndim - 1)), u, v
        ),
        a,
        b,
    )


def fixed_size_mask(key: jax.Array, m: int, size: int) -> jax.Array:
    """Boolean mask with exactly `size` uniformly chosen agents active —
    the single owner of the fixed-size participation draw (uniform
    without replacement via permutation).  Lives here, below both
    `repro.fed` (PartialParticipation's sampler) and `repro.sim`
    (FixedSizeSampling's availability process), so neither layer imports
    the other for it."""
    sel = jax.random.permutation(key, m)[:size]
    return jnp.zeros((m,), bool).at[sel].set(True)


def renormalized_weights(active, dtype=None) -> jax.Array:
    """Uniform aggregation weights over the active set, re-normalized so
    they sum to 1 for ANY nonempty active set — the membership-aware
    server weighting (a naive server keeps 1/m and silently loses the
    departed agents' mass).  Accepts a boolean mask or 0/1 floats."""
    a = jnp.asarray(active).astype(dtype or jnp.result_type(float))
    return a / jnp.sum(a)


def pod_weighted_sums(
    tree: Pytree, weights: jax.Array, pod_ids: jax.Array, num_pods: int
) -> Pytree:
    """Level one of the two-level agent -> pod -> server aggregation
    tree: per-pod partial weighted sums via segment-sum over the agents'
    pod assignments (`pod_ids`, [n] int — `sim.PodMap.pod_of` of the
    active ids).  Leaves gain a leading [num_pods] axis; summing it
    (`pods_total`) recovers the flat weighted sum to fp tolerance —
    Σ_p Σ_{i∈p} w_i u_i vs Σ_i w_i u_i differ only in reduction order
    (tests/test_sparse_elastic.py pins the property)."""

    def seg(u):
        w = weights.astype(u.dtype)
        uw = u * w.reshape((-1,) + (1,) * (u.ndim - 1))
        return jax.ops.segment_sum(uw, pod_ids, num_segments=num_pods)

    return jax.tree.map(seg, tree)


def pods_total(pod_tree: Pytree) -> Pytree:
    """Level two: the server's sum over the pod axis of the partial
    aggregates from `pod_weighted_sums` (quiet pods contribute exact
    zeros, so skipping them is a no-op on the value)."""
    return jax.tree.map(lambda u: jnp.sum(u, axis=0), pod_tree)


def tracking_corrections(
    gx: Pytree, gy: Pytree, gbar_x: Pytree, gbar_y: Pytree, cdt=None
):
    """The raw tracking corrections c_i = gbar - g_i per agent, optionally
    stored reduced (`cdt`).  One owner for the formation across every
    schedule: the fused exchange phase, the async runtime's server
    exchange and the multi-host shard encode all call this."""

    def corr(gbar, gi):
        c = gbar[None] - gi
        if cdt is not None:
            c = c.astype(cdt)
        return c

    return jax.tree.map(corr, gbar_x, gx), jax.tree.map(corr, gbar_y, gy)


def noise_eval_keys(noise_keys: jax.Array, idx) -> jax.Array:
    """Per-agent evaluation keys for ONE stochastic gradient call: fold
    the in-round call index (0 = the anchor exchange, 1 + k = local step
    k) into each agent's per-round noise key.  Single owner of the
    eval-level fold, shared by the fused round, the elastic round and
    the async shard programs so every schedule consumes the exact same
    draws (the full fold tree is documented in `repro.fed.noise`)."""
    return jax.vmap(jax.random.fold_in, in_axes=(0, None))(noise_keys, idx)


def make_noise_vgrad(gfn: Callable, noise) -> Callable:
    """vmapped per-agent stochastic gradient oracle for a noise model
    (duck-typed on `.grad(gfn, key, x, y, data)` — see
    `repro.fed.noise.NoiseModel`; this module stays free of `repro.fed`
    imports).  Signature: `(keys[m], xs, ys, agent_data) -> SaddleField`
    — the stochastic counterpart of `jax.vmap(gfn, (0, 0, 0))`."""

    def one(key, xi, yi, di):
        return noise.grad(gfn, key, xi, yi, di)

    return jax.vmap(one, in_axes=(0, 0, 0, 0))


# kept as private aliases — pre-split internal names, still referenced by
# downstream forks of the monolithic engine
_agent_mean = agent_mean
_anchor_step = anchor_step


@dataclasses.dataclass
class RoundState:
    """Explicit state threaded through the round phases.

    A registered-dataclass pytree, so separately-jitted phase programs can
    take and return it directly; `fused` is static metadata (it gates
    whether `local_steps` takes the anchor shortcut and must be known at
    trace time).

    Fields are populated progressively: `broadcast` fills xs/ys/weights
    (plus the elastic schedule's step_budgets/active when a runner passes
    them), `exchange_corrections` fills cx/cy/gbar_x/gbar_y/fused,
    `local_steps` advances xs/ys, `aggregate` consumes the lot.  Unused
    fields stay None (empty subtrees)."""

    x: Pytree                      # global iterates at round start
    y: Pytree
    state: Pytree                  # strategy state (RNG, EF buffers)
    xs: Pytree = None              # per-agent iterates [m, ...]
    ys: Pytree = None
    weights: Optional[jax.Array] = None  # participation weights (None=uniform)
    cx: Pytree = None              # tracking corrections [m, ...]
    cy: Pytree = None
    gbar_x: Pytree = None          # anchor-point global gradients
    gbar_y: Pytree = None
    step_budgets: Optional[jax.Array] = None  # [m] local-step caps (None=K)
    active: Optional[jax.Array] = None        # [m] availability mask
    noise_keys: Optional[jax.Array] = None    # [m] per-round noise keys
    #: GLOBAL agent ids of the rows in this state ([n] int64) — None on
    #: the dense path (row i IS agent i).  The sparse O(active) runtime
    #: threads the round's active id list here so id-keyed draws (noise
    #: stream folds) hit the same per-agent streams as the dense layout
    active_indices: Optional[jax.Array] = None
    fused: bool = False            # static: anchor shortcut applies


jax.tree_util.register_dataclass(
    RoundState,
    data_fields=(
        "x", "y", "state", "xs", "ys", "weights",
        "cx", "cy", "gbar_x", "gbar_y", "step_budgets", "active",
        "noise_keys", "active_indices",
    ),
    meta_fields=("fused",),
)


class RoundPhases(NamedTuple):
    """The four phase functions for one strategy (see module docstring).

    broadcast(x, y, agent_data, state, *, weights=...,
              step_budgets=None, active=None, noise_keys=...) -> RoundState
    exchange_corrections(rs, agent_data) -> RoundState
    local_steps(rs, agent_data) -> RoundState
    aggregate(rs) -> (x1, y1, state)

    Each is pure and shard-agnostic: the agent count is read from
    `agent_data` at trace time, so the same functions serve the fused
    single-program round (`make_round`) and per-shard dispatch
    (`fed.async_runtime`).  `broadcast`'s keyword-only `weights` lets a
    sharded runtime sample participation ONCE server-side and feed each
    shard its slice instead of re-sampling per shard; `step_budgets` and
    `active` carry an elastic schedule's per-agent local-step caps and
    availability mask (`repro.sim`) — `local_steps` freezes an agent
    once its budget is spent, and `None` (the default) is the pinned
    legacy trace with no gating primitives at all.  `noise_keys` works
    like `weights`: left unset, a stochastic strategy samples its
    per-agent keys from the dedicated noise stream in `state`; a
    sharded runtime samples once server-side and feeds each shard its
    slice (None — explicit — means deterministic, e.g. tracker init)."""

    broadcast: Callable
    exchange_corrections: Callable
    local_steps: Callable
    aggregate: Callable


def _num_agents(agent_data: Pytree) -> int:
    return jax.tree.leaves(agent_data)[0].shape[0]


def make_phases(
    loss: LossFn,
    strategy,
    num_local_steps: int,
    eta_x: float,
    eta_y: Optional[float] = None,
    *,
    proj_x: ProjFn = identity_proj,
    proj_y: ProjFn = identity_proj,
    update_fn: Callable = default_update,
    constrain_agents: Optional[Callable] = None,
) -> RoundPhases:
    """Build the four round phases for `strategy` (see RoundPhases)."""
    if eta_y is None:
        eta_y = eta_x
    gfn = grad_xy(loss)

    if getattr(strategy, "sync_every_step", False):
        # FullSync: K communicated steps, each a centralized GDA update.
        # There is no per-agent divergence to broadcast or correct, so
        # broadcast/exchange are identities and the whole round lives in
        # local_steps (each "local" step IS a global aggregate).
        vg = jax.vmap(gfn, in_axes=(None, None, 0))

        def gda_step(x, y, agent_data, weights=None):
            g = vg(x, y, agent_data)
            gx = agent_mean(g.gx, weights)
            gy = agent_mean(g.gy, weights)
            x1 = proj_x(jax.tree.map(lambda u, v: u - eta_x * v, x, gx))
            y1 = proj_y(jax.tree.map(lambda u, v: u + eta_y * v, y, gy))
            return x1, y1

        def broadcast(x, y, agent_data, state, *, weights=_UNSET,
                      step_budgets=None, active=None, noise_keys=_UNSET,
                      active_indices=None):
            # every "local" step is a global aggregate, so there is no
            # per-agent divergence to budget — step_budgets is ignored;
            # an elastic schedule's membership enters through `weights`.
            # FullSync is a deterministic baseline: noise_keys accepted
            # for signature uniformity, never consumed
            del agent_data, step_budgets, noise_keys
            w = None if weights is _UNSET else weights
            return RoundState(x=x, y=y, state=state, weights=w, active=active,
                              active_indices=active_indices)

        def exchange_corrections(rs, agent_data):
            del agent_data
            return rs

        def local_steps(rs, agent_data):
            x, y, w = rs.x, rs.y, rs.weights
            if num_local_steps == 1:
                x, y = gda_step(x, y, agent_data, w)
            else:
                (x, y), _ = jax.lax.scan(
                    lambda c, _: (gda_step(*c, agent_data, w), None),
                    (x, y),
                    None,
                    length=num_local_steps,
                )
            return dataclasses.replace(rs, x=x, y=y)

        def aggregate(rs):
            return rs.x, rs.y, rs.state

        return RoundPhases(broadcast, exchange_corrections, local_steps, aggregate)

    vgrad = jax.vmap(gfn, in_axes=(0, 0, 0))
    use_corr = bool(getattr(strategy, "use_correction", False))
    cdt = getattr(strategy, "correction_dtype", None)
    # stochastic knobs — None / 0.0 are trace-time identities: the
    # deterministic path below keeps the exact legacy primitives (no
    # zeroed noise, no 0-scaled velocity — bitwise-pinned)
    noise = getattr(strategy, "noise", None)
    momentum = float(getattr(strategy, "momentum", 0.0) or 0.0)
    nvgrad = make_noise_vgrad(gfn, noise) if noise is not None else None
    if momentum:
        # lazy: optim.momentum imports core — only the momentum round
        # needs the shared heavy-ball primitive
        from ..optim.momentum import heavy_ball

    def broadcast(x, y, agent_data, state, *, weights=_UNSET,
                  step_budgets=None, active=None, noise_keys=_UNSET,
                  active_indices=None):
        m = _num_agents(agent_data)
        if weights is _UNSET:
            weights, state = strategy.sample_weights(state, m)
        if noise_keys is _UNSET:
            noise_keys = None
            if noise is not None:
                if active_indices is not None:
                    # sparse layout: rows are the active subset — fold
                    # the GLOBAL ids so each agent sees the same noise
                    # stream it would in the dense [m] layout
                    noise_keys, state = strategy.sample_noise_keys_ids(
                        state, active_indices
                    )
                else:
                    noise_keys, state = strategy.sample_noise_keys(state, m)
        xs = tree_broadcast_agents(x, m)
        ys = tree_broadcast_agents(y, m)
        if constrain_agents is not None:
            xs, ys = constrain_agents(xs, ys)
        return RoundState(
            x=x, y=y, state=state, xs=xs, ys=ys, weights=weights,
            step_budgets=step_budgets, active=active, noise_keys=noise_keys,
            active_indices=active_indices,
        )

    def exchange_corrections(rs, agent_data):
        if not use_corr:
            return rs
        m = _num_agents(agent_data)
        state = rs.state
        if m > 1:
            # one gradient exchange at the anchor point (eval index 0 of
            # the noise stream when stochastic)
            if noise is None or rs.noise_keys is None:
                g0 = vgrad(rs.xs, rs.ys, agent_data)
            else:
                g0 = nvgrad(
                    noise_eval_keys(rs.noise_keys, 0),
                    rs.xs, rs.ys, agent_data,
                )
            gbar_x = agent_mean(g0.gx, rs.weights)
            gbar_y = agent_mean(g0.gy, rs.weights)
            cx, cy = tracking_corrections(g0.gx, g0.gy, gbar_x, gbar_y, cdt)
            cx, cy, state = strategy.transform_correction(cx, cy, state)
            # wire-transport strategies hand back PACKED payloads
            # (repro.fed.transport.PackedTree — duck-typed on the
            # `decode` hook to keep the engine import-decoupled):
            # the server gathers the packed buffers and scatter-adds
            # them back to dense corrections before the local steps
            if hasattr(cx, "decode"):
                cx = cx.decode()
            if hasattr(cy, "decode"):
                cy = cy.decode()
            # momentum folds the correction into a velocity, so the
            # first step is no longer the plain anchor update
            fused = bool(strategy.exact_correction) and not momentum
            return dataclasses.replace(
                rs, cx=cx, cy=cy, gbar_x=gbar_x, gbar_y=gbar_y,
                fused=fused, state=state,
            )
        # m == 1: the correction is identically zero and elided
        cx = jax.tree.map(jnp.zeros_like, rs.xs)
        cy = jax.tree.map(jnp.zeros_like, rs.ys)
        return dataclasses.replace(rs, cx=cx, cy=cy)

    def local_steps(rs, agent_data):
        xs, ys = rs.xs, rs.ys
        budgets = rs.step_budgets
        stochastic = noise is not None and rs.noise_keys is not None

        def grads(xs, ys, k):
            # k is the in-round step index; the stochastic oracle draws
            # at eval index 1 + k (0 belongs to the anchor exchange)
            if not stochastic:
                return vgrad(xs, ys, agent_data)
            return nvgrad(
                noise_eval_keys(rs.noise_keys, 1 + k), xs, ys, agent_data
            )

        if use_corr:
            cx, cy = rs.cx, rs.cy

            def step_once(xs, ys, k):
                g = grads(xs, ys, k)
                xs = update_fn(xs, g.gx, cx, eta_x, -1.0)
                ys = update_fn(ys, g.gy, cy, eta_y, +1.0)
                if constrain_agents is not None:
                    # re-anchor the scan carry's sharding every step
                    xs, ys = constrain_agents(xs, ys)
                return xs, ys

        else:

            def step_once(xs, ys, k):
                g = grads(xs, ys, k)
                xs = jax.tree.map(lambda u, v: u - eta_x * v, xs, g.gx)
                ys = jax.tree.map(lambda u, v: u + eta_y * v, ys, g.gy)
                return xs, ys

        start = 0
        if rs.fused:
            xs1 = anchor_step(xs, rs.gbar_x, eta_x, -1.0)
            ys1 = anchor_step(ys, rs.gbar_y, eta_y, +1.0)
            if constrain_agents is not None:
                xs1, ys1 = constrain_agents(xs1, ys1)
            if budgets is None:
                xs, ys = xs1, ys1
            else:
                live = budgets >= 1
                xs = agent_where(live, xs1, xs)
                ys = agent_where(live, ys1, ys)
            start = 1
        if num_local_steps - start > 0:
            if momentum:
                # heavy-ball local steps (Local SGDA+): per-round
                # velocities, zero-initialized, carrying the corrected
                # step direction; budget gating freezes iterate AND
                # velocity so a spent agent's round contribution is
                # exactly its last live step
                def eff(g, c):
                    if c is None:
                        return g
                    return jax.tree.map(
                        lambda gv, cv: gv + cv.astype(gv.dtype), g, c
                    )

                def mom_body(carry, k):
                    xs, ys, vx, vy = carry
                    g = grads(xs, ys, k)
                    vx1 = heavy_ball(vx, eff(g.gx, cx if use_corr else None),
                                     momentum)
                    vy1 = heavy_ball(vy, eff(g.gy, cy if use_corr else None),
                                     momentum)
                    xs1 = jax.tree.map(lambda u, v: u - eta_x * v, xs, vx1)
                    ys1 = jax.tree.map(lambda u, v: u + eta_y * v, ys, vy1)
                    if constrain_agents is not None:
                        xs1, ys1 = constrain_agents(xs1, ys1)
                    if budgets is None:
                        return (xs1, ys1, vx1, vy1), None
                    live = k < budgets
                    return (
                        agent_where(live, xs1, xs),
                        agent_where(live, ys1, ys),
                        agent_where(live, vx1, vx),
                        agent_where(live, vy1, vy),
                    ), None

                zeros = lambda t: jax.tree.map(jnp.zeros_like, t)
                (xs, ys, _, _), _ = jax.lax.scan(
                    mom_body,
                    (xs, ys, zeros(xs), zeros(ys)),
                    jnp.arange(start, num_local_steps),
                )
            elif budgets is None and not stochastic:
                # the pinned legacy trace: no gating or indexing
                # primitives at all
                (xs, ys), _ = jax.lax.scan(
                    lambda c, _: (step_once(*c, 0), None),
                    (xs, ys),
                    None,
                    length=num_local_steps - start,
                )
            elif budgets is None:
                # stochastic, full budgets: the scan indexes the noise
                # stream by step but gates nothing
                (xs, ys), _ = jax.lax.scan(
                    lambda c, k: (step_once(*c, k), None),
                    (xs, ys),
                    jnp.arange(start, num_local_steps),
                )
            else:
                # elastic: step k only advances agents whose budget still
                # covers it — a spent (or absent, budget 0) agent's
                # iterate is frozen so its weighted aggregate share (and
                # its zero weight, for inactive agents) stays exact
                def gated(carry, k):
                    xs, ys = carry
                    xs1, ys1 = step_once(xs, ys, k)
                    live = k < budgets
                    return (
                        agent_where(live, xs1, xs),
                        agent_where(live, ys1, ys),
                    ), None

                (xs, ys), _ = jax.lax.scan(
                    gated, (xs, ys), jnp.arange(start, num_local_steps)
                )
        return dataclasses.replace(rs, xs=xs, ys=ys)

    def aggregate(rs):
        x1 = proj_x(agent_mean(rs.xs, rs.weights))
        y1 = proj_y(agent_mean(rs.ys, rs.weights))
        return x1, y1, rs.state

    return RoundPhases(broadcast, exchange_corrections, local_steps, aggregate)


def make_round(
    loss: LossFn,
    strategy,
    num_local_steps: int,
    eta_x: float,
    eta_y: Optional[float] = None,
    *,
    proj_x: ProjFn = identity_proj,
    proj_y: ProjFn = identity_proj,
    update_fn: Callable = default_update,
    constrain_agents: Optional[Callable] = None,
    explicit_state: Optional[bool] = None,
) -> Callable:
    """Build one communication round for `strategy`: the fused
    single-program composition of the four phases (`make_phases`).

    Returns `round(x, y, agent_data) -> (x, y)` for stateless strategies.
    Stateful strategies (client sampling RNG, error-feedback buffers)
    return `round(x, y, agent_data, state) -> (x, y, state)` with the
    initial state from `strategy.init_state(x, y, m)`; pass
    `explicit_state=True` to force that signature for stateless
    strategies too (useful when mixing strategies under one scan).
    """
    stateful = bool(getattr(strategy, "stateful", False))
    if explicit_state is None:
        explicit_state = stateful
    if stateful and not explicit_state:
        raise ValueError(
            f"strategy {strategy!r} carries cross-round state; build with "
            "explicit_state=True and thread `state` through the rounds"
        )
    phases = make_phases(
        loss,
        strategy,
        num_local_steps,
        eta_x,
        eta_y,
        proj_x=proj_x,
        proj_y=proj_y,
        update_fn=update_fn,
        constrain_agents=constrain_agents,
    )

    def core(x, y, agent_data, state):
        rs = phases.broadcast(x, y, agent_data, state)
        rs = phases.exchange_corrections(rs, agent_data)
        rs = phases.local_steps(rs, agent_data)
        return phases.aggregate(rs)

    if explicit_state:
        return core

    def round(x, y, agent_data):
        x1, y1, _ = core(x, y, agent_data, {})
        return x1, y1

    return round


def run_strategy_rounds(
    round_fn: Callable,
    x0: Pytree,
    y0: Pytree,
    agent_data: Pytree,
    num_rounds: int,
    state0: Optional[Pytree] = None,
    metric_fn: Optional[Callable] = None,
):
    """Scan a stateful round (built with `explicit_state=True`) for
    `num_rounds`, threading the strategy state through the carry.

    Returns ((x, y, state), metrics) with metrics evaluated on the input
    of each round plus once at the end — the stateful counterpart of
    `repro.core.gda.run_rounds`."""
    if state0 is None:
        state0 = {}

    def body(carry, _):
        x, y, s = carry
        meas = metric_fn(x, y) if metric_fn is not None else None
        x1, y1, s1 = round_fn(x, y, agent_data, s)
        return (x1, y1, s1), meas

    (x, y, s), metrics = jax.lax.scan(
        body, (x0, y0, state0), None, length=num_rounds
    )
    if metric_fn is not None:
        final = metric_fn(x, y)
        metrics = jax.tree.map(
            lambda hist, last: jnp.concatenate([hist, last[None]]), metrics, final
        )
    return (x, y, s), metrics
