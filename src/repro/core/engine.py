"""Unified federated minimax round engine.

`make_round(loss, strategy, ...)` emits one communication round of the
generic federated descent-ascent template

  1. server broadcasts (x^t, y^t); a strategy may sample participants
  2. (if the strategy corrects drift) agents exchange gradients once and
     form the tracking correction c_i = gbar - g_i, possibly transformed
     (reduced dtype, sparsification, error feedback)
  3. K local GDA steps, each adding c_i to the local gradient
  4. server aggregates (weighted by participation) and projects

The legacy constructors — `make_gda_step`, `make_local_sgda_round`,
`make_fedgda_gt_round` — are thin wrappers over this engine with the
`FullSync` / `LocalOnly` / `GradientTracking` strategies; the engine
reproduces their iterate sequences exactly (bitwise for gradient
tracking — see tests/test_engine_parity.py).  Strategies are duck-typed
(`repro.fed.strategies.CommStrategy` is the reference protocol), which
keeps this module free of `repro.fed` imports.

Fused k=0 (§Perf, exact): when the correction is exact, the first local
gradient is evaluated at the same point as the tracking gradient, so
g_i + c_i == gbar and the step reduces to z <- z -/+ eta * gbar, saving
one full gradient evaluation per round.  Strategies whose corrections are
inexact (sparsified) report `exact_correction = False` and take the
literal K-step schedule instead.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .types import (
    LossFn,
    ProjFn,
    Pytree,
    grad_xy,
    identity_proj,
    tree_broadcast_agents,
)


def default_update(z: Pytree, g: Pytree, c: Pytree, eta, sign: float) -> Pytree:
    """z <- z + sign*eta*(g + c); sign=-1 descent (x), +1 ascent (y)."""
    return jax.tree.map(
        lambda u, gv, cv: u + sign * eta * (gv + cv.astype(gv.dtype)), z, g, c
    )


def _agent_mean(tree: Pytree, weights) -> Pytree:
    """Uniform mean over the agent axis (weights None — the bitwise-pinned
    legacy path) or a weighted sum with participation weights."""
    if weights is None:
        return jax.tree.map(lambda u: jnp.mean(u, axis=0), tree)
    return jax.tree.map(
        lambda u: jnp.tensordot(weights.astype(u.dtype), u, axes=1), tree
    )


def _anchor_step(zs: Pytree, gbar: Pytree, eta, sign: float) -> Pytree:
    """The fused k=0 local step: every agent moves by the global gradient."""
    return jax.tree.map(
        lambda u, gb: u + sign * eta * gb[None].astype(u.dtype), zs, gbar
    )


def make_round(
    loss: LossFn,
    strategy,
    num_local_steps: int,
    eta_x: float,
    eta_y: Optional[float] = None,
    *,
    proj_x: ProjFn = identity_proj,
    proj_y: ProjFn = identity_proj,
    update_fn: Callable = default_update,
    constrain_agents: Optional[Callable] = None,
    explicit_state: Optional[bool] = None,
) -> Callable:
    """Build one communication round for `strategy`.

    Returns `round(x, y, agent_data) -> (x, y)` for stateless strategies.
    Stateful strategies (client sampling RNG, error-feedback buffers)
    return `round(x, y, agent_data, state) -> (x, y, state)` with the
    initial state from `strategy.init_state(x, y, m)`; pass
    `explicit_state=True` to force that signature for stateless
    strategies too (useful when mixing strategies under one scan).
    """
    if eta_y is None:
        eta_y = eta_x
    stateful = bool(getattr(strategy, "stateful", False))
    if explicit_state is None:
        explicit_state = stateful
    if stateful and not explicit_state:
        raise ValueError(
            f"strategy {strategy!r} carries cross-round state; build with "
            "explicit_state=True and thread `state` through the rounds"
        )
    gfn = grad_xy(loss)

    if getattr(strategy, "sync_every_step", False):
        # FullSync: K communicated steps, each a centralized GDA update
        vg = jax.vmap(gfn, in_axes=(None, None, 0))

        def gda_step(x, y, agent_data):
            g = vg(x, y, agent_data)
            gx = jax.tree.map(lambda u: jnp.mean(u, axis=0), g.gx)
            gy = jax.tree.map(lambda u: jnp.mean(u, axis=0), g.gy)
            x1 = proj_x(jax.tree.map(lambda u, v: u - eta_x * v, x, gx))
            y1 = proj_y(jax.tree.map(lambda u, v: u + eta_y * v, y, gy))
            return x1, y1

        def core(x, y, agent_data, state):
            if num_local_steps == 1:
                x, y = gda_step(x, y, agent_data)
            else:
                (x, y), _ = jax.lax.scan(
                    lambda c, _: (gda_step(*c, agent_data), None),
                    (x, y),
                    None,
                    length=num_local_steps,
                )
            return x, y, state

    else:
        vgrad = jax.vmap(gfn, in_axes=(0, 0, 0))
        use_corr = bool(getattr(strategy, "use_correction", False))
        cdt = getattr(strategy, "correction_dtype", None)

        def core(x, y, agent_data, state):
            m = jax.tree.leaves(agent_data)[0].shape[0]
            weights, state = strategy.sample_weights(state, m)
            xs = tree_broadcast_agents(x, m)
            ys = tree_broadcast_agents(y, m)
            if constrain_agents is not None:
                xs, ys = constrain_agents(xs, ys)

            fused = False
            if use_corr and m > 1:
                # one gradient exchange at the anchor point
                g0 = vgrad(xs, ys, agent_data)
                gbar_x = _agent_mean(g0.gx, weights)
                gbar_y = _agent_mean(g0.gy, weights)

                def corr(gbar, gi):
                    c = gbar[None] - gi
                    if cdt is not None:
                        c = c.astype(cdt)
                    return c

                cx = jax.tree.map(corr, gbar_x, g0.gx)
                cy = jax.tree.map(corr, gbar_y, g0.gy)
                cx, cy, state = strategy.transform_correction(cx, cy, state)
                # wire-transport strategies hand back PACKED payloads
                # (repro.fed.transport.PackedTree — duck-typed on the
                # `decode` hook to keep the engine import-decoupled):
                # the server gathers the packed buffers and scatter-adds
                # them back to dense corrections before the local steps
                if hasattr(cx, "decode"):
                    cx = cx.decode()
                if hasattr(cy, "decode"):
                    cy = cy.decode()
                fused = bool(strategy.exact_correction)
            elif use_corr:
                # m == 1: the correction is identically zero and elided
                cx = jax.tree.map(jnp.zeros_like, xs)
                cy = jax.tree.map(jnp.zeros_like, ys)

            if use_corr:

                def inner(carry, _):
                    xs, ys = carry
                    g = vgrad(xs, ys, agent_data)
                    xs = update_fn(xs, g.gx, cx, eta_x, -1.0)
                    ys = update_fn(ys, g.gy, cy, eta_y, +1.0)
                    if constrain_agents is not None:
                        # re-anchor the scan carry's sharding every step
                        xs, ys = constrain_agents(xs, ys)
                    return (xs, ys), None

            else:

                def inner(carry, _):
                    xs, ys = carry
                    g = vgrad(xs, ys, agent_data)
                    xs = jax.tree.map(lambda u, v: u - eta_x * v, xs, g.gx)
                    ys = jax.tree.map(lambda u, v: u + eta_y * v, ys, g.gy)
                    return (xs, ys), None

            inner_steps = num_local_steps
            if fused:
                xs = _anchor_step(xs, gbar_x, eta_x, -1.0)
                ys = _anchor_step(ys, gbar_y, eta_y, +1.0)
                if constrain_agents is not None:
                    xs, ys = constrain_agents(xs, ys)
                inner_steps -= 1
            if inner_steps > 0:
                (xs, ys), _ = jax.lax.scan(
                    inner, (xs, ys), None, length=inner_steps
                )
            x1 = proj_x(_agent_mean(xs, weights))
            y1 = proj_y(_agent_mean(ys, weights))
            return x1, y1, state

    if explicit_state:
        return core

    def round(x, y, agent_data):
        x1, y1, _ = core(x, y, agent_data, {})
        return x1, y1

    return round


def run_strategy_rounds(
    round_fn: Callable,
    x0: Pytree,
    y0: Pytree,
    agent_data: Pytree,
    num_rounds: int,
    state0: Optional[Pytree] = None,
    metric_fn: Optional[Callable] = None,
):
    """Scan a stateful round (built with `explicit_state=True`) for
    `num_rounds`, threading the strategy state through the carry.

    Returns ((x, y, state), metrics) with metrics evaluated on the input
    of each round plus once at the end — the stateful counterpart of
    `repro.core.gda.run_rounds`."""
    if state0 is None:
        state0 = {}

    def body(carry, _):
        x, y, s = carry
        meas = metric_fn(x, y) if metric_fn is not None else None
        x1, y1, s1 = round_fn(x, y, agent_data, s)
        return (x1, y1, s1), meas

    (x, y, s), metrics = jax.lax.scan(
        body, (x0, y0, state0), None, length=num_rounds
    )
    if metric_fn is not None:
        final = metric_fn(x, y)
        metrics = jax.tree.map(
            lambda hist, last: jnp.concatenate([hist, last[None]]), metrics, final
        )
    return (x, y, s), metrics
