"""Fixed-point characterization of Local SGDA (Proposition 1, Appendix C).

Proposition 1: if Local SGDA (constant steps, full gradients) converges to
(x*, y*), then  (1/m) sum_i sum_{k<K} grad f_i(D_i^k(x*,y*), A_i^k(x*,y*)) = 0,
where D_i / A_i are the per-agent descent/ascent operators.  For K >= 2 this
differs from the true minimax condition grad f(x*,y*) = 0.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from .types import LossFn, Pytree, grad_xy


def local_operators(
    loss: LossFn, eta_x: float, eta_y: float
) -> Callable:
    """Returns ops(x, y, data_i, k) -> (D_i^k(x,y), A_i^k(x,y))."""
    gfn = grad_xy(loss)

    def ops(x: Pytree, y: Pytree, data_i: Pytree, k: int):
        def body(carry, _):
            xk, yk = carry
            g = gfn(xk, yk, data_i)
            xk = jax.tree.map(lambda u, v: u - eta_x * v, xk, g.gx)
            yk = jax.tree.map(lambda u, v: u + eta_y * v, yk, g.gy)
            return (xk, yk), None

        (xk, yk), _ = jax.lax.scan(body, (x, y), None, length=k)
        return xk, yk

    return ops


def prop1_residual(
    loss: LossFn,
    x: Pytree,
    y: Pytree,
    agent_data: Pytree,
    num_local_steps: int,
    eta_x: float,
    eta_y: float,
) -> jax.Array:
    """|| (1/m) sum_i sum_k grad f_i(D^k, A^k) ||  at (x, y).

    Zero exactly at fixed points of Local SGDA (Proposition 1).
    """
    gfn = grad_xy(loss)
    ops = local_operators(loss, eta_x, eta_y)

    def per_agent(data_i):
        def body(carry, _):
            xk, yk, accx, accy = carry
            g = gfn(xk, yk, data_i)
            accx = jax.tree.map(jnp.add, accx, g.gx)
            accy = jax.tree.map(jnp.add, accy, g.gy)
            xk = jax.tree.map(lambda u, v: u - eta_x * v, xk, g.gx)
            yk = jax.tree.map(lambda u, v: u + eta_y * v, yk, g.gy)
            return (xk, yk, accx, accy), None

        zx = jax.tree.map(jnp.zeros_like, x)
        zy = jax.tree.map(jnp.zeros_like, y)
        (_, _, accx, accy), _ = jax.lax.scan(
            body, (x, y, zx, zy), None, length=num_local_steps
        )
        return accx, accy

    accx, accy = jax.vmap(per_agent)(agent_data)
    sq = 0.0
    for acc in (accx, accy):
        mean = jax.tree.map(lambda u: jnp.mean(u, axis=0), acc)
        sq = sq + jax.tree.reduce(
            jnp.add, jax.tree.map(lambda u: jnp.sum(u**2), mean)
        )
    return jnp.sqrt(sq)


def appendix_c_fixed_point(
    num_local_steps: int, eta_x: float, eta_y: float
) -> Tuple[float, float]:
    """Closed-form Local-SGDA fixed point for the Appendix-C example.

    f_1 = x^2 - y^2 - (x - y),  f_2 = 4x^2 - 4y^2 - 32(x - y):
      x*_LSGDA = [sum_i sum_k 2 i^2 (1-2 eta_x i^2)^k]^{-1}
                 [sum_i sum_k (31 i - 30)(1-2 eta_x i^2)^k]
    (analogous for y).  True minimax point is x* = y* = 3.3.
    """

    def fp(eta: float) -> float:
        num = 0.0
        den = 0.0
        for i in (1, 2):
            for k in range(num_local_steps):
                w = (1.0 - 2.0 * eta * i * i) ** k
                den += 2.0 * i * i * w
                num += (31.0 * i - 30.0) * w
        return num / den

    return fp(eta_x), fp(eta_y)


APPENDIX_C_MINIMAX_POINT = (3.3, 3.3)
