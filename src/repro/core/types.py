"""Core type definitions for federated minimax optimization.

A minimax problem is  min_{x in X} max_{y in Y} (1/m) sum_i f_i(x, y)
where f_i is agent i's private objective.  We represent the stacked agent
data with a leading axis of size m on every leaf ("agent-stacked pytree"),
so the same code runs single-host (vmap) and SPMD (agent axis sharded over
the fed mesh axes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Pytree = Any
# loss(x, y, agent_data) -> scalar.  agent_data is ONE agent's slice.
LossFn = Callable[[Pytree, Pytree, Pytree], jax.Array]
# projection(p) -> p projected onto the feasible set.
ProjFn = Callable[[Pytree], Pytree]


def identity_proj(p: Pytree) -> Pytree:
    return p


@dataclasses.dataclass(frozen=True)
class MinimaxProblem:
    """min_x max_y (1/m) sum_i loss(x, y, agent_data_i).

    Attributes:
      loss: per-agent loss; pure function of (x, y, agent_data).
      agent_data: pytree whose leaves have leading axis m (one slice/agent).
      proj_x / proj_y: projections onto X and Y (identity = unconstrained).
      num_agents: m.
    """

    loss: LossFn
    agent_data: Pytree
    num_agents: int
    proj_x: ProjFn = identity_proj
    proj_y: ProjFn = identity_proj

    def agent_slice(self, i: int) -> Pytree:
        return jax.tree.map(lambda a: a[i], self.agent_data)

    def global_loss(self, x: Pytree, y: Pytree) -> jax.Array:
        per_agent = jax.vmap(self.loss, in_axes=(None, None, 0))(
            x, y, self.agent_data
        )
        return jnp.mean(per_agent)


class SaddleField(NamedTuple):
    """F(z) = (grad_x f, -grad_y f) evaluated per agent and globally."""

    gx: Pytree
    gy: Pytree  # NOTE: stores +grad_y; ascent applies the + sign.


def grad_xy(loss: LossFn) -> Callable[[Pytree, Pytree, Pytree], SaddleField]:
    """Returns a function computing (grad_x, grad_y) of the loss."""
    g = jax.grad(loss, argnums=(0, 1))

    def f(x: Pytree, y: Pytree, data: Pytree) -> SaddleField:
        gx, gy = g(x, y, data)
        return SaddleField(gx=gx, gy=gy)

    return f


def tree_add(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a: Pytree, b: Pytree) -> Pytree:
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a: Pytree, s) -> Pytree:
    return jax.tree.map(lambda u: u * s, a)


def tree_mean_over_agents(a: Pytree) -> Pytree:
    """Mean over the leading (agent) axis of every leaf."""
    return jax.tree.map(lambda u: jnp.mean(u, axis=0), a)


def tree_broadcast_agents(a: Pytree, m: int) -> Pytree:
    """Stack m copies along a new leading axis."""
    return jax.tree.map(
        lambda u: jnp.broadcast_to(u[None], (m,) + u.shape), a
    )


def tree_sq_dist(a: Pytree, b: Pytree) -> jax.Array:
    """||a - b||^2 summed over all leaves."""
    d = jax.tree.map(lambda u, v: jnp.sum((u - v) ** 2), a, b)
    return jax.tree.reduce(jnp.add, d)


def tree_cast(a: Pytree, dtype) -> Pytree:
    return jax.tree.map(lambda u: u.astype(dtype), a)
