"""FedGDA-GT (Algorithm 2) — the paper's contribution.

One communication round t:
  1. server broadcasts (x^t, y^t)                       [replication, no-op in SPMD]
  2. agents compute grad f_i(x^t, y^t), server averages  [ONE all-reduce]
  3. K local steps with gradient-tracking correction:
       x_{i,k+1} = x_{i,k} - eta*(gx_i(x_{i,k},y_{i,k}) - gx_i(x^t,y^t) + gx(x^t,y^t))
       y_{i,k+1} = y_{i,k} + eta*(gy_i(x_{i,k},y_{i,k}) - gy_i(x^t,y^t) + gy(x^t,y^t))
     [no communication]
  4. server averages and projects                        [ONE all-reduce]

Theorem 1: linear convergence to the exact minimax point with constant eta.

Beyond-paper extensions implemented here, both OFF by default:
  * `correction_dtype` — store the (parameter-sized) tracking correction
    c_i = grad f(x^t,y^t) - grad f_i(x^t,y^t) in a reduced dtype (e.g.
    float8_e4m3fn) to cut the +1-param-copy memory cost of GT on very large
    models (used by the llama4-maverick config; measured in EXPERIMENTS §Perf).
  * `update_fn` — pluggable fused update (the Pallas `gt_update` kernel).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .engine import default_update as _default_update
from .engine import make_round
from .types import (
    LossFn,
    ProjFn,
    Pytree,
    SaddleField,
    grad_xy,
    identity_proj,
    tree_broadcast_agents,
    tree_mean_over_agents,
)


def make_fedgda_gt_round(
    loss: LossFn,
    num_local_steps: int,
    eta: float,
    proj_x: ProjFn = identity_proj,
    proj_y: ProjFn = identity_proj,
    correction_dtype=None,
    update_fn: Callable = _default_update,
    constrain_agents: Optional[Callable] = None,
) -> Callable:
    """Returns round(x, y, agent_data) -> (x, y) implementing Algorithm 2 —
    a `GradientTracking` round of the unified engine (bitwise-identical
    iterates to the pre-engine implementation; tests/test_engine_parity.py).

    agent_data leaves carry a leading agent axis of size m.  When m == 1 the
    correction is identically zero and is elided (the algorithm provably
    reduces to centralized GDA — Appendix D.4 intuition).
    """
    from ..fed.strategies import GradientTracking

    return make_round(
        loss,
        GradientTracking(correction_dtype=correction_dtype),
        num_local_steps,
        eta,
        eta,
        proj_x=proj_x,
        proj_y=proj_y,
        update_fn=update_fn,
        constrain_agents=constrain_agents,
    )


def make_fedgda_gt_round_reference(
    loss: LossFn,
    num_local_steps: int,
    eta: float,
    proj_x: ProjFn = identity_proj,
    proj_y: ProjFn = identity_proj,
    correction_dtype=None,
    update_fn: Callable = _default_update,
    constrain_agents: Optional[Callable] = None,
) -> Callable:
    """Pre-engine implementation, kept verbatim as the differential-test
    oracle: the engine's GradientTracking path must reproduce its iterates
    BITWISE (tests/test_engine_parity.py)."""
    gfn = grad_xy(loss)
    vgrad = jax.vmap(gfn, in_axes=(0, 0, 0))

    def round(x: Pytree, y: Pytree, agent_data: Pytree):
        m = jax.tree.leaves(agent_data)[0].shape[0]

        xs = tree_broadcast_agents(x, m)
        ys = tree_broadcast_agents(y, m)
        if constrain_agents is not None:
            # anchor GSPMD: agent axis sharded over the fed mesh axes
            xs, ys = constrain_agents(xs, ys)

        if m > 1:
            # line 3-4: local gradients at the broadcast point + global average
            g0 = vgrad(xs, ys, agent_data)
            gbar_x = jax.tree.map(lambda u: jnp.mean(u, axis=0), g0.gx)
            gbar_y = jax.tree.map(lambda u: jnp.mean(u, axis=0), g0.gy)
            # tracking correction c_i = gbar - g_i  (parameter-sized per agent)
            def corr(gbar, gi):
                c = gbar[None] - gi
                if correction_dtype is not None:
                    c = c.astype(correction_dtype)
                return c

            cx = jax.tree.map(corr, gbar_x, g0.gx)
            cy = jax.tree.map(corr, gbar_y, g0.gy)
        else:
            cx = jax.tree.map(jnp.zeros_like, xs)
            cy = jax.tree.map(jnp.zeros_like, ys)

        def inner(carry, _):
            xs, ys = carry
            g = vgrad(xs, ys, agent_data)
            xs = update_fn(xs, g.gx, cx, eta, -1.0)
            ys = update_fn(ys, g.gy, cy, eta, +1.0)
            if constrain_agents is not None:
                # re-anchor the scan carry's sharding every local step
                xs, ys = constrain_agents(xs, ys)
            return (xs, ys), None

        inner_steps = num_local_steps
        if m > 1:
            # fused step k=0 (§Perf, exact): the inner gradient at k=0 is
            # evaluated at the SAME point as the tracking gradient, so the
            # correction cancels exactly and the step reduces to
            # z <- z -/+ eta * gbar.  Saves one full gradient evaluation per
            # round — (K+1) -> K evals — with bitwise-identical iterates.
            def bstep(zs, gbar, sign):
                return jax.tree.map(
                    lambda u, gb: u + sign * eta * gb[None].astype(u.dtype),
                    zs, gbar,
                )

            xs = bstep(xs, gbar_x, -1.0)
            ys = bstep(ys, gbar_y, +1.0)
            if constrain_agents is not None:
                xs, ys = constrain_agents(xs, ys)
            inner_steps = num_local_steps - 1

        if inner_steps > 0:
            (xs, ys), _ = jax.lax.scan(
                inner, (xs, ys), None, length=inner_steps
            )
        x1 = proj_x(tree_mean_over_agents(xs))
        y1 = proj_y(tree_mean_over_agents(ys))
        return x1, y1

    return round


def communication_bytes_per_round(
    x: Pytree, y: Pytree, algorithm, num_local_steps: int
) -> int:
    """Analytic bytes exchanged with the server per communication round.

    Counted as payload bytes a single agent up/downloads (the star-topology
    cost model of the paper; the SPMD all-reduce realization is measured
    separately from HLO in the dry-run).  `algorithm` is a legacy name
    ("gda" | "local_sgda" | "fedgda_gt" | ...) or any `CommStrategy`; the
    per-strategy payload models live in `repro.fed.strategies`.
    """
    from ..fed.strategies import resolve_strategy

    return resolve_strategy(algorithm).bytes_per_round(x, y, num_local_steps)
