"""Projection operators Proj_X / Proj_Y (Assumption 3 feasible sets)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


def l2_ball_proj(radius: float):
    """Projection onto {p : ||p||_2 <= radius} over the *whole* pytree."""

    def proj(p: Pytree) -> Pytree:
        sq = jax.tree.reduce(
            jnp.add, jax.tree.map(lambda u: jnp.sum(u.astype(jnp.float32) ** 2), p)
        )
        norm = jnp.sqrt(jnp.maximum(sq, 1e-30))
        scale = jnp.minimum(1.0, radius / norm)
        return jax.tree.map(lambda u: (u * scale).astype(u.dtype), p)

    return proj


def box_proj(lo: float, hi: float):
    """Per-coordinate clipping onto [lo, hi]^d."""

    def proj(p: Pytree) -> Pytree:
        return jax.tree.map(lambda u: jnp.clip(u, lo, hi), p)

    return proj


def simplex_proj():
    """Projection of a single 1-D array onto the probability simplex
    (used for agnostic-FL style mixture weights, Appendix A.2)."""

    def proj_vec(v: jax.Array) -> jax.Array:
        n = v.shape[0]
        u = jnp.sort(v)[::-1]
        css = jnp.cumsum(u)
        ks = jnp.arange(1, n + 1, dtype=v.dtype)
        cond = u - (css - 1.0) / ks > 0
        rho = jnp.max(jnp.where(cond, jnp.arange(n), -1))
        theta = (css[rho] - 1.0) / (rho + 1.0)
        return jnp.maximum(v - theta, 0.0)

    def proj(p: Pytree) -> Pytree:
        return jax.tree.map(proj_vec, p)

    return proj
