"""Generalization bounds for distributed minimax learning (Section 4).

Implements:
  * Monte-Carlo estimation of the distributed Rademacher complexity (Eq. 8)
      R(X, y) = E_sigma sup_{x in X} (1/mn) sum_ij sigma_ij l(x, y; xi_ij)
    with the sup taken over a finite candidate set of x's (exact for finite
    hypothesis classes; a lower bound otherwise).
  * The Theorem-2 high-probability bound assembly.
  * The Lemma-3 VC-dimension bound on R(X, Y).
  * `generalization_gap` — the MEASURED train/held-out risk gap the
    bounds control, tracked per round by `benchmarks/generalization.py`
    for the stochastic strategy family.
"""
from __future__ import annotations

import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

Pytree = jax.Array  # candidates are stacked along axis 0


def empirical_rademacher(
    loss_matrix_fn: Callable[[jax.Array], jax.Array],
    num_candidates: int,
    m: int,
    n: int,
    key: jax.Array,
    num_mc: int = 256,
) -> jax.Array:
    """E_sigma sup_x (1/mn) sum_ij sigma_ij l(x, y; xi_ij).

    loss_matrix_fn(candidate_index_batch) must return the loss matrix
    [num_candidates, m, n] evaluated at fixed y over the dataset; we only
    need it once.
    """
    L = loss_matrix_fn(jnp.arange(num_candidates))  # [C, m, n]
    L = L.reshape(num_candidates, m * n)

    def one(key):
        sigma = jax.random.rademacher(key, (m * n,), dtype=L.dtype)
        corr = L @ sigma / (m * n)  # [C]
        return jnp.max(corr)

    keys = jax.random.split(key, num_mc)
    return jnp.mean(jax.vmap(one)(keys))


def theorem2_bound(
    empirical_risk: float,
    rademacher: float,
    M_i: Sequence[float],
    n: int,
    cover_size: int,
    delta: float,
    L_y: float,
    eps: float,
) -> float:
    """RHS of Eq. (10):  f + 2 R(X,y) + sqrt(sum_i M_i^2/(2 m^2 n) log(|Y_eps|/delta)) + 2 L_y eps."""
    m = len(M_i)
    conc = math.sqrt(
        sum(Mi**2 for Mi in M_i) / (2.0 * m * m * n) * math.log(cover_size / delta)
    )
    return float(empirical_risk + 2.0 * rademacher + conc + 2.0 * L_y * eps)


def lemma3_vc_bound(M_i: Sequence[float], n: int, vc_dim: int) -> float:
    """RHS of Eq. (12):  sqrt(2 d max_y sum_i M_i^2/(m^2 n) (1 + log(mn/d)))."""
    m = len(M_i)
    s = sum(Mi**2 for Mi in M_i) / (m * m * n)
    return math.sqrt(2.0 * vc_dim * s * (1.0 + math.log(m * n / vc_dim)))


def generalization_gap(
    loss: Callable,
    train_data,
    test_data,
) -> Callable:
    """Measured counterpart of the Section-4 bounds: returns
    gap(x, y) = R_test(x, y) - R_train(x, y), where each risk is the
    mean over agents of the per-agent loss on that split.

    Only meaningful when the loss is an empirical RISK on both splits
    (same per-sample-mean scale) — e.g. problems built by
    `problems.quadratic.make_dirichlet_quadratic_problem`, whose
    sufficient statistics are per-sample means.  Both data pytrees must
    be agent-stacked ([m, ...] leaves) with the same m."""
    vloss = jax.vmap(loss, in_axes=(None, None, 0))

    def gap(x, y):
        return jnp.mean(vloss(x, y, test_data)) - jnp.mean(
            vloss(x, y, train_data)
        )

    return gap


def l2_cover_size(radius: float, eps: float, dim: int) -> int:
    """Standard covering-number upper bound |Y_eps| <= (1 + 2 radius/eps)^dim
    for an l2 ball of given radius in R^dim."""
    return int(math.ceil((1.0 + 2.0 * radius / eps) ** dim))
