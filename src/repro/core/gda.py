"""Centralized (projected) Gradient Descent Ascent — the paper's baseline.

x^{t+1} = Proj_X(x^t - eta_x * grad_x f(x^t, y^t))
y^{t+1} = Proj_Y(y^t + eta_y * grad_y f(x^t, y^t))

with f(x,y) = (1/m) sum_i f_i(x,y).  Equivalent to Local SGDA with K=1
(Section 3.1 of the paper).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .engine import make_round
from .types import (
    LossFn,
    ProjFn,
    Pytree,
    grad_xy,
    identity_proj,
)


def make_gda_step(
    loss: LossFn,
    eta_x: float,
    eta_y: float,
    proj_x: ProjFn = identity_proj,
    proj_y: ProjFn = identity_proj,
) -> Callable:
    """One centralized GDA step over agent-stacked data — a one-step
    `FullSync` round of the unified engine."""
    from ..fed.strategies import FullSync

    return make_round(
        loss, FullSync(), 1, eta_x, eta_y, proj_x=proj_x, proj_y=proj_y
    )


def make_gda_step_reference(
    loss: LossFn,
    eta_x: float,
    eta_y: float,
    proj_x: ProjFn = identity_proj,
    proj_y: ProjFn = identity_proj,
) -> Callable:
    """Pre-engine implementation, kept verbatim as the differential-test
    oracle for the engine's FullSync path (tests/test_engine_parity.py)."""
    gfn = grad_xy(loss)

    def step(x: Pytree, y: Pytree, agent_data: Pytree):
        g = jax.vmap(gfn, in_axes=(None, None, 0))(x, y, agent_data)
        gx = jax.tree.map(lambda u: jnp.mean(u, axis=0), g.gx)
        gy = jax.tree.map(lambda u: jnp.mean(u, axis=0), g.gy)
        x1 = proj_x(jax.tree.map(lambda u, v: u - eta_x * v, x, gx))
        y1 = proj_y(jax.tree.map(lambda u, v: u + eta_y * v, y, gy))
        return x1, y1

    return step


def run_rounds(
    round_fn: Callable,
    x0: Pytree,
    y0: Pytree,
    agent_data: Pytree,
    num_rounds: int,
    metric_fn: Optional[Callable] = None,
):
    """Run `round_fn(x, y, agent_data) -> (x, y)` for num_rounds via lax.scan.

    Returns final (x, y) and stacked per-round metrics (metric_fn(x, y),
    evaluated on the *input* of each round, plus once at the end).
    """

    def body(carry, _):
        x, y = carry
        meas = metric_fn(x, y) if metric_fn is not None else None
        x1, y1 = round_fn(x, y, agent_data)
        return (x1, y1), meas

    (x, y), metrics = jax.lax.scan(body, (x0, y0), None, length=num_rounds)
    if metric_fn is not None:
        final = metric_fn(x, y)
        metrics = jax.tree.map(
            lambda hist, last: jnp.concatenate([hist, last[None]]), metrics, final
        )
    return (x, y), metrics
