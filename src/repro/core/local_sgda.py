"""Local SGDA (Algorithm 1, Deng & Mahdavi 2021) with full local gradients.

One communication round:
  each agent i starts from the server model (x^t, y^t) and performs K
  local GDA steps using ONLY its own gradient; the server then averages.

With constant stepsizes this has *incorrect* fixed points for K >= 2
(Proposition 1) — implemented here both as the paper's baseline and as the
subject of the fixed-point analysis in `fixed_point.py`.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .engine import make_round
from .types import (
    LossFn,
    ProjFn,
    Pytree,
    grad_xy,
    identity_proj,
    tree_broadcast_agents,
    tree_mean_over_agents,
)


def make_local_sgda_round(
    loss: LossFn,
    num_local_steps: int,
    eta_x: float,
    eta_y: float,
    proj_x: ProjFn = identity_proj,
    proj_y: ProjFn = identity_proj,
    constrain_agents=None,
) -> Callable:
    """Returns round(x, y, agent_data) -> (x, y) implementing Algorithm 1 —
    a `LocalOnly` round of the unified engine."""
    from ..fed.strategies import LocalOnly

    return make_round(
        loss,
        LocalOnly(),
        num_local_steps,
        eta_x,
        eta_y,
        proj_x=proj_x,
        proj_y=proj_y,
        constrain_agents=constrain_agents,
    )


def make_local_sgda_round_reference(
    loss: LossFn,
    num_local_steps: int,
    eta_x: float,
    eta_y: float,
    proj_x: ProjFn = identity_proj,
    proj_y: ProjFn = identity_proj,
    constrain_agents=None,
) -> Callable:
    """Pre-engine implementation, kept verbatim as the differential-test
    oracle for the engine's LocalOnly path (tests/test_engine_parity.py)."""
    gfn = grad_xy(loss)
    vgrad = jax.vmap(gfn, in_axes=(0, 0, 0))

    def round(x: Pytree, y: Pytree, agent_data: Pytree):
        m = jax.tree.leaves(agent_data)[0].shape[0]
        xs = tree_broadcast_agents(x, m)
        ys = tree_broadcast_agents(y, m)
        if constrain_agents is not None:
            xs, ys = constrain_agents(xs, ys)

        def inner(carry, _):
            xs, ys = carry
            g = vgrad(xs, ys, agent_data)
            xs = jax.tree.map(lambda u, v: u - eta_x * v, xs, g.gx)
            ys = jax.tree.map(lambda u, v: u + eta_y * v, ys, g.gy)
            return (xs, ys), None

        (xs, ys), _ = jax.lax.scan(
            inner, (xs, ys), None, length=num_local_steps
        )
        x1 = proj_x(tree_mean_over_agents(xs))
        y1 = proj_y(tree_mean_over_agents(ys))
        return x1, y1

    return round


def make_scheduled_local_sgda_round(
    loss: LossFn,
    num_local_steps: int,
    proj_x: ProjFn = identity_proj,
    proj_y: ProjFn = identity_proj,
) -> Callable:
    """Local SGDA with the stepsize as a CALL-TIME argument:
    round(x, y, agent_data, eta) -> (x, y).

    This is the regime of [25, 26]: with a diminishing eta_t, Local SGDA
    converges to the exact solution — sublinearly (the accurate-but-slow
    branch of the paper's tradeoff, cf. the constant-stepsize bias floor
    of Proposition 1).  One jitted program serves every round because eta
    is traced, not baked in."""
    gfn = grad_xy(loss)
    vgrad = jax.vmap(gfn, in_axes=(0, 0, 0))

    def round(x: Pytree, y: Pytree, agent_data: Pytree, eta):
        m = jax.tree.leaves(agent_data)[0].shape[0]
        xs = tree_broadcast_agents(x, m)
        ys = tree_broadcast_agents(y, m)

        def inner(carry, _):
            xs, ys = carry
            g = vgrad(xs, ys, agent_data)
            xs = jax.tree.map(lambda u, v: u - eta * v, xs, g.gx)
            ys = jax.tree.map(lambda u, v: u + eta * v, ys, g.gy)
            return (xs, ys), None

        (xs, ys), _ = jax.lax.scan(
            inner, (xs, ys), None, length=num_local_steps
        )
        return proj_x(tree_mean_over_agents(xs)), proj_y(tree_mean_over_agents(ys))

    return round
