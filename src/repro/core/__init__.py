"""Core federated minimax algorithms (the paper's contribution)."""
from .types import (
    MinimaxProblem,
    SaddleField,
    grad_xy,
    identity_proj,
    tree_broadcast_agents,
    tree_mean_over_agents,
    tree_sq_dist,
)
from .projections import l2_ball_proj, box_proj, simplex_proj
from .engine import (
    RoundPhases,
    RoundState,
    agent_mean,
    agent_weighted_sum,
    anchor_step,
    default_update,
    make_noise_vgrad,
    make_phases,
    make_round,
    noise_eval_keys,
    run_strategy_rounds,
    tracking_corrections,
)
from .gda import make_gda_step, make_gda_step_reference, run_rounds
from .local_sgda import (
    make_local_sgda_round,
    make_local_sgda_round_reference,
    make_scheduled_local_sgda_round,
)
from .fedgda_gt import (
    communication_bytes_per_round,
    make_fedgda_gt_round,
    make_fedgda_gt_round_reference,
)
from .fixed_point import (
    APPENDIX_C_MINIMAX_POINT,
    appendix_c_fixed_point,
    prop1_residual,
)
from .generalization import (
    empirical_rademacher,
    generalization_gap,
    lemma3_vc_bound,
    theorem2_bound,
)

__all__ = [
    "MinimaxProblem",
    "SaddleField",
    "grad_xy",
    "identity_proj",
    "tree_broadcast_agents",
    "tree_mean_over_agents",
    "tree_sq_dist",
    "l2_ball_proj",
    "box_proj",
    "simplex_proj",
    "RoundPhases",
    "RoundState",
    "agent_mean",
    "agent_weighted_sum",
    "anchor_step",
    "default_update",
    "make_noise_vgrad",
    "make_phases",
    "make_round",
    "noise_eval_keys",
    "run_strategy_rounds",
    "tracking_corrections",
    "make_gda_step",
    "make_gda_step_reference",
    "run_rounds",
    "make_local_sgda_round",
    "make_local_sgda_round_reference",
    "make_scheduled_local_sgda_round",
    "make_fedgda_gt_round",
    "make_fedgda_gt_round_reference",
    "communication_bytes_per_round",
    "APPENDIX_C_MINIMAX_POINT",
    "appendix_c_fixed_point",
    "prop1_residual",
    "empirical_rademacher",
    "generalization_gap",
    "lemma3_vc_bound",
    "theorem2_bound",
]
