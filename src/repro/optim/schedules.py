"""Stepsize schedules.

The paper's point is that FedGDA-GT admits a CONSTANT stepsize (Theorem 1)
while Local SGDA needs a diminishing one for exact convergence; both are
provided so benchmarks can compare the regimes.
"""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(eta: float):
    return lambda t: jnp.asarray(eta)


def diminishing_schedule(eta0: float, decay: float = 1.0):
    """eta_t = eta0 / (1 + decay * t)  — the O(1/t) rate used by Local SGDA
    analyses [25, 26]."""
    return lambda t: eta0 / (1.0 + decay * t)
