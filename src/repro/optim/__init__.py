from .schedules import constant_schedule, diminishing_schedule
from .momentum import make_momentum_fedgda_gt_round

__all__ = [
    "constant_schedule",
    "diminishing_schedule",
    "make_momentum_fedgda_gt_round",
]
