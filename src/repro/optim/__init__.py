from .schedules import constant_schedule, diminishing_schedule
from .momentum import heavy_ball, make_momentum_fedgda_gt_round

__all__ = [
    "constant_schedule",
    "diminishing_schedule",
    "heavy_ball",
    "make_momentum_fedgda_gt_round",
]
