"""Heavy-ball momentum: the shared velocity primitive + the server variant.

`heavy_ball` is the one leafwise recurrence ``v <- beta * v + g`` both
momentum schedules in the codebase run on:

  * the INNER (local) loop of Local SGDA+ (Sharma et al. 2022) — the
    engine's momentum local steps (`core.engine.make_phases` imports it
    lazily, only when `strategy.momentum` is nonzero, so the
    momentum-free trace carries no velocity primitives and stays
    bitwise-pinned);
  * the OUTER (server) update below — a beyond-paper FedAvgM-style
    acceleration applied to the round increment while keeping the inner
    GT loop untouched, so Theorem 1's inner-loop analysis still applies
    round-wise.  OFF by default everywhere; benchmarked in EXPERIMENTS
    §Perf as a beyond-paper optimization.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..core.fedgda_gt import make_fedgda_gt_round
from ..core.types import LossFn, ProjFn, Pytree, identity_proj


def heavy_ball(v: Pytree, g: Pytree, beta: float) -> Pytree:
    """One leafwise heavy-ball velocity update: ``v <- beta * v + g``.

    Pure pytree algebra with no core imports beyond types, so the engine
    can pull it in lazily without creating an import cycle."""
    return jax.tree.map(lambda vv, gg: beta * vv + gg, v, g)


def make_momentum_fedgda_gt_round(
    loss: LossFn,
    num_local_steps: int,
    eta: float,
    beta: float = 0.9,
    proj_x: ProjFn = identity_proj,
    proj_y: ProjFn = identity_proj,
) -> Callable:
    """Returns round((x, y, vel), agent_data) -> (x, y, vel).

    vel is a pytree pair (vx, vy) of server-side velocities.
    """
    base = make_fedgda_gt_round(
        loss, num_local_steps, eta, identity_proj, identity_proj
    )

    def round(state, agent_data):
        x, y, (vx, vy) = state
        x1, y1 = base(x, y, agent_data)
        dx = jax.tree.map(jnp.subtract, x1, x)
        dy = jax.tree.map(jnp.subtract, y1, y)
        vx = heavy_ball(vx, dx, beta)
        vy = heavy_ball(vy, dy, beta)
        x2 = proj_x(jax.tree.map(jnp.add, x, vx))
        y2 = proj_y(jax.tree.map(jnp.add, y, vy))
        return (x2, y2, (vx, vy))

    def init_velocity(x: Pytree, y: Pytree):
        return (
            jax.tree.map(jnp.zeros_like, x),
            jax.tree.map(jnp.zeros_like, y),
        )

    round.init_velocity = init_velocity
    return round
