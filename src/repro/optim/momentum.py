"""Beyond-paper variant: heavy-ball momentum on the *outer* (server) update.

The paper's Algorithm 2 aggregates by plain averaging.  Server momentum is a
standard FL acceleration (e.g. FedAvgM); here it is applied to the round
increment while keeping the inner GT loop untouched, so Theorem 1's
inner-loop analysis still applies round-wise.  OFF by default everywhere;
benchmarked in EXPERIMENTS §Perf as a beyond-paper optimization.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..core.fedgda_gt import make_fedgda_gt_round
from ..core.types import LossFn, ProjFn, Pytree, identity_proj


def make_momentum_fedgda_gt_round(
    loss: LossFn,
    num_local_steps: int,
    eta: float,
    beta: float = 0.9,
    proj_x: ProjFn = identity_proj,
    proj_y: ProjFn = identity_proj,
) -> Callable:
    """Returns round((x, y, vel), agent_data) -> (x, y, vel).

    vel is a pytree pair (vx, vy) of server-side velocities.
    """
    base = make_fedgda_gt_round(
        loss, num_local_steps, eta, identity_proj, identity_proj
    )

    def round(state, agent_data):
        x, y, (vx, vy) = state
        x1, y1 = base(x, y, agent_data)
        dx = jax.tree.map(jnp.subtract, x1, x)
        dy = jax.tree.map(jnp.subtract, y1, y)
        vx = jax.tree.map(lambda v, d: beta * v + d, vx, dx)
        vy = jax.tree.map(lambda v, d: beta * v + d, vy, dy)
        x2 = proj_x(jax.tree.map(jnp.add, x, vx))
        y2 = proj_y(jax.tree.map(jnp.add, y, vy))
        return (x2, y2, (vx, vy))

    def init_velocity(x: Pytree, y: Pytree):
        return (
            jax.tree.map(jnp.zeros_like, x),
            jax.tree.map(jnp.zeros_like, y),
        )

    round.init_velocity = init_velocity
    return round
