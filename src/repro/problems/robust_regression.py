"""Robust linear regression — paper Section 5.2, Eq. (14).

  f_i(x, y) = (1/n_i) sum_j (x^T (a_ij + y) - b_ij)^2 + 1/2 ||x||^2,
  solved as  min_x max_{||y|| <= 1} (1/m) sum_i f_i(x, y).

Data generation follows the paper: local model x_i* ~ MVN(0, I);
b_ij = x_i*^T a_ij + eps_j, eps ~ N(0,1); a_ij ~ N(mu_i, K_i) with
mu_i ~ N(c_i, I), K_i = i^{-1.3} I, c_i entries ~ N(0, alpha^2).
alpha controls heterogeneity (paper uses alpha in {1, 5, 20}).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.projections import l2_ball_proj
from ..core.types import MinimaxProblem


def _loss(x, y, data):
    a, b = data["a"], data["b"]
    pred = (a + y[None, :]) @ x
    return jnp.mean((pred - b) ** 2) + 0.5 * jnp.sum(x**2)


def make_robust_regression_problem(
    key: jax.Array,
    dim: int = 50,
    num_samples: int = 200,
    num_agents: int = 20,
    alpha: float = 5.0,
    noise_radius: float = 1.0,
    dtype=jnp.float64,
) -> MinimaxProblem:
    k_xstar, k_c, k_mu, k_a, k_eps = jax.random.split(key, 5)
    x_star = jax.random.normal(k_xstar, (num_agents, dim), dtype=dtype)
    c = alpha * jax.random.normal(k_c, (num_agents, dim), dtype=dtype)
    mu = c + jax.random.normal(k_mu, (num_agents, dim), dtype=dtype)
    cov_scale = jnp.arange(1, num_agents + 1, dtype=dtype) ** (-0.65)  # sqrt(i^-1.3)
    a = (
        mu[:, None, :]
        + jax.random.normal(k_a, (num_agents, num_samples, dim), dtype=dtype)
        * cov_scale[:, None, None]
    )
    eps = jax.random.normal(k_eps, (num_agents, num_samples), dtype=dtype)
    b = jnp.einsum("mnd,md->mn", a, x_star) + eps

    return MinimaxProblem(
        loss=_loss,
        agent_data={"a": a, "b": b},
        num_agents=num_agents,
        proj_y=l2_ball_proj(noise_radius),
    )


def robust_loss(
    problem: MinimaxProblem,
    x: jax.Array,
    num_ascent_steps: int = 2000,
    eta: float = 1e-3,
    noise_radius: float = 1.0,
) -> jax.Array:
    """Worst-case robust loss  max_{||y||<=1} sum_i f_i(x, y)  (paper's metric;
    note the paper sums rather than averages here).  Solved by projected
    gradient ascent to convergence (the inner problem is concave? — it is a
    quadratic in y, maximized on a compact ball, so PGA with small eta works).
    """
    proj = l2_ball_proj(noise_radius)

    def total(y):
        per_agent = jax.vmap(problem.loss, in_axes=(None, None, 0))(
            x, y, problem.agent_data
        )
        return jnp.sum(per_agent)

    g = jax.grad(total)

    def body(y, _):
        y = proj(y + eta * g(y))
        return y, None

    y0 = jnp.zeros(x.shape, x.dtype)
    y, _ = jax.lax.scan(body, y0, None, length=num_ascent_steps)
    return total(y)
