"""Adversarial-embedding minimax objective for the assigned architectures.

The paper's robust-regression instantiation (Eq. 14) lifted to sequence
models:  min_params  max_{||delta|| <= eps}  (1/m) sum_i CE_i(params, delta)
where delta in R^{d_model} perturbs every input embedding (a universal
adversarial perturbation).  x = params pytree, y = {"delta": [d_model]}.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.projections import l2_ball_proj
from ..models import chunked_lm_loss, embed_inputs, forward

Pytree = Any


def make_adversarial_loss(
    cfg: ModelConfig,
    remat: bool = True,
    aux_weight: float = 0.0,
    h_sharding=None,
):
    """Returns loss(params, y, batch) -> scalar for one agent's batch."""

    def loss(params: Pytree, y: Dict, batch: Dict) -> jax.Array:
        h = embed_inputs(params, cfg, batch)
        h = h + y["delta"].astype(h.dtype)
        h, _, aux = forward(params, cfg, h, remat=remat, h_sharding=h_sharding)
        labels = batch["labels"]
        if cfg.causal and cfg.frontend != "audio":
            pass  # labels already next-token aligned by the data pipeline
        out = chunked_lm_loss(params, cfg, h, labels)
        if aux_weight:
            out = out + aux_weight * aux
        return out

    return loss


def init_delta(cfg: ModelConfig, dtype=jnp.float32) -> Dict:
    return {"delta": jnp.zeros((cfg.d_model,), dtype)}


def delta_projection(radius: float = 1.0):
    return l2_ball_proj(radius)
