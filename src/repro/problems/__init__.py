"""Concrete federated minimax problems (the paper's experiments + the
adversarial-LM instantiation used by the assigned architectures)."""
from .quadratic import (
    make_dirichlet_quadratic_problem,
    make_quadratic_problem,
    quadratic_minimax_point,
)
from .robust_regression import (
    make_robust_regression_problem,
    robust_loss,
)
from .toy import make_appendix_c_problem
from .agnostic import (
    make_agnostic_problem,
    per_agent_risks,
    uniform_lambda,
)

__all__ = [
    "make_dirichlet_quadratic_problem",
    "make_quadratic_problem",
    "quadratic_minimax_point",
    "make_robust_regression_problem",
    "robust_loss",
    "make_appendix_c_problem",
    "make_agnostic_problem",
    "per_agent_risks",
    "uniform_lambda",
]
