"""Agnostic Federated Learning — paper Appendix A.2 (Mohri et al. [13]).

  min_theta  max_{lambda in simplex}  sum_i lambda_i R_i(theta)

cast into the paper's average form (Eq. 1) via  f_i(x, y) = m * y_i * R_i(x)
so that (1/m) sum_i f_i = sum_i y_i R_i.  x = theta (model), y = lambda
(mixture weights on the m-simplex, Proj_Y = simplex projection).  The
adversary upweights the worst-off agent; the solution is the minimax-fair
model over agent distributions.

Local risks here are ridge-regularized linear regression on per-agent data
(strongly convex in x; linear — concave — in y, so the projected ascent is
exact on the simplex)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..core.projections import simplex_proj
from ..core.types import MinimaxProblem


def _loss(x, y, data):
    a, b, idx, m = data["a"], data["b"], data["agent_index"], data["m"]
    pred = a @ x
    risk = jnp.mean((pred - b) ** 2) + 0.05 * jnp.sum(x**2)
    return m * y[idx] * risk


def make_agnostic_problem(
    key: jax.Array,
    dim: int = 10,
    num_samples: int = 100,
    num_agents: int = 5,
    shift: float = 2.0,
    dtype=jnp.float64,
) -> MinimaxProblem:
    """Heterogeneous agents with CONFLICTING true models: agent i labels
    with x_true + (i/m)*shift*e_0, so no single model fits everyone and a
    uniform average underserves the extreme agents — the setting where
    agnostic reweighting matters (Mohri et al. §1)."""
    kx, ka, ke = jax.random.split(key, 3)
    x_true = jax.random.normal(kx, (dim,), dtype)
    disagree = (
        jnp.arange(num_agents, dtype=dtype)[:, None]
        * (shift / num_agents)
        * jnp.eye(dim, dtype=dtype)[0][None, :]
    )
    x_agents = x_true[None, :] + disagree  # [m, dim]
    a = jax.random.normal(ka, (num_agents, num_samples, dim), dtype)
    b = jnp.einsum("mnd,md->mn", a, x_agents)
    b = b + 0.1 * jax.random.normal(ke, b.shape, dtype)
    data = {
        "a": a,
        "b": b,
        "agent_index": jnp.arange(num_agents, dtype=jnp.int32),
        "m": jnp.full((num_agents,), float(num_agents), dtype),
    }
    return MinimaxProblem(
        loss=_loss,
        agent_data=data,
        num_agents=num_agents,
        proj_y=simplex_proj(),
    )


def per_agent_risks(problem: MinimaxProblem, x: jax.Array) -> jax.Array:
    """R_i(x) for every agent (the quantities lambda* weights)."""

    def risk(data):
        pred = data["a"] @ x
        return jnp.mean((pred - data["b"]) ** 2) + 0.05 * jnp.sum(x**2)

    return jax.vmap(risk)(problem.agent_data)


def uniform_lambda(num_agents: int, dtype=jnp.float64) -> jax.Array:
    return jnp.full((num_agents,), 1.0 / num_agents, dtype)
