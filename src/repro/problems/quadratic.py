"""Uncoupled quadratic minimax game — paper Section 5.1, Eq. (13).

  f_i(x, y) = 1/2 x^T A_i^T A_i x - 1/2 y^T A_i^T A_i y + (A_i^T b_i)^T (2x - y)

Data generation follows the paper exactly:
  [A_i]_kl ~ N(0, (0.5 i)^-2);  theta_i ~ N(mu_i, I);  mu_i entries ~ N(alpha, 1)
  with alpha ~ N(0, 100);  b_i = A_i theta_i + eps_i,  eps_i ~ N(0, 0.25 I).
Defaults: d = 50, n_i = 500, m = 20 agents.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..core.types import MinimaxProblem


def _loss(x, y, data):
    G, Ab = data["G"], data["Ab"]
    return (
        0.5 * x @ G @ x
        - 0.5 * y @ G @ y
        + Ab @ (2.0 * x - y)
    )


def make_quadratic_problem(
    key: jax.Array,
    dim: int = 50,
    num_samples: int = 500,
    num_agents: int = 20,
    dtype=jnp.float64,
) -> MinimaxProblem:
    k_alpha, k_mu, k_theta, k_A, k_eps = jax.random.split(key, 5)
    alpha = 10.0 * jax.random.normal(k_alpha, (), dtype=dtype)  # N(0, 100)
    mu = alpha + jax.random.normal(k_mu, (num_agents, dim), dtype=dtype)
    theta = mu + jax.random.normal(k_theta, (num_agents, dim), dtype=dtype)
    std = 2.0 / jnp.arange(1, num_agents + 1, dtype=dtype)  # (0.5 i)^{-1}
    A = (
        jax.random.normal(k_A, (num_agents, num_samples, dim), dtype=dtype)
        * std[:, None, None]
    )
    eps = 0.5 * jax.random.normal(k_eps, (num_agents, num_samples), dtype=dtype)
    b = jnp.einsum("mnd,md->mn", A, theta) + eps

    G = jnp.einsum("mnd,mne->mde", A, A)  # A_i^T A_i, [m, d, d]
    Ab = jnp.einsum("mnd,mn->md", A, b)  # A_i^T b_i,   [m, d]
    return MinimaxProblem(
        loss=_loss, agent_data={"G": G, "Ab": Ab}, num_agents=num_agents
    )


def quadratic_minimax_point(problem: MinimaxProblem) -> Tuple[jax.Array, jax.Array]:
    """Closed-form minimax point:
    grad_x f = Gbar x + 2 Abbar = 0  ->  x* = -2 Gbar^{-1} Abbar
    grad_y f = -Gbar y - Abbar = 0   ->  y* = -  Gbar^{-1} Abbar
    """
    Gbar = jnp.mean(problem.agent_data["G"], axis=0)
    Abbar = jnp.mean(problem.agent_data["Ab"], axis=0)
    sol = jnp.linalg.solve(Gbar, Abbar)
    return -2.0 * sol, -sol
