"""Uncoupled quadratic minimax game — paper Section 5.1, Eq. (13).

  f_i(x, y) = 1/2 x^T A_i^T A_i x - 1/2 y^T A_i^T A_i y + (A_i^T b_i)^T (2x - y)

Data generation follows the paper exactly:
  [A_i]_kl ~ N(0, (0.5 i)^-2);  theta_i ~ N(mu_i, I);  mu_i entries ~ N(alpha, 1)
  with alpha ~ N(0, 100);  b_i = A_i theta_i + eps_i,  eps_i ~ N(0, 0.25 I).
Defaults: d = 50, n_i = 500, m = 20 agents.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..core.types import MinimaxProblem


def _loss(x, y, data):
    G, Ab = data["G"], data["Ab"]
    return (
        0.5 * x @ G @ x
        - 0.5 * y @ G @ y
        + Ab @ (2.0 * x - y)
    )


def make_quadratic_problem(
    key: jax.Array,
    dim: int = 50,
    num_samples: int = 500,
    num_agents: int = 20,
    dtype=jnp.float64,
) -> MinimaxProblem:
    k_alpha, k_mu, k_theta, k_A, k_eps = jax.random.split(key, 5)
    alpha = 10.0 * jax.random.normal(k_alpha, (), dtype=dtype)  # N(0, 100)
    mu = alpha + jax.random.normal(k_mu, (num_agents, dim), dtype=dtype)
    theta = mu + jax.random.normal(k_theta, (num_agents, dim), dtype=dtype)
    std = 2.0 / jnp.arange(1, num_agents + 1, dtype=dtype)  # (0.5 i)^{-1}
    A = (
        jax.random.normal(k_A, (num_agents, num_samples, dim), dtype=dtype)
        * std[:, None, None]
    )
    eps = 0.5 * jax.random.normal(k_eps, (num_agents, num_samples), dtype=dtype)
    b = jnp.einsum("mnd,md->mn", A, theta) + eps

    G = jnp.einsum("mnd,mne->mde", A, A)  # A_i^T A_i, [m, d, d]
    Ab = jnp.einsum("mnd,mn->md", A, b)  # A_i^T b_i,   [m, d]
    return MinimaxProblem(
        loss=_loss, agent_data={"G": G, "Ab": Ab}, num_agents=num_agents
    )


def _sufficient_stats(A, b):
    """Per-agent per-sample-MEAN sufficient statistics: G_i = A_i^T A_i / n,
    Ab_i = A_i^T b_i / n.  The 1/n makes the loss an empirical risk (mean
    over samples), so train and held-out risks are on the same scale and
    conditioning does not grow with the sample count."""
    n = A.shape[1]
    G = jnp.einsum("mnd,mne->mde", A, A) / n
    Ab = jnp.einsum("mnd,mn->md", A, b) / n
    return G, Ab


def make_dirichlet_quadratic_problem(
    key: jax.Array,
    dim: int = 20,
    num_samples: int = 100,
    num_agents: int = 10,
    alpha: float = 1.0,
    num_components: int = 4,
    test_samples: int = 0,
    dtype=jnp.float64,
):
    """Dirichlet-heterogeneous quadratic game with a held-out split.

    The population has `num_components` latent regression targets
    theta_c; agent i draws its mixture over components from
    Dirichlet(alpha) (`data.synthetic.dirichlet_partition_weights`),
    then each of its samples picks a component from that mixture:

        row A ~ N(0, I);  b = A theta_c + eps,  eps ~ N(0, 0.25).

    alpha -> 0 gives near-one-hot agents (maximal heterogeneity),
    alpha -> inf the iid limit; unlike `make_quadratic_problem`, A's
    row scale is agent-independent so alpha is the ONLY heterogeneity
    dial.  Sufficient statistics are per-sample MEANS (see
    `_sufficient_stats`), so the train risk and the held-out risk of
    `test_data` are directly comparable — that difference is the
    generalization gap (`core.generalization.generalization_gap`).

    Returns (problem, test_data, weights); `test_data` is None when
    `test_samples == 0`, `weights` is the [m, C] mixture matrix."""
    from ..data.synthetic import dirichlet_partition_weights

    k_w, k_theta, k_draw = jax.random.split(key, 3)
    weights = dirichlet_partition_weights(
        k_w, num_agents, num_components, alpha, dtype=dtype
    )
    theta = jax.random.normal(k_theta, (num_components, dim), dtype=dtype)

    def sample_split(k, n):
        k_c, k_A, k_eps = jax.random.split(k, 3)
        # [m, n] component index per sample, drawn from each agent's row
        comp = jax.vmap(
            lambda kk, w: jax.random.categorical(kk, jnp.log(w), shape=(n,))
        )(jax.random.split(k_c, num_agents), weights)
        A = jax.random.normal(k_A, (num_agents, n, dim), dtype=dtype)
        eps = 0.5 * jax.random.normal(k_eps, (num_agents, n), dtype=dtype)
        b = jnp.einsum("mnd,mnd->mn", A, theta[comp]) + eps
        G, Ab = _sufficient_stats(A, b)
        return {"G": G, "Ab": Ab}

    k_train, k_test = jax.random.split(k_draw)
    agent_data = sample_split(k_train, num_samples)
    test_data = sample_split(k_test, test_samples) if test_samples else None
    problem = MinimaxProblem(
        loss=_loss, agent_data=agent_data, num_agents=num_agents
    )
    return problem, test_data, weights


def quadratic_minimax_point(problem: MinimaxProblem) -> Tuple[jax.Array, jax.Array]:
    """Closed-form minimax point:
    grad_x f = Gbar x + 2 Abbar = 0  ->  x* = -2 Gbar^{-1} Abbar
    grad_y f = -Gbar y - Abbar = 0   ->  y* = -  Gbar^{-1} Abbar
    """
    Gbar = jnp.mean(problem.agent_data["G"], axis=0)
    Abbar = jnp.mean(problem.agent_data["Ab"], axis=0)
    sol = jnp.linalg.solve(Gbar, Abbar)
    return -2.0 * sol, -sol
