"""Appendix-C two-agent scalar example.

  f_1(x, y) = x^2 - y^2 - (x - y)
  f_2(x, y) = 4x^2 - 4y^2 - 32(x - y)

i.e. f_i = a_i x^2 - a_i y^2 - c_i (x - y) with a = (1, 4), c = (1, 32).
True minimax point: x* = y* = 3.3.  Local SGDA's constant-stepsize fixed
point is given in closed form by `core.fixed_point.appendix_c_fixed_point`.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.types import MinimaxProblem


def _loss(x, y, data):
    a, c = data["a"], data["c"]
    return a * x**2 - a * y**2 - c * (x - y)


def make_appendix_c_problem(dtype=jnp.float64) -> MinimaxProblem:
    data = {
        "a": jnp.array([1.0, 4.0], dtype=dtype),
        "c": jnp.array([1.0, 32.0], dtype=dtype),
    }
    return MinimaxProblem(loss=_loss, agent_data=data, num_agents=2)
