from .runtime import FederatedRunner, RoundStats
from .comm import comm_table

__all__ = ["FederatedRunner", "RoundStats", "comm_table"]
