from .runtime import FederatedRunner, RoundStats
from .async_runtime import AsyncFederatedRunner
from .comm import comm_table
from .noise import (
    GaussianNoise,
    MinibatchNoise,
    NoiseModel,
    noise_key,
    resolve_noise,
)
from .strategies import (
    CommStrategy,
    CompressedGT,
    FullSync,
    GradientTracking,
    LocalOnly,
    LocalSGDAPlus,
    PartialParticipation,
    QuantizedGT,
    SAGDA,
    resolve_strategy,
)
from .transport import (
    HEADER_BYTES,
    LeafPayload,
    LeafSpec,
    PackedTree,
    decode_leaf,
    encode_leaf,
    measured_bytes_per_round,
    wire_header_overhead,
)

__all__ = [
    "AsyncFederatedRunner",
    "FederatedRunner",
    "RoundStats",
    "comm_table",
    "CommStrategy",
    "CompressedGT",
    "FullSync",
    "GaussianNoise",
    "GradientTracking",
    "LocalOnly",
    "LocalSGDAPlus",
    "MinibatchNoise",
    "NoiseModel",
    "PartialParticipation",
    "QuantizedGT",
    "SAGDA",
    "noise_key",
    "resolve_noise",
    "resolve_strategy",
    "HEADER_BYTES",
    "LeafPayload",
    "LeafSpec",
    "PackedTree",
    "decode_leaf",
    "encode_leaf",
    "measured_bytes_per_round",
    "wire_header_overhead",
]
