from .runtime import FederatedRunner, RoundStats
from .comm import comm_table
from .strategies import (
    CommStrategy,
    CompressedGT,
    FullSync,
    GradientTracking,
    LocalOnly,
    PartialParticipation,
    QuantizedGT,
    resolve_strategy,
)

__all__ = [
    "FederatedRunner",
    "RoundStats",
    "comm_table",
    "CommStrategy",
    "CompressedGT",
    "FullSync",
    "GradientTracking",
    "LocalOnly",
    "PartialParticipation",
    "QuantizedGT",
    "resolve_strategy",
]
