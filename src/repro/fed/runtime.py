"""Synchronous federated round orchestration (single fused program).

`FederatedRunner` drives any round function produced by `repro.core` —
legacy constructors or the phase-split engine (`make_round`, the fused
composition of the `broadcast` / `exchange_corrections` / `local_steps` /
`aggregate` phases) with any `CommStrategy` — records per-round metrics
on the host, and periodically checkpoints; the single-host counterpart of
`repro.launch.train`.  Stateful strategies (client-sampling RNG,
error-feedback buffers) have their state initialized lazily on the first
round and threaded across rounds; build via `FederatedRunner.from_strategy`
for that path.  Stochastic strategies (a non-None `strategy.noise`) ride
the same state thread: `state["noise_key"]` is the dedicated noise
stream (`fed.noise.noise_key`), advanced once per round inside the
jitted round by `broadcast`, so checkpoint/resume replays the exact
noise sequence and the async runner — which samples the same stream
once server-side and slices per shard — consumes bit-identical draws.

This runner executes each round as ONE jitted program on the default
device: broadcast, exchange and K local steps lower together, so nothing
overlaps and strategy state is replicated.  Its asynchronous counterpart
— `repro.fed.async_runtime.AsyncFederatedRunner` — dispatches the same
phase functions per agent shard on separate devices, overlaps the
correction exchange with trailing local steps, and shards per-agent
strategy state; the two agree on iterates to fp tolerance
(tests/test_async_runtime.py).

Both runners also consume a `repro.sim.RoundSchedule` (`run(...,
schedule=...)`): per-round active sets and local-step budgets from a
seedable client population.  Non-full rounds execute the membership-
aware elastic round (`sim.make_elastic_round` — re-normalized weights,
tracker-table corrections, budget-gated local steps, EF re-anchoring
via the strategy's `rebase_state` hook; `rebase=False` is the
naive-server ablation).  A static-full schedule degenerates to the
unmodified legacy loop, so full participation stays bitwise identical
to running without a schedule (tests/test_elastic.py).

Both runners emit into an optional `repro.obs.Telemetry` sink
(`telemetry=...`): per-round spans, wire-byte counters
(`sim.per_agent_bytes` x the round's active count — the same
active-set-aware account `wire_report` prices), and sampled invariant
probes (`repro.obs.probes`).  `telemetry=None` (the default) runs the
pre-telemetry code verbatim — the sink lives entirely on the host, the
jitted round programs never change, and iterates stay bitwise identical
(tests/test_obs.py).  `Telemetry(phase_spans=True)` additionally lets a
strategy-built sync runner dispatch the four engine phases as SEPARATE
jitted programs for genuine per-phase wall-clock — matching the fused
round to fp tolerance by the phases contract (tests/test_phases.py: the
composition is the same math, only XLA's program partitioning differs).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint import save_checkpoint

Pytree = Any


@dataclasses.dataclass
class RoundStats:
    round_index: int
    metrics: Dict[str, float]
    seconds: float


class RunnerHistoryMixin:
    """Per-round history, the wire report, telemetry emission and the
    elastic-schedule driver shared by the sync and async runners."""

    history: List[RoundStats]
    #: optional `repro.obs.Telemetry` sink — PUBLIC so tests can flip it
    #: on an already-compiled runner; None runs the pre-telemetry code
    #: verbatim (the bitwise pin, tests/test_obs.py)
    telemetry = None
    #: remembered by `run(..., schedule=...)` so `wire_report` defaults
    #: to the schedule the run actually executed
    _last_schedule = None
    _num_local_steps: Optional[int] = None
    _loss: Optional[Callable] = None

    def metric_series(self, name: str) -> np.ndarray:
        available = sorted({k for s in self.history for k in s.metrics})
        if name not in available:
            # also on an EMPTY history: a silent empty array for any
            # name hides typos exactly when a run produced nothing
            raise ValueError(
                f"unknown metric {name!r}; available metric keys: "
                f"{available}"
            )
        return np.array([s.metrics[name] for s in self.history])

    def wire_report(
        self,
        x: Pytree,
        y: Pytree,
        num_local_steps: int,
        schedule=None,
        pods=None,
    ) -> Dict:
        """Priced vs measured per-round communication for this runner's
        strategy: the analytic `bytes_per_round` next to the probe of the
        actual packed buffer lengths (`transport.measured_bytes_per_round`,
        headers included).  Requires a strategy-built runner.

        On an elastic/sparse run the full-participation price is wrong —
        only the schedule's active agents move bytes — so with a
        schedule (passed explicitly, or remembered from the last
        `run(..., schedule=...)`) the report adds the active-set-aware
        account via `sim.schedule_bytes`: the per-ACTIVE-agent payload
        (`sim.per_agent_bytes` — participation patched to 1, membership
        comes from the schedule) and the scheduled totals."""
        if self._strategy is None:
            raise ValueError("wire_report needs a runner built from_strategy")
        from .transport import measured_bytes_per_round

        report = {
            "bytes_per_round": int(
                self._strategy.bytes_per_round(x, y, num_local_steps)
            ),
            "measured_bytes_per_round": measured_bytes_per_round(
                self._strategy, x, y, num_local_steps
            ),
        }
        if schedule is None:
            schedule = self._last_schedule
        if schedule is not None and not getattr(
            schedule, "is_static_full", False
        ):
            from ..sim.elastic import per_agent_bytes, schedule_bytes

            totals = schedule_bytes(
                self._strategy, x, y, num_local_steps, schedule, pods=pods
            )
            report["scheduled_per_agent_bytes"] = per_agent_bytes(
                self._strategy, x, y, num_local_steps
            )
            report["scheduled_total_bytes"] = int(np.sum(totals))
            report["scheduled_mean_bytes_per_round"] = float(np.mean(totals))
        return report

    # ------------------------------------------------------- telemetry
    def _telemetry_state(self) -> Optional[Dict]:
        """The strategy-state dict probes read (EF residual buffers);
        overridden by runners that hold state elsewhere (sharded)."""
        return getattr(self, "_state", None)

    def _wire_counter_args(self, x, y, scheduled: bool = True
                           ) -> Optional[int]:
        """Per-agent payload for the "wire_bytes" counter; None when the
        runner lacks the strategy/K context (raw-round runners).  On a
        scheduled (elastic) run membership comes from the schedule, so
        the payload is `sim.per_agent_bytes` (participation patched to
        1) — the same account `wire_report` and `sim.schedule_bytes`
        price.  Unscheduled, the strategy's OWN client sampling governs
        and the payload is `measured_bytes_per_round` as-is."""
        if self._strategy is None or self._num_local_steps is None:
            return None
        if scheduled:
            from ..sim.elastic import per_agent_bytes

            return per_agent_bytes(
                self._strategy, x, y, self._num_local_steps
            )
        from .transport import measured_bytes_per_round

        return int(measured_bytes_per_round(
            self._strategy, x, y, self._num_local_steps
        ))

    def _emit_wire_probe(self, tm, x, y) -> None:
        """One-shot priced-vs-measured probe at run start."""
        if (
            self._strategy is None
            or self._num_local_steps is None
            or not tm.probe_due("priced_vs_measured", 0)
        ):
            return
        from ..obs import probes as _p

        tm.probe_value(
            "priced_vs_measured",
            0,
            _p.priced_vs_measured(
                self._strategy, x, y, self._num_local_steps
            ),
        )

    def _emit_probes(self, tm, t, x, y, tracker=None) -> None:
        """Sampled invariant probes shared by both runners — pure
        functions from `repro.obs.probes` over state the runner already
        holds.  `tracker` is the elastic tracker table on an elastic
        round; without one the GT residual recomputes the anchor
        corrections from the loss (full participation only)."""
        from ..obs import probes as _p

        if tm.probe_due("gt_residual", t):
            if tracker is not None:
                if tracker.get("gx") is not None:
                    cx, cy = _p.corrections_from_table(
                        tracker["gx"], tracker["gy"]
                    )
                    tm.probe_value("gt_residual", t, _p.gt_residual(cx, cy))
            elif (
                self._loss is not None
                and getattr(self._strategy, "use_correction", False)
                and getattr(self, "_agent_data", None) is not None
            ):
                from ..core.types import grad_xy

                cx, cy = _p.anchor_corrections(
                    grad_xy(self._loss), x, y, self._agent_data
                )
                tm.probe_value("gt_residual", t, _p.gt_residual(cx, cy))
        if tm.probe_due("ef_residual", t):
            norms = _p.ef_residual_norms(self._telemetry_state())
            if norms:
                tm.probe_value("ef_residual", t, norms)
        if tm.gap_fn is not None and tm.probe_due("duality_gap", t):
            tm.probe_value(
                "duality_gap", t, _p.duality_gap(tm.gap_fn, x, y)
            )

    def _drive_elastic(
        self,
        x,
        y,
        num_rounds: int,
        schedule,
        rebase: bool,
        log_every: int,
        elastic_state,
        init_tracker_fn: Callable,
        round_fn: Callable,
        label: str,
        checkpoint_fn: Optional[Callable] = None,
        num_agents: Optional[int] = None,
    ):
        """ONE owner of the elastic run loop for both runtimes:
        schedule validation, the `ElasticAggregator`, tracker +
        prev_active continuation (`elastic_state` — resuming without it
        re-anchors absent agents' trackers at the resume iterate and
        forgets who participated last round), per-round `n_active`
        metrics, history, logging and optional checkpointing.  The
        runners differ only in `round_fn(x, y, ev, agg, tracker,
        prev_active) -> (x, y, tracker)` — the fused elastic round vs
        per-shard dispatch."""
        import jax.numpy as jnp

        from ..sim.elastic import ElasticAggregator

        if len(schedule) < num_rounds:
            raise ValueError(
                f"schedule covers {len(schedule)} rounds, need {num_rounds}"
            )
        if num_agents is not None and schedule.m != num_agents:
            # a larger-m schedule would renormalize weights over agents
            # that don't exist and then silently lose their mass when
            # the runner slices — exactly the naive-server failure mode
            raise ValueError(
                f"schedule is for m={schedule.m} agents, runner has "
                f"{num_agents}"
            )
        agg = ElasticAggregator(self._strategy, rebase=rebase)
        if elastic_state is not None:
            tracker = elastic_state["tracker"]
            prev_active = elastic_state.get("prev_active")
        else:
            tracker = init_tracker_fn(x, y)
            prev_active = None
        tm = self.telemetry
        per_agent = None
        if tm is not None:
            self._emit_wire_probe(tm, x, y)
            per_agent = self._wire_counter_args(x, y)
        for t in range(num_rounds):
            t0 = time.perf_counter()
            ev = schedule[t]
            if tm is not None:
                tm.begin_round(t)
            x, y, tracker = round_fn(x, y, ev, agg, tracker, prev_active)
            prev_active = jnp.asarray(ev.active)
            metrics = {"n_active": float(ev.num_active)}
            if self._metric_fn is not None:
                metrics.update(
                    {k: float(v) for k, v in self._metric_fn(x, y).items()}
                )
            dt = time.perf_counter() - t0
            self.history.append(RoundStats(t, metrics, dt))
            if tm is not None:
                tm.round_event(
                    t, runtime=label, seconds=dt,
                    n_active=int(ev.num_active),
                )
                if per_agent is not None:
                    tm.counter(
                        "wire_bytes", per_agent * int(ev.num_active),
                        per_agent=per_agent, n_active=int(ev.num_active),
                    )
                self._emit_probes(tm, t, x, y, tracker=tracker)
                tm.end_round(t)
            if log_every and (t % log_every == 0 or t == num_rounds - 1):
                msg = " ".join(f"{k}={v:.3e}" for k, v in metrics.items())
                print(f"[{label} {t:5d}] {msg} ({dt*1e3:.1f} ms)")
            if checkpoint_fn is not None:
                checkpoint_fn(t, x, y, tracker, prev_active)
        #: where the run left off, for continuation:
        #: run(..., elastic_state=runner.elastic_state, schedule=tail)
        self.elastic_state = {"tracker": tracker, "prev_active": prev_active}
        return x, y


class FederatedRunner(RunnerHistoryMixin):
    def __init__(
        self,
        round_fn: Callable,
        agent_data: Pytree,
        metric_fn: Optional[Callable] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
        strategy=None,
        elastic_round_fn: Optional[Callable] = None,
        tracker_init_fn: Optional[Callable] = None,
        telemetry=None,
    ):
        self._round = jax.jit(round_fn)
        self._agent_data = agent_data
        self._metric_fn = jax.jit(metric_fn) if metric_fn else None
        self._ckpt_dir = checkpoint_dir
        self._ckpt_every = checkpoint_every
        # non-None strategy with state => round_fn was built with
        # explicit_state=True and is called as round(x, y, data, state)
        self._strategy = strategy
        self._state: Optional[Pytree] = None
        #: repro.obs.Telemetry sink or None (None = pre-telemetry code
        #: verbatim); public so tests flip it on a compiled runner
        self.telemetry = telemetry
        # set by from_strategy — feed the wire counters / probes and the
        # lazily-jitted per-phase programs (Telemetry(phase_spans=True))
        self._loss: Optional[Callable] = None
        self._num_local_steps: Optional[int] = None
        self._phase_factory: Optional[Callable] = None
        self._phase_fns = None
        self._last_schedule = None
        # elastic (sim.RoundSchedule) support: the membership-aware round
        # round(x, y, data, state, tracker, weights, budgets, active)
        # and the tracker-table initializer (x, y, data) -> tracker.
        # Built by from_strategy; a raw-round runner cannot run elastic.
        self._elastic = (
            jax.jit(elastic_round_fn) if elastic_round_fn is not None else None
        )
        self._tracker_init = tracker_init_fn
        #: set by an elastic run: {"tracker", "prev_active"} where it
        #: left off (also checkpointed as "elastic_state")
        self.elastic_state: Optional[Dict] = None
        self.history: List[RoundStats] = []

    @classmethod
    def from_strategy(
        cls,
        loss: Callable,
        strategy,
        agent_data: Pytree,
        num_local_steps: int,
        eta_x: float,
        eta_y: Optional[float] = None,
        *,
        metric_fn: Optional[Callable] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
        telemetry=None,
        **round_kwargs,
    ) -> "FederatedRunner":
        """Build the round for `strategy` (name or CommStrategy) via the
        unified engine and wrap it in a runner."""
        import functools

        from ..core.engine import make_phases, make_round
        from ..sim.elastic import init_tracker, make_elastic_round
        from .strategies import resolve_strategy

        strategy = resolve_strategy(strategy)
        rnd = make_round(
            loss,
            strategy,
            num_local_steps,
            eta_x,
            eta_y,
            explicit_state=strategy.stateful,
            **round_kwargs,
        )
        elastic_kwargs = {
            k: v
            for k, v in round_kwargs.items()
            if k in ("proj_x", "proj_y", "update_fn", "constrain_agents")
        }
        elastic = make_elastic_round(
            loss, strategy, num_local_steps, eta_x, eta_y, **elastic_kwargs
        )
        runner = cls(
            rnd,
            agent_data,
            metric_fn=metric_fn,
            checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every,
            strategy=strategy,
            elastic_round_fn=elastic,
            tracker_init_fn=functools.partial(init_tracker, loss, strategy),
            telemetry=telemetry,
        )
        runner._loss = loss
        runner._num_local_steps = num_local_steps
        # deferred: Telemetry(phase_spans=True) rebuilds the SAME phases
        # as separate jitted programs (bitwise-identical to the fused
        # round — tests/test_phases.py); nothing is traced until used
        runner._phase_factory = functools.partial(
            make_phases, loss, strategy, num_local_steps, eta_x, eta_y,
            **{
                k: v for k, v in round_kwargs.items()
                if k in ("proj_x", "proj_y", "update_fn", "constrain_agents")
            },
        )
        return runner

    @property
    def _stateful(self) -> bool:
        return self._strategy is not None and getattr(
            self._strategy, "stateful", False
        )

    def run(
        self,
        x: Pytree,
        y: Pytree,
        num_rounds: int,
        log_every: int = 0,
        state: Optional[Pytree] = None,
        schedule=None,
        rebase: bool = True,
        elastic_state: Optional[Dict] = None,
    ):
        if state is not None:  # resume from a checkpointed strategy_state
            self._state = state
        if self._stateful and self._state is None:
            m = jax.tree.leaves(self._agent_data)[0].shape[0]
            self._state = self._strategy.init_state(x, y, m)
        if schedule is not None and hasattr(schedule, "densify"):
            # a SparseRoundSchedule (O(active) id lists): this runner's
            # round math is m-dense, so densify — correct and bitwise
            # for simulation-scale m, but deliberately refused at a
            # scale where [T, m] masks defeat the sparse representation
            # (that regime belongs to sim.sparse.SparseElasticEngine)
            from ..sim.sparse import DENSE_FALLBACK_MAX_M

            if schedule.m > DENSE_FALLBACK_MAX_M:
                raise ValueError(
                    f"sparse schedule over m={schedule.m} agents is too "
                    f"large to densify (> {DENSE_FALLBACK_MAX_M}); use "
                    "sim.sparse.SparseElasticEngine for O(active) runs"
                )
            schedule = schedule.densify()
        if schedule is not None and schedule.is_static_full:
            # degenerate schedule (all agents, full budgets, every
            # round): the legacy loop below IS that run, bitwise
            schedule = None
        self._last_schedule = schedule
        if schedule is not None:
            return self._run_elastic(
                x, y, num_rounds, schedule, rebase, log_every,
                elastic_state,
            )
        tm = self.telemetry
        per_agent = None
        round_dispatch = None
        if tm is not None:
            self._emit_wire_probe(tm, x, y)
            per_agent = self._wire_counter_args(x, y, scheduled=False)
            if tm.phase_spans and self._phase_factory is not None:
                round_dispatch = self._phase_round(tm)
        for t in range(num_rounds):
            t0 = time.perf_counter()
            if tm is not None:
                tm.begin_round(t)
            if round_dispatch is not None:
                x, y, new_state = round_dispatch(
                    x, y, self._agent_data,
                    self._state if self._stateful else {},
                )
                if self._stateful:
                    self._state = new_state
            elif self._stateful:
                x, y, self._state = self._round(
                    x, y, self._agent_data, self._state
                )
            else:
                x, y = self._round(x, y, self._agent_data)
            metrics = {}
            if self._metric_fn is not None:
                metrics = {
                    k: float(v)
                    for k, v in self._metric_fn(x, y).items()
                }
            dt = time.perf_counter() - t0
            self.history.append(RoundStats(t, metrics, dt))
            if tm is not None:
                tm.round_event(t, runtime="sync", seconds=dt)
                if per_agent is not None:
                    m = jax.tree.leaves(self._agent_data)[0].shape[0]
                    tm.counter(
                        "wire_bytes", per_agent * m,
                        per_agent=per_agent, n_active=m,
                    )
                self._emit_probes(tm, t, x, y)
                tm.end_round(t)
            if log_every and (t % log_every == 0 or t == num_rounds - 1):
                msg = " ".join(f"{k}={v:.3e}" for k, v in metrics.items())
                print(f"[round {t:5d}] {msg} ({dt*1e3:.1f} ms)")
            if (
                self._ckpt_dir
                and self._ckpt_every
                and (t + 1) % self._ckpt_every == 0
            ):
                payload = {"x": x, "y": y}
                if self._state is not None:
                    # resuming without this replays RNG draws / zeroes the
                    # error-feedback buffers
                    payload["strategy_state"] = self._state
                save_checkpoint(self._ckpt_dir, t + 1, payload)
        return x, y

    def _run_elastic(
        self, x, y, num_rounds, schedule, rebase, log_every,
        elastic_state=None,
    ):
        """Drive `num_rounds` through the membership-aware elastic round
        (see `repro.sim.elastic`): per-round weights re-normalized over
        the schedule's active set, local steps capped by its budgets,
        the tracker table threaded across rounds, and the strategy's
        membership-dependent state re-anchored via `rebase_state`.
        `rebase=False` is the naive-server ablation (1/m weights, stale
        EF residuals).

        Checkpoints made on this path carry an `elastic_state` entry
        ({"tracker": ..., "prev_active": ...}) alongside the strategy
        state: resuming WITHOUT it would re-anchor every absent agent's
        tracker at the resume iterate and forget who participated last
        round, silently diverging from the uninterrupted run.  Resume
        with `run(..., state=ckpt["strategy_state"],
        elastic_state=ckpt["elastic_state"],
        schedule=schedule.tail(t_ckpt))`."""
        import jax.numpy as jnp

        if self._elastic is None or self._strategy is None:
            raise ValueError(
                "elastic schedules need a runner built via from_strategy"
            )
        state = self._state if self._state is not None else {}

        def round_fn(x, y, ev, agg, tracker, prev_active):
            nonlocal state
            active = jnp.asarray(ev.active)
            weights = agg.weights(active)
            budgets = jnp.asarray(ev.budgets)
            # EF re-anchoring happens INSIDE the jitted round (fused
            # with the state's first use); None = the naive ablation
            x, y, state, tracker = self._elastic(
                x, y, self._agent_data, state, tracker,
                weights, budgets, active,
                agg.round_prev_active(active, prev_active),
            )
            if self._stateful:
                # keep the probe-visible state current mid-run (the
                # mixin's ef_residual probe reads `_telemetry_state`)
                self._state = state
            return x, y, tracker

        def checkpoint_fn(t, x, y, tracker, prev_active):
            if not (
                self._ckpt_dir
                and self._ckpt_every
                and (t + 1) % self._ckpt_every == 0
            ):
                return
            payload = {
                "x": x,
                "y": y,
                # resuming without this re-anchors absent agents'
                # trackers at the resume iterate and forgets the
                # previous active set (see docstring)
                "elastic_state": {
                    "tracker": tracker,
                    "prev_active": prev_active,
                },
            }
            if self._stateful:
                payload["strategy_state"] = state
            save_checkpoint(self._ckpt_dir, t + 1, payload)

        x, y = self._drive_elastic(
            x, y, num_rounds, schedule, rebase, log_every, elastic_state,
            lambda xx, yy: self._tracker_init(xx, yy, self._agent_data),
            round_fn, "elastic round", checkpoint_fn,
            num_agents=jax.tree.leaves(self._agent_data)[0].shape[0],
        )
        if self._stateful:
            self._state = state
        return x, y

    def _phase_round(self, tm):
        """The `Telemetry(phase_spans=True)` dispatch: the four engine
        phases as SEPARATE jitted programs, each wrapped in a span and
        blocked to completion so the span measures device time, not
        async-dispatch time.  `RoundState` is a registered pytree, so
        the phases cross jit boundaries directly; the composition is the
        fused round's math (tests/test_phases.py pins separately-jitted
        phases to the fused round at rtol 1e-12 — XLA partitions the
        programs differently, so agreement is fp-level, not bitwise).
        Lazily traced on first use: default-mode runners never pay for
        it."""
        if self._phase_factory is None:
            raise ValueError(
                "phase_spans needs a runner built via from_strategy"
            )
        if self._phase_fns is None:
            phases = self._phase_factory()
            # broadcast's keyword-only knobs carry `_UNSET` sentinel
            # defaults (not jit-traceable) — bind the positional form
            self._phase_fns = (
                jax.jit(lambda x, y, d, s: phases.broadcast(x, y, d, s)),
                jax.jit(phases.exchange_corrections),
                jax.jit(phases.local_steps),
                jax.jit(phases.aggregate),
            )
        bcast, exch, local, aggr = self._phase_fns

        def dispatch(x, y, data, state):
            with tm.span("broadcast"):
                rs = jax.block_until_ready(bcast(x, y, data, state))
            with tm.span("exchange_corrections"):
                rs = jax.block_until_ready(exch(rs, data))
            with tm.span("local_steps"):
                rs = jax.block_until_ready(local(rs, data))
            with tm.span("aggregate"):
                out = jax.block_until_ready(aggr(rs))
            return out

        return dispatch
