"""Federated round orchestration with metric logging and checkpointing.

`FederatedRunner` drives any round function (FedGDA-GT, Local SGDA, GDA)
produced by `repro.core`, records per-round metrics on the host, and
periodically checkpoints — the single-host counterpart of `repro.launch.train`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint import save_checkpoint

Pytree = Any


@dataclasses.dataclass
class RoundStats:
    round_index: int
    metrics: Dict[str, float]
    seconds: float


class FederatedRunner:
    def __init__(
        self,
        round_fn: Callable,
        agent_data: Pytree,
        metric_fn: Optional[Callable] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
    ):
        self._round = jax.jit(round_fn)
        self._agent_data = agent_data
        self._metric_fn = jax.jit(metric_fn) if metric_fn else None
        self._ckpt_dir = checkpoint_dir
        self._ckpt_every = checkpoint_every
        self.history: List[RoundStats] = []

    def run(self, x: Pytree, y: Pytree, num_rounds: int, log_every: int = 0):
        for t in range(num_rounds):
            t0 = time.perf_counter()
            x, y = self._round(x, y, self._agent_data)
            metrics = {}
            if self._metric_fn is not None:
                metrics = {
                    k: float(v)
                    for k, v in self._metric_fn(x, y).items()
                }
            dt = time.perf_counter() - t0
            self.history.append(RoundStats(t, metrics, dt))
            if log_every and (t % log_every == 0 or t == num_rounds - 1):
                msg = " ".join(f"{k}={v:.3e}" for k, v in metrics.items())
                print(f"[round {t:5d}] {msg} ({dt*1e3:.1f} ms)")
            if (
                self._ckpt_dir
                and self._ckpt_every
                and (t + 1) % self._ckpt_every == 0
            ):
                save_checkpoint(self._ckpt_dir, t + 1, {"x": x, "y": y})
        return x, y

    def metric_series(self, name: str) -> np.ndarray:
        return np.array([s.metrics[name] for s in self.history])
