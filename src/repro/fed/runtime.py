"""Synchronous federated round orchestration (single fused program).

`FederatedRunner` drives any round function produced by `repro.core` —
legacy constructors or the phase-split engine (`make_round`, the fused
composition of the `broadcast` / `exchange_corrections` / `local_steps` /
`aggregate` phases) with any `CommStrategy` — records per-round metrics
on the host, and periodically checkpoints; the single-host counterpart of
`repro.launch.train`.  Stateful strategies (client-sampling RNG,
error-feedback buffers) have their state initialized lazily on the first
round and threaded across rounds; build via `FederatedRunner.from_strategy`
for that path.

This runner executes each round as ONE jitted program on the default
device: broadcast, exchange and K local steps lower together, so nothing
overlaps and strategy state is replicated.  Its asynchronous counterpart
— `repro.fed.async_runtime.AsyncFederatedRunner` — dispatches the same
phase functions per agent shard on separate devices, overlaps the
correction exchange with trailing local steps, and shards per-agent
strategy state; the two agree on iterates to fp tolerance
(tests/test_async_runtime.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint import save_checkpoint

Pytree = Any


@dataclasses.dataclass
class RoundStats:
    round_index: int
    metrics: Dict[str, float]
    seconds: float


class RunnerHistoryMixin:
    """Per-round history shared by the sync and async runners."""

    history: List[RoundStats]

    def metric_series(self, name: str) -> np.ndarray:
        available = sorted({k for s in self.history for k in s.metrics})
        if self.history and name not in available:
            raise ValueError(
                f"unknown metric {name!r}; available metric keys: "
                f"{available}"
            )
        return np.array([s.metrics[name] for s in self.history])


class FederatedRunner(RunnerHistoryMixin):
    def __init__(
        self,
        round_fn: Callable,
        agent_data: Pytree,
        metric_fn: Optional[Callable] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
        strategy=None,
    ):
        self._round = jax.jit(round_fn)
        self._agent_data = agent_data
        self._metric_fn = jax.jit(metric_fn) if metric_fn else None
        self._ckpt_dir = checkpoint_dir
        self._ckpt_every = checkpoint_every
        # non-None strategy with state => round_fn was built with
        # explicit_state=True and is called as round(x, y, data, state)
        self._strategy = strategy
        self._state: Optional[Pytree] = None
        self.history: List[RoundStats] = []

    @classmethod
    def from_strategy(
        cls,
        loss: Callable,
        strategy,
        agent_data: Pytree,
        num_local_steps: int,
        eta_x: float,
        eta_y: Optional[float] = None,
        *,
        metric_fn: Optional[Callable] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
        **round_kwargs,
    ) -> "FederatedRunner":
        """Build the round for `strategy` (name or CommStrategy) via the
        unified engine and wrap it in a runner."""
        from ..core.engine import make_round
        from .strategies import resolve_strategy

        strategy = resolve_strategy(strategy)
        rnd = make_round(
            loss,
            strategy,
            num_local_steps,
            eta_x,
            eta_y,
            explicit_state=strategy.stateful,
            **round_kwargs,
        )
        return cls(
            rnd,
            agent_data,
            metric_fn=metric_fn,
            checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every,
            strategy=strategy,
        )

    @property
    def _stateful(self) -> bool:
        return self._strategy is not None and getattr(
            self._strategy, "stateful", False
        )

    def run(
        self,
        x: Pytree,
        y: Pytree,
        num_rounds: int,
        log_every: int = 0,
        state: Optional[Pytree] = None,
    ):
        if state is not None:  # resume from a checkpointed strategy_state
            self._state = state
        if self._stateful and self._state is None:
            m = jax.tree.leaves(self._agent_data)[0].shape[0]
            self._state = self._strategy.init_state(x, y, m)
        for t in range(num_rounds):
            t0 = time.perf_counter()
            if self._stateful:
                x, y, self._state = self._round(
                    x, y, self._agent_data, self._state
                )
            else:
                x, y = self._round(x, y, self._agent_data)
            metrics = {}
            if self._metric_fn is not None:
                metrics = {
                    k: float(v)
                    for k, v in self._metric_fn(x, y).items()
                }
            dt = time.perf_counter() - t0
            self.history.append(RoundStats(t, metrics, dt))
            if log_every and (t % log_every == 0 or t == num_rounds - 1):
                msg = " ".join(f"{k}={v:.3e}" for k, v in metrics.items())
                print(f"[round {t:5d}] {msg} ({dt*1e3:.1f} ms)")
            if (
                self._ckpt_dir
                and self._ckpt_every
                and (t + 1) % self._ckpt_every == 0
            ):
                payload = {"x": x, "y": y}
                if self._state is not None:
                    # resuming without this replays RNG draws / zeroes the
                    # error-feedback buffers
                    payload["strategy_state"] = self._state
                save_checkpoint(self._ckpt_dir, t + 1, payload)
        return x, y

    def wire_report(self, x: Pytree, y: Pytree, num_local_steps: int) -> Dict:
        """Priced vs measured per-round communication for this runner's
        strategy: the analytic `bytes_per_round` next to the probe of the
        actual packed buffer lengths (`transport.measured_bytes_per_round`,
        headers included).  Requires a strategy-built runner."""
        if self._strategy is None:
            raise ValueError("wire_report needs a runner built from_strategy")
        from .transport import measured_bytes_per_round

        return {
            "bytes_per_round": int(
                self._strategy.bytes_per_round(x, y, num_local_steps)
            ),
            "measured_bytes_per_round": measured_bytes_per_round(
                self._strategy, x, y, num_local_steps
            ),
        }
