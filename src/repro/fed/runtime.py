"""Synchronous federated round orchestration (single fused program).

`FederatedRunner` drives any round function produced by `repro.core` —
legacy constructors or the phase-split engine (`make_round`, the fused
composition of the `broadcast` / `exchange_corrections` / `local_steps` /
`aggregate` phases) with any `CommStrategy` — records per-round metrics
on the host, and periodically checkpoints; the single-host counterpart of
`repro.launch.train`.  Stateful strategies (client-sampling RNG,
error-feedback buffers) have their state initialized lazily on the first
round and threaded across rounds; build via `FederatedRunner.from_strategy`
for that path.  Stochastic strategies (a non-None `strategy.noise`) ride
the same state thread: `state["noise_key"]` is the dedicated noise
stream (`fed.noise.noise_key`), advanced once per round inside the
jitted round by `broadcast`, so checkpoint/resume replays the exact
noise sequence and the async runner — which samples the same stream
once server-side and slices per shard — consumes bit-identical draws.

This runner executes each round as ONE jitted program on the default
device: broadcast, exchange and K local steps lower together, so nothing
overlaps and strategy state is replicated.  Its asynchronous counterpart
— `repro.fed.async_runtime.AsyncFederatedRunner` — dispatches the same
phase functions per agent shard on separate devices, overlaps the
correction exchange with trailing local steps, and shards per-agent
strategy state; the two agree on iterates to fp tolerance
(tests/test_async_runtime.py).

Both runners also consume a `repro.sim.RoundSchedule` (`run(...,
schedule=...)`): per-round active sets and local-step budgets from a
seedable client population.  Non-full rounds execute the membership-
aware elastic round (`sim.make_elastic_round` — re-normalized weights,
tracker-table corrections, budget-gated local steps, EF re-anchoring
via the strategy's `rebase_state` hook; `rebase=False` is the
naive-server ablation).  A static-full schedule degenerates to the
unmodified legacy loop, so full participation stays bitwise identical
to running without a schedule (tests/test_elastic.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from ..checkpoint import save_checkpoint

Pytree = Any


@dataclasses.dataclass
class RoundStats:
    round_index: int
    metrics: Dict[str, float]
    seconds: float


class RunnerHistoryMixin:
    """Per-round history + the elastic-schedule driver shared by the
    sync and async runners."""

    history: List[RoundStats]

    def metric_series(self, name: str) -> np.ndarray:
        available = sorted({k for s in self.history for k in s.metrics})
        if self.history and name not in available:
            raise ValueError(
                f"unknown metric {name!r}; available metric keys: "
                f"{available}"
            )
        return np.array([s.metrics[name] for s in self.history])

    def _drive_elastic(
        self,
        x,
        y,
        num_rounds: int,
        schedule,
        rebase: bool,
        log_every: int,
        elastic_state,
        init_tracker_fn: Callable,
        round_fn: Callable,
        label: str,
        checkpoint_fn: Optional[Callable] = None,
        num_agents: Optional[int] = None,
    ):
        """ONE owner of the elastic run loop for both runtimes:
        schedule validation, the `ElasticAggregator`, tracker +
        prev_active continuation (`elastic_state` — resuming without it
        re-anchors absent agents' trackers at the resume iterate and
        forgets who participated last round), per-round `n_active`
        metrics, history, logging and optional checkpointing.  The
        runners differ only in `round_fn(x, y, ev, agg, tracker,
        prev_active) -> (x, y, tracker)` — the fused elastic round vs
        per-shard dispatch."""
        import jax.numpy as jnp

        from ..sim.elastic import ElasticAggregator

        if len(schedule) < num_rounds:
            raise ValueError(
                f"schedule covers {len(schedule)} rounds, need {num_rounds}"
            )
        if num_agents is not None and schedule.m != num_agents:
            # a larger-m schedule would renormalize weights over agents
            # that don't exist and then silently lose their mass when
            # the runner slices — exactly the naive-server failure mode
            raise ValueError(
                f"schedule is for m={schedule.m} agents, runner has "
                f"{num_agents}"
            )
        agg = ElasticAggregator(self._strategy, rebase=rebase)
        if elastic_state is not None:
            tracker = elastic_state["tracker"]
            prev_active = elastic_state.get("prev_active")
        else:
            tracker = init_tracker_fn(x, y)
            prev_active = None
        for t in range(num_rounds):
            t0 = time.perf_counter()
            ev = schedule[t]
            x, y, tracker = round_fn(x, y, ev, agg, tracker, prev_active)
            prev_active = jnp.asarray(ev.active)
            metrics = {"n_active": float(ev.num_active)}
            if self._metric_fn is not None:
                metrics.update(
                    {k: float(v) for k, v in self._metric_fn(x, y).items()}
                )
            dt = time.perf_counter() - t0
            self.history.append(RoundStats(t, metrics, dt))
            if log_every and (t % log_every == 0 or t == num_rounds - 1):
                msg = " ".join(f"{k}={v:.3e}" for k, v in metrics.items())
                print(f"[{label} {t:5d}] {msg} ({dt*1e3:.1f} ms)")
            if checkpoint_fn is not None:
                checkpoint_fn(t, x, y, tracker, prev_active)
        #: where the run left off, for continuation:
        #: run(..., elastic_state=runner.elastic_state, schedule=tail)
        self.elastic_state = {"tracker": tracker, "prev_active": prev_active}
        return x, y


class FederatedRunner(RunnerHistoryMixin):
    def __init__(
        self,
        round_fn: Callable,
        agent_data: Pytree,
        metric_fn: Optional[Callable] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
        strategy=None,
        elastic_round_fn: Optional[Callable] = None,
        tracker_init_fn: Optional[Callable] = None,
    ):
        self._round = jax.jit(round_fn)
        self._agent_data = agent_data
        self._metric_fn = jax.jit(metric_fn) if metric_fn else None
        self._ckpt_dir = checkpoint_dir
        self._ckpt_every = checkpoint_every
        # non-None strategy with state => round_fn was built with
        # explicit_state=True and is called as round(x, y, data, state)
        self._strategy = strategy
        self._state: Optional[Pytree] = None
        # elastic (sim.RoundSchedule) support: the membership-aware round
        # round(x, y, data, state, tracker, weights, budgets, active)
        # and the tracker-table initializer (x, y, data) -> tracker.
        # Built by from_strategy; a raw-round runner cannot run elastic.
        self._elastic = (
            jax.jit(elastic_round_fn) if elastic_round_fn is not None else None
        )
        self._tracker_init = tracker_init_fn
        #: set by an elastic run: {"tracker", "prev_active"} where it
        #: left off (also checkpointed as "elastic_state")
        self.elastic_state: Optional[Dict] = None
        self.history: List[RoundStats] = []

    @classmethod
    def from_strategy(
        cls,
        loss: Callable,
        strategy,
        agent_data: Pytree,
        num_local_steps: int,
        eta_x: float,
        eta_y: Optional[float] = None,
        *,
        metric_fn: Optional[Callable] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 0,
        **round_kwargs,
    ) -> "FederatedRunner":
        """Build the round for `strategy` (name or CommStrategy) via the
        unified engine and wrap it in a runner."""
        import functools

        from ..core.engine import make_round
        from ..sim.elastic import init_tracker, make_elastic_round
        from .strategies import resolve_strategy

        strategy = resolve_strategy(strategy)
        rnd = make_round(
            loss,
            strategy,
            num_local_steps,
            eta_x,
            eta_y,
            explicit_state=strategy.stateful,
            **round_kwargs,
        )
        elastic_kwargs = {
            k: v
            for k, v in round_kwargs.items()
            if k in ("proj_x", "proj_y", "update_fn", "constrain_agents")
        }
        elastic = make_elastic_round(
            loss, strategy, num_local_steps, eta_x, eta_y, **elastic_kwargs
        )
        return cls(
            rnd,
            agent_data,
            metric_fn=metric_fn,
            checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every,
            strategy=strategy,
            elastic_round_fn=elastic,
            tracker_init_fn=functools.partial(init_tracker, loss, strategy),
        )

    @property
    def _stateful(self) -> bool:
        return self._strategy is not None and getattr(
            self._strategy, "stateful", False
        )

    def run(
        self,
        x: Pytree,
        y: Pytree,
        num_rounds: int,
        log_every: int = 0,
        state: Optional[Pytree] = None,
        schedule=None,
        rebase: bool = True,
        elastic_state: Optional[Dict] = None,
    ):
        if state is not None:  # resume from a checkpointed strategy_state
            self._state = state
        if self._stateful and self._state is None:
            m = jax.tree.leaves(self._agent_data)[0].shape[0]
            self._state = self._strategy.init_state(x, y, m)
        if schedule is not None and hasattr(schedule, "densify"):
            # a SparseRoundSchedule (O(active) id lists): this runner's
            # round math is m-dense, so densify — correct and bitwise
            # for simulation-scale m, but deliberately refused at a
            # scale where [T, m] masks defeat the sparse representation
            # (that regime belongs to sim.sparse.SparseElasticEngine)
            from ..sim.sparse import DENSE_FALLBACK_MAX_M

            if schedule.m > DENSE_FALLBACK_MAX_M:
                raise ValueError(
                    f"sparse schedule over m={schedule.m} agents is too "
                    f"large to densify (> {DENSE_FALLBACK_MAX_M}); use "
                    "sim.sparse.SparseElasticEngine for O(active) runs"
                )
            schedule = schedule.densify()
        if schedule is not None and schedule.is_static_full:
            # degenerate schedule (all agents, full budgets, every
            # round): the legacy loop below IS that run, bitwise
            schedule = None
        if schedule is not None:
            return self._run_elastic(
                x, y, num_rounds, schedule, rebase, log_every,
                elastic_state,
            )
        for t in range(num_rounds):
            t0 = time.perf_counter()
            if self._stateful:
                x, y, self._state = self._round(
                    x, y, self._agent_data, self._state
                )
            else:
                x, y = self._round(x, y, self._agent_data)
            metrics = {}
            if self._metric_fn is not None:
                metrics = {
                    k: float(v)
                    for k, v in self._metric_fn(x, y).items()
                }
            dt = time.perf_counter() - t0
            self.history.append(RoundStats(t, metrics, dt))
            if log_every and (t % log_every == 0 or t == num_rounds - 1):
                msg = " ".join(f"{k}={v:.3e}" for k, v in metrics.items())
                print(f"[round {t:5d}] {msg} ({dt*1e3:.1f} ms)")
            if (
                self._ckpt_dir
                and self._ckpt_every
                and (t + 1) % self._ckpt_every == 0
            ):
                payload = {"x": x, "y": y}
                if self._state is not None:
                    # resuming without this replays RNG draws / zeroes the
                    # error-feedback buffers
                    payload["strategy_state"] = self._state
                save_checkpoint(self._ckpt_dir, t + 1, payload)
        return x, y

    def _run_elastic(
        self, x, y, num_rounds, schedule, rebase, log_every,
        elastic_state=None,
    ):
        """Drive `num_rounds` through the membership-aware elastic round
        (see `repro.sim.elastic`): per-round weights re-normalized over
        the schedule's active set, local steps capped by its budgets,
        the tracker table threaded across rounds, and the strategy's
        membership-dependent state re-anchored via `rebase_state`.
        `rebase=False` is the naive-server ablation (1/m weights, stale
        EF residuals).

        Checkpoints made on this path carry an `elastic_state` entry
        ({"tracker": ..., "prev_active": ...}) alongside the strategy
        state: resuming WITHOUT it would re-anchor every absent agent's
        tracker at the resume iterate and forget who participated last
        round, silently diverging from the uninterrupted run.  Resume
        with `run(..., state=ckpt["strategy_state"],
        elastic_state=ckpt["elastic_state"],
        schedule=schedule.tail(t_ckpt))`."""
        import jax.numpy as jnp

        if self._elastic is None or self._strategy is None:
            raise ValueError(
                "elastic schedules need a runner built via from_strategy"
            )
        state = self._state if self._state is not None else {}

        def round_fn(x, y, ev, agg, tracker, prev_active):
            nonlocal state
            active = jnp.asarray(ev.active)
            weights = agg.weights(active)
            budgets = jnp.asarray(ev.budgets)
            # EF re-anchoring happens INSIDE the jitted round (fused
            # with the state's first use); None = the naive ablation
            x, y, state, tracker = self._elastic(
                x, y, self._agent_data, state, tracker,
                weights, budgets, active,
                agg.round_prev_active(active, prev_active),
            )
            return x, y, tracker

        def checkpoint_fn(t, x, y, tracker, prev_active):
            if not (
                self._ckpt_dir
                and self._ckpt_every
                and (t + 1) % self._ckpt_every == 0
            ):
                return
            payload = {
                "x": x,
                "y": y,
                # resuming without this re-anchors absent agents'
                # trackers at the resume iterate and forgets the
                # previous active set (see docstring)
                "elastic_state": {
                    "tracker": tracker,
                    "prev_active": prev_active,
                },
            }
            if self._stateful:
                payload["strategy_state"] = state
            save_checkpoint(self._ckpt_dir, t + 1, payload)

        x, y = self._drive_elastic(
            x, y, num_rounds, schedule, rebase, log_every, elastic_state,
            lambda xx, yy: self._tracker_init(xx, yy, self._agent_data),
            round_fn, "elastic round", checkpoint_fn,
            num_agents=jax.tree.leaves(self._agent_data)[0].shape[0],
        )
        if self._stateful:
            self._state = state
        return x, y

    def wire_report(self, x: Pytree, y: Pytree, num_local_steps: int) -> Dict:
        """Priced vs measured per-round communication for this runner's
        strategy: the analytic `bytes_per_round` next to the probe of the
        actual packed buffer lengths (`transport.measured_bytes_per_round`,
        headers included).  Requires a strategy-built runner."""
        if self._strategy is None:
            raise ValueError("wire_report needs a runner built from_strategy")
        from .transport import measured_bytes_per_round

        return {
            "bytes_per_round": int(
                self._strategy.bytes_per_round(x, y, num_local_steps)
            ),
            "measured_bytes_per_round": measured_bytes_per_round(
                self._strategy, x, y, num_local_steps
            ),
        }
