"""Analytic communication accounting (star-topology cost model, Section 3).

Bytes exchanged between ONE agent and the server to reach a target accuracy:
  rounds(eps) x bytes/round.  FedGDA-GT pays 2x Local SGDA per round but needs
  O(log 1/eps) rounds instead of O(1/eps) — this table quantifies the paper's
  headline claim.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax

from ..core.fedgda_gt import communication_bytes_per_round

Pytree = Any


def comm_table(
    x: Pytree, y: Pytree, num_local_steps: int, rounds_to_eps: Dict[str, float]
) -> Dict[str, Dict[str, float]]:
    """rounds_to_eps: measured rounds to reach the target per algorithm
    (math.inf if never reached).  Returns per-algorithm bytes/round and
    total bytes to target."""
    out = {}
    for algo, rounds in rounds_to_eps.items():
        per_round = communication_bytes_per_round(x, y, algo, num_local_steps)
        total = per_round * rounds if math.isfinite(rounds) else math.inf
        out[algo] = {
            "bytes_per_round": float(per_round),
            "rounds_to_eps": float(rounds),
            "total_bytes": float(total),
        }
    return out
