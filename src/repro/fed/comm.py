"""Analytic communication accounting (star-topology cost model, Section 3).

Bytes exchanged between ONE agent and the server to reach a target accuracy:
  rounds(eps) x bytes/round.  FedGDA-GT pays 2x Local SGDA per round but needs
  O(log 1/eps) rounds instead of O(1/eps) — this table quantifies the paper's
  headline claim.  Per-round payloads are strategy-derived
  (`CommStrategy.bytes_per_round`), so compressed / partially-participating
  variants are priced by the same table.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax

from .strategies import CommStrategy, resolve_strategy

Pytree = Any


def comm_table(
    x: Pytree, y: Pytree, num_local_steps: int, rounds_to_eps: Dict
) -> Dict[str, Dict[str, float]]:
    """rounds_to_eps: measured rounds to reach the target per algorithm
    (math.inf if never reached), keyed by legacy algorithm name or by a
    `CommStrategy` instance.  Returns per-algorithm bytes/round and total
    bytes to target, keyed by name."""
    out = {}
    for algo, rounds in rounds_to_eps.items():
        strategy = resolve_strategy(algo)
        per_round = strategy.bytes_per_round(x, y, num_local_steps)
        total = per_round * rounds if math.isfinite(rounds) else math.inf
        name = algo if isinstance(algo, str) else strategy.name
        if name in out:
            # same strategy class, different hyperparameters: keep both rows
            name = f"{name}#{sum(1 for k in out if k.split('#')[0] == name)}"
        out[name] = {
            "bytes_per_round": float(per_round),
            "rounds_to_eps": float(rounds),
            "total_bytes": float(total),
        }
    return out
