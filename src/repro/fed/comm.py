"""Analytic communication accounting (star-topology cost model, Section 3).

Bytes exchanged between ONE agent and the server to reach a target accuracy:
  rounds(eps) x bytes/round.  FedGDA-GT pays 2x Local SGDA per round but needs
  O(log 1/eps) rounds instead of O(1/eps) — this table quantifies the paper's
  headline claim.  Per-round payloads are strategy-derived
  (`CommStrategy.bytes_per_round`), so compressed / partially-participating
  variants are priced by the same table — and every row also carries the
  MEASURED per-round bytes (`transport.measured_bytes_per_round`, probing
  the actual packed wire buffers), so analytic and empirical accounting are
  compared on every run.
"""
from __future__ import annotations

import dataclasses
import math
from collections import Counter
from typing import Any, Dict

from .strategies import CommStrategy, resolve_strategy
from .transport import measured_bytes_per_round

Pytree = Any


def knob_signature(strategy: CommStrategy, fields=None) -> str:
    """Deterministic rendering of a strategy's hyperparameter knobs
    (dataclass fields in declaration order) — the collision-proof row
    key for `comm_table`.  `fields` restricts to a subset of field
    names; by default every non-default knob is rendered, so keys stay
    short and stable when new fields grow onto the dataclasses."""
    if not dataclasses.is_dataclass(strategy):
        return repr(strategy)
    parts = []
    for f in dataclasses.fields(strategy):
        v = getattr(strategy, f.name)
        if fields is not None:
            if f.name not in fields:
                continue
        elif f.default is not dataclasses.MISSING and v == f.default:
            continue
        parts.append(f"{f.name}={v!r}")
    return ",".join(parts)


def _collision_fields(strategies) -> set:
    """Field names that disambiguate a group of same-class strategies:
    anything set away from its default on any member, plus anything that
    differs across the group (covers members that only differ in a
    knob whose value on one of them IS the default)."""
    names = set()
    for s in strategies:
        if not dataclasses.is_dataclass(s):
            continue
        for f in dataclasses.fields(s):
            v = getattr(s, f.name)
            if f.default is dataclasses.MISSING or v != f.default:
                names.add(f.name)
            elif any(
                dataclasses.is_dataclass(o) and getattr(o, f.name, v) != v
                for o in strategies
            ):
                names.add(f.name)
    return names


def comm_table(
    x: Pytree, y: Pytree, num_local_steps: int, rounds_to_eps: Dict
) -> Dict[str, Dict[str, float]]:
    """rounds_to_eps: measured rounds to reach the target per algorithm
    (math.inf if never reached), keyed by legacy algorithm name or by a
    `CommStrategy` instance.  Returns per-algorithm bytes/round (priced
    AND measured) and total bytes to target, keyed by name.

    Legacy STRING keys always keep their plain name (the documented
    contract — `table["fedgda_gt"]` works whatever else is in the dict).
    Strategy-instance entries whose base name collides are keyed by
    their distinguishing knob signature — deterministic in the knobs
    themselves, independent of insertion order (the old `name#k`
    suffixing numbered rows by arrival, so reordering the input dict
    silently relabeled them).  Entries indistinguishable even by knobs
    get a `+` suffix."""
    resolved = []
    for algo, rounds in rounds_to_eps.items():
        strategy = resolve_strategy(algo)
        base = algo if isinstance(algo, str) else strategy.name
        resolved.append((base, isinstance(algo, str), strategy, rounds))
    counts = Counter(base for base, _, _, _ in resolved)
    keys = {
        b: _collision_fields([s for bb, _, s, _ in resolved if bb == b])
        for b, n in counts.items()
        if n > 1
    }
    out = {}
    for base, is_str, strategy, rounds in resolved:
        name = base
        if counts[base] > 1 and not is_str:
            sig = knob_signature(strategy, keys[base])
            # an instance row never takes the bare name in a collision —
            # that is reserved for a legacy string key whatever the
            # insertion order
            name = f"{base}[{sig}]" if sig else f"{base}+"
        while name in out:  # indistinguishable entries: keep both rows
            name += "+"    # with a deterministic suffix
        per_round = strategy.bytes_per_round(x, y, num_local_steps)
        measured = measured_bytes_per_round(strategy, x, y, num_local_steps)
        total = per_round * rounds if math.isfinite(rounds) else math.inf
        out[name] = {
            "bytes_per_round": float(per_round),
            "measured_bytes_per_round": float(measured),
            "rounds_to_eps": float(rounds),
            "total_bytes": float(total),
        }
    return out
