"""Pod-level wire payloads + pod/shard alignment for the two-level tree.

The hierarchical aggregation path (million-agent ROADMAP item) inserts a
pod tier between agents and the server: active agents aggregate into
their pod's partial weighted sum (`core.engine.pod_weighted_sums`), and
each LIVE pod ships ONE partial payload to the server instead of the
server fanning in every agent.  This module owns the wire side of that
tier, reusing the PR-3 transport stack end to end:

  * `encode_pod_partials` packs the live pods' partial-sum rows as a
    `transport.PackedTree` with DENSE leaf specs — the dense encoding
    round-trips bitwise (decode(encode(c)) == c, the transport
    conformance contract), so shipping partials through the packed path
    moves no values;
  * `pod_payload_bytes` prices one pod's per-round traffic (partial up,
    broadcast down) with the same priced == measured contract the
    per-agent payloads carry (`sim.elastic.schedule_bytes` consumes it
    for the pod edge);
  * `pod_aligned_shard_count` picks an agent-shard count that keeps
    whole pods inside single shards, so `AsyncFederatedRunner`'s
    skip-absent-shards dispatch doubles as "skip quiet pods": a pod
    with no active agents never costs a device program.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import numpy as np

from ..core.types import Pytree
from .transport import (
    HEADER_BYTES,
    LeafSpec,
    PackedTree,
    encode_leaf,
    probe_leaf_bytes,
)


def pod_aligned_shard_count(num_pods: int, max_shards: int) -> int:
    """Largest shard count <= max_shards that divides `num_pods`, so
    every shard holds a whole number of pods.  With pod-aligned shards,
    a fully-quiet pod lands inside a shard whose other pods may still
    be live — but a run of quiet pods spanning a whole shard makes that
    shard's `active.any()` false and the async runner skips it without
    any pod-specific dispatch logic."""
    if num_pods < 1 or max_shards < 1:
        raise ValueError(
            f"need num_pods >= 1 and max_shards >= 1, got "
            f"{num_pods}, {max_shards}"
        )
    for d in range(min(num_pods, max_shards), 0, -1):
        if num_pods % d == 0:
            return d
    return 1


def encode_pod_partials(
    partials: Pytree, *, use_kernel: bool = False, interpret: bool = True
) -> PackedTree:
    """Pack per-pod partial aggregates (leaves with a leading pod axis —
    typically only the LIVE pods' rows, gathered by the caller) into a
    `PackedTree` of DENSE payloads.  Dense specs (ratio 1.0, 32 bits)
    make the encode/decode round trip bitwise, so the pod tier can ride
    the exact wire machinery the compressed strategies use without
    perturbing the aggregate (tests/test_sparse_elastic.py pins the
    round trip)."""
    leaves, treedef = jax.tree.flatten(partials)
    payloads, specs, shapes = [], [], []
    for u in leaves:
        num_rows = u.shape[0]
        base = LeafSpec.build(u.shape[1:], u.dtype, 1.0, 32)
        spec = base.stacked(num_rows)
        flat = u.reshape(num_rows * base.rows, base.cols)
        payload, _ = encode_leaf(
            flat, None, None, None, spec,
            use_kernel=use_kernel, interpret=interpret,
        )
        payloads.append(payload)
        specs.append(spec)
        shapes.append(u.shape)
    return PackedTree(
        payloads, specs, treedef, shapes,
        use_kernel=use_kernel, interpret=interpret,
    )


def pod_payload_bytes(x: Pytree, y: Pytree, *, measured: bool = True) -> int:
    """Per-round wire bytes of ONE live pod on the pod <-> server edge:
    the pod's partial aggregate up plus the server broadcast down — two
    dense (x, y) model copies in packed framing (headers included).
    `measured=True` probes the encoder's actual emitted buffers
    (`transport.probe_leaf_bytes`), `measured=False` takes the spec
    arithmetic; the PR-3 conformance contract keeps the two equal."""
    total = 0
    for u in jax.tree.leaves((x, y)):
        spec = LeafSpec.build(u.shape, u.dtype, 1.0, 32)
        total += (
            probe_leaf_bytes(spec) if measured else spec.wire_bytes()
        ) + HEADER_BYTES
    return 2 * total


def decode_pod_partials(tree: PackedTree) -> Pytree:
    """Inverse of `encode_pod_partials` (bitwise, dense specs)."""
    return tree.decode()
