"""Asynchronous multi-device federated round driver.

`AsyncFederatedRunner` executes the SAME round phases as the fused
single-program engine (`repro.core.engine.make_phases`) but dispatches
them per **agent shard** on separate devices, so the schedule — not the
math — changes:

  * the m agents are split into contiguous shards, one per device; each
    shard's `agent_data` slice and per-agent strategy state (error-
    feedback buffers — `strategy.sharded_state_keys`) live on that
    shard's device permanently, instead of replicating the full stack;
  * `broadcast` + the anchor-gradient half of `exchange_corrections`
    run per shard as independently dispatched programs (one XLA stream
    per device — jax's async dispatch keeps every shard's queue busy
    while the host runs ahead);
  * the server half of the exchange — participation sampling, gbar,
    forming c_i = gbar - g_i, the strategy's `transform_correction`
    (identical code, identical RNG draws as the sync path, so iterates
    match `FederatedRunner` to fp tolerance) and the packed-payload
    decode — runs on the server device over the gathered gradients;
  * `local_steps` runs per shard with its correction slice; the shard
    returns a weighted PARTIAL aggregate (`core.agent_weighted_sum`),
    and the server combines + projects;
  * the next round's `broadcast` transfer is **double-buffered**: the
    server enqueues `jax.device_put` of (x^{t+1}, y^{t+1}) to every
    shard device as soon as the aggregate is dispatched (before its
    values are ready), while the previous round's broadcast buffers are
    still feeding trailing local steps — and those consumed buffers are
    donated into the local-step program, so the transfer of round t+1
    overlaps the tail of round t instead of serializing behind it.

FullSync (sync_every_step) has no local divergence to overlap: the
runner executes its K communicated steps as K (per-shard grad-sum →
server combine) exchanges per round — which is exactly why it is K times
more expensive on the wire, now visible as wall-clock in
`benchmarks/comm_efficiency.py --overlap`.

The runner also consumes an elastic `repro.sim.RoundSchedule`
(`run(..., schedule=...)`): agent SHARDS join and leave between rounds —
a shard whose agents are all absent this round is never dispatched (its
anchor-gradient and local-step programs simply don't run; stale tracker
rows stand in server-side), and partially-present shards run
budget-gated local steps with their weight slice re-normalized over the
global active set.  Membership is identical to the sync runner's by
construction (both read the same materialized schedule), and the
tracker-table exchange runs server-side through the same
`sim.make_elastic_round` math, so elastic iterates match the sync
elastic path to fp tolerance.  The elastic rounds forgo the
double-buffered donated broadcasts (membership changes the set of live
shard programs round to round); a static-full schedule falls back to
the unmodified overlapped loop.

The fp-tolerance contract with the sync runner holds because per-agent
gradients and local steps are elementwise identical computations on
shard slices, and every random draw (participation sampling, rand-k
selection scores, stochastic-rounding uniforms, and the per-agent
gradient-noise keys of a stochastic strategy — `_round_noise_keys`,
sliced per shard exactly like the participation weights) happens once,
server-side, through the very same `strategy` code path; only the
aggregate's reduction order differs (per-shard sums combined
server-side vs one mean), which is the usual ~ulp-level float
non-associativity.  Stochastic strategies' noise keys are folded by
GLOBAL agent index (`fed.noise`), so a shard's draws do not depend on
how agents were split into shards.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from ..core.engine import (
    agent_mean,
    agent_weighted_sum,
    make_noise_vgrad,
    make_phases,
    noise_eval_keys,
    tracking_corrections,
)
from ..core.types import Pytree, grad_xy, identity_proj
from .runtime import RoundStats, RunnerHistoryMixin
from .strategies import resolve_strategy


def _num_agents(agent_data: Pytree) -> int:
    return jax.tree.leaves(agent_data)[0].shape[0]


def _slice_agents(tree: Pytree, lo: int, hi: int) -> Pytree:
    return jax.tree.map(lambda u: u[lo:hi], tree)


def largest_shard_count(m: int, n_devices: int) -> int:
    """Most shards we can use: the largest divisor of m that fits the
    device count (contiguous equal shards keep every program shape
    static and identical across shards — one compilation serves all).
    Shared with `launch.multihost`."""
    for n in range(min(m, n_devices), 0, -1):
        if m % n == 0:
            return n
    return 1


def concat_on_device(parts: List[Pytree], device) -> Pytree:
    """Gather per-shard pytrees onto one device and re-stack the agent
    axis (the up-link transfer of a sharded schedule).  Shared with
    `launch.multihost`."""
    parts = [jax.device_put(p, device) for p in parts]
    if len(parts) == 1:
        return parts[0]
    return jax.tree.map(lambda *u: jnp.concatenate(u, axis=0), *parts)


class AsyncFederatedRunner(RunnerHistoryMixin):
    """Drive federated rounds with per-agent-shard phase programs on
    separate devices (see module docstring).

    Mirrors `FederatedRunner`'s surface: `run(x, y, num_rounds)` returns
    the final iterates, `history` / `metric_series` record per-round
    metrics, `wire_report` prices the strategy.  Construction takes the
    loss + strategy directly (there is no externally-built round
    function to wrap — the runner owns the phase schedule)."""

    def __init__(
        self,
        loss: Callable,
        strategy,
        agent_data: Pytree,
        num_local_steps: int,
        eta_x: float,
        eta_y: Optional[float] = None,
        *,
        proj_x: Callable = identity_proj,
        proj_y: Callable = identity_proj,
        metric_fn: Optional[Callable] = None,
        devices: Optional[Sequence] = None,
        pod_map=None,
        telemetry=None,
        **strategy_kwargs,
    ):
        self._strategy = resolve_strategy(strategy, **strategy_kwargs)
        self._K = num_local_steps
        #: repro.obs.Telemetry sink or None (None = pre-telemetry code
        #: verbatim); public so tests flip it on a compiled runner
        self.telemetry = telemetry
        self._loss = loss
        self._num_local_steps = num_local_steps
        self._eta_x = eta_x
        self._eta_y = eta_x if eta_y is None else eta_y
        self._proj_x = proj_x
        self._proj_y = proj_y
        self._m = _num_agents(agent_data)

        devices = list(devices) if devices is not None else jax.local_devices()
        self._pod_map = pod_map
        if pod_map is not None:
            # pod-aligned sharding: pick a shard count dividing the pod
            # count so every shard holds whole pods — the existing
            # skip-absent-shards dispatch below then doubles as "skip
            # quiet pods" with no pod-specific branching (fed.pods)
            from .pods import pod_aligned_shard_count

            if pod_map.m != self._m:
                raise ValueError(
                    f"pod_map is for m={pod_map.m}, runner has {self._m}"
                )
            if self._m % pod_map.num_pods != 0:
                raise ValueError(
                    f"pod-aligned sharding needs m divisible by the pod "
                    f"count, got m={self._m}, pods={pod_map.num_pods}"
                )
            self._n_shards = pod_aligned_shard_count(
                pod_map.num_pods, len(devices)
            )
        else:
            self._n_shards = largest_shard_count(self._m, len(devices))
        self._per = self._m // self._n_shards
        #: server device: owns the exchange transform, sampling RNG and
        #: the aggregate; also hosts shard 0 (a dedicated server device
        #: would idle during local steps on small hosts)
        self._server = devices[0]
        self._shard_devices = devices[: self._n_shards]
        self._data_s = [
            jax.device_put(
                _slice_agents(agent_data, i * self._per, (i + 1) * self._per),
                d,
            )
            for i, d in enumerate(self._shard_devices)
        ]

        self._phases = make_phases(
            loss,
            self._strategy,
            num_local_steps,
            eta_x,
            eta_y,
            proj_x=proj_x,
            proj_y=proj_y,
        )
        self._gfn = grad_xy(loss)
        self._vgrad = jax.vmap(self._gfn, in_axes=(0, 0, 0))
        self._use_corr = bool(getattr(self._strategy, "use_correction", False))
        self._sync_every = bool(
            getattr(self._strategy, "sync_every_step", False)
        )
        self._cdt = getattr(self._strategy, "correction_dtype", None)
        self._noise = getattr(self._strategy, "noise", None)
        self._nvgrad = (
            make_noise_vgrad(self._gfn, self._noise)
            if self._noise is not None
            else None
        )
        self._fused = (
            self._use_corr
            and self._m > 1
            and bool(self._strategy.exact_correction)
            # momentum folds the correction into a velocity, so the
            # first step is no longer the plain anchor update
            and not getattr(self._strategy, "momentum", 0.0)
        )
        self._build_programs()

        self._metric_fn = jax.jit(metric_fn) if metric_fn else None
        self._server_state: Dict = {}
        self._shard_state: Optional[List[Dict]] = None
        #: set by an elastic run: {"tracker", "prev_active"} where it
        #: left off (mirrors FederatedRunner.elastic_state)
        self.elastic_state: Optional[Dict] = None
        self.history: List[RoundStats] = []

    @property
    def pods_per_shard(self) -> Optional[int]:
        """Whole pods per agent shard under pod-aligned sharding (None
        without a pod_map) — a quiet run of this many consecutive pods
        makes its shard's programs skip entirely."""
        if self._pod_map is None:
            return None
        return self._pod_map.num_pods // self._n_shards

    # ------------------------------------------------------------ programs
    def _build_programs(self) -> None:
        ph = self._phases
        strategy = self._strategy
        cdt = self._cdt
        fused = self._fused

        noise = self._noise

        def shard_grads(x, y, data_s, nk_s=None):
            """Per-shard anchor gradients (the up half of the exchange).
            `nk_s` is this shard's slice of the round's per-agent noise
            keys; None — a noiseless strategy, or the tracker init,
            which must match the sync path's deterministic
            `sim.init_tracker` — is the exact oracle (the dispatch is
            trace-time: None vs array is part of the jit signature)."""
            rs = ph.broadcast(x, y, data_s, {}, weights=None,
                              noise_keys=nk_s)
            if noise is None or nk_s is None:
                g = self._vgrad(rs.xs, rs.ys, data_s)
            else:
                g = self._nvgrad(
                    noise_eval_keys(rs.noise_keys, 0), rs.xs, rs.ys, data_s
                )
            return g.gx, g.gy

        def shard_point_grads(x, y, data_s):
            """Per-agent gradients at the SHARED point (FullSync: every
            'local' step is evaluated at the current global iterate)."""
            g = jax.vmap(self._gfn, in_axes=(None, None, 0))(x, y, data_s)
            return g.gx, g.gy

        def fullsync_step(x, y, gx, gy, weights):
            """One centralized GDA step from gathered per-agent grads;
            weights None is the bitwise-pinned uniform mean, an elastic
            round passes its re-normalized active-set weights."""
            gxm = agent_mean(gx, weights)
            gym = agent_mean(gy, weights)
            x1 = self._proj_x(
                jax.tree.map(lambda u, v: u - self._eta_x * v, x, gxm)
            )
            y1 = self._proj_y(
                jax.tree.map(lambda u, v: u + self._eta_y * v, y, gym)
            )
            return x1, y1

        def server_exchange(gx, gy, state, weights):
            """Server half of exchange_corrections: gbar, corrections,
            strategy transform (same draws as the sync path), decode."""
            gbar_x = agent_mean(gx, weights)
            gbar_y = agent_mean(gy, weights)
            cx, cy = tracking_corrections(gx, gy, gbar_x, gbar_y, cdt)
            cx, cy, state = strategy.transform_correction(cx, cy, state)
            if hasattr(cx, "decode"):
                cx = cx.decode()
            if hasattr(cy, "decode"):
                cy = cy.decode()
            return cx, cy, gbar_x, gbar_y, state

        def shard_steps(x, y, data_s, cx_s, cy_s, gbar_x, gbar_y, w_s,
                        b_s=None, nk_s=None):
            """Per-shard local_steps + partial aggregate — ONE body for
            both schedules (b_s None is the legacy pinned trace; an
            elastic round passes its budget slice).  It is jitted twice
            below: the legacy instance DONATES the broadcast buffers
            (x, y) — by the time it runs they have served the gradient
            program, and freeing them lets the next round's
            double-buffered transfer land without growing the working
            set — while the elastic instance re-broadcasts per round
            (the set of live shard programs changes with membership, so
            there is no stable double-buffer to donate into)."""
            rs = ph.broadcast(x, y, data_s, {}, weights=None,
                              step_budgets=b_s, noise_keys=nk_s)
            rs = dataclasses.replace(
                rs, cx=cx_s, cy=cy_s, gbar_x=gbar_x, gbar_y=gbar_y,
                fused=fused,
            )
            rs = ph.local_steps(rs, data_s)
            return (
                agent_weighted_sum(rs.xs, w_s),
                agent_weighted_sum(rs.ys, w_s),
            )

        def server_combine(x_sums, y_sums):
            """Combine the shards' partial aggregates and project.  The
            shard sums already carry the participation weights (or 1/m
            for uniform averaging), so the combine is a plain sum."""
            x1 = jax.tree.map(lambda *u: sum(u), *x_sums)
            y1 = jax.tree.map(lambda *u: sum(u), *y_sums)
            return self._proj_x(x1), self._proj_y(y1)

        def zeros_like_agents(bx, by):
            """m == 1: the correction is identically zero and elided."""
            z = lambda t: jax.tree.map(
                lambda u: jnp.zeros((1,) + u.shape, u.dtype), t
            )
            return z(bx), z(by)

        def server_exchange_elastic(gx, gy, state, active, tab_x, tab_y,
                                    prev_active):
            """Membership-aware server exchange: one thin jit wrapper
            over `sim.elastic.tracker_exchange` — the SAME function the
            sync elastic round fuses, so the GT-invariant math (and the
            in-jit EF re-anchoring) has one owner whatever the
            execution schedule (skipped shards deliver zero-filled
            gradient rows that the active mask discards in favor of the
            stale tracker rows)."""
            from ..sim.elastic import tracker_exchange

            return tracker_exchange(
                strategy, gx, gy, state, active, tab_x, tab_y, cdt,
                prev_active,
            )

        self._shard_grads = jax.jit(shard_grads)
        self._shard_point_grads = jax.jit(shard_point_grads)
        self._fullsync_step = jax.jit(fullsync_step)
        self._server_exchange = jax.jit(server_exchange)
        self._shard_steps = jax.jit(shard_steps, donate_argnums=(0, 1))
        self._server_combine = jax.jit(server_combine)
        self._zeros_like_agents = jax.jit(zeros_like_agents)
        self._server_exchange_elastic = jax.jit(server_exchange_elastic)
        self._shard_steps_elastic = jax.jit(shard_steps)

    # ---------------------------------------------------------- state plumbing
    def _init_state(self, x: Pytree, y: Pytree) -> None:
        strategy = self._strategy
        if not getattr(strategy, "stateful", False):
            self._server_state = {}
            self._shard_state = [{} for _ in range(self._n_shards)]
            return
        full = strategy.init_state(x, y, self._m)
        sharded_keys = tuple(
            k for k in getattr(strategy, "sharded_state_keys", ()) if k in full
        )
        self._server_state = {
            k: jax.device_put(v, self._server)
            for k, v in full.items()
            if k not in sharded_keys
        }
        per = self._per
        self._shard_state = [
            {
                k: jax.device_put(
                    _slice_agents(full[k], i * per, (i + 1) * per), d
                )
                for k in sharded_keys
            }
            for i, d in enumerate(self._shard_devices)
        ]
        self._sharded_keys = sharded_keys

    def _gather_state(self) -> Dict:
        """Full strategy state on the server device: sharded entries are
        gathered (they ride the same up-link as the corrections they
        compensate), the rest already live there."""
        state = dict(self._server_state)
        for k in getattr(self, "_sharded_keys", ()):
            parts = [
                jax.device_put(s[k], self._server) for s in self._shard_state
            ]
            state[k] = jax.tree.map(
                lambda *u: jnp.concatenate(u, axis=0), *parts
            )
        return state

    def _scatter_state(self, state: Dict) -> None:
        """Split the transform's updated state back: per-agent entries to
        their shard devices, the rest stays server-side."""
        per = self._per
        for k in getattr(self, "_sharded_keys", ()):
            full = state.pop(k)
            for i, (s, d) in enumerate(
                zip(self._shard_state, self._shard_devices)
            ):
                s[k] = jax.device_put(
                    _slice_agents(full, i * per, (i + 1) * per), d
                )
        self._server_state = state

    # ------------------------------------------------------------- round loop
    def _round_weights(self):
        """Participation sampling, once per round, server-side — shards
        receive their weight slices instead of re-sampling (the draws
        must match the sync path's exactly)."""
        strategy = self._strategy
        state = self._server_state
        weights, state = strategy.sample_weights(state, self._m)
        self._server_state = state
        if weights is None:
            w = jnp.full((self._m,), 1.0 / self._m)
        else:
            w = weights
        per = self._per
        w_slices = [
            jax.device_put(w[i * per : (i + 1) * per], d)
            for i, d in enumerate(self._shard_devices)
        ]
        return weights, w_slices

    def _round_noise_keys(self):
        """Per-agent gradient-noise keys, once per round, server-side —
        shards receive their slices (mirrors `_round_weights`: the draws
        must match the sync path's exactly, which holds because the keys
        are folded by global agent index — see `fed.noise`)."""
        if self._noise is None:
            return [None] * self._n_shards
        keys, state = self._strategy.sample_noise_keys(
            self._server_state, self._m
        )
        self._server_state = state
        per = self._per
        return [
            jax.device_put(keys[i * per : (i + 1) * per], d)
            for i, d in enumerate(self._shard_devices)
        ]

    def _run_fullsync_round(self, x, y, weights=None, shard_live=None):
        """FullSync: K communicated steps; each is a per-shard gradient
        fan-out + server combine (no local divergence to overlap).
        `weights` None is the legacy uniform mean; an elastic round
        passes its re-normalized active-set weights (budgets are
        meaningless here — there are no local phases to cap) and
        `shard_live`, so fully-absent shards are never dispatched —
        their zero-weight rows are zero-filled server-side."""
        zx = zy = None
        if shard_live is not None and not all(shard_live):
            zx, zy = self._zero_shard_rows(x, y)
        for _ in range(self._K):
            gs = [
                self._shard_point_grads(
                    jax.device_put(x, d), jax.device_put(y, d), data
                )
                if shard_live is None or shard_live[i]
                else None
                for i, (d, data) in enumerate(
                    zip(self._shard_devices, self._data_s)
                )
            ]
            gx = self._concat_server(
                [g[0] if g is not None else zx for g in gs]
            )
            gy = self._concat_server(
                [g[1] if g is not None else zy for g in gs]
            )
            x, y = self._fullsync_step(x, y, gx, gy, weights)
        return x, y

    def _bcast(self, x, y) -> List:
        """Double-buffer fill: fresh per-shard (x, y) broadcast buffers.
        Cross-device `device_put` transfers into a new buffer; for the
        shard sharing the server device the copy is explicit —
        `device_put` to the resident device is a no-op alias, and these
        buffers are DONATED into the local-step program, which must
        never delete an array the caller (or the next round) still
        owns."""
        out = []
        for d in self._shard_devices:
            if d == self._server:
                out.append(
                    (jax.tree.map(jnp.copy, x), jax.tree.map(jnp.copy, y))
                )
            else:
                out.append((jax.device_put(x, d), jax.device_put(y, d)))
        return out

    def _concat_server(self, parts: List[Pytree]) -> Pytree:
        return concat_on_device(parts, self._server)

    def _zero_shard_rows(self, x, y):
        """One shard's worth of zero-filled per-agent gradient rows —
        the stand-in for a shard that was never dispatched this round
        (the active mask / zero weights discard them downstream).  ONE
        owner of the placeholder layout for both elastic paths."""
        z = lambda t: jax.tree.map(
            lambda u: jnp.zeros((self._per,) + u.shape, u.dtype), t
        )
        return z(x), z(y)

    def run(
        self,
        x: Pytree,
        y: Pytree,
        num_rounds: int,
        log_every: int = 0,
        state: Optional[Pytree] = None,
        schedule=None,
        rebase: bool = True,
        elastic_state: Optional[Dict] = None,
    ):
        x = jax.device_put(x, self._server)
        y = jax.device_put(y, self._server)
        if self._shard_state is None:
            self._init_state(x, y)
            if state is not None:
                # resume: re-split a checkpointed full state
                self._scatter_state(dict(state))
        if schedule is not None and schedule.is_static_full:
            # degenerate schedule: the overlapped legacy loop below IS
            # the full-participation run
            schedule = None
        self._last_schedule = schedule
        if schedule is not None:
            return self._run_elastic(
                x, y, num_rounds, schedule, rebase, log_every,
                elastic_state,
            )
        tm = self.telemetry
        per_agent = None
        if tm is not None:
            self._emit_wire_probe(tm, x, y)
            per_agent = self._wire_counter_args(x, y, scheduled=False)
        # double-buffered broadcast: the per-shard (x, y) copies for the
        # round ABOUT to run; refreshed (device_put enqueued) as soon as
        # the aggregate producing the next iterates is dispatched.
        # FullSync has no local phase to pre-feed — its per-step fan-out
        # transfers live inside _run_fullsync_round
        bcast = None if self._sync_every else self._bcast(x, y)
        for t in range(num_rounds):
            t0 = time.perf_counter()
            if tm is not None:
                tm.begin_round(t)
            if self._sync_every:
                x, y = self._run_fullsync_round(x, y)
            else:
                x, y, bcast = self._run_round(x, y, bcast)
            metrics = {}
            if self._metric_fn is not None:
                metrics = {
                    k: float(v) for k, v in self._metric_fn(x, y).items()
                }
            dt = time.perf_counter() - t0
            self.history.append(RoundStats(t, metrics, dt))
            if tm is not None:
                tm.round_event(
                    t, runtime="async", seconds=dt,
                    n_shards=self._n_shards,
                )
                if per_agent is not None:
                    tm.counter(
                        "wire_bytes", per_agent * self._m,
                        per_agent=per_agent, n_active=self._m,
                    )
                self._emit_probes(tm, t, x, y)
                tm.end_round(t)
            if log_every and (t % log_every == 0 or t == num_rounds - 1):
                msg = " ".join(f"{k}={v:.3e}" for k, v in metrics.items())
                print(f"[async round {t:5d}] {msg} ({dt*1e3:.1f} ms)")
        jax.block_until_ready((x, y))
        return x, y

    def _run_round(self, x, y, bcast):
        from ..obs.telemetry import maybe_span

        tm = self.telemetry
        weights, w_slices = self._round_weights()
        nk_slices = self._round_noise_keys()
        per = self._per
        cx_s = cy_s = [None] * self._n_shards
        gbx_s = gby_s = [None] * self._n_shards
        if self._use_corr and self._m > 1:
            # fan-out: every shard's anchor-gradient program is dispatched
            # before any result is awaited (async dispatch == one stream
            # per device); the device_put gathers below overlap shards
            # that are still computing
            with maybe_span(tm, "exchange_corrections",
                            dispatches=self._n_shards):
                gs = [
                    self._shard_grads(bx, by, data, nk)
                    for (bx, by), data, nk in zip(
                        bcast, self._data_s, nk_slices
                    )
                ]
                gx = self._concat_server([g[0] for g in gs])
                gy = self._concat_server([g[1] for g in gs])
                full_state = self._gather_state()
                cx, cy, gbar_x, gbar_y, new_state = self._server_exchange(
                    gx, gy, full_state, weights
                )
                self._scatter_state(dict(new_state))
                # down-link: correction slices + the global anchor gradient
                cx_s = [
                    jax.device_put(
                        _slice_agents(cx, i * per, (i + 1) * per), d
                    )
                    for i, d in enumerate(self._shard_devices)
                ]
                cy_s = [
                    jax.device_put(
                        _slice_agents(cy, i * per, (i + 1) * per), d
                    )
                    for i, d in enumerate(self._shard_devices)
                ]
                gbx_s = [
                    jax.device_put(gbar_x, d) for d in self._shard_devices
                ]
                gby_s = [
                    jax.device_put(gbar_y, d) for d in self._shard_devices
                ]
        elif self._use_corr:
            # m == 1: correction identically zero — build it shard-side
            z = [self._zeros_like_agents(bx, by) for (bx, by) in bcast]
            cx_s = [zi[0] for zi in z]
            cy_s = [zi[1] for zi in z]

        with maybe_span(tm, "local_steps", dispatches=self._n_shards):
            sums = [
                self._shard_steps(
                    bx, by, data, cxi, cyi, gbxi, gbyi, wi, None, nki
                )
                for (bx, by), data, cxi, cyi, gbxi, gbyi, wi, nki in zip(
                    bcast, self._data_s, cx_s, cy_s, gbx_s, gby_s, w_slices,
                    nk_slices,
                )
            ]
        with maybe_span(tm, "aggregate"):
            x1, y1 = self._server_combine(
                [jax.device_put(a, self._server) for a, _ in sums],
                [jax.device_put(b, self._server) for _, b in sums],
            )
        # double-buffer flip: enqueue next round's broadcast immediately
        # (the transfers ride behind the still-executing local steps; the
        # donated buffers they replace free as those programs retire)
        with maybe_span(tm, "broadcast", dispatches=self._n_shards):
            bcast = self._bcast(x1, y1)
        return x1, y1, bcast

    # ---------------------------------------------------------- elastic rounds
    def _run_elastic(self, x, y, num_rounds, schedule, rebase, log_every,
                     elastic_state=None):
        """Drive `num_rounds` through the membership-aware schedule:
        shards join/leave between rounds (fully-absent shards are never
        dispatched), budgets gate local steps, the tracker table lives
        server-side.  Same schedule + same strategy draws as the sync
        runner's elastic loop => iterates match to fp tolerance.

        The loop itself is the shared `RunnerHistoryMixin._drive_elastic`
        driver, so validation, continuation (`elastic_state` +
        `schedule.tail`) and per-round bookkeeping cannot drift between
        the runtimes; only the per-round step differs (per-shard
        dispatch here, the fused elastic program in `FederatedRunner`).
        The tracker table initializes lazily from the first round's
        broadcast (the per-shard agent data never leaves its device)."""
        x, y = self._drive_elastic(
            x, y, num_rounds, schedule, rebase, log_every, elastic_state,
            lambda xx, yy: None,  # lazy: built from the first broadcast
            self._run_elastic_round, "elastic async round",
            num_agents=self._m,
        )
        jax.block_until_ready((x, y))
        return x, y

    def _init_tracker(self, bcast):
        """Tracker table at the first elastic round: every agent's
        anchor gradient at the current broadcast iterate, gathered from
        ALL shards once (matches `sim.init_tracker` on the sync path)."""
        gs = [
            self._shard_grads(bx, by, data)
            for (bx, by), data in zip(bcast, self._data_s)
        ]
        return {
            "gx": self._concat_server([g[0] for g in gs]),
            "gy": self._concat_server([g[1] for g in gs]),
        }

    def _run_elastic_round(self, x, y, ev, agg, tracker, prev_active):
        from ..obs.telemetry import maybe_span

        tm = self.telemetry
        per = self._per
        active = jax.device_put(jnp.asarray(ev.active), self._server)
        weights = agg.weights(active)
        n = self._n_shards
        shard_live = [
            bool(ev.active[i * per : (i + 1) * per].any()) for i in range(n)
        ]
        if tm is not None:
            for i, live in enumerate(shard_live):
                if not live:
                    tm.emit("event", "shard_skipped", shard=i)

        if self._sync_every:
            x, y = self._run_fullsync_round(x, y, weights, shard_live)
            return x, y, tracker

        budgets = jnp.asarray(ev.budgets)
        # one noise draw per round, server-side, exactly as the sync
        # elastic round's broadcast samples it — including for absent
        # agents, whose keys are drawn and discarded (the fold tree is
        # positional, so presence cannot shift other agents' draws)
        nk_slices = self._round_noise_keys()
        # fresh per-shard broadcast (no donation — see shard_steps_elastic);
        # absent shards still receive it cheaply enough, keeping the
        # transfer schedule uniform
        with maybe_span(tm, "broadcast", dispatches=n):
            bcast = [
                (jax.device_put(x, d), jax.device_put(y, d))
                for d in self._shard_devices
            ]
        w_slices = [
            jax.device_put(weights[i * per : (i + 1) * per], d)
            for i, d in enumerate(self._shard_devices)
        ]
        b_slices = [
            jax.device_put(budgets[i * per : (i + 1) * per], d)
            for i, d in enumerate(self._shard_devices)
        ]

        cx_s = cy_s = [None] * n
        gbx_s = gby_s = [None] * n
        if self._use_corr:
            _exch_t0 = time.perf_counter()
            if tracker is None:
                tracker = self._init_tracker(bcast)
            else:
                # no-op when already resident (every round after the
                # first); a cross-runtime resume hands us host/default-
                # device arrays that must land server-side
                tracker = jax.device_put(tracker, self._server)
            # fan-out: only LIVE shards' anchor-gradient programs are
            # dispatched; a fully-absent shard's rows are zero-filled
            # placeholders the active mask discards in favor of the
            # stale tracker rows
            gs = [
                self._shard_grads(bx, by, data, nk) if live else None
                for live, (bx, by), data, nk in zip(
                    shard_live, bcast, self._data_s, nk_slices
                )
            ]
            if not all(shard_live):
                # placeholders only when a shard actually skipped
                zx, zy = self._zero_shard_rows(x, y)
            gx = self._concat_server(
                [g[0] if g is not None else zx for g in gs]
            )
            gy = self._concat_server(
                [g[1] if g is not None else zy for g in gs]
            )
            full_state = self._gather_state()
            (
                cx, cy, gbar_x, gbar_y, new_state, tab_x, tab_y
            ) = self._server_exchange_elastic(
                gx, gy, full_state, active, tracker["gx"], tracker["gy"],
                agg.round_prev_active(active, prev_active),
            )
            tracker = {"gx": tab_x, "gy": tab_y}
            self._scatter_state(dict(new_state))
            cx_s = [
                jax.device_put(_slice_agents(cx, i * per, (i + 1) * per), d)
                for i, d in enumerate(self._shard_devices)
            ]
            cy_s = [
                jax.device_put(_slice_agents(cy, i * per, (i + 1) * per), d)
                for i, d in enumerate(self._shard_devices)
            ]
            gbx_s = [jax.device_put(gbar_x, d) for d in self._shard_devices]
            gby_s = [jax.device_put(gbar_y, d) for d in self._shard_devices]
            if tm is not None:
                # post-hoc span (the body stays un-nested): dispatch +
                # host time of the live shards' exchange fan-out
                tm.emit(
                    "span", "exchange_corrections",
                    seconds=time.perf_counter() - _exch_t0,
                    dispatches=sum(shard_live),
                )

        # local steps only on live shards: a shard that left this round
        # runs NOTHING (that is the elastic contract — its weight slice
        # is zero, so it has no aggregate share either)
        with maybe_span(tm, "local_steps", dispatches=sum(shard_live)):
            sums = [
                self._shard_steps_elastic(
                    bcast[i][0], bcast[i][1], self._data_s[i],
                    cx_s[i], cy_s[i], gbx_s[i], gby_s[i],
                    w_slices[i], b_slices[i], nk_slices[i],
                )
                for i in range(n)
                if shard_live[i]
            ]
        with maybe_span(tm, "aggregate"):
            x1, y1 = self._server_combine(
                [jax.device_put(a, self._server) for a, _ in sums],
                [jax.device_put(b, self._server) for _, b in sums],
            )
        return x1, y1, tracker

    # ------------------------------------------------------------- reporting
    # `wire_report` comes from RunnerHistoryMixin (one owner for both
    # runtimes, schedule-aware); probes read the gathered state:
    def _telemetry_state(self) -> Dict:
        return self._gather_state()
