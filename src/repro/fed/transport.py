"""Sparse wire transport for compressed tracking corrections.

PR 1/PR 2 priced the compressed-correction exchange analytically
(`CommStrategy.bytes_per_round`) but still moved DENSE masked tensors:
the fused compress kernel hands the engine a dense tree, so the traffic
the collectives carry never matched the price.  This module is the wire
format that closes that gap:

  LeafSpec      static layout of one packed leaf — rows (quantization
                groups), cols, kept-per-row k, bits, the chosen encoding
                and the index/scale widths.  `LeafSpec.build` is the
                SINGLE owner of the payload arithmetic: the strategies'
                `bytes_per_round` pricing and the encoder's buffer
                shapes both derive from it, so priced bytes equal packed
                buffer lengths by construction.
  LeafPayload   the actual packed buffers for one leaf: bit-packed
                uint32 words (or raw values), uint16/int32 indices, and
                per-row scales in a CSR-style flat layout (k is constant
                per row, so offsets are implicit).
  encode_leaf / decode_leaf
                pack one flattened [R, C] leaf / scatter-add it back to
                the dense correction; fused Pallas path on lane-aligned
                leaves, pure-jnp oracle otherwise (both are
                `kernels.ref.pack_payload_ref`'s math on the same
                uniform draws, so decode(encode(c)) reproduces the dense
                compressed correction bitwise).
  PackedTree    what a wire-transport strategy returns from
                `transform_correction` instead of a dense tree; the
                engine's server aggregation path calls `.decode()` to
                scatter-add the payloads back before the local steps.
  measured_bytes_per_round
                probe of the ACTUAL packed buffer lengths (via
                jax.eval_shape over the encoder), reported next to the
                analytic price in `fed.comm.comm_table` and
                benchmarks/comm_efficiency.py so the accounting cannot
                silently drift.

Quantization groups are the rows of the [R, C] layout: a per-agent leaf
of shape (.., d) contributes size // d rows of length d (vectors are one
row), each with its own max-abs scale — and the pricing charges one
scale per GROUP, not one per leaf.  Index width derives from the row
length (uint16 up to 2**16 columns, int32 beyond).  Values are stored at
`ref.storage_bits(bits)` — the next power-of-two sub-word width — so
levels never straddle words.  Each packed leaf also carries a fixed
HEADER_BYTES of static metadata (rows/cols/k/bits/encoding/dtype tags),
priced separately from the payload.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ref
from ..kernels.compress_correction import LANE
from ..kernels.pack_payload import pack_payload_2d, unpack_payload_2d

Pytree = Any

#: fixed per-leaf wire header: rows (u32) + cols (u32) + k (u32) +
#: bits/mode/encoding/index-width/scale-width/dtype tags (4 bytes)
HEADER_BYTES = 16


def wire_rows_cols(shape: Tuple[int, ...]) -> Tuple[int, int]:
    """[rows, cols] wire layout of one per-agent leaf: last-axis rows are
    the quantization groups (per-channel scales for matrices), vectors
    and scalars are a single group."""
    if len(shape) == 0:
        return 1, 1
    if len(shape) == 1:
        return 1, max(1, shape[0])
    cols = shape[-1]
    return int(np.prod(shape[:-1], dtype=np.int64)), cols


def index_dtype_for(cols: int):
    """Narrowest integer that can index a row of length `cols` (max
    stored index cols - 1) — the same width the pricing charges (no
    hard-coded 4-byte indices).  UNSIGNED 16-bit, not int16: column
    indices reach cols - 1, and a signed halfword overflows at 2**15,
    silently corrupting the scatter-add for rows between 32769 and
    65536 columns."""
    return jnp.uint16 if cols <= 2**16 else jnp.int32


@dataclasses.dataclass(frozen=True)
class LeafSpec:
    """Static wire layout of one packed correction leaf."""

    rows: int
    cols: int
    k: int            # kept entries per row (== cols when not sparsifying)
    bits: int         # quantization grid width (>= 32: unquantized)
    mode: str         # "topk" | "randk" (does not affect bytes)
    dtype: Any        # leaf value dtype (np.dtype)
    #: wire representation, the cheapest of:
    #:   dense        the full masked/quantized row at leaf dtype
    #:   sparse       k (value, index) pairs at leaf dtype
    #:   quant        k bit-packed levels + indices + per-row scale
    #:   quant_dense  ALL cols bit-packed levels + per-row scale, no
    #:                indices (masked levels encode exact zeros) — wins
    #:                over `quant` once k/cols outgrows the index cost
    encoding: str

    @classmethod
    def build(cls, shape, dtype, ratio: float, bits: int,
              mode: str = "topk") -> "LeafSpec":
        """Layout for one per-agent leaf of `shape`/`dtype` compressed at
        (`ratio`, `bits`): picks the cheapest encoding, exactly like the
        payload pricing (this IS the payload pricing) — candidate costs
        are wire_bytes() itself, so the chooser and the buffers cannot
        desynchronize.

        The encoding only chooses the wire REPRESENTATION of the
        already-compressed values — `bits` < 32 quantizes every leaf of
        the tree uniformly (the estimator the convergence analysis sees
        must not vary with leaf size), so a tiny leaf whose cheapest
        encoding is "sparse" or "dense" still carries quantized values,
        just at full storage width."""
        rows, cols = wire_rows_cols(tuple(shape))
        dt = np.dtype(dtype)
        k = cols if ratio >= 1 else max(1, math.ceil(ratio * cols))
        candidates = ["dense"]
        if k < cols:
            candidates.append("sparse")
        if bits < 32:
            candidates.append("quant")
            if k < cols:
                candidates.append("quant_dense")
        base = cls(rows, cols, k, bits, mode, dt, "dense")
        costs = {
            e: dataclasses.replace(base, encoding=e).wire_bytes()
            for e in candidates
        }
        encoding = min(costs, key=lambda e: (costs[e], e != "dense"))
        return dataclasses.replace(base, encoding=encoding)

    def stacked(self, m: int) -> "LeafSpec":
        """The same layout with m agents' rows stacked (the shape the
        strategies actually encode); costs scale linearly, so the
        encoding choice is unchanged."""
        return dataclasses.replace(self, rows=self.rows * m)

    # ------------------------------------------------------ wire widths
    @property
    def sparse(self) -> bool:
        return self.k < self.cols

    @property
    def index_dtype(self):
        return index_dtype_for(self.cols)

    @property
    def scale_dtype(self):
        return ref.compute_dtype(self.dtype)

    @property
    def words_per_row(self) -> int:
        n = self.cols if self.encoding == "quant_dense" else self.k
        return ref.word_layout(n, self.bits)[2]

    def wire_bytes(self) -> int:
        """Exact payload bytes of the packed buffers (no header) — the
        single owner of the per-encoding arithmetic: LeafSpec.build's
        chooser and LeafPayload.nbytes both reduce to it."""
        if self.encoding == "dense":
            return self.rows * self.cols * self.dtype.itemsize
        idx = self.rows * self.k * np.dtype(self.index_dtype).itemsize
        if self.encoding == "sparse":
            return self.rows * self.k * self.dtype.itemsize + idx
        scale = self.rows * np.dtype(self.scale_dtype).itemsize
        words = self.rows * 4 * self.words_per_row
        if self.encoding == "quant_dense":
            return words + scale
        return words + scale + (idx if self.sparse else 0)

    def total_bytes(self) -> int:
        return self.wire_bytes() + HEADER_BYTES


class LeafPayload(NamedTuple):
    """Packed buffers of one leaf.  indices is None for dense encodings
    (and for k == cols, where indices are implicit); scales is None
    unless the values are bit-packed quantized levels."""

    data: jax.Array
    indices: Optional[jax.Array]
    scales: Optional[jax.Array]

    @property
    def nbytes(self) -> int:
        return sum(
            int(a.size) * np.dtype(a.dtype).itemsize
            for a in (self.data, self.indices, self.scales)
            if a is not None
        )


def _fusable(spec: LeafSpec) -> bool:
    return spec.cols > 0 and spec.cols % LANE == 0


def encode_leaf(
    c: jax.Array,  # [rows, cols] flattened leaf (feedback NOT yet injected)
    e: Optional[jax.Array],
    u_sel: Optional[jax.Array],
    u_rnd: Optional[jax.Array],
    spec: LeafSpec,
    *,
    use_kernel: bool = False,
    interpret: bool = True,
) -> Tuple[LeafPayload, jax.Array]:
    """Pack one leaf into its wire payload.  Returns (payload, resid)
    with resid = (c + e) - decode(payload) in c.dtype — the
    error-feedback update, identical to the dense compress path's."""
    kw = dict(
        k=spec.k, bits=spec.bits, mode=spec.mode, encoding=spec.encoding
    )
    if use_kernel and _fusable(spec):
        data, idx, scale, resid = pack_payload_2d(
            c, e, u_sel, u_rnd,
            index_dtype=spec.index_dtype, scale_dtype=spec.scale_dtype,
            interpret=interpret, **kw,
        )
    else:
        data, idx, scale, resid = ref.pack_payload_ref(
            c, e, u_sel, u_rnd, index_dtype=spec.index_dtype, **kw
        )
    keep_idx = spec.sparse and spec.encoding in ("sparse", "quant")
    keep_scale = spec.encoding in ("quant", "quant_dense")
    return (
        LeafPayload(data, idx if keep_idx else None,
                    scale if keep_scale else None),
        resid,
    )


def decode_leaf(
    payload: LeafPayload,
    spec: LeafSpec,
    *,
    use_kernel: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Scatter-add the packed payload back to the dense [rows, cols]
    compressed correction (bitwise the chat that produced it)."""
    rows = payload.data.shape[0]
    idx = payload.indices
    if idx is None:  # dense, or k == cols: indices are implicit
        idx = jax.lax.broadcasted_iota(jnp.int32, (rows, spec.k), 1)
    scale = payload.scales
    if scale is None:
        scale = jnp.zeros((rows, 1), spec.scale_dtype)
    kw = dict(
        cols=spec.cols, dtype=spec.dtype, k=spec.k, bits=spec.bits,
        encoding=spec.encoding,
    )
    if use_kernel and _fusable(spec):
        return unpack_payload_2d(
            payload.data, idx, scale, interpret=interpret, **kw
        )
    return ref.decode_payload_ref(payload.data, idx, scale, **kw)


class PackedTree:
    """A correction pytree in wire format: what a wire-transport strategy
    returns from `transform_correction` instead of the dense tree.  The
    engine's server aggregation path detects it by its `decode` hook and
    scatter-adds the payloads back into dense [m, *leaf_shape] arrays
    before driving the local steps."""

    def __init__(self, payloads: List[LeafPayload], specs: List[LeafSpec],
                 treedef, shapes: List[Tuple[int, ...]],
                 use_kernel: bool = False, interpret: bool = True):
        self.payloads = payloads
        self.specs = specs
        self.treedef = treedef
        self.shapes = shapes  # original [m, *leaf_shape] shapes
        self.use_kernel = use_kernel
        self.interpret = interpret

    def decode(self) -> Pytree:
        leaves = [
            decode_leaf(
                p, s, use_kernel=self.use_kernel, interpret=self.interpret
            ).reshape(shape)
            for p, s, shape in zip(self.payloads, self.specs, self.shapes)
        ]
        return jax.tree.unflatten(self.treedef, leaves)

    def wire_bytes(self) -> int:
        """Actual packed buffer bytes across all leaves and agents."""
        return sum(p.nbytes for p in self.payloads)

    def total_bytes(self) -> int:
        return self.wire_bytes() + HEADER_BYTES * len(self.payloads)


# --------------------------------------------------------------------------
# measured-bytes probe (actual packed buffer lengths, not the price)
# --------------------------------------------------------------------------
def probe_leaf_bytes(spec: LeafSpec) -> int:
    """Measure one leaf's payload by ENCODING it abstractly: eval_shape
    the encoder and sum the emitted buffer sizes.  This is the empirical
    check on LeafSpec.wire_bytes — the two must agree (and a conformance
    test pins that), but the probe never trusts the arithmetic."""
    c = jax.ShapeDtypeStruct((spec.rows, spec.cols), spec.dtype)
    u = jax.ShapeDtypeStruct((spec.rows, spec.cols), jnp.float32)
    payload = jax.eval_shape(
        lambda cc, uu: encode_leaf(cc, None, uu, uu, spec)[0], c, u
    )
    return sum(
        int(s.size) * np.dtype(s.dtype).itemsize
        for s in jax.tree.leaves(payload)
    )


def dense_payload_bytes(tree: Pytree) -> int:
    """Dense payload bytes of one model copy (works on arrays and
    ShapeDtypeStructs alike) — the single owner of the dense-size sum,
    shared with the strategies' pricing."""
    return sum(
        int(u.size) * np.dtype(u.dtype).itemsize for u in jax.tree.leaves(tree)
    )


def measured_bytes_per_round(
    strategy, x: Pytree, y: Pytree, num_local_steps: int,
    *, include_headers: bool = True,
) -> int:
    """Per-agent wire bytes of one round, MEASURED from the packed buffer
    shapes the encoder actually emits (plus HEADER_BYTES per compressed
    leaf per direction unless disabled).  For strategies that exchange
    dense tensors only (full sync, local-only, plain gradient tracking)
    the wire format is the tensors themselves, so the measurement is the
    analytic `bytes_per_round` — and a compressor with wire_transport
    OFF also moves dense masked corrections, so it measures at the dense
    gradient-tracking cost, not at its price: the gap between the two
    columns is exactly what enabling the wire buys."""
    ratio = getattr(strategy, "_ratio", 1.0)
    bits = getattr(strategy, "_bits", 32)
    if ratio >= 1 and bits >= 32:
        return int(strategy.bytes_per_round(x, y, num_local_steps))
    # the engine casts corrections to correction_dtype before the
    # transform, so that — not the model dtype — is what actually moves
    cdt = getattr(strategy, "correction_dtype", None)
    if not getattr(strategy, "wire_transport", False):
        # dense masked corrections actually move: up grad + model, down
        # global grad + model — corrections at the correction dtype
        corr = dense_payload_bytes(
            jax.tree.map(
                lambda u: jax.ShapeDtypeStruct(u.shape, cdt or u.dtype),
                (x, y),
            )
        )
        return 2 * dense_payload_bytes((x, y)) + 2 * corr
    mode = getattr(strategy, "mode", "topk")
    leaves = jax.tree.leaves((x, y))
    payload = header = 0
    for u in leaves:
        spec = LeafSpec.build(u.shape, cdt or u.dtype, ratio, bits, mode)
        payload += probe_leaf_bytes(spec)
        header += HEADER_BYTES
    # up: compressed correction + dense local model; down: compressed
    # global correction + dense averaged model — mirroring bytes_per_round
    total = 2 * dense_payload_bytes((x, y)) + 2 * payload
    if include_headers:
        total += 2 * header
    return int(total)


def wire_header_overhead(x: Pytree, y: Pytree) -> int:
    """Fixed per-round header bytes: HEADER_BYTES per leaf per direction
    — the documented gap between measured and priced bytes."""
    return 2 * HEADER_BYTES * len(jax.tree.leaves((x, y)))
