"""Composable stochastic-gradient noise models for the round engine.

The deterministic engine evaluates each agent's exact gradient oracle;
the stochastic algorithms of the comparison literature (SAGDA, Local
SGDA / SGDA+) instead see noisy draws.  A `NoiseModel` wraps the exact
per-agent gradient function into a *seeded* stochastic oracle, so every
run — and both runtimes — is replayable bit-for-bit.

Noise-fold contract (pinned by tests/test_stochastic_parity.py)
---------------------------------------------------------------
Mirrors `sim.schedule.availability_key`: the noise stream hangs off a
DEDICATED fold of the run key, never off the raw ``PRNGKey(seed)``
chains that client sampling (`PartialParticipation.init_state`) and
correction compression (`_CorrectionCompressor.init_state`) split from.
Equal integer seeds therefore cannot alias across subsystems, and
toggling noise on leaves every compression / participation draw
bitwise unchanged.

  stream  : ``noise_key(seed) = fold_in(PRNGKey(seed), NOISE_STREAM)``
  round   : ``round_key, sub = split(state["noise_key"])``
  agent i : ``agent_key = fold_in(sub, i)``          (index in 0..m-1)
  eval    : ``fold_in(agent_key, 0)``                 anchor exchange
            ``fold_in(agent_key, 1 + k)``             local step k

Per-agent keys are folded from the agent's *global* index, so a sharded
runtime can draw the whole ``[m]`` key array once server-side and hand
each shard its slice — the draws match the fused single-host path
exactly (`AsyncFederatedRunner._round_noise_keys`, same pattern as
`_round_weights`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..core.types import SaddleField

#: Dedicated stream constant for the gradient-noise fold.  Any fixed
#: odd constant distinct from the other stream folds works; sharing the
#: raw seed (or another subsystem's constant — see
#: `sim.schedule.AVAILABILITY_STREAM`) is the aliasing bug this prevents.
NOISE_STREAM = 0x5A_6D_A0  # "sagda-0"


def noise_key(seed: int) -> jax.Array:
    """Root key of the dedicated gradient-noise stream for `seed`."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), NOISE_STREAM)


@dataclasses.dataclass(frozen=True)
class NoiseModel:
    """A seeded stochastic gradient oracle.

    `grad(gfn, key, x, y, data)` returns a noisy `SaddleField` for ONE
    agent; `gfn` is the exact oracle (`grad_xy(loss)`), `key` the
    per-evaluation key from the noise-fold contract above.  Models must
    be unbiased — ``E_key[grad(...)] == gfn(x, y, data)`` — which the
    properties suite checks empirically.
    """

    def grad(
        self, gfn: Callable, key: jax.Array, x: Any, y: Any, data: Any
    ) -> SaddleField:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class GaussianNoise(NoiseModel):
    """Additive oracle noise: ``g + sigma * N(0, I)`` per leaf — the
    abstraction the stochastic-minimax analyses assume (bounded-variance
    unbiased oracle).  The x and y components, and every leaf within
    each, draw from disjoint folds of the eval key, so pytree layout
    never correlates draws."""

    sigma: float = 0.1

    def grad(self, gfn, key, x, y, data):
        g = gfn(x, y, data)
        kx, ky = jax.random.split(key)

        def perturb(k, tree):
            leaves, treedef = jax.tree.flatten(tree)
            noisy = [
                u
                + jnp.asarray(self.sigma, u.dtype)
                * jax.random.normal(
                    jax.random.fold_in(k, i), u.shape, u.dtype
                )
                for i, u in enumerate(leaves)
            ]
            return jax.tree.unflatten(treedef, noisy)

        return SaddleField(gx=perturb(kx, g.gx), gy=perturb(ky, g.gy))


@dataclasses.dataclass(frozen=True)
class MinibatchNoise(NoiseModel):
    """Subsampling noise: evaluate the exact oracle on a minibatch of
    ``round(fraction * n)`` samples drawn WITH replacement along axis 0
    of every data leaf (with-replacement keeps the estimator unbiased
    for any loss that is a mean over samples).  Requires per-sample
    agent data — problems that precompute sufficient statistics (the
    quadratic game's ``G = A^T A``) have no sample axis left to draw
    from; use `GaussianNoise` there."""

    fraction: float = 0.5

    def grad(self, gfn, key, x, y, data):
        n = jax.tree.leaves(data)[0].shape[0]
        b = max(1, int(round(self.fraction * n)))
        idx = jax.random.randint(key, (b,), 0, n)
        sub = jax.tree.map(lambda u: jnp.take(u, idx, axis=0), data)
        return gfn(x, y, sub)


def resolve_noise(
    spec: Any = None, sigma: float | None = None, fraction: float | None = None
) -> NoiseModel | None:
    """Map a noise spec to a `NoiseModel` (or None = deterministic).

    Accepts a `NoiseModel` instance (pass-through), ``None``/"none"
    (deterministic — unless a scale knob is set, which implies the
    matching model: CLI users can say just ``--noise-sigma 0.1``),
    "gaussian" or "minibatch".
    """
    if isinstance(spec, NoiseModel):
        return spec
    if spec in (None, "", "none"):
        if sigma:
            return GaussianNoise(sigma=float(sigma))
        if fraction:
            return MinibatchNoise(fraction=float(fraction))
        return None
    if spec == "gaussian":
        return GaussianNoise(
            sigma=float(sigma) if sigma is not None else 0.1
        )
    if spec == "minibatch":
        return MinibatchNoise(
            fraction=float(fraction) if fraction is not None else 0.5
        )
    raise ValueError(
        f"unknown noise model {spec!r} (none | gaussian | minibatch)"
    )
