"""Pluggable communication strategies for the unified round engine.

The paper's FedGDA-GT (Algorithm 2) is one point in a family of federated
descent-ascent rounds that differ only along one axis: WHAT the agents
communicate each round and HOW local drift is corrected (cf. Sharma et al.
2022; Yang et al., SAGDA, 2022).  A `CommStrategy` captures that axis as
data; `repro.core.engine.make_round` consumes it and emits a round
function.  The engine reads only the hook protocol below, so strategies
and engine stay import-decoupled (strategies -> core.types plus the
kernels package for the fused compress-correction path).

Protocol consumed by the engine (all trace-time unless noted):
  sync_every_step    aggregate after EVERY local step (centralized GDA)
  use_correction     add a gradient-tracking correction to local steps
  exact_correction   correction cancels exactly at the anchor point, so
                     the fused-k0 trick applies (saves one grad eval)
  correction_dtype   optional reduced storage dtype for the correction
  stateful           round carries persistent cross-round state
  init_state(x,y,m)  build that state (RNG keys, error-feedback buffers)
  noise              optional `fed.noise.NoiseModel`: the local/anchor
                     gradient oracles become seeded stochastic draws;
                     None is the deterministic regime and elides every
                     noise primitive at trace time (bitwise legacy
                     rounds)
  sample_weights(state, m) -> (weights | None, state)   [traced]
  sample_noise_keys(state, m) -> (keys | None, state)   [traced]
                     one [m]-stacked per-agent key array per round from
                     the DEDICATED noise stream (`fed.noise`), folded by
                     global agent index so a sharded runtime can draw
                     once server-side and slice (never aliases the
                     sampling / compression "key" chains)
  transform_correction(cx, cy, state) -> (cx, cy, state) [traced]
                     cx/cy may come back as `transport.PackedTree` wire
                     payloads (objects with a `.decode()` hook) instead
                     of dense trees; the engine decodes before use
  rebase_state(state, active, prev_active) -> state  [traced]
                     re-anchor membership-dependent state for an elastic
                     round's active set (`repro.sim`): compressors zero
                     the error-feedback rows of agents that did not
                     participate last round, so a rejoining agent never
                     re-injects residuals of corrections it never
                     applied
  bytes_per_round(x, y, K)  analytic star-topology payload per agent
                     (`transport.measured_bytes_per_round` is the
                     empirical counterpart probing packed buffers)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engine import agent_where, fixed_size_mask, renormalized_weights
from ..core.types import Pytree
from ..kernels.compress_correction import compress_leaf
from .noise import resolve_noise, noise_key as _noise_stream_key
from .transport import (
    LeafSpec,
    PackedTree,
    dense_payload_bytes as _payload_bytes,
    encode_leaf,
)

Weights = Optional[jax.Array]
State = dict


def _compressed_payload_bytes(tree: Pytree, ratio: float, bits: int = 32,
                              value_dtype=None) -> int:
    """Bytes for a `ratio`-sparsified, `bits`-bit stochastically
    quantized copy of `tree` (bits >= 32: sparsification only): kept
    values — bit-packed at the power-of-two storage width, padded to
    whole uint32 words per row, when quantizing — plus an integer index
    per kept value when sparsified (uint16 up to 2**16 columns, not a
    hard-coded 4 bytes) and ONE quantization scale per quantization
    GROUP — a last-axis row, exactly how `QuantizedGT` scales the grid.
    The layout arithmetic lives in `transport.LeafSpec` — the same
    object that shapes the packed encoder's buffers — so priced bytes
    equal packed buffer lengths by construction, and each leaf
    degenerates to the unquantized-sparse or dense encoding whenever
    that is cheaper.  `value_dtype` overrides the leaf dtype — the
    correction exchange is priced at the strategy's `correction_dtype`
    when one is set, since that is what the encoder actually packs."""
    return sum(
        LeafSpec.build(
            u.shape, value_dtype or u.dtype, ratio, bits
        ).wire_bytes()
        for u in jax.tree.leaves(tree)
    )


@dataclasses.dataclass(frozen=True)
class CommStrategy:
    """Base strategy: hook defaults shared by all concrete strategies."""

    # trace-time flags the engine dispatches on (class attributes, not
    # dataclass fields — concrete strategies override as needed)
    name = "base"
    sync_every_step = False
    use_correction = False
    correction_dtype: Any = None
    #: optional `fed.noise.NoiseModel` stochastic gradient oracle; None
    #: is the deterministic regime (bitwise-pinned legacy rounds)
    noise: Any = None
    #: seed of the dedicated noise stream (`fed.noise.noise_key` — a
    #: fold of NOISE_STREAM, never the raw PRNGKey(seed) the sampling /
    #: compression state chains from, so equal seeds cannot alias)
    noise_seed: int = 0

    @property
    def exact_correction(self) -> bool:
        # gradient noise voids the anchor-point cancellation: the
        # tracked gbar and the first local step see different draws
        return self.noise is None

    @property
    def stateful(self) -> bool:
        return self.noise is not None

    def _noise_state(self) -> State:
        """The noise stream's state entry (empty when deterministic) —
        concrete strategies merge this into their own `init_state`."""
        if self.noise is None:
            return {}
        return {"noise_key": _noise_stream_key(self.noise_seed)}

    def init_state(self, x: Pytree, y: Pytree, m: int) -> State:
        return self._noise_state()

    def sample_noise_keys(
        self, state: State, m: int
    ) -> Tuple[Optional[jax.Array], State]:
        """Per-agent noise keys for ONE round: split the dedicated
        stream once, then fold each agent's GLOBAL index into the round
        subkey — a sharded runtime samples this once server-side and
        hands each shard its slice, bit-identical to the fused path
        (`fed.noise` documents the full fold tree).  None when the
        strategy is deterministic."""
        if self.noise is None:
            return None, state
        state = dict(state)
        key, sub = jax.random.split(state["noise_key"])
        state["noise_key"] = key
        keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
            sub, jnp.arange(m)
        )
        return keys, state

    def sample_noise_keys_ids(
        self, state: State, ids
    ) -> Tuple[Optional[jax.Array], State]:
        """`sample_noise_keys` for the sparse O(active) layout: the same
        one-split-per-round advance of the dedicated stream, but folding
        the given GLOBAL agent ids instead of arange(m) — an agent draws
        from the same stream whether its row lives at position `id` of a
        dense [m] stack or anywhere in an active-subset stack."""
        if self.noise is None:
            return None, state
        state = dict(state)
        key, sub = jax.random.split(state["noise_key"])
        state["noise_key"] = key
        keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
            sub, jnp.asarray(ids)
        )
        return keys, state

    @property
    def sharded_state_keys(self) -> Tuple[str, ...]:
        """Top-level state entries whose leaves carry a leading per-agent
        axis.  A sharded runtime (`fed.async_runtime`, `launch.multihost`)
        stores these as per-shard slices living on the agents' devices
        instead of replicating the whole stack; everything else (sampling
        / rounding RNG keys) stays server-side."""
        return ()

    def sample_weights(self, state: State, m: int) -> Tuple[Weights, State]:
        """None means exact uniform averaging over all m agents (the
        bitwise-pinned legacy path); otherwise a length-m weight vector
        with sum(w) == 1 used for both gbar and the final aggregate."""
        return None, state

    def transform_correction(
        self, cx: Pytree, cy: Pytree, state: State
    ) -> Tuple[Pytree, Pytree, State]:
        return cx, cy, state

    def rebase_state(
        self, state: State, active, prev_active=None
    ) -> State:
        """Re-anchor membership-dependent state when an elastic schedule
        changes the active set (`repro.sim.ElasticAggregator` calls this
        each non-full round).  The base strategies carry no per-agent
        state that can go stale — corrections are re-formed from the
        current server iterate every round — so the default is a no-op."""
        del active, prev_active
        return state

    def realign_state_rows(self, state: State, prev_ids, ids) -> State:
        """`rebase_state` for the sparse O(active) layout, where the
        per-agent state entries (`sharded_state_keys`) carry one row per
        ACTIVE agent instead of one per population member.  Rows are
        re-gathered from last round's id layout into this round's: a
        continuing agent (present in both id lists) keeps its row, every
        other slot restarts at zero — exactly the dense rebase rule
        `keep = active & prev_active`, expressed over id lists.
        `prev_ids` None (first round / fresh start) zeroes everything,
        matching `init_state`'s zero buffers."""
        keys = [k for k in self.sharded_state_keys if k in state]
        if not keys:
            return state
        ids = np.asarray(ids)
        state = dict(state)
        if prev_ids is None or len(np.asarray(prev_ids)) == 0:
            pos = np.full(len(ids), -1, np.int64)
        else:
            prev_ids = np.asarray(prev_ids)
            # position of each current id in the previous (sorted) id
            # layout; -1 = not present last round
            idx = np.clip(
                np.searchsorted(prev_ids, ids), 0, len(prev_ids) - 1
            )
            pos = np.where(prev_ids[idx] == ids, idx, -1)
        pos_j = jnp.asarray(pos)
        keep = jnp.asarray(pos >= 0)

        def regather(t):
            def leaf(u):
                rows = jnp.take(u, jnp.maximum(pos_j, 0), axis=0)
                mask = keep.reshape((-1,) + (1,) * (rows.ndim - 1))
                return jnp.where(mask, rows, jnp.zeros_like(rows))

            return jax.tree.map(leaf, t)

        for k in keys:
            state[k] = regather(state[k])
        return state

    def bytes_per_round(self, x: Pytree, y: Pytree, num_local_steps: int) -> int:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FullSync(CommStrategy):
    """Centralized GDA: agents exchange gradients EVERY local step, so one
    'round' of K local steps costs K model up/downloads (paper Section 3.1,
    the K=1-equivalent baseline)."""

    name = "full_sync"
    sync_every_step = True

    def bytes_per_round(self, x, y, num_local_steps):
        return 2 * _payload_bytes((x, y)) * num_local_steps


@dataclasses.dataclass(frozen=True)
class LocalOnly(CommStrategy):
    """Local SGDA (Deng & Mahdavi 2021): K uncorrected local steps, then
    one model up/download.  Cheap but biased for K >= 2 (Proposition 1)."""

    name = "local_only"

    def bytes_per_round(self, x, y, num_local_steps):
        return 2 * _payload_bytes((x, y))


@dataclasses.dataclass(frozen=True)
class GradientTracking(CommStrategy):
    """FedGDA-GT (Algorithm 2): one gradient exchange per round buys the
    tracking correction c_i = gbar - g_i; linear convergence to the exact
    minimax point (Theorem 1).  `correction_dtype` optionally stores c_i
    reduced (e.g. float8_e4m3fn) to cut the +1-param-copy memory cost."""

    correction_dtype: Any = None
    name = "gradient_tracking"
    use_correction = True

    def bytes_per_round(self, x, y, num_local_steps):
        # up: grad + local model; down: global grad + averaged model
        return 4 * _payload_bytes((x, y))


@dataclasses.dataclass(frozen=True)
class PartialParticipation(GradientTracking):
    """Gradient tracking with client sampling: each round a uniform subset
    of S = max(1, round(participation*m)) agents participates; gbar and
    the aggregate are plain means over the sampled set (unbiased for the
    global mean under uniform sampling without replacement).

    participation >= 1 is the identity configuration: sampling is elided
    entirely and the round is EXACTLY GradientTracking.

    The subset draw itself is owned by `repro.sim.population` — this
    strategy is the degenerate Population (i.i.d. fixed-size sampling,
    no churn memory) expressed as a per-round weight sampler, and
    `sim.FixedSizeSampling` is the same draw expressed as an
    availability process (tests/test_population.py pins the two to the
    historical inline implementation bitwise)."""

    participation: float = 0.5
    seed: int = 0
    name = "partial_participation"

    @property
    def _sampling(self) -> bool:
        return self.participation < 1.0

    @property
    def stateful(self) -> bool:
        return self._sampling or self.noise is not None

    def init_state(self, x, y, m):
        state = self._noise_state()
        if self._sampling:
            # the sampling chain stays the raw PRNGKey(seed) it always
            # was (bitwise-pinned); only the noise stream is a fold
            state["key"] = jax.random.PRNGKey(self.seed)
        return state

    def sample_weights(self, state, m):
        if not self._sampling:
            return None, state
        S = max(1, int(round(self.participation * m)))
        if S >= m:
            return None, state
        state = dict(state)
        key, sub = jax.random.split(state["key"])
        state["key"] = key
        return renormalized_weights(fixed_size_mask(sub, m, S)), state

    def bytes_per_round(self, x, y, num_local_steps):
        # expected per-agent payload: only sampled agents communicate
        return int(round(self.participation * 4 * _payload_bytes((x, y))))


@dataclasses.dataclass(frozen=True)
class _CorrectionCompressor(CommStrategy):
    """Shared machinery for strategies that transform the tracking
    correction leaf-by-leaf — sparsification and/or stochastic
    quantization with error feedback.

    Concrete subclasses (CompressedGT, QuantizedGT) declare the knob
    fields and the `_ratio` / `_bits` hooks; this base owns the state
    layout (per-agent feedback buffers "ex"/"ey" + RNG "key"), the
    per-leaf transform loop, and the dispatch to the fused Pallas
    compress-correction kernel: lane-aligned 2D leaves take the fused
    VMEM pass when `use_kernel` is set, everything else falls back to
    the pure-jnp oracle (`repro.kernels.ref.compress_correction_ref`) —
    both paths are the same math on the same uniform draws, so the
    dispatch moves iterates by at most ~1 ulp.

    Each leaf is laid out as [m * rows, cols] with last-axis rows as the
    selection/quantization groups (`transport.wire_rows_cols`): vectors
    are one group per agent, matrices get per-channel scales and
    per-channel top-k — the same layout `bytes_per_round` prices.

    With `wire_transport` set, `transform_correction` returns
    `transport.PackedTree`s — REAL packed (value, index, scale) wire
    payloads — instead of dense masked trees; the engine scatter-adds
    them back on decode.  Both paths run identical math on identical
    draws, so wire on/off produces bitwise-identical GT iterates."""

    use_kernel: bool = False       # fused Pallas path on aligned 2D leaves
    kernel_interpret: bool = True  # interpret=True is the CPU validation path
    wire_transport: bool = False   # emit packed payloads, not dense trees
    use_correction = True
    # knob defaults, overridden by concrete subclasses' dataclass fields
    mode = "topk"
    error_feedback = True
    seed = 0

    def __post_init__(self):
        if self.mode not in ("topk", "randk"):
            raise ValueError(f"unknown compression mode {self.mode!r}")

    # ------------------------------------------------------- knob hooks
    @property
    def _ratio(self) -> float:
        """Kept fraction of correction entries per leaf (1.0 = dense)."""
        raise NotImplementedError

    @property
    def _bits(self) -> int:
        """Stochastic-quantization bit-width (>= 32 = no quantization)."""
        return 32

    # ------------------------------------------------- derived structure
    @property
    def _sparsifying(self) -> bool:
        return self._ratio < 1.0

    @property
    def _quantizing(self) -> bool:
        return self._bits < 32

    @property
    def _active(self) -> bool:
        return self._sparsifying or self._quantizing

    @property
    def _needs_rng(self) -> bool:
        # rand-k selection scores and/or stochastic-rounding draws
        return self._quantizing or (self._sparsifying and self.mode == "randk")

    @property
    def exact_correction(self) -> bool:
        # any lossy transform (or gradient noise) voids the
        # anchor-point cancellation
        return not self._active and self.noise is None

    @property
    def _compressor_state(self) -> bool:
        return self._active and (self.error_feedback or self._needs_rng)

    @property
    def stateful(self) -> bool:
        return self._compressor_state or self.noise is not None

    @property
    def sharded_state_keys(self) -> Tuple[str, ...]:
        # per-agent error-feedback buffers shard; the RNG key does not
        if self._active and self.error_feedback:
            return ("ex", "ey")
        return ()

    def init_state(self, x, y, m):
        state: State = self._noise_state()
        if not self._compressor_state:
            return state
        if self.error_feedback:
            # buffers live in the correction dtype (the engine casts the
            # correction before transform_correction, so residuals carry
            # that dtype — a mismatch would break the scan carry)
            zeros = lambda p: jax.tree.map(
                lambda u: jnp.zeros(
                    (m,) + u.shape, self.correction_dtype or u.dtype
                ),
                p,
            )
            state["ex"] = zeros(x)
            state["ey"] = zeros(y)
        if self._needs_rng:
            state["key"] = jax.random.PRNGKey(self.seed)
        return state

    def transform_correction(self, cx, cy, state):
        if not self._active:
            return cx, cy, state
        state = dict(state)
        sub = None
        if self._needs_rng:
            key, sub = jax.random.split(state["key"])
            state["key"] = key

        def compress(tree, err, tag):
            leaves, treedef = jax.tree.flatten(tree)
            eleaves = (
                jax.tree.leaves(err) if err is not None else [None] * len(leaves)
            )
            chat_leaves, resid_leaves = [], []
            payloads, specs, shapes = [], [], []
            for i, (c, e) in enumerate(zip(leaves, eleaves)):
                m = c.shape[0]
                spec = LeafSpec.build(
                    c.shape[1:], c.dtype, self._ratio, self._bits, self.mode
                )
                flat = c.reshape(m * spec.rows, spec.cols)
                k, n = spec.k, spec.cols
                leaf_key = (
                    None if sub is None else jax.random.fold_in(sub, 2 * i + tag)
                )
                u_sel = u_rnd = None
                if self.mode == "randk" and k < n:
                    u_sel = jax.random.uniform(
                        jax.random.fold_in(leaf_key, 0), flat.shape
                    )
                if self._quantizing:
                    u_rnd = jax.random.uniform(
                        jax.random.fold_in(leaf_key, 1), flat.shape
                    )
                e_flat = None if e is None else e.reshape(flat.shape)
                if self.wire_transport:
                    payload, resid = encode_leaf(
                        flat, e_flat, u_sel, u_rnd, spec.stacked(m),
                        use_kernel=self.use_kernel,
                        interpret=self.kernel_interpret,
                    )
                    payloads.append(payload)
                    specs.append(spec.stacked(m))
                    shapes.append(c.shape)
                else:
                    chat, resid = compress_leaf(
                        flat,
                        e_flat,
                        u_sel,
                        u_rnd,
                        k=k,
                        bits=self._bits,
                        mode=self.mode,
                        use_kernel=self.use_kernel,
                        interpret=self.kernel_interpret,
                    )
                    chat_leaves.append(chat.reshape(c.shape))
                resid_leaves.append(None if e is None else resid.reshape(c.shape))
            resid = (
                jax.tree.unflatten(treedef, resid_leaves)
                if err is not None
                else None
            )
            if self.wire_transport:
                chat = PackedTree(
                    payloads, specs, treedef, shapes,
                    use_kernel=self.use_kernel,
                    interpret=self.kernel_interpret,
                )
            else:
                chat = jax.tree.unflatten(treedef, chat_leaves)
            return chat, resid

        ex = state.get("ex") if self.error_feedback else None
        ey = state.get("ey") if self.error_feedback else None
        cx, ex = compress(cx, ex, 0)
        cy, ey = compress(cy, ey, 1)
        if self.error_feedback:
            state["ex"], state["ey"] = ex, ey
        return cx, cy, state

    def rebase_state(self, state, active, prev_active=None):
        """Elastic re-anchoring of the error-feedback buffers: keep an
        agent's residual rows only if it participated BOTH last round
        (so the residual describes a correction it actually applied)
        and this round (so it is about to re-inject it).  Departed and
        rejoining agents restart from a zero residual — the compressed
        round they next see is anchored purely at the current server
        iterate.

        NOTE on prev_active=None: HERE it means "fresh start" (keep =
        active alone, matching a first round where every buffer is
        zero).  In `sim.elastic.tracker_exchange` the same None means
        "skip rebasing entirely" — the naive-server ablation — because
        there the hook is simply never called; use
        `ElasticAggregator.round_prev_active` to produce the right
        value rather than forwarding None through."""
        if "ex" not in state:
            return state
        keep = active if prev_active is None else (active & prev_active)
        zero_stale = lambda t: agent_where(
            keep, t, jax.tree.map(jnp.zeros_like, t)
        )
        state = dict(state)
        state["ex"] = zero_stale(state["ex"])
        state["ey"] = zero_stale(state["ey"])
        return state


@dataclasses.dataclass(frozen=True)
class CompressedGT(_CorrectionCompressor):
    """Gradient tracking with top-k / random-k sparsified corrections and
    (optional) error feedback.

    Each round the exact correction c_i = gbar - g_i is sparsified to a
    `compression_ratio` fraction of its entries before driving the local
    steps; what compression drops is accumulated in a per-agent feedback
    buffer e_i and re-injected next round (c_i + e_i is compressed, the
    residual becomes the new e_i) so the bias is compensated over time.
    Exactly k entries are kept per agent row (earliest index wins ties),
    so the kept fraction always matches what bytes_per_round prices.

    compression_ratio >= 1 is the identity configuration: compression is
    elided and the round is EXACTLY GradientTracking.  Ratios < 1 void
    the anchor-point cancellation, so the fused-k0 trick is disabled."""

    compression_ratio: float = 0.1
    mode: str = "topk"  # "topk" | "randk"
    error_feedback: bool = True
    seed: int = 0
    name = "compressed_gt"

    @property
    def _ratio(self) -> float:
        return self.compression_ratio

    def bytes_per_round(self, x, y, num_local_steps):
        # up: sparsified grad + local model; down: sparsified global grad +
        # averaged model (models stay dense; only the tracked-gradient
        # exchange is compressed)
        dense = _payload_bytes((x, y))
        return 2 * dense + 2 * _compressed_payload_bytes(
            (x, y), self.compression_ratio,
            value_dtype=self.correction_dtype,
        )


@dataclasses.dataclass(frozen=True)
class QuantizedGT(_CorrectionCompressor):
    """Gradient tracking with QSGD-style stochastically quantized (and
    optionally sparsified) corrections + error feedback (cf. Alistarh et
    al. 2017; the communication-complexity focus of SAGDA and Sharma et
    al. 2022 in PAPERS.md).

    The kept entries of each correction leaf are mapped to a symmetric
    `bits`-bit grid with a per-agent-row max-abs scale and rounded
    STOCHASTICALLY (floor + Bernoulli(frac)), so the quantizer is
    unbiased: E[Q(c)] = c.  The quantization error joins the
    sparsification residual in the error-feedback buffer.  `ratio` < 1
    additionally keeps only a top-k/rand-k fraction of entries before
    quantizing (compose both axes of compression).

    bits >= 32 AND ratio >= 1 is the identity configuration: the round
    is EXACTLY GradientTracking.  Any lossy setting voids the
    anchor-point cancellation, so the fused-k0 trick is disabled."""

    bits: int = 8
    ratio: float = 1.0
    mode: str = "topk"  # "topk" | "randk" (only used when ratio < 1)
    error_feedback: bool = True
    seed: int = 0
    name = "quantized_gt"

    def __post_init__(self):
        super().__post_init__()
        if self.bits < 2:
            raise ValueError(
                f"quantization needs bits >= 2 (sign + magnitude), got {self.bits}"
            )

    @property
    def _ratio(self) -> float:
        return self.ratio

    @property
    def _bits(self) -> int:
        return self.bits

    def bytes_per_round(self, x, y, num_local_steps):
        # up: quantized sparsified grad + local model; down: quantized
        # sparsified global grad + averaged model (models stay dense;
        # only the tracked-gradient exchange is compressed)
        dense = _payload_bytes((x, y))
        return 2 * dense + 2 * _compressed_payload_bytes(
            (x, y), self.ratio, self.bits,
            value_dtype=self.correction_dtype,
        )


@dataclasses.dataclass(frozen=True)
class SAGDA(GradientTracking):
    """Stochastic sampled averaged GDA (Yang et al. 2022, PAPERS.md):
    the gradient-tracking round driven by a stochastic gradient oracle —
    the anchor exchange AND every local step consume fresh draws from
    the dedicated noise stream, while the tracking correction
    c_i = gbar - g_i keeps the local drift centred on the (noisy) global
    direction.

    ``noise=None`` is the identity configuration: every noise primitive
    is elided at trace time (not zeroed at run time), so the round is
    BITWISE GradientTracking — tests/test_stochastic_parity.py pins it."""

    name = "sagda"


@dataclasses.dataclass(frozen=True)
class LocalSGDAPlus(CommStrategy):
    """Local SGDA+ (Sharma et al. 2022, PAPERS.md): Local SGDA's
    uncorrected K-step round with heavy-ball momentum on the local
    update direction (`optim.momentum.heavy_ball`; velocities are
    per-round, zero-initialized, so the round stays a pure function of
    the broadcast iterate) and a stochastic gradient oracle.

    ``momentum=0, noise=None`` is the identity configuration: the
    momentum carry and every noise primitive are elided at trace time,
    so the round is BITWISE LocalOnly."""

    momentum: float = 0.0
    name = "local_sgda_plus"

    def bytes_per_round(self, x, y, num_local_steps):
        # same cost model as LocalOnly: momentum state never leaves the
        # agent, so one model up/download per round
        return 2 * _payload_bytes((x, y))


# ------------------------------------------------------------------ registry
def _noise_kwargs(kw) -> dict:
    """Shared noise knobs for the stochastic-capable aliases; empty when
    the spec resolves to the deterministic regime, so identity configs
    construct bit-identical strategy dataclasses."""
    n = resolve_noise(
        kw.get("noise"),
        sigma=kw.get("noise_sigma"),
        fraction=kw.get("noise_fraction"),
    )
    if n is None:
        return {}
    return {"noise": n, "noise_seed": kw.get("noise_seed", 0)}


_ALIASES = {
    "gda": lambda kw: FullSync(),
    "sync_gda": lambda kw: FullSync(),
    "full_sync": lambda kw: FullSync(),
    "local_sgda": lambda kw: LocalOnly(),
    "local_only": lambda kw: LocalOnly(),
    "fedgda_gt": lambda kw: GradientTracking(
        correction_dtype=kw.get("correction_dtype"),
        **_noise_kwargs(kw),
    ),
    "gradient_tracking": lambda kw: GradientTracking(
        correction_dtype=kw.get("correction_dtype"),
        **_noise_kwargs(kw),
    ),
    "sagda": lambda kw: SAGDA(
        correction_dtype=kw.get("correction_dtype"),
        **_noise_kwargs(kw),
    ),
    "local_sgda_plus": lambda kw: LocalSGDAPlus(
        momentum=kw.get("momentum", 0.0),
        **_noise_kwargs(kw),
    ),
    "partial_gt": lambda kw: PartialParticipation(
        participation=kw.get("participation", 0.5),
        correction_dtype=kw.get("correction_dtype"),
        seed=kw.get("seed", 0),
        **_noise_kwargs(kw),
    ),
    "partial_participation": lambda kw: PartialParticipation(
        participation=kw.get("participation", 0.5),
        correction_dtype=kw.get("correction_dtype"),
        seed=kw.get("seed", 0),
        **_noise_kwargs(kw),
    ),
    "compressed_gt": lambda kw: CompressedGT(
        compression_ratio=kw.get("compression_ratio", 0.1),
        mode=kw.get("compression_mode", "topk"),
        error_feedback=kw.get("error_feedback", True),
        correction_dtype=kw.get("correction_dtype"),
        seed=kw.get("seed", 0),
        use_kernel=kw.get("use_kernel", False),
        wire_transport=kw.get("wire_transport", False),
        **_noise_kwargs(kw),
    ),
    "quantized_gt": lambda kw: QuantizedGT(
        bits=kw.get("quantization_bits", 8),
        ratio=kw.get("compression_ratio", 1.0),
        mode=kw.get("compression_mode", "topk"),
        error_feedback=kw.get("error_feedback", True),
        correction_dtype=kw.get("correction_dtype"),
        seed=kw.get("seed", 0),
        use_kernel=kw.get("use_kernel", False),
        wire_transport=kw.get("wire_transport", False),
        **_noise_kwargs(kw),
    ),
}


def resolve_strategy(spec, **kwargs) -> CommStrategy:
    """Map an algorithm name (or a ready strategy) to a CommStrategy.

    Accepts the legacy algorithm strings ("gda"/"sync_gda", "local_sgda",
    "fedgda_gt") plus the scenario-opening ones ("partial_gt",
    "compressed_gt", "quantized_gt") and the stochastic family ("sagda",
    "local_sgda_plus").  kwargs supply strategy hyperparameters
    (correction_dtype, participation, compression_ratio,
    quantization_bits, noise / noise_sigma / noise_fraction /
    noise_seed, momentum, ...).  The legacy strings ("gda",
    "local_sgda", "full_sync") stay deterministic oracles and ignore
    the noise knobs — the stochastic regime is opted into via the
    strategies that define it."""
    if isinstance(spec, CommStrategy):
        return spec
    try:
        factory = _ALIASES[spec]
    except (KeyError, TypeError):
        raise ValueError(f"unknown algorithm {spec!r}") from None
    return factory(kwargs)
