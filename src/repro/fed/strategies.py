"""Pluggable communication strategies for the unified round engine.

The paper's FedGDA-GT (Algorithm 2) is one point in a family of federated
descent-ascent rounds that differ only along one axis: WHAT the agents
communicate each round and HOW local drift is corrected (cf. Sharma et al.
2022; Yang et al., SAGDA, 2022).  A `CommStrategy` captures that axis as
data; `repro.core.engine.make_round` consumes it and emits a round
function.  The engine reads only the hook protocol below, so strategies
and engine stay import-decoupled (strategies -> core.types only).

Protocol consumed by the engine (all trace-time unless noted):
  sync_every_step    aggregate after EVERY local step (centralized GDA)
  use_correction     add a gradient-tracking correction to local steps
  exact_correction   correction cancels exactly at the anchor point, so
                     the fused-k0 trick applies (saves one grad eval)
  correction_dtype   optional reduced storage dtype for the correction
  stateful           round carries persistent cross-round state
  init_state(x,y,m)  build that state (RNG keys, error-feedback buffers)
  sample_weights(state, m) -> (weights | None, state)   [traced]
  transform_correction(cx, cy, state) -> (cx, cy, state) [traced]
  bytes_per_round(x, y, K)  analytic star-topology payload per agent
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.types import Pytree

Weights = Optional[jax.Array]
State = dict


def _payload_bytes(tree: Pytree) -> int:
    """Dense payload bytes of one model copy (works on arrays and
    ShapeDtypeStructs alike)."""
    return sum(u.size * u.dtype.itemsize for u in jax.tree.leaves(tree))


def _sparse_payload_bytes(tree: Pytree, ratio: float, index_bytes: int = 4) -> int:
    """Bytes for a `ratio`-sparsified copy of `tree`: kept values plus an
    integer index per kept value, never worse than sending densely."""
    total = 0
    for u in jax.tree.leaves(tree):
        dense = u.size * u.dtype.itemsize
        k = max(1, math.ceil(ratio * u.size))
        total += min(dense, k * (u.dtype.itemsize + index_bytes))
    return total


@dataclasses.dataclass(frozen=True)
class CommStrategy:
    """Base strategy: hook defaults shared by all concrete strategies."""

    # trace-time flags the engine dispatches on (class attributes, not
    # dataclass fields — concrete strategies override as needed)
    name = "base"
    sync_every_step = False
    use_correction = False
    correction_dtype: Any = None

    @property
    def exact_correction(self) -> bool:
        return True

    @property
    def stateful(self) -> bool:
        return False

    def init_state(self, x: Pytree, y: Pytree, m: int) -> State:
        return {}

    def sample_weights(self, state: State, m: int) -> Tuple[Weights, State]:
        """None means exact uniform averaging over all m agents (the
        bitwise-pinned legacy path); otherwise a length-m weight vector
        with sum(w) == 1 used for both gbar and the final aggregate."""
        return None, state

    def transform_correction(
        self, cx: Pytree, cy: Pytree, state: State
    ) -> Tuple[Pytree, Pytree, State]:
        return cx, cy, state

    def bytes_per_round(self, x: Pytree, y: Pytree, num_local_steps: int) -> int:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FullSync(CommStrategy):
    """Centralized GDA: agents exchange gradients EVERY local step, so one
    'round' of K local steps costs K model up/downloads (paper Section 3.1,
    the K=1-equivalent baseline)."""

    name = "full_sync"
    sync_every_step = True

    def bytes_per_round(self, x, y, num_local_steps):
        return 2 * _payload_bytes((x, y)) * num_local_steps


@dataclasses.dataclass(frozen=True)
class LocalOnly(CommStrategy):
    """Local SGDA (Deng & Mahdavi 2021): K uncorrected local steps, then
    one model up/download.  Cheap but biased for K >= 2 (Proposition 1)."""

    name = "local_only"

    def bytes_per_round(self, x, y, num_local_steps):
        return 2 * _payload_bytes((x, y))


@dataclasses.dataclass(frozen=True)
class GradientTracking(CommStrategy):
    """FedGDA-GT (Algorithm 2): one gradient exchange per round buys the
    tracking correction c_i = gbar - g_i; linear convergence to the exact
    minimax point (Theorem 1).  `correction_dtype` optionally stores c_i
    reduced (e.g. float8_e4m3fn) to cut the +1-param-copy memory cost."""

    correction_dtype: Any = None
    name = "gradient_tracking"
    use_correction = True

    def bytes_per_round(self, x, y, num_local_steps):
        # up: grad + local model; down: global grad + averaged model
        return 4 * _payload_bytes((x, y))


@dataclasses.dataclass(frozen=True)
class PartialParticipation(GradientTracking):
    """Gradient tracking with client sampling: each round a uniform subset
    of S = max(1, round(participation*m)) agents participates; gbar and
    the aggregate are plain means over the sampled set (unbiased for the
    global mean under uniform sampling without replacement).

    participation >= 1 is the identity configuration: sampling is elided
    entirely and the round is EXACTLY GradientTracking."""

    participation: float = 0.5
    seed: int = 0
    name = "partial_participation"

    @property
    def stateful(self) -> bool:
        return self.participation < 1.0

    def init_state(self, x, y, m):
        if not self.stateful:
            return {}
        return {"key": jax.random.PRNGKey(self.seed)}

    def sample_weights(self, state, m):
        if not self.stateful:
            return None, state
        S = max(1, int(round(self.participation * m)))
        if S >= m:
            return None, state
        state = dict(state)
        key, sub = jax.random.split(state["key"])
        state["key"] = key
        sel = jax.random.permutation(sub, m)[:S]
        w = jnp.zeros((m,)).at[sel].set(1.0 / S)
        return w, state

    def bytes_per_round(self, x, y, num_local_steps):
        # expected per-agent payload: only sampled agents communicate
        return int(round(self.participation * 4 * _payload_bytes((x, y))))


@dataclasses.dataclass(frozen=True)
class CompressedGT(CommStrategy):
    """Gradient tracking with top-k / random-k sparsified corrections and
    (optional) error feedback.

    Each round the exact correction c_i = gbar - g_i is sparsified to a
    `compression_ratio` fraction of its entries before driving the local
    steps; what compression drops is accumulated in a per-agent feedback
    buffer e_i and re-injected next round (c_i + e_i is compressed, the
    residual becomes the new e_i) so the bias is compensated over time.

    compression_ratio >= 1 is the identity configuration: compression is
    elided and the round is EXACTLY GradientTracking.  Ratios < 1 void
    the anchor-point cancellation, so the fused-k0 trick is disabled."""

    compression_ratio: float = 0.1
    mode: str = "topk"  # "topk" | "randk"
    error_feedback: bool = True
    seed: int = 0
    name = "compressed_gt"
    use_correction = True

    def __post_init__(self):
        if self.mode not in ("topk", "randk"):
            raise ValueError(f"unknown compression mode {self.mode!r}")

    @property
    def exact_correction(self) -> bool:
        return self.compression_ratio >= 1.0

    @property
    def stateful(self) -> bool:
        return self.compression_ratio < 1.0 and (
            self.error_feedback or self.mode == "randk"
        )

    def init_state(self, x, y, m):
        if not self.stateful:
            return {}
        state: State = {}
        if self.error_feedback:
            # buffers live in the correction dtype (the engine casts the
            # correction before transform_correction, so residuals carry
            # that dtype — a mismatch would break the scan carry)
            zeros = lambda p: jax.tree.map(
                lambda u: jnp.zeros(
                    (m,) + u.shape, self.correction_dtype or u.dtype
                ),
                p,
            )
            state["ex"] = zeros(x)
            state["ey"] = zeros(y)
        if self.mode == "randk":
            state["key"] = jax.random.PRNGKey(self.seed)
        return state

    def transform_correction(self, cx, cy, state):
        if self.compression_ratio >= 1.0:
            return cx, cy, state
        state = dict(state)
        sub = None
        if self.mode == "randk":
            key, sub = jax.random.split(state["key"])
            state["key"] = key

        def compress(tree, err, tag):
            leaves, treedef = jax.tree.flatten(tree)
            eleaves = (
                jax.tree.leaves(err) if err is not None else [None] * len(leaves)
            )
            chat_leaves, resid_leaves = [], []
            for i, (c, e) in enumerate(zip(leaves, eleaves)):
                ceff = c if e is None else c + e.astype(c.dtype)
                flat = ceff.reshape(ceff.shape[0], -1)
                n = flat.shape[1]
                k = max(1, math.ceil(self.compression_ratio * n))
                if k >= n:
                    mask = jnp.ones_like(flat)
                elif self.mode == "topk":
                    # scatter exactly k ones (ties broken by index) so the
                    # kept fraction always matches what bytes_per_round
                    # prices — a >=threshold mask would keep every tied
                    # entry, degenerating to dense when the k-th magnitude
                    # is 0
                    idx = jax.lax.top_k(jnp.abs(flat), k)[1]
                    rows = jnp.arange(flat.shape[0])[:, None]
                    mask = jnp.zeros_like(flat).at[rows, idx].set(1.0)
                else:
                    mask = _randk_mask(flat, k, jax.random.fold_in(sub, 2 * i + tag))
                chat = (flat * mask).reshape(ceff.shape)
                chat_leaves.append(chat)
                resid_leaves.append(None if e is None else ceff - chat)
            resid = (
                jax.tree.unflatten(treedef, resid_leaves)
                if err is not None
                else None
            )
            return jax.tree.unflatten(treedef, chat_leaves), resid

        ex = state.get("ex") if self.error_feedback else None
        ey = state.get("ey") if self.error_feedback else None
        cx, ex = compress(cx, ex, 0)
        cy, ey = compress(cy, ey, 1)
        if self.error_feedback:
            state["ex"], state["ey"] = ex, ey
        return cx, cy, state

    def bytes_per_round(self, x, y, num_local_steps):
        # up: sparsified grad + local model; down: sparsified global grad +
        # averaged model (models stay dense; only the tracked-gradient
        # exchange is compressed)
        dense = _payload_bytes((x, y))
        return 2 * dense + 2 * _sparse_payload_bytes((x, y), self.compression_ratio)


def _randk_mask(flat: jax.Array, k: int, key: jax.Array) -> jax.Array:
    m, n = flat.shape
    keys = jax.random.split(key, m)

    def one(kk):
        idx = jax.random.permutation(kk, n)[:k]
        return jnp.zeros((n,), flat.dtype).at[idx].set(1.0)

    return jax.vmap(one)(keys)


# ------------------------------------------------------------------ registry
_ALIASES = {
    "gda": lambda kw: FullSync(),
    "sync_gda": lambda kw: FullSync(),
    "full_sync": lambda kw: FullSync(),
    "local_sgda": lambda kw: LocalOnly(),
    "local_only": lambda kw: LocalOnly(),
    "fedgda_gt": lambda kw: GradientTracking(
        correction_dtype=kw.get("correction_dtype")
    ),
    "gradient_tracking": lambda kw: GradientTracking(
        correction_dtype=kw.get("correction_dtype")
    ),
    "partial_gt": lambda kw: PartialParticipation(
        participation=kw.get("participation", 0.5),
        correction_dtype=kw.get("correction_dtype"),
        seed=kw.get("seed", 0),
    ),
    "partial_participation": lambda kw: PartialParticipation(
        participation=kw.get("participation", 0.5),
        correction_dtype=kw.get("correction_dtype"),
        seed=kw.get("seed", 0),
    ),
    "compressed_gt": lambda kw: CompressedGT(
        compression_ratio=kw.get("compression_ratio", 0.1),
        mode=kw.get("compression_mode", "topk"),
        error_feedback=kw.get("error_feedback", True),
        correction_dtype=kw.get("correction_dtype"),
        seed=kw.get("seed", 0),
    ),
}


def resolve_strategy(spec, **kwargs) -> CommStrategy:
    """Map an algorithm name (or a ready strategy) to a CommStrategy.

    Accepts the legacy algorithm strings ("gda"/"sync_gda", "local_sgda",
    "fedgda_gt") plus the scenario-opening ones ("partial_gt",
    "compressed_gt").  kwargs supply strategy hyperparameters
    (correction_dtype, participation, compression_ratio, ...)."""
    if isinstance(spec, CommStrategy):
        return spec
    try:
        factory = _ALIASES[spec]
    except (KeyError, TypeError):
        raise ValueError(f"unknown algorithm {spec!r}") from None
    return factory(kwargs)
