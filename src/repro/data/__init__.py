from .synthetic import (
    dirichlet_partition_weights,
    federated_token_batches,
    heterogeneity_index,
    partition_among_agents,
)
from .tokens import synthetic_lm_batch

__all__ = [
    "dirichlet_partition_weights",
    "federated_token_batches",
    "heterogeneity_index",
    "partition_among_agents",
    "synthetic_lm_batch",
]
