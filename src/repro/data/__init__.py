from .synthetic import federated_token_batches, partition_among_agents
from .tokens import synthetic_lm_batch

__all__ = [
    "federated_token_batches",
    "partition_among_agents",
    "synthetic_lm_batch",
]
