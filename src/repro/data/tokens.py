"""Synthetic token streams for LM training/serving paths.

Tokens are drawn from per-agent Zipfian distributions whose supports are
shifted per agent — this gives *controllable heterogeneity* analogous to the
paper's alpha knob in Section 5.2: `skew` rotates each agent's vocabulary so
local token marginals differ across agents.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def synthetic_lm_batch(
    key: jax.Array,
    batch: int,
    seq_len: int,
    vocab_size: int,
    skew: int = 0,
    zipf_a: float = 1.2,
) -> dict:
    """Returns {tokens: [B,S] int32, labels: [B,S] int32} (labels = next token)."""
    ranks = jnp.arange(1, vocab_size + 1, dtype=jnp.float32)
    logits = -zipf_a * jnp.log(ranks)
    toks = jax.random.categorical(key, logits, shape=(batch, seq_len + 1))
    toks = (toks + skew) % vocab_size
    return {
        "tokens": toks[:, :-1].astype(jnp.int32),
        "labels": toks[:, 1:].astype(jnp.int32),
    }
