"""Federated data pipeline: per-agent heterogeneous synthetic batches."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .tokens import synthetic_lm_batch


def federated_token_batches(
    key: jax.Array,
    num_agents: int,
    per_agent_batch: int,
    seq_len: int,
    vocab_size: int,
    heterogeneity: int = 0,
) -> dict:
    """Agent-stacked LM batches: leaves shaped [m, B_local, S].

    heterogeneity shifts each agent's token marginal by
    `agent_index * heterogeneity` vocabulary slots (0 = iid agents).
    """
    keys = jax.random.split(key, num_agents)
    batches = [
        synthetic_lm_batch(
            keys[i], per_agent_batch, seq_len, vocab_size, skew=i * heterogeneity
        )
        for i in range(num_agents)
    ]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)


def partition_among_agents(data: dict, num_agents: int) -> dict:
    """Split leading batch axis of every leaf into [m, B/m, ...]."""

    def split(u):
        b = u.shape[0]
        assert b % num_agents == 0, (b, num_agents)
        return u.reshape((num_agents, b // num_agents) + u.shape[1:])

    return jax.tree.map(split, data)
