"""Federated data pipeline: per-agent heterogeneous synthetic batches.

Two heterogeneity dials coexist here:

  * the legacy integer `heterogeneity` knob of `federated_token_batches`
    — a deterministic per-agent vocabulary shift;
  * Dirichlet mixture weights (`dirichlet_partition_weights`) — the
    standard federated non-iid model (Hsu et al. 2019): each agent draws
    its component mixture from Dirichlet(alpha), so alpha -> 0 gives
    near-one-hot (maximally heterogeneous) agents and alpha -> inf the
    iid limit.  `heterogeneity_index` scores a weight matrix on [0, 1)
    so tests and benchmarks can assert monotonicity in alpha instead of
    eyeballing it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .tokens import synthetic_lm_batch


def federated_token_batches(
    key: jax.Array,
    num_agents: int,
    per_agent_batch: int,
    seq_len: int,
    vocab_size: int,
    heterogeneity: int = 0,
) -> dict:
    """Agent-stacked LM batches: leaves shaped [m, B_local, S].

    heterogeneity shifts each agent's token marginal by
    `agent_index * heterogeneity` vocabulary slots (0 = iid agents).
    """
    keys = jax.random.split(key, num_agents)
    batches = [
        synthetic_lm_batch(
            keys[i], per_agent_batch, seq_len, vocab_size, skew=i * heterogeneity
        )
        for i in range(num_agents)
    ]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)


def dirichlet_partition_weights(
    key: jax.Array,
    num_agents: int,
    num_components: int,
    alpha: float,
    dtype=jnp.float64,
) -> jax.Array:
    """Per-agent mixture weights over `num_components` latent data
    components: rows of a [m, C] matrix, each an independent draw from
    Dirichlet(alpha * ones(C)).  Every row sums to 1 for any alpha > 0.

    alpha small  -> rows concentrate on single components (non-iid);
    alpha large  -> rows approach the uniform 1/C mixture (iid)."""
    if alpha <= 0:
        raise ValueError(f"Dirichlet concentration must be > 0, got {alpha}")
    conc = jnp.full((num_components,), alpha, dtype=dtype)
    return jax.random.dirichlet(key, conc, shape=(num_agents,), dtype=dtype)


def heterogeneity_index(weights: jax.Array) -> jax.Array:
    """Mean total-variation distance between each agent's mixture and
    the population mixture (the column mean): 0 for identical agents,
    approaching (C-1)/C as rows become one-hot on distinct components.
    Scale-free summary used by tests (monotone in 1/alpha) and the
    generalization benchmark's table rows."""
    weights = jnp.asarray(weights)
    mix = jnp.mean(weights, axis=0)
    return 0.5 * jnp.mean(jnp.sum(jnp.abs(weights - mix[None, :]), axis=1))


def partition_among_agents(data: dict, num_agents: int) -> dict:
    """Split leading batch axis of every leaf into [m, B/m, ...]."""

    def split(u):
        b = u.shape[0]
        assert b % num_agents == 0, (b, num_agents)
        return u.reshape((num_agents, b // num_agents) + u.shape[1:])

    return jax.tree.map(split, data)
