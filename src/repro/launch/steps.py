"""SPMD step builders: federated minimax train_step + prefill/decode serve_step.

train_step = ONE federated communication round lowered as a single jitted
SPMD program on the production mesh, built by the phase-split round
engine (`repro.core.engine.make_round` — the fused composition of
broadcast / exchange_corrections / local_steps / aggregate) for any
`CommStrategy` — FedGDA-GT by default; baselines (local_sgda, sync_gda)
and the scenario strategies (partial_gt, compressed_gt, quantized_gt)
share the same signature so the dry-run can compare their collective
schedules directly.  Stateful strategies thread their state as an extra
replicated step input.

The async runtime executes the same phases as separately dispatched
per-shard programs plus a server-side packed-payload gather;
`build_gather_decode_train_step` lowers that gather on the production
mesh (payload buffers sharded over the fed axes, decode replicated) so
the dry-run can census its all-gather bytes against
`measured_bytes_per_round` (`--runtime async`, tag `__async`).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..core.engine import make_round
from ..fed.strategies import CommStrategy, resolve_strategy
from ..models import batch_struct, init_caches, init_params
from ..models.transformer import embed_inputs, forward, logits_from_hidden
from ..problems.adversarial import delta_projection, make_adversarial_loss
from .mesh import fed_axes, num_agents
from .shardings import (
    cache_shardings,
    make_agent_constraint,
    param_shardings,
    replicated,
    serve_batch_sharding,
    train_batch_shardings,
)

Pytree = Any

_CORRECTION_DTYPES = {"float8_e4m3fn": jnp.float8_e4m3fn, "bfloat16": jnp.bfloat16}


def abstract_params(cfg: ModelConfig, dtype) -> Pytree:
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg, dtype)
    )


def abstract_caches(cfg: ModelConfig, batch: int, capacity: int, dtype) -> Pytree:
    return jax.eval_shape(lambda: init_caches(cfg, batch, capacity, dtype))


def delta_struct(cfg: ModelConfig, dtype) -> Dict:
    return {"delta": jax.ShapeDtypeStruct((cfg.d_model,), dtype)}


# --------------------------------------------------------------------------
# training (one federated communication round)
# --------------------------------------------------------------------------
def train_input_specs(
    cfg: ModelConfig, shape: ShapeConfig, mesh, dtype=jnp.bfloat16
) -> Dict:
    """ShapeDtypeStructs for (x_global, y_global, agent_batches)."""
    m = num_agents(mesh, cfg.fed_mode)
    assert shape.global_batch % m == 0, (shape.global_batch, m)
    b_local = shape.global_batch // m
    one = batch_struct(cfg, b_local, shape.seq_len, dtype)
    agent_batches = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((m,) + s.shape, s.dtype), one
    )
    return {
        "x": abstract_params(cfg, dtype),
        "y": delta_struct(cfg, dtype),
        "batch": agent_batches,
    }


def _resolve_cfg_strategy(cfg: ModelConfig, algorithm) -> CommStrategy:
    """One owner for the cfg-knob -> strategy resolution, shared by the
    fused train step and the async gather-census step."""
    kw = dict(
        correction_dtype=_CORRECTION_DTYPES.get(cfg.correction_dtype),
        participation=cfg.participation,
        compression_ratio=cfg.compression_ratio,
        quantization_bits=cfg.quantization_bits,
        wire_transport=cfg.wire_transport,
        momentum=cfg.momentum,
    )
    # gate on the cfg knob, not on sigma/fraction: resolve_noise treats a
    # bare nonzero sigma as gaussian, and the defaults (0.1/0.5) would
    # otherwise silently make every config stochastic
    if cfg.noise != "none":
        kw.update(
            noise=cfg.noise,
            noise_sigma=cfg.noise_sigma,
            noise_fraction=cfg.noise_fraction,
            noise_seed=cfg.noise_seed,
        )
    return resolve_strategy(algorithm, **kw)


def build_train_step(
    cfg: ModelConfig,
    mesh,
    *,
    algorithm="fedgda_gt",  # legacy name or a CommStrategy instance
    num_local_steps: int = 4,
    eta: float = 1e-3,
    delta_radius: float = 1.0,
    dtype=jnp.bfloat16,
    remat: bool = True,
    sequence_parallel: bool = True,
    sharding_variant: str = "baseline",
    h_shard: Optional[str] = None,  # overrides sequence_parallel: seq|batch|none
    q_block: Optional[int] = None,  # overrides cfg.q_block
) -> Tuple[Callable, Callable]:
    """Returns (jitted_step, input_specs_fn)."""
    import dataclasses as _dc

    if q_block:
        cfg = _dc.replace(cfg, q_block=q_block)
    if h_shard is None:
        h_shard = "seq" if sequence_parallel else "none"
    inner = "data" if cfg.fed_mode == "B" else None
    h_sh = None
    if h_shard == "seq":
        h_sh = NamedSharding(mesh, P(inner, "model", None))
    elif h_shard == "batch":
        h_sh = NamedSharding(mesh, P("model", None, None))
    loss = make_adversarial_loss(cfg, remat=remat, h_sharding=h_sh)
    proj_y = delta_projection(delta_radius)
    constrain = make_agent_constraint(cfg, mesh, None, sharding_variant)
    strategy = _resolve_cfg_strategy(cfg, algorithm)
    stateful = strategy.stateful
    rnd = make_round(
        loss,
        strategy,
        num_local_steps,
        eta,
        proj_y=proj_y,
        constrain_agents=constrain,
        explicit_state=stateful,
    )

    x_sh = param_shardings(abstract_params(cfg, dtype), cfg, mesh, sharding_variant)
    y_sh = jax.tree.map(lambda _: replicated(mesh), delta_struct(cfg, dtype))
    bsh = train_batch_shardings(cfg, mesh)
    batch_sh_fn = lambda tree: jax.tree.map(lambda s: bsh(len(s.shape)), tree)

    def specs_fn(shape: ShapeConfig, dt=dtype):
        sp = train_input_specs(cfg, shape, mesh, dt)
        if stateful:
            # strategy state (sampling RNG / error-feedback buffers) rides
            # along as a fourth, replicated step input
            m = num_agents(mesh, cfg.fed_mode)
            sp["state"] = jax.eval_shape(
                lambda xx, yy: strategy.init_state(xx, yy, m), sp["x"], sp["y"]
            )
        return sp

    def jitted(shape: ShapeConfig):
        sp = specs_fn(shape)
        if stateful:
            st_sh = jax.tree.map(lambda _: replicated(mesh), sp["state"])
            return jax.jit(
                rnd,
                in_shardings=(x_sh, y_sh, batch_sh_fn(sp["batch"]), st_sh),
                out_shardings=(x_sh, y_sh, st_sh),
                donate_argnums=(0,),
            )
        return jax.jit(
            rnd,
            in_shardings=(x_sh, y_sh, batch_sh_fn(sp["batch"])),
            out_shardings=(x_sh, y_sh),
            donate_argnums=(0,),
        )

    return jitted, specs_fn


def build_elastic_train_step(
    cfg: ModelConfig,
    mesh,
    *,
    algorithm="fedgda_gt",
    num_local_steps: int = 4,
    eta: float = 1e-3,
    delta_radius: float = 1.0,
    dtype=jnp.bfloat16,
    remat: bool = True,
    sequence_parallel: bool = True,
    sharding_variant: str = "baseline",
    h_shard: Optional[str] = None,
    q_block: Optional[int] = None,
) -> Tuple[Callable, Callable]:
    """The membership-aware elastic round (`repro.sim.make_elastic_round`)
    as one SPMD program: `build_train_step`'s signature plus the
    schedule inputs — tracker table (per-agent anchor gradients, agent
    axis over the fed axes like the batch), weights / budgets / active
    (tiny [m] vectors, replicated).  This is what a `--population`
    dry-run lowers: the collective schedule of a round that must gate
    local steps and re-normalize the aggregate per membership."""
    import dataclasses as _dc

    from ..sim.elastic import make_elastic_round

    if q_block:
        cfg = _dc.replace(cfg, q_block=q_block)
    if h_shard is None:
        h_shard = "seq" if sequence_parallel else "none"
    inner = "data" if cfg.fed_mode == "B" else None
    h_sh = None
    if h_shard == "seq":
        h_sh = NamedSharding(mesh, P(inner, "model", None))
    elif h_shard == "batch":
        h_sh = NamedSharding(mesh, P("model", None, None))
    loss = make_adversarial_loss(cfg, remat=remat, h_sharding=h_sh)
    proj_y = delta_projection(delta_radius)
    constrain = make_agent_constraint(cfg, mesh, None, sharding_variant)
    strategy = _resolve_cfg_strategy(cfg, algorithm)
    rnd = make_elastic_round(
        loss,
        strategy,
        num_local_steps,
        eta,
        proj_y=proj_y,
        constrain_agents=constrain,
    )

    m = num_agents(mesh, cfg.fed_mode)
    fa = fed_axes(mesh, cfg.fed_mode)
    x_sh = param_shardings(abstract_params(cfg, dtype), cfg, mesh, sharding_variant)
    y_sh = jax.tree.map(lambda _: replicated(mesh), delta_struct(cfg, dtype))
    bsh = train_batch_shardings(cfg, mesh)
    batch_sh_fn = lambda tree: jax.tree.map(lambda s: bsh(len(s.shape)), tree)
    agent_sh = lambda tree: jax.tree.map(
        lambda s: NamedSharding(
            mesh, P(fa if fa else None, *([None] * (len(s.shape) - 1)))
        ),
        tree,
    )

    def specs_fn(shape: ShapeConfig, dt=dtype):
        sp = train_input_specs(cfg, shape, mesh, dt)
        sp["state"] = jax.eval_shape(
            lambda xx, yy: strategy.init_state(xx, yy, m), sp["x"], sp["y"]
        )
        agent_stack = lambda t: jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((m,) + s.shape, s.dtype), t
        )
        sp["tracker"] = (
            {"gx": agent_stack(sp["x"]), "gy": agent_stack(sp["y"])}
            if getattr(strategy, "use_correction", False)
            else {}
        )
        sp["weights"] = jax.ShapeDtypeStruct((m,), jnp.float32)
        sp["budgets"] = jax.ShapeDtypeStruct((m,), jnp.int32)
        sp["active"] = jax.ShapeDtypeStruct((m,), jnp.bool_)
        sp["prev_active"] = jax.ShapeDtypeStruct((m,), jnp.bool_)
        return sp

    def jitted(shape: ShapeConfig):
        sp = specs_fn(shape)
        st_sh = jax.tree.map(lambda _: replicated(mesh), sp["state"])
        rep = replicated(mesh)
        return jax.jit(
            rnd,
            in_shardings=(
                x_sh,
                y_sh,
                batch_sh_fn(sp["batch"]),
                st_sh,
                agent_sh(sp["tracker"]),
                rep,
                rep,
                rep,
                rep,
            ),
            out_shardings=(x_sh, y_sh, st_sh, agent_sh(sp["tracker"])),
            donate_argnums=(0,),
        )

    return jitted, specs_fn


def pod_aggregation_plan(cfg: ModelConfig, mesh, num_pods: int) -> Dict:
    """The two-level aggregation tree's placement on a launch mesh:
    agents (the fed-axes device product) are split into `num_pods`
    contiguous device groups (`mesh.pod_device_groups`), each owning
    the level-one partial weighted sum of its agents; only the per-pod
    partials cross group boundaries.  Returns the plan the dry-run
    records (`--pods`):

      num_pods / agents_per_pod / devices_per_pod — the tree shape;
      pod_payload_bytes — one pod's per-round wire price on the
      pod <-> server edge (dense packed framing, priced == measured —
      `fed.pods.pod_payload_bytes`);
      groups — per-pod device id lists.
    """
    from ..fed.pods import pod_payload_bytes
    from .mesh import pod_device_groups

    m = num_agents(mesh, cfg.fed_mode)
    groups = pod_device_groups(mesh, cfg.fed_mode, num_pods)
    x = abstract_params(cfg, jnp.bfloat16)
    y = delta_struct(cfg, jnp.bfloat16)
    return {
        "num_pods": num_pods,
        "agents_per_pod": m // num_pods,
        "devices_per_pod": len(groups[0]),
        "pod_payload_bytes": pod_payload_bytes(x, y, measured=False),
        "groups": [[d.id for d in g] for g in groups],
    }


def build_gather_decode_train_step(
    cfg: ModelConfig,
    mesh,
    *,
    algorithm="fedgda_gt",
    dtype=jnp.bfloat16,
):
    """The async runtime's server-side exchange as one SPMD program on
    the production mesh: all-gather the per-agent packed correction
    payloads over the fed axes and decode them replicated.

    Returns (jitted, arg_structs, expected_gather_bytes) — compile and
    census the collectives; their all-gather bytes must track
    `transport.measured_bytes_per_round`'s payload share (the dry-run
    stores both, benchmarks/comm_collectives.py --check-async gates)."""
    from .multihost import build_gather_decode_step

    strategy = _resolve_cfg_strategy(cfg, algorithm)
    x = abstract_params(cfg, dtype)
    y = delta_struct(cfg, dtype)
    return build_gather_decode_step(
        strategy, x, y, mesh, fed_axes(mesh, cfg.fed_mode)
    )


# --------------------------------------------------------------------------
# serving (prefill builds the KV cache; decode extends it one token)
# --------------------------------------------------------------------------
def build_prefill_step(
    cfg: ModelConfig, mesh, *, dtype=jnp.bfloat16, sequence_parallel: bool = True,
    sharding_variant: str = "baseline",
):
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    h_sh = (
        NamedSharding(mesh, P(dp if dp else None, "model", None))
        if sequence_parallel
        else None
    )

    def prefill(params, batch, caches):
        h = embed_inputs(params, cfg, batch)
        h, caches, _ = forward(params, cfg, h, caches=caches, h_sharding=h_sh)
        logits = logits_from_hidden(params, cfg, h[:, -1:])
        return logits, caches

    def encoder_fwd(params, batch):
        h = embed_inputs(params, cfg, batch)
        h, _, _ = forward(params, cfg, h, h_sharding=h_sh)
        return logits_from_hidden(params, cfg, h)

    def specs_fn(shape: ShapeConfig):
        sp = {
            "params": abstract_params(cfg, dtype),
            "batch": batch_struct(cfg, shape.global_batch, shape.seq_len, dtype),
        }
        if cfg.supports_decode:
            sp["caches"] = abstract_caches(
                cfg, shape.global_batch, shape.seq_len, dtype
            )
        return sp

    def jitted(shape: ShapeConfig):
        sp = specs_fn(shape)
        p_sh = param_shardings(sp["params"], cfg, mesh, sharding_variant)
        b_sh = jax.tree.map(
            lambda s: serve_batch_sharding(mesh, shape.global_batch, len(s.shape)),
            sp["batch"],
        )
        if not cfg.supports_decode:
            return jax.jit(encoder_fwd, in_shardings=(p_sh, b_sh))
        c_sh = cache_shardings(sp["caches"], cfg, mesh)
        return jax.jit(
            prefill,
            in_shardings=(p_sh, b_sh, c_sh),
            out_shardings=(serve_batch_sharding(mesh, shape.global_batch, 3), c_sh),
            donate_argnums=(2,),
        )

    return jitted, specs_fn


def build_decode_step(
    cfg: ModelConfig, mesh, *, dtype=jnp.bfloat16,
    sharding_variant: str = "baseline",
):
    """One new token against a seq_len KV cache (decode_32k / long_500k)."""

    def decode(params, caches, tokens, position):
        h = embed_inputs(params, cfg, {"tokens": tokens})
        h, caches, _ = forward(params, cfg, h, caches=caches, position=position)
        logits = logits_from_hidden(params, cfg, h)
        return logits, caches

    def specs_fn(shape: ShapeConfig):
        B = shape.global_batch
        return {
            "params": abstract_params(cfg, dtype),
            "caches": abstract_caches(cfg, B, shape.seq_len, dtype),
            "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
            "position": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def jitted(shape: ShapeConfig):
        sp = specs_fn(shape)
        B = shape.global_batch
        p_sh = param_shardings(sp["params"], cfg, mesh, sharding_variant)
        c_sh = cache_shardings(sp["caches"], cfg, mesh)
        t_sh = serve_batch_sharding(mesh, B, 2)
        return jax.jit(
            decode,
            in_shardings=(p_sh, c_sh, t_sh, replicated(mesh)),
            out_shardings=(serve_batch_sharding(mesh, B, 3), c_sh),
            donate_argnums=(1,),
        )

    return jitted, specs_fn


def step_builder_for(cfg: ModelConfig, shape: ShapeConfig, mesh, **kw):
    """Dispatch on the input-shape kind."""
    if shape.kind == "train":
        return build_train_step(cfg, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, mesh)
    return build_decode_step(cfg, mesh)
