"""Serving driver: prefill a batch of prompts, then decode tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --batch 4 --prompt-len 64 --decode-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..models import (
    embed_inputs,
    init_caches,
    init_params,
    logits_from_hidden,
    random_batch,
)
from ..models.transformer import forward


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode path")

    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg, jnp.float32)
    capacity = args.prompt_len + args.decode_tokens
    caches = init_caches(cfg, args.batch, capacity, jnp.float32)
    batch = random_batch(jax.random.PRNGKey(1), cfg, args.batch,
                         args.prompt_len, jnp.float32)

    @jax.jit
    def prefill(params, batch, caches):
        h = embed_inputs(params, cfg, batch)
        h, caches, _ = forward(params, cfg, h, caches=caches)
        return logits_from_hidden(params, cfg, h[:, -1:]), caches

    @jax.jit
    def decode(params, caches, tok, pos):
        h = embed_inputs(params, cfg, {"tokens": tok})
        h, caches, _ = forward(params, cfg, h, caches=caches, position=pos)
        return logits_from_hidden(params, cfg, h), caches

    t0 = time.time()
    logits, caches = prefill(params, batch, caches)
    print(f"prefill [{args.batch}x{args.prompt_len}] {time.time()-t0:.2f}s")

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    toks = [tok]
    t0 = time.time()
    for i in range(args.decode_tokens - 1):
        pos = jnp.int32(args.prompt_len + i)
        logits, caches = decode(params, caches, tok, pos)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        toks.append(tok)
    dt = time.time() - t0
    out = jnp.concatenate(toks, axis=1)
    print(f"decoded {args.decode_tokens} tokens/seq in {dt:.2f}s "
          f"({args.batch*args.decode_tokens/max(dt,1e-9):.1f} tok/s)")
    print("sample:", out[0].tolist())


if __name__ == "__main__":
    main()
