"""Production mesh construction (functions only — importing this module
never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) over ("data", "model") = 256 chips.
    Multi-pod:   (2, 16, 16) over ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever local devices exist (tests/examples)."""
    return jax.make_mesh(
        (data, model),
        ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def fed_axes(mesh, fed_mode: str):
    """Mesh axes that carry the federated agents (DESIGN.md §4)."""
    names = mesh.axis_names
    if fed_mode == "A":
        return tuple(a for a in ("pod", "data") if a in names)
    if fed_mode == "B":
        return tuple(a for a in ("pod",) if a in names)
    raise ValueError(fed_mode)


def num_agents(mesh, fed_mode: str) -> int:
    m = 1
    for a in fed_axes(mesh, fed_mode):
        m *= mesh.shape[a]
    return max(m, 1)
