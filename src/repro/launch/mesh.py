"""Production mesh construction (functions only — importing this module
never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) over ("data", "model") = 256 chips.
    Multi-pod:   (2, 16, 16) over ("pod", "data", "model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever local devices exist (tests/examples)."""
    return jax.make_mesh(
        (data, model),
        ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def fed_axes(mesh, fed_mode: str):
    """Mesh axes that carry the federated agents (DESIGN.md §4)."""
    names = mesh.axis_names
    if fed_mode == "A":
        return tuple(a for a in ("pod", "data") if a in names)
    if fed_mode == "B":
        return tuple(a for a in ("pod",) if a in names)
    raise ValueError(fed_mode)


def num_agents(mesh, fed_mode: str) -> int:
    m = 1
    for a in fed_axes(mesh, fed_mode):
        m *= mesh.shape[a]
    return max(m, 1)


def pod_device_groups(mesh, fed_mode: str, num_pods: int):
    """Map aggregation pods onto the mesh's federated axes: the devices
    along `fed_axes` are split into `num_pods` contiguous groups (row-
    major over those axes), one group per pod — level one of the
    agents -> pods -> server tree runs inside a group, and only the
    per-pod partials cross group boundaries.  Returns a list of
    `num_pods` device lists.

    `num_pods` must divide the federated device count so groups are
    equal-sized (equal-shape per-group programs — one compilation
    serves all, matching the agent-shard rule in `fed.async_runtime`).
    More pods than federated devices is the simulation regime — pods
    are then a host-side segment-sum, not a device grouping — and is
    rejected here so a launch config can't silently oversubscribe."""
    axes = fed_axes(mesh, fed_mode)
    if not axes:
        raise ValueError(
            f"mesh {mesh.axis_names} has no federated axes in mode "
            f"{fed_mode!r} to place pods on"
        )
    devs = mesh.devices.transpose(
        [mesh.axis_names.index(a) for a in axes]
        + [
            i
            for i, a in enumerate(mesh.axis_names)
            if a not in axes
        ]
    ).reshape(num_agents(mesh, fed_mode), -1)
    n_fed = devs.shape[0]
    if num_pods < 1 or n_fed % num_pods != 0:
        raise ValueError(
            f"num_pods={num_pods} must divide the federated device "
            f"count {n_fed} (mesh {dict(mesh.shape)}, mode {fed_mode!r})"
        )
    per = n_fed // num_pods
    return [
        [d for row in devs[p * per : (p + 1) * per] for d in row]
        for p in range(num_pods)
    ]
