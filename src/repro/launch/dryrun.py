import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -------------------------------------
import argparse
import json
import re
import time
import traceback
from typing import Dict, List, Optional

import jax

from ..configs import ARCHS, INPUT_SHAPES, get_config, supported_shapes
from .mesh import make_production_mesh
from .steps import (
    build_decode_step,
    build_prefill_step,
    build_train_step,
)

_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9_]+\[[0-9,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_collectives(hlo_text: str) -> List[Dict]:
    """Census of collective ops in the compiled module (static counts;
    ops inside while bodies appear once — trip-count scaling is applied
    analytically in benchmarks/roofline.py, see DESIGN.md §6)."""
    out = []
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        out.append(
            {"op": m.group(2), "bytes": _shape_bytes(m.group(1))}
        )
    return out


def summarize_collectives(ops: List[Dict]) -> Dict:
    summary: Dict[str, Dict] = {}
    for o in ops:
        s = summary.setdefault(o["op"], {"count": 0, "bytes": 0})
        s["count"] += 1
        s["bytes"] += o["bytes"]
    return summary


def _mem_analysis(compiled) -> Dict:
    try:
        ma = compiled.memory_analysis()
        return {
            k: int(getattr(ma, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(ma, k)
        }
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def run_one(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    algorithm: str = "fedgda_gt",
    num_local_steps: int = 4,
    sharding_variant: str = "baseline",
    sequence_parallel: bool = True,
    h_shard=None,
    q_block=None,
    moe_dispatch=None,
    participation=None,
    compression_ratio=None,
    quantization_bits=None,
    wire_transport=False,
    runtime="sync",
    population=None,
    noise=None,
    noise_sigma=None,
    momentum=None,
    pods=None,
) -> Dict:
    cfg = get_config(arch)
    if (
        moe_dispatch
        or participation is not None
        or compression_ratio is not None
        or quantization_bits is not None
        or wire_transport
        or noise is not None
        or noise_sigma is not None
        or momentum is not None
    ):
        import dataclasses as _dc

        repl = {}
        if moe_dispatch:
            repl["moe_dispatch"] = moe_dispatch
        if participation is not None:
            repl["participation"] = participation
        if compression_ratio is not None:
            repl["compression_ratio"] = compression_ratio
        if quantization_bits is not None:
            repl["quantization_bits"] = quantization_bits
        if wire_transport:
            repl["wire_transport"] = True
        if noise is not None:
            repl["noise"] = noise
        if noise_sigma is not None:
            repl["noise_sigma"] = noise_sigma
        if momentum is not None:
            repl["momentum"] = momentum
        cfg = _dc.replace(cfg, **repl)
    if runtime != "sync":
        import dataclasses as _dc

        cfg = _dc.replace(cfg, runtime=runtime)
    if population:
        import dataclasses as _dc

        from ..sim.scenarios import SCENARIOS

        if population not in SCENARIOS:
            raise ValueError(
                f"unknown population scenario {population!r}; "
                f"known: {sorted(SCENARIOS)}"
            )
        cfg = _dc.replace(cfg, population=population)
    if pods is not None:
        import dataclasses as _dc

        cfg = _dc.replace(cfg, pods=pods)
    #: non-stable population => lower the membership-aware elastic round
    #: (extra schedule inputs: tracker table, weights, budgets, active)
    elastic = cfg.population != "stable"
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: Dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "algorithm": algorithm if shape.kind == "train" else None,
        "num_local_steps": num_local_steps if shape.kind == "train" else None,
        "participation": cfg.participation if shape.kind == "train" else None,
        "compression_ratio": (
            cfg.compression_ratio if shape.kind == "train" else None
        ),
        "quantization_bits": (
            cfg.quantization_bits if shape.kind == "train" else None
        ),
        "wire_transport": (
            cfg.wire_transport if shape.kind == "train" else None
        ),
        "runtime": cfg.runtime if shape.kind == "train" else None,
        "population": cfg.population if shape.kind == "train" else None,
        "noise": cfg.noise if shape.kind == "train" else None,
        "noise_sigma": cfg.noise_sigma if shape.kind == "train" else None,
        "momentum": cfg.momentum if shape.kind == "train" else None,
        "pods": cfg.pods if shape.kind == "train" else None,
        "sharding_variant": sharding_variant,
        "sequence_parallel": sequence_parallel,
        "h_shard": h_shard,
        "q_block_override": q_block,
    }
    if shape.kind == "train" and cfg.pods:
        # record the two-level tree's device placement + per-pod wire
        # price alongside the round's census (launch.steps owns the plan)
        from .steps import pod_aggregation_plan

        rec["pod_plan"] = pod_aggregation_plan(cfg, mesh, cfg.pods)
    t0 = time.perf_counter()
    with jax.set_mesh(mesh):
        if shape.kind == "train" and elastic:
            from .steps import build_elastic_train_step

            jitted_fn, specs_fn = build_elastic_train_step(
                cfg, mesh, algorithm=algorithm, num_local_steps=num_local_steps,
                sharding_variant=sharding_variant,
                sequence_parallel=sequence_parallel,
                h_shard=h_shard,
                q_block=q_block,
            )
            sp = specs_fn(shape)
            lowered = jitted_fn(shape).lower(
                sp["x"], sp["y"], sp["batch"], sp["state"], sp["tracker"],
                sp["weights"], sp["budgets"], sp["active"],
                sp["prev_active"],
            )
        elif shape.kind == "train":
            jitted_fn, specs_fn = build_train_step(
                cfg, mesh, algorithm=algorithm, num_local_steps=num_local_steps,
                sharding_variant=sharding_variant,
                sequence_parallel=sequence_parallel,
                h_shard=h_shard,
                q_block=q_block,
            )
            sp = specs_fn(shape)
            step_args = [sp["x"], sp["y"], sp["batch"]]
            if "state" in sp:  # stateful strategy (sampling RNG / EF buffers)
                step_args.append(sp["state"])
            lowered = jitted_fn(shape).lower(*step_args)
        elif shape.kind == "prefill":
            jitted_fn, specs_fn = build_prefill_step(
                cfg, mesh, sharding_variant=sharding_variant
            )
            sp = specs_fn(shape)
            if cfg.supports_decode:
                lowered = jitted_fn(shape).lower(
                    sp["params"], sp["batch"], sp["caches"]
                )
            else:
                lowered = jitted_fn(shape).lower(sp["params"], sp["batch"])
        else:  # decode
            jitted_fn, specs_fn = build_decode_step(
                cfg, mesh, sharding_variant=sharding_variant
            )
            sp = specs_fn(shape)
            lowered = jitted_fn(shape).lower(
                sp["params"], sp["caches"], sp["tokens"], sp["position"]
            )
        rec["lower_s"] = time.perf_counter() - t0
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = time.perf_counter() - t1
        rec["memory_analysis"] = _mem_analysis(compiled)
        try:
            cost = compiled.cost_analysis()
            rec["cost_analysis"] = {
                k: float(v)
                for k, v in cost.items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "bytes accessed", "optimal_seconds")
                    or k.startswith("bytes accessed")
                )
            }
        except Exception as e:  # pragma: no cover
            rec["cost_analysis"] = {"error": str(e)}
        hlo = compiled.as_text()
        rec["collectives"] = summarize_collectives(parse_collectives(hlo))
        rec["hlo_bytes"] = len(hlo)
        # exact executed census (trip-count-scaled; DESIGN.md §6)
        from .hlo_census import HloCensus

        rec["census"] = HloCensus(hlo).summary()

        if cfg.runtime == "async" and shape.kind == "train":
            # the async runtime's packed-payload all-gather, lowered and
            # censused on its own: interconnect bytes must equal the wire
            # payload (comm_collectives --check-async gates the drift).
            # Only correction strategies at full participation gather a
            # payload — for anything else (sync_gda, local_sgda, sampled
            # partial_gt) there is no wire record to census and the
            # (measured - 2*dense)/2 share below would be meaningless
            import jax.numpy as jnp

            from ..fed.transport import (
                dense_payload_bytes,
                measured_bytes_per_round,
            )
            from .mesh import num_agents
            from .steps import (
                _resolve_cfg_strategy,
                abstract_params,
                build_gather_decode_train_step,
                delta_struct,
            )

            strategy = _resolve_cfg_strategy(cfg, algorithm)
            if (
                getattr(strategy, "use_correction", False)
                and getattr(strategy, "participation", 1.0) >= 1.0
            ):
                jg, argsg, expected = build_gather_decode_train_step(
                    cfg, mesh, algorithm=algorithm
                )
                cg = jg.lower(*argsg).compile()
                rec["gather_census"] = HloCensus(cg.as_text()).summary()[
                    "collectives_executed"
                ]
                rec["expected_gather_bytes"] = int(expected)
                x = abstract_params(cfg, jnp.bfloat16)
                y = delta_struct(cfg, jnp.bfloat16)
                meas = int(
                    measured_bytes_per_round(
                        strategy, x, y, num_local_steps, include_headers=False
                    )
                )
                dense = int(dense_payload_bytes((x, y)))
                rec["wire"] = {
                    "measured_bytes_per_round": meas,
                    "payload_share_per_agent": max(0, (meas - 2 * dense) // 2),
                    "num_agents": num_agents(mesh, cfg.fed_mode),
                }
    return rec


def combos(archs=None):
    for name, cfg in ARCHS.items():
        if archs and name not in archs:
            continue
        for shape in supported_shapes(cfg):
            yield name, shape.name


def main() -> None:
    ap = argparse.ArgumentParser(description="multi-pod dry-run: lower+compile")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--algorithm", default="fedgda_gt")
    ap.add_argument("--num-local-steps", type=int, default=4)
    ap.add_argument("--participation", type=float, default=None,
                    help="client fraction per round (partial_gt)")
    ap.add_argument("--compression-ratio", type=float, default=None,
                    help="kept fraction of sparsified corrections "
                         "(compressed_gt / quantized_gt)")
    ap.add_argument("--quantization-bits", type=int, default=None,
                    help="stochastic-quantization bit-width for tracking "
                         "corrections (quantized_gt; >=32 disables)")
    ap.add_argument("--wire-transport", action="store_true",
                    help="encode compressed corrections as packed "
                         "(value, index, scale) payloads inside the step "
                         "(payload bytes match bytes_per_round)")
    ap.add_argument("--noise", default=None,
                    choices=["none", "gaussian", "minibatch"],
                    help="stochastic-gradient noise model for the "
                         "stochastic strategies (sagda / local_sgda_plus "
                         "and the noise-capable GT aliases); the round "
                         "gains the per-round noise-key state input")
    ap.add_argument("--noise-sigma", type=float, default=None,
                    help="gaussian noise scale (implies --noise gaussian "
                         "semantics only when --noise is set)")
    ap.add_argument("--momentum", type=float, default=None,
                    help="local heavy-ball momentum (local_sgda_plus); "
                         "voids the fused-anchor shortcut")
    ap.add_argument("--runtime", default="sync", choices=["sync", "async"],
                    help="round schedule: sync lowers the fused round; "
                         "async additionally lowers + censuses the "
                         "packed-payload all-gather of the phase-"
                         "dispatched runtime (tag __async)")
    from ..sim.scenarios import SCENARIOS

    ap.add_argument("--pods", type=int, default=None,
                    help="two-level aggregation tree: split the fed-axes "
                         "devices into this many pod groups and record "
                         "the pod plan (launch.mesh.pod_device_groups)")
    ap.add_argument("--population", default=None,
                    choices=sorted(SCENARIOS),
                    help="client-population scenario (repro.sim); any "
                         "non-stable preset lowers the membership-aware "
                         "elastic round — tracker table, per-agent step "
                         "budgets, re-normalized weights (tag __pop<name>)")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "megatron"])
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--h-shard", default=None, choices=["seq", "batch", "none"])
    ap.add_argument("--q-block", type=int, default=None)
    ap.add_argument("--moe-dispatch", default=None, choices=["einsum", "scatter"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--telemetry", default=None, metavar="DIR",
                    help="write a run ledger (repro.obs.RunLedger) under "
                         "DIR: manifest with the resolved flags + one "
                         "'dryrun' event per successful tag (lower / "
                         "compile seconds, collectives, memory)")
    args = ap.parse_args()

    # an unset knob falls back to the registry's ACTIVE default for the
    # strategy being dried-run — the ModelConfig defaults are the identity
    # configuration, so `--algorithm quantized_gt` without
    # --quantization-bits would otherwise lower plain FedGDA-GT and tag
    # it as quantized (same for compressed_gt / partial_gt)
    if args.algorithm == "quantized_gt" and args.quantization_bits is None:
        args.quantization_bits = 8
    if args.algorithm == "compressed_gt" and args.compression_ratio is None:
        args.compression_ratio = 0.1
    if (
        args.algorithm in ("partial_gt", "partial_participation")
        and args.participation is None
    ):
        args.participation = 0.5
    # same active-default rule for the stochastic family: `--algorithm
    # sagda` without a noise spec would lower plain FedGDA-GT (SAGDA's
    # zero-noise degeneration is bitwise GT) and tag it as sagda
    if args.algorithm == "sagda" and args.noise is None:
        args.noise = "gaussian"
    if args.algorithm == "local_sgda_plus" and args.momentum is None:
        args.momentum = 0.9

    os.makedirs(args.out, exist_ok=True)
    if args.all:
        pairs = list(combos([args.arch] if args.arch else None))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        pairs = [(args.arch, args.shape)]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    ledger = None
    if args.telemetry:
        from ..obs import RunLedger, run_manifest

        ledger = RunLedger(args.telemetry)
        ledger.write_manifest(run_manifest(config=vars(args)))

    failures = 0
    for arch, shape in pairs:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            if args.algorithm != "fedgda_gt":
                tag += f"__{args.algorithm}"
            if args.participation is not None:
                tag += f"__p{args.participation:g}"
            if args.compression_ratio is not None:
                tag += f"__r{args.compression_ratio:g}"
            if args.quantization_bits is not None:
                tag += f"__q{args.quantization_bits:d}"
            if args.wire_transport:
                tag += "__wire"
            if args.noise and args.noise != "none":
                tag += f"__n{args.noise}"
                if args.noise_sigma is not None:
                    tag += f"{args.noise_sigma:g}"
            if args.momentum is not None:
                tag += f"__m{args.momentum:g}"
            if args.runtime != "sync":
                tag += f"__{args.runtime}"
            if args.population and args.population != "stable":
                tag += f"__pop{args.population}"
            if args.pods:
                tag += f"__pods{args.pods}"
            if args.variant != "baseline":
                tag += f"__{args.variant}"
            if args.no_seq_parallel:
                tag += "__nosp"
            if args.h_shard:
                tag += f"__h{args.h_shard}"
            if args.q_block:
                tag += f"__qb{args.q_block}"
            if args.moe_dispatch:
                tag += f"__{args.moe_dispatch}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag} (exists)")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                rec = run_one(
                    arch, shape, mp,
                    algorithm=args.algorithm,
                    num_local_steps=args.num_local_steps,
                    sharding_variant=args.variant,
                    sequence_parallel=not args.no_seq_parallel,
                    h_shard=args.h_shard,
                    q_block=args.q_block,
                    moe_dispatch=args.moe_dispatch,
                    participation=args.participation,
                    compression_ratio=args.compression_ratio,
                    quantization_bits=args.quantization_bits,
                    wire_transport=args.wire_transport,
                    runtime=args.runtime,
                    population=args.population,
                    noise=args.noise,
                    noise_sigma=args.noise_sigma,
                    momentum=args.momentum,
                    pods=args.pods,
                )
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if ledger is not None:
                    ledger.write({
                        "kind": "event", "name": "dryrun", "tag": tag,
                        "lower_s": rec["lower_s"],
                        "compile_s": rec["compile_s"],
                        "collectives": rec["collectives"],
                        "memory": rec["memory_analysis"],
                    })
                ma = rec["memory_analysis"]
                print(
                    f"  ok lower={rec['lower_s']:.1f}s compile={rec['compile_s']:.1f}s "
                    f"args={ma.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                    f"temp={ma.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                    f"flops={rec['cost_analysis'].get('flops', float('nan')):.3e} "
                    f"coll={rec['collectives']}",
                    flush=True,
                )
            except Exception:
                failures += 1
                print(f"  FAILED {tag}\n{traceback.format_exc()}", flush=True)
            finally:
                jax.clear_caches()  # bound process memory across 64 compiles
    if ledger is not None:
        ledger.close()
    if failures:
        raise SystemExit(f"{failures} dry-run failures")


if __name__ == "__main__":
    main()
