"""Multi-host federated launch path: agents on devices, packed wire gather.

The fused single-program engine moves corrections between agents and
server inside one XLA program, so the bytes that `fed.transport` so
carefully packs never cross a real interconnect.  This module is the
launch path where they do:

  * `init_distributed` — `jax.distributed`-aware process bootstrap
    (gated: a single-process run is a no-op, so the same entry point
    serves laptops and multi-host pods; under multi-host,
    `jax.devices()` spans every host and the agent shards below land on
    remote devices automatically);
  * `MultiHostRunner` — each agent shard lives on its own device with
    its own strategy-state slice (error-feedback buffers AND the
    rounding/selection RNG — draws are per-shard, folded by shard
    index).  Per round, shards compute anchor gradients, the server
    forms gbar, each shard ENCODES its correction as a
    `transport.PackedTree` payload on-device, and the server
    **all-gathers the packed buffers** — shape-static per-agent byte
    buffers, so interconnect traffic equals the strategy's
    `measured_bytes_per_round` payload share — and DECODES server-side;
    the decoded correction slices ride the down-link into per-shard
    local steps, and the server combines the partial aggregates.  Every
    round's actual gathered byte count lands in `wire_log`;
  * `build_gather_decode_step` — the same gather, lowered as one SPMD
    program on a production mesh (payload buffers sharded over the fed
    axes, decode replicated) for the dry-run HLO census: the program's
    all-gather collective bytes must track `measured_bytes_per_round`
    (benchmarks/comm_collectives.py --check-async gates that).

Unlike `fed.async_runtime` (whose exchange transform runs server-side so
its draws — and therefore iterates — match the sync runner exactly), the
multi-host path draws per shard: iterates are statistically equivalent
but not bitwise-reproducible against the single-program round.  What IS
pinned: the server-side decode of the gathered payloads reproduces each
shard's own decode bitwise (same buffers, same `decode_leaf`), and the
gathered size equals the priced payload (tests/test_async_runtime.py).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.engine import (
    agent_mean,
    agent_weighted_sum,
    make_phases,
    tracking_corrections,
)
from ..core.types import Pytree, grad_xy, identity_proj
from ..fed.async_runtime import concat_on_device, largest_shard_count
from ..fed.strategies import resolve_strategy
from ..fed.transport import (
    LeafSpec,
    PackedTree,
    decode_leaf,
    encode_leaf,
)

__all__ = [
    "MultiHostRunner",
    "build_gather_decode_step",
    "expected_gather_bytes",
    "init_distributed",
    "leaf_specs",
    "payload_structs",
]


def init_distributed(
    coordinator_address: Optional[str] = None,
    num_processes: Optional[int] = None,
    process_id: Optional[int] = None,
) -> bool:
    """Initialize `jax.distributed` when a multi-process launch is
    configured (explicit arguments or the standard JAX_COORDINATOR_*
    environment), and no-op otherwise.  Returns True when a multi-host
    runtime was actually brought up.  Safe to call unconditionally from
    launch scripts: single-process development runs skip straight to the
    local devices."""
    coordinator_address = coordinator_address or os.environ.get(
        "JAX_COORDINATOR_ADDRESS"
    )
    if coordinator_address is None:
        return False
    if num_processes is None:
        num_processes = int(os.environ.get("JAX_NUM_PROCESSES", "1"))
    if process_id is None:
        process_id = int(os.environ.get("JAX_PROCESS_ID", "0"))
    jax.distributed.initialize(
        coordinator_address=coordinator_address,
        num_processes=num_processes,
        process_id=process_id,
    )
    return True


# --------------------------------------------------------------------------
# packed-payload layout shared by the runner and the census program
# --------------------------------------------------------------------------
def leaf_specs(strategy, tree: Pytree, m: int) -> List[LeafSpec]:
    """The stacked wire layout of every leaf of one correction tree for
    `m` agents — exactly the specs `transform_correction` builds, so the
    runner's host-side PackedTree reconstruction and the strategy's
    in-trace encode cannot disagree."""
    cdt = getattr(strategy, "correction_dtype", None)
    ratio = getattr(strategy, "_ratio", 1.0)
    bits = getattr(strategy, "_bits", 32)
    mode = getattr(strategy, "mode", "topk")
    return [
        LeafSpec.build(u.shape, cdt or u.dtype, ratio, bits, mode).stacked(m)
        for u in jax.tree.leaves(tree)
    ]


def payload_structs(specs: Sequence[LeafSpec]) -> List:
    """ShapeDtypeStructs of each spec's packed buffers (via eval_shape of
    the encoder — the probe never trusts the layout arithmetic)."""
    out = []
    for spec in specs:
        c = jax.ShapeDtypeStruct((spec.rows, spec.cols), spec.dtype)
        u = jax.ShapeDtypeStruct((spec.rows, spec.cols), jnp.float32)
        out.append(
            jax.eval_shape(lambda cc, uu: encode_leaf(cc, None, uu, uu, spec)[0], c, u)
        )
    return out


def expected_gather_bytes(strategy, x: Pytree, y: Pytree, m: int) -> int:
    """Packed payload bytes the server gathers per round (both correction
    trees, all m agents, headers excluded) — the number the census'
    all-gather bytes and the runner's `wire_log` must track."""
    return sum(
        s.wire_bytes() for s in leaf_specs(strategy, (x, y), m)
    )


# --------------------------------------------------------------------------
# multi-host round driver
# --------------------------------------------------------------------------
class MultiHostRunner:
    """Federated rounds with per-device agent shards and a packed-payload
    gather (see module docstring).  Requires a correction strategy (the
    GT family — there is no payload to gather otherwise)."""

    def __init__(
        self,
        loss: Callable,
        strategy,
        agent_data: Pytree,
        num_local_steps: int,
        eta_x: float,
        eta_y: Optional[float] = None,
        *,
        proj_x: Callable = identity_proj,
        proj_y: Callable = identity_proj,
        devices: Optional[Sequence] = None,
        pod_map=None,
        telemetry=None,
        **strategy_kwargs,
    ):
        self._strategy = resolve_strategy(strategy, **strategy_kwargs)
        if not getattr(self._strategy, "use_correction", False):
            raise ValueError(
                "MultiHostRunner gathers correction payloads; strategy "
                f"{self._strategy.name!r} exchanges none (use "
                "fed.async_runtime.AsyncFederatedRunner for it)"
            )
        if getattr(self._strategy, "participation", 1.0) < 1.0:
            raise ValueError(
                "MultiHostRunner is a full-participation path; client "
                "sampling needs the async runtime's server-side draw"
            )
        self._proj_x, self._proj_y = proj_x, proj_y
        self._m = jax.tree.leaves(agent_data)[0].shape[0]
        devices = list(devices) if devices is not None else jax.local_devices()
        if pod_map is not None:
            # pod-aligned shards (shared rule with AsyncFederatedRunner):
            # whole pods per device shard, so the per-shard packed
            # payloads double as pod-level partial payloads
            from ..fed.pods import pod_aligned_shard_count

            if pod_map.m != self._m or self._m % pod_map.num_pods != 0:
                raise ValueError(
                    f"pod_map ({pod_map.m} agents, {pod_map.num_pods} "
                    f"pods) does not align with m={self._m}"
                )
            n = pod_aligned_shard_count(pod_map.num_pods, len(devices))
        else:
            n = largest_shard_count(self._m, len(devices))
        self._n_shards, self._per = n, self._m // n
        self._server = devices[0]
        self._shard_devices = devices[:n]
        self._data_s = [
            jax.device_put(
                jax.tree.map(
                    lambda u: u[i * self._per : (i + 1) * self._per], agent_data
                ),
                d,
            )
            for i, d in enumerate(self._shard_devices)
        ]
        self._phases = make_phases(
            loss, self._strategy, num_local_steps, eta_x, eta_y,
            proj_x=proj_x, proj_y=proj_y,
        )
        self._gfn = grad_xy(loss)
        self._vgrad = jax.vmap(self._gfn, in_axes=(0, 0, 0))
        self._cdt = getattr(self._strategy, "correction_dtype", None)
        self._fused = self._m > 1 and bool(self._strategy.exact_correction)
        self._wire = bool(getattr(self._strategy, "wire_transport", False))
        self._build_programs()
        self._state_s: Optional[List[Dict]] = None
        self._specs: Optional[Tuple[List[LeafSpec], List[LeafSpec]]] = None
        #: repro.obs.Telemetry sink or None; the wire_log below predates
        #: it and stays (telemetry ABSORBS it: every wire_log append also
        #: lands in the sink as a "gathered_payload_bytes" counter)
        self.telemetry = telemetry
        #: per-round wire accounting: gathered payload/total bytes
        self.wire_log: List[Dict[str, int]] = []

    # ------------------------------------------------------------ programs
    def _build_programs(self) -> None:
        ph = self._phases
        strategy = self._strategy
        cdt = self._cdt
        fused = self._fused

        def shard_grads(x, y, data_s):
            rs = ph.broadcast(x, y, data_s, {}, weights=None)
            g = self._vgrad(rs.xs, rs.ys, data_s)
            return g.gx, g.gy

        def shard_encode(gx_s, gy_s, gbar_x, gbar_y, state_s):
            """Form this shard's corrections and ENCODE them on-device:
            the up-link payload is the packed buffers, nothing else."""
            cx, cy = tracking_corrections(gx_s, gy_s, gbar_x, gbar_y, cdt)
            cx, cy, state_s = strategy.transform_correction(cx, cy, state_s)
            if hasattr(cx, "decode"):
                # wire transport: ship the raw packed buffers (the
                # PackedTree wrapper is host-side metadata)
                return cx.payloads, cy.payloads, state_s
            return cx, cy, state_s

        def shard_steps(x, y, data_s, cx_s, cy_s, gbar_x, gbar_y):
            rs = ph.broadcast(x, y, data_s, {}, weights=None)
            rs = dataclasses.replace(
                rs, cx=cx_s, cy=cy_s, gbar_x=gbar_x, gbar_y=gbar_y,
                fused=fused,
            )
            rs = ph.local_steps(rs, data_s)
            return (
                agent_weighted_sum(rs.xs, None),
                agent_weighted_sum(rs.ys, None),
            )

        def server_combine(x_sums, y_sums):
            x1 = jax.tree.map(lambda *u: sum(u) / self._m, *x_sums)
            y1 = jax.tree.map(lambda *u: sum(u) / self._m, *y_sums)
            return self._proj_x(x1), self._proj_y(y1)

        self._shard_grads = jax.jit(shard_grads)
        self._shard_encode = jax.jit(shard_encode)
        self._shard_steps = jax.jit(shard_steps)
        self._server_combine = jax.jit(server_combine)

    # ------------------------------------------------------------- plumbing
    def _init_state(self, x: Pytree, y: Pytree) -> None:
        strategy = self._strategy
        self._state_s = []
        for i, d in enumerate(self._shard_devices):
            s = (
                strategy.init_state(x, y, self._per)
                if getattr(strategy, "stateful", False)
                else {}
            )
            if "key" in s:
                # independent draws per shard — each agent group owns its
                # selection/rounding randomness, nothing is replicated
                s = dict(s)
                s["key"] = jax.random.fold_in(s["key"], i)
            self._state_s.append(jax.device_put(s, d))
        self._specs = (
            leaf_specs(strategy, x, self._per),
            leaf_specs(strategy, y, self._per),
        )
        self._treedefs = (
            jax.tree.structure(x),
            jax.tree.structure(y),
        )
        self._shapes = (
            [(self._per,) + u.shape for u in jax.tree.leaves(x)],
            [(self._per,) + u.shape for u in jax.tree.leaves(y)],
        )

    def _gather_decode(self, payloads_s: List, which: int) -> Tuple[Pytree, int, int]:
        """Server side of the exchange: pull every shard's packed buffers
        to the server device (THE wire transfer — its size is the
        payload), rebuild the PackedTrees, decode, and stack the agent
        axis back together.  Returns (decoded [m, ...] tree, payload
        bytes, payload+header bytes)."""
        specs = self._specs[which]
        treedef = self._treedefs[which]
        shapes = self._shapes[which]
        parts, payload_bytes, total_bytes = [], 0, 0
        for p in payloads_s:
            gathered = jax.device_put(p, self._server)
            tree = PackedTree(list(gathered), specs, treedef, shapes)
            payload_bytes += tree.wire_bytes()
            total_bytes += tree.total_bytes()
            parts.append(tree.decode())
        if len(parts) == 1:
            return parts[0], payload_bytes, total_bytes
        stacked = jax.tree.map(
            lambda *u: jnp.concatenate(u, axis=0), *parts
        )
        return stacked, payload_bytes, total_bytes

    # ------------------------------------------------------------- run loop
    def _log_wire(self, payload_bytes: int, total_bytes: int) -> None:
        """ONE owner of the per-round wire record: the legacy `wire_log`
        entry plus (when a telemetry sink is attached) the
        "gathered_payload_bytes" counter carrying the same numbers."""
        self.wire_log.append(
            {
                "gathered_payload_bytes": payload_bytes,
                "gathered_total_bytes": total_bytes,
            }
        )
        if self.telemetry is not None:
            self.telemetry.counter(
                "gathered_payload_bytes", payload_bytes,
                total_bytes=total_bytes,
            )

    def run(self, x: Pytree, y: Pytree, num_rounds: int):
        import time

        from ..obs.telemetry import maybe_span

        x = jax.device_put(x, self._server)
        y = jax.device_put(y, self._server)
        if self._state_s is None:
            self._init_state(x, y)
        per = self._per
        tm = self.telemetry
        for t in range(num_rounds):
            t0 = time.perf_counter()
            if tm is not None:
                tm.begin_round(t)
            with maybe_span(tm, "broadcast", dispatches=self._n_shards):
                bcast = [
                    (jax.device_put(x, d), jax.device_put(y, d))
                    for d in self._shard_devices
                ]
            with maybe_span(tm, "exchange_corrections",
                            dispatches=self._n_shards):
                gs = [
                    self._shard_grads(bx, by, data)
                    for (bx, by), data in zip(bcast, self._data_s)
                ]
                gx = self._concat_server([g[0] for g in gs])
                gy = self._concat_server([g[1] for g in gs])
                gbar_x = self._agent_mean_jit(gx)
                gbar_y = self._agent_mean_jit(gy)
                gb_s = [
                    (jax.device_put(gbar_x, d), jax.device_put(gbar_y, d))
                    for d in self._shard_devices
                ]
                enc = [
                    self._shard_encode(g[0], g[1], gbx, gby, st)
                    for g, (gbx, gby), st in zip(gs, gb_s, self._state_s)
                ]
                self._state_s = [
                    jax.device_put(e[2], d)
                    for e, d in zip(enc, self._shard_devices)
                ]
                if self._wire:
                    cx, pbx, tbx = self._gather_decode(
                        [e[0] for e in enc], 0
                    )
                    cy, pby, tby = self._gather_decode(
                        [e[1] for e in enc], 1
                    )
                    self._log_wire(pbx + pby, tbx + tby)
                else:
                    # dense strategies: the gathered "payload" is the
                    # dense correction stack itself
                    cx = self._concat_server([e[0] for e in enc])
                    cy = self._concat_server([e[1] for e in enc])
                    dense = sum(
                        int(np.prod(u.shape)) * u.dtype.itemsize
                        for u in jax.tree.leaves((cx, cy))
                    )
                    self._log_wire(dense, dense)
            with maybe_span(tm, "local_steps", dispatches=self._n_shards):
                sums = [
                    self._shard_steps(
                        bx, by, data,
                        jax.device_put(
                            jax.tree.map(
                                lambda u: u[i * per:(i + 1) * per], cx
                            ),
                            d,
                        ),
                        jax.device_put(
                            jax.tree.map(
                                lambda u: u[i * per:(i + 1) * per], cy
                            ),
                            d,
                        ),
                        gbx, gby,
                    )
                    for i, ((bx, by), data, (gbx, gby), d) in enumerate(
                        zip(bcast, self._data_s, gb_s, self._shard_devices)
                    )
                ]
            with maybe_span(tm, "aggregate"):
                x, y = self._server_combine(
                    [jax.device_put(a, self._server) for a, _ in sums],
                    [jax.device_put(b, self._server) for _, b in sums],
                )
            if tm is not None:
                tm.round_event(
                    t, runtime="multihost",
                    seconds=time.perf_counter() - t0,
                    n_shards=self._n_shards,
                )
                tm.end_round(t)
        jax.block_until_ready((x, y))
        return x, y

    def _concat_server(self, parts: List[Pytree]) -> Pytree:
        return concat_on_device(parts, self._server)

    @property
    def _agent_mean_jit(self):
        if not hasattr(self, "_amj"):
            self._amj = jax.jit(lambda g: agent_mean(g, None))
        return self._amj


# --------------------------------------------------------------------------
# the gather, lowered for the HLO census (dry-run --runtime async)
# --------------------------------------------------------------------------
def build_gather_decode_step(
    strategy, x: Pytree, y: Pytree, mesh, fed_axes: Tuple[str, ...]
):
    """One SPMD program performing the multi-host payload gather on a
    production mesh: per-agent packed buffers arrive SHARDED over the fed
    axes, the decode is replicated — GSPMD therefore materializes the
    gather as all-gather collectives whose bytes are exactly the packed
    payload (the dry-run census checks this against
    `measured_bytes_per_round`).

    Returns (jitted, arg_structs, expected_bytes): call
    `jitted.lower(*arg_structs).compile()` and census the collectives."""
    m = 1
    for a in fed_axes:
        m *= mesh.shape[a]
    m = max(m, 1)
    specs = leaf_specs(strategy, (x, y), m)
    structs = payload_structs(specs)

    def shard_of(struct):
        return jax.tree.map(
            lambda u: NamedSharding(
                mesh, P(fed_axes, *([None] * (len(u.shape) - 1)))
            ),
            struct,
        )

    in_shardings = ([shard_of(s) for s in structs],)

    def gather_decode(payloads):
        rep = jax.tree.map(
            lambda u: jax.lax.with_sharding_constraint(
                u, NamedSharding(mesh, P(*([None] * len(u.shape))))
            ),
            payloads,
        )
        return [
            decode_leaf(p, spec) for p, spec in zip(rep, specs)
        ]

    jitted = jax.jit(gather_decode, in_shardings=in_shardings)
    expected = sum(s.wire_bytes() for s in specs)
    return jitted, (structs,), expected
