"""Exact executed-op census from compiled HLO text.

``compiled.cost_analysis()`` counts a while (scan) body ONCE regardless of
its trip count, which makes raw numbers useless for scanned layers/inner
steps (DESIGN.md §6).  XLA, however, annotates every while op with
``backend_config={"known_trip_count":{"n":...}}``.  This module parses the
computation graph, propagates trip-count multipliers from ENTRY through
fusions / calls / while bodies, and returns an *executed* census:

  * matmul FLOPs (dot ops, 2*M*N*K, scaled by the enclosing trip product)
  * collective bytes per op kind (operand bytes, scaled)
  * dot-shape duplication census (remat / redundancy smell test)

Caveats (documented, acceptable for roofline purposes):
  * conditional branches are all counted at the parent multiplier (upper
    bound; used only by the zamba2 shared-attention cond),
  * elementwise FLOPs are ignored (dots dominate every model here),
  * convolutions are absent from these models (frontends are stubs).
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

# one op definition line:  %name = type[dims]{layout} opcode(operands), attrs
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^()]*\)|[a-z0-9_]+\[[0-9,]*\]\S*)\s+"
    r"([a-z0-9\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?([\w.\-]+(?:,\s*%[\w.\-]+)*)\}?"
)

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    """Total (elements, bytes) over every `dtype[dims]` group in text."""
    elems = 0
    byts = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES.get(dt, 4)
    return elems, byts


class HloCensus:
    def __init__(self, hlo_text: str):
        self._parse(hlo_text)
        self._propagate()

    # ------------------------------------------------------------- parsing
    def _parse(self, text: str) -> None:
        comps: Dict[str, List[dict]] = {}
        shapes: Dict[Tuple[str, str], str] = {}  # (comp, op name) -> type str
        entry = None
        cur = None
        for line in text.splitlines():
            mc = _COMP_RE.match(line)
            if mc and line.rstrip().endswith("{"):
                cur = mc.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
                continue
            if cur is None:
                continue
            mo = _OP_RE.match(line)
            if not mo:
                continue
            name, typ, opcode, rest = mo.groups()
            shapes[(cur, name)] = typ
            comps[cur].append(
                {"name": name, "type": typ, "op": opcode, "rest": rest}
            )
        self.computations = comps
        self.shapes = shapes
        self.entry = entry

    # -------------------------------------------------- multiplier propagation
    def _propagate(self) -> None:
        mult: Dict[str, int] = defaultdict(int)
        if self.entry is None:
            self.multiplier = {}
            return
        # edges: computation -> [(callee, factor)]
        edges: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
        for comp, ops in self.computations.items():
            for o in ops:
                rest = o["rest"]
                factor = 1
                if o["op"] == "while":
                    mt = _TRIP_RE.search(rest)
                    factor = int(mt.group(1)) if mt else 1
                for mcall in _CALL_ATTR_RE.finditer(rest):
                    attr = mcall.group(0).split("=", 1)[0]
                    for callee in re.split(r",\s*%?", mcall.group(1)):
                        callee = callee.strip().lstrip("%")
                        if callee in self.computations:
                            # only the while BODY runs trip_count times; the
                            # condition runs trip+1 (~= trip for our sizes)
                            f = factor if attr in ("body", "condition") else 1
                            edges[comp].append((callee, f))
        # BFS from entry, accumulating products (call graph is a DAG in HLO)
        mult[self.entry] = 1
        order = [self.entry]
        seen = {self.entry}
        while order:
            nxt = []
            for c in order:
                for callee, f in edges.get(c, ()):
                    m = mult[c] * f
                    if m > mult[callee]:
                        mult[callee] = m
                    if callee not in seen:
                        seen.add(callee)
                        nxt.append(callee)
            order = nxt
        self.multiplier = dict(mult)

    # ------------------------------------------------------------- queries
    def _operand_shapes(self, comp: str, rest: str) -> List[str]:
        out = []
        for name in re.findall(r"%([\w.\-]+)", rest):
            t = self.shapes.get((comp, name))
            if t:
                out.append(t)
        return out

    def dot_flops(self) -> Tuple[int, Dict[str, int]]:
        """Executed matmul FLOPs (2*out_elems*contraction), plus a census of
        unscaled per-shape occurrence counts for duplication analysis."""
        total = 0
        shape_counts: Dict[str, int] = defaultdict(int)
        for comp, ops in self.computations.items():
            m = self.multiplier.get(comp, 1)
            for o in ops:
                if o["op"] != "dot":
                    continue
                out_elems, _ = _shape_elems_bytes(o["type"])
                # contraction size: lhs elems / (out elems contributed by lhs)
                mdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", o["rest"])
                opshapes = self._operand_shapes(comp, o["rest"])
                k = 1
                if mdims and opshapes:
                    lhs_dims = _SHAPE_RE.search(opshapes[0])
                    if lhs_dims:
                        dims = [int(d) for d in lhs_dims.group(2).split(",") if d]
                        for ci in mdims.group(1).split(","):
                            if ci:
                                k *= dims[int(ci)]
                total += m * 2 * out_elems * k
                shape_counts[o["type"]] += 1
        return total, dict(shape_counts)

    def collective_bytes(self) -> Dict[str, Dict[str, int]]:
        """Executed collective census: op kind -> {count, bytes} with bytes =
        operand bytes * enclosing trip product."""
        out: Dict[str, Dict[str, int]] = {}
        for comp, ops in self.computations.items():
            m = self.multiplier.get(comp, 1)
            for o in ops:
                kind = o["op"].removesuffix("-start")
                if kind not in _COLLECTIVES:
                    continue
                if o["op"].endswith("-done"):
                    continue
                _, byts = _shape_elems_bytes(o["type"])
                # for tuple-typed results (variadic all-gather etc.) the type
                # string already contains every member shape
                s = out.setdefault(kind, {"count": 0, "bytes": 0})
                s["count"] += m
                s["bytes"] += m * byts
        return out

    def summary(self) -> Dict:
        flops, shape_counts = self.dot_flops()
        dup = {s: c for s, c in shape_counts.items() if c > 1}
        return {
            "executed_dot_flops": flops,
            "collectives_executed": self.collective_bytes(),
            "duplicate_dot_shapes": dict(
                sorted(dup.items(), key=lambda kv: -kv[1])[:12]
            ),
        }


def census_from_compiled(compiled) -> Dict:
    return HloCensus(compiled.as_text()).summary()


if __name__ == "__main__":  # tiny self-check
    import jax
    import jax.numpy as jnp

    def body(c, _):
        return c @ c, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=8)

        def inner(c, _):
            z, _ = jax.lax.scan(body, c, None, length=3)
            return z, None

        y2, _ = jax.lax.scan(inner, y, None, length=5)
        return y2

    compiled = jax.jit(f).lower(jnp.ones((128, 128))).compile()
    s = census_from_compiled(compiled)
    want = 2 * 128**3 * (8 + 15)
    print(json.dumps(s, indent=1))
    assert s["executed_dot_flops"] == want, (s["executed_dot_flops"], want)
    print("census self-check OK:", want)
