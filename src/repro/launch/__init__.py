from .mesh import fed_axes, make_host_mesh, make_production_mesh, num_agents

__all__ = ["fed_axes", "make_host_mesh", "make_production_mesh", "num_agents"]
