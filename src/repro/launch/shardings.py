"""Sharding rules: parameters, agent-stacked state, batches and caches.

Rules (DESIGN.md §4):
  * params: largest >=2-D dim divisible by the model-axis size -> "model";
    MoE expert dim -> "data" (expert parallelism, fed mode B);
    embed table vocab dim -> "model";  1-D leaves replicated.
  * agent-stacked training state: leading agent axis -> fed axes
    (("pod","data") mode A, ("pod",) mode B).
  * batches: train — agent axis over fed axes, per-agent batch over the
    within-agent data axis (mode B);  serve — batch over ("pod","data").
  * KV caches: batch dim over ("pod","data") when divisible, else the
    capacity (sequence) dim over "data" (context parallelism, long_500k).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from .mesh import fed_axes

Pytree = Any


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _largest_divisible(shape, start: int, size: int) -> Optional[int]:
    best, best_dim = None, -1
    for i in range(start, len(shape)):
        if shape[i] % size == 0 and shape[i] > best_dim:
            best, best_dim = i, shape[i]
    return best


def _baseline_pspec(path_str, shape, cfg, mesh, off) -> P:
    """Paper-faithful first cut: largest >=2-D dim divisible by the model
    axis.  Kept as the §Perf 'before' reference — it leaves contraction dims
    sharded, which GSPMD resolves with per-layer activation collectives."""
    model_n = mesh.shape["model"]
    data_n = mesh.shape.get("data", 1)
    entries = [None] * len(shape)
    is_expert = "/moe/" in path_str and path_str.rsplit("/", 1)[-1] in (
        "gate", "up", "down",
    )
    if is_expert and cfg.fed_mode == "B" and shape[off] % data_n == 0:
        entries[off] = "data"
        j = _largest_divisible(shape, off + 1, model_n)
        if j is not None:
            entries[j] = "model"
        return P(*entries)
    j = _largest_divisible(shape, off, model_n)
    if j is not None:
        entries[j] = "model"
    return P(*entries)


def _megatron_pspec(path_str, shape, cfg, mesh, off) -> P:
    """Beyond-baseline rules (§Perf hillclimb): classic column/row pairing so
    every matmul is local and the only model-axis collective is one
    activation reduction per block half.

      wq      [d, H, hd]   -> column on H (heads); replicate if H % n != 0
      wk/wv   [d, KV, hd]  -> column on KV, else REPLICATE (GQA KV is tiny)
      wo      [H, hd, d]   -> row on H (matches attention output sharding)
      gate/up [d, ff]      -> column on ff
      down    [ff, d]      -> row on ff
      embed   [V, d]       -> vocab-sharded (masked-local lookup + logits)
      MoE     [E, d, ff]   -> E over data (mode B) + column/row on ff
      mamba   in_proj col on 2*d_inner, out_proj row on d_inner,
              x/dt/conv/norm replicated (tiny)
    """
    model_n = mesh.shape["model"]
    data_n = mesh.shape.get("data", 1)
    name = path_str.rsplit("/", 1)[-1]
    entries = [None] * len(shape)
    if len(shape) - off < 2:
        return P(*entries)

    def put(i) -> P:
        entries[i] = "model"
        return P(*entries)

    if "/moe/" in path_str and name in ("gate", "up", "down"):
        if cfg.fed_mode == "B" and shape[off] % data_n == 0:
            entries[off] = "data"  # expert parallelism
        ff_dim = off + 2 if name in ("gate", "up") else off + 1
        if shape[ff_dim] % model_n == 0:
            entries[ff_dim] = "model"
        return P(*entries)
    if name == "wq":
        return put(off + 1) if shape[off + 1] % model_n == 0 else P(*entries)
    if name in ("wk", "wv"):
        return put(off + 1) if shape[off + 1] % model_n == 0 else P(*entries)
    if name == "wo":
        return put(off) if shape[off] % model_n == 0 else P(*entries)
    if name in ("gate", "up"):  # dense swiglu
        return put(off + 1) if shape[off + 1] % model_n == 0 else P(*entries)
    if name == "down":
        return put(off) if shape[off] % model_n == 0 else P(*entries)
    if name == "embed":
        return put(off) if shape[off] % model_n == 0 else P(*entries)
    if name == "in_proj":  # mamba column
        return put(off + 1) if shape[off + 1] % model_n == 0 else P(*entries)
    if name == "out_proj":  # mamba row
        return put(off) if shape[off] % model_n == 0 else P(*entries)
    if name in ("frontend_proj", "out_head"):
        return put(off + 1) if shape[off + 1] % model_n == 0 else P(*entries)
    # router / x_proj / dt_proj / conv / norms / biases: replicated (tiny)
    return P(*entries)


def param_pspec(
    path_str: str,
    shape: Tuple[int, ...],
    cfg: ModelConfig,
    mesh,
    variant: str = "baseline",
) -> P:
    stacked = path_str.startswith("blocks/")
    off = 1 if stacked else 0
    if len(shape) - off < 2:
        return P(*([None] * len(shape)))  # replicate 1-D / scalar leaves
    if variant == "megatron":
        return _megatron_pspec(path_str, shape, cfg, mesh, off)
    return _baseline_pspec(path_str, shape, cfg, mesh, off)


def param_shardings(
    params_shape: Pytree, cfg: ModelConfig, mesh, variant: str = "baseline"
) -> Pytree:
    """NamedShardings for the global (server) parameter pytree."""

    def f(path, leaf):
        return NamedSharding(
            mesh, param_pspec(_path_str(path), leaf.shape, cfg, mesh, variant)
        )

    return jax.tree_util.tree_map_with_path(f, params_shape)


def agent_pspec(
    path_str: str, shape, cfg: ModelConfig, mesh, variant: str = "baseline"
) -> P:
    """Spec for agent-stacked ([m, ...]) training state."""
    base = param_pspec(path_str, shape[1:], cfg, mesh, variant)
    fa = fed_axes(mesh, cfg.fed_mode)
    return P(fa if fa else None, *base)


def make_agent_constraint(cfg: ModelConfig, mesh, y_tree, variant: str = "baseline"):
    """constrain_agents hook for the core rounds: anchors the agent axis."""
    fa = fed_axes(mesh, cfg.fed_mode)

    def constrain(xs, ys):
        def cx(path, leaf):
            spec = agent_pspec(_path_str(path), leaf.shape, cfg, mesh, variant)
            return jax.lax.with_sharding_constraint(
                leaf, NamedSharding(mesh, spec)
            )

        xs = jax.tree_util.tree_map_with_path(cx, xs)
        ys = jax.tree.map(
            lambda u: jax.lax.with_sharding_constraint(
                u, NamedSharding(mesh, P(fa if fa else None))
            ),
            ys,
        )
        return xs, ys

    return constrain


def train_batch_shardings(cfg: ModelConfig, mesh) -> "jax.sharding.Sharding":
    """Agent-stacked batch [m, B_local, ...]: agent axis over fed axes;
    mode B additionally shards B_local over the within-agent data axis."""
    fa = fed_axes(mesh, cfg.fed_mode)
    inner = "data" if (cfg.fed_mode == "B" and "data" in mesh.axis_names) else None

    def shard_for(leaf_ndim: int):
        entries = [fa if fa else None, inner] + [None] * (leaf_ndim - 2)
        return NamedSharding(mesh, P(*entries))

    return shard_for


def serve_batch_sharding(mesh, batch: int, leaf_ndim: int):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    first = axes if (axes and batch % n == 0) else None
    return NamedSharding(mesh, P(first, *([None] * (leaf_ndim - 1))))


def cache_pspec(path_str: str, shape, cfg: ModelConfig, mesh) -> P:
    """Stacked cache leaves: [n_layers, B, C, KV, hd] (attn k/v),
    [n_layers, C] (pos), [n_layers, B, W-1, di] (conv), [n_layers, B, nh, p, N] (ssm)."""
    model_n = mesh.shape["model"]
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_n = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    name = path_str.rsplit("/", 1)[-1]
    entries = [None] * len(shape)
    if name in ("k", "v"):
        _, B, C, KV, hd = shape
        if B % dp_n == 0 and dp_axes:
            entries[1] = dp_axes
        elif "data" in mesh.axis_names and C % mesh.shape["data"] == 0:
            entries[2] = "data"  # context parallelism over the KV sequence
        if KV % model_n == 0:
            entries[3] = "model"
        elif hd % model_n == 0:
            entries[4] = "model"
        return P(*entries)
    if name == "pos":
        return P(*entries)  # replicated slot-position metadata
    if name == "conv":
        _, B, W, di = shape
        if B % dp_n == 0 and dp_axes:
            entries[1] = dp_axes
        if di % model_n == 0:
            entries[3] = "model"
        return P(*entries)
    if name == "ssm":
        _, B, nh, p, N = shape
        if B % dp_n == 0 and dp_axes:
            entries[1] = dp_axes
        if nh % model_n == 0:
            entries[2] = "model"
        return P(*entries)
    return P(*entries)


def cache_shardings(cache_shape: Pytree, cfg: ModelConfig, mesh) -> Pytree:
    def f(path, leaf):
        return NamedSharding(mesh, cache_pspec(_path_str(path), leaf.shape, cfg, mesh))

    return jax.tree_util.tree_map_with_path(f, cache_shape)


def replicated(mesh):
    return NamedSharding(mesh, P())
