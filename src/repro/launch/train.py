"""End-to-end federated minimax training driver.

Runs FedGDA-GT (or a baseline / scenario strategy — any
`resolve_strategy` name: local_sgda, sync_gda, partial_gt, compressed_gt,
quantized_gt, and the stochastic family sagda / local_sgda_plus with
`--noise` / `--momentum`) over one of the assigned architectures on whatever devices
exist (a host mesh locally; the production mesh on a real cluster), with
synthetic heterogeneous federated data, metrics and checkpointing.  The
round comes from the phase-split engine (`make_round`), bitwise-identical
to the legacy constructors for the legacy names (tests/test_engine_parity);
stateful strategies (sampling RNG, error-feedback buffers) thread their
state across rounds and into checkpoints.

`--runtime async` hands the same loss/strategy to
`fed.async_runtime.AsyncFederatedRunner`: per-agent-shard phase programs
on separate devices, server-side exchange, double-buffered broadcasts —
iterates match the sync loop to fp tolerance.  `init_distributed` runs
first either way, so a multi-process launch (JAX_COORDINATOR_ADDRESS set)
spans hosts transparently.

`--telemetry DIR` attaches the unified observability sink (`repro.obs`):
a structured run ledger (JSONL event stream + run manifest with the
resolved config, strategy signature, seed folds and schedule digest)
plus per-round spans, wire-byte counters, opt-in invariant probes
(`--telemetry-probes`) and sampled `jax.profiler` traces
(`--profile-rounds`).  Probes are evaluated by the runner paths
(`--population` and `--runtime async`); the raw fused sync loop emits
round spans + wire-byte counters only.  Without the flag nothing is
constructed and the runners execute their exact pre-telemetry traces.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
        --rounds 50 --local-steps 8 --agents 4 \
        [--algorithm quantized_gt --quantization-bits 8] [--runtime async]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import save_checkpoint
from ..configs import get_config
from ..core.engine import make_round
from ..data import federated_token_batches
from ..fed.strategies import resolve_strategy
from ..models import init_params, num_params
from ..problems.adversarial import (
    delta_projection,
    init_delta,
    make_adversarial_loss,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--per-agent-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--eta", type=float, default=2e-3)
    ap.add_argument("--heterogeneity", type=int, default=7)
    ap.add_argument("--algorithm", default="fedgda_gt",
                    help="any repro.fed.resolve_strategy name")
    ap.add_argument("--participation", type=float, default=None,
                    help="client fraction per round (partial_gt)")
    ap.add_argument("--compression-ratio", type=float, default=None,
                    help="kept fraction of sparsified corrections "
                         "(compressed_gt / quantized_gt)")
    ap.add_argument("--quantization-bits", type=int, default=None,
                    help="stochastic-quantization bit-width "
                         "(quantized_gt; >=32 disables)")
    ap.add_argument("--wire-transport", action="store_true",
                    help="move compressed corrections as packed "
                         "(value, index, scale) payloads "
                         "(compressed_gt / quantized_gt)")
    ap.add_argument("--noise", default=None,
                    choices=["gaussian", "minibatch"],
                    help="stochastic-gradient noise model (sagda / "
                         "local_sgda_plus and the noise-capable GT "
                         "aliases); unset = the deterministic oracle")
    ap.add_argument("--noise-sigma", type=float, default=None,
                    help="gaussian noise scale (default 0.1)")
    ap.add_argument("--noise-fraction", type=float, default=None,
                    help="minibatch subsampling fraction (default 0.5)")
    ap.add_argument("--noise-seed", type=int, default=None,
                    help="seed of the dedicated noise stream "
                         "(fed.noise.noise_key — a dedicated fold, "
                         "independent of sampling/compression RNG)")
    ap.add_argument("--momentum", type=float, default=None,
                    help="local heavy-ball momentum (local_sgda_plus)")
    ap.add_argument("--runtime", default="sync", choices=["sync", "async"],
                    help="sync: one fused round program per step; "
                         "async: per-agent-shard phase dispatch "
                         "(fed.async_runtime) across the local devices")
    from ..sim.scenarios import SCENARIOS

    ap.add_argument("--population", default=None,
                    choices=sorted(SCENARIOS),
                    help="client-population scenario (repro.sim): agents "
                         "join/leave/lag per a seeded RoundSchedule; the "
                         "runners execute the membership-aware elastic "
                         "round (stable = the legacy full-participation "
                         "path, bitwise)")
    ap.add_argument("--population-seed", type=int, default=0,
                    help="seed of the availability stream (a dedicated "
                         "fold — independent of model/data RNG)")
    ap.add_argument("--no-rebase", action="store_true",
                    help="ablation: naive membership handling (1/m "
                         "weights over the full registry, stale EF "
                         "residuals) — expected to stall under churn")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--telemetry", default=None, metavar="DIR",
                    help="write a structured run ledger (events.jsonl + "
                         "manifest.json, repro.obs.RunLedger) under DIR "
                         "and emit per-round spans / wire-byte counters")
    ap.add_argument("--telemetry-probes", default="",
                    help="comma-separated invariant probes to sample "
                         "(repro.obs.probes: gt_residual, tracker_drift, "
                         "ef_residual, priced_vs_measured, duality_gap)")
    ap.add_argument("--telemetry-probe-every", type=int, default=1,
                    help="sample the enabled probes every N rounds")
    ap.add_argument("--profile-rounds", default="",
                    help="comma-separated round indices to wrap in a "
                         "jax.profiler trace (written under "
                         "DIR/profile; requires --telemetry)")
    args = ap.parse_args()

    from .multihost import init_distributed

    init_distributed()  # no-op unless a multi-process launch is configured

    # resolve the strategy first: a bad --algorithm must fail before the
    # expensive model/data setup below.  Only pass knobs the user set —
    # unset flags must not override the registry defaults (e.g.
    # compressed_gt's active 0.1 ratio)
    knobs = {
        "participation": args.participation,
        "compression_ratio": args.compression_ratio,
        "quantization_bits": args.quantization_bits,
        "wire_transport": args.wire_transport or None,
        "noise": args.noise,
        "noise_sigma": args.noise_sigma,
        "noise_fraction": args.noise_fraction,
        "noise_seed": args.noise_seed,
        "momentum": args.momentum,
    }
    strategy = resolve_strategy(
        args.algorithm, **{k: v for k, v in knobs.items() if v is not None}
    )

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg, jnp.float32)
    delta = init_delta(cfg)
    print(f"arch={cfg.name} params={num_params(params)/1e6:.1f}M "
          f"agents={args.agents} K={args.local_steps} algo={args.algorithm}")

    data = federated_token_batches(
        jax.random.PRNGKey(1), args.agents, args.per_agent_batch,
        args.seq_len, cfg.vocab_size, heterogeneity=args.heterogeneity,
    )
    loss = make_adversarial_loss(cfg, remat=False)

    def global_loss(x, y):
        per = jax.vmap(loss, in_axes=(None, None, 0))(x, y, data)
        return jnp.mean(per)

    gl = jax.jit(global_loss)

    schedule = None
    rebase = not args.no_rebase
    if args.population:
        from ..sim import make_population

        pop = make_population(args.population, args.agents)
        schedule = pop.schedule(
            args.population_seed, args.rounds, args.local_steps
        )
        print(
            f"population={args.population} seed={args.population_seed} "
            f"participation={schedule.participation_rate():.2f} "
            f"churn_events={schedule.churn_events()} rebase={rebase}"
        )

    telemetry = ledger = None
    if args.telemetry:
        import os

        from ..obs import RunLedger, Telemetry, run_manifest

        ledger = RunLedger(args.telemetry)
        probes = tuple(p for p in args.telemetry_probes.split(",") if p)
        prof = tuple(int(r) for r in args.profile_rounds.split(",") if r)
        telemetry = Telemetry(
            ledger=ledger, probes=probes,
            probe_every=args.telemetry_probe_every,
            profile_dir=(os.path.join(args.telemetry, "profile")
                         if prof else None),
            profile_rounds=prof,
        )
        ledger.write_manifest(run_manifest(
            config=vars(args), strategy=strategy,
            noise_seed=args.noise_seed,
            availability_seed=(args.population_seed if args.population
                               else None),
            schedule=schedule,
        ))
        print(f"telemetry: ledger at {args.telemetry}")

    if args.runtime == "async":
        from ..fed import AsyncFederatedRunner

        runner = AsyncFederatedRunner(
            loss, strategy, data, args.local_steps, args.eta,
            proj_y=delta_projection(1.0),
            metric_fn=lambda x, y: {
                "loss": global_loss(x, y),
                "delta_norm": jnp.linalg.norm(y["delta"]),
            },
            telemetry=telemetry,
        )
        params, delta = runner.run(
            params, delta, args.rounds, log_every=args.log_every,
            schedule=schedule, rebase=rebase,
        )
        if args.ckpt_dir:
            save_checkpoint(
                args.ckpt_dir, args.rounds, {"x": params, "y": delta}
            )
        if ledger is not None:
            ledger.close()
        print("done.")
        return

    if schedule is not None:
        # elastic sync run: the runner owns the schedule threading
        # (membership-aware round, tracker table, rebase hook)
        from ..fed import FederatedRunner

        runner = FederatedRunner.from_strategy(
            loss, strategy, data, args.local_steps, args.eta,
            proj_y=delta_projection(1.0),
            metric_fn=lambda x, y: {
                "loss": global_loss(x, y),
                "delta_norm": jnp.linalg.norm(y["delta"]),
            },
            checkpoint_dir=args.ckpt_dir,
            checkpoint_every=50 if args.ckpt_dir else 0,
            telemetry=telemetry,
        )
        params, delta = runner.run(
            params, delta, args.rounds, log_every=args.log_every,
            schedule=schedule, rebase=rebase,
        )
        if ledger is not None:
            ledger.close()
        print("done.")
        return

    stateful = strategy.stateful
    rnd = jax.jit(make_round(
        loss, strategy, args.local_steps, args.eta,
        proj_y=delta_projection(1.0), explicit_state=stateful,
    ))
    state = strategy.init_state(params, delta, args.agents) if stateful else None
    per_agent = None
    if telemetry is not None:
        from ..fed.transport import measured_bytes_per_round

        per_agent = int(measured_bytes_per_round(
            strategy, params, delta, args.local_steps
        ))
    t0 = time.time()
    for t in range(args.rounds):
        rt0 = time.perf_counter()
        if telemetry is not None:
            telemetry.begin_round(t)
        if stateful:
            params, delta, state = rnd(params, delta, data, state)
        else:
            params, delta = rnd(params, delta, data)
        if telemetry is not None:
            jax.block_until_ready(params)
            telemetry.round_event(
                t, runtime="fused", seconds=time.perf_counter() - rt0
            )
            telemetry.counter(
                "wire_bytes", per_agent * args.agents,
                per_agent=per_agent, n_active=args.agents,
            )
            telemetry.end_round(t)
        if t % args.log_every == 0 or t == args.rounds - 1:
            lv = float(gl(params, delta))
            dn = float(jnp.linalg.norm(delta["delta"]))
            print(f"[round {t:4d}] loss={lv:.4f} |delta|={dn:.4f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
        if args.ckpt_dir and (t + 1) % 50 == 0:
            payload = {"x": params, "y": delta}
            if state is not None:
                # resuming without this replays RNG draws / zeroes the
                # error-feedback buffers
                payload["strategy_state"] = state
            save_checkpoint(args.ckpt_dir, t + 1, payload)
    if ledger is not None:
        ledger.close()
    print("done.")


if __name__ == "__main__":
    main()
