"""End-to-end federated minimax training driver.

Runs FedGDA-GT (or a baseline) over one of the assigned architectures on
whatever devices exist (a host mesh locally; the production mesh on a real
cluster), with synthetic heterogeneous federated data, metrics and
checkpointing.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
        --rounds 50 --local-steps 8 --agents 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import save_checkpoint
from ..configs import get_config
from ..core.fedgda_gt import make_fedgda_gt_round
from ..core.local_sgda import make_local_sgda_round
from ..data import federated_token_batches
from ..models import init_params, num_params
from ..problems.adversarial import (
    delta_projection,
    init_delta,
    make_adversarial_loss,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--per-agent-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--eta", type=float, default=2e-3)
    ap.add_argument("--heterogeneity", type=int, default=7)
    ap.add_argument("--algorithm", default="fedgda_gt",
                    choices=["fedgda_gt", "local_sgda"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg, jnp.float32)
    delta = init_delta(cfg)
    print(f"arch={cfg.name} params={num_params(params)/1e6:.1f}M "
          f"agents={args.agents} K={args.local_steps} algo={args.algorithm}")

    data = federated_token_batches(
        jax.random.PRNGKey(1), args.agents, args.per_agent_batch,
        args.seq_len, cfg.vocab_size, heterogeneity=args.heterogeneity,
    )
    loss = make_adversarial_loss(cfg, remat=False)
    if args.algorithm == "fedgda_gt":
        rnd = make_fedgda_gt_round(
            loss, args.local_steps, args.eta, proj_y=delta_projection(1.0)
        )
    else:
        rnd = make_local_sgda_round(
            loss, args.local_steps, args.eta, args.eta,
            proj_y=delta_projection(1.0),
        )
    rnd = jax.jit(rnd)

    def global_loss(x, y):
        per = jax.vmap(loss, in_axes=(None, None, 0))(x, y, data)
        return jnp.mean(per)

    gl = jax.jit(global_loss)
    t0 = time.time()
    for t in range(args.rounds):
        params, delta = rnd(params, delta, data)
        if t % args.log_every == 0 or t == args.rounds - 1:
            lv = float(gl(params, delta))
            dn = float(jnp.linalg.norm(delta["delta"]))
            print(f"[round {t:4d}] loss={lv:.4f} |delta|={dn:.4f} "
                  f"({time.time()-t0:.1f}s)", flush=True)
        if args.ckpt_dir and (t + 1) % 50 == 0:
            save_checkpoint(args.ckpt_dir, t + 1, {"x": params, "y": delta})
    print("done.")


if __name__ == "__main__":
    main()
