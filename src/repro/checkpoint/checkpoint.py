"""Minimal pytree checkpointing (npz-based, dependency-free).

Layout: <dir>/ckpt_<step>.npz holding flattened leaves plus a treedef pickle.
Good enough for the single-host examples; on a real cluster this would be
swapped for tensorstore/orbax behind the same three functions.
"""
from __future__ import annotations

import io
import os
import pickle
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np

Pytree = Any


def save_checkpoint(directory: str, step: int, tree: Pytree) -> str:
    os.makedirs(directory, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    buf = io.BytesIO()
    np.savez(buf, treedef=np.frombuffer(pickle.dumps(treedef), dtype=np.uint8), **arrays)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(buf.getvalue())
    os.replace(tmp, path)  # atomic publish
    return path


def restore_checkpoint(path: str) -> Pytree:
    with np.load(path) as z:
        treedef = pickle.loads(z["treedef"].tobytes())
        n = len([k for k in z.files if k.startswith("leaf_")])
        leaves = [z[f"leaf_{i}"] for i in range(n)]
    return jax.tree.unflatten(treedef, leaves)


def latest_checkpoint(directory: str) -> Optional[Tuple[int, str]]:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"ckpt_(\d+)\.npz", name)
        if m:
            step = int(m.group(1))
            if best is None or step > best[0]:
                best = (step, os.path.join(directory, name))
    return best
