"""Client-population model: who is available each round, and how slow.

The paper's Algorithm 2 assumes all m agents participate synchronously in
every round.  The production north star does not: agents join, leave and
lag between rounds (elastic per-pod placement — ROADMAP).  This module
owns the POPULATION side of that story as data:

  * `AvailabilityProcess` — a deterministic, seedable process emitting a
    [num_rounds, m] boolean availability matrix: `AlwaysOn` (the paper's
    setting), `BernoulliAvailability` (i.i.d. dropout), `MarkovChurn`
    (per-agent join/leave chain — correlated absences, the hard case for
    tracking state), `DiurnalAvailability` (time-of-day participation
    waves) and `FixedSizeSampling` (exactly-S uniform subsets — the draw
    `fed.strategies.PartialParticipation` delegates to, so there is ONE
    owner of active-set sampling logic);
  * `StragglerModel` — per-agent-round local-step budgets capping how
    many of the K local steps a slow agent completes before the server
    aggregates: `NoStragglers`, `UniformStragglers` (random slowdowns),
    `DeterministicLag` (a fixed slow cohort);
  * `Population` — the registry combining m, an availability process and
    a straggler model, with a `min_active` floor guaranteeing the server
    never faces an empty round.  `Population.schedule(...)` materializes
    a `repro.sim.schedule.RoundSchedule`.

Everything here is pure data + jax PRNG: the same (population, seed)
pair yields the identical schedule on every runtime (the sync
`FederatedRunner`, the per-shard `AsyncFederatedRunner`, a benchmark
process), which is what makes churn a reproducible benchmark axis
instead of an accident of the run.

Two scaling regimes coexist (million-agent ROADMAP item):

  * chunked — every process draws each round from a PER-ROUND fold of
    its key (`sample_rounds`), so a `[t0, t1)` block is bit-identical to
    the same rows of the full materialization and
    `repro.sim.schedule.ChunkedRoundSchedule` can generate rounds lazily
    in O(chunk * m) memory;
  * sparse — a `SparseAvailability` process emits the ACTIVE ID LIST of
    a round directly in O(active) work (`sample_active_ids`), never
    touching an [m] row; `UniformActiveSubset` is the huge-m counterpart
    of `FixedSizeSampling` (whose permutation draw is O(m)).

`PodMap` partitions agents into contiguous pods for the two-level
agent -> pod -> server aggregation tree; `Population.pods` opts a
population into it.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------- shared samplers
# one owner for both, in the core layer (below repro.fed AND repro.sim):
# `fixed_size_mask` is the draw PartialParticipation and FixedSizeSampling
# share; `renormalized_weights` is the membership-aware server weighting
from ..core.engine import fixed_size_mask, renormalized_weights  # noqa: F401,E402


def _round_keys(key: jax.Array, num_rounds: int) -> jax.Array:
    """One independent key per round, by fold — stable under changes to
    how many draws any single round consumes."""
    return _round_keys_window(key, 0, num_rounds)


def _round_keys_window(key: jax.Array, t0: int, t1: int) -> jax.Array:
    """Per-round keys for the half-open window [t0, t1).  Folding the
    ABSOLUTE round index is what makes chunked generation bit-identical
    to a full materialization: row t's key never depends on where the
    chunk boundaries fall."""
    return jax.vmap(lambda t: jax.random.fold_in(key, t))(
        jnp.arange(t0, t1)
    )


# ------------------------------------------------------ availability processes
class AvailabilityProcess:
    """Base: emit the availability matrix for one run.

    The primitive is `sample_rounds(key, m, t0, t1, carry)` — the rows
    for the half-open round window [t0, t1), each drawn from a
    PER-ROUND fold of `key`, plus the carry a stateful process (Markov
    chains) threads between consecutive windows.  Chunk-invariance
    contract: splitting [0, T) into consecutive windows and threading
    the carry yields bit-identical rows to one full-range call, which
    is what lets `ChunkedRoundSchedule` stream a schedule without ever
    holding [T, m].  `sample` is the dense convenience wrapper.
    """

    def sample_rounds(self, key, m: int, t0: int, t1: int, carry=None):
        """Rows for rounds [t0, t1) -> ([t1 - t0, m] bool, carry')."""
        raise NotImplementedError

    def sample(self, key: jax.Array, m: int, num_rounds: int) -> jax.Array:
        rows, _ = self.sample_rounds(key, m, 0, num_rounds, None)
        return rows


class SparseAvailability(AvailabilityProcess):
    """Marker base for processes that can emit a round's ACTIVE ID LIST
    directly in O(active) work — the representation `SparseRoundSchedule`
    streams for populations too large to touch [m] rows.  Stateless per
    round by contract (each round is a pure function of (key, m, t))."""

    def sample_active_ids(self, key, m: int, t: int) -> "np.ndarray":
        """Sorted unique int64 ids of the agents active in round t."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class AlwaysOn(AvailabilityProcess):
    """Full synchronous participation — the paper's Assumption setting.
    The degenerate process: a schedule built from it is detected as
    static-full and the runners take their bitwise-pinned legacy path."""

    def sample_rounds(self, key, m, t0, t1, carry=None):
        del key
        return jnp.ones((t1 - t0, m), bool), carry


@dataclasses.dataclass(frozen=True)
class BernoulliAvailability(AvailabilityProcess):
    """i.i.d. per-agent-round dropout: active with probability `p`.
    Memoryless — the textbook partial-participation model (SAGDA, Sharma
    et al. 2022 analyze exactly this regime)."""

    p: float = 0.9

    def sample_rounds(self, key, m, t0, t1, carry=None):
        keys = _round_keys_window(key, t0, t1)
        rows = jax.vmap(
            lambda rk: jax.random.bernoulli(rk, self.p, (m,))
        )(keys)
        return rows, carry


@dataclasses.dataclass(frozen=True)
class MarkovChurn(AvailabilityProcess):
    """Per-agent two-state join/leave chain: an active agent leaves with
    `p_leave`, an inactive one (re)joins with `p_join`.  Absences are
    CORRELATED across rounds (an agent that left stays gone for
    ~1/p_join rounds), which is what makes naive tracking state stale —
    the case the elastic aggregator's rebase exists for.  Stationary
    active fraction: p_join / (p_join + p_leave).

    The only stateful process: its carry is the [m] chain state after
    the last emitted round, threaded between chunks so a windowed scan
    continues the same trajectory bit-for-bit."""

    p_leave: float = 0.2
    p_join: float = 0.6
    start_active: float = 1.0

    def sample_rounds(self, key, m, t0, t1, carry=None):
        k0, kt = jax.random.split(key)
        if carry is None:
            if t0 != 0:
                raise ValueError(
                    "MarkovChurn is stateful: windows starting at "
                    f"t0={t0} > 0 need the carry from the previous "
                    "window (thread the second return value)"
                )
            carry = jax.random.bernoulli(k0, self.start_active, (m,))

        def step(s, rk):
            u = jax.random.uniform(rk, (m,))
            s1 = jnp.where(s, u >= self.p_leave, u < self.p_join)
            return s1, s1

        s_end, trace = jax.lax.scan(
            step, carry, _round_keys_window(kt, t0, t1)
        )
        return trace, s_end


@dataclasses.dataclass(frozen=True)
class DiurnalAvailability(AvailabilityProcess):
    """Participation probability oscillating between `low` and `high`
    with `period` rounds per cycle (time-of-day waves over a fleet):
    p_t = low + (high-low) * (1 + cos(2 pi t / period + phase)) / 2."""

    period: int = 100
    low: float = 0.3
    high: float = 1.0
    phase: float = 0.0

    def sample_rounds(self, key, m, t0, t1, carry=None):
        t = jnp.arange(t0, t1)
        p = self.low + (self.high - self.low) * 0.5 * (
            1.0 + jnp.cos(2.0 * jnp.pi * t / self.period + self.phase)
        )
        u = jax.vmap(
            lambda rk: jax.random.uniform(rk, (m,))
        )(_round_keys_window(key, t0, t1))
        return u < p[:, None], carry


@dataclasses.dataclass(frozen=True)
class FixedSizeSampling(AvailabilityProcess):
    """Exactly S = max(1, round(participation * m)) uniformly sampled
    agents per round — `PartialParticipation`'s draw expressed as a
    degenerate population process (i.i.d. across rounds, no churn
    memory).  Both call `fixed_size_mask`, so the active-set logic has
    one owner.  The permutation draw is O(m) per round — for huge
    populations use `UniformActiveSubset` instead."""

    participation: float = 0.5

    def subset_size(self, m: int) -> int:
        return max(1, int(round(self.participation * m)))

    def sample_rounds(self, key, m, t0, t1, carry=None):
        size = self.subset_size(m)
        if size >= m:
            return jnp.ones((t1 - t0, m), bool), carry
        rows = jax.vmap(lambda rk: fixed_size_mask(rk, m, size))(
            _round_keys_window(key, t0, t1)
        )
        return rows, carry


@dataclasses.dataclass(frozen=True)
class UniformActiveSubset(SparseAvailability):
    """Exactly `size` uniformly sampled agents per round, drawn in
    O(size) work and memory — the sparse counterpart of
    `FixedSizeSampling` for populations where even one [m] row is too
    big.  Draw: rejection sampling of uniform ids, deduplicated in draw
    order, with the attempt counter folded into the round key so the
    result is a pure function of (key, m, t)."""

    size: int = 256

    def sample_active_ids(self, key, m, t):
        if self.size >= m:
            return np.arange(m, dtype=np.int64)
        kt = jax.random.fold_in(key, t)
        seen: dict = {}
        attempt = 0
        # oversample ~2x per attempt; for size << m one attempt almost
        # always suffices (collision probability ~ size^2 / m)
        block = max(2 * self.size, 64)
        while len(seen) < self.size:
            ka = jax.random.fold_in(kt, attempt)
            draw = np.asarray(
                jax.random.randint(ka, (block,), 0, m, jnp.int64)
            )
            for i in draw:
                seen.setdefault(int(i), None)
                if len(seen) >= self.size:
                    break
            attempt += 1
        ids = np.fromiter(seen.keys(), np.int64, self.size)
        ids.sort()
        return ids

    def sample_rounds(self, key, m, t0, t1, carry=None):
        # dense materialization (small-m parity tests only): one row
        # per round, scattered from the sparse draw so dense == sparse
        # by construction
        rows = np.zeros((t1 - t0, m), bool)
        for i, t in enumerate(range(t0, t1)):
            rows[i, self.sample_active_ids(key, m, t)] = True
        return jnp.asarray(rows), carry


# ----------------------------------------------------------- straggler models
class StragglerModel:
    """Base: per-agent-round local-step budgets in [0, K].  The schedule
    builder zeroes budgets of inactive agents and floors active agents
    at 1 step, so models only decide how SLOW an active agent is.

    Like availability, the primitive is windowed (`budgets_rounds`, one
    key fold per absolute round) so chunked generation is bit-identical
    to dense; `budgets_for_ids` is the O(active) variant for sparse
    events — a pure function of (key, t, global id), so the same agent
    gets the same budget however the round is represented, and
    `SparseRoundSchedule.densify()` (which scatters these exact values)
    is self-consistent by construction."""

    def budgets_rounds(
        self, key, active, t0: int, num_local_steps: int
    ):
        """Budgets for rounds [t0, t0 + active.shape[0]) -> [c, m] int32."""
        raise NotImplementedError

    def budgets(self, key: jax.Array, active: jax.Array, num_local_steps: int):
        return self.budgets_rounds(key, active, 0, num_local_steps)

    def budgets_for_ids(self, key, ids, t: int, num_local_steps: int):
        """Budgets for the global agent `ids` of round t -> [n] int32.
        Base: no stragglers — full budget."""
        return np.full(len(ids), num_local_steps, np.int32)


@dataclasses.dataclass(frozen=True)
class NoStragglers(StragglerModel):
    """Every active agent completes all K local steps."""

    def budgets_rounds(self, key, active, t0, num_local_steps):
        del key
        return jnp.full(active.shape, num_local_steps, jnp.int32)


@dataclasses.dataclass(frozen=True)
class UniformStragglers(StragglerModel):
    """With probability `p_straggle` an agent-round is slow and completes
    a uniform number of steps in [ceil(min_frac * K), K]; otherwise all
    K."""

    p_straggle: float = 0.5
    min_frac: float = 0.25

    def _row(self, kt, m, num_local_steps):
        k_sel, k_cnt = jax.random.split(kt)
        lo = max(1, int(-(-self.min_frac * num_local_steps // 1)))
        slow = jax.random.bernoulli(k_sel, self.p_straggle, (m,))
        b = jax.random.randint(k_cnt, (m,), lo, num_local_steps + 1, jnp.int32)
        return jnp.where(slow, b, num_local_steps).astype(jnp.int32)

    def budgets_rounds(self, key, active, t0, num_local_steps):
        c, m = active.shape
        return jax.vmap(lambda kt: self._row(kt, m, num_local_steps))(
            _round_keys_window(key, t0, t0 + c)
        )

    def budgets_for_ids(self, key, ids, t, num_local_steps):
        # O(n): one (round, global-id) fold per active agent — same
        # distribution as the dense row, stable under any active-set
        # representation of the same round
        kt = jax.random.fold_in(key, t)
        k_sel, k_cnt = jax.random.split(kt)
        lo = max(1, int(-(-self.min_frac * num_local_steps // 1)))
        ids = jnp.asarray(ids, jnp.int64)
        sel_keys = jax.vmap(lambda i: jax.random.fold_in(k_sel, i))(ids)
        cnt_keys = jax.vmap(lambda i: jax.random.fold_in(k_cnt, i))(ids)
        slow = jax.vmap(lambda k: jax.random.bernoulli(k, self.p_straggle))(
            sel_keys
        )
        b = jax.vmap(
            lambda k: jax.random.randint(k, (), lo, num_local_steps + 1)
        )(cnt_keys)
        out = jnp.where(slow, b, num_local_steps).astype(jnp.int32)
        return np.asarray(out)


@dataclasses.dataclass(frozen=True)
class DeterministicLag(StragglerModel):
    """A fixed slow cohort: every `slow_every`-th agent completes only
    ceil(budget_frac * K) steps, every round.  Deterministic — for tests
    that need to know exactly who lagged."""

    slow_every: int = 4
    budget_frac: float = 0.25

    def _slow_budget(self, num_local_steps):
        return max(1, int(-(-self.budget_frac * num_local_steps // 1)))

    def budgets_rounds(self, key, active, t0, num_local_steps):
        del key
        m = active.shape[-1]
        slow = (jnp.arange(m) % self.slow_every) == 0
        b = self._slow_budget(num_local_steps)
        return jnp.where(slow[None, :], b, num_local_steps).astype(jnp.int32)

    def budgets_for_ids(self, key, ids, t, num_local_steps):
        del key
        ids = np.asarray(ids)
        slow = (ids % self.slow_every) == 0
        b = self._slow_budget(num_local_steps)
        return np.where(slow, b, num_local_steps).astype(np.int32)


# -------------------------------------------------------------------- pods
@dataclasses.dataclass(frozen=True)
class PodMap:
    """Contiguous partition of the m agents into `num_pods` pods — level
    one of the two-level agent -> pod -> server aggregation tree.  Agent
    i belongs to pod i // pod_size; the last pod may be short.  The map
    is pure arithmetic (no [m] table), so pod routing stays O(active)
    however large the population."""

    m: int
    num_pods: int

    def __post_init__(self):
        if not 1 <= self.num_pods <= self.m:
            raise ValueError(
                f"num_pods must be in [1, m={self.m}], got {self.num_pods}"
            )

    @property
    def pod_size(self) -> int:
        return -(-self.m // self.num_pods)  # ceil

    def pod_of(self, ids):
        """Pod index of each agent id (numpy or jax arrays alike)."""
        return ids // self.pod_size

    def live_pods(self, ids) -> np.ndarray:
        """Sorted unique pods with at least one of `ids` — the pods that
        send a partial payload this round."""
        return np.unique(np.asarray(self.pod_of(np.asarray(ids))))

    def agents_of(self, pod: int) -> np.ndarray:
        lo = pod * self.pod_size
        return np.arange(lo, min(lo + self.pod_size, self.m), dtype=np.int64)


# ---------------------------------------------------------------- population
@dataclasses.dataclass(frozen=True)
class Population:
    """The client registry: m agents, an availability process and a
    straggler model.  `min_active` is the server's liveness floor — a
    round the process left empty gets that many agents force-activated
    (deterministically from the schedule's own key stream), so the
    aggregate is always over a nonempty set.  `pods > 0` opts the
    population into the two-level aggregation tree (`pod_map()`); 0
    means flat agent -> server aggregation."""

    m: int
    availability: AvailabilityProcess = AlwaysOn()
    stragglers: StragglerModel = NoStragglers()
    min_active: int = 1
    pods: int = 0

    def __post_init__(self):
        if self.m < 1:
            raise ValueError(f"population needs m >= 1, got {self.m}")
        if not 1 <= self.min_active <= self.m:
            raise ValueError(
                f"min_active must be in [1, m={self.m}], got {self.min_active}"
            )
        if self.pods and not 1 <= self.pods <= self.m:
            raise ValueError(
                f"pods must be 0 (flat) or in [1, m={self.m}], got {self.pods}"
            )

    def pod_map(self) -> PodMap | None:
        return PodMap(self.m, self.pods) if self.pods else None

    @property
    def supports_sparse(self) -> bool:
        return isinstance(self.availability, SparseAvailability)

    def schedule(self, seed: int, num_rounds: int, num_local_steps: int):
        """Materialize the per-round active sets + step budgets for one
        run (see `repro.sim.schedule.RoundSchedule`)."""
        from .schedule import RoundSchedule

        return RoundSchedule.build(self, seed, num_rounds, num_local_steps)

    def chunked_schedule(
        self, seed: int, num_rounds: int, num_local_steps: int, *,
        chunk_rounds: int = 128,
    ):
        """Lazy schedule generating [chunk_rounds, m] blocks on demand —
        bit-identical rounds to `schedule(...)`, O(chunk * m) memory."""
        from .schedule import ChunkedRoundSchedule

        return ChunkedRoundSchedule(
            self, seed, num_rounds, num_local_steps,
            chunk_rounds=chunk_rounds,
        )

    def sparse_schedule(self, seed: int, num_rounds: int, num_local_steps: int):
        """O(active)-per-round schedule of `SparseRoundEvent`s; requires
        a `SparseAvailability` process (e.g. `UniformActiveSubset`)."""
        from .schedule import SparseRoundSchedule

        if not self.supports_sparse:
            raise TypeError(
                "sparse schedules need a SparseAvailability process, got "
                f"{type(self.availability).__name__}"
            )
        return SparseRoundSchedule(self, seed, num_rounds, num_local_steps)
