"""Client-population model: who is available each round, and how slow.

The paper's Algorithm 2 assumes all m agents participate synchronously in
every round.  The production north star does not: agents join, leave and
lag between rounds (elastic per-pod placement — ROADMAP).  This module
owns the POPULATION side of that story as data:

  * `AvailabilityProcess` — a deterministic, seedable process emitting a
    [num_rounds, m] boolean availability matrix: `AlwaysOn` (the paper's
    setting), `BernoulliAvailability` (i.i.d. dropout), `MarkovChurn`
    (per-agent join/leave chain — correlated absences, the hard case for
    tracking state), `DiurnalAvailability` (time-of-day participation
    waves) and `FixedSizeSampling` (exactly-S uniform subsets — the draw
    `fed.strategies.PartialParticipation` delegates to, so there is ONE
    owner of active-set sampling logic);
  * `StragglerModel` — per-agent-round local-step budgets capping how
    many of the K local steps a slow agent completes before the server
    aggregates: `NoStragglers`, `UniformStragglers` (random slowdowns),
    `DeterministicLag` (a fixed slow cohort);
  * `Population` — the registry combining m, an availability process and
    a straggler model, with a `min_active` floor guaranteeing the server
    never faces an empty round.  `Population.schedule(...)` materializes
    a `repro.sim.schedule.RoundSchedule`.

Everything here is pure data + jax PRNG: the same (population, seed)
pair yields the identical schedule on every runtime (the sync
`FederatedRunner`, the per-shard `AsyncFederatedRunner`, a benchmark
process), which is what makes churn a reproducible benchmark axis
instead of an accident of the run.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


# --------------------------------------------------------- shared samplers
# one owner for both, in the core layer (below repro.fed AND repro.sim):
# `fixed_size_mask` is the draw PartialParticipation and FixedSizeSampling
# share; `renormalized_weights` is the membership-aware server weighting
from ..core.engine import fixed_size_mask, renormalized_weights  # noqa: F401,E402


def _round_keys(key: jax.Array, num_rounds: int) -> jax.Array:
    """One independent key per round, by fold — stable under changes to
    how many draws any single round consumes."""
    return jax.vmap(lambda t: jax.random.fold_in(key, t))(
        jnp.arange(num_rounds)
    )


# ------------------------------------------------------ availability processes
class AvailabilityProcess:
    """Base: emit the [num_rounds, m] availability matrix for one run."""

    def sample(self, key: jax.Array, m: int, num_rounds: int) -> jax.Array:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class AlwaysOn(AvailabilityProcess):
    """Full synchronous participation — the paper's Assumption setting.
    The degenerate process: a schedule built from it is detected as
    static-full and the runners take their bitwise-pinned legacy path."""

    def sample(self, key, m, num_rounds):
        del key
        return jnp.ones((num_rounds, m), bool)


@dataclasses.dataclass(frozen=True)
class BernoulliAvailability(AvailabilityProcess):
    """i.i.d. per-agent-round dropout: active with probability `p`.
    Memoryless — the textbook partial-participation model (SAGDA, Sharma
    et al. 2022 analyze exactly this regime)."""

    p: float = 0.9

    def sample(self, key, m, num_rounds):
        return jax.random.bernoulli(key, self.p, (num_rounds, m))


@dataclasses.dataclass(frozen=True)
class MarkovChurn(AvailabilityProcess):
    """Per-agent two-state join/leave chain: an active agent leaves with
    `p_leave`, an inactive one (re)joins with `p_join`.  Absences are
    CORRELATED across rounds (an agent that left stays gone for
    ~1/p_join rounds), which is what makes naive tracking state stale —
    the case the elastic aggregator's rebase exists for.  Stationary
    active fraction: p_join / (p_join + p_leave)."""

    p_leave: float = 0.2
    p_join: float = 0.6
    start_active: float = 1.0

    def sample(self, key, m, num_rounds):
        k0, kt = jax.random.split(key)
        s0 = jax.random.bernoulli(k0, self.start_active, (m,))

        def step(s, rk):
            u = jax.random.uniform(rk, (m,))
            s1 = jnp.where(s, u >= self.p_leave, u < self.p_join)
            return s1, s1

        _, trace = jax.lax.scan(step, s0, _round_keys(kt, num_rounds))
        return trace


@dataclasses.dataclass(frozen=True)
class DiurnalAvailability(AvailabilityProcess):
    """Participation probability oscillating between `low` and `high`
    with `period` rounds per cycle (time-of-day waves over a fleet):
    p_t = low + (high-low) * (1 + cos(2 pi t / period + phase)) / 2."""

    period: int = 100
    low: float = 0.3
    high: float = 1.0
    phase: float = 0.0

    def sample(self, key, m, num_rounds):
        t = jnp.arange(num_rounds)
        p = self.low + (self.high - self.low) * 0.5 * (
            1.0 + jnp.cos(2.0 * jnp.pi * t / self.period + self.phase)
        )
        u = jax.random.uniform(key, (num_rounds, m))
        return u < p[:, None]


@dataclasses.dataclass(frozen=True)
class FixedSizeSampling(AvailabilityProcess):
    """Exactly S = max(1, round(participation * m)) uniformly sampled
    agents per round — `PartialParticipation`'s draw expressed as a
    degenerate population process (i.i.d. across rounds, no churn
    memory).  Both call `fixed_size_mask`, so the active-set logic has
    one owner."""

    participation: float = 0.5

    def subset_size(self, m: int) -> int:
        return max(1, int(round(self.participation * m)))

    def sample(self, key, m, num_rounds):
        size = self.subset_size(m)
        if size >= m:
            return jnp.ones((num_rounds, m), bool)
        return jax.vmap(lambda rk: fixed_size_mask(rk, m, size))(
            _round_keys(key, num_rounds)
        )


# ----------------------------------------------------------- straggler models
class StragglerModel:
    """Base: per-agent-round local-step budgets in [0, K].  The schedule
    builder zeroes budgets of inactive agents and floors active agents
    at 1 step, so models only decide how SLOW an active agent is."""

    def budgets(self, key: jax.Array, active: jax.Array, num_local_steps: int):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class NoStragglers(StragglerModel):
    """Every active agent completes all K local steps."""

    def budgets(self, key, active, num_local_steps):
        del key
        return jnp.full(active.shape, num_local_steps, jnp.int32)


@dataclasses.dataclass(frozen=True)
class UniformStragglers(StragglerModel):
    """With probability `p_straggle` an agent-round is slow and completes
    a uniform number of steps in [ceil(min_frac * K), K]; otherwise all
    K."""

    p_straggle: float = 0.5
    min_frac: float = 0.25

    def budgets(self, key, active, num_local_steps):
        k_sel, k_cnt = jax.random.split(key)
        lo = max(1, int(-(-self.min_frac * num_local_steps // 1)))
        slow = jax.random.bernoulli(k_sel, self.p_straggle, active.shape)
        b = jax.random.randint(
            k_cnt, active.shape, lo, num_local_steps + 1, jnp.int32
        )
        return jnp.where(slow, b, num_local_steps).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class DeterministicLag(StragglerModel):
    """A fixed slow cohort: every `slow_every`-th agent completes only
    ceil(budget_frac * K) steps, every round.  Deterministic — for tests
    that need to know exactly who lagged."""

    slow_every: int = 4
    budget_frac: float = 0.25

    def budgets(self, key, active, num_local_steps):
        del key
        m = active.shape[-1]
        slow = (jnp.arange(m) % self.slow_every) == 0
        b = max(1, int(-(-self.budget_frac * num_local_steps // 1)))
        return jnp.where(slow[None, :], b, num_local_steps).astype(jnp.int32)


# ---------------------------------------------------------------- population
@dataclasses.dataclass(frozen=True)
class Population:
    """The client registry: m agents, an availability process and a
    straggler model.  `min_active` is the server's liveness floor — a
    round the process left empty gets that many agents force-activated
    (deterministically from the schedule's own key stream), so the
    aggregate is always over a nonempty set."""

    m: int
    availability: AvailabilityProcess = AlwaysOn()
    stragglers: StragglerModel = NoStragglers()
    min_active: int = 1

    def __post_init__(self):
        if self.m < 1:
            raise ValueError(f"population needs m >= 1, got {self.m}")
        if not 1 <= self.min_active <= self.m:
            raise ValueError(
                f"min_active must be in [1, m={self.m}], got {self.min_active}"
            )

    def schedule(self, seed: int, num_rounds: int, num_local_steps: int):
        """Materialize the per-round active sets + step budgets for one
        run (see `repro.sim.schedule.RoundSchedule`)."""
        from .schedule import RoundSchedule

        return RoundSchedule.build(self, seed, num_rounds, num_local_steps)
