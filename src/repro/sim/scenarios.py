"""Named population scenarios — the benchmark/launch axis for churn.

Each scenario is a `Population` factory keyed by name, so launch
drivers (`repro.launch.train --population`, `repro.launch.dryrun
--population`), `benchmarks/elastic.py` and tests all mean the same
thing by "flaky":

  stable           all m agents, every round, full K budgets — the
                   paper's synchronous setting.  Degenerate by
                   construction: its schedule is static-full, so the
                   runners take their bitwise-pinned legacy path.
  flaky            Markov join/leave churn (correlated multi-round
                   absences, ~3/4 of agents present in stationarity).
                   The headline elastic case: FedGDA-GT with tracker
                   rebasing keeps its exact limit here; the naive
                   no-rebase server stalls (benchmarks/elastic.py).
  diurnal          participation waves between ~40% and 100% with a
                   50-round period — fleet-wide time-of-day rhythms.
  straggler_heavy  nearly everyone shows up (5% dropout) but 60% of
                   agent-rounds are stragglers completing a uniform
                   1/4..all of their K local steps.
  mega             the million-agent preset: m = 1e6 registered agents,
                   a uniform 256-agent active subset per round
                   (`UniformActiveSubset` — a `SparseAvailability`, so
                   only `sparse_schedule` applies; densifying is an
                   error at this scale), light stragglers, and 1024
                   pods for the two-level aggregation tree.  Runs in
                   O(active + pods) host memory through
                   `sim.sparse.SparseElasticEngine`
                   (benchmarks/elastic.py --population mega gates the
                   memory claim).  The m argument is IGNORED — the
                   scenario pins its own scale.
"""
from __future__ import annotations

from typing import Callable, Dict

from .population import (
    AlwaysOn,
    BernoulliAvailability,
    DiurnalAvailability,
    MarkovChurn,
    NoStragglers,
    Population,
    UniformActiveSubset,
    UniformStragglers,
)

#: the mega preset's pinned scale (the m argument is ignored)
MEGA_AGENTS = 1_000_000
MEGA_ACTIVE = 256
MEGA_PODS = 1024

SCENARIOS: Dict[str, Callable[[int], Population]] = {
    "stable": lambda m: Population(m, AlwaysOn(), NoStragglers()),
    "flaky": lambda m: Population(
        m, MarkovChurn(p_leave=0.2, p_join=0.6), NoStragglers()
    ),
    "diurnal": lambda m: Population(
        m,
        DiurnalAvailability(period=50, low=0.4, high=1.0),
        NoStragglers(),
    ),
    "straggler_heavy": lambda m: Population(
        m,
        BernoulliAvailability(p=0.95),
        UniformStragglers(p_straggle=0.6, min_frac=0.25),
    ),
    "mega": lambda m: Population(
        MEGA_AGENTS,
        UniformActiveSubset(size=MEGA_ACTIVE),
        UniformStragglers(p_straggle=0.3, min_frac=0.5),
        pods=MEGA_PODS,
    ),
}


def make_population(name: str, m: int) -> Population:
    """Resolve a scenario name to a Population of m agents."""
    try:
        factory = SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"unknown population scenario {name!r}; "
            f"known: {sorted(SCENARIOS)}"
        ) from None
    return factory(m)
