"""Client-population simulation: churn, stragglers, elastic rounds.

The paper's FedGDA-GT assumes all m agents participate synchronously in
every round; this package owns what production does not guarantee —
agents that join, leave and lag — as a deterministic, seedable
subsystem:

  population  availability processes (Bernoulli dropout, Markov churn,
              diurnal waves, fixed-size sampling) + straggler models +
              the `Population` registry
  schedule    `RoundSchedule`: materialized per-round active sets and
              local-step budgets, from a DEDICATED fold of the run seed
              (sync and async runtimes consume identical membership)
  elastic     `ElasticAggregator` (re-normalized weights, tracker/EF
              rebase) and `make_elastic_round` (the membership-aware
              round over the engine's phases)
  scenarios   named presets: stable / flaky / diurnal / straggler_heavy
"""
from .elastic import (
    ElasticAggregator,
    init_tracker,
    make_elastic_round,
    schedule_bytes,
    tracker_exchange,
)
from .population import (
    AlwaysOn,
    AvailabilityProcess,
    BernoulliAvailability,
    DeterministicLag,
    DiurnalAvailability,
    FixedSizeSampling,
    MarkovChurn,
    NoStragglers,
    Population,
    StragglerModel,
    UniformStragglers,
    fixed_size_mask,
    renormalized_weights,
)
from .scenarios import SCENARIOS, make_population
from .schedule import (
    AVAILABILITY_STREAM,
    RoundEvent,
    RoundSchedule,
    availability_key,
)

__all__ = [
    "AVAILABILITY_STREAM",
    "AlwaysOn",
    "AvailabilityProcess",
    "BernoulliAvailability",
    "DeterministicLag",
    "DiurnalAvailability",
    "ElasticAggregator",
    "FixedSizeSampling",
    "MarkovChurn",
    "NoStragglers",
    "Population",
    "RoundEvent",
    "RoundSchedule",
    "SCENARIOS",
    "StragglerModel",
    "UniformStragglers",
    "availability_key",
    "fixed_size_mask",
    "init_tracker",
    "make_elastic_round",
    "make_population",
    "renormalized_weights",
    "schedule_bytes",
    "tracker_exchange",
]
