"""Client-population simulation: churn, stragglers, elastic rounds.

The paper's FedGDA-GT assumes all m agents participate synchronously in
every round; this package owns what production does not guarantee —
agents that join, leave and lag — as a deterministic, seedable
subsystem:

  population  availability processes (Bernoulli dropout, Markov churn,
              diurnal waves, fixed-size sampling, sparse uniform
              subsets) + straggler models + the `Population` registry
              and the `PodMap` agent -> pod assignment
  schedule    `RoundSchedule` (materialized [T, m]),
              `ChunkedRoundSchedule` (lazy windows — same rounds
              bitwise, O(chunk) resident) and `SparseRoundSchedule`
              (O(active) id lists for `SparseAvailability` processes),
              all from a DEDICATED fold of the run seed (sync, async
              and sparse runtimes consume identical membership)
  elastic     `ElasticAggregator` (re-normalized weights, tracker/EF
              rebase) and `make_elastic_round` (the membership-aware
              round over the engine's phases)
  sparse      `SparseElasticEngine`: the O(active) driver — running-sum
              tracker, id-keyed EF/noise rows, optional two-level pod
              aggregation; dense-fallback-pinned bitwise for small m
  scenarios   named presets: stable / flaky / diurnal /
              straggler_heavy / mega (1e6 agents, 256 active, 1024
              pods)
"""
from .elastic import (
    ElasticAggregator,
    init_tracker,
    make_elastic_round,
    per_agent_bytes,
    schedule_bytes,
    tracker_exchange,
)
from .population import (
    AlwaysOn,
    AvailabilityProcess,
    BernoulliAvailability,
    DeterministicLag,
    DiurnalAvailability,
    FixedSizeSampling,
    MarkovChurn,
    NoStragglers,
    PodMap,
    Population,
    SparseAvailability,
    StragglerModel,
    UniformActiveSubset,
    UniformStragglers,
    fixed_size_mask,
    renormalized_weights,
)
from .scenarios import SCENARIOS, make_population
from .schedule import (
    AVAILABILITY_STREAM,
    ChunkedRoundSchedule,
    RoundEvent,
    RoundSchedule,
    SparseRoundEvent,
    SparseRoundSchedule,
    availability_key,
)
from .sparse import (
    AgentDataSource,
    ArrayDataSource,
    SparseElasticEngine,
    SparseTracker,
    SyntheticDataSource,
)

__all__ = [
    "AVAILABILITY_STREAM",
    "AgentDataSource",
    "AlwaysOn",
    "ArrayDataSource",
    "AvailabilityProcess",
    "BernoulliAvailability",
    "ChunkedRoundSchedule",
    "DeterministicLag",
    "DiurnalAvailability",
    "ElasticAggregator",
    "FixedSizeSampling",
    "MarkovChurn",
    "NoStragglers",
    "PodMap",
    "Population",
    "RoundEvent",
    "RoundSchedule",
    "SCENARIOS",
    "SparseAvailability",
    "SparseElasticEngine",
    "SparseRoundEvent",
    "SparseRoundSchedule",
    "SparseTracker",
    "StragglerModel",
    "SyntheticDataSource",
    "UniformActiveSubset",
    "UniformStragglers",
    "availability_key",
    "fixed_size_mask",
    "init_tracker",
    "make_elastic_round",
    "make_population",
    "per_agent_bytes",
    "renormalized_weights",
    "schedule_bytes",
    "tracker_exchange",
]
