"""RoundSchedule: the per-round (active set, step budgets) of one run.

A schedule is built ONCE from (population, seed, num_rounds, K) and then
consumed by whichever runtime executes the run — the sync
`fed.runtime.FederatedRunner`, the per-shard
`fed.async_runtime.AsyncFederatedRunner`, or a benchmark loop.  Because
the availability RNG stream is a DEDICATED fold of the run seed
(`availability_key`), the schedule depends only on the population config
and the seed: it cannot drift when some other consumer of the run seed
(model init, data synthesis, a strategy's rounding RNG) changes how many
draws it takes, and sync and async runtimes consume bit-identical active
sets for the same config (tests/test_population.py pins this).

Three representations share the same event contract (million-agent
ROADMAP item — memory must scale with the ACTIVE set, not m):

  * `RoundSchedule` — the dense [T, m] materialization, host-side numpy.
    Fine for simulation-scale populations; every membership fact is a
    cheap array op.
  * `ChunkedRoundSchedule` — the same rounds bit-for-bit, generated
    lazily in [chunk_rounds, m] blocks from the per-round key folds
    (`AvailabilityProcess.sample_rounds`).  O(chunk * m) resident
    memory; sequential iteration costs one block sample per chunk.
  * `SparseRoundSchedule` — O(active) per round: events carry the active
    ID LIST (`SparseRoundEvent`), never an [m] row.  Requires a
    `SparseAvailability` process; `densify()` scatters it into a dense
    `RoundSchedule` for small-m parity tests.

Per-round statistics (`participation_rate`, `churn_events`,
`summary_trace`) are computed STREAMINGLY by iterating events — one pass,
no [T, m] densification — so reporting works identically for all three.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

#: the dedicated fold of the run seed that the availability stream hangs
#: off.  Any fixed odd constant works; sharing the raw seed with other
#: consumers is the bug this prevents.
AVAILABILITY_STREAM = 0x5E_D0_AC  # "seed-0-active"


def availability_key(seed: int) -> jax.Array:
    """The availability PRNG stream for a run: a dedicated fold of the
    run seed, so schedules are a pure function of (population, seed)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), AVAILABILITY_STREAM)


@dataclasses.dataclass(frozen=True)
class RoundEvent:
    """One round's membership facts, as the runners consume them."""

    index: int
    active: np.ndarray    # [m] bool — who participates this round
    budgets: np.ndarray   # [m] int32 — local-step cap (0 where inactive)
    joined: np.ndarray    # [m] bool — newly active vs the previous round
    departed: np.ndarray  # [m] bool — newly absent vs the previous round
    full: bool            # all active with their full K budget

    @property
    def num_active(self) -> int:
        return int(self.active.sum())

    @property
    def churned(self) -> bool:
        return bool(self.joined.any() or self.departed.any())

    @property
    def active_ids(self) -> np.ndarray:
        """Sorted global ids of this round's active agents — the
        representation-independent view shared with `SparseRoundEvent`."""
        return np.nonzero(self.active)[0].astype(np.int64)


@dataclasses.dataclass(frozen=True)
class SparseRoundEvent:
    """One round's membership facts in O(active): the sorted active id
    list plus per-active budgets, no [m] row anywhere.  `prev_ids` None
    means a fresh start (the dense all-present convention); joins and
    departures then report empty rather than scanning m agents."""

    index: int
    m: int
    active_ids: np.ndarray           # [n] int64, sorted unique
    budgets: np.ndarray              # [n] int32 (>= 1), aligned to active_ids
    prev_ids: Optional[np.ndarray]   # previous round's ids, or None
    full: bool

    @property
    def num_active(self) -> int:
        return len(self.active_ids)

    @property
    def joined_ids(self) -> np.ndarray:
        if self.prev_ids is None:
            return np.empty(0, np.int64)
        return np.setdiff1d(self.active_ids, self.prev_ids)

    @property
    def departed_ids(self) -> np.ndarray:
        if self.prev_ids is None:
            return np.empty(0, np.int64)
        return np.setdiff1d(self.prev_ids, self.active_ids)

    @property
    def churned(self) -> bool:
        return self.prev_ids is not None and not np.array_equal(
            self.active_ids, self.prev_ids
        )

    def to_dense(self, num_local_steps: int) -> RoundEvent:
        """Scatter into the dense event representation (small m only).
        A None `prev_ids` densifies to the all-present convention,
        matching a dense schedule's round 0."""
        active = np.zeros(self.m, bool)
        active[self.active_ids] = True
        budgets = np.zeros(self.m, np.int32)
        budgets[self.active_ids] = self.budgets
        if self.prev_ids is None:
            prev = np.ones(self.m, bool)
        else:
            prev = np.zeros(self.m, bool)
            prev[self.prev_ids] = True
        return RoundEvent(
            index=self.index,
            active=active,
            budgets=budgets,
            joined=active & ~prev,
            departed=prev & ~active,
            full=bool(
                active.all() and (budgets == num_local_steps).all()
            ),
        )


def _event_ids(ev) -> np.ndarray:
    """Representation-independent active-id view of any event type."""
    return ev.active_ids


class ScheduleStats:
    """Streaming per-round statistics, shared by every schedule flavor.

    One pass over events; nothing here materializes [T, m], which is the
    satellite contract that keeps reporting working for chunked and
    sparse schedules.  Requires `__iter__`, `__len__`, `.m`,
    `.num_local_steps`, `.seed` on the concrete class.
    """

    def participation_rate(self) -> float:
        total = 0
        for ev in self:
            total += ev.num_active
        return total / (len(self) * self.m)

    def churn_events(self) -> int:
        """Rounds whose active set differs from the previous round's
        (round 0 never counts — there is no in-schedule predecessor)."""
        count = 0
        prev = None
        for ev in self:
            ids = _event_ids(ev)
            if prev is not None and not np.array_equal(ids, prev):
                count += 1
            prev = ids
        return count

    def summary_trace(self) -> dict:
        """Per-round membership summary without the [T, m] mask:
        active counts, budget totals, and a CRC digest of each round's
        sorted active ids (representation-independent — a dense and a
        sparse schedule of the same rounds digest identically).
        Identical configs must yield identical summaries whatever
        runtime consumes them."""
        n_active = np.zeros(len(self), np.int64)
        budget_total = np.zeros(len(self), np.int64)
        digest = np.zeros(len(self), np.uint32)
        for t, ev in enumerate(self):
            n_active[t] = ev.num_active
            budget_total[t] = int(np.asarray(ev.budgets).sum())
            digest[t] = zlib.crc32(
                np.ascontiguousarray(_event_ids(ev)).tobytes()
            )
        return {
            "num_active": n_active,
            "budget_total": budget_total,
            "active_digest": digest,
            "seed": self.seed,
            "num_local_steps": self.num_local_steps,
        }


class RoundSchedule(ScheduleStats):
    """Iterator over `RoundEvent`s for one run (see module docstring).

    `is_static_full` flags the degenerate all-on/no-straggler schedule:
    runners given one take their unmodified legacy path, which is how
    the full-participation population reproduces the existing runners
    BITWISE (tests/test_elastic.py)."""

    def __init__(
        self,
        active,
        budgets,
        num_local_steps: int,
        seed: int = 0,
        population=None,
        prev_active=None,
    ):
        self.active = np.asarray(active, bool)
        self.budgets = np.asarray(budgets, np.int32)
        #: the active set of the round BEFORE this schedule's first —
        #: None means a fresh start (all-present, the legacy baseline);
        #: `tail()` propagates the true row so round 0 of a resumed
        #: schedule reports joins/departures against what actually ran
        self.prev_active = (
            None if prev_active is None else np.asarray(prev_active, bool)
        )
        if self.active.shape != self.budgets.shape or self.active.ndim != 2:
            raise ValueError(
                f"active {self.active.shape} and budgets "
                f"{self.budgets.shape} must both be [num_rounds, m]"
            )
        if (self.budgets[~self.active] != 0).any():
            raise ValueError("inactive agents must have a zero step budget")
        if (self.budgets[self.active] < 1).any():
            raise ValueError("active agents need a budget of >= 1 steps")
        empty = ~self.active.any(axis=1)
        if empty.any():
            # the weights' "sum to 1 for ANY nonempty active set" contract
            # (and the async runner's shard dispatch) both assume this —
            # an empty round would renormalize 0/0 into NaN iterates
            raise ValueError(
                f"rounds {np.nonzero(empty)[0].tolist()} have no active "
                "agents; every round needs at least one (Population "
                "enforces min_active when building schedules)"
            )
        self.num_local_steps = int(num_local_steps)
        self.seed = int(seed)
        self.population = population

    @classmethod
    def build(
        cls, population, seed: int, num_rounds: int, num_local_steps: int
    ) -> "RoundSchedule":
        m = population.m
        key = availability_key(seed)
        k_avail, k_strag, k_force = jax.random.split(key, 3)
        # one full-range window — the SAME per-round-fold primitives the
        # chunked schedule streams, so chunked == dense is bitwise by
        # construction rather than by luck
        active, _ = population.availability.sample_rounds(
            k_avail, m, 0, num_rounds, None
        )
        active = _force_min_active(
            active, population.min_active, k_force, 0
        )
        budgets = population.stragglers.budgets_rounds(
            k_strag, active, 0, num_local_steps
        )
        budgets = _clamp_budgets(active, budgets, num_local_steps)
        return cls(
            np.asarray(active),
            np.asarray(budgets),
            num_local_steps,
            seed=seed,
            population=population,
        )

    # ------------------------------------------------------------ access
    @property
    def num_rounds(self) -> int:
        return self.active.shape[0]

    @property
    def m(self) -> int:
        return self.active.shape[1]

    @property
    def is_static_full(self) -> bool:
        return bool(
            self.active.all() and (self.budgets == self.num_local_steps).all()
        )

    def __len__(self) -> int:
        return self.num_rounds

    def __getitem__(self, t: int) -> RoundEvent:
        if not 0 <= t < self.num_rounds:
            raise IndexError(t)
        if t > 0:
            prev = self.active[t - 1]
        elif self.prev_active is not None:
            prev = self.prev_active
        else:
            prev = np.ones((self.m,), bool)
        a = self.active[t]
        return RoundEvent(
            index=t,
            active=a,
            budgets=self.budgets[t],
            joined=a & ~prev,
            departed=prev & ~a,
            full=bool(a.all() and (self.budgets[t] == self.num_local_steps).all()),
        )

    def __iter__(self) -> Iterator[RoundEvent]:
        return (self[t] for t in range(self.num_rounds))

    def tail(self, start: int) -> "RoundSchedule":
        """The remaining schedule from round `start` — for resuming a
        checkpointed elastic run: pass `schedule.tail(t_ckpt)` together
        with the checkpoint's `elastic_state`.  The slice carries the
        true previous active row (`prev_active`), so round 0 of the
        tail reports joins/departures against what actually ran, not
        against an implicit all-present start."""
        if not 0 <= start <= self.num_rounds:
            raise IndexError(start)
        return RoundSchedule(
            self.active[start:],
            self.budgets[start:],
            self.num_local_steps,
            seed=self.seed,
            population=self.population,
            prev_active=(
                self.active[start - 1] if start > 0 else self.prev_active
            ),
        )

    # --------------------------------------------------------- diagnostics
    def trace(self) -> dict:
        """The full membership record, for regression tests and
        benchmark provenance: identical configs must yield identical
        traces whatever runtime consumes them.  (Only the dense
        schedule offers the [T, m] arrays; use `summary_trace()` for a
        representation-independent record.)"""
        return {
            "active": self.active.copy(),
            "budgets": self.budgets.copy(),
            "seed": self.seed,
            "num_local_steps": self.num_local_steps,
        }


class ChunkedRoundSchedule(ScheduleStats):
    """The same rounds as `RoundSchedule.build(population, seed, ...)`,
    bit-for-bit, generated lazily in [chunk_rounds, m] blocks.

    Resident memory is O(chunk_rounds * m) (one block plus the carry /
    boundary-row checkpoints, each O(m)), not O(T * m) — the schedule
    half of the million-agent story.  Correctness rests on the
    per-round-fold contract of `AvailabilityProcess.sample_rounds`: a
    row's draw depends only on the absolute round index, never on where
    block boundaries fall; the one stateful process (`MarkovChurn`)
    threads its carry across consecutive blocks, and random access
    behind the last checkpoint replays forward from the nearest one.
    """

    def __init__(
        self,
        population,
        seed: int,
        num_rounds: int,
        num_local_steps: int,
        *,
        chunk_rounds: int = 128,
        start: int = 0,
        prev_active=None,
        _carry0=None,
    ):
        if num_rounds < 1:
            raise ValueError(f"need >= 1 round, got {num_rounds}")
        self.population = population
        self.seed = int(seed)
        self.num_local_steps = int(num_local_steps)
        self.chunk_rounds = max(1, int(chunk_rounds))
        self._T = int(num_rounds)
        self._start = int(start)  # absolute round of our index 0
        self.prev_active = (
            None if prev_active is None else np.asarray(prev_active, bool)
        )
        key = availability_key(seed)
        self._k_avail, self._k_strag, self._k_force = jax.random.split(key, 3)
        # checkpoints: absolute round -> (carry entering it, row before it)
        self._carries = {self._start: _carry0}
        self._prev_rows = {self._start: self.prev_active}
        self._cache = None  # (abs_t0, active[c,m], budgets[c,m], prev_row)

    # ------------------------------------------------------------ access
    @property
    def num_rounds(self) -> int:
        return self._T

    @property
    def m(self) -> int:
        return self.population.m

    @property
    def is_static_full(self) -> bool:
        # decided from config, not materialization: only the degenerate
        # all-on / no-straggler population is static-full
        from .population import AlwaysOn, NoStragglers

        return isinstance(
            self.population.availability, AlwaysOn
        ) and isinstance(self.population.stragglers, NoStragglers)

    def __len__(self) -> int:
        return self._T

    def __iter__(self) -> Iterator[RoundEvent]:
        return (self[t] for t in range(self._T))

    def __getitem__(self, t: int) -> RoundEvent:
        if not 0 <= t < self._T:
            raise IndexError(t)
        abs0, active, budgets, prev_row = self._block(t // self.chunk_rounds)
        i = t - (abs0 - self._start)
        a = active[i]
        if i > 0:
            prev = active[i - 1]
        elif prev_row is not None:
            prev = prev_row
        else:
            prev = np.ones((self.m,), bool)
        b = budgets[i]
        return RoundEvent(
            index=t,
            active=a,
            budgets=b,
            joined=a & ~prev,
            departed=prev & ~a,
            full=bool(a.all() and (b == self.num_local_steps).all()),
        )

    def tail(self, start: int) -> "ChunkedRoundSchedule":
        """Remaining rounds from `start`, still chunked: advances the
        availability carry to the cut point so the tail continues the
        exact same trajectory (resume parity with the dense `tail`)."""
        if not 0 <= start <= self._T:
            raise IndexError(start)
        carry, prev_row = self._advance_to(self._start + start)
        return ChunkedRoundSchedule(
            self.population,
            self.seed,
            self._T - start,
            self.num_local_steps,
            chunk_rounds=self.chunk_rounds,
            start=self._start + start,
            prev_active=prev_row,
            _carry0=carry,
        )

    def materialize(self) -> RoundSchedule:
        """Densify into a `RoundSchedule` (small m / tests only)."""
        blocks_a, blocks_b = [], []
        nblocks = -(-self._T // self.chunk_rounds)
        for b in range(nblocks):
            _, active, budgets, _ = self._block(b)
            blocks_a.append(active)
            blocks_b.append(budgets)
        return RoundSchedule(
            np.concatenate(blocks_a),
            np.concatenate(blocks_b),
            self.num_local_steps,
            seed=self.seed,
            population=self.population,
            prev_active=self.prev_active,
        )

    def trace(self) -> dict:
        return self.summary_trace()

    # --------------------------------------------------------- generation
    def _sample_window(self, t0: int, t1: int, carry, with_budgets=True):
        pop = self.population
        rows, carry1 = pop.availability.sample_rounds(
            self._k_avail, pop.m, t0, t1, carry
        )
        rows = _force_min_active(rows, pop.min_active, self._k_force, t0)
        rows_np = np.asarray(rows, bool)
        if not with_budgets:
            return rows_np, None, carry1
        budgets = pop.stragglers.budgets_rounds(
            self._k_strag, rows, t0, self.num_local_steps
        )
        budgets = _clamp_budgets(rows, budgets, self.num_local_steps)
        return rows_np, np.asarray(budgets, np.int32), carry1

    def _advance_to(self, abs_t: int):
        """Carry + preceding row entering absolute round `abs_t`,
        replaying forward from the nearest checkpoint at or before it."""
        s = max(cp for cp in self._carries if cp <= abs_t)
        carry = self._carries[s]
        prev_row = self._prev_rows[s]
        while s < abs_t:
            e = min(abs_t, s + self.chunk_rounds)
            rows, _, carry = self._sample_window(
                s, e, carry, with_budgets=False
            )
            prev_row = rows[-1]
            s = e
            self._carries[s] = carry
            self._prev_rows[s] = prev_row
        return carry, prev_row

    def _block(self, b: int):
        abs0 = self._start + b * self.chunk_rounds
        abs1 = min(self._start + self._T, abs0 + self.chunk_rounds)
        if self._cache is not None and self._cache[0] == abs0:
            return self._cache
        carry, prev_row = self._advance_to(abs0)
        active, budgets, carry1 = self._sample_window(abs0, abs1, carry)
        self._carries[abs1] = carry1
        self._prev_rows[abs1] = active[-1]
        self._cache = (abs0, active, budgets, prev_row)
        return self._cache


class SparseRoundSchedule(ScheduleStats):
    """O(active)-per-round schedule: every event is a `SparseRoundEvent`
    carrying the active id list, drawn statelessly from the per-round
    fold of the availability stream.  Nothing here allocates an [m]
    row, so a 1e6-agent population with a few hundred active agents
    costs a few KB per round.  `densify()` scatters the same draws into
    a dense `RoundSchedule` — the small-m bridge the parity tests pin
    against."""

    def __init__(
        self,
        population,
        seed: int,
        num_rounds: int,
        num_local_steps: int,
        *,
        start: int = 0,
        prev_ids=None,
    ):
        from .population import SparseAvailability

        if not isinstance(population.availability, SparseAvailability):
            raise TypeError(
                "SparseRoundSchedule needs a SparseAvailability process, "
                f"got {type(population.availability).__name__}"
            )
        size = getattr(population.availability, "size", None)
        if size is not None and size < population.min_active:
            raise ValueError(
                f"subset size {size} is below the population's "
                f"min_active={population.min_active} floor"
            )
        if num_rounds < 1:
            raise ValueError(f"need >= 1 round, got {num_rounds}")
        self.population = population
        self.seed = int(seed)
        self.num_local_steps = int(num_local_steps)
        self._T = int(num_rounds)
        self._start = int(start)
        self.prev_ids = (
            None if prev_ids is None else np.asarray(prev_ids, np.int64)
        )
        key = availability_key(seed)
        # same stream split as the dense builder; k_force is unused
        # because sparse processes guarantee a nonempty draw themselves
        self._k_avail, self._k_strag, _ = jax.random.split(key, 3)
        self._ids_cache: dict = {}

    # ------------------------------------------------------------ access
    @property
    def num_rounds(self) -> int:
        return self._T

    @property
    def m(self) -> int:
        return self.population.m

    @property
    def is_static_full(self) -> bool:
        return False

    def __len__(self) -> int:
        return self._T

    def __iter__(self) -> Iterator[SparseRoundEvent]:
        return (self[t] for t in range(self._T))

    def _ids(self, abs_t: int) -> np.ndarray:
        ids = self._ids_cache.get(abs_t)
        if ids is None:
            ids = self.population.availability.sample_active_ids(
                self._k_avail, self.m, abs_t
            )
            # keep only a sliding window: sequential iteration reuses
            # round t's ids as round t+1's prev without re-drawing
            if len(self._ids_cache) > 2:
                self._ids_cache.pop(min(self._ids_cache))
            self._ids_cache[abs_t] = ids
        return ids

    def __getitem__(self, t: int) -> SparseRoundEvent:
        if not 0 <= t < self._T:
            raise IndexError(t)
        abs_t = self._start + t
        ids = self._ids(abs_t)
        if len(ids) == 0:
            raise ValueError(f"round {t} has no active agents")
        budgets = np.clip(
            self.population.stragglers.budgets_for_ids(
                self._k_strag, ids, abs_t, self.num_local_steps
            ),
            1,
            self.num_local_steps,
        ).astype(np.int32)
        prev = self._ids(abs_t - 1) if t > 0 else self.prev_ids
        return SparseRoundEvent(
            index=t,
            m=self.m,
            active_ids=ids,
            budgets=budgets,
            prev_ids=prev,
            full=bool(
                len(ids) == self.m
                and (budgets == self.num_local_steps).all()
            ),
        )

    def tail(self, start: int) -> "SparseRoundSchedule":
        """Remaining rounds from `start`; round 0 of the tail reports
        churn against the ids that actually ran before the cut."""
        if not 0 <= start <= self._T:
            raise IndexError(start)
        prev = (
            self._ids(self._start + start - 1)
            if start > 0
            else self.prev_ids
        )
        return SparseRoundSchedule(
            self.population,
            self.seed,
            self._T - start,
            self.num_local_steps,
            start=self._start + start,
            prev_ids=prev,
        )

    def densify(self) -> RoundSchedule:
        """Scatter into the dense representation (small m only) — the
        bridge the bitwise small-m parity pin runs through: dense events
        of the densified schedule equal `ev.to_dense()` of the sparse
        ones by construction."""
        active = np.zeros((self._T, self.m), bool)
        budgets = np.zeros((self._T, self.m), np.int32)
        for t, ev in enumerate(self):
            active[t, ev.active_ids] = True
            budgets[t, ev.active_ids] = ev.budgets
        prev_active = None
        if self.prev_ids is not None:
            prev_active = np.zeros(self.m, bool)
            prev_active[self.prev_ids] = True
        return RoundSchedule(
            active,
            budgets,
            self.num_local_steps,
            seed=self.seed,
            population=self.population,
            prev_active=prev_active,
        )

    def trace(self) -> dict:
        return self.summary_trace()


def _force_min_active(active, min_active: int, key, t0: int = 0):
    """Guarantee >= min_active agents per round: deficient rounds get the
    top-priority agents (a per-round fold of the schedule's own key
    stream — row t's draw depends only on the absolute round index, so
    chunked generation matches dense bitwise) force-activated.  Rounds
    already at the floor are untouched, so the common case stays exactly
    what the process drew."""
    T, m = active.shape
    deficit = active.sum(axis=1) < min_active
    pri = jax.vmap(
        lambda t: jax.random.uniform(jax.random.fold_in(key, t), (m,))
    )(jnp.arange(t0, t0 + T))
    rank = jnp.argsort(jnp.argsort(-pri, axis=1), axis=1)
    forced = rank < min_active
    return jnp.where(deficit[:, None], active | forced, active)


def _clamp_budgets(active, budgets, num_local_steps: int):
    """Clamp budgets to the membership contract: 0 where inactive, in
    [1, K] where active."""
    b = jnp.clip(budgets, 1, num_local_steps)
    return jnp.where(active, b, 0).astype(jnp.int32)
