"""RoundSchedule: the materialized per-round (active set, step budgets).

A schedule is built ONCE from (population, seed, num_rounds, K) and then
consumed by whichever runtime executes the run — the sync
`fed.runtime.FederatedRunner`, the per-shard
`fed.async_runtime.AsyncFederatedRunner`, or a benchmark loop.  Because
the availability RNG stream is a DEDICATED fold of the run seed
(`availability_key`), the schedule depends only on the population config
and the seed: it cannot drift when some other consumer of the run seed
(model init, data synthesis, a strategy's rounding RNG) changes how many
draws it takes, and sync and async runtimes consume bit-identical active
sets for the same config (tests/test_population.py pins this).

The arrays are materialized host-side (numpy) — populations are small
(m agents, not parameters), and host arrays let the runners make cheap
per-round control-flow decisions (skip fully-inactive shards, take the
bitwise-pinned full-participation path) without device round-trips.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

#: the dedicated fold of the run seed that the availability stream hangs
#: off.  Any fixed odd constant works; sharing the raw seed with other
#: consumers is the bug this prevents.
AVAILABILITY_STREAM = 0x5E_D0_AC  # "seed-0-active"


def availability_key(seed: int) -> jax.Array:
    """The availability PRNG stream for a run: a dedicated fold of the
    run seed, so schedules are a pure function of (population, seed)."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), AVAILABILITY_STREAM)


@dataclasses.dataclass(frozen=True)
class RoundEvent:
    """One round's membership facts, as the runners consume them."""

    index: int
    active: np.ndarray    # [m] bool — who participates this round
    budgets: np.ndarray   # [m] int32 — local-step cap (0 where inactive)
    joined: np.ndarray    # [m] bool — newly active vs the previous round
    departed: np.ndarray  # [m] bool — newly absent vs the previous round
    full: bool            # all active with their full K budget

    @property
    def num_active(self) -> int:
        return int(self.active.sum())

    @property
    def churned(self) -> bool:
        return bool(self.joined.any() or self.departed.any())


class RoundSchedule:
    """Iterator over `RoundEvent`s for one run (see module docstring).

    `is_static_full` flags the degenerate all-on/no-straggler schedule:
    runners given one take their unmodified legacy path, which is how
    the full-participation population reproduces the existing runners
    BITWISE (tests/test_elastic.py)."""

    def __init__(
        self,
        active,
        budgets,
        num_local_steps: int,
        seed: int = 0,
        population=None,
        prev_active=None,
    ):
        self.active = np.asarray(active, bool)
        self.budgets = np.asarray(budgets, np.int32)
        #: the active set of the round BEFORE this schedule's first —
        #: None means a fresh start (all-present, the legacy baseline);
        #: `tail()` propagates the true row so round 0 of a resumed
        #: schedule reports joins/departures against what actually ran
        self.prev_active = (
            None if prev_active is None else np.asarray(prev_active, bool)
        )
        if self.active.shape != self.budgets.shape or self.active.ndim != 2:
            raise ValueError(
                f"active {self.active.shape} and budgets "
                f"{self.budgets.shape} must both be [num_rounds, m]"
            )
        if (self.budgets[~self.active] != 0).any():
            raise ValueError("inactive agents must have a zero step budget")
        if (self.budgets[self.active] < 1).any():
            raise ValueError("active agents need a budget of >= 1 steps")
        empty = ~self.active.any(axis=1)
        if empty.any():
            # the weights' "sum to 1 for ANY nonempty active set" contract
            # (and the async runner's shard dispatch) both assume this —
            # an empty round would renormalize 0/0 into NaN iterates
            raise ValueError(
                f"rounds {np.nonzero(empty)[0].tolist()} have no active "
                "agents; every round needs at least one (Population "
                "enforces min_active when building schedules)"
            )
        self.num_local_steps = int(num_local_steps)
        self.seed = int(seed)
        self.population = population

    @classmethod
    def build(
        cls, population, seed: int, num_rounds: int, num_local_steps: int
    ) -> "RoundSchedule":
        m = population.m
        key = availability_key(seed)
        k_avail, k_strag, k_force = jax.random.split(key, 3)
        active = population.availability.sample(k_avail, m, num_rounds)
        active = _force_min_active(active, population.min_active, k_force)
        budgets = population.stragglers.budgets(
            k_strag, active, num_local_steps
        )
        budgets = _clamp_budgets(active, budgets, num_local_steps)
        return cls(
            np.asarray(active),
            np.asarray(budgets),
            num_local_steps,
            seed=seed,
            population=population,
        )

    # ------------------------------------------------------------ access
    @property
    def num_rounds(self) -> int:
        return self.active.shape[0]

    @property
    def m(self) -> int:
        return self.active.shape[1]

    @property
    def is_static_full(self) -> bool:
        return bool(
            self.active.all() and (self.budgets == self.num_local_steps).all()
        )

    def __len__(self) -> int:
        return self.num_rounds

    def __getitem__(self, t: int) -> RoundEvent:
        if not 0 <= t < self.num_rounds:
            raise IndexError(t)
        if t > 0:
            prev = self.active[t - 1]
        elif self.prev_active is not None:
            prev = self.prev_active
        else:
            prev = np.ones((self.m,), bool)
        a = self.active[t]
        return RoundEvent(
            index=t,
            active=a,
            budgets=self.budgets[t],
            joined=a & ~prev,
            departed=prev & ~a,
            full=bool(a.all() and (self.budgets[t] == self.num_local_steps).all()),
        )

    def __iter__(self) -> Iterator[RoundEvent]:
        return (self[t] for t in range(self.num_rounds))

    def tail(self, start: int) -> "RoundSchedule":
        """The remaining schedule from round `start` — for resuming a
        checkpointed elastic run: pass `schedule.tail(t_ckpt)` together
        with the checkpoint's `elastic_state`.  The slice carries the
        true previous active row (`prev_active`), so round 0 of the
        tail reports joins/departures against what actually ran, not
        against an implicit all-present start."""
        if not 0 <= start <= self.num_rounds:
            raise IndexError(start)
        return RoundSchedule(
            self.active[start:],
            self.budgets[start:],
            self.num_local_steps,
            seed=self.seed,
            population=self.population,
            prev_active=(
                self.active[start - 1] if start > 0 else self.prev_active
            ),
        )

    # --------------------------------------------------------- diagnostics
    def trace(self) -> dict:
        """The full membership record, for regression tests and
        benchmark provenance: identical configs must yield identical
        traces whatever runtime consumes them."""
        return {
            "active": self.active.copy(),
            "budgets": self.budgets.copy(),
            "seed": self.seed,
            "num_local_steps": self.num_local_steps,
        }

    def participation_rate(self) -> float:
        return float(self.active.mean())

    def churn_events(self) -> int:
        """Rounds whose active set differs from the previous round's."""
        return int(
            (self.active[1:] != self.active[:-1]).any(axis=1).sum()
        )


def _force_min_active(active, min_active: int, key):
    """Guarantee >= min_active agents per round: deficient rounds get the
    top-priority agents (a per-round uniform draw from the schedule's
    own key stream) force-activated.  Rounds already at the floor are
    untouched, so the common case stays exactly what the process drew."""
    T, m = active.shape
    deficit = active.sum(axis=1) < min_active
    pri = jax.random.uniform(key, (T, m))
    rank = jnp.argsort(jnp.argsort(-pri, axis=1), axis=1)
    forced = rank < min_active
    return jnp.where(deficit[:, None], active | forced, active)


def _clamp_budgets(active, budgets, num_local_steps: int):
    """Clamp budgets to the membership contract: 0 where inactive, in
    [1, K] where active."""
    b = jnp.clip(budgets, 1, num_local_steps)
    return jnp.where(active, b, 0).astype(jnp.int32)
