"""O(active) elastic execution: sparse tracker state + the sparse round.

The dense elastic path (`sim.elastic`) is correct but m-dense: the
tracker table, the broadcast stacks and the EF buffers all carry one row
per POPULATION member.  This module is the million-agent counterpart —
everything scales with the ACTIVE set:

**SparseTracker.**  The dense tracker table never needs to be held:
gbar is its MEAN, and only active agents' rows change per round.  So the
tracker is (a) the running SUM of the full table (one gradient-shaped
pytree, O(dim)), (b) explicit rows only for agents that have been active
at least once since init ("touched"), and (c) the anchor iterate
(x0, y0) at which every untouched agent's row is, by construction, its
init-time anchor gradient — recomputable on demand from its data.  A
round updates `sum += Σ_active (g_new - g_old)` and re-anchors the
touched rows; `gbar = sum / m` equals the dense full-table mean up to
fp reduction order.  Memory: O(dim + touched); touched grows with
distinct participants, bounded by m but ~active * rounds in the sparse
regime.

**SparseElasticEngine.**  Drives `SparseRoundSchedule`s through
per-round programs whose shapes are [n_active, ...]: data rows are
gathered from an `AgentDataSource` (dense arrays, or synthesized
per-id for populations too large to materialize), strategy EF rows are
re-gathered between rounds via `CommStrategy.realign_state_rows`, and
noise streams fold GLOBAL agent ids (`RoundState.active_indices`) so an
agent's draws don't depend on the layout.  With a `sim.PodMap` the
aggregate runs the two-level tree (`core.engine.pod_weighted_sums` ->
`pods_total`), optionally shipping the live pods' partials through
`fed.pods.encode_pod_partials` (dense `PackedTree`s — bitwise codec)
for wire accounting.

**Dense fallback.**  For m <= `dense_fallback_max_m` the engine
densifies the schedule and routes through `fed.runtime.FederatedRunner`
+ `sim.make_elastic_round` — the EXISTING dense elastic machinery —
which is the bitwise small-m pin of the sparse entry point
(tests/test_sparse_elastic.py).  The genuinely-sparse path matches the
dense path to fp tolerance for deterministic-draw strategies (reduction
order differs; RNG-shaped transforms — stochastic rounding, rand-k —
draw [n·rows] instead of [m·rows] uniforms and are excluded from parity
by construction).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engine import (
    RoundPhases,
    agent_where,
    make_noise_vgrad,
    make_phases,
    noise_eval_keys,
    pod_weighted_sums,
    pods_total,
    renormalized_weights,
    tracking_corrections,
)
from ..core.types import LossFn, Pytree, grad_xy, identity_proj
from ..core.types import tree_broadcast_agents

#: populations at or below this size route through the dense elastic
#: machinery (bitwise-pinned); above it the O(active) path engages
DENSE_FALLBACK_MAX_M = 4096


# ----------------------------------------------------------- data sources
class AgentDataSource:
    """O(active) access to per-agent data: the sparse round gathers only
    the active agents' rows, so a huge population's data never needs to
    exist as one [m, ...] array."""

    m: int

    def gather(self, ids: np.ndarray) -> Pytree:
        """Rows (leading axis len(ids)) for the given GLOBAL ids."""
        raise NotImplementedError


class ArrayDataSource(AgentDataSource):
    """Dense [m, ...] per-agent arrays as a source (simulation scale)."""

    def __init__(self, agent_data: Pytree):
        self.agent_data = agent_data
        self.m = int(jax.tree.leaves(agent_data)[0].shape[0])

    def gather(self, ids):
        idx = jnp.asarray(np.asarray(ids))
        return jax.tree.map(lambda u: jnp.take(u, idx, axis=0), self.agent_data)

    def materialize(self) -> Pytree:
        return self.agent_data


class SyntheticDataSource(AgentDataSource):
    """Per-agent data synthesized from the global id on demand:
    `row_fn(ids[n]) -> rows [n, ...]` must be a pure function of the
    ids (typically a fold of a data key), so any subset of agents can
    be generated at any time in O(n) memory — the only way a 1e6-agent
    population fits on a host."""

    def __init__(self, m: int, row_fn: Callable):
        self.m = int(m)
        self._row_fn = row_fn

    def gather(self, ids):
        return self._row_fn(jnp.asarray(np.asarray(ids)))

    def materialize(self) -> Pytree:
        # dense fallback / tests only — deliberately O(m)
        return self.gather(np.arange(self.m, dtype=np.int64))


# ---------------------------------------------------------- sparse tracker
class SparseTracker:
    """Running-sum + touched-rows representation of the dense tracker
    table (see module docstring).  Rows live host-side (numpy) because
    they are a per-agent K/V store, not a tensor the round math scans;
    the running sums stay on device."""

    def __init__(self, m: int, sum_gx: Pytree, sum_gy: Pytree,
                 x0: Pytree, y0: Pytree):
        self.m = int(m)
        self.sum_gx = sum_gx
        self.sum_gy = sum_gy
        self.x0 = x0
        self.y0 = y0
        self._index: Dict[int, int] = {}
        self._gx_leaves: Optional[List[np.ndarray]] = None
        self._gy_leaves: Optional[List[np.ndarray]] = None
        self._gx_def = None
        self._gy_def = None
        self._cap = 0
        self._n = 0

    # ------------------------------------------------------------- init
    @classmethod
    def init(
        cls,
        loss: LossFn,
        x0: Pytree,
        y0: Pytree,
        source: AgentDataSource,
        chunk: int = 8192,
    ) -> "SparseTracker":
        """Σ_i g_i(x0, y0) over ALL m agents, computed in id chunks:
        O(m) compute once, O(chunk) resident memory — the init cost the
        sparse representation cannot avoid (gbar is a full-population
        mean), paid without ever materializing an [m, ...] stack."""
        gfn = grad_xy(loss)

        @jax.jit
        def chunk_sums(x, y, data):
            g = jax.vmap(gfn, in_axes=(None, None, 0))(x, y, data)
            s = lambda t: jax.tree.map(lambda u: jnp.sum(u, axis=0), t)
            return s(g.gx), s(g.gy)

        m = source.m
        chunk = max(1, min(chunk, m))
        sum_gx = sum_gy = None
        add = lambda a, b: (
            b if a is None else jax.tree.map(jnp.add, a, b)
        )
        # equal-size main chunks + one remainder: two trace shapes max
        for lo in range(0, m - m % chunk, chunk):
            ids = np.arange(lo, lo + chunk, dtype=np.int64)
            sx, sy = chunk_sums(x0, y0, source.gather(ids))
            sum_gx, sum_gy = add(sum_gx, sx), add(sum_gy, sy)
        if m % chunk:
            ids = np.arange(m - m % chunk, m, dtype=np.int64)
            sx, sy = chunk_sums(x0, y0, source.gather(ids))
            sum_gx, sum_gy = add(sum_gx, sx), add(sum_gy, sy)
        return cls(m, sum_gx, sum_gy, x0, y0)

    # ------------------------------------------------------------ access
    @property
    def num_touched(self) -> int:
        return self._n

    def lookup(self, ids: np.ndarray):
        """(touched [n] bool, rows_gx, rows_gy) for the given ids; rows
        of never-touched agents are zeros — the round program replaces
        them with the recomputed anchor gradient under the mask."""
        ids = np.asarray(ids)
        pos = np.array([self._index.get(int(i), -1) for i in ids], np.int64)
        touched = pos >= 0
        safe = np.where(touched, pos, 0)

        def take(leaves, treedef, like):
            if leaves is None:
                return jax.tree.map(jnp.zeros_like, like)
            sel = [leaf[safe] for leaf in leaves]
            out = jax.tree.unflatten(treedef, sel)
            mask = jnp.asarray(touched)
            return jax.tree.map(
                lambda u: jnp.where(
                    mask.reshape((-1,) + (1,) * (u.ndim - 1)), u,
                    jnp.zeros_like(u),
                ),
                out,
            )

        n = len(ids)
        zx = jax.tree.map(
            lambda u: jnp.zeros((n,) + u.shape, u.dtype), self.x0
        )
        zy = jax.tree.map(
            lambda u: jnp.zeros((n,) + u.shape, u.dtype), self.y0
        )
        rows_gx = take(self._gx_leaves, self._gx_def, zx)
        rows_gy = take(self._gy_leaves, self._gy_def, zy)
        return touched, rows_gx, rows_gy

    def commit(self, ids: np.ndarray, new_gx: Pytree, new_gy: Pytree,
               sum_gx: Pytree, sum_gy: Pytree) -> None:
        """Store this round's fresh anchor rows and adopt the updated
        running sums the round program computed."""
        ids = np.asarray(ids)
        gx_leaves, gx_def = jax.tree.flatten(new_gx)
        gy_leaves, gy_def = jax.tree.flatten(new_gy)
        gx_np = [np.asarray(u) for u in gx_leaves]
        gy_np = [np.asarray(u) for u in gy_leaves]
        if self._gx_leaves is None:
            self._gx_def, self._gy_def = gx_def, gy_def
            self._gx_leaves = [
                np.empty((0,) + u.shape[1:], u.dtype) for u in gx_np
            ]
            self._gy_leaves = [
                np.empty((0,) + u.shape[1:], u.dtype) for u in gy_np
            ]
        # assign row slots (grow geometrically on demand)
        pos = np.empty(len(ids), np.int64)
        for j, i in enumerate(np.asarray(ids)):
            i = int(i)
            p = self._index.get(i)
            if p is None:
                p = self._n
                self._index[i] = p
                self._n += 1
            pos[j] = p
        if self._n > self._cap:
            new_cap = max(16, self._cap * 2, self._n)
            grow = lambda leaves: [
                np.concatenate(
                    [u, np.empty((new_cap - len(u),) + u.shape[1:], u.dtype)]
                )
                for u in leaves
            ]
            self._gx_leaves = grow(self._gx_leaves)
            self._gy_leaves = grow(self._gy_leaves)
            self._cap = new_cap
        for store, rows in zip(self._gx_leaves, gx_np):
            store[pos] = rows
        for store, rows in zip(self._gy_leaves, gy_np):
            store[pos] = rows
        self.sum_gx, self.sum_gy = sum_gx, sum_gy


# ----------------------------------------------------------- sparse engine
class SparseElasticEngine:
    """O(active) driver for `SparseRoundSchedule`s (module docstring).

    Always membership-aware (re-normalized 1/n_active weights, tracker
    running-sum exchange, EF row realignment) — the naive-server
    `rebase=False` ablation exists only on the dense path, where the
    full registry it mis-weights over is actually materialized.

    Per-round programs are jitted per active-set SIZE: a fixed-size
    sampler (`UniformActiveSubset`) compiles once; variable-size
    processes recompile per distinct n_active.
    """

    def __init__(
        self,
        loss: LossFn,
        strategy,
        source: AgentDataSource,
        num_local_steps: int,
        eta_x: float,
        eta_y: Optional[float] = None,
        *,
        proj_x: Callable = identity_proj,
        proj_y: Callable = identity_proj,
        pod_map=None,
        wire_pods: bool = False,
        metric_fn: Optional[Callable] = None,
        init_chunk: int = 8192,
        dense_fallback_max_m: int = DENSE_FALLBACK_MAX_M,
        telemetry=None,
    ):
        from ..fed.strategies import resolve_strategy

        self._loss = loss
        self._strategy = resolve_strategy(strategy)
        self._source = source
        self._K = int(num_local_steps)
        self._eta_x = eta_x
        self._eta_y = eta_x if eta_y is None else eta_y
        self._proj_x = proj_x
        self._proj_y = proj_y
        self._pods = pod_map
        self._wire_pods = bool(wire_pods)
        if self._wire_pods and pod_map is None:
            raise ValueError("wire_pods needs a pod_map")
        self._metric_raw = metric_fn
        self._metric_fn = jax.jit(metric_fn) if metric_fn else None
        self._init_chunk = int(init_chunk)
        self._fallback_m = int(dense_fallback_max_m)
        self._use_corr = bool(getattr(self._strategy, "use_correction", False))
        self._phases: RoundPhases = make_phases(
            loss, self._strategy, self._K, self._eta_x, self._eta_y,
            proj_x=proj_x, proj_y=proj_y,
        )
        #: repro.obs.Telemetry sink or None (None = pre-telemetry code
        #: verbatim); public so tests flip it on a built engine
        self.telemetry = telemetry
        gfn = grad_xy(loss)
        #: the noiseless anchor oracle — probes re-derive untouched
        #: tracker rows with it (`obs.probes.sparse_tracker_table`)
        self._gfn = gfn
        self._vgrad = jax.vmap(gfn, in_axes=(0, 0, 0))
        noise = getattr(self._strategy, "noise", None)
        self._noise = noise
        self._nvgrad = make_noise_vgrad(gfn, noise) if noise else None
        self._momentum = float(getattr(self._strategy, "momentum", 0.0) or 0.0)
        self._jit_round = jax.jit(self._round_program)
        # cross-run continuation (resume=True)
        self._tracker: Optional[SparseTracker] = None
        self._state: Optional[Pytree] = None
        self._prev_ids: Optional[np.ndarray] = None
        self._dense_runner = None
        self.history: List[Dict] = []

    # ----------------------------------------------------- round program
    def _round_program(self, x, y, data, ids, budgets, touched,
                       st_gx, st_gy, sum_gx, sum_gy, state, pod_ids,
                       x0, y0):
        """One sparse round as a single traced program; `n` is read from
        the data shapes at trace time (recompiles per distinct size).
        (x0, y0) is the tracker's init anchor — an argument, not a
        closure capture, so a fresh non-resume run retraces nothing."""
        n = jax.tree.leaves(data)[0].shape[0]
        active = jnp.ones((n,), bool)
        weights = renormalized_weights(active)
        rs = self._phases.broadcast(
            x, y, data, state,
            weights=weights, step_budgets=budgets, active=active,
            active_indices=ids,
        )
        new_gx = new_gy = None
        if self._use_corr:
            if self._noise is None:
                g = self._vgrad(rs.xs, rs.ys, data)
            else:
                g = self._nvgrad(
                    noise_eval_keys(rs.noise_keys, 0), rs.xs, rs.ys, data
                )
            # untouched agents' last table row IS their init anchor
            # gradient — recompute it at (x0, y0) (the same noiseless
            # oracle `init_tracker` uses) and select under the mask
            g0 = self._vgrad(
                tree_broadcast_agents(x0, n),
                tree_broadcast_agents(y0, n),
                data,
            )
            old_gx = agent_where(touched, st_gx, g0.gx)
            old_gy = agent_where(touched, st_gy, g0.gy)
            upd = lambda s, gn, go: jax.tree.map(
                lambda sv, nv, ov: sv
                + jnp.sum(nv - ov, axis=0).astype(sv.dtype),
                s, gn, go,
            )
            sum_gx = upd(sum_gx, g.gx, old_gx)
            sum_gy = upd(sum_gy, g.gy, old_gy)
            gbar_x = jax.tree.map(lambda s: s / self._source.m, sum_gx)
            gbar_y = jax.tree.map(lambda s: s / self._source.m, sum_gy)
            cdt = getattr(self._strategy, "correction_dtype", None)
            cx, cy = tracking_corrections(g.gx, g.gy, gbar_x, gbar_y, cdt)
            cx, cy, state2 = self._strategy.transform_correction(
                cx, cy, rs.state
            )
            if hasattr(cx, "decode"):
                cx = cx.decode()
            if hasattr(cy, "decode"):
                cy = cy.decode()
            rs = dataclasses.replace(
                rs, cx=cx, cy=cy, gbar_x=gbar_x, gbar_y=gbar_y,
                fused=bool(self._strategy.exact_correction)
                and not self._momentum,
                state=state2,
            )
            new_gx, new_gy = g.gx, g.gy
        else:
            rs = self._phases.exchange_corrections(rs, data)
        rs = self._phases.local_steps(rs, data)
        pod_px = pod_py = None
        if self._pods is not None and not getattr(
            self._strategy, "sync_every_step", False
        ):
            # two-level aggregate: agent rows -> per-pod partial
            # weighted sums -> server total (fp-tolerance-equal to the
            # flat weighted mean; quiet pods are exact zero rows)
            pod_px = pod_weighted_sums(
                rs.xs, rs.weights, pod_ids, self._pods.num_pods
            )
            pod_py = pod_weighted_sums(
                rs.ys, rs.weights, pod_ids, self._pods.num_pods
            )
            x1 = self._proj_x(pods_total(pod_px))
            y1 = self._proj_y(pods_total(pod_py))
            state3 = rs.state
        else:
            x1, y1, state3 = self._phases.aggregate(rs)
        return (x1, y1, state3, new_gx, new_gy, sum_gx, sum_gy,
                pod_px, pod_py)

    # --------------------------------------------------------------- run
    def run(self, x, y, schedule, num_rounds: Optional[int] = None,
            log_every: int = 0, resume: bool = False):
        """Drive `num_rounds` (default: all) of `schedule`.  With
        `resume=True` the engine continues from its own previous run
        (tracker sums, touched rows, strategy state, prev ids) — pass
        `schedule.tail(t)` for the remaining rounds."""
        T = len(schedule) if num_rounds is None else int(num_rounds)
        if len(schedule) < T:
            raise ValueError(
                f"schedule covers {len(schedule)} rounds, need {T}"
            )
        if schedule.m != self._source.m:
            raise ValueError(
                f"schedule is for m={schedule.m}, source has "
                f"{self._source.m}"
            )
        dense = bool(
            self._fallback_m
            and self._source.m <= self._fallback_m
            and hasattr(schedule, "densify")
            and hasattr(self._source, "materialize")
        )
        if self.telemetry is not None:
            self.telemetry.emit(
                "event", "dense_fallback", round=None, value=dense,
                m=self._source.m, max_m=self._fallback_m,
            )
        if dense:
            return self._run_dense(x, y, schedule, T, log_every, resume)
        return self._run_sparse(x, y, schedule, T, log_every, resume)

    def _run_dense(self, x, y, schedule, T, log_every, resume):
        """Small-m fallback: densify and route through the EXISTING
        dense elastic machinery (`FederatedRunner` +
        `make_elastic_round`) — bitwise-equal to a dense elastic run by
        construction, which is the small-m pin of this entry point."""
        from ..fed.runtime import FederatedRunner

        if self._dense_runner is None:
            self._dense_runner = FederatedRunner.from_strategy(
                self._loss, self._strategy, self._source.materialize(),
                self._K, self._eta_x, self._eta_y,
                metric_fn=self._metric_raw,
                proj_x=self._proj_x, proj_y=self._proj_y,
            )
        runner = self._dense_runner
        # refreshed every call so flipping the engine's sink (tests do)
        # reaches an already-built dense runner
        runner.telemetry = self.telemetry
        prev_n = len(runner.history)
        x, y = runner.run(
            x, y, T, log_every=log_every,
            schedule=schedule.densify(),
            elastic_state=runner.elastic_state if resume else None,
        )
        for s in runner.history[prev_n:]:
            self.history.append(
                {"round": s.round_index, "path": "dense-fallback",
                 **s.metrics}
            )
        return x, y

    def _run_sparse(self, x, y, schedule, T, log_every, resume):
        import time

        from ..fed.pods import encode_pod_partials

        strategy = self._strategy
        tm = self.telemetry
        per_agent = None
        if tm is not None:
            from ..obs import probes as _p

            if tm.probe_due("priced_vs_measured", 0):
                tm.probe_value(
                    "priced_vs_measured", 0,
                    _p.priced_vs_measured(strategy, x, y, self._K),
                )
            # per-ACTIVE-agent payload — the same `sim.per_agent_bytes`
            # account schedule_bytes and the runners' wire_report price
            from .elastic import per_agent_bytes

            per_agent = per_agent_bytes(strategy, x, y, self._K)
        if resume and self._tracker is None:
            raise ValueError("resume=True but no previous sparse run")
        if not resume:
            self._tracker = (
                SparseTracker.init(
                    self._loss, x, y, self._source, self._init_chunk
                )
                if self._use_corr
                else SparseTracker(
                    self._source.m,
                    jax.tree.map(jnp.zeros_like, x),
                    jax.tree.map(jnp.zeros_like, y),
                    x, y,
                )
            )
            self._state = None
            self._prev_ids = None
        for t in range(T):
            t0 = time.perf_counter()
            ev = schedule[t]
            ids = ev.active_ids
            n = len(ids)
            if tm is not None:
                tm.begin_round(t)
            data = self._source.gather(ids)
            if self._state is None:
                self._state = (
                    strategy.init_state(x, y, n)
                    if getattr(strategy, "stateful", False)
                    else {}
                )
            else:
                # re-gather per-agent state rows (EF residuals) from the
                # previous round's id layout into this one: continuing
                # agents keep their rows, everyone else restarts at zero
                # — the dense `rebase_state` rule over id lists
                self._state = strategy.realign_state_rows(
                    self._state, self._prev_ids, ids
                )
                if tm is not None:
                    tm.emit(
                        "event", "realign",
                        n_continuing=int(
                            len(np.intersect1d(self._prev_ids, ids))
                        ),
                        n_active=n,
                    )
            touched, st_gx, st_gy = self._tracker.lookup(ids)
            pod_ids = (
                jnp.asarray(self._pods.pod_of(ids))
                if self._pods is not None
                else jnp.zeros((n,), jnp.int32)
            )
            (
                x, y, self._state, new_gx, new_gy, sum_gx, sum_gy,
                pod_px, pod_py,
            ) = self._jit_round(
                x, y, data, jnp.asarray(ids), jnp.asarray(ev.budgets),
                jnp.asarray(touched), st_gx, st_gy,
                self._tracker.sum_gx, self._tracker.sum_gy,
                self._state, pod_ids,
                self._tracker.x0, self._tracker.y0,
            )
            if self._use_corr:
                self._tracker.commit(ids, new_gx, new_gy, sum_gx, sum_gy)
            rec = {"round": t, "path": "sparse", "n_active": n}
            if self._pods is not None:
                live = self._pods.live_pods(ids)
                rec["live_pods"] = len(live)
                if self._wire_pods and pod_px is not None:
                    rows = jnp.asarray(live)
                    gather_live = lambda tree: jax.tree.map(
                        lambda u: jnp.take(u, rows, axis=0), tree
                    )
                    packed = encode_pod_partials(
                        (gather_live(pod_px), gather_live(pod_py))
                    )
                    rec["pod_wire_bytes"] = packed.total_bytes()
            if self._metric_fn is not None:
                rec.update(
                    {k: float(v) for k, v in self._metric_fn(x, y).items()}
                )
            self.history.append(rec)
            if tm is not None:
                dt = time.perf_counter() - t0
                tm.round_event(
                    t, runtime="sparse", seconds=dt,
                    n_active=n,
                    **{
                        k: rec[k]
                        for k in ("live_pods", "pod_wire_bytes")
                        if k in rec
                    },
                )
                if per_agent is not None:
                    wire = per_agent * n + rec.get("pod_wire_bytes", 0)
                    tm.counter(
                        "wire_bytes", wire,
                        per_agent=per_agent, n_active=n,
                    )
                self._emit_sparse_probes(tm, t, x, y)
                tm.end_round(t)
            if log_every and (t % log_every == 0 or t == T - 1):
                msg = " ".join(
                    f"{k}={v:.3e}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in rec.items()
                    if k not in ("round", "path")
                )
                print(f"[sparse round {t:5d}] {msg}")
            self._prev_ids = ids
        return x, y

    def _emit_sparse_probes(self, tm, t, x, y) -> None:
        """Sampled invariant probes on the O(active) path.  The GT and
        drift probes materialize the implied DENSE table
        (`obs.probes.sparse_tracker_table` — O(m), a probe cost, never a
        runtime one) so the probe function evaluated is the SAME one the
        dense runtimes feed their tracker tables to: probe parity across
        runtimes localizes a faulty layer (tests/test_obs.py)."""
        from ..obs import probes as _p

        want_gt = tm.probe_due("gt_residual", t)
        want_drift = tm.probe_due("tracker_drift", t)
        if self._use_corr and (want_gt or want_drift):
            tab_x, tab_y = _p.sparse_tracker_table(
                self._tracker, self._source, self._gfn
            )
            if want_gt:
                cx, cy = _p.corrections_from_table(tab_x, tab_y)
                tm.probe_value("gt_residual", t, _p.gt_residual(cx, cy))
            if want_drift:
                tm.probe_value(
                    "tracker_drift", t,
                    _p.tracker_drift(
                        tab_x, tab_y,
                        self._tracker.sum_gx, self._tracker.sum_gy,
                    ),
                )
        if tm.probe_due("ef_residual", t):
            norms = _p.ef_residual_norms(self._state)
            if norms:
                tm.probe_value("ef_residual", t, norms)
        if tm.gap_fn is not None and tm.probe_due("duality_gap", t):
            tm.probe_value(
                "duality_gap", t, _p.duality_gap(tm.gap_fn, x, y)
            )
