"""Membership-aware aggregation + gradient-tracking rebase.

The round engine proves its guarantees for a fixed population; this
module owns what changes when the population is elastic:

**Weights.**  A naive server keeps averaging with 1/m over the full
registry; on a round where only a subset A participates, the aggregate
sum_{i in A} x_i / m silently loses (m - |A|)/m of the iterate's mass
and the run collapses toward the origin instead of the minimax point.
`ElasticAggregator.weights` re-normalizes over the active set (sum = 1
for ANY nonempty A); the `rebase=False` ablation keeps the naive 1/m
weighting so the failure is reproducible on demand
(tests/test_elastic.py, benchmarks/elastic.py).

**Trackers.**  Gradient-tracking corrections c_i = gbar - g_i only
cancel drift if gbar tracks the FULL population's gradient.  Under
churn the server cannot evaluate absent agents, so the elastic round
keeps a per-agent tracker table of each agent's last exchanged anchor
gradient: active agents re-anchor their entry at the CURRENT server
iterate every round (a rejoining agent therefore re-anchors within one
round of returning — never steps on stale state), absent agents stand
in with their last entry, and gbar is the full-table mean.  The GT
invariant — the (uniform) corrections summing to the tracked global
gradient gap, sum_i c_i / m = gbar - mean_i(table_i) = 0 — holds by
construction every round, and because the table's staleness is
proportional to past iterate movement, FedGDA-GT keeps its EXACT limit
under persistent churn (the noise is multiplicative in the gradient,
not additive).  With `rebase=False` the stale-state failure mode is the
naive weighting above plus never re-anchored error-feedback residuals.

**Error feedback.**  Compressing strategies carry per-agent EF
residual buffers; a departed agent's residual describes corrections it
never applied.  `ElasticAggregator.rebase_state` defers to the
strategy's `rebase_state` hook (`fed.strategies`), which zeroes the
rows of agents that did not participate in the previous round — their
wire bytes disappear from the round's accounting too
(`schedule_bytes`).

`make_elastic_round` composes the engine's phases
(`repro.core.engine.make_phases`) with the tracker-table exchange into
one jittable round:

    round(x, y, agent_data, state, tracker, weights, budgets, active)
        -> (x1, y1, state, tracker)

Both runtimes (`fed.runtime.FederatedRunner`,
`fed.async_runtime.AsyncFederatedRunner`) consume it through the same
`RoundSchedule`, so sync and async see identical membership.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from ..core.engine import (
    RoundPhases,
    agent_mean,
    agent_where,
    make_noise_vgrad,
    make_phases,
    noise_eval_keys,
    tracking_corrections,
)
from ..core.types import LossFn, Pytree, grad_xy, identity_proj
from .population import renormalized_weights


def init_tracker(
    loss: LossFn, strategy, x: Pytree, y: Pytree, agent_data: Pytree
) -> dict:
    """The tracker table at round 0: every agent's anchor gradient at
    the initial server iterate (x0, y0) — i.e. every agent starts
    freshly re-anchored, exactly like a joiner does later.  Strategies
    without corrections carry no table ({}).  Deliberately NOISELESS
    even for stochastic strategies: the table seeds round 0 before any
    round key is drawn, and the async runner's lazy tracker init
    (`AsyncFederatedRunner._init_tracker`) matches this exact oracle."""
    if not getattr(strategy, "use_correction", False):
        return {}
    g = jax.vmap(grad_xy(loss), in_axes=(None, None, 0))(x, y, agent_data)
    return {"gx": g.gx, "gy": g.gy}


def tracker_exchange(strategy, gx, gy, state, active, tab_x, tab_y, cdt=None,
                     prev_active=None):
    """The membership-aware exchange — ONE owner of the GT-invariant
    math, shared by the fused elastic round below and the async
    runner's server-side exchange program: active agents re-anchor
    their tracker row with their fresh anchor gradient, absent agents
    stand in with their last row, gbar is the full-table mean (so the
    uniform corrections sum to the tracked global gradient gap by
    construction), then the strategy's transform + wire decode run
    exactly as on the all-present path.

    `prev_active` non-None additionally re-anchors the strategy's
    membership-dependent state (EF residual rows) via its
    `rebase_state` hook BEFORE the transform — inside the jitted round,
    so the masked selects fuse with the state's first use instead of
    materializing fresh full-size buffers eagerly each round.  None is
    the naive no-rebase ablation (stale residuals).

    Returns (cx, cy, gbar_x, gbar_y, state, tab_x, tab_y)."""
    if prev_active is not None:
        hook = getattr(strategy, "rebase_state", None)
        if hook is not None and state:
            state = hook(state, active, prev_active)
    tab_x = agent_where(active, gx, tab_x)
    tab_y = agent_where(active, gy, tab_y)
    gbar_x = agent_mean(tab_x, None)
    gbar_y = agent_mean(tab_y, None)
    cx, cy = tracking_corrections(tab_x, tab_y, gbar_x, gbar_y, cdt)
    cx, cy, state = strategy.transform_correction(cx, cy, state)
    if hasattr(cx, "decode"):
        cx = cx.decode()
    if hasattr(cy, "decode"):
        cy = cy.decode()
    return cx, cy, gbar_x, gbar_y, state, tab_x, tab_y


@dataclasses.dataclass
class ElasticAggregator:
    """Membership-aware server policy for one run (see module docstring).

    rebase=True   re-normalized weights + tracker/EF re-anchoring —
                  the membership-aware path.
    rebase=False  the naive-server ablation: 1/m weights over the full
                  registry and stale EF residuals.  Exists so the
                  failure mode stays a tracked benchmark row, not lore.
    """

    strategy: Any
    rebase: bool = True

    def weights(self, active) -> jax.Array:
        active = jnp.asarray(active)
        if self.rebase:
            return renormalized_weights(active)
        m = active.shape[0]
        return active.astype(jnp.result_type(float)) / m

    def rebase_state(self, state, active, prev_active=None):
        """Re-anchor the strategy's membership-dependent state (EF
        residual rows) for this round's active set.  The runners fold
        this into the jitted round via `tracker_exchange(...,
        prev_active=...)`; this eager form remains for callers (and
        tests) working with a bare state dict."""
        if not self.rebase or not state:
            return state
        hook = getattr(self.strategy, "rebase_state", None)
        if hook is None:
            return state
        return hook(state, jnp.asarray(active), prev_active)

    def round_prev_active(self, active, prev_active):
        """What to feed `tracker_exchange`'s rebase: None when rebasing
        is off (the naive ablation), the previous round's active set
        when continuing, and all-present for the very first round
        (fresh EF buffers are zero, so `keep = active & ones` matches
        the from-scratch semantics)."""
        if not self.rebase:
            return None
        if prev_active is not None:
            return prev_active
        return jnp.ones(jnp.asarray(active).shape, bool)


def make_elastic_round(
    loss: LossFn,
    strategy,
    num_local_steps: int,
    eta_x: float,
    eta_y: Optional[float] = None,
    *,
    proj_x: Callable = identity_proj,
    proj_y: Callable = identity_proj,
    update_fn: Optional[Callable] = None,
    constrain_agents: Optional[Callable] = None,
) -> Callable:
    """Build the membership-aware round for `strategy`:

        round(x, y, agent_data, state, tracker, weights, budgets,
              active, prev_active) -> (x1, y1, state, tracker)

    `weights` come from `ElasticAggregator.weights(active)`, `budgets`
    and `active` from the `RoundSchedule`, `prev_active` from
    `ElasticAggregator.round_prev_active` (None = the naive no-rebase
    ablation; otherwise EF residual rows of non-continuing agents are
    re-anchored inside this jitted round); `tracker` is the per-agent
    anchor-gradient table (`init_tracker`; {} for strategies without
    corrections).  The phases are the engine's own — only the exchange
    differs, swapping the all-present anchor exchange for the tracker
    table refresh (strategies without corrections, FullSync included,
    skip it: membership enters purely through weights and budgets)."""
    phase_kwargs = {} if update_fn is None else {"update_fn": update_fn}
    phases: RoundPhases = make_phases(
        loss,
        strategy,
        num_local_steps,
        eta_x,
        eta_y,
        proj_x=proj_x,
        proj_y=proj_y,
        constrain_agents=constrain_agents,
        **phase_kwargs,
    )
    use_corr = bool(getattr(strategy, "use_correction", False))
    cdt = getattr(strategy, "correction_dtype", None)
    noise = getattr(strategy, "noise", None)
    momentum = float(getattr(strategy, "momentum", 0.0) or 0.0)
    gfn = grad_xy(loss)
    vgrad = jax.vmap(gfn, in_axes=(0, 0, 0))
    nvgrad = make_noise_vgrad(gfn, noise) if noise is not None else None

    def elastic_round(x, y, agent_data, state, tracker, weights, budgets,
                      active, prev_active):
        rs = phases.broadcast(
            x, y, agent_data, state,
            weights=weights, step_budgets=budgets, active=active,
        )
        if use_corr:
            # the anchor gradients at the CURRENT broadcast iterate feed
            # the shared membership-aware exchange (`tracker_exchange`);
            # a stochastic strategy draws them at eval index 0 of the
            # per-round noise keys `broadcast` just sampled (absent
            # agents' noisy rows are discarded by the active mask in
            # favor of their stale tracker rows, exactly like the
            # deterministic path)
            if noise is None:
                g = vgrad(rs.xs, rs.ys, agent_data)
            else:
                g = nvgrad(
                    noise_eval_keys(rs.noise_keys, 0),
                    rs.xs, rs.ys, agent_data,
                )
            (
                cx, cy, gbar_x, gbar_y, state, tab_x, tab_y
            ) = tracker_exchange(
                strategy, g.gx, g.gy, rs.state, active,
                tracker["gx"], tracker["gy"], cdt, prev_active,
            )
            rs = dataclasses.replace(
                rs, cx=cx, cy=cy, gbar_x=gbar_x, gbar_y=gbar_y,
                fused=bool(strategy.exact_correction) and not momentum,
                state=state, active=active,
            )
            tracker = {"gx": tab_x, "gy": tab_y}
        rs = phases.local_steps(rs, agent_data)
        x1, y1, state = phases.aggregate(rs)
        return x1, y1, state, tracker

    return elastic_round


def per_agent_bytes(
    strategy,
    x: Pytree,
    y: Pytree,
    num_local_steps: int,
    *,
    measured: bool = True,
) -> int:
    """One ACTIVE agent's per-round payload under an external schedule
    (measured packed buffers by default, the analytic price with
    measured=False).  Membership comes from the schedule, bypassing the
    strategy's own client sampling, so a participation-discounted price
    would double-discount — the price is taken at participation=1 (see
    `schedule_bytes`, which multiplies this by each round's active
    count).  ONE owner of that rule: `schedule_bytes`, the runners'
    `wire_report`, and the telemetry wire counters all derive from it."""
    from ..fed.transport import measured_bytes_per_round

    if getattr(strategy, "participation", 1.0) < 1.0:
        strategy = dataclasses.replace(strategy, participation=1.0)
    return (
        int(measured_bytes_per_round(strategy, x, y, num_local_steps))
        if measured
        else int(strategy.bytes_per_round(x, y, num_local_steps))
    )


def schedule_bytes(
    strategy,
    x: Pytree,
    y: Pytree,
    num_local_steps: int,
    schedule,
    *,
    measured: bool = True,
    pods=None,
) -> list:
    """Per-round TOTAL wire bytes of a run under `schedule`: the
    per-agent payload (measured packed buffers by default, the analytic
    price with measured=False) times the number of ACTIVE agents that
    round — departed agents move no bytes, so their payload leaves the
    account the round they leave.  Computed STREAMINGLY from the
    schedule's events (one pass over `ev.num_active`, never the dense
    [T, m] mask), so dense, chunked and sparse schedules price
    identically for the same rounds.

    With a `pods` `sim.PodMap`, the two-level tree adds the pod edge:
    each LIVE pod (>= 1 active agent) moves one partial payload up and
    one broadcast down per round (`fed.pods.pod_payload_bytes` — dense
    packed encoding, priced == measured by the PR-3 contract), while
    the per-agent payloads become agent <-> pod traffic.  The headline
    saving is the server fan-in: live_pods payloads instead of
    n_active.

    Under a schedule the strategy's OWN client sampling is bypassed
    (membership comes from the schedule), so a participation-discounted
    price (`PartialParticipation.bytes_per_round` scales by the expected
    sampled fraction) would double-discount: every active agent moves
    the full payload.  The price is therefore taken at participation=1
    (`per_agent_bytes`)."""
    per_agent = per_agent_bytes(
        strategy, x, y, num_local_steps, measured=measured
    )
    per_pod = 0
    if pods is not None:
        from ..fed.pods import pod_payload_bytes

        per_pod = pod_payload_bytes(x, y, measured=measured)
    totals = []
    for ev in schedule:
        total = per_agent * ev.num_active
        if pods is not None:
            total += per_pod * len(pods.live_pods(ev.active_ids))
        totals.append(total)
    return totals
