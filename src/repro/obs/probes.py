"""Invariant probes: pure functions over round state, shared by every runtime.

The paper's guarantees are measurable invariants — FedGDA-GT's linear
rate rests on the gradient-tracking identity `sum_i c_i = 0` holding
every round (PAPER.md, Theorem 1), error-feedback compressors must keep
their residual mass bounded, and the wire accounting must price what the
buffers actually carry.  Each probe here is a PURE function of explicit
inputs (correction stacks, tracker tables, strategy state, iterates) —
no runtime handles, no hidden state — so the sync, async and sparse
runtimes evaluate the SAME function on the state they hold, and a probe
mismatch localizes the faulty layer instead of the faulty runner.

Probe names (what runners emit under `Telemetry(probes=(...))`):

  gt_residual         ||sum_i c_i|| over both correction trees — the GT
                      invariant residual, ~fp-reduction noise when the
                      tracker math is right (`gt_residual`,
                      `corrections_from_table`, `anchor_corrections`)
  tracker_drift       ||column-sum(dense table) - running sum|| — the
                      `SparseTracker` running-sum representation vs the
                      table it stands for (`tracker_drift`,
                      `sparse_tracker_table`)
  ef_residual         per-buffer norms of the strategy's error-feedback
                      state ("ex" / "ey") (`ef_residual_norms`)
  priced_vs_measured  analytic `bytes_per_round` next to the packed-
                      buffer probe (`priced_vs_measured`)
  duality_gap         a caller-supplied gap oracle at the current
                      iterate (`duality_gap`)

Probes run on the host against materialized values; they never alter
the jitted round programs (sampling them cannot change iterates).
Stochastic strategies are probed with the NOISELESS anchor oracle —
the same convention `sim.init_tracker` pins.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def _global_norm(*trees) -> float:
    """l2 norm over every leaf of every tree, as one scalar.  Host-side
    numpy accumulation: true float64 even when jax_enable_x64 is off
    (float32 model runs), with no dtype-truncation warning."""
    total = 0.0
    for t in trees:
        for u in jax.tree.leaves(t):
            total += float(np.sum(np.square(np.asarray(u, np.float64))))
    return float(np.sqrt(total))


# ------------------------------------------------------------ GT invariant
def gt_residual(cx: Pytree, cy: Pytree,
                weights: Optional[jax.Array] = None) -> float:
    """The gradient-tracking invariant residual `||sum_i c_i||` over
    both correction trees (weighted when `weights` is given).  Exact
    corrections sum to zero by construction; anything above fp-reduction
    noise means the exchange (tracker table, re-anchoring, transform)
    broke the identity."""
    if weights is None:
        s = lambda t: jax.tree.map(lambda u: jnp.sum(u, axis=0), t)
    else:
        w = jnp.asarray(weights)
        s = lambda t: jax.tree.map(
            lambda u: jnp.tensordot(w, u, axes=(0, 0)), t
        )
    return _global_norm(s(cx), s(cy))


def corrections_from_table(tab_x: Pytree, tab_y: Pytree
                           ) -> Tuple[Pytree, Pytree]:
    """The uniform GT corrections a tracker table implies:
    `c_i = mean_j(table_j) - table_i` — exactly the exchange identity
    `sim.elastic.tracker_exchange` builds (before any strategy
    transform), reconstructible from the table alone.  This is the
    probe input every runtime can produce: the sync and async elastic
    runners hold the table directly, the sparse engine materializes it
    via `sparse_tracker_table`."""
    mean = lambda t: jax.tree.map(lambda u: jnp.mean(u, axis=0), t)
    gbar_x, gbar_y = mean(tab_x), mean(tab_y)
    sub = lambda g, t: jax.tree.map(lambda gb, u: gb[None] - u, g, t)
    return sub(gbar_x, tab_x), sub(gbar_y, tab_y)


def anchor_corrections(gfn: Callable, x: Pytree, y: Pytree,
                       agent_data: Pytree) -> Tuple[Pytree, Pytree]:
    """The full-participation corrections at the current server iterate,
    recomputed from scratch with the noiseless oracle (`gfn =
    grad_xy(loss)`): `c_i = gbar - g_i(x, y)`.  The probe input for
    non-elastic rounds, where no tracker table exists."""
    g = jax.vmap(gfn, in_axes=(None, None, 0))(x, y, agent_data)
    gbar_x = jax.tree.map(lambda u: jnp.mean(u, axis=0), g.gx)
    gbar_y = jax.tree.map(lambda u: jnp.mean(u, axis=0), g.gy)
    sub = lambda gb, t: jax.tree.map(lambda b, u: b[None] - u, gb, t)
    return sub(gbar_x, g.gx), sub(gbar_y, g.gy)


# ------------------------------------------------------- tracker vs sparse
def tracker_drift(tab_x: Pytree, tab_y: Pytree,
                  sum_gx: Pytree, sum_gy: Pytree) -> float:
    """||column-sum(table) - running sum|| across both trees: how far a
    `SparseTracker`'s incremental `sum += Σ(g_new - g_old)` has drifted
    from the dense table it represents.  Zero up to accumulated fp
    noise when commit/lookup bookkeeping is right."""
    colsum = lambda t: jax.tree.map(lambda u: jnp.sum(u, axis=0), t)
    diff = lambda a, b: jax.tree.map(jnp.subtract, colsum(a), b)
    return _global_norm(diff(tab_x, sum_gx), diff(tab_y, sum_gy))


def sparse_tracker_table(tracker, source, gfn: Callable,
                         chunk: int = 8192) -> Tuple[Pytree, Pytree]:
    """Materialize the dense tracker table a `sim.SparseTracker` stands
    for: touched agents' stored rows, untouched agents' anchor gradient
    recomputed at the tracker's init iterate (x0, y0) — the exact
    noiseless oracle `SparseTracker.init` summed.  O(m) compute and
    memory: a PROBE, deliberately not a runtime path."""
    m = tracker.m
    vgrad0 = jax.jit(
        lambda x, y, d: jax.vmap(gfn, in_axes=(None, None, 0))(x, y, d)
    )
    tabs_x, tabs_y = [], []
    chunk = max(1, min(int(chunk), m))
    for lo in range(0, m, chunk):
        ids = np.arange(lo, min(lo + chunk, m), dtype=np.int64)
        touched, rows_gx, rows_gy = tracker.lookup(ids)
        g0 = vgrad0(tracker.x0, tracker.y0, source.gather(ids))
        mask = jnp.asarray(touched)
        sel = lambda rows, anchors: jax.tree.map(
            lambda r, a: jnp.where(
                mask.reshape((-1,) + (1,) * (r.ndim - 1)), r, a
            ),
            rows, anchors,
        )
        tabs_x.append(sel(rows_gx, g0.gx))
        tabs_y.append(sel(rows_gy, g0.gy))
    cat = lambda parts: jax.tree.map(
        lambda *u: jnp.concatenate(u, axis=0), *parts
    )
    return cat(tabs_x), cat(tabs_y)


# ----------------------------------------------------------- EF residuals
def ef_residual_norms(state: Optional[Dict]) -> Dict[str, float]:
    """Per-buffer l2 norms of the strategy's error-feedback residuals
    (the "ex" / "ey" entries compressing strategies carry).  Empty dict
    for strategies without EF state — the probe is a no-op for them."""
    out: Dict[str, float] = {}
    for k in ("ex", "ey"):
        if state and k in state:
            out[k] = _global_norm(state[k])
    return out


# --------------------------------------------------------- wire accounting
def priced_vs_measured(strategy, x: Pytree, y: Pytree,
                       num_local_steps: int) -> Dict[str, int]:
    """The analytic per-round price next to the packed-buffer probe —
    the two byte accounts that must never silently drift
    (`fed.transport`)."""
    from ..fed.transport import measured_bytes_per_round

    return {
        "priced": int(strategy.bytes_per_round(x, y, num_local_steps)),
        "measured": int(
            measured_bytes_per_round(strategy, x, y, num_local_steps)
        ),
    }


# -------------------------------------------------------------- optimality
def duality_gap(gap_fn: Callable, x: Pytree, y: Pytree) -> float:
    """Caller-supplied duality-gap / eps oracle at the current iterate
    (e.g. `tree_sq_dist` to a known saddle on the quadratic game)."""
    return float(gap_fn(x, y))
