"""The telemetry sink: per-round / per-phase spans, counters, probes.

One owner for observability events across every runtime.  A `Telemetry`
instance is a host-side event sink the runners emit into — it never
appears inside a jitted program, so a runner given `telemetry=None`
executes the EXACT pre-telemetry trace (the bitwise pin shares the
noise/momentum elision discipline: disabled means absent, not zeroed;
tests/test_obs.py).  Enabled without probes it costs a few microseconds
of host bookkeeping per round (`benchmarks/obs.py` gates the wall-clock
overhead at <= 3%).

Event kinds (each event is one flat dict; the schema is the
observability contract in tests/README.md):

  span     {"kind": "span", "name": <phase or "round">, "round": t,
            "seconds": wall, ...}  — "round" spans carry the runtime
            ("sync" / "async" / "multihost" / "sparse" / elastic
            labels) and dispatch counts; phase spans are named after
            `core.engine.make_phases` (broadcast /
            exchange_corrections / local_steps / aggregate).  On the
            async runtimes a phase span measures dispatch + host time
            (jax's async dispatch returns before the device finishes);
            the sync runner can dispatch the four phases as separate
            jitted programs (`phase_spans=True` — fp-tolerance-equal to
            the fused round by the phases contract, tests/test_phases)
            for genuine per-phase wall-clock.
  counter  {"kind": "counter", "name": ..., "round": t, "value": n, ...}
           — wire bytes ("wire_bytes" with per_agent / n_active,
           "gathered_payload_bytes" on the multihost gather), peak
           memory, active-set sizes.
  probe    {"kind": "probe", "name": ..., "round": t, "value": ...} —
           sampled invariant probes (`repro.obs.probes`): opt in by
           name via `probes=(...)`, sampled every `probe_every` rounds.
  event    {"kind": "event", "name": ..., ...} — discrete occurrences:
           "shard_skipped" (async elastic), "realign" / "dense_fallback"
           (sparse engine).

The `round` field defaults to the sink's `current_round`, set by
`begin_round` — emitters deep inside a runner (a skipped shard, a wire
gather) need no round plumbing.  Attach a `repro.obs.RunLedger` to
stream every event to JSONL as it is emitted; `profile_rounds` wraps the
listed rounds in a `jax.profiler` trace (written under `profile_dir`).
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Callable, Dict, List, Optional, Sequence


class Telemetry:
    """Host-side observability sink (see module docstring).

    Off is `None`, not a disabled instance: runners guard every emit
    site with `if telemetry is not None`, so the disabled path is the
    pre-telemetry code verbatim.
    """

    def __init__(
        self,
        ledger=None,
        probes: Sequence[str] = (),
        probe_every: int = 1,
        phase_spans: bool = False,
        gap_fn: Optional[Callable] = None,
        profile_dir: Optional[str] = None,
        profile_rounds: Sequence[int] = (),
    ):
        self.events: List[Dict[str, Any]] = []
        self.ledger = ledger
        self.probes = frozenset(probes)
        self.probe_every = max(1, int(probe_every))
        self.phase_spans = bool(phase_spans)
        #: duality-gap oracle for the "duality_gap" probe — supplied by
        #: the caller (the saddle point is problem knowledge, not ours)
        self.gap_fn = gap_fn
        self.profile_dir = profile_dir
        self.profile_rounds = frozenset(int(r) for r in profile_rounds)
        self.current_round: Optional[int] = None
        self._profiling = False

    # ------------------------------------------------------------- emit
    def emit(self, kind: str, name: str, round: Optional[int] = None,
             **attrs) -> Dict[str, Any]:
        ev: Dict[str, Any] = {"kind": kind, "name": name}
        r = self.current_round if round is None else round
        if r is not None:
            ev["round"] = int(r)
        ev.update(attrs)
        self.events.append(ev)
        if self.ledger is not None:
            self.ledger.write(ev)
        return ev

    def counter(self, name: str, value, round: Optional[int] = None,
                **attrs) -> Dict[str, Any]:
        return self.emit("counter", name, round=round, value=int(value),
                         **attrs)

    @contextlib.contextmanager
    def span(self, name: str, round: Optional[int] = None, **attrs):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.emit("span", name, round=round,
                      seconds=time.perf_counter() - t0, **attrs)

    def round_event(self, t: int, runtime: str, seconds: float,
                    **attrs) -> Dict[str, Any]:
        """The per-round span, emitted post-hoc from the runner's own
        wall-clock measurement (the same number its history records)."""
        return self.emit("span", "round", round=t, seconds=float(seconds),
                         runtime=runtime, **attrs)

    # ------------------------------------------------------------ rounds
    def begin_round(self, t: int) -> None:
        self.current_round = int(t)
        if self.profile_dir is not None and t in self.profile_rounds:
            self.start_profile()

    def end_round(self, t: int) -> None:
        if self._profiling:
            self.stop_profile()

    def start_profile(self) -> None:
        if self._profiling:
            return
        import jax

        jax.profiler.start_trace(self.profile_dir)
        self._profiling = True

    def stop_profile(self) -> None:
        if not self._profiling:
            return
        import jax

        jax.profiler.stop_trace()
        self._profiling = False
        self.emit("event", "profile_trace", dir=self.profile_dir)

    # ------------------------------------------------------------ probes
    def probe_due(self, name: str, t: int) -> bool:
        return name in self.probes and t % self.probe_every == 0

    def probe_value(self, name: str, t: int, value, **attrs) -> Dict:
        return self.emit("probe", name, round=t, value=value, **attrs)

    # ----------------------------------------------------------- queries
    def series(self, kind: Optional[str] = None,
               name: Optional[str] = None) -> List[Dict[str, Any]]:
        return [
            e for e in self.events
            if (kind is None or e["kind"] == kind)
            and (name is None or e["name"] == name)
        ]

    def probe_series(self, name: str) -> List[Any]:
        return [e["value"] for e in self.series("probe", name)]


def maybe_span(telemetry: Optional[Telemetry], name: str, **attrs):
    """`telemetry.span(...)` when enabled, a no-op context otherwise —
    lets runner phase blocks stay un-duplicated across the two modes
    (the disabled branch is a bare `nullcontext`, zero JAX-graph
    change)."""
    if telemetry is None:
        return contextlib.nullcontext()
    return telemetry.span(name, **attrs)
