"""The structured run ledger: a JSONL event stream + a run manifest.

A telemetry-enabled run leaves two files under its ledger directory:

  events.jsonl   one JSON object per telemetry event, appended as
                 emitted (the `Telemetry` sink streams through
                 `RunLedger.write`) — the event schema is the
                 observability contract (tests/README.md);
  manifest.json  everything needed to re-run or audit the run: the
                 resolved config, the strategy's class / name / knob
                 signature (`fed.comm.knob_signature` — the same
                 collision-proof key `comm_table` rows use), the seed
                 folds (the dedicated `NOISE_STREAM` and
                 `AVAILABILITY_STREAM` constants plus the folded keys
                 they produce), and the schedule digest
                 (`ScheduleStats.summary_trace` — per-round CRC32 of
                 the sorted active ids, representation-independent).

Consumers (`benchmarks/obs.py`, post-hoc analysis) read the ledger back
with `RunLedger.events` / `RunLedger.manifest` instead of recomputing —
byte truth, round timings and probe values have ONE exported form.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional


def _jsonable(o):
    """JSON default: numpy / jax scalars and arrays to plain python."""
    import numpy as np

    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    if hasattr(o, "tolist"):  # jax arrays
        return o.tolist()
    return str(o)


class RunLedger:
    """Append-only JSONL event stream + manifest in one directory."""

    EVENTS = "events.jsonl"
    MANIFEST = "manifest.json"

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self._fh = None

    # ------------------------------------------------------------ write
    def write(self, event: Dict[str, Any]) -> None:
        if self._fh is None:
            self._fh = open(
                os.path.join(self.directory, self.EVENTS), "a"
            )
        self._fh.write(json.dumps(event, default=_jsonable) + "\n")
        self._fh.flush()

    def write_manifest(self, manifest: Dict[str, Any]) -> str:
        path = os.path.join(self.directory, self.MANIFEST)
        with open(path, "w") as f:
            json.dump(manifest, f, indent=2, default=_jsonable)
        return path

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # ------------------------------------------------------------- read
    @classmethod
    def events(cls, directory: str) -> List[Dict[str, Any]]:
        path = os.path.join(directory, cls.EVENTS)
        if not os.path.exists(path):
            return []
        with open(path) as f:
            return [json.loads(line) for line in f if line.strip()]

    @classmethod
    def manifest(cls, directory: str) -> Optional[Dict[str, Any]]:
        path = os.path.join(directory, cls.MANIFEST)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)


def run_manifest(
    *,
    config: Optional[Dict[str, Any]] = None,
    strategy=None,
    seed: Optional[int] = None,
    noise_seed: Optional[int] = None,
    availability_seed: Optional[int] = None,
    schedule=None,
    extra: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Assemble the run manifest (see module docstring).  Every section
    is optional — pass what the run actually resolved.  Seed folds are
    always recorded with their dedicated stream constants, so an audit
    can verify no stream aliases another."""
    import numpy as np

    from ..fed.noise import NOISE_STREAM, noise_key
    from ..sim.schedule import AVAILABILITY_STREAM, availability_key

    manifest: Dict[str, Any] = {}
    if config is not None:
        manifest["config"] = dict(config)
    if strategy is not None:
        from ..fed.comm import knob_signature

        manifest["strategy"] = {
            "class": type(strategy).__name__,
            "name": getattr(strategy, "name", type(strategy).__name__),
            "signature": knob_signature(strategy),
        }
    seeds: Dict[str, Any] = {
        "noise_stream": NOISE_STREAM,
        "availability_stream": AVAILABILITY_STREAM,
    }
    if seed is not None:
        seeds["seed"] = int(seed)
    if noise_seed is not None:
        seeds["noise_seed"] = int(noise_seed)
        seeds["noise_key"] = np.asarray(noise_key(noise_seed)).tolist()
    if availability_seed is not None:
        seeds["availability_seed"] = int(availability_seed)
        seeds["availability_key"] = np.asarray(
            availability_key(availability_seed)
        ).tolist()
    manifest["seeds"] = seeds
    if schedule is not None:
        manifest["schedule"] = dict(schedule.summary_trace())
    if extra:
        manifest.update(extra)
    return manifest
