"""Unified telemetry: phase spans, invariant probes, and the run ledger.

One owner for observability across every runtime (`fed.runtime`,
`fed.async_runtime`, `launch.multihost`, `sim.sparse`):

  telemetry  the `Telemetry` event sink — per-round / per-phase spans
             (named after `core.engine.make_phases`), counters (wire
             bytes, active-set sizes, peak memory) and sampled probes;
             off by default (`telemetry=None` runs the pre-telemetry
             code verbatim — the bitwise pin, tests/test_obs.py)
  probes     pure invariant probes: the GT identity residual
             `||sum_i c_i||`, tracker-table vs `SparseTracker` drift,
             EF residual norms, priced-vs-measured bytes, duality gap —
             the same function on every runtime, so a mismatch
             localizes the faulty layer
  ledger     the structured export: JSONL event stream + run manifest
             (resolved config, strategy knob signature, seed folds,
             schedule digest), written by `launch.train --telemetry`
             and consumed by `benchmarks/`
  memory     `peak_memory` (moved from `benchmarks.common`, shim kept)

The overhead gate lives in `benchmarks/obs.py`: telemetry enabled
without probes must stay within 3% of disabled wall clock.
"""
from . import probes
from .ledger import RunLedger, run_manifest
from .memory import peak_memory
from .telemetry import Telemetry, maybe_span

__all__ = [
    "RunLedger",
    "Telemetry",
    "maybe_span",
    "peak_memory",
    "probes",
    "run_manifest",
]
