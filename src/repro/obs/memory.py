"""Peak-memory measurement (moved from `benchmarks.common` — one owner).

`benchmarks.common.peak_memory` remains as a re-export shim, so the
elastic memory gate (`benchmarks/elastic.py --check-pods`) and ad-hoc
callers keep working; new callers should import from `repro.obs` and
pass a `Telemetry` sink so the measurement lands in the run ledger.
"""
from __future__ import annotations

from typing import Dict, Optional


def peak_memory(fn, *args, telemetry=None, label: Optional[str] = None,
                **kwargs) -> Dict:
    """Run fn(*args, **kwargs) and report its peak memory footprint:

      host_peak_bytes    tracemalloc's peak traced python/numpy
                         allocation during the call (deltas against the
                         running baseline — tracing starts/stops here);
      live_buffer_bytes  a census of live jax device buffers at the end
                         of the call (`jax.live_arrays`), the device-
                         side residency the traced-malloc peak misses;
      result             fn's return value.

    This is the measurement behind the O(active) memory gate: the mega
    population run's peak must scale with the ACTIVE set (+ pods), not
    with the m = 1e6 registry (`benchmarks/elastic.py --check`).

    With a `telemetry` sink the measurement is also emitted as a
    "peak_memory" counter event (value = host peak; the device census
    rides as an attribute), so ledgers carry memory truth alongside
    wire and timing truth."""
    import tracemalloc

    import jax

    tracemalloc.start()
    try:
        result = fn(*args, **kwargs)
        _, host_peak = tracemalloc.get_traced_memory()
    finally:
        tracemalloc.stop()
    live = sum(
        a.size * a.dtype.itemsize
        for a in jax.live_arrays()
        if hasattr(a, "size") and hasattr(a, "dtype")
    )
    rec = {
        "host_peak_bytes": int(host_peak),
        "live_buffer_bytes": int(live),
        "result": result,
    }
    if telemetry is not None:
        attrs = {"live_buffer_bytes": rec["live_buffer_bytes"]}
        if label is not None:
            attrs["label"] = label
        telemetry.counter(
            "peak_memory", rec["host_peak_bytes"], **attrs
        )
    return rec
