"""Fused wire-payload kernels: select + quantize + bit-pack in one pass.

`compress_correction.py` (PR 2) fuses the compressed-correction MATH but
still materializes a dense masked tree, so the collectives move dense
tensors and `bytes_per_round` prices traffic that never happens.  These
kernels produce (and consume) the actual packed wire format of
`repro.fed.transport`:

  pack_payload_2d    ceff [R, C] -> (data, idx, scale, resid): feedback
                     injection, exact-k selection, QSGD quantization,
                     index extraction and uint32 bit-packing fused in one
                     VMEM pass per row block (the residual never leaves
                     VMEM between the select and the pack);
  unpack_payload_2d  (data, idx, scale) -> dense chat [R, C]: word
                     unpack, dequantization and the scatter-add back to
                     the dense correction, one VMEM pass.

The grid tiles rows only, like compress_correction: per-row top-k, the
per-row quantization scale and the per-row index extraction all need the
full C-length row resident in VMEM, so the fused path requires
lane-aligned leaves (C % 128 == 0).  The kernel bodies ARE the oracles —
each invokes `ref.pack_payload_ref` / `ref.decode_payload_ref` on its
VMEM-resident block, so kernel == oracle by construction on the same
uniform draws: data and idx agree BITWISE, values to <= 1 ulp (the
kernel compiles as one XLA unit whose fusion may round differently).

Like compress_correction, randomness arrives as iid U[0,1) inputs rather
than an in-kernel PRNG so the kernel, the pure-jnp oracle and the
strategy fallback can be compared exactly instead of distributionally.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .compress_correction import LANE, _operand, _row_block


def _pack_kernel(c_ref, e_ref, us_ref, ur_ref,
                 data_ref, idx_ref, scale_ref, res_ref, *,
                 k: int, bits: int, mode: str, encoding: str,
                 has_feedback: bool, needs_sel: bool):
    # the oracle IS the kernel body — one implementation of the encode
    # math, so kernel == oracle by construction (not by transcription);
    # unused operands are trace-time None so the dummy tiles are never
    # read
    data, idx, scale, resid = ref.pack_payload_ref(
        c_ref[...],
        e_ref[...] if has_feedback else None,
        us_ref[...] if needs_sel else None,
        ur_ref[...] if bits < 32 else None,
        k=k, bits=bits, mode=mode, encoding=encoding,
        index_dtype=idx_ref.dtype,
    )
    data_ref[...] = data
    idx_ref[...] = idx
    scale_ref[...] = scale.astype(scale_ref.dtype)
    res_ref[...] = resid


def pack_payload_2d(
    c: jax.Array,  # [R, C], C % 128 == 0
    e: Optional[jax.Array],  # [R, C] feedback residual, or None
    u_sel: Optional[jax.Array],  # [R, C] U[0,1) — rand-k scores
    u_rnd: Optional[jax.Array],  # [R, C] U[0,1) — stochastic rounding
    *,
    k: int,
    bits: int = 32,
    mode: str = "topk",
    encoding: str = "quant",
    index_dtype=jnp.int32,
    scale_dtype=None,
    block_rows: int = 8,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One fused VMEM pass of (feedback-inject, exact-k select, quantize,
    index-extract, bit-pack, residual-update) per row block.  Returns
    (data, idx, scale, resid) exactly like `ref.pack_payload_ref` —
    data/idx bitwise-equal, scale/resid to the last ulp."""
    R, C = c.shape
    assert C % LANE == 0, f"fused path needs lane-aligned leaves, got C={C}"
    assert mode in ("topk", "randk"), mode
    assert encoding in ("quant", "quant_dense", "sparse", "dense"), encoding
    if bits < 32:
        assert u_rnd is not None, "stochastic rounding (bits<32) needs u_rnd"
    else:
        assert encoding not in ("quant", "quant_dense"), (
            "bit-packing needs bits < 32"
        )
    if mode == "randk" and k < C:
        assert u_sel is not None, "rand-k selection needs u_sel scores"
    if encoding in ("quant", "quant_dense"):
        n = C if encoding == "quant_dense" else k
        data_shape, data_dtype = (R, ref.word_layout(n, bits)[2]), jnp.uint32
    elif encoding == "sparse":
        data_shape, data_dtype = (R, k), c.dtype
    else:
        data_shape, data_dtype = (R, C), c.dtype
    scale_dtype = scale_dtype or ref.compute_dtype(c.dtype)
    br = _row_block(R, block_rows)
    spec = pl.BlockSpec((br, C), lambda i: (i, 0))
    e_arr, e_spec = _operand(e, c.dtype, spec)
    us_arr, us_spec = _operand(u_sel, c.dtype, spec)
    ur_arr, ur_spec = _operand(u_rnd, c.dtype, spec)
    kern = functools.partial(
        _pack_kernel, k=k, bits=bits, mode=mode, encoding=encoding,
        has_feedback=e is not None,
        needs_sel=mode == "randk" and k < C,
    )
    row_spec = lambda w: pl.BlockSpec((br, w), lambda i: (i, 0))
    return pl.pallas_call(
        kern,
        grid=(R // br,),
        in_specs=[spec, e_spec, us_spec, ur_spec],
        out_specs=(row_spec(data_shape[1]), row_spec(k), row_spec(1), spec),
        out_shape=(
            jax.ShapeDtypeStruct(data_shape, data_dtype),
            jax.ShapeDtypeStruct((R, k), index_dtype),
            jax.ShapeDtypeStruct((R, 1), scale_dtype),
            jax.ShapeDtypeStruct(c.shape, c.dtype),
        ),
        interpret=interpret,
    )(c, e_arr, us_arr, ur_arr)


def _unpack_kernel(data_ref, idx_ref, scale_ref, out_ref, *,
                   k: int, bits: int, encoding: str, cols: int):
    # the oracle IS the kernel body — one implementation of the decode
    # math, so kernel == oracle by construction (not by transcription)
    out_ref[...] = ref.decode_payload_ref(
        data_ref[...], idx_ref[...], scale_ref[...],
        cols=cols, dtype=out_ref.dtype, k=k, bits=bits, encoding=encoding,
    )


def unpack_payload_2d(
    data: jax.Array,
    idx: jax.Array,
    scale: jax.Array,
    *,
    cols: int,
    dtype,
    k: int,
    bits: int = 32,
    encoding: str = "quant",
    block_rows: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Fused unpack + dequantize + scatter-add back to the dense [R, cols]
    compressed correction; bitwise-equal to `ref.decode_payload_ref`."""
    assert cols % LANE == 0, f"fused path needs lane-aligned leaves, got {cols}"
    assert encoding in ("quant", "quant_dense", "sparse", "dense"), encoding
    R = data.shape[0]
    br = _row_block(R, block_rows)
    row_spec = lambda w: pl.BlockSpec((br, w), lambda i: (i, 0))
    kern = functools.partial(
        _unpack_kernel, k=k, bits=bits, encoding=encoding, cols=cols
    )
    return pl.pallas_call(
        kern,
        grid=(R // br,),
        in_specs=[row_spec(data.shape[1]), row_spec(idx.shape[1]),
                  row_spec(scale.shape[1])],
        out_specs=row_spec(cols),
        out_shape=jax.ShapeDtypeStruct((R, cols), dtype),
        interpret=interpret,
    )(data, idx, scale)
