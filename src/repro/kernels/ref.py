"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gt_update_ref(z, g, c, eta: float, sign: float):
    """Fused FedGDA-GT inner update: z + sign*eta*(g + c)."""
    return z + sign * eta * (g + c.astype(g.dtype))


def compute_dtype(dtype):
    """f64 in, f64 math; anything narrower (f32/bf16/f16/float8) runs in
    f32.  Explicit because jnp.promote_types has no implicit promotion
    path for the float8 correction dtypes."""
    return jnp.float64 if dtype == jnp.float64 else jnp.float32


def stochastic_quantize(kept, u_rnd, bits: int, ct):
    """QSGD core shared VERBATIM by the oracle and the Pallas kernel
    (`compress_correction._compress_kernel` calls this inside the kernel
    body): symmetric s = 2^(bits-1)-1 grid, per-row max-abs scale,
    floor + Bernoulli(frac) rounding — unbiased given u_rnd ~ U[0,1).
    The dequant is a constant-reciprocal multiply, not q*(safe/s): XLA
    compiles the division differently inside vs outside the
    interpret-mode kernel (1 f32 ulp), enough to flip a bf16 rounding
    boundary — sharing one implementation keeps kernel == oracle."""
    s = float(2 ** (bits - 1) - 1)
    scale = jnp.max(jnp.abs(kept), axis=-1, keepdims=True)
    safe = jnp.where(scale > 0, scale, jnp.ones_like(scale))
    u = kept * (s / safe)
    lo = jnp.floor(u)
    q = lo + (u_rnd.astype(ct) < u - lo).astype(ct)
    return q * (safe * (1.0 / s))


def exact_k_mask(score, k: int):
    """Boolean mask keeping exactly k entries per row of `score` [R, C]:
    the k largest, earliest index winning ties (the `jax.lax.top_k`
    order, so a >=threshold mask can never degenerate to dense when the
    k-th score is tied or zero)."""
    n = score.shape[-1]
    if k >= n:
        return jnp.ones(score.shape, bool)
    thr = jax.lax.top_k(score, k)[0][..., -1:]
    gt = score > thr
    n_gt = jnp.sum(gt, axis=-1, keepdims=True)
    tie = score == thr
    tie_rank = jnp.cumsum(tie.astype(jnp.int32), axis=-1)
    return gt | (tie & (tie_rank <= k - n_gt))


def compress_correction_ref(c, e, u_sel, u_rnd, *, k: int, bits: int,
                            mode: str = "topk"):
    """Oracle of the fused compress-correction kernel on one flattened
    leaf c [R, C] (R = agents): error-feedback injection, exact-k
    selection, QSGD stochastic quantization, residual update.

      ceff = c + e                         (e may be None)
      kept = ceff * exact_k_mask(score)    score = |ceff| (topk) | u_sel (randk)
      chat = round_stoch(kept/scale * s) * scale/s   per-row scale = max|kept|,
                                           s = 2^(bits-1)-1; identity for bits>=32
      resid = ceff - chat                  (what compression+quantization dropped)

    u_sel / u_rnd are iid U[0,1) arrays of c's shape (keeping the k largest
    uniforms IS a uniform k-subset; round_stoch(u) = floor(u) + [u_rnd < frac]).
    Returns (chat, resid), both in c.dtype.  Math runs in
    `compute_dtype(c.dtype)` exactly like the kernel."""
    ct = compute_dtype(c.dtype)
    ceff = c.astype(ct) if e is None else c.astype(ct) + e.astype(ct)
    n = ceff.shape[-1]
    if k < n:
        score = jnp.abs(ceff) if mode == "topk" else u_sel.astype(ct)
        kept = jnp.where(exact_k_mask(score, k), ceff, jnp.zeros_like(ceff))
    else:
        kept = ceff
    if bits < 32:
        chat = stochastic_quantize(kept, u_rnd, bits, ct)
    else:
        chat = kept
    chat = chat.astype(c.dtype)
    resid = (ceff - chat.astype(ct)).astype(c.dtype)
    return chat, resid


def flash_attention_ref(
    q, k, v, *, causal: bool = True, window: int = 0, softcap: float = 0.0
):
    """q [B,H,Sq,hd], k/v [B,H,Skv,hd] (heads already grouped/repeated)."""
    hd = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    s = s / jnp.sqrt(jnp.float32(hd))
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    Sq, Skv = q.shape[2], k.shape[2]
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qp >= kp
    if window > 0:
        mask &= qp - kp < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def ssm_scan_ref(da, dbx, c_coef, state0):
    """Sequential oracle of h_t = da_t * h_{t-1} + dbx_t;  y_t = <h_t, c_t>.

    da  [S, d, N] (broadcastable), dbx [S, d, N], c_coef [S, N],
    state0 [d, N].  Returns (y [S, d], final_state [d, N]).
    """

    def step(h, inp):
        a, b, cc = inp
        h = a * h + b
        return h, jnp.einsum("dn,n->d", h, cc)

    state, y = jax.lax.scan(step, state0, (da, dbx, c_coef))
    return y, state
