"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gt_update_ref(z, g, c, eta: float, sign: float):
    """Fused FedGDA-GT inner update: z + sign*eta*(g + c)."""
    return z + sign * eta * (g + c.astype(g.dtype))


def flash_attention_ref(
    q, k, v, *, causal: bool = True, window: int = 0, softcap: float = 0.0
):
    """q [B,H,Sq,hd], k/v [B,H,Skv,hd] (heads already grouped/repeated)."""
    hd = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    s = s / jnp.sqrt(jnp.float32(hd))
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    Sq, Skv = q.shape[2], k.shape[2]
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qp >= kp
    if window > 0:
        mask &= qp - kp < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def ssm_scan_ref(da, dbx, c_coef, state0):
    """Sequential oracle of h_t = da_t * h_{t-1} + dbx_t;  y_t = <h_t, c_t>.

    da  [S, d, N] (broadcastable), dbx [S, d, N], c_coef [S, N],
    state0 [d, N].  Returns (y [S, d], final_state [d, N]).
    """

    def step(h, inp):
        a, b, cc = inp
        h = a * h + b
        return h, jnp.einsum("dn,n->d", h, cc)

    state, y = jax.lax.scan(step, state0, (da, dbx, c_coef))
    return y, state
