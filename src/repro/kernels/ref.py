"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gt_update_ref(z, g, c, eta: float, sign: float):
    """Fused FedGDA-GT inner update: z + sign*eta*(g + c)."""
    return z + sign * eta * (g + c.astype(g.dtype))


def compute_dtype(dtype):
    """f64 in, f64 math; anything narrower (f32/bf16/f16/float8) runs in
    f32.  Explicit because jnp.promote_types has no implicit promotion
    path for the float8 correction dtypes."""
    return jnp.float64 if dtype == jnp.float64 else jnp.float32


def quantize_levels(kept, u_rnd, bits: int, ct):
    """QSGD quantization half: map each row of `kept` onto the symmetric
    s = 2^(bits-1)-1 grid with a per-row max-abs scale and round
    STOCHASTICALLY (floor + Bernoulli(frac)) — unbiased given
    u_rnd ~ U[0,1).  Returns (q, scale): integer-valued grid levels in
    [-s, s] (carried in the compute dtype) and the per-row scale.  The
    wire transport stores exactly (q + s, scale), so this function is
    the single owner of the level math for the dense path, the pack
    kernel, and the packed encoder alike."""
    s = float(2 ** (bits - 1) - 1)
    scale = jnp.max(jnp.abs(kept), axis=-1, keepdims=True)
    safe = jnp.where(scale > 0, scale, jnp.ones_like(scale))
    u = kept * (s / safe)
    lo = jnp.floor(u)
    q = lo + (u_rnd.astype(ct) < u - lo).astype(ct)
    # fp rounding can land u an ulp outside [-s, s] (|kept| == scale with
    # s/safe rounded up), making floor/ceil reach -s-1 or s+1; -s-1 would
    # wrap to 0xFFFFFFFF as a packed level and corrupt every neighbour in
    # its uint32 word, so clamp to the grid in the ONE shared quantizer —
    # dense path, fused kernels and wire codec stay bitwise-identical
    return jnp.clip(q, -s, s), scale


def dequantize_levels(q, scale, bits: int, ct):
    """QSGD dequantization half: q * scale / s, written as a
    constant-reciprocal multiply, not q*(safe/s): XLA compiles the
    division differently inside vs outside the interpret-mode kernel
    (1 f32 ulp), enough to flip a bf16 rounding boundary — sharing one
    implementation keeps kernel == oracle == wire decode bitwise."""
    s = float(2 ** (bits - 1) - 1)
    safe = jnp.where(scale > 0, scale, jnp.ones_like(scale))
    return q * (safe * (1.0 / s))


def stochastic_quantize(kept, u_rnd, bits: int, ct):
    """QSGD core shared VERBATIM by the oracle and the Pallas kernels
    (`compress_correction._compress_kernel` calls this inside the kernel
    body): quantize_levels . dequantize_levels, so the dense compressed
    correction and the decoded wire payload are the same bits."""
    q, scale = quantize_levels(kept, u_rnd, bits, ct)
    return dequantize_levels(q, scale, bits, ct)


def exact_k_mask(score, k: int):
    """Boolean mask keeping exactly k entries per row of `score` [R, C]:
    the k largest, earliest index winning ties (the `jax.lax.top_k`
    order, so a >=threshold mask can never degenerate to dense when the
    k-th score is tied or zero)."""
    n = score.shape[-1]
    if k >= n:
        return jnp.ones(score.shape, bool)
    thr = jax.lax.top_k(score, k)[0][..., -1:]
    gt = score > thr
    n_gt = jnp.sum(gt, axis=-1, keepdims=True)
    tie = score == thr
    tie_rank = jnp.cumsum(tie.astype(jnp.int32), axis=-1)
    return gt | (tie & (tie_rank <= k - n_gt))


def compress_correction_ref(c, e, u_sel, u_rnd, *, k: int, bits: int,
                            mode: str = "topk"):
    """Oracle of the fused compress-correction kernel on one flattened
    leaf c [R, C] (R = agents): error-feedback injection, exact-k
    selection, QSGD stochastic quantization, residual update.

      ceff = c + e                         (e may be None)
      kept = ceff * exact_k_mask(score)    score = |ceff| (topk) | u_sel (randk)
      chat = round_stoch(kept/scale * s) * scale/s   per-row scale = max|kept|,
                                           s = 2^(bits-1)-1; identity for bits>=32
      resid = ceff - chat                  (what compression+quantization dropped)

    u_sel / u_rnd are iid U[0,1) arrays of c's shape (keeping the k largest
    uniforms IS a uniform k-subset; round_stoch(u) = floor(u) + [u_rnd < frac]).
    Returns (chat, resid), both in c.dtype.  Math runs in
    `compute_dtype(c.dtype)` exactly like the kernel."""
    ct = compute_dtype(c.dtype)
    ceff = c.astype(ct) if e is None else c.astype(ct) + e.astype(ct)
    n = ceff.shape[-1]
    if k < n:
        score = jnp.abs(ceff) if mode == "topk" else u_sel.astype(ct)
        kept = jnp.where(exact_k_mask(score, k), ceff, jnp.zeros_like(ceff))
    else:
        kept = ceff
    if bits < 32:
        chat = stochastic_quantize(kept, u_rnd, bits, ct)
    else:
        chat = kept
    chat = chat.astype(c.dtype)
    resid = (ceff - chat.astype(ct)).astype(c.dtype)
    return chat, resid


# ----------------------------------------------------------------------
# packed (value, index) wire payloads — oracles of kernels/pack_payload.py
# ----------------------------------------------------------------------
_WORD_BITS = 32
_STORAGE_WIDTHS = (2, 4, 8, 16, 32)


def storage_bits(bits: int) -> int:
    """Wire width of one quantized level: the smallest power-of-two
    sub-word width (2/4/8/16/32) holding `bits` bits, so levels never
    straddle a uint32 word boundary and packing stays a vectorized
    shift+sum.  The payload pricing uses the same function, so priced
    and packed widths agree by construction."""
    for w in _STORAGE_WIDTHS:
        if w >= bits:
            return w
    raise ValueError(f"bits={bits} exceeds the 32-bit word")


def word_layout(k: int, bits: int):
    """(storage bits, levels per uint32 word, words per row) for k kept
    levels of `bits`-bit quantized values."""
    sb = storage_bits(bits)
    per_word = _WORD_BITS // sb
    return sb, per_word, -(-k // per_word)


def kept_indices(mask, k: int):
    """Column indices [.., k] (ascending, int32) of the k True entries
    per row of `mask` — the index half of a packed sparse payload.
    Kept columns sort below C + anything, so one jnp.sort suffices."""
    C = mask.shape[-1]
    it = jax.lax.broadcasted_iota(jnp.int32, mask.shape, mask.ndim - 1)
    return jnp.sort(jnp.where(mask, it, it + C), axis=-1)[..., :k]


def pack_words(levels, bits: int):
    """Bit-pack non-negative integer levels [.., k] (uint32, each <
    2^storage_bits) into uint32 words [.., W], level i of a row landing
    at bit (i % per_word) * storage_bits of word i // per_word."""
    k = levels.shape[-1]
    sb, per_word, W = word_layout(k, bits)
    pad = [(0, 0)] * (levels.ndim - 1) + [(0, W * per_word - k)]
    lv = jnp.pad(levels, pad).reshape(*levels.shape[:-1], W, per_word)
    shifts = jax.lax.broadcasted_iota(jnp.uint32, lv.shape, lv.ndim - 1)
    return jnp.sum(
        jnp.left_shift(lv, shifts * jnp.uint32(sb)),
        axis=-1,
        dtype=jnp.uint32,  # disjoint bit ranges: sum == bitwise or
    )


def unpack_words(words, k: int, bits: int):
    """Inverse of pack_words: uint32 words [.., W] -> levels [.., k]."""
    sb, per_word, W = word_layout(k, bits)
    lv = jnp.broadcast_to(
        words[..., None], (*words.shape, per_word)
    )
    shifts = jax.lax.broadcasted_iota(jnp.uint32, lv.shape, lv.ndim - 1)
    lv = jnp.right_shift(lv, shifts * jnp.uint32(sb)) & jnp.uint32(2**sb - 1)
    return lv.reshape(*words.shape[:-1], W * per_word)[..., :k]


def pack_payload_ref(c, e, u_sel, u_rnd, *, k: int, bits: int,
                     mode: str = "topk", encoding: str = "quant",
                     index_dtype=jnp.int32):
    """Oracle of the fused pack-payload kernel on one flattened leaf
    c [R, C]: error-feedback injection, exact-k selection, QSGD
    quantization, then ENCODING as an actual wire buffer instead of a
    dense masked tree.  Returns (data, idx, scale, resid):

      data   encoding == "quant":  uint32 words [R, W] of bit-packed
                                   levels q + s (see pack_words)
             encoding == "quant_dense": all C levels bit-packed, no
                                   indices (masked levels encode 0)
             encoding == "sparse": kept values [R, k] in c.dtype
             encoding == "dense":  the full masked/quantized chat [R, C]
      idx    kept column indices [R, k] (ascending; iota when k == C)
      scale  per-row quantization scale [R, 1] in compute_dtype(c.dtype)
             (zeros when bits >= 32)
      resid  ceff - chat in c.dtype (the error-feedback update), where
             chat is exactly what decode_payload_ref reconstructs

    The selection/quantization math is compress_correction_ref's, on the
    same uniform draws — so the packed payload round-trips to the dense
    compressed correction bitwise (mod -0.0 lost to the scatter-add)."""
    ct = compute_dtype(c.dtype)
    ceff = c.astype(ct) if e is None else c.astype(ct) + e.astype(ct)
    n = ceff.shape[-1]
    if k < n:
        score = jnp.abs(ceff) if mode == "topk" else u_sel.astype(ct)
        mask = exact_k_mask(score, k)
        kept = jnp.where(mask, ceff, jnp.zeros_like(ceff))
        idx = kept_indices(mask, k)
    else:
        kept = ceff
        idx = jax.lax.broadcasted_iota(
            jnp.int32, (*ceff.shape[:-1], k), ceff.ndim - 1
        )
    if bits < 32:
        q, scale = quantize_levels(kept, u_rnd, bits, ct)
        chat = dequantize_levels(q, scale, bits, ct)
    else:
        q, scale = kept, jnp.zeros((*ceff.shape[:-1], 1), ct)
        chat = kept
    chat_out = chat.astype(c.dtype)
    resid = (ceff - chat_out.astype(ct)).astype(c.dtype)
    if encoding in ("quant", "quant_dense"):
        s = 2 ** (bits - 1) - 1
        qk = q if encoding == "quant_dense" else jnp.take_along_axis(
            q, idx, axis=-1
        )
        levels = (qk + float(s)).astype(jnp.int32).astype(jnp.uint32)
        data = pack_words(levels, bits)
    elif encoding == "sparse":
        data = jnp.take_along_axis(chat_out, idx, axis=-1)
    elif encoding == "dense":
        data = chat_out
    else:
        raise ValueError(f"unknown payload encoding {encoding!r}")
    return data, idx.astype(index_dtype), scale, resid


def decode_payload_ref(data, idx, scale, *, cols: int, dtype, k: int,
                       bits: int, encoding: str = "quant"):
    """Inverse of pack_payload_ref: scatter-add the packed payload back
    into the dense [R, cols] compressed correction the agents apply.
    Bitwise equal to the chat that produced the payload (the dequant is
    the same dequantize_levels expression on the same operands; kept
    slots land via exact scatter-add into zeros)."""
    if encoding == "dense":
        return data
    ct = compute_dtype(dtype)
    s = 2 ** (bits - 1) - 1
    if encoding == "quant_dense":
        # implicit indices: every level of the row is present (masked
        # levels decode to exact zeros) — no scatter needed
        levels = unpack_words(data, cols, bits).astype(jnp.int32)
        q = levels.astype(ct) - float(s)
        return dequantize_levels(q, scale.astype(ct), bits, ct).astype(dtype)
    ii = idx.astype(jnp.int32)
    if encoding == "sparse":
        vals = data
    else:
        levels = unpack_words(data, k, bits).astype(jnp.int32)
        q = levels.astype(ct) - float(s)
        vals = dequantize_levels(q, scale.astype(ct), bits, ct).astype(dtype)
    rows = jax.lax.broadcasted_iota(jnp.int32, ii.shape, 0)
    dense = jnp.zeros((*ii.shape[:-1], cols), dtype)
    return dense.at[rows, ii].add(vals)


def flash_attention_ref(
    q, k, v, *, causal: bool = True, window: int = 0, softcap: float = 0.0
):
    """q [B,H,Sq,hd], k/v [B,H,Skv,hd] (heads already grouped/repeated)."""
    hd = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    s = s / jnp.sqrt(jnp.float32(hd))
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    Sq, Skv = q.shape[2], k.shape[2]
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qp >= kp
    if window > 0:
        mask &= qp - kp < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def ssm_scan_ref(da, dbx, c_coef, state0):
    """Sequential oracle of h_t = da_t * h_{t-1} + dbx_t;  y_t = <h_t, c_t>.

    da  [S, d, N] (broadcastable), dbx [S, d, N], c_coef [S, N],
    state0 [d, N].  Returns (y [S, d], final_state [d, N]).
    """

    def step(h, inp):
        a, b, cc = inp
        h = a * h + b
        return h, jnp.einsum("dn,n->d", h, cc)

    state, y = jax.lax.scan(step, state0, (da, dbx, c_coef))
    return y, state
