"""Pallas TPU kernels for the substrate hot-spots (validated on CPU with
interpret=True against the ref.py oracles).

The paper itself contributes no kernel — its contribution is the outer
communication schedule — so these serve the schedule and the model
substrate:
  * gt_update            — fused FedGDA-GT inner update (one HBM pass)
  * compress_correction  — fused select+quantize+error-feedback on tracking
                           corrections (CompressedGT / QuantizedGT)
  * pack_payload         — fused select+quantize+BIT-PACK to the actual
                           sparse wire format (and the fused unpack+
                           dequant+scatter-add inverse) for fed.transport
  * flash_attention — blocked online-softmax attention (causal/window/softcap)
  * ssm_scan        — chunked Mamba selective scan with VMEM-carried state
"""
from .gt_update import gt_update_2d
from .compress_correction import (
    compress_correction_2d,
    compress_leaf,
    fusable_leaf,
)
from .pack_payload import pack_payload_2d, unpack_payload_2d
from .flash_attention import flash_attention
from .ssm_scan import ssm_scan
from .ops import (
    batched_ssm_scan,
    grouped_flash_attention,
    make_gt_update_fn,
)
from . import ref

__all__ = [
    "gt_update_2d",
    "compress_correction_2d",
    "compress_leaf",
    "fusable_leaf",
    "pack_payload_2d",
    "unpack_payload_2d",
    "flash_attention",
    "ssm_scan",
    "batched_ssm_scan",
    "grouped_flash_attention",
    "make_gt_update_fn",
    "ref",
]
