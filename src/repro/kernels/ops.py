"""Jit'd public wrappers around the Pallas kernels.

Every wrapper takes `use_kernel` / `interpret` switches: on CPU (this
container) the kernels run under interpret=True for validation; on TPU the
same pallas_calls compile to Mosaic.  `use_kernel=False` falls back to the
ref oracle (the default inside the model code, which targets both runtimes).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .gt_update import gt_update_2d
from .ssm_scan import ssm_scan

Pytree = Any


def _to_2d(u: jax.Array):
    n = u.size
    cols = 128
    rows = -(-n // cols)
    pad = rows * cols - n
    flat = jnp.pad(u.reshape(-1), (0, pad))
    return flat.reshape(rows, cols), pad


def make_gt_update_fn(interpret: bool = True, use_kernel: bool = True):
    """Drop-in `update_fn` for core.fedgda_gt.make_fedgda_gt_round."""

    def update(z: Pytree, g: Pytree, c: Pytree, eta, sign: float) -> Pytree:
        if not use_kernel:
            return jax.tree.map(
                lambda u, gv, cv: ref.gt_update_ref(u, gv, cv, eta, sign), z, g, c
            )

        def leaf(u, gv, cv):
            u2, pad = _to_2d(u)
            g2, _ = _to_2d(gv)
            c2, _ = _to_2d(cv.astype(gv.dtype))
            r = gt_update_2d(
                u2, g2, c2, eta=float(eta), sign=sign,
                block_rows=min(256, u2.shape[0]), interpret=interpret,
            )
            return r.reshape(-1)[: u.size].reshape(u.shape)

        return jax.tree.map(leaf, z, g, c)

    return update


def grouped_flash_attention(
    q: jax.Array,  # [B, Sq, H, hd] (model layout)
    k: jax.Array,  # [B, Skv, KV, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    interpret: bool = True,
) -> jax.Array:
    """GQA adapter: repeats KV groups, runs the kernel, restores layout."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qt = q.transpose(0, 2, 1, 3)  # [B,H,Sq,hd]
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1)
    out = flash_attention(
        qt, kt, vt, causal=causal, window=window, softcap=softcap,
        interpret=interpret,
    )
    return out.transpose(0, 2, 1, 3)


def batched_ssm_scan(
    da: jax.Array,  # [B, S, D, N]
    dbx: jax.Array,
    c_coef: jax.Array,  # [B, S, N]
    *,
    chunk: int = 64,
    interpret: bool = True,
) -> jax.Array:
    fn = functools.partial(ssm_scan, chunk=chunk, interpret=interpret)
    return jax.vmap(fn)(da, dbx, c_coef)
