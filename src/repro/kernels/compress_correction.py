"""Fused compressed-correction kernel: select + quantize + error feedback.

The `CompressedGT` / `QuantizedGT` strategies transform each tracking
correction leaf (shape [agents, ...], flattened to [R, C]) three ways per
round: inject the error-feedback residual (ceff = c + e), keep the k
largest-magnitude (or a random-k subset of) entries, stochastically
quantize the kept values to `bits` bits with a per-row scale, and write
the dropped mass back into the feedback buffer (e' = ceff - chat).  Done
naively that is four elementwise passes plus a dense mask over HBM; this
kernel streams c, e and the two uniform arrays through VMEM once and
writes both outputs fused (mirroring `kernels/gt_update.py` for the
dense update).

The grid tiles rows only — per-row top-k and the per-row quantization
scale need the full C-length row resident in VMEM, so C must be
lane-aligned (C % 128 == 0) and one (block_rows, C) tile must fit VMEM;
correction leaves are (num_agents, prod(param_shape)) so R is small.
`jax.lax.top_k` / `jnp.cumsum` run on the VPU inside the kernel (and
trivially under interpret=True, the CPU validation path).

Selection and rounding randomness comes in as iid U[0,1) inputs rather
than an in-kernel PRNG: keeping the k largest uniforms IS a uniform
k-subset (rand-k), `floor(u) + [u_rnd < frac(u)]` IS unbiased stochastic
rounding, and sharing the draws with the pure-jnp oracle
(`ref.compress_correction_ref`) makes kernel-vs-reference and
kernel-vs-fallback comparisons exact instead of distributional.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

LANE = 128  # TPU lane width: last dim of every tile must be a multiple


def _compress_kernel(c_ref, e_ref, us_ref, ur_ref, chat_ref, res_ref, *,
                     k: int, bits: int, mode: str, has_feedback: bool):
    ct = ref.compute_dtype(c_ref.dtype)
    ceff = c_ref[...].astype(ct)
    if has_feedback:
        ceff = ceff + e_ref[...].astype(ct)
    n = ceff.shape[-1]
    if k < n:
        score = jnp.abs(ceff) if mode == "topk" else us_ref[...].astype(ct)
        kept = jnp.where(ref.exact_k_mask(score, k), ceff, jnp.zeros_like(ceff))
    else:
        kept = ceff
    if bits < 32:
        chat = ref.stochastic_quantize(kept, ur_ref[...], bits, ct)
    else:
        chat = kept
    chat_ref[...] = chat.astype(chat_ref.dtype)
    # residual against the DELIVERED (dtype-cast) values, so the feedback
    # buffer absorbs the storage-dtype rounding too
    res_ref[...] = (ceff - chat_ref[...].astype(ct)).astype(res_ref.dtype)


def _row_block(R: int, want: int) -> int:
    br = max(1, min(want, R))
    while R % br:
        br -= 1
    return br


def _operand(arr, dtype, spec):
    """Stand-in for an unused kernel operand: the python-level gates in
    the kernel bodies are trace-time constants, so a None operand is
    never read — but pallas_call arity is fixed, so substitute one
    (1, LANE) tile pinned to block (0, 0) so nothing dense is
    materialized or streamed through VMEM.  Shared by every kernel in
    this package that takes optional uniforms/feedback inputs."""
    if arr is None:
        return jnp.zeros((1, LANE), dtype), pl.BlockSpec(
            (1, LANE), lambda i: (0, 0)
        )
    return arr, spec


def compress_correction_2d(
    c: jax.Array,  # [R, C], C % 128 == 0
    e: Optional[jax.Array],  # [R, C] feedback residual, or None
    u_sel: Optional[jax.Array],  # [R, C] U[0,1) — rand-k scores (randk only)
    u_rnd: Optional[jax.Array],  # [R, C] U[0,1) — stochastic rounding (bits<32)
    *,
    k: int,
    bits: int = 32,
    mode: str = "topk",
    block_rows: int = 8,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """One fused VMEM pass of (feedback-inject, exact-k select, quantize,
    residual-update) per row.  Returns (chat, resid), both c.dtype and
    bitwise-equal to `ref.compress_correction_ref` on the same inputs."""
    R, C = c.shape
    assert C % LANE == 0, f"fused path needs lane-aligned leaves, got C={C}"
    assert mode in ("topk", "randk"), mode
    if bits < 32:
        assert u_rnd is not None, "stochastic rounding (bits<32) needs u_rnd"
    if mode == "randk" and k < C:
        assert u_sel is not None, "rand-k selection needs u_sel scores"
    br = _row_block(R, block_rows)
    spec = pl.BlockSpec((br, C), lambda i: (i, 0))
    has_feedback = e is not None
    e_arr, e_spec = _operand(e, c.dtype, spec)
    us_arr, us_spec = _operand(u_sel, c.dtype, spec)
    ur_arr, ur_spec = _operand(u_rnd, c.dtype, spec)
    kern = functools.partial(
        _compress_kernel, k=k, bits=bits, mode=mode, has_feedback=has_feedback
    )
    return pl.pallas_call(
        kern,
        grid=(R // br,),
        in_specs=[spec, e_spec, us_spec, ur_spec],
        out_specs=(spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct(c.shape, c.dtype),
            jax.ShapeDtypeStruct(c.shape, c.dtype),
        ),
        interpret=interpret,
    )(c, e_arr, us_arr, ur_arr)


def fusable_leaf(flat: jax.Array) -> bool:
    """The fused kernel handles 2D leaves with a lane-aligned row length."""
    return flat.ndim == 2 and flat.shape[-1] > 0 and flat.shape[-1] % LANE == 0


def compress_leaf(
    c: jax.Array,
    e: Optional[jax.Array],
    u_sel: Optional[jax.Array],
    u_rnd: Optional[jax.Array],
    *,
    k: int,
    bits: int = 32,
    mode: str = "topk",
    use_kernel: bool = False,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Strategy-facing dispatcher: the fused Pallas path on aligned 2D
    leaves, the pure-jnp oracle otherwise.  Both paths are the same math
    on the same uniforms — the choice moves results by at most the last
    ulp (the kernel compiles as one XLA unit whose fusion may round
    differently than the per-op path)."""
    if use_kernel and fusable_leaf(c):
        return compress_correction_2d(
            c, e, u_sel, u_rnd, k=k, bits=bits, mode=mode, interpret=interpret
        )
    return ref.compress_correction_ref(c, e, u_sel, u_rnd, k=k, bits=bits, mode=mode)
