"""Fused FedGDA-GT inner-loop update kernel.

z <- z + sign * eta * (g + c): three HBM-resident arrays (params, gradient,
tracking correction — the correction may be a narrower dtype, e.g. fp8) are
streamed through VMEM once and written back fused, instead of the three
separate elementwise passes XLA would otherwise schedule around the dtype
conversion.  Tiles are (block_rows, 128) — lane-aligned for the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gt_update_kernel(z_ref, g_ref, c_ref, o_ref, *, eta: float, sign: float):
    z = z_ref[...]
    g = g_ref[...]
    c = c_ref[...].astype(jnp.float32)
    upd = z.astype(jnp.float32) + sign * eta * (g.astype(jnp.float32) + c)
    o_ref[...] = upd.astype(o_ref.dtype)


def gt_update_2d(
    z: jax.Array,  # [R, C], C % 128 == 0
    g: jax.Array,
    c: jax.Array,
    *,
    eta: float,
    sign: float,
    block_rows: int = 256,
    block_cols: int = 512,
    interpret: bool = False,
) -> jax.Array:
    R, C = z.shape
    br = min(block_rows, R)
    bc = min(block_cols, C)
    assert R % br == 0 and C % bc == 0, (z.shape, br, bc)
    grid = (R // br, C // bc)
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(_gt_update_kernel, eta=eta, sign=sign),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(z.shape, z.dtype),
        interpret=interpret,
    )(z, g, c)
