"""Blocked online-softmax (flash) attention for TPU.

Grid (batch*heads, q_blocks, kv_blocks); the KV axis is the innermost,
sequentially-iterated dimension so the running (max, denom, acc) scratch
persists across KV tiles in VMEM.  Q tiles stay resident; K/V stream in
(block_kv, head_dim) tiles.  Supports causal masking, sliding windows and
the Gemma-2 logit softcap.  MXU-aligned tiles (multiples of 128 on the
seq axes; head_dim padded by the caller if needed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, sm_scale, causal, window, softcap, block_q, block_kv, num_kv_blocks,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
    k_pos = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    mask = jnp.ones((block_q, block_kv), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= q_pos - k_pos < window

    # skip fully-masked tiles (above the causal diagonal / outside the window)
    needed = jnp.logical_not(causal) | (
        (iq + 1) * block_q - 1 >= ik * block_kv
    )
    if window > 0:
        needed &= iq * block_q < ik * block_kv + block_kv - 1 + window

    @pl.when(needed)
    def _tile():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))
        ) * sm_scale  # [bq, bkv]
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # [B, H, Sq, hd]
    k: jax.Array,  # [B, H, Skv, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, hd = q.shape
    Skv = k.shape[2]
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    assert Sq % block_q == 0 and Skv % block_kv == 0
    nq, nk = Sq // block_q, Skv // block_kv
    qf = q.reshape(B * H, Sq, hd)
    kf = k.reshape(B * H, Skv, hd)
    vf = v.reshape(B * H, Skv, hd)
    kernel = functools.partial(
        _flash_kernel,
        sm_scale=1.0 / (hd**0.5),
        causal=causal,
        window=window,
        softcap=softcap,
        block_q=block_q,
        block_kv=block_kv,
        num_kv_blocks=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, hd)
