"""Chunked selective-scan (Mamba) kernel.

h_t = da_t * h_{t-1} + dbx_t;   y_t = <h_t, c_t>

Grid (d_blocks, chunks): the channel axis is parallel; the chunk axis is the
innermost sequential dimension with the carried state [d_block, N] living in
VMEM scratch across chunks.  Inside a chunk the recurrence runs as a fori
loop over time steps on VMEM-resident tiles — the working set is
O(chunk * d_block * N) regardless of sequence length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(da_ref, dbx_ref, c_ref, y_ref, h_scr, *, chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    def step(t, h):
        a = da_ref[t]  # [d_block, N]
        b = dbx_ref[t]
        cc = c_ref[t]  # [1, N]
        h = a * h + b
        y_ref[t] = jnp.sum(h * cc, axis=-1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h


def ssm_scan(
    da: jax.Array,  # [S, D, N] float32 (decay factors, broadcast-expanded)
    dbx: jax.Array,  # [S, D, N] float32
    c_coef: jax.Array,  # [S, N] float32
    *,
    chunk: int = 64,
    block_d: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Returns y [S, D].  Single sequence; vmap over batch."""
    S, D, N = da.shape
    # the kernel computes in float32 (VMEM scratch dtype); normalize inputs
    da = da.astype(jnp.float32)
    dbx = dbx.astype(jnp.float32)
    c_coef = c_coef.astype(jnp.float32)
    chunk = min(chunk, S)
    block_d = min(block_d, D)
    assert S % chunk == 0 and D % block_d == 0, (da.shape, chunk, block_d)
    grid = (D // block_d, S // chunk)
    kernel = functools.partial(_ssm_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk, block_d, N), lambda d, c: (c, d, 0)),
            pl.BlockSpec((chunk, block_d, N), lambda d, c: (c, d, 0)),
            pl.BlockSpec((chunk, 1, N), lambda d, c: (c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((chunk, block_d), lambda d, c: (c, d)),
        out_shape=jax.ShapeDtypeStruct((S, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(da, dbx, c_coef.reshape(S, 1, N))
