"""Modality frontend STUBS (the one sanctioned carve-out).

[audio] and [vlm] architectures specify the transformer backbone only; the
conv feature extractor / ViT is NOT implemented.  These helpers produce the
precomputed frame/patch embeddings the backbone consumes, both as concrete
random arrays (smoke tests) and as ShapeDtypeStructs (dry-run input_specs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


def audio_frames(key, cfg: ModelConfig, batch: int, seq_len: int, dtype):
    """Mel+conv-codec output stand-in: [B, S, frontend_dim]."""
    return jax.random.normal(key, (batch, seq_len, cfg.frontend_dim), dtype)


def vision_patches(key, cfg: ModelConfig, batch: int, dtype):
    """ViT/SigLIP patch embeddings stand-in: [B, num_patches, frontend_dim]."""
    return jax.random.normal(key, (batch, cfg.num_patches, cfg.frontend_dim), dtype)


def batch_struct(cfg: ModelConfig, batch: int, seq_len: int, dtype) -> dict:
    """ShapeDtypeStruct pytree for one training/prefill batch."""
    i32 = jnp.int32
    if cfg.frontend == "audio":
        return {
            "frames": jax.ShapeDtypeStruct((batch, seq_len, cfg.frontend_dim), dtype),
            "labels": jax.ShapeDtypeStruct((batch, seq_len), i32),
        }
    if cfg.frontend == "vision_text":
        s_text = seq_len - cfg.num_patches
        return {
            "tokens": jax.ShapeDtypeStruct((batch, s_text), i32),
            "patches": jax.ShapeDtypeStruct(
                (batch, cfg.num_patches, cfg.frontend_dim), dtype
            ),
            "labels": jax.ShapeDtypeStruct((batch, seq_len), i32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq_len), i32),
        "labels": jax.ShapeDtypeStruct((batch, seq_len), i32),
    }


def random_batch(key, cfg: ModelConfig, batch: int, seq_len: int, dtype) -> dict:
    """Concrete batch matching batch_struct (smoke tests / examples)."""
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.frontend == "audio":
        return {
            "frames": audio_frames(k1, cfg, batch, seq_len, dtype),
            "labels": jax.random.randint(
                k2, (batch, seq_len), 0, cfg.vocab_size, jnp.int32
            ),
        }
    if cfg.frontend == "vision_text":
        s_text = seq_len - cfg.num_patches
        labels = jax.random.randint(
            k2, (batch, seq_len), 0, cfg.vocab_size, jnp.int32
        )
        # no next-token target on patch positions
        labels = labels.at[:, : cfg.num_patches].set(-1)
        return {
            "tokens": jax.random.randint(
                k1, (batch, s_text), 0, cfg.vocab_size, jnp.int32
            ),
            "patches": vision_patches(k3, cfg, batch, dtype),
            "labels": labels,
        }
    return {
        "tokens": jax.random.randint(
            k1, (batch, seq_len), 0, cfg.vocab_size, jnp.int32
        ),
        "labels": jax.random.randint(
            k2, (batch, seq_len), 0, cfg.vocab_size, jnp.int32
        ),
    }
