"""Mixture-of-Experts FFN (top-k router, capacity-based dispatch).

Two dispatch implementations, selectable per config (and the subject of one
of the §Perf hillclimbs):
  * "einsum"  — Mesh-TF style one-hot dispatch/combine einsums. GSPMD-friendly
    (lowers to all-to-all when experts are mesh-sharded) at the cost of
    O(B*S*E*C*d) dispatch FLOPs.
  * "scatter" — sort-free scatter/gather by expert id with capacity dropping.
    Near-zero dispatch FLOPs, but relies on GSPMD handling scatter across
    expert-sharded operands.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def init_moe(key, d: int, ff: int, num_experts: int, dtype) -> Dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    s_in = 1.0 / jnp.sqrt(d)
    s_ff = 1.0 / jnp.sqrt(ff)
    E = num_experts
    return {
        "router": (jax.random.normal(kr, (d, E)) * s_in).astype(jnp.float32),
        "gate": (jax.random.normal(kg, (E, d, ff)) * s_in).astype(dtype),
        "up": (jax.random.normal(ku, (E, d, ff)) * s_in).astype(dtype),
        "down": (jax.random.normal(kd, (E, ff, d)) * s_ff).astype(dtype),
    }


def _expert_ffn(params: Dict, x: jax.Array) -> jax.Array:
    """x: [E, G, C, d] -> [E, G, C, d] (per-expert SwiGLU)."""
    gate = jax.nn.silu(jnp.einsum("egcd,edf->egcf", x, params["gate"]))
    up = jnp.einsum("egcd,edf->egcf", x, params["up"])
    return jnp.einsum("egcf,efd->egcd", gate * up, params["down"])


def router_decisions(
    params: Dict, h: jax.Array, top_k: int
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (expert_index [B,S,K], gate_weight [B,S,K], aux_loss scalar)."""
    logits = (h.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary: E * <fraction routed> . <mean prob>
    E = probs.shape[-1]
    frac = jnp.mean(
        jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * mean_prob)
    return idx, gate.astype(h.dtype), aux


def moe_ffn(
    params: Dict,
    h: jax.Array,  # [B, S, d]
    *,
    top_k: int = 1,
    capacity_factor: float = 1.25,
    dispatch: str = "einsum",
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output [B,S,d], load-balance aux loss)."""
    B, S, d = h.shape
    E = params["gate"].shape[0]
    idx, gate, aux = router_decisions(params, h, top_k)
    C = max(1, int(S * top_k * capacity_factor) // E)
    if dispatch == "einsum":
        out = _dispatch_einsum(params, h, idx, gate, top_k, C, E)
    elif dispatch == "scatter":
        out = _dispatch_scatter(params, h, idx, gate, top_k, C, E)
    else:
        raise ValueError(f"unknown dispatch {dispatch!r}")
    return out, aux


def _dispatch_einsum(params, h, idx, gate, top_k, C, E):
    B, S, d = h.shape
    out = jnp.zeros_like(h)
    for k in range(top_k):
        onehot = jax.nn.one_hot(idx[..., k], E, dtype=jnp.float32)  # [B,S,E]
        pos = jnp.cumsum(onehot, axis=1) * onehot - 1.0  # slot within expert
        keep = (pos >= 0.0) & (pos < C)
        dm = jnp.where(keep[..., None], jax.nn.one_hot(pos, C), 0.0)  # [B,S,E,C]
        dm = (dm * onehot[..., None]).astype(h.dtype)
        xin = jnp.einsum("bsec,bsd->ebcd", dm, h)  # [E,B,C,d]
        xout = _expert_ffn(params, xin)
        comb = dm * gate[..., k][..., None, None]
        out = out + jnp.einsum("bsec,ebcd->bsd", comb, xout)
    return out


def _dispatch_scatter(params, h, idx, gate, top_k, C, E):
    B, S, d = h.shape
    out = jnp.zeros_like(h)
    for k in range(top_k):
        e_id = idx[..., k]  # [B,S]
        onehot = jax.nn.one_hot(e_id, E, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=1) * onehot  # 1-based where selected
        pos = jnp.take_along_axis(pos, e_id[..., None], axis=-1)[..., 0] - 1
        # scatter tokens into [E, B, C, d]; capacity overflow -> dropped
        buf = jnp.zeros((E, B, C, d), h.dtype)
        b_ix = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S))
        buf = buf.at[e_id, b_ix, pos].set(h, mode="drop")
        xout = _expert_ffn(params, buf)
        gathered = xout[e_id, b_ix, pos]  # [B,S,d]
        valid = (pos >= 0) & (pos < C)
        out = out + jnp.where(
            valid[..., None], gathered * gate[..., k][..., None], 0.0
        )
    return out
