"""Shared neural-net building blocks (pure-jnp, param dicts)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms_norm(d: int, dtype) -> Dict:
    return {"scale": jnp.zeros((d,), dtype)}


def swiglu(x: jax.Array, w: Dict) -> jax.Array:
    """SwiGLU MLP: (silu(x W_gate) * (x W_up)) W_down."""
    gate = jax.nn.silu(x @ w["gate"])
    up = x @ w["up"]
    return (gate * up) @ w["down"]


def init_swiglu(key, d: int, ff: int, dtype) -> Dict:
    kg, ku, kd = jax.random.split(key, 3)
    s_in = 1.0 / jnp.sqrt(d)
    s_ff = 1.0 / jnp.sqrt(ff)
    return {
        "gate": (jax.random.normal(kg, (d, ff)) * s_in).astype(dtype),
        "up": (jax.random.normal(ku, (d, ff)) * s_in).astype(dtype),
        "down": (jax.random.normal(kd, (ff, d)) * s_ff).astype(dtype),
    }


def embed_tokens(tokens: jax.Array, table: jax.Array, scale: bool = True):
    h = table[tokens]
    if scale:
        h = h * jnp.asarray(jnp.sqrt(table.shape[-1]), h.dtype)
    return h


def unembed(h: jax.Array, table: jax.Array, softcap: float = 0.0) -> jax.Array:
    logits = jnp.einsum("...d,vd->...v", h, table).astype(jnp.float32)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token CE; logits [..., V] float32, labels [...] int."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
