"""GQA attention with RoPE, sliding windows, Gemma-2 logit softcap and a
ring-buffer KV cache.

Memory discipline: scores are never materialized at [S, S] — the q axis is
processed in checkpointed blocks (`q_block`), bounding live memory to
[B, H, q_block, S_kv] (the pure-jnp analogue of the Pallas flash kernel in
`repro.kernels.flash_attention`, which replaces the inner block on TPU).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def init_attention(key, d: int, heads: int, kv_heads: int, head_dim: int, dtype) -> Dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = 1.0 / jnp.sqrt(d)
    so = 1.0 / jnp.sqrt(heads * head_dim)
    return {
        "wq": (jax.random.normal(kq, (d, heads, head_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(kk, (d, kv_heads, head_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(kv, (d, kv_heads, head_dim)) * s).astype(dtype),
        "wo": (jax.random.normal(ko, (heads, head_dim, d)) * so).astype(dtype),
    }


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, hd]; positions: [S] (shared across batch)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [S, half]
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    dt = x.dtype
    return jnp.concatenate(
        [
            (x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin).astype(dt),
            (x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin).astype(dt),
        ],
        axis=-1,
    )


def _attend(
    q: jax.Array,  # [B, Sq, H, hd]  (already rope'd)
    k: jax.Array,  # [B, Skv, KV, hd]
    v: jax.Array,  # [B, Skv, KV, hd]
    q_positions: jax.Array,  # [Sq]
    kv_positions: jax.Array,  # [Skv]
    kv_valid: Optional[jax.Array],  # [Skv] bool or None
    causal: bool,
    window: int,
    softcap: float,
) -> jax.Array:
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    mask = jnp.ones((Sq, k.shape[1]), dtype=bool)
    if causal:
        mask &= q_positions[:, None] >= kv_positions[None, :]
    if window > 0:
        mask &= q_positions[:, None] - kv_positions[None, :] < window
    if kv_valid is not None:
        mask &= kv_valid[None, :]
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", p, v)
    return out.reshape(B, Sq, H, hd)


def multihead_attention(
    params: Dict,
    h: jax.Array,  # [B, Sq, d]
    *,
    q_positions: jax.Array,  # [Sq]
    rope_theta: float,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    cache: Optional[Dict] = None,
    cache_index: Optional[jax.Array] = None,
    q_block: int = 512,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Returns (output [B, Sq, d], updated cache or None).

    cache (decode/prefill-fill): {"k": [B, C, KV, hd], "v": same,
    "pos": [C] int32 positions stored in each slot (-1 = empty)}.
    cache_index: slot offset at which to write the new K/V (ring for windows).
    """
    B, Sq, d = h.shape
    q = jnp.einsum("bsd,dnh->bsnh", h, params["wq"])
    k_new = jnp.einsum("bsd,dnh->bsnh", h, params["wk"])
    v_new = jnp.einsum("bsd,dnh->bsnh", h, params["wv"])
    q = apply_rope(q, q_positions, rope_theta)
    k_new = apply_rope(k_new, q_positions, rope_theta)

    if cache is not None and Sq >= cache["k"].shape[1]:
        # prefill longer than a ring (sliding-window) cache: attend over the
        # full new K/V; store only the last C entries, rotated so slot i
        # holds the position p with p % C == i (decode continues the ring)
        C = cache["k"].shape[1]
        tail_pos = q_positions[-C:].astype(jnp.int32)
        order = jnp.argsort(tail_pos % C)
        new_cache = {
            "k": k_new[:, -C:][:, order],
            "v": v_new[:, -C:][:, order],
            "pos": tail_pos[order],
        }
        k, v = k_new, v_new
        kv_positions, kv_valid = q_positions, None
    elif cache is not None:
        C = cache["k"].shape[1]
        slot = (cache_index % C).astype(jnp.int32)
        zero = jnp.int32(0)
        k_all = jax.lax.dynamic_update_slice(
            cache["k"], k_new, (zero, slot, zero, zero)
        )
        v_all = jax.lax.dynamic_update_slice(
            cache["v"], v_new, (zero, slot, zero, zero)
        )
        pos_all = jax.lax.dynamic_update_slice(
            cache["pos"], q_positions.astype(jnp.int32), (slot,)
        )
        new_cache = {"k": k_all, "v": v_all, "pos": pos_all}
        kv_positions, kv_valid = pos_all, pos_all >= 0
        k, v = k_all, v_all
    else:
        new_cache = None
        k, v = k_new, v_new
        kv_positions, kv_valid = q_positions, None

    attend = functools.partial(
        _attend,
        k=k,
        v=v,
        kv_positions=kv_positions,
        kv_valid=kv_valid,
        causal=causal,
        window=window,
        softcap=softcap,
    )
    if Sq <= q_block:
        out = attend(q, q_positions=q_positions)
    else:
        # blocked over q with rematerialized scores (flash-style memory bound)
        nb = Sq // q_block
        assert Sq % q_block == 0, (Sq, q_block)
        qb = q.reshape(B, nb, q_block, *q.shape[2:]).transpose(1, 0, 2, 3, 4)
        pb = q_positions.reshape(nb, q_block)

        blk = jax.checkpoint(lambda qq, pp: attend(qq, q_positions=pp))
        out = jax.lax.map(lambda args: blk(*args), (qb, pb))
        out = out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, *q.shape[2:])

    out = jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    return out, new_cache


def init_cache(
    batch: int, capacity: int, kv_heads: int, head_dim: int, dtype
) -> Dict:
    return {
        "k": jnp.zeros((batch, capacity, kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, capacity, kv_heads, head_dim), dtype),
        "pos": jnp.full((capacity,), -1, jnp.int32),
    }
