from .transformer import (
    chunked_lm_loss,
    embed_inputs,
    forward,
    init_caches,
    init_params,
    logits_from_hidden,
    num_params,
)
from .frontends import batch_struct, random_batch

__all__ = [
    "chunked_lm_loss",
    "embed_inputs",
    "forward",
    "init_caches",
    "init_params",
    "logits_from_hidden",
    "num_params",
    "batch_struct",
    "random_batch",
]
