"""Mamba-1 (selective scan) and Mamba-2 (SSD, scalar-per-head decay) blocks.

Unified state layout [B, n_heads, head_p, d_state]:
  * mamba1: n_heads = d_inner, head_p = 1, A in R^{d_inner x N} (per-channel).
  * mamba2: n_heads = d_inner/head_p, A scalar per head.

The sequence scan is CHUNKED: an associative scan runs inside fixed-size
chunks (VMEM-sized working set — the same blocking the Pallas `ssm_scan`
kernel uses) while a lax.scan carries the [B, nh, p, N] state across chunks.
This bounds live memory to O(B * chunk * d_inner * N) instead of O(B * S * d_inner * N).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def init_mamba(
    key,
    d: int,
    d_inner: int,
    d_state: int,
    conv_width: int,
    variant: str,
    dtype,
    head_p: int = 64,
    dt_rank: Optional[int] = None,
) -> Dict:
    ks = jax.random.split(key, 8)
    s_in = 1.0 / jnp.sqrt(d)
    s_inner = 1.0 / jnp.sqrt(d_inner)
    dt_rank = dt_rank or max(1, d // 16)
    nh = d_inner if variant == "mamba1" else d_inner // head_p
    p = {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * d_inner)) * s_in).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_width, d_inner)) * 0.5).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "out_proj": (jax.random.normal(ks[2], (d_inner, d)) * s_inner).astype(dtype),
        "D": jnp.ones((d_inner,), dtype),
        "norm": jnp.zeros((d_inner,), dtype),
    }
    if variant == "mamba1":
        p["x_proj"] = (
            jax.random.normal(ks[3], (d_inner, dt_rank + 2 * d_state)) * s_inner
        ).astype(dtype)
        p["dt_proj"] = (
            jax.random.normal(ks[4], (dt_rank, d_inner)) / jnp.sqrt(dt_rank)
        ).astype(dtype)
        p["dt_bias"] = jnp.zeros((d_inner,), dtype)
        p["A_log"] = jnp.log(
            jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_inner, d_state))
        ).astype(jnp.float32)
    elif variant == "mamba2":
        p["bcdt_proj"] = (
            jax.random.normal(ks[3], (d, 2 * d_state + nh)) * s_in
        ).astype(dtype)
        p["dt_bias"] = jnp.zeros((nh,), dtype)
        p["A_log"] = jnp.zeros((nh,), jnp.float32)
    else:
        raise ValueError(variant)
    return p


def _chunked_scan(da, dbx, state, chunk):
    """h_t = da_t * h_{t-1} + dbx_t, scanned over axis 1 (seq).

    da: [B,S,nh,1,Na] (Na = N or 1), dbx: [B,S,nh,p,N], state: [B,nh,p,N].
    Returns (hs [B,S,nh,p,N], final state).
    """
    B, S = dbx.shape[:2]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    da_c = da.reshape(B, nc, chunk, *da.shape[2:]).swapaxes(0, 1)
    dbx_c = dbx.reshape(B, nc, chunk, *dbx.shape[2:]).swapaxes(0, 1)

    def comb(left, right):
        la, lb = left
        ra, rb = right
        return (ra * la, ra * lb + rb)

    def chunk_fn(st, inp):
        dac, dbxc = inp  # [B,c,...]
        aa, bb = jax.lax.associative_scan(comb, (dac, dbxc), axis=1)
        hs = aa * st[:, None] + bb
        return hs[:, -1], hs

    state, hs = jax.lax.scan(chunk_fn, state, (da_c, dbx_c))
    return hs.swapaxes(0, 1).reshape(B, S, *dbx.shape[2:]), state


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv; x [B,S,di], w [W,di]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp,
        w[:, None, :],  # [W, 1, di]
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return out + b


def mamba_block(
    params: Dict,
    u: jax.Array,  # [B, S, d]
    *,
    variant: str,
    d_state: int,
    head_p: int = 64,
    chunk: int = 256,
    cache: Optional[Dict] = None,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Returns (output [B,S,d], updated cache or None).

    cache (decode): {"conv": [B, W-1, di], "ssm": [B, nh, p, N]}.
    """
    B, S, d = u.shape
    d_inner = params["in_proj"].shape[1] // 2
    nh = d_inner if variant == "mamba1" else d_inner // head_p
    p_dim = 1 if variant == "mamba1" else head_p

    xz = u @ params["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)

    W = params["conv_w"].shape[0]
    if cache is not None:
        xw = jnp.concatenate([cache["conv"], x], axis=1)  # [B, W-1+S, di]
        new_conv = xw[:, -(W - 1):]
        if S == 1:
            x = (
                jnp.einsum("bwd,wd->bd", xw[:, -W:], params["conv_w"])
                + params["conv_b"]
            )[:, None]
        else:  # prefill: valid conv over the cache-prefixed window
            x = jax.lax.conv_general_dilated(
                xw,
                params["conv_w"][:, None, :],
                window_strides=(1,),
                padding="VALID",
                dimension_numbers=("NWC", "WIO", "NWC"),
                feature_group_count=x.shape[-1],
            ) + params["conv_b"]
    else:
        new_conv = None
        x = _causal_conv(x, params["conv_w"], params["conv_b"])
    x = jax.nn.silu(x)

    if variant == "mamba1":
        dbl = x @ params["x_proj"]
        dt_rank = params["dt_proj"].shape[0]
        dt_raw, Bc, Cc = jnp.split(dbl, [dt_rank, dt_rank + d_state], axis=-1)
        dt = jax.nn.softplus(dt_raw @ params["dt_proj"] + params["dt_bias"])
        A = -jnp.exp(params["A_log"])  # [di, N]
        da = jnp.exp(
            dt.astype(jnp.float32)[..., None] * A
        )  # [B,S,di,N]
        da = da[..., None, :].reshape(B, S, nh, 1, d_state)
        dbx = (
            dt[..., None] * x[..., None] * Bc[:, :, None, :]
        )  # [B,S,di,N]
        dbx = dbx.reshape(B, S, nh, 1, d_state)
    else:  # mamba2
        bcd = u @ params["bcdt_proj"]
        Bc, Cc, dt_raw = jnp.split(bcd, [d_state, 2 * d_state], axis=-1)
        dt = jax.nn.softplus(dt_raw + params["dt_bias"])  # [B,S,nh]
        A = -jnp.exp(params["A_log"])  # [nh]
        da = jnp.exp(dt.astype(jnp.float32) * A)[..., None, None]  # [B,S,nh,1,1]
        xh = x.reshape(B, S, nh, head_p)
        dbx = (dt[..., None] * xh)[..., None] * Bc[:, :, None, None, :]

    state0 = (
        cache["ssm"]
        if cache is not None
        else jnp.zeros((B, nh, p_dim, d_state), jnp.float32)
    )
    if S == 1:
        h1 = da[:, 0] * state0 + dbx[:, 0]
        hs, state = h1[:, None], h1
    else:
        hs, state = _chunked_scan(
            da, dbx.astype(jnp.float32), state0, min(chunk, S)
        )

    if variant == "mamba1":
        y = jnp.einsum("bsnpN,bsN->bsnp", hs, Cc.astype(jnp.float32))
        y = y.reshape(B, S, d_inner)
    else:
        y = jnp.einsum("bsnpN,bsN->bsnp", hs, Cc.astype(jnp.float32))
        y = y.reshape(B, S, d_inner)
    y = y.astype(u.dtype) + params["D"] * x.reshape(B, S, d_inner)
    # gated RMSNorm (Mamba-2 style; harmless for mamba1)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * (1.0 + params["norm"].astype(jnp.float32))
    out = yf.astype(u.dtype) @ params["out_proj"]

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "ssm": state}
    return out, new_cache


def init_mamba_cache(
    batch: int, d_inner: int, d_state: int, conv_width: int, variant: str, dtype,
    head_p: int = 64,
) -> Dict:
    nh = d_inner if variant == "mamba1" else d_inner // head_p
    p_dim = 1 if variant == "mamba1" else head_p
    return {
        "conv": jnp.zeros((batch, conv_width - 1, d_inner), dtype),
        "ssm": jnp.zeros((batch, nh, p_dim, d_state), jnp.float32),
    }
