"""Model assembly: pattern-cycled blocks, scan-over-layers, KV/SSM caches.

One code path serves all 10 assigned architectures:
  dense GQA (granite, starcoder2), alternating local/global + softcaps
  (gemma2), MoE (llama4 scout/maverick), pure SSM (falcon-mamba), hybrid
  Mamba2 + shared attention block (zamba2), encoder-only (hubert), and
  embedding-frontend VLM (pixtral).

Layers are scanned: parameters are stacked [num_periods, ...] per pattern
slot so the HLO contains ONE period body regardless of depth (compile-time
and dry-run friendly); the zamba2 shared attention block is a closure applied
inside the scan via lax.cond every `shared_attn_every` layers.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import init_attention, init_cache, multihead_attention
from .layers import (
    embed_tokens,
    init_rms_norm,
    init_swiglu,
    rms_norm,
    swiglu,
    unembed,
)
from .mamba import init_mamba, init_mamba_cache, mamba_block
from .moe import init_moe, moe_ffn

Pytree = Any


# --------------------------------------------------------------------------
# parameter construction
# --------------------------------------------------------------------------
def _init_layer(key, kind: str, cfg: ModelConfig, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    if kind in ("attn", "local", "moe"):
        p = {
            "ln1": init_rms_norm(cfg.d_model, dtype),
            "attn": init_attention(
                ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim, dtype
            ),
            "ln2": init_rms_norm(cfg.d_model, dtype),
        }
        if kind == "moe":
            p["moe"] = init_moe(ks[1], cfg.d_model, cfg.d_ff, cfg.num_experts, dtype)
        else:
            p["mlp"] = init_swiglu(ks[1], cfg.d_model, cfg.d_ff, dtype)
        return p
    if kind in ("mamba1", "mamba2"):
        return {
            "ln1": init_rms_norm(cfg.d_model, dtype),
            "mamba": init_mamba(
                ks[0],
                cfg.d_model,
                cfg.d_inner,
                cfg.ssm_state,
                cfg.conv_width,
                kind,
                dtype,
                head_p=cfg.head_p,
            ),
        }
    raise ValueError(kind)


def init_params(key, cfg: ModelConfig, dtype=jnp.float32) -> Pytree:
    per = len(cfg.pattern)
    assert cfg.num_layers % per == 0, (cfg.name, cfg.num_layers, per)
    n_per = cfg.num_layers // per
    keys = jax.random.split(key, per + 4)
    blocks = {}
    for j, kind in enumerate(cfg.pattern):
        lk = jax.random.split(keys[j], n_per)
        blocks[f"{j}_{kind}"] = jax.vmap(
            lambda k: _init_layer(k, kind, cfg, dtype)
        )(lk)
    params = {
        "blocks": blocks,
        "final_norm": init_rms_norm(cfg.d_model, dtype),
    }
    if cfg.frontend == "audio":
        params["frontend_proj"] = (
            jax.random.normal(keys[per], (cfg.frontend_dim, cfg.d_model))
            / jnp.sqrt(cfg.frontend_dim)
        ).astype(dtype)
        params["out_head"] = (
            jax.random.normal(keys[per + 1], (cfg.d_model, cfg.vocab_size))
            / jnp.sqrt(cfg.d_model)
        ).astype(dtype)
    else:
        params["embed"] = (
            jax.random.normal(keys[per], (cfg.vocab_size, cfg.d_model)) * 0.02
        ).astype(dtype)
        if cfg.frontend == "vision_text":
            params["frontend_proj"] = (
                jax.random.normal(keys[per + 1], (cfg.frontend_dim, cfg.d_model))
                / jnp.sqrt(cfg.frontend_dim)
            ).astype(dtype)
    if cfg.shared_attn_every:
        params["shared_attn"] = {
            "ln": init_rms_norm(cfg.d_model, dtype),
            "attn": init_attention(
                keys[per + 2],
                cfg.d_model,
                cfg.num_heads,
                cfg.num_kv_heads,
                cfg.head_dim,
                dtype,
            ),
        }
    return params


def num_params(params: Pytree) -> int:
    return sum(u.size for u in jax.tree.leaves(params))


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------
def _layer_cache_capacity(kind: str, cfg: ModelConfig, capacity: int) -> int:
    if kind == "local":
        return min(capacity, cfg.sliding_window)
    return capacity


def init_caches(cfg: ModelConfig, batch: int, capacity: int, dtype) -> Pytree:
    per = len(cfg.pattern)
    n_per = cfg.num_layers // per
    caches = {}
    for j, kind in enumerate(cfg.pattern):
        cap = _layer_cache_capacity(kind, cfg, capacity)
        if kind in ("attn", "local", "moe"):
            one = init_cache(batch, cap, cfg.num_kv_heads, cfg.head_dim, dtype)
        else:
            one = init_mamba_cache(
                batch, cfg.d_inner, cfg.ssm_state, cfg.conv_width, kind, dtype,
                head_p=cfg.head_p,
            )
        caches[f"{j}_{kind}"] = jax.tree.map(
            lambda u: jnp.broadcast_to(u[None], (n_per,) + u.shape), one
        )
    out = {"layers": caches}
    if cfg.shared_attn_every:
        n_shared = cfg.num_layers // cfg.shared_attn_every
        one = init_cache(batch, capacity, cfg.num_kv_heads, cfg.head_dim, dtype)
        out["shared"] = jax.tree.map(
            lambda u: jnp.broadcast_to(u[None], (n_shared,) + u.shape), one
        )
    return out


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------
def _apply_layer(
    kind: str,
    p: Dict,
    cfg: ModelConfig,
    h: jax.Array,
    cache: Optional[Dict],
    q_positions: jax.Array,
    cache_index: Optional[jax.Array],
):
    aux = jnp.float32(0.0)
    if kind in ("attn", "local", "moe"):
        hn = rms_norm(h, p["ln1"]["scale"])
        out, new_c = multihead_attention(
            p["attn"],
            hn,
            q_positions=q_positions,
            rope_theta=cfg.rope_theta,
            causal=cfg.causal,
            window=cfg.sliding_window if kind == "local" else 0,
            softcap=cfg.logit_softcap,
            cache=cache,
            cache_index=cache_index,
            q_block=cfg.q_block,
        )
        h = h + out
        hn2 = rms_norm(h, p["ln2"]["scale"])
        if kind == "moe":
            mo, aux = moe_ffn(
                p["moe"],
                hn2,
                top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor,
                dispatch=cfg.moe_dispatch,
            )
        else:
            mo = swiglu(hn2, p["mlp"])
        return h + mo, new_c, aux
    if kind in ("mamba1", "mamba2"):
        hn = rms_norm(h, p["ln1"]["scale"])
        out, new_c = mamba_block(
            p["mamba"],
            hn,
            variant=kind,
            d_state=cfg.ssm_state,
            head_p=cfg.head_p,
            chunk=cfg.ssm_chunk,
            cache=cache,
        )
        return h + out, new_c, aux
    raise ValueError(kind)


def embed_inputs(params: Pytree, cfg: ModelConfig, batch: Dict) -> jax.Array:
    """batch: {"tokens": [B,St]} (+ "patches"/"frames" per frontend)."""
    if cfg.frontend == "audio":
        return batch["frames"] @ params["frontend_proj"]
    h = embed_tokens(batch["tokens"], params["embed"])
    if cfg.frontend == "vision_text" and "patches" in batch:
        ph = batch["patches"] @ params["frontend_proj"]
        h = jnp.concatenate([ph.astype(h.dtype), h], axis=1)
    return h


def forward(
    params: Pytree,
    cfg: ModelConfig,
    h: jax.Array,  # [B, S, d] embedded inputs (see embed_inputs)
    *,
    caches: Optional[Pytree] = None,
    position: Optional[jax.Array] = None,  # decode: current absolute position
    remat: bool = False,
    h_sharding=None,  # sequence-parallel constraint on layer-boundary h
) -> Tuple[jax.Array, Optional[Pytree], jax.Array]:
    """Returns (final hidden [B,S,d], updated caches, aux loss).

    h_sharding (a NamedSharding/PartitionSpec or None) is applied to the
    residual stream at every layer boundary: the stored scan carries —
    the dominant activation-memory term, L x B x S x d — are then sharded
    (Megatron-style sequence parallelism when it maps S to the model axis).
    """
    B, S, _ = h.shape
    per = len(cfg.pattern)
    n_per = cfg.num_layers // per
    decode = position is not None
    if decode:
        q_positions = position[None].astype(jnp.int32)
        cache_index = position.astype(jnp.int32)
    else:
        q_positions = jnp.arange(S, dtype=jnp.int32)
        cache_index = jnp.int32(0)

    shared_p = params.get("shared_attn")
    shared_cache0 = caches.get("shared") if caches else None

    def apply_shared(h, shared_cache, global_idx):
        hn = rms_norm(h, shared_p["ln"]["scale"])
        if shared_cache is not None:
            s_idx = (global_idx + 1) // cfg.shared_attn_every - 1
            cs = jax.tree.map(
                lambda u: jax.lax.dynamic_index_in_dim(u, s_idx, 0, keepdims=False),
                shared_cache,
            )
        else:
            cs = None
        out, new_cs = multihead_attention(
            shared_p["attn"],
            hn,
            q_positions=q_positions,
            rope_theta=cfg.rope_theta,
            causal=cfg.causal,
            softcap=cfg.logit_softcap,
            cache=cs,
            cache_index=cache_index,
            q_block=cfg.q_block,
        )
        if shared_cache is not None:
            shared_cache = jax.tree.map(
                lambda full, ns: jax.lax.dynamic_update_index_in_dim(
                    full, ns, s_idx, 0
                ),
                shared_cache,
                new_cs,
            )
        return h + out, shared_cache

    def body(carry, xs):
        h, shared_cache, aux = carry
        if h_sharding is not None:
            h = jax.lax.with_sharding_constraint(h, h_sharding)
        bp, layer_caches, i_per = xs
        new_caches = {}
        for j, kind in enumerate(cfg.pattern):
            key = f"{j}_{kind}"
            c_j = layer_caches[key] if layer_caches is not None else None
            h, new_c, a = _apply_layer(
                kind, bp[key], cfg, h, c_j, q_positions, cache_index
            )
            aux = aux + a
            if layer_caches is not None:
                new_caches[key] = new_c
            gi = i_per * per + j
            if cfg.shared_attn_every:
                do_shared = (gi + 1) % cfg.shared_attn_every == 0
                h, shared_cache = jax.lax.cond(
                    do_shared,
                    lambda h, sc: apply_shared(h, sc, gi),
                    lambda h, sc: (h, sc),
                    h,
                    shared_cache,
                )
        return (h, shared_cache, aux), (new_caches if layer_caches is not None else None)

    if remat:
        body = jax.checkpoint(body)

    layer_caches = caches["layers"] if caches else None
    xs = (params["blocks"], layer_caches, jnp.arange(n_per))
    (h, shared_cache, aux), new_layer_caches = jax.lax.scan(
        body, (h, shared_cache0, jnp.float32(0.0)), xs
    )
    h = rms_norm(h, params["final_norm"]["scale"])
    new_caches = None
    if caches is not None:
        new_caches = {"layers": new_layer_caches}
        if cfg.shared_attn_every:
            new_caches["shared"] = shared_cache
    return h, new_caches, aux


def logits_from_hidden(params: Pytree, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    if cfg.frontend == "audio":
        logits = (h @ params["out_head"]).astype(jnp.float32)
        if cfg.final_softcap > 0.0:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        return logits
    return unembed(h, params["embed"], cfg.final_softcap)


def chunked_lm_loss(
    params: Pytree,
    cfg: ModelConfig,
    h: jax.Array,  # [B, S, d]
    labels: jax.Array,  # [B, S] int32, -1 = ignore
    chunk: int = 512,
) -> jax.Array:
    """Token CE without materializing [B, S, V]: checkpointed chunks over S."""
    B, S, _ = h.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    hc = h.reshape(B, nc, chunk, -1).swapaxes(0, 1)
    lc = labels.reshape(B, nc, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def one(hb, lb):
        logits = logits_from_hidden(params, cfg, hb)
        logz = jax.nn.logsumexp(logits, axis=-1)
        safe = jnp.maximum(lb, 0)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        valid = lb >= 0
        return jnp.sum(jnp.where(valid, logz - gold, 0.0)), jnp.sum(valid)

    def scan_body(acc, xs):
        s, n = one(*xs)
        return (acc[0] + s, acc[1] + n), None

    (tot, cnt), _ = jax.lax.scan(
        scan_body, (jnp.float32(0.0), jnp.float32(0.0)), (hc, lc)
    )
    return tot / jnp.maximum(cnt, 1.0)
