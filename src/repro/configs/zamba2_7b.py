"""zamba2-7b [hybrid] — Mamba-2 backbone with a single SHARED attention block
applied every 6 layers [arXiv:2411.15242]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,  # shared-block MLP dim (recorded; shared block here is attn)
    vocab_size=32000,
    pattern=("mamba2",),
    shared_attn_every=6,
    ssm_state=64,
    d_inner=7168,  # 2 * d_model
    head_p=64,
    conv_width=4,
    fed_mode="A",
    supports_decode=True,
    supports_long_context=True,  # SSM backbone; shared attn context-parallel
    citation="arXiv:2411.15242",
)
