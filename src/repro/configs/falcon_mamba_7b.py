"""falcon-mamba-7b [ssm] — attention-free Mamba-1 [arXiv:2410.05355]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    arch_type="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=1,  # unused (attention-free)
    num_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    pattern=("mamba1",),
    ssm_state=16,
    d_inner=8192,  # 2 * d_model
    conv_width=4,
    fed_mode="A",
    supports_decode=True,
    supports_long_context=True,  # O(1) recurrent state
    citation="arXiv:2410.05355",
)
