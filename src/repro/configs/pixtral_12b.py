"""pixtral-12b [vlm] — pixtral-ViT frontend (stubbed) + mistral-nemo decoder
[hf:mistralai/Pixtral-12B-2409]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    arch_type="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    pattern=("attn",),
    frontend="vision_text",
    num_patches=256,
    frontend_dim=1024,
    rope_theta=1e6,
    fed_mode="A",
    supports_decode=True,
    supports_long_context=False,
    citation="hf:mistralai/Pixtral-12B-2409",
)
