"""starcoder2-7b [dense] — GQA + RoPE code model [arXiv:2402.19173]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    arch_type="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    pattern=("attn",),
    fed_mode="A",
    supports_decode=True,
    supports_long_context=False,
    citation="arXiv:2402.19173",
)
