"""llama4-scout-17b-a16e [moe] — 16-expert top-1 MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    pattern=("moe",),
    num_experts=16,
    top_k=1,
    rope_theta=5e5,
    fed_mode="B",  # experts sharded over the data axis -> agents over pods
    supports_decode=True,
    supports_long_context=False,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
)
