"""Architecture and input-shape configuration dataclasses."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # layer pattern, cycled over layers. entries:
    #   "attn"   — global attention + dense MLP
    #   "local"  — sliding-window attention + dense MLP
    #   "moe"    — global attention + MoE FFN
    #   "mamba1" / "mamba2" — SSM block (no attention/MLP)
    pattern: Tuple[str, ...] = ("attn",)
    sliding_window: int = 4096
    logit_softcap: float = 0.0  # attention logit softcap (gemma2)
    final_softcap: float = 0.0  # final-logit softcap (gemma2)
    rope_theta: float = 10000.0
    causal: bool = True  # False => encoder-only (hubert)
    # MoE
    num_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    moe_dispatch: str = "einsum"
    # SSM
    ssm_state: int = 0
    d_inner: int = 0
    conv_width: int = 4
    head_p: int = 64  # mamba2 head size
    ssm_chunk: int = 256
    # hybrid (zamba2): apply a single SHARED attention block after every
    # `shared_attn_every` pattern layers (0 = disabled)
    shared_attn_every: int = 0
    # modality frontend: "text" | "audio" | "vision_text"
    frontend: str = "text"
    num_patches: int = 256  # vision_text: patches prepended to the text
    frontend_dim: int = 1024  # embedding dim delivered by the stub frontend
    # distribution
    fed_mode: str = "A"  # A: agents over (pod,data); B: agents over (pod,)
    correction_dtype: Optional[str] = None  # e.g. "float8_e4m3fn"
    # communication strategy knobs (repro.fed.strategies): fraction of
    # clients sampled per round, kept fraction of sparsified tracking
    # corrections, and stochastic-quantization bit-width for them;
    # participation/compression_ratio 1.0 and quantization_bits >= 32 =
    # plain FedGDA-GT
    participation: float = 1.0
    compression_ratio: float = 1.0
    quantization_bits: int = 32
    # stochastic-gradient family (repro.fed.noise): "none" keeps the
    # deterministic oracle (bitwise-pinned legacy traces); "gaussian" /
    # "minibatch" wrap every local/anchor gradient eval in the named
    # NoiseModel, seeded from the DEDICATED noise stream (noise_seed ->
    # fed.noise.noise_key, never the sampling/compression RNG folds).
    # momentum > 0 runs Local-SGDA+-style heavy-ball local steps
    # (optim.momentum.heavy_ball) and voids the fused-anchor shortcut.
    noise: str = "none"
    noise_sigma: float = 0.1
    noise_fraction: float = 0.5
    noise_seed: int = 0
    momentum: float = 0.0
    # encode compressed corrections as REAL packed (value, index, scale)
    # payloads (repro.fed.transport) instead of dense masked trees —
    # identical iterates, packed payload bytes matching bytes_per_round
    wire_transport: bool = False
    # round execution schedule: "sync" lowers the whole round as one
    # fused program; "async" is the phase-dispatched runtime
    # (fed.async_runtime / launch.multihost) — per-agent-shard phase
    # programs, server-side exchange, packed-payload all-gather (the
    # dry-run tags its artifacts "__async" and adds the gather census)
    runtime: str = "sync"
    # client-population scenario (repro.sim.scenarios): "stable" is the
    # paper's full synchronous participation; any other preset (flaky /
    # diurnal / straggler_heavy) makes the launchers run the
    # membership-aware elastic round over a seeded RoundSchedule
    population: str = "stable"
    # two-level aggregation tree (agents -> pods -> server): 0 disables
    # the pod tier; > 0 splits the fed-axes devices into that many
    # contiguous pod groups (launch.mesh.pod_device_groups) and the
    # dry-run records the pod plan + per-pod wire price (--pods).
    # Must divide the federated device count of the target mesh
    pods: int = 0
    # shape support
    supports_decode: bool = True
    supports_long_context: bool = False
    # attention q-blocking (memory bound for the jnp path)
    q_block: int = 512
    citation: str = ""

    @property
    def layer_types(self) -> Tuple[str, ...]:
        reps = -(-self.num_layers // len(self.pattern))  # ceil
        return (self.pattern * reps)[: self.num_layers]

    def reduced(self) -> "ModelConfig":
        """2-layer / d_model<=512 / <=4-expert variant of the same family
        for CPU smoke tests (same pattern, same code paths)."""
        num_layers = max(2, min(2, self.num_layers))
        if len(self.pattern) > 1:
            num_layers = len(self.pattern)
        d_model = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = max(1, min(self.num_kv_heads, heads))
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=32,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            d_inner=min(self.d_inner, 512) if self.d_inner else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            head_p=16,
            ssm_chunk=32,
            sliding_window=64,
            num_patches=8,
            frontend_dim=64,
            q_block=64,
            shared_attn_every=2 if self.shared_attn_every else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
