"""Architecture registry: 10 assigned architectures + input shapes."""
from .base import INPUT_SHAPES, ModelConfig, ShapeConfig
from .granite_34b import CONFIG as granite_34b
from .gemma2_2b import CONFIG as gemma2_2b
from .pixtral_12b import CONFIG as pixtral_12b
from .hubert_xlarge import CONFIG as hubert_xlarge
from .falcon_mamba_7b import CONFIG as falcon_mamba_7b
from .llama4_scout_17b_a16e import CONFIG as llama4_scout_17b_a16e
from .llama4_maverick_400b_a17b import CONFIG as llama4_maverick_400b_a17b
from .starcoder2_7b import CONFIG as starcoder2_7b
from .granite_8b import CONFIG as granite_8b
from .zamba2_7b import CONFIG as zamba2_7b

ARCHS = {
    c.name: c
    for c in [
        granite_34b,
        gemma2_2b,
        pixtral_12b,
        hubert_xlarge,
        falcon_mamba_7b,
        llama4_scout_17b_a16e,
        llama4_maverick_400b_a17b,
        starcoder2_7b,
        granite_8b,
        zamba2_7b,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def supported_shapes(cfg: ModelConfig):
    """The (documented) subset of INPUT_SHAPES an architecture runs."""
    out = []
    for s in INPUT_SHAPES.values():
        if s.kind == "decode":
            if not cfg.supports_decode:
                continue
            if s.name == "long_500k" and not cfg.supports_long_context:
                continue
        out.append(s)
    return out


__all__ = [
    "ARCHS",
    "INPUT_SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "supported_shapes",
]
