"""gemma2-2b [dense] — local+global alternating attention, logit softcaps
[arXiv:2408.00118]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    arch_type="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    pattern=("local", "attn"),  # alternating sliding-window / global
    sliding_window=4096,
    logit_softcap=50.0,
    final_softcap=30.0,
    fed_mode="A",
    supports_decode=True,
    # local layers bound the KV ring buffer; global layers run
    # context-parallel over the data axis at 500k
    supports_long_context=True,
    citation="arXiv:2408.00118",
)
