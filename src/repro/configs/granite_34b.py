"""granite-34b [dense] — llama-arch code model [arXiv:2405.04324]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    arch_type="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,  # MQA
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    pattern=("attn",),
    fed_mode="A",
    supports_decode=True,
    supports_long_context=False,  # pure full attention
    citation="arXiv:2405.04324",
)
