"""granite-8b [dense] — llama-arch code model [arXiv:2405.04324]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    arch_type="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    pattern=("attn",),
    fed_mode="A",
    supports_decode=True,
    supports_long_context=False,
    citation="arXiv:2405.04324",
)
