"""llama4-maverick-400b-a17b [moe] — 128-expert top-1 MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

The gradient-tracking correction for this model is stored in float8_e4m3fn
(beyond-paper memory optimization, see DESIGN.md §4 and EXPERIMENTS §Perf):
with m=2 pod-agents the GT state would otherwise exceed v5e HBM.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    pattern=("moe",),
    num_experts=128,
    top_k=1,
    rope_theta=5e5,
    fed_mode="B",
    correction_dtype="float8_e4m3fn",
    supports_decode=True,
    supports_long_context=False,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
)
