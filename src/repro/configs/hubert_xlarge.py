"""hubert-xlarge [audio] — encoder-only transformer over conv-codec frames
(frontend stubbed) [arXiv:2106.07447]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,  # full MHA
    head_dim=80,
    d_ff=5120,
    vocab_size=504,  # masked-unit targets
    pattern=("attn",),
    causal=False,  # bidirectional encoder
    frontend="audio",
    frontend_dim=512,
    fed_mode="A",
    supports_decode=False,  # encoder-only: no decode shapes
    supports_long_context=False,
    citation="arXiv:2106.07447",
)
