"""Paper Section 5.2 — robust linear regression under gross contamination.

Reproduces Figure 2: FedGDA-GT vs Local SGDA at three heterogeneity levels
alpha in {1, 5, 20}, printing robust-loss trajectories and each method's
distance from the centralized projected-GDA reference solution.

    PYTHONPATH=src python examples/robust_regression.py
"""
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core import make_fedgda_gt_round, make_local_sgda_round
from repro.problems import make_robust_regression_problem, robust_loss

DIM, N, M, K, T = 20, 100, 10, 10, 400


def stable_eta(prob) -> float:
    a = prob.agent_data["a"]
    H = 2 * jnp.einsum("mnd,mne->de", a, a) / (a.shape[0] * a.shape[1])
    return 0.1 / float(jnp.linalg.eigvalsh(H + jnp.eye(DIM))[-1])


def main() -> None:
    for alpha in (1.0, 5.0, 20.0):
        prob = make_robust_regression_problem(
            jax.random.PRNGKey(0), dim=DIM, num_samples=N, num_agents=M,
            alpha=alpha,
        )
        eta = stable_eta(prob)
        r_gt = jax.jit(make_fedgda_gt_round(prob.loss, K, eta, proj_y=prob.proj_y))
        r_ls = jax.jit(
            make_local_sgda_round(prob.loss, K, eta, eta, proj_y=prob.proj_y)
        )
        z = jnp.zeros(DIM)
        xg, yg, xl, yl = z, z, z, z
        print(f"\n== alpha={alpha} (eta={eta:.2e}) ==")
        print(f"{'round':>6} {'robust_loss GT':>16} {'robust_loss LS':>16}")
        for t in range(T + 1):
            if t % (T // 4) == 0:
                print(
                    f"{t:6d} {float(robust_loss(prob, xg)):16.4f} "
                    f"{float(robust_loss(prob, xl)):16.4f}"
                )
            if t < T:
                xg, yg = r_gt(xg, yg, prob.agent_data)
                xl, yl = r_ls(xl, yl, prob.agent_data)
        # reference: centralized projected GDA with the same step budget
        r_c = jax.jit(
            make_local_sgda_round(prob.loss, 1, eta, eta, proj_y=prob.proj_y)
        )
        xc, yc = z, z
        for _ in range(T * K):
            xc, yc = r_c(xc, yc, prob.agent_data)
        print(
            f"   dist to centralized solution: "
            f"GT={float(jnp.linalg.norm(xg - xc)):.2e}  "
            f"LS={float(jnp.linalg.norm(xl - xc)):.2e}"
        )


if __name__ == "__main__":
    main()
