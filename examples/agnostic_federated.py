"""Agnostic Federated Learning (paper Appendix A.2 / Mohri et al.) with
FedGDA-GT: learn a model that is minimax-fair over agent distributions.

x = regression model, y = mixture weights lambda on the simplex; the
adversary shifts weight onto the worst-served agents, and the saddle point
equalizes their risks.

    PYTHONPATH=src python examples/agnostic_federated.py
"""
import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import make_fedgda_gt_round
from repro.problems import (
    make_agnostic_problem,
    per_agent_risks,
    uniform_lambda,
)

M, DIM, T = 5, 8, 1500


def main() -> None:
    prob = make_agnostic_problem(
        jax.random.PRNGKey(0), dim=DIM, num_samples=80, num_agents=M, shift=4.0
    )
    rnd = jax.jit(make_fedgda_gt_round(prob.loss, 5, 2e-3, proj_y=prob.proj_y))
    frozen = jax.jit(
        make_fedgda_gt_round(prob.loss, 5, 2e-3, proj_y=lambda y: uniform_lambda(M))
    )
    x0, y0 = jnp.zeros(DIM), uniform_lambda(M)
    xa, ya = x0, y0
    xu, yu = x0, y0
    for t in range(T):
        xa, ya = rnd(xa, ya, prob.agent_data)
        xu, yu = frozen(xu, yu, prob.agent_data)
    ra = np.asarray(per_agent_risks(prob, xa))
    ru = np.asarray(per_agent_risks(prob, xu))
    print("agents have CONFLICTING true models (disagreement grows with i)\n")
    print(f"{'agent':>6} {'uniform-FL risk':>16} {'agnostic risk':>14} {'lambda*':>9}")
    for i in range(M):
        print(f"{i:6d} {ru[i]:16.4f} {ra[i]:14.4f} {float(ya[i]):9.4f}")
    print(f"\nworst-agent risk:  uniform={ru.max():.4f}  agnostic={ra.max():.4f}")
    print(f"risk spread:       uniform={ru.max()-ru.min():.4f}  "
          f"agnostic={ra.max()-ra.min():.4f}")
    print("\nthe agnostic model trades mean risk for worst-case fairness —")
    print("solved by the SAME FedGDA-GT round as every other problem here.")


if __name__ == "__main__":
    main()
