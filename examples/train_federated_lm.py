"""End-to-end driver: federated adversarial training of a language model
with FedGDA-GT (deliverable b).

x = transformer parameters, y = universal adversarial embedding
perturbation with ||y|| <= 1 (the paper's Eq.-14 robustness structure
lifted to sequence models; DESIGN.md §2).  Heterogeneous agents hold
synthetic token streams with shifted vocabularies.

Defaults train a ~25M-parameter llama-family model for 60 rounds so the
script finishes on a laptop CPU; `--full` switches to the ~100M model /
300 rounds configuration:

    PYTHONPATH=src python examples/train_federated_lm.py
    PYTHONPATH=src python examples/train_federated_lm.py --full
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_checkpoint, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core import make_fedgda_gt_round
from repro.data import federated_token_batches
from repro.core import communication_bytes_per_round
from repro.models import init_params, num_params
from repro.problems.adversarial import (
    delta_projection,
    init_delta,
    make_adversarial_loss,
)


def model_config(full: bool):
    base = get_config("granite-8b")  # llama-family block structure
    if full:  # ~100M params
        return dataclasses.replace(
            base, name="granite-100m", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
            vocab_size=32768, q_block=512,
        )
    return dataclasses.replace(  # ~25M params
        base, name="granite-25m", num_layers=6, d_model=384,
        num_heads=6, num_kv_heads=2, head_dim=64, d_ff=1024,
        vocab_size=16384, q_block=256,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2, help="per-agent batch")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--eta", type=float, default=5e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/fedgda_lm_ckpt")
    args = ap.parse_args()
    rounds = args.rounds or (300 if args.full else 60)

    cfg = model_config(args.full)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    delta = init_delta(cfg)
    print(
        f"model={cfg.name} params={num_params(params)/1e6:.1f}M "
        f"agents={args.agents} K={args.local_steps} rounds={rounds}"
    )
    print(
        "bytes/round (star-topology model): "
        f"{communication_bytes_per_round(params, delta, 'fedgda_gt', args.local_steps)/2**20:.1f} MiB"
    )

    data = federated_token_batches(
        jax.random.PRNGKey(1), args.agents, args.batch, args.seq_len,
        cfg.vocab_size, heterogeneity=cfg.vocab_size // (2 * args.agents),
    )
    loss = make_adversarial_loss(cfg, remat=False)
    rnd = jax.jit(
        make_fedgda_gt_round(
            loss, args.local_steps, args.eta, proj_y=delta_projection(1.0)
        )
    )

    @jax.jit
    def global_loss(x, y):
        per = jax.vmap(loss, in_axes=(None, None, 0))(x, y, data)
        return jnp.mean(per)

    # resume if a checkpoint exists
    start = 0
    found = latest_checkpoint(args.ckpt_dir)
    if found:
        start, path = found
        state = restore_checkpoint(path)
        params, delta = state["x"], state["y"]
        print(f"resumed from round {start}")

    t0 = time.time()
    for t in range(start, rounds):
        params, delta = rnd(params, delta, data)
        if t % 10 == 0 or t == rounds - 1:
            lv = float(global_loss(params, delta))
            dn = float(jnp.linalg.norm(delta["delta"]))
            print(
                f"[round {t:4d}] global_loss={lv:.4f} |delta|={dn:.3f} "
                f"({time.time()-t0:.0f}s)",
                flush=True,
            )
        if (t + 1) % 50 == 0:
            save_checkpoint(args.ckpt_dir, t + 1, {"x": params, "y": delta})
    print("done — adversarially-robust LM trained with 2 model-sized")
    print("messages per round instead of K (Theorem 1's schedule).")


if __name__ == "__main__":
    main()
